"""Docs gate: links resolve, the benchmark table is complete, examples run.

    PYTHONPATH=src python tools/check_docs.py              # everything
    PYTHONPATH=src python tools/check_docs.py --links-only # fast (tier-1)

Four checks over README.md + docs/*.md:

1. **links** — every relative markdown link/image target exists
   (anchors stripped; http(s)/mailto links are skipped);
2. **benchmark table** — every module in ``benchmarks.run.BENCHES``
   is mentioned in docs/benchmarks.md, and every ``benchmarks/*.py``
   path mentioned anywhere in the docs exists (the figure → script map
   cannot rot in either direction);
3. **cli flags** — every ``--flag`` token in a markdown table row is
   cross-checked against the launcher's real argparse parser.  The
   launcher context is the most recent ``repro.launch.<name>`` mention
   in the file; launchers expose ``build_parser()`` for this.  A
   documented flag the parser does not define fails the check;
4. **examples** — every fenced ```python block executes in a fresh
   interpreter with PYTHONPATH=src and smoke sizes
   (REPRO_BENCH_SMOKE=1).  A block preceded by an HTML comment line
   ``<!-- docs: no-run -->`` is skipped.

Exit status is non-zero on the first category with failures.
"""
from __future__ import annotations

import argparse
import os
import re
import subprocess
import sys
from pathlib import Path
from typing import List, Tuple

ROOT = Path(__file__).resolve().parent.parent
DOC_FILES = [ROOT / "README.md"] + sorted((ROOT / "docs").glob("*.md"))

LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)\)")
FENCE_RE = re.compile(r"^```(\w*)\s*$")
NO_RUN = "<!-- docs: no-run -->"


def check_links() -> List[str]:
    errors = []
    for md in DOC_FILES:
        for n, line in enumerate(md.read_text().splitlines(), 1):
            for target in LINK_RE.findall(line):
                if target.startswith(("http://", "https://", "mailto:",
                                      "#")):
                    continue
                path = (md.parent / target.split("#")[0]).resolve()
                if not path.is_relative_to(ROOT):
                    # only GitHub-side virtual paths (the CI badge) may
                    # escape the repo; anything else is a broken link
                    if "actions/workflows" not in target:
                        errors.append(f"{md.relative_to(ROOT)}:{n}: "
                                      f"link escapes the repo -> {target}")
                    continue
                if not path.exists():
                    errors.append(f"{md.relative_to(ROOT)}:{n}: "
                                  f"broken link -> {target}")
    return errors


def check_benchmark_table() -> List[str]:
    errors = []
    sys.path.insert(0, str(ROOT))
    from benchmarks.run import BENCHES
    table = (ROOT / "docs" / "benchmarks.md").read_text()
    for name in BENCHES:
        if f"benchmarks/{name}.py" not in table:
            errors.append(f"docs/benchmarks.md: missing row for "
                          f"benchmarks/{name}.py (in benchmarks.run."
                          f"BENCHES)")
    # any benchmarks/*.py path mentioned in any doc must exist
    for md in DOC_FILES:
        for m in re.finditer(r"benchmarks/(\w+)\.py", md.read_text()):
            if not (ROOT / "benchmarks" / f"{m.group(1)}.py").exists():
                errors.append(f"{md.relative_to(ROOT)}: references "
                              f"missing {m.group(0)}")
    return errors


LAUNCH_RE = re.compile(r"repro\.launch\.(\w+)")
FLAG_RE = re.compile(r"--[\w][\w-]*")


def _parser_flags(launcher: str):
    """Option strings of repro.launch.<launcher>'s argparse parser, or
    None when the module does not expose build_parser()."""
    import importlib
    mod = importlib.import_module(f"repro.launch.{launcher}")
    build = getattr(mod, "build_parser", None)
    if build is None:
        return None
    return {s for action in build()._actions
            for s in action.option_strings}


def check_cli_flags() -> List[str]:
    errors: List[str] = []
    sys.path.insert(0, str(ROOT / "src"))
    cache: dict = {}
    for md in DOC_FILES:
        context = None
        in_fence = False
        for n, line in enumerate(md.read_text().splitlines(), 1):
            if line.strip().startswith("```"):
                in_fence = not in_fence
            m = LAUNCH_RE.search(line)
            if m:
                # fenced shell examples legitimately set the context too
                context = m.group(1)
            if in_fence or not line.lstrip().startswith("|"):
                continue
            flags = FLAG_RE.findall(line)
            if not flags or context is None:
                continue
            where = f"{md.relative_to(ROOT)}:{n}"
            if context not in cache:
                try:
                    cache[context] = _parser_flags(context)
                except Exception as e:   # pragma: no cover - import rot
                    cache[context] = e
            known = cache[context]
            if isinstance(known, Exception):
                errors.append(f"{where}: cannot import repro.launch."
                              f"{context} to verify flags: {known}")
                continue
            if known is None:
                errors.append(f"{where}: repro.launch.{context} exposes "
                              f"no build_parser() to verify flags "
                              f"against")
                continue
            for flag in flags:
                if flag not in known:
                    errors.append(f"{where}: documents {flag}, not a "
                                  f"repro.launch.{context} flag")
    return errors


def extract_python_blocks(md: Path) -> List[Tuple[int, str]]:
    blocks, buf, lang, start = [], [], None, 0
    skip_next = False
    for n, line in enumerate(md.read_text().splitlines(), 1):
        if lang is None and line.strip() == NO_RUN:
            skip_next = True
            continue
        m = FENCE_RE.match(line.strip())
        if m and lang is None:
            lang, buf, start = m.group(1), [], n
            continue
        if line.strip() == "```" and lang is not None:
            if lang == "python" and not skip_next:
                blocks.append((start, "\n".join(buf)))
            lang = None
            skip_next = False
            continue
        if lang is not None:
            buf.append(line)
    return blocks


def check_examples() -> List[str]:
    errors = []
    env = dict(os.environ,
               PYTHONPATH=f"{ROOT / 'src'}:{os.environ.get('PYTHONPATH', '')}",
               REPRO_BENCH_SMOKE="1")
    for md in DOC_FILES:
        for start, code in extract_python_blocks(md):
            proc = subprocess.run(
                [sys.executable, "-"], input=code, text=True,
                capture_output=True, cwd=ROOT, env=env, timeout=600)
            where = f"{md.relative_to(ROOT)}: python block at line {start}"
            if proc.returncode != 0:
                tail = proc.stderr.strip().splitlines()[-8:]
                errors.append(where + " failed:\n    "
                              + "\n    ".join(tail))
            else:
                print(f"ok: {where}")
    return errors


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--links-only", action="store_true",
                    help="skip executing the fenced python examples")
    args = ap.parse_args()

    failures = 0
    for title, errs in (("links", check_links()),
                        ("benchmark table", check_benchmark_table()),
                        ("cli flags", check_cli_flags())):
        if errs:
            failures += len(errs)
            print(f"FAIL [{title}]:")
            for e in errs:
                print(f"  {e}")
        else:
            print(f"ok: {title} ({len(DOC_FILES)} files)")
    if not args.links_only:
        errs = check_examples()
        if errs:
            failures += len(errs)
            print("FAIL [examples]:")
            for e in errs:
                print(f"  {e}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Docs gate: links resolve, the benchmark table is complete, examples run.

    PYTHONPATH=src python tools/check_docs.py              # everything
    PYTHONPATH=src python tools/check_docs.py --links-only # fast (tier-1)

Three checks over README.md + docs/*.md:

1. **links** — every relative markdown link/image target exists
   (anchors stripped; http(s)/mailto links are skipped);
2. **benchmark table** — every module in ``benchmarks.run.BENCHES``
   is mentioned in docs/benchmarks.md, and every ``benchmarks/*.py``
   path mentioned anywhere in the docs exists (the figure → script map
   cannot rot in either direction);
3. **examples** — every fenced ```python block executes in a fresh
   interpreter with PYTHONPATH=src and smoke sizes
   (REPRO_BENCH_SMOKE=1).  A block preceded by an HTML comment line
   ``<!-- docs: no-run -->`` is skipped.

Exit status is non-zero on the first category with failures.
"""
from __future__ import annotations

import argparse
import os
import re
import subprocess
import sys
from pathlib import Path
from typing import List, Tuple

ROOT = Path(__file__).resolve().parent.parent
DOC_FILES = [ROOT / "README.md"] + sorted((ROOT / "docs").glob("*.md"))

LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)\)")
FENCE_RE = re.compile(r"^```(\w*)\s*$")
NO_RUN = "<!-- docs: no-run -->"


def check_links() -> List[str]:
    errors = []
    for md in DOC_FILES:
        for n, line in enumerate(md.read_text().splitlines(), 1):
            for target in LINK_RE.findall(line):
                if target.startswith(("http://", "https://", "mailto:",
                                      "#")):
                    continue
                path = (md.parent / target.split("#")[0]).resolve()
                if not path.is_relative_to(ROOT):
                    # only GitHub-side virtual paths (the CI badge) may
                    # escape the repo; anything else is a broken link
                    if "actions/workflows" not in target:
                        errors.append(f"{md.relative_to(ROOT)}:{n}: "
                                      f"link escapes the repo -> {target}")
                    continue
                if not path.exists():
                    errors.append(f"{md.relative_to(ROOT)}:{n}: "
                                  f"broken link -> {target}")
    return errors


def check_benchmark_table() -> List[str]:
    errors = []
    sys.path.insert(0, str(ROOT))
    from benchmarks.run import BENCHES
    table = (ROOT / "docs" / "benchmarks.md").read_text()
    for name in BENCHES:
        if f"benchmarks/{name}.py" not in table:
            errors.append(f"docs/benchmarks.md: missing row for "
                          f"benchmarks/{name}.py (in benchmarks.run."
                          f"BENCHES)")
    # any benchmarks/*.py path mentioned in any doc must exist
    for md in DOC_FILES:
        for m in re.finditer(r"benchmarks/(\w+)\.py", md.read_text()):
            if not (ROOT / "benchmarks" / f"{m.group(1)}.py").exists():
                errors.append(f"{md.relative_to(ROOT)}: references "
                              f"missing {m.group(0)}")
    return errors


def extract_python_blocks(md: Path) -> List[Tuple[int, str]]:
    blocks, buf, lang, start = [], [], None, 0
    skip_next = False
    for n, line in enumerate(md.read_text().splitlines(), 1):
        if lang is None and line.strip() == NO_RUN:
            skip_next = True
            continue
        m = FENCE_RE.match(line.strip())
        if m and lang is None:
            lang, buf, start = m.group(1), [], n
            continue
        if line.strip() == "```" and lang is not None:
            if lang == "python" and not skip_next:
                blocks.append((start, "\n".join(buf)))
            lang = None
            skip_next = False
            continue
        if lang is not None:
            buf.append(line)
    return blocks


def check_examples() -> List[str]:
    errors = []
    env = dict(os.environ,
               PYTHONPATH=f"{ROOT / 'src'}:{os.environ.get('PYTHONPATH', '')}",
               REPRO_BENCH_SMOKE="1")
    for md in DOC_FILES:
        for start, code in extract_python_blocks(md):
            proc = subprocess.run(
                [sys.executable, "-"], input=code, text=True,
                capture_output=True, cwd=ROOT, env=env, timeout=600)
            where = f"{md.relative_to(ROOT)}: python block at line {start}"
            if proc.returncode != 0:
                tail = proc.stderr.strip().splitlines()[-8:]
                errors.append(where + " failed:\n    "
                              + "\n    ".join(tail))
            else:
                print(f"ok: {where}")
    return errors


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--links-only", action="store_true",
                    help="skip executing the fenced python examples")
    args = ap.parse_args()

    failures = 0
    for title, errs in (("links", check_links()),
                        ("benchmark table", check_benchmark_table())):
        if errs:
            failures += len(errs)
            print(f"FAIL [{title}]:")
            for e in errs:
                print(f"  {e}")
        else:
            print(f"ok: {title} ({len(DOC_FILES)} files)")
    if not args.links_only:
        errs = check_examples()
        if errs:
            failures += len(errs)
            print("FAIL [examples]:")
            for e in errs:
                print(f"  {e}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())

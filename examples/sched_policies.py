"""Scheduling-policy comparison through the Digital Twin's fast path.

Fits the Eq. (1) estimators from synthetic-engine probes, then serves
the *same* rotating-hot-phase skewed workload once per registered
scheduling policy (``repro.serving.policy``) and prints the
throughput-vs-starvation frontier — the trade each policy makes when a
few adapters go hot and slots are scarce.

    PYTHONPATH=src python examples/sched_policies.py
"""
import os
import sys

sys.path.insert(0, "src")

from repro.core import (FastTwin, WorkloadSpec, collect_benchmark,  # noqa
                        collect_memmax, fit_estimators,
                        generate_drifting_requests, make_adapter_pool,
                        rotating_hot_phases)
from repro.serving import (SCHED_POLICIES, HardwareProfile,  # noqa
                           SyntheticExecutor)


def main():
    smoke = os.environ.get("REPRO_BENCH_SMOKE", "") == "1"
    horizon = 60.0 if smoke else 90.0
    n_adapters, slots = 24, 3

    # creation phase: probe the synthetic engine, fit the estimators
    profile = HardwareProfile()
    ranks = {i: (8, 16)[i % 2] for i in range(n_adapters)}
    ex = SyntheticExecutor(profile, ranks, slots=8, n_adapters=n_adapters,
                           seed=0)
    est = fit_estimators(collect_benchmark(ex, 8, n_adapters, ranks),
                         collect_memmax(profile), 8, n_adapters)

    # a skewed drifting workload: 20% of adapters are hot, and the hot
    # set rotates mid-run — with 3 slots, admission order decides which
    # adapters ever get one
    pool = make_adapter_pool(n_adapters, [8, 16], [0.05])
    phases = rotating_hot_phases(pool, horizon, n_phases=2,
                                 hot_fraction=0.2, hot_rate=1.8,
                                 cold_rate=0.05)
    reqs = generate_drifting_requests(pool, "medium", horizon, phases,
                                      seed=3)
    spec = WorkloadSpec(adapters=pool, dataset="medium", horizon=horizon,
                        seed=3)

    print(f"{'policy':<16} {'thpt tok/s':>10} {'starved':>8} "
          f"{'finished':>8} {'ttft p50':>9} {'ttft p99':>9}")
    results = {}
    for policy in sorted(SCHED_POLICIES):
        twin = FastTwin(est, mode="full", max_running=32,
                        sched_policy=policy)
        m = twin.simulate(spec, slots=slots, requests=reqs).metrics
        results[policy] = m
        print(f"{policy:<16} {m.throughput:>10.0f} "
              f"{m.n_starved_requests:>8d} {m.n_finished:>8d} "
              f"{m.ttft_p50:>8.1f}s {m.ttft_p99:>8.1f}s")

    fair, fcfs = results["adapter-fair"], results["fcfs"]
    print(f"\nadapter-fair starves {fcfs.n_starved_requests - fair.n_starved_requests} "
          f"fewer requests than fcfs on this skewed point "
          f"({fair.n_starved_requests} vs {fcfs.n_starved_requests}).")


if __name__ == "__main__":
    main()

"""Quickstart: build a reduced model, serve a few batched multi-adapter
requests through the real JAX engine, then ask the Digital Twin to
replicate the run.

    PYTHONPATH=src python examples/quickstart.py

``REPRO_BENCH_SMOKE=1`` shrinks horizons to CI-gate sizes.
"""
import os
import sys

sys.path.insert(0, "src")

SMOKE = os.environ.get("REPRO_BENCH_SMOKE", "") == "1"

import jax  # noqa: E402

from repro.configs import get_reduced  # noqa: E402
from repro.core import (DigitalTwin, collect_benchmark, collect_memmax,  # noqa
                        fit_estimators, WorkloadSpec, generate_requests,
                        make_adapter_pool)
from repro.models import Model, ShardingPlan  # noqa: E402
from repro.serving import (EngineConfig, HardwareProfile, JaxExecutor,  # noqa
                           ServingEngine, SyntheticExecutor, smape)


def main():
    # --- 1. a real (reduced) model served by the real engine -----------
    cfg = get_reduced("phi4-mini-3.8b")
    model = Model(cfg, ShardingPlan(mode="decode"))
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    lora = model.init_lora(key, n_adapters=4, rank=8)
    executor = JaxExecutor(model, params, lora, max_batch=8, cache_len=256)

    horizon = 4.0 if SMOKE else 10.0
    pool = make_adapter_pool(8, ranks=[8], rates=[0.8])
    spec = WorkloadSpec(adapters=pool, dataset="small", horizon=horizon)
    engine = ServingEngine(
        EngineConfig(kv_capacity_tokens=4096, adapter_slots=4), executor)
    m = engine.run(generate_requests(spec), horizon=horizon)
    print(f"[engine/jax] {m.n_finished} finished, "
          f"throughput={m.throughput:.1f} tok/s, itl={m.itl * 1e3:.1f} ms, "
          f"ttft={m.ttft * 1e3:.1f} ms, loads={m.n_loads}")

    # --- 2. the Digital Twin replicating a (synthetic H100) node -------
    profile = HardwareProfile()
    n, slots = 24, 12
    pool = make_adapter_pool(n, [8, 16, 32], [0.2, 0.1, 0.05])
    ranks = {a.uid: a.rank for a in pool}
    ex = SyntheticExecutor(profile, ranks, slots=slots, n_adapters=n)
    est = fit_estimators(collect_benchmark(ex, slots, n, ranks),
                         collect_memmax(profile), slots, n)
    horizon = 40.0 if SMOKE else 120.0
    spec = WorkloadSpec(adapters=pool, dataset="sharegpt", horizon=horizon)
    real = ServingEngine(
        EngineConfig(kv_capacity_tokens=profile.kv_capacity(slots, 18.7),
                     adapter_slots=slots),
        SyntheticExecutor(profile, ranks, slots=slots, n_adapters=n, seed=1)
    ).run(generate_requests(spec), horizon=horizon)
    sim = DigitalTwin(est, mode="full").simulate(
        spec, slots=slots, requests=generate_requests(spec)).metrics
    print(f"[real]  throughput={real.throughput:.1f} tok/s")
    print(f"[twin]  throughput={sim.throughput:.1f} tok/s "
          f"(SMAPE {smape(sim.throughput, real.throughput):.2f}%)")


if __name__ == "__main__":
    main()

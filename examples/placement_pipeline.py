"""The paper's full pipeline (Fig. 1), end to end:

  benchmark real engine -> fit Eq.(1) estimators -> DT scenario sweeps ->
  labelled dataset -> interpretable model -> sub-ms placement recommendations
  (+ extracted decision rules).

    PYTHONPATH=src python examples/placement_pipeline.py

``REPRO_BENCH_SMOKE=1`` shrinks the sweep to CI-gate sizes.
"""
import os
import sys
import time

sys.path.insert(0, "src")

SMOKE = os.environ.get("REPRO_BENCH_SMOKE", "") == "1"

from repro.core import build_pipeline  # noqa: E402
from repro.core.dataset import FEATURE_NAMES, TARGET_NAMES  # noqa: E402
from repro.core.forest import DecisionTree  # noqa: E402


def main():
    t0 = time.time()
    print("creation phase: benchmarking + fitting + DT sweep + training...")
    if SMOKE:
        pipe = build_pipeline(n_scenarios=8, max_adapters=48, horizon=40.0,
                              model_name="forest", verbose=True)
    else:
        pipe = build_pipeline(n_scenarios=24, max_adapters=96,
                              horizon=120.0, model_name="forest",
                              verbose=True)
    print(f"  built in {time.time() - t0:.1f}s; "
          f"held-out SMAPE: {pipe.fit_report}")

    print("\nproduction phase: recommendations")
    for rates, ranks in [([0.2, 0.1, 0.05], [8, 16, 32]),
                         ([1.6, 0.8, 0.4], [8]),
                         ([0.0125, 0.00625], [32])]:
        rec = pipe.recommend(rates, ranks,
                             {"in_mean": 250, "in_std": 0,
                              "out_mean": 231, "out_std": 0})
        print(f"  rates={rates} ranks={ranks} -> "
              f"serve {rec['served_adapters']} adapters with "
              f"{rec['adapter_slots']} slots "
              f"(pred. {rec['throughput']:.0f} tok/s, "
              f"{rec['inference_ms']:.3f} ms inference)")

    print("\ninterpretability: a depth-3 tree distilled from the labels")
    # refit a tiny tree purely for rule extraction
    from repro.core.dataset import label_scenarios, scenario_grid
    xs, ys, _ = label_scenarios(pipe.est,
                                scenario_grid(limit=6 if SMOKE else 12,
                                              seed=3),
                                max_adapters=32 if SMOKE else 64,
                                horizon=30.0 if SMOKE else 80.0)
    tree = DecisionTree(max_depth=3).fit(xs, ys)
    for rule in tree.rules(FEATURE_NAMES, TARGET_NAMES)[:6]:
        print("   ", rule)


if __name__ == "__main__":
    main()

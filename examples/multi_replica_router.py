"""Production phase at fleet scale: the placement model drives a
multi-replica router — packing, slot configuration, failure re-packing
and straggler avoidance — and the Digital Twin verifies each replica's
plan is starvation-free.

    PYTHONPATH=src python examples/multi_replica_router.py
"""
import sys

sys.path.insert(0, "src")

from repro.core import DigitalTwin, WorkloadSpec, build_pipeline, \
    make_adapter_pool  # noqa: E402
from repro.serving import PlacementRouter  # noqa: E402

STATS = {"in_mean": 250, "in_std": 0, "out_mean": 231, "out_std": 0}


def main():
    pipe = build_pipeline(n_scenarios=16, max_adapters=96, horizon=100.0)
    router = PlacementRouter(pipe, n_replicas=4)
    pool = make_adapter_pool(120, [8, 16, 32], [0.2, 0.1, 0.05])
    state = router.plan(pool, STATS)
    print("fleet plan:")
    dt = DigitalTwin(pipe.est, mode="mean")
    for p in state.plans:
        spec = WorkloadSpec(adapters=p.adapters, dataset="medium",
                            horizon=120.0)
        m = dt.simulate(spec, slots=max(p.slots, 1)).metrics
        print(f"  replica {p.replica}: {len(p.adapters)} adapters, "
              f"{p.slots} slots -> DT-verified thpt={m.throughput:.0f} "
              f"tok/s starved={m.starved}")

    print("\nreplica 2 dies -> repack:")
    state = router.report_failure(2, pool, STATS)
    print("  sizes:", [len(p.adapters) for p in state.plans],
          "alive:", [p.alive for p in state.plans])

    print("\nstraggler detection (replica 1 slow):")
    bad = router.observe_itl({0: 0.031, 1: 0.40, 3: 0.029})
    print("  flagged:", bad, "-> new adapters avoid it:",
          {router.route(uid) for uid in range(500, 520)})


if __name__ == "__main__":
    main()

"""Fleet-scale production phase on the real cluster subsystem.

Creation phase fits the Eq. (1) estimators once; `find_cluster_placement`
predicts each replica's (concurrent adapters N*, parallel slots G*) from
the joint workload; the `ClusterDigitalTwin` then scores every routing
policy offline with the *same* `ClusterRouter` the online fleet uses;
finally the winning policy drives a real `ServingCluster` of engine
replicas and we check the DT's cluster prediction against it.

    PYTHONPATH=src python examples/multi_replica_router.py
"""
import sys

sys.path.insert(0, "src")

from repro.core import (ClusterDigitalTwin, WorkloadSpec,  # noqa: E402
                        collect_benchmark, collect_memmax,
                        find_cluster_placement, fit_estimators,
                        generate_drifting_requests, generate_requests,
                        make_adapter_pool, rotating_hot_phases)
from repro.serving import (ClusterRouter, FailureEvent,  # noqa: E402
                           HardwareProfile, RebalancePolicy,
                           ServingCluster, SyntheticExecutor, smape)
from repro.serving.cluster import POLICIES  # noqa: E402

N_REPLICAS = 3
N_ADAPTERS = 48
HORIZON = 120.0


def creation_phase():
    profile = HardwareProfile()
    slots, n = 16, 48
    ranks = {i: (8, 16, 32)[i % 3] for i in range(n)}
    ex = SyntheticExecutor(profile, ranks, slots=slots, n_adapters=n, seed=0)
    est = fit_estimators(collect_benchmark(ex, slots, n, ranks),
                         collect_memmax(profile), slots, n)
    return profile, est


def main():
    profile, est = creation_phase()
    pool = make_adapter_pool(N_ADAPTERS, [8, 16, 32], [0.2, 0.1, 0.05])
    ranks = {a.uid: a.rank for a in pool}

    print(f"1. joint placement for {N_ADAPTERS} adapters on "
          f"{N_REPLICAS} replicas:")
    plan = find_cluster_placement(est, pool, "medium",
                                  n_replicas=N_REPLICAS, horizon=HORIZON)
    for rp in plan.replicas:
        print(f"   replica {rp.replica}: {len(rp.adapters)} adapters -> "
              f"N*={rp.placement.n_adapters} G*={rp.placement.slots} "
              f"pred_thpt={rp.placement.throughput:.0f} tok/s")
    print(f"   predicted cluster throughput: "
          f"{plan.total_throughput:.0f} tok/s")

    print("\n2. DT policy scoring (same router as the online fleet):")
    twin = ClusterDigitalTwin(est, mode="mean")
    mean_rank = sum(a.rank for a in pool) / len(pool)
    specs = twin.specs_from_slots(plan.slots, mean_rank=mean_rank)
    spec = WorkloadSpec(adapters=pool, dataset="medium", horizon=HORIZON,
                        seed=3)
    best, best_m = None, None
    for policy in sorted(POLICIES):
        m = twin.simulate(spec, ClusterRouter(specs, policy=policy)).metrics
        print(f"   {policy:<12} thpt={m.throughput:.0f} tok/s "
              f"adapter_loads={m.n_loads} ttft={m.ttft * 1e3:.0f}ms "
              f"starved={m.starved}")
        if best_m is None or (m.throughput, -m.n_loads) > \
                (best_m.throughput, -best_m.n_loads):
            best, best_m = policy, m

    print(f"\n3. online fleet with the winning policy ({best}):")
    router = ClusterRouter(specs, policy=best)
    executors = [SyntheticExecutor(profile, ranks, slots=s.adapter_slots,
                                   n_adapters=N_ADAPTERS, seed=10 + i)
                 for i, s in enumerate(specs)]
    real = ServingCluster(router, executors).run(
        generate_requests(spec), horizon=HORIZON)
    print(f"   real cluster: thpt={real.throughput:.0f} tok/s "
          f"(DT predicted {best_m.throughput:.0f}, smape="
          f"{smape(real.throughput, best_m.throughput):.1f}%) "
          f"adapter_loads={real.n_loads} starved={real.starved}")

    print("\n4. living fleet: drifting popularity + a replica failure,")
    print("   online rebalancing on (epoch loop, heartbeats, failover):")
    phases = rotating_hot_phases(pool, HORIZON, n_phases=3, hot_rate=0.8,
                                 cold_rate=0.02)
    drift_reqs = generate_drifting_requests(pool, "medium", HORIZON,
                                            phases, seed=5)
    router = ClusterRouter(specs, policy="affinity")
    executors = [SyntheticExecutor(profile, ranks, slots=s.adapter_slots,
                                   n_adapters=N_ADAPTERS, seed=20 + i)
                 for i, s in enumerate(specs)]
    cluster = ServingCluster(router, executors)
    load_cost = profile.load_cpu_base + profile.load_cpu_per_rank * 16
    report = cluster.run_online(
        drift_reqs, horizon=HORIZON, epoch=5.0,
        rebalancer=RebalancePolicy(router,
                                   load_cost_fn=lambda uid: load_cost),
        failures=[FailureEvent(replica=1, at=HORIZON * 0.4)])
    m = report.metrics
    print(f"   thpt={m.throughput:.0f} tok/s finished={m.n_finished} "
          f"migrations={len(report.migrations)} "
          f"rerouted={report.n_rerouted} "
          f"failure_detected_at={report.failures_detected.get(1, -1):.0f}s "
          f"survivors_alive={report.router_summary['alive']}")


if __name__ == "__main__":
    main()

"""Train a small model for a few hundred steps with checkpointing and an
injected failure + restart (fault-tolerance demo).

    PYTHONPATH=src python examples/train_small.py [--steps 120]
"""
import argparse
import sys
import tempfile
import time

sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import get_reduced  # noqa: E402
from repro.data import DataConfig, TokenPipeline  # noqa: E402
from repro.launch.fault_tolerance import FTConfig, FaultTolerantLoop  # noqa
from repro.models import Model, ShardingPlan  # noqa: E402
from repro.training import (AdamWConfig, TrainConfig,  # noqa: E402
                            init_train_state, make_train_step)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    args = ap.parse_args()

    cfg = get_reduced("internlm2-20b")
    model = Model(cfg, ShardingPlan(mode="train"))
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=3e-3, warmup_steps=20))
    step_fn = jax.jit(make_train_step(model, tcfg))
    pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=128,
                                    global_batch=8))

    def init_fn():
        p, o = init_train_state(model, jax.random.PRNGKey(0), tcfg)
        return {"params": p, "opt": o}

    losses = []

    def one(state, batch):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        p, o, info = step_fn(state["params"], state["opt"], batch)
        losses.append(float(info["loss"]))
        return {"params": p, "opt": o}

    with tempfile.TemporaryDirectory() as d:
        ft = FaultTolerantLoop(FTConfig(d, checkpoint_every=25), init_fn())
        t0 = time.time()
        state = ft.run_with_restarts(init_fn, one, pipe.batch_at,
                                     n_steps=args.steps,
                                     failure_at=args.steps // 2)
        print(f"trained {args.steps} steps in {time.time() - t0:.1f}s "
              f"(1 injected failure, {ft.report.restarts} restart, "
              f"resumed from step {ft.report.resumed_from})")
        print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f}")
        assert losses[-1] < losses[0]


if __name__ == "__main__":
    main()

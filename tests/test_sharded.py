"""Multi-device (8 placeholder hosts) equivalence tests.

Each case runs in a subprocess because the device count must be set
before jax initializes (the main test process stays single-device)."""
import os
import subprocess
import sys

import pytest

from tests.sharded_cases import CASES

_SCRIPT = os.path.join(os.path.dirname(__file__), "sharded_cases.py")


@pytest.mark.parametrize("case", sorted(CASES))
def test_sharded_case(case):
    proc = subprocess.run(
        [sys.executable, _SCRIPT, case],
        capture_output=True, text=True, timeout=900,
        env={**os.environ, "JAX_PLATFORMS": "cpu"})
    assert proc.returncode == 0, \
        f"{case} failed:\n{proc.stdout[-2000:]}\n{proc.stderr[-3000:]}"

"""Training substrate: optimizer, data pipeline, checkpointing,
fault-tolerant loop, gradient compression."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (pip install -e .[dev])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.checkpoint import CheckpointManager
from repro.configs import get_reduced
from repro.data import DataConfig, TokenPipeline
from repro.launch.fault_tolerance import FTConfig, FaultTolerantLoop
from repro.models import Model, ShardingPlan
from repro.training import (AdamWConfig, TrainConfig, adamw_init,
                            adamw_update, init_train_state, make_train_step)
from repro.training.compression import _quantize, quantized_psum

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def tiny():
    cfg = get_reduced("phi4_mini_3p8b")
    model = Model(cfg, ShardingPlan(mode="train"))
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=3e-3, warmup_steps=5))
    pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                    global_batch=8))
    return cfg, model, tcfg, pipe


def test_loss_decreases(tiny):
    cfg, model, tcfg, pipe = tiny
    params, opt = init_train_state(model, KEY, tcfg)
    step = jax.jit(make_train_step(model, tcfg))
    losses = []
    for i in range(25):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
        params, opt, info = step(params, opt, batch)
        losses.append(float(info["loss"]))
    assert losses[-1] < losses[0] - 0.3


def test_adamw_weight_decay_shrinks_params():
    p = {"w": jnp.ones((4, 4))}
    g = {"w": jnp.zeros((4, 4))}
    cfg = AdamWConfig(lr=0.1, weight_decay=0.5, warmup_steps=0,
                      grad_clip=0.0)
    st_ = adamw_init(p, cfg)
    p2, _, _ = adamw_update(p, g, st_, cfg)
    assert float(p2["w"][0, 0]) < 1.0


def test_data_pipeline_seekable_and_sharded():
    cfg = DataConfig(vocab_size=128, seq_len=16, global_batch=8)
    p = TokenPipeline(cfg)
    a = p.batch_at(5)["tokens"]
    b = p.batch_at(5)["tokens"]
    np.testing.assert_array_equal(a, b)          # deterministic
    c = p.batch_at(6)["tokens"]
    assert not np.array_equal(a, c)
    s0 = TokenPipeline(cfg, shard=(0, 2)).batch_at(3)["tokens"]
    s1 = TokenPipeline(cfg, shard=(1, 2)).batch_at(3)["tokens"]
    assert s0.shape == (4, 17)
    assert not np.array_equal(s0, s1)            # different shard data
    assert (a < 128).all() and (a >= 0).all()


def test_checkpoint_roundtrip_and_retention(tiny):
    cfg, model, tcfg, pipe = tiny
    params, opt = init_train_state(model, KEY, tcfg)
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2, async_save=False)
        for s in (10, 20, 30):
            mgr.save(s, {"params": params, "opt": opt}, {"s": s})
        assert mgr.steps() == [20, 30]           # retention
        restored = mgr.restore({"params": params, "opt": opt})
        diff = jax.tree.reduce(max, jax.tree.map(
            lambda a, b: float(np.max(np.abs(
                np.asarray(a, np.float32) - np.asarray(b, np.float32)))),
            {"params": params, "opt": opt}, restored))
        assert diff == 0.0
        assert mgr.metadata() == {"s": 30}


def test_ft_loop_crash_and_resume(tiny):
    cfg, model, tcfg, pipe = tiny
    step_fn = jax.jit(make_train_step(model, tcfg))

    def init_fn():
        p, o = init_train_state(model, KEY, tcfg)
        return {"params": p, "opt": o}

    def one(state, batch):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        p, o, _ = step_fn(state["params"], state["opt"], batch)
        return {"params": p, "opt": o}

    with tempfile.TemporaryDirectory() as d:
        ft = FaultTolerantLoop(FTConfig(d, checkpoint_every=5),
                               init_fn())
        state = ft.run_with_restarts(init_fn, one, pipe.batch_at,
                                     n_steps=12, failure_at=8)
        assert ft.report.restarts == 1
        assert ft.report.resumed_from == 5       # restarted from step 5
        assert int(state["opt"]["step"]) == 12


def test_elastic_restore_resharding(tiny):
    """Restore a checkpoint into a different sharding (mesh change)."""
    cfg, model, tcfg, pipe = tiny
    params, opt = init_train_state(model, KEY, tcfg)
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, async_save=False)
        mgr.save(1, {"params": params})
        shardings = jax.tree.map(
            lambda x: jax.sharding.SingleDeviceSharding(jax.devices()[0]),
            {"params": params})
        restored = mgr.restore({"params": params}, shardings=shardings)
        leaf = jax.tree.leaves(restored)[0]
        assert isinstance(leaf, jax.Array)


# --------------------------------------------------------------------- #
# compression
# --------------------------------------------------------------------- #

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_quantize_error_bound(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, 3, (64,)), jnp.float32)
    q, s = _quantize(x)
    err = np.max(np.abs(np.asarray(q, np.float32) * float(s) - x))
    assert err <= float(s) / 2 + 1e-6            # half-ulp of the grid


def test_quantized_psum_single_shard_identity():
    x = jnp.array([1.0, -2.5, 3.25])
    np.testing.assert_allclose(quantized_psum(x, "pod", 1), x)

import os
import sys

# tests run single-device (the dry-run manages its own placeholder fleet
# in subprocesses); make `repro` importable without installation.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import faulthandler
import os
import sys

import pytest

# tests run single-device (the dry-run manages its own placeholder fleet
# in subprocesses); make `repro` importable without installation.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Per-test hang guard without the pytest-timeout plugin (not available in
# the pinned environment): faulthandler dumps every thread's traceback
# and aborts the process if a single test exceeds the budget.  The fault
# tests drive retry/backoff loops that would otherwise hang silently on
# a regression.  REPRO_TEST_TIMEOUT=0 disables (e.g. when debugging).
_TEST_TIMEOUT_S = float(os.environ.get("REPRO_TEST_TIMEOUT", "300"))


@pytest.fixture(autouse=True)
def _per_test_timeout():
    if _TEST_TIMEOUT_S > 0:
        faulthandler.dump_traceback_later(_TEST_TIMEOUT_S, exit=True)
    yield
    if _TEST_TIMEOUT_S > 0:
        faulthandler.cancel_dump_traceback_later()

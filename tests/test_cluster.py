"""Cluster layer: routing policies, metric aggregation, heterogeneous
replicas, ClusterDigitalTwin fidelity vs the single-engine DT."""
import numpy as np
import pytest

from repro.core import (ClusterDigitalTwin, DigitalTwin, WorkloadSpec,
                        collect_benchmark, collect_memmax,
                        find_cluster_placement, fit_estimators,
                        generate_requests, make_adapter_pool,
                        split_pool_by_rate)
from repro.serving import (ClusterMetrics, ClusterRouter, HardwareProfile,
                           ServingCluster, ServingMetrics, SyntheticExecutor,
                           make_replica_specs, smape)
from repro.serving.cluster import POLICIES
from repro.serving.request import Request


@pytest.fixture(scope="module")
def est():
    profile = HardwareProfile()
    n, slots = 24, 12
    ranks = {i: (8, 16, 32)[i % 3] for i in range(n)}
    ex = SyntheticExecutor(profile, ranks, slots=slots, n_adapters=n, seed=0)
    return fit_estimators(collect_benchmark(ex, slots, n, ranks),
                          collect_memmax(profile), slots, n)


def _req(uid, adapter, arrival=0.0, prompt=100, output=100):
    return Request(uid=uid, adapter=adapter, arrival=arrival,
                   prompt_len=prompt, output_len=output)


def _specs(n=2, slots=4, kv=100_000):
    return make_replica_specs(n, slots, kv)


# --------------------------------------------------------------------- #
# router + policies
# --------------------------------------------------------------------- #

def test_policy_registry_and_validation():
    assert {"affinity", "round-robin", "least-loaded"} <= set(POLICIES)
    with pytest.raises(ValueError):
        ClusterRouter(_specs(), policy="no-such-policy")
    with pytest.raises(ValueError):
        ClusterRouter([])


def test_round_robin_cycles_replicas():
    router = ClusterRouter(_specs(3), policy="round-robin")
    reps = [router.route(_req(i, adapter=i)) for i in range(6)]
    assert reps == [0, 1, 2, 0, 1, 2]


def test_affinity_sticks_to_resident_replica():
    router = ClusterRouter(_specs(2, slots=4), policy="affinity")
    first = router.route(_req(0, adapter=7))
    # interleave other adapters so loads shift around
    for i in range(1, 5):
        router.route(_req(i, adapter=10 + i))
    assert router.route(_req(9, adapter=7)) == first


def test_affinity_spills_away_from_overloaded_replica():
    router = ClusterRouter(_specs(2, slots=4, kv=10_000), policy="affinity")
    home = router.route(_req(0, adapter=7))
    # overload the home replica far past factor * floor + slack
    router.assigned_tokens[home] += 1e6
    assert router.route(_req(1, adapter=7)) == 1 - home


def test_least_loaded_respects_heterogeneous_capacity():
    # replica 0 has 4x the KV capacity -> should absorb ~4x the tokens
    specs = make_replica_specs(2, 8, [100_000, 25_000])
    router = ClusterRouter(specs, policy="least-loaded")
    for i in range(200):
        router.route(_req(i, adapter=i % 16))
    t0, t1 = router.assigned_tokens
    assert t0 > 2.5 * t1


def test_partition_preserves_and_orders_requests():
    router = ClusterRouter(_specs(3), policy="round-robin")
    reqs = [_req(i, adapter=i % 5, arrival=float(13 * i % 7))
            for i in range(30)]
    parts = router.partition(reqs)
    got = [r.uid for part in parts for r in part]
    assert sorted(got) == sorted(r.uid for r in reqs)
    for part in parts:
        assert all(a.arrival <= b.arrival for a, b in zip(part, part[1:]))
    assert set(router.assignments) == {r.uid for r in reqs}


def test_router_residency_lru_capped_at_slots():
    router = ClusterRouter(_specs(1, slots=3), policy="round-robin")
    for i in range(10):
        router.route(_req(i, adapter=i))
    assert len(router.resident[0]) == 3
    # the most recently routed adapters are the ones believed resident
    assert set(router.resident[0]) == {7, 8, 9}


# --------------------------------------------------------------------- #
# metrics aggregation
# --------------------------------------------------------------------- #

def _metrics(thpt, dur, ideal, itl=0.03, ttft=0.1, fin=10, loads=5,
             preempt=1, kv=0.5):
    return ServingMetrics(throughput=thpt, itl=itl, ttft=ttft,
                          ideal_throughput=ideal, duration=dur,
                          n_finished=fin, n_preemptions=preempt,
                          max_kv_used=kv, n_loads=loads)


def test_cluster_metrics_aggregation():
    a = _metrics(100.0, 100.0, 110.0, itl=0.02, fin=30, loads=4)
    b = _metrics(50.0, 50.0, 60.0, itl=0.04, fin=10, loads=3)
    m = ClusterMetrics.aggregate([a, b])
    assert m.duration == 100.0
    # tokens: 100*100 + 50*50 over the longest clock
    assert m.throughput == pytest.approx(125.0)
    assert m.ideal_throughput == pytest.approx(140.0)
    assert m.itl == pytest.approx((0.02 * 30 + 0.04 * 10) / 40)
    assert m.n_finished == 40 and m.n_loads == 7 and m.n_preemptions == 2
    assert m.max_kv_used == 0.5


def test_cluster_metrics_starvation_rule_matches_single_engine():
    ok = ClusterMetrics.aggregate([_metrics(95.0, 10.0, 100.0)])
    bad = ClusterMetrics.aggregate([_metrics(80.0, 10.0, 100.0)])
    assert not ok.starved and bad.starved


def test_cluster_ttft_percentiles_exact_from_pooled_samples():
    """Regression: cluster TTFT p50/p99 must be computed from the pooled
    raw samples, not a finished-weighted mean of per-replica percentiles
    — the mean is provably wrong when replicas see skewed distributions."""
    from repro.serving.metrics import ttft_percentiles

    a_samples = [0.01] * 9 + [2.0]     # replica A: fast, one straggler
    b_samples = [1.0] * 30             # replica B: uniformly slow

    def mk(samples, fin):
        pct = ttft_percentiles(samples)
        return ServingMetrics(
            throughput=100.0, itl=0.02, ttft=float(np.mean(samples)),
            ideal_throughput=100.0, duration=10.0, n_finished=fin,
            n_preemptions=0, ttft_p50=pct["p50"], ttft_p99=pct["p99"],
            ttft_samples=list(samples))

    m = ClusterMetrics.aggregate([mk(a_samples, 10), mk(b_samples, 30)])
    pooled = ttft_percentiles(a_samples + b_samples)
    assert m.ttft_p50 == pooled["p50"]
    assert m.ttft_p99 == pooled["p99"]
    # the old weighted-mean approximation lands far from the truth here
    weighted_p50 = (10 * ttft_percentiles(a_samples)["p50"]
                    + 30 * ttft_percentiles(b_samples)["p50"]) / 40
    assert abs(m.ttft_p50 - weighted_p50) > 0.1


def test_cluster_ttft_percentiles_fallback_without_samples():
    """Hand-built metrics without raw samples keep the weighted-mean
    approximation instead of silently reporting zeros."""
    a = _metrics(100.0, 10.0, 100.0, fin=30)
    b = _metrics(100.0, 10.0, 100.0, fin=10)
    a.ttft_p50, a.ttft_p99 = 0.1, 0.5
    b.ttft_p50, b.ttft_p99 = 0.3, 0.9
    m = ClusterMetrics.aggregate([a, b])
    assert m.ttft_p50 == pytest.approx((0.1 * 30 + 0.3 * 10) / 40)
    assert m.ttft_p99 == pytest.approx((0.5 * 30 + 0.9 * 10) / 40)


# --------------------------------------------------------------------- #
# cluster of real engines
# --------------------------------------------------------------------- #

def test_serving_cluster_end_to_end():
    profile = HardwareProfile()
    n_adapters = 12
    pool = make_adapter_pool(n_adapters, [8, 16], [0.3])
    ranks = {a.uid: a.rank for a in pool}
    spec = WorkloadSpec(adapters=pool, dataset="small", horizon=40.0, seed=2)
    specs = make_replica_specs(2, [6, 4],
                               [profile.kv_capacity(6, 12),
                                profile.kv_capacity(4, 12)])
    router = ClusterRouter(specs, policy="affinity")
    executors = [SyntheticExecutor(profile, ranks, slots=s.adapter_slots,
                                   n_adapters=n_adapters, seed=3 + i)
                 for i, s in enumerate(specs)]
    reqs = generate_requests(spec)
    m = ServingCluster(router, executors).run(reqs, horizon=40.0)
    assert len(m.per_replica) == 2
    assert m.n_finished > 0
    assert m.throughput > 0
    # every request was routed; not all necessarily finish by the horizon
    assert sum(router.assigned_requests) == len(reqs)
    assert m.n_finished <= len(reqs)


def test_serving_cluster_rejects_executor_mismatch():
    router = ClusterRouter(_specs(2), policy="round-robin")
    with pytest.raises(ValueError):
        ServingCluster(router, executors=[object()])


# --------------------------------------------------------------------- #
# cluster digital twin
# --------------------------------------------------------------------- #

def test_cluster_dt_single_replica_matches_single_dt(est):
    pool = make_adapter_pool(12, [8, 16, 32], [0.2])
    mean_rank = float(np.mean([a.rank for a in pool]))
    spec = WorkloadSpec(adapters=pool, dataset="sharegpt", horizon=150.0,
                        seed=11)
    reqs = generate_requests(spec)
    slots = 6
    single = DigitalTwin(est, mode="full").simulate(
        spec, slots=slots, requests=reqs).metrics
    twin = ClusterDigitalTwin(est, mode="full")
    router = ClusterRouter(
        twin.specs_from_slots([slots], mean_rank=mean_rank),
        policy="round-robin")
    cluster = twin.simulate(spec, router, requests=reqs).metrics
    assert smape(cluster.throughput, single.throughput) < 2.0
    assert smape(cluster.itl, single.itl) < 5.0


def test_cluster_dt_matches_summed_single_dt(est):
    """2-replica cluster throughput ~ sum of single-engine DT runs on
    the router's own partitions (same machinery, split workload)."""
    pool = make_adapter_pool(16, [8, 16], [0.2])
    mean_rank = float(np.mean([a.rank for a in pool]))
    spec = WorkloadSpec(adapters=pool, dataset="medium", horizon=150.0,
                        seed=7)
    reqs = generate_requests(spec)
    slots = 4
    twin = ClusterDigitalTwin(est, mode="full")
    router = ClusterRouter(
        twin.specs_from_slots([slots, slots], mean_rank=mean_rank),
        policy="affinity")
    cluster = twin.simulate(spec, router, requests=reqs).metrics

    # replay the router's partition through the single-engine DT
    parts = [[r for r in reqs if router.assignments[r.uid] == i]
             for i in range(2)]
    summed = 0.0
    dt = DigitalTwin(est, mode="full")
    for part in parts:
        uids = {r.adapter for r in part}
        sub = WorkloadSpec(adapters=[a for a in pool if a.uid in uids],
                           dataset="medium", horizon=150.0, seed=7)
        m = dt.simulate(sub, slots=slots, requests=part).metrics
        summed += m.throughput * m.duration
    summed /= cluster.duration
    assert smape(cluster.throughput, summed) < 5.0


def test_cluster_dt_scales_with_replicas(est):
    """Adding a replica lifts an overloaded workload's throughput."""
    pool = make_adapter_pool(24, [8, 16], [0.4])
    mean_rank = float(np.mean([a.rank for a in pool]))
    spec = WorkloadSpec(adapters=pool, dataset="medium", horizon=100.0,
                        seed=4)
    twin = ClusterDigitalTwin(est, mode="mean")

    def thpt(n_rep):
        router = ClusterRouter(
            twin.specs_from_slots([8] * n_rep, mean_rank=mean_rank),
            policy="affinity")
        return twin.simulate(spec, router).metrics.throughput

    assert thpt(2) > 1.2 * thpt(1)


def test_affinity_beats_round_robin_on_adapter_loads(est):
    """Acceptance: in the cluster sweep configuration, affinity routing
    produces strictly fewer cold adapter loads than round-robin."""
    pool = make_adapter_pool(24, [8, 16], [0.1])
    mean_rank = float(np.mean([a.rank for a in pool]))
    spec = WorkloadSpec(adapters=pool, dataset="medium", horizon=120.0,
                        seed=5)
    twin = ClusterDigitalTwin(est, mode="mean")

    def run(policy):
        router = ClusterRouter(
            twin.specs_from_slots([6, 6], mean_rank=mean_rank),
            policy=policy)
        return twin.simulate(spec, router).metrics

    affinity, rr = run("affinity"), run("round-robin")
    assert affinity.n_loads < rr.n_loads
    assert affinity.throughput >= 0.95 * rr.throughput


# --------------------------------------------------------------------- #
# cluster placement
# --------------------------------------------------------------------- #

def test_split_pool_by_rate_balances_rates():
    pool = make_adapter_pool(20, [8], [0.4, 0.2, 0.1, 0.05])
    parts = split_pool_by_rate(pool, 3)
    assert sum(len(p) for p in parts) == len(pool)
    rates = [sum(a.rate for a in p) for p in parts]
    assert max(rates) - min(rates) <= 0.4 + 1e-9   # within one max adapter


def test_find_cluster_placement_predicts_per_replica_config(est):
    pool = make_adapter_pool(12, [8, 16], [0.2, 0.1])
    plan = find_cluster_placement(est, pool, "medium", n_replicas=2,
                                  horizon=60.0, n_grid=[3, 6])
    assert len(plan.replicas) == 2
    assert sum(len(r.adapters) for r in plan.replicas) == len(pool)
    assert all(n >= 1 for n in plan.n_adapters)
    assert all(g >= 1 for g in plan.slots)
    assert plan.total_throughput > 0

"""Workload generation properties + multi-replica router behaviour."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (pip install -e .[dev])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (WorkloadSpec, generate_requests, make_adapter_pool,
                        resample_requests)
from repro.serving import PlacementRouter


@settings(max_examples=10, deadline=None)
@given(rate=st.sampled_from([0.1, 0.5, 2.0]), seed=st.integers(0, 1000))
def test_poisson_arrival_rate(rate, seed):
    spec = WorkloadSpec(adapters=make_adapter_pool(1, [8], [rate]),
                        horizon=400.0, seed=seed)
    reqs = generate_requests(spec)
    observed = len(reqs) / spec.horizon
    assert abs(observed - rate) < 4 * np.sqrt(rate / spec.horizon) + 0.05


def test_requests_sorted_and_adapter_tagged():
    pool = make_adapter_pool(6, [8, 16], [0.5])
    spec = WorkloadSpec(adapters=pool, horizon=60.0, seed=1)
    reqs = generate_requests(spec)
    assert all(a.arrival <= b.arrival for a, b in zip(reqs, reqs[1:]))
    assert {r.adapter for r in reqs} <= {a.uid for a in pool}


def test_dataset_profiles_fixed_lengths():
    spec = WorkloadSpec(adapters=make_adapter_pool(2, [8], [1.0]),
                        dataset="medium", horizon=30.0, seed=0)
    reqs = generate_requests(spec)
    assert all(r.prompt_len == 250 and r.output_len == 231 for r in reqs)


def test_mean_mode_resampling_matches_moments():
    spec = WorkloadSpec(adapters=make_adapter_pool(4, [8], [2.0]),
                        dataset="sharegpt", horizon=400.0, seed=0)
    stats = spec.length_stats()
    reqs = resample_requests(spec, stats)
    outs = np.array([r.output_len for r in reqs])
    assert abs(outs.mean() - stats["out_mean"]) / stats["out_mean"] < 0.25


# --------------------------------------------------------------------- #
# router
# --------------------------------------------------------------------- #

class FakePipeline:
    def recommend(self, rates, ranks, stats):
        return {"throughput": 100.0 * len(rates),
                "served_adapters": 10, "adapter_slots": 5,
                "inference_ms": 0.1}


STATS = {"in_mean": 250, "in_std": 0, "out_mean": 231, "out_std": 0}


def test_router_packs_and_routes():
    router = PlacementRouter(FakePipeline(), n_replicas=3)
    pool = make_adapter_pool(24, [8], [0.1])
    state = router.plan(pool, STATS)
    sizes = [len(p.adapters) for p in state.plans]
    assert sum(sizes) == 24
    assert max(sizes) - min(sizes) <= 10          # capacity-bounded spread
    for a in pool:
        rep = router.route(a.uid)
        assert a.uid in [x.uid for x in state.plans[rep].adapters]


def test_router_failure_repacks_to_survivors():
    router = PlacementRouter(FakePipeline(), n_replicas=3)
    pool = make_adapter_pool(12, [8], [0.1])
    router.plan(pool, STATS)
    state = router.report_failure(1, pool, STATS)
    assert not state.plans[1].alive and not state.plans[1].adapters
    assert sum(len(p.adapters) for p in state.plans) == 12
    rep = router.route(pool[0].uid)
    assert state.plans[rep].alive


def test_router_straggler_detection():
    router = PlacementRouter(FakePipeline(), n_replicas=3,
                             straggler_factor=2.0)
    router.plan(make_adapter_pool(6, [8], [0.1]), STATS)
    bad = router.observe_itl({0: 0.03, 1: 0.032, 2: 0.30})
    assert bad == [2]
    # new traffic avoids the straggler
    assert all(router.route(uid) != 2 for uid in range(100, 120))

"""int8 KV cache: decode logits stay within quantization tolerance of the
bf16-cache reference (beyond-paper §Perf optimization)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_reduced
from repro.models import Model, ShardingPlan
from repro.models.attention import quantize_kv
from repro.models.transformer import pad_cache

KEY = jax.random.PRNGKey(4)


def test_quantize_kv_roundtrip_error():
    x = jax.random.normal(KEY, (4, 8, 64), jnp.float32) * 3
    q, s = quantize_kv(x)
    recon = q.astype(jnp.float32) * s[..., None].astype(jnp.float32)
    err = float(jnp.max(jnp.abs(recon - x)))
    assert err <= float(jnp.max(s)) / 2 + 1e-5


@pytest.mark.parametrize("arch", ["phi4_mini_3p8b", "qwen1p5_4b"])
def test_int8_kv_decode_close_to_fp(arch):
    cfg = dataclasses.replace(get_reduced(arch), dtype="float32")
    m_pre = Model(cfg, ShardingPlan(mode="prefill"))
    m_pre_q = Model(cfg, ShardingPlan(mode="prefill", kv_quant=True))
    m_dec = Model(cfg, ShardingPlan(mode="decode"))
    m_dec_q = Model(cfg, ShardingPlan(mode="decode", kv_quant=True))
    params = m_pre.init(KEY)
    lora = m_pre.init_lora(KEY, 4, 4)
    b, s = 2, 24
    tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    idx = jnp.array([0, 1], jnp.int32)
    _, cache = jax.jit(m_pre.prefill)(params, lora, tokens[:, :-1], idx)
    _, cache_q = jax.jit(m_pre_q.prefill)(params, lora, tokens[:, :-1], idx)
    ref, _ = jax.jit(m_dec.decode_step)(params, lora, pad_cache(cache, 4),
                                        tokens[:, -1:], idx)
    got, ncache = jax.jit(m_dec_q.decode_step)(
        params, lora, pad_cache(cache_q, 4), tokens[:, -1:], idx)
    # int8 caches stay int8 through the step
    kv = ncache["segments"][0]["blocks"][0]
    assert kv["k"].dtype == jnp.int8 and "k_scale" in kv
    rel = float(jnp.max(jnp.abs(got - ref))) / \
        (float(jnp.max(jnp.abs(ref))) + 1e-9)
    assert rel < 2e-2, rel

"""repro-lint self-tests: every rule gets a failing + passing fixture,
suppression and baseline round-trips, and the acceptance-criteria
mutations (drop a field from TWIN_EXACT_FIELDS / ClusterMetrics.aggregate
/ the gateway /v1/metrics body -> the gate fails)."""
import json
import os
import subprocess
import sys
from pathlib import Path

from repro.analysis import core
from repro.analysis.__main__ import main as lint_main

ROOT = core.REPO_ROOT

FIXTURE = "src/repro/serving/zz_lint_fixture.py"


def lint(rules, overrides=None):
    return core.run_rules(core.Repo(overrides=overrides), rules=rules)


def fixture_findings(rule, text, path=FIXTURE):
    report = lint([rule], overrides={path: text})
    return [f for f in report.new if f.path == path]


def read(rel: str) -> str:
    return (ROOT / rel).read_text()


def mutate(rel: str, old: str, new: str) -> dict:
    text = read(rel)
    assert old in text, f"mutation anchor missing from {rel}: {old!r}"
    return {rel: text.replace(old, new)}


# --------------------------------------------------------------------- #
# determinism rules
# --------------------------------------------------------------------- #

def test_wallclock_negative():
    bad = "import time\n\n\ndef step():\n    return time.time()\n"
    found = fixture_findings("determinism-wallclock", bad)
    assert len(found) == 1 and "time.time" in found[0].message


def test_wallclock_positive():
    ok = "def step(clock):\n    return clock + 0.5\n"
    assert fixture_findings("determinism-wallclock", ok) == []


def test_perf_counter_forbidden_in_serving_allowed_in_core():
    text = "import time\n\n\ndef f():\n    return time.perf_counter()\n"
    assert fixture_findings("determinism-wallclock", text)  # serving/
    core_path = "src/repro/core/zz_lint_fixture.py"
    assert fixture_findings("determinism-wallclock", text,
                            path=core_path) == []


def test_rng_negative_unseeded_default_rng():
    bad = ("import numpy as np\n\n\ndef f():\n"
           "    return np.random.default_rng()\n")
    found = fixture_findings("determinism-rng", bad)
    assert len(found) == 1 and "unseeded" in found[0].message


def test_rng_negative_stdlib_and_global_numpy():
    bad = ("import random\nimport numpy as np\n\n\ndef f():\n"
           "    np.random.seed(0)\n    return random.random()\n")
    found = fixture_findings("determinism-rng", bad)
    assert {f.key for f in found} == {"np.random.seed@f",
                                      "random.random@f"}


def test_rng_positive_seeded():
    ok = ("import numpy as np\n\n\ndef f(seed):\n"
          "    return np.random.default_rng(seed)\n")
    assert fixture_findings("determinism-rng", ok) == []


# --------------------------------------------------------------------- #
# twin-contract rules (the acceptance-criteria mutations)
# --------------------------------------------------------------------- #

def test_twin_metrics_fields_clean():
    assert lint(["twin-metrics-fields"]).new == []


def test_twin_metrics_fields_drop_from_exact_fails():
    ov = mutate("src/repro/serving/metrics.py",
                '"n_preemptions", "n_loads", "max_kv_used", "ttft",',
                '"n_preemptions", "max_kv_used", "ttft",')
    report = lint(["twin-metrics-fields"], overrides=ov)
    assert any(f.key == "unclassified-n_loads" for f in report.new)


def test_twin_metrics_fields_stale_entry_fails():
    ov = mutate("src/repro/serving/metrics.py",
                'TWIN_TOLERANT_FIELDS = ("itl",)',
                'TWIN_TOLERANT_FIELDS = ("itl", "ghost")')
    report = lint(["twin-metrics-fields"], overrides=ov)
    assert any(f.key == "stale-ghost" for f in report.new)


def test_twin_cluster_aggregate_clean():
    assert lint(["twin-cluster-aggregate"]).new == []


def test_twin_cluster_aggregate_drop_kwarg_fails():
    ov = mutate("src/repro/serving/cluster.py",
                "            n_loads=sum(m.n_loads for m in per),\n", "")
    report = lint(["twin-cluster-aggregate"], overrides=ov)
    assert any(f.key == "not-aggregated-n_loads" for f in report.new)


def test_twin_cluster_aggregate_drop_field_fails():
    ov = mutate("src/repro/serving/cluster.py",
                "    n_loads: int\n", "")
    report = lint(["twin-cluster-aggregate"], overrides=ov)
    assert any(f.key == "no-field-n_loads" for f in report.new)


def test_twin_gateway_metrics_clean():
    assert lint(["twin-gateway-metrics"]).new == []


def test_twin_gateway_metrics_drop_key_fails():
    ov = mutate("src/repro/serving/gateway.py",
                '                "n_loads": s.n_loads,\n', "")
    report = lint(["twin-gateway-metrics"], overrides=ov)
    assert any(f.key == "not-emitted-n_loads" for f in report.new)


# --------------------------------------------------------------------- #
# config-threading rules
# --------------------------------------------------------------------- #

def test_config_threading_clean():
    assert lint(["config-replica-threading",
                 "config-cli-threading"]).new == []


def test_config_replica_threading_drop_param_fails():
    ov = mutate("src/repro/serving/cluster.py",
                "        block_size: int = 16,\n", "")
    report = lint(["config-replica-threading"], overrides=ov)
    assert any(f.key == "maker-block_size" for f in report.new)


def test_config_cli_threading_drop_flag_fails():
    ov = mutate(
        "src/repro/launch/serve_cluster.py",
        'ap.add_argument("--block-size", type=int, default=16,',
        'ap.add_argument("--zz-renamed", type=int, default=16,')
    report = lint(["config-cli-threading"], overrides=ov)
    assert any(f.key == "flag-block_size" for f in report.new)


# --------------------------------------------------------------------- #
# mirror-coverage rules
# --------------------------------------------------------------------- #

def test_mirror_engine_surface_clean():
    assert lint(["mirror-engine-surface"]).new == []


def test_mirror_engine_surface_hidden_method_fails():
    ov = mutate("src/repro/core/fast_twin.py",
                "    def cancel(", "    def _cancel(")
    report = lint(["mirror-engine-surface"], overrides=ov)
    assert any(f.key == "missing-cancel" for f in report.new)


def test_mirror_kernel_oracle_clean():
    assert lint(["mirror-kernel-oracle"]).new == []


def test_mirror_kernel_oracle_negative():
    rel = "src/repro/kernels/ops.py"
    text = read(rel).replace(
        'KERNEL_MODES = ("pallas", "ref", "interpret")',
        'KERNEL_MODES = ("pallas", "interpret")')
    text += "\n\ndef rogue_op(x):\n    return x\n"
    report = lint(["mirror-kernel-oracle"], overrides={rel: text})
    keys = {f.key for f in report.new}
    assert {"kernel-modes-ref", "no-oracle-rogue_op"} <= keys


# --------------------------------------------------------------------- #
# async-safety rule
# --------------------------------------------------------------------- #

def test_async_blocking_negative():
    bad = ("import time\n\n\nasync def pump():\n"
           "    time.sleep(0.1)\n    open('x').read()\n")
    found = fixture_findings("async-blocking-call", bad)
    assert {f.key for f in found} == {"time.sleep@pump", "open@pump"}


def test_async_blocking_positive():
    ok = ("import asyncio\n\n\nasync def pump():\n"
          "    await asyncio.sleep(0.1)\n")
    assert fixture_findings("async-blocking-call", ok) == []


# --------------------------------------------------------------------- #
# trace round-trip rule
# --------------------------------------------------------------------- #

def test_trace_fields_clean():
    assert lint(["trace-request-fields"]).new == []


def test_trace_fields_new_request_field_fails():
    ov = mutate("src/repro/serving/request.py",
                "    prefix_len: int = 0\n",
                "    prefix_len: int = 0\n    priority: int = 0\n")
    report = lint(["trace-request-fields"], overrides=ov)
    found = [f for f in report.new if f.key == "dropped-priority"]
    assert found and "save_trace" in found[0].message


def test_trace_fields_stale_progress_entry_fails():
    ov = mutate("src/repro/core/workload.py",
                '    "token_times", "n_preemptions",',
                '    "token_times", "n_preemptions", "ghost_field",')
    report = lint(["trace-request-fields"], overrides=ov)
    assert any(f.key == "stale-ghost_field" for f in report.new)


# --------------------------------------------------------------------- #
# suppressions, baseline, CLI
# --------------------------------------------------------------------- #

def test_inline_suppression_same_line_and_line_above():
    bad = ("import time\n\n\ndef f():\n"
           "    a = time.time()  # repro-lint: ignore[determinism-wallclock]\n"
           "    # repro-lint: ignore[determinism-wallclock]\n"
           "    b = time.time()\n"
           "    return a + b\n")
    report = lint(["determinism-wallclock"], overrides={FIXTURE: bad})
    mine = [f for f in report.suppressed if f.path == FIXTURE]
    assert len(mine) == 2
    assert not [f for f in report.new if f.path == FIXTURE]


def test_inline_suppression_wrong_rule_does_not_apply():
    bad = ("import time\n\n\ndef f():\n"
           "    return time.time()  # repro-lint: ignore[determinism-rng]\n")
    assert len(fixture_findings("determinism-wallclock", bad)) == 1


def test_baseline_round_trip(tmp_path):
    bad = "import time\n\n\ndef f():\n    return time.time()\n"
    repo = core.Repo(overrides={FIXTURE: bad})
    report = core.run_rules(repo, rules=["determinism-wallclock"])
    mine = [f for f in report.new if f.path == FIXTURE]
    assert len(mine) == 1
    bl = tmp_path / "baseline.json"
    core.save_baseline(bl, mine)
    entries = core.load_baseline(bl)
    assert len(entries) == 1 and entries[0]["rule"] == \
        "determinism-wallclock"
    again = core.run_rules(repo, rules=["determinism-wallclock"],
                           baseline=entries)
    assert not [f for f in again.new if f.path == FIXTURE]
    assert [f for f in again.baselined if f.path == FIXTURE]


def test_stale_baseline_entries_reported():
    entries = [{"rule": "determinism-wallclock", "path": "nope.py",
                "key": "gone@nowhere"}]
    report = core.run_rules(core.Repo(), rules=["determinism-wallclock"],
                            baseline=entries)
    assert report.stale_baseline == \
        ["determinism-wallclock::nope.py::gone@nowhere"]


def test_committed_baseline_is_small_and_justified():
    data = json.loads(read("tools/repro_lint_baseline.json"))
    entries = data["suppressions"]
    assert len(entries) <= 5
    assert all(e.get("reason", "").strip() and
               "TODO" not in e["reason"] for e in entries)


def test_cli_clean_repo_exits_zero_in_process():
    assert lint_main(["-q"]) == 0


def test_cli_negative_fixture_exits_nonzero():
    ov = mutate("src/repro/serving/metrics.py",
                '"n_preemptions", "n_loads", "max_kv_used", "ttft",',
                '"n_preemptions", "max_kv_used", "ttft",')
    assert lint_main(["-q", "--rules", "twin-metrics-fields"],
                     overrides=ov) == 1


def test_cli_unknown_rule_errors():
    try:
        lint_main(["--rules", "no-such-rule"])
    except KeyError as e:
        assert "no-such-rule" in str(e)
    else:
        raise AssertionError("unknown rule id should raise")


def test_cli_clean_repo_exits_zero_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis"], cwd=ROOT, env=env,
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
        timeout=120)
    assert proc.returncode == 0, proc.stdout
    assert "0 new" in proc.stdout


def test_write_baseline_round_trip(tmp_path):
    bl = tmp_path / "bl.json"
    ov = mutate("src/repro/serving/metrics.py",
                '"n_preemptions", "n_loads", "max_kv_used", "ttft",',
                '"n_preemptions", "max_kv_used", "ttft",')
    assert lint_main(["-q", "--rules", "twin-metrics-fields",
                      "--baseline", str(bl), "--write-baseline"],
                     overrides=ov) == 0
    assert lint_main(["-q", "--rules", "twin-metrics-fields",
                      "--baseline", str(bl)], overrides=ov) == 0
    assert Path(bl).is_file() and json.loads(bl.read_text())["suppressions"]


def test_every_rule_has_registry_metadata():
    assert len(core.RULES) >= 11
    for rid, info in core.RULES.items():
        assert rid == info.rule_id and info.synopsis

"""Pallas kernel validation: interpret=True vs pure-jnp oracles, over
shape/dtype sweeps + hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (pip install -e .[dev])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels import ops, ref
from repro.kernels.bgmv import bgmv
from repro.kernels.flash_decode import flash_decode
from repro.kernels.sgmv import sgmv

KEY = jax.random.PRNGKey(0)


def _lora_data(t, d, r, o, n, dtype):
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (t, d), dtype)
    a = (jax.random.normal(ks[1], (n, d, r), jnp.float32) * 0.1).astype(dtype)
    b = (jax.random.normal(ks[2], (n, r, o), jnp.float32) * 0.1).astype(dtype)
    idx = jax.random.randint(ks[3], (t,), 0, n)
    return x, a, b, idx


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("t,d,r,o,n", [
    (4, 64, 8, 64, 2), (8, 128, 16, 256, 4), (16, 256, 32, 128, 8),
    (1, 512, 4, 512, 3),
])
def test_bgmv_matches_ref(t, d, r, o, n, dtype):
    x, a, b, idx = _lora_data(t, d, r, o, n, dtype)
    got = bgmv(x, a, b, idx, 1.5, interpret=True)
    want = ref.lora_ref(x, a, b, idx, 1.5)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("t,d,r,o,n", [
    (256, 64, 8, 64, 2), (300, 128, 16, 128, 3), (512, 64, 8, 256, 8),
])
def test_sgmv_matches_ref(t, d, r, o, n, dtype):
    x, a, b, idx = _lora_data(t, d, r, o, n, dtype)
    got = sgmv(x, a, b, idx, 1.0, interpret=True)
    want = ref.lora_ref(x, a, b, idx, 1.0)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,kv,d,s", [
    (2, 8, 2, 64, 512), (3, 4, 4, 128, 256), (2, 4, 1, 64, 300),
    (1, 16, 8, 128, 1024),
])
def test_flash_decode_matches_ref(b, h, kv, d, s, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, h, d), dtype)
    k = jax.random.normal(ks[1], (b, s, kv, d), dtype)
    v = jax.random.normal(ks[2], (b, s, kv, d), dtype)
    length = jnp.arange(1, b + 1) * (s // (b + 1)) + 1
    got = flash_decode(q, k, v, length, interpret=True)
    want = ref.flash_decode_ref(q, k, v, length)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_ops_dispatch_cpu_uses_ref():
    x, a, b, idx = _lora_data(6, 32, 4, 32, 2, jnp.float32)
    got = ops.lora_apply(x, a, b, idx)
    want = ref.lora_ref(x, a, b, idx, 1.0)
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_ops_lora_apply_broadcasts_request_idx():
    """(B, S, d) input with per-request idx -> per-token application."""
    b, s, d, r, o, n = 2, 5, 16, 4, 16, 3
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (b, s, d), jnp.float32)
    a = jax.random.normal(ks[1], (n, d, r), jnp.float32)
    bb = jax.random.normal(ks[2], (n, r, o), jnp.float32)
    idx = jnp.array([0, 2], jnp.int32)
    got = ops.lora_apply(x, a, bb, idx)
    for i in range(b):
        want = ref.lora_ref(x[i], a, bb, jnp.full((s,), idx[i]), 1.0)
        np.testing.assert_allclose(got[i], want, rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------- #
# property-based
# --------------------------------------------------------------------- #

@settings(max_examples=12, deadline=None)
@given(t=st.integers(1, 16), n=st.integers(1, 6),
       r=st.sampled_from([4, 8, 16]), seed=st.integers(0, 2 ** 16))
def test_bgmv_property_random_shapes(t, n, r, seed):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    d, o = 64, 96
    x = jax.random.normal(ks[0], (t, d), jnp.float32)
    a = jax.random.normal(ks[1], (n, d, r), jnp.float32)
    b = jax.random.normal(ks[2], (n, r, o), jnp.float32)
    idx = jax.random.randint(ks[3], (t,), 0, n)
    got = bgmv(x, a, b, idx, 1.0, interpret=True)
    want = ref.lora_ref(x, a, b, idx, 1.0)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(s=st.integers(2, 600), seed=st.integers(0, 2 ** 16))
def test_flash_decode_property_lengths(s, seed):
    """Invariant: output depends only on the first `length` positions."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    b, h, kv, d = 2, 4, 2, 32
    q = jax.random.normal(ks[0], (b, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kv, d), jnp.float32)
    length = int(jax.random.randint(ks[3], (), 1, s + 1))
    out1 = flash_decode(q, k, v, length, interpret=True)
    # scramble the masked tail: output must not change
    noise = jax.random.normal(ks[3], k.shape, jnp.float32) * 100
    mask = (jnp.arange(s) >= length)[None, :, None, None]
    k2 = jnp.where(mask, noise, k)
    v2 = jnp.where(mask, noise, v)
    out2 = flash_decode(q, k2, v2, length, interpret=True)
    np.testing.assert_allclose(out1, out2, rtol=1e-5, atol=1e-5)

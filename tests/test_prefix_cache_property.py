"""Property-based shared-prefix cache harness (optional dependency).

Hypothesis drives random interleavings of admission planning, commits,
request KV allocation, releases and idle eviction against a real
``PagedKVCache`` pool, asserting the invariants the scheduler relies on:

* **block conservation** — ``free + request-held + cached == total``
  after every operation (the cache can never leak or double-count pool
  blocks);
* **plan exclusivity** — at most one of ``(covered, insert_tokens)`` is
  nonzero and coverage never exceeds the clamped prefix;
* **bounded hit rate** — ``hit_rate`` stays in ``[0, 1]``;
* **clean teardown** — releasing every holder and draining the idle LRU
  returns the pool to fully free.

The always-on unit and edge coverage lives in tests/test_prefix_cache.py;
this module skips entirely when hypothesis is absent.
"""
import pytest

pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (pip install -e .[dev])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.serving import PagedKVCache, SharedPrefixCache  # noqa: E402

OPS = st.lists(
    st.tuples(st.integers(0, 3),          # prefix id (few -> collisions)
              st.integers(1, 48),         # prefix_len
              st.integers(1, 80),         # prompt_len
              st.booleans()),             # release oldest holder after
    min_size=1, max_size=60)


@settings(max_examples=120, deadline=None)
@given(ops=OPS, capacity_blocks=st.integers(0, 24))
def test_block_conservation_under_random_interleavings(ops, capacity_blocks):
    pool = PagedKVCache(capacity_blocks * 16, block_size=16)
    pc = SharedPrefixCache(pool)
    holders = []
    for uid, (pid, plen, prompt, do_release) in enumerate(ops):
        cov, ins = pc.plan(pid, plen, prompt)
        assert (cov > 0) + (ins > 0) <= 1
        assert cov <= min(plen, prompt)
        ctx = prompt
        if pc.fit_blocks(cov, ins, ctx) > pool.free_blocks:
            # the scheduler's pressure ladder: evict an idle prefix,
            # else serve a miss uncached (downgrade the insert)
            if not pc.evict_idle_lru(exclude=pid if cov else None):
                ins = 0
        if pc.fit_blocks(cov, ins, ctx) <= pool.free_blocks:
            pc.commit(holder=uid, prefix_id=pid, covered=cov,
                      insert_tokens=ins)
            if pool.allocate(uid, ctx + 1 - cov - ins):
                holders.append(uid)
            else:
                pc.release(uid)
        if do_release and holders:
            h = holders.pop(0)
            pc.release(h)
            pool.free(h)
        held = sum(pool.table.values())
        assert pool.free_blocks + held + pc.cached_blocks \
            == pool.total_blocks
        assert 0.0 <= pc.hit_rate <= 1.0
    # full teardown returns every block to the pool
    for h in holders:
        pc.release(h)
        pool.free(h)
    while pc.evict_idle_lru():
        pass
    assert not pc.entries
    assert pool.free_blocks == pool.total_blocks - sum(pool.table.values())

"""Always-on kernel edge cases (no hypothesis dependency).

The property harness in tests/test_kernels_property.py needs hypothesis,
which the dev extra provides but a bare environment may not have; this
suite pins the kernel edge cases with plain pytest so kernel correctness
is verified everywhere the repo's tests run at all.

Covered edges: token counts not divisible by the kernel block size,
rank-1 adapters, a single-adapter bank, expand dim larger than the input
dim (o > d), mixed f32/bf16 inputs, the sgmv capacity-buffer overflow
contract, the ragged-rank bitwise identity, and fused-decode odd shapes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.bgmv import bgmv
from repro.kernels.flash_decode import flash_decode, flash_decode_lora
from repro.kernels.ops import fused_decode, lora_apply
from repro.kernels.sgmv import sgmv


def _close(got, want, dtype, tol=None):
    tol = tol or (2e-5 if dtype == jnp.float32 else 3e-2)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def _bank(key, t, d, r, o, n, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    x = jax.random.normal(ks[0], (t, d), dtype)
    a = (jax.random.normal(ks[1], (n, d, r), jnp.float32) * 0.1).astype(dtype)
    b = (jax.random.normal(ks[2], (n, r, o), jnp.float32) * 0.1).astype(dtype)
    return x, a, b


# --------------------------------------------------------------------- #
# block-size edges
# --------------------------------------------------------------------- #

def test_sgmv_tokens_not_divisible_by_block():
    # T = 130: capacity buckets round to 128, tokens straddle the block
    # boundary of the grouped matmul's (adapters x capacity-block) grid.
    key = jax.random.PRNGKey(0)
    x, a, b = _bank(key, 130, 32, 8, 48, 3)
    idx = jax.random.randint(key, (130,), -1, 3).astype(jnp.int32)
    got = sgmv(x, a, b, idx, 1.0, interpret=True)
    _close(got, ref.lora_ref(x, a, b, idx, 1.0), jnp.float32)


@pytest.mark.parametrize("s,block_s", [(100, 512), (33, 16), (7, 512)])
def test_flash_decode_seq_not_divisible_by_block(s, block_s):
    # block_s halves until it divides S; odd S must still be exact.
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (2, 4, 16), jnp.float32)
    k = jax.random.normal(ks[1], (2, s, 2, 16), jnp.float32)
    v = jax.random.normal(ks[2], (2, s, 2, 16), jnp.float32)
    length = jax.random.randint(ks[3], (2,), 1, s + 1).astype(jnp.int32)
    got = flash_decode(q, k, v, length, block_s=block_s, interpret=True)
    _close(got, ref.flash_decode_ref(q, k, v, length), jnp.float32)


# --------------------------------------------------------------------- #
# rank-1, single adapter, o > d
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("kernel", [bgmv, sgmv])
def test_rank_one(kernel):
    key = jax.random.PRNGKey(2)
    x, a, b = _bank(key, 8, 32, 1, 32, 4)
    idx = jnp.array([0, 1, 2, 3, -1, 0, 1, 2], jnp.int32)
    got = kernel(x, a, b, idx, 2.0, interpret=True)
    _close(got, ref.lora_ref(x, a, b, idx, 2.0), jnp.float32)


@pytest.mark.parametrize("kernel", [bgmv, sgmv])
def test_single_adapter_bank(kernel):
    key = jax.random.PRNGKey(3)
    x, a, b = _bank(key, 6, 16, 4, 24, 1)
    idx = jnp.zeros((6,), jnp.int32)
    got = kernel(x, a, b, idx, 1.0, interpret=True)
    _close(got, ref.lora_ref(x, a, b, idx, 1.0), jnp.float32)


@pytest.mark.parametrize("kernel", [bgmv, sgmv])
def test_expand_wider_than_input(kernel):
    # o > d: LoRA up-projection wider than the input activation
    key = jax.random.PRNGKey(4)
    x, a, b = _bank(key, 8, 16, 4, 192, 3)
    idx = jax.random.randint(key, (8,), 0, 3).astype(jnp.int32)
    got = kernel(x, a, b, idx, 1.0, interpret=True)
    _close(got, ref.lora_ref(x, a, b, idx, 1.0), jnp.float32)


# --------------------------------------------------------------------- #
# mixed dtypes
# --------------------------------------------------------------------- #

def test_mixed_dtype_inputs_bgmv():
    # bf16 activations against an f32 adapter bank (the serving engine
    # keeps the bank in weight dtype); accumulation is f32 either way,
    # output follows x.dtype.
    key = jax.random.PRNGKey(5)
    x, a, b = _bank(key, 8, 32, 8, 32, 2, jnp.bfloat16)
    a32, b32 = a.astype(jnp.float32), b.astype(jnp.float32)
    idx = jnp.array([0, 1, 0, 1, -1, 0, 1, 0], jnp.int32)
    got = bgmv(x, a32, b32, idx, 1.0, interpret=True)
    assert got.dtype == jnp.bfloat16
    _close(got, ref.lora_ref(x, a32, b32, idx, 1.0), jnp.bfloat16)


def test_mixed_dtype_inputs_fused_decode():
    key = jax.random.PRNGKey(6)
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (2, 4, 16), jnp.bfloat16)
    k = jax.random.normal(ks[1], (2, 64, 2, 16), jnp.bfloat16)
    v = jax.random.normal(ks[2], (2, 64, 2, 16), jnp.bfloat16)
    x, a, b = _bank(jax.random.fold_in(key, 1), 2, 32, 8, 4 * 16, 3,
                    jnp.float32)
    x = x.astype(jnp.bfloat16)
    idx = jnp.array([1, -1], jnp.int32)
    length = jnp.array([40, 64], jnp.int32)
    got = flash_decode_lora(q, k, v, length, x, a, b, idx, 1.0,
                            interpret=True)
    assert got.dtype == jnp.bfloat16
    _close(got, ref.fused_decode_ref(q, k, v, length, x, a, b, idx, 1.0),
           jnp.bfloat16)


# --------------------------------------------------------------------- #
# sgmv capacity-buffer overflow
# --------------------------------------------------------------------- #

def test_sgmv_capacity_overflow_contract():
    # T=512 tokens all on adapter 0 of an N=8 bank: capacity is
    # min(T, 2*ceil(T/N) + 128) = 256.  The documented contract: the
    # first 256 tokens (in arrival order) get the exact delta, tokens
    # over capacity fall back to exactly 0 — same as the ref bucketed
    # oracle, never garbage.
    key = jax.random.PRNGKey(7)
    x, a, b = _bank(key, 512, 32, 8, 32, 8)
    idx = jnp.zeros((512,), jnp.int32)
    got = np.asarray(sgmv(x, a, b, idx, 1.0, interpret=True))
    want = np.asarray(ref.lora_ref(x, a, b, idx, 1.0))
    np.testing.assert_allclose(got[:256], want[:256], rtol=2e-5, atol=2e-5)
    np.testing.assert_array_equal(got[256:], np.zeros_like(got[256:]))


def test_sgmv_no_overflow_when_balanced():
    # Balanced load at the same T never trips the capacity clamp.
    key = jax.random.PRNGKey(8)
    x, a, b = _bank(key, 512, 32, 8, 32, 8)
    idx = (jnp.arange(512, dtype=jnp.int32) % 8)
    got = sgmv(x, a, b, idx, 1.0, interpret=True)
    _close(got, ref.lora_ref(x, a, b, idx, 1.0), jnp.float32)


# --------------------------------------------------------------------- #
# ragged ranks: the bitwise identity
# --------------------------------------------------------------------- #

def test_sgmv_ragged_bitwise_vs_dense_masked_bank():
    key = jax.random.PRNGKey(9)
    x, a, b = _bank(key, 192, 32, 16, 48, 4)
    ranks = jnp.array([1, 16, 7, 4], jnp.int32)
    idx = jax.random.randint(key, (192,), -1, 4).astype(jnp.int32)
    ragged = sgmv(x, a, b, idx, 1.0, ranks=ranks, interpret=True)
    am, bm = ref.mask_ragged(a, b, ranks)
    dense = sgmv(x, am, bm, idx, 1.0, interpret=True)
    np.testing.assert_array_equal(np.asarray(ragged), np.asarray(dense))
    _close(ragged, ref.lora_ref_ragged(x, a, b, idx, ranks, 1.0),
           jnp.float32)


def test_lora_apply_ragged_routes_both_kernels():
    # ops.lora_apply with ranks= must agree with the ragged oracle on the
    # bgmv path (decode-sized T) and the sgmv path (prefill-sized T).
    key = jax.random.PRNGKey(10)
    ranks = jnp.array([2, 8, 5], jnp.int32)
    for t in (4, 96):   # 4 <= N*4 -> bgmv; 96 > N*4 -> sgmv
        x, a, b = _bank(jax.random.fold_in(key, t), t, 16, 8, 24, 3)
        idx = jax.random.randint(key, (t,), -1, 3).astype(jnp.int32)
        got = lora_apply(x, a, b, idx, 1.0, ranks=ranks, force="interpret")
        _close(got, ref.lora_ref_ragged(x, a, b, idx, ranks, 1.0),
               jnp.float32)


# --------------------------------------------------------------------- #
# fused decode edge shapes
# --------------------------------------------------------------------- #

def test_fused_decode_batch_one_rank_one():
    key = jax.random.PRNGKey(11)
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (1, 2, 16), jnp.float32)
    k = jax.random.normal(ks[1], (1, 48, 1, 16), jnp.float32)
    v = jax.random.normal(ks[2], (1, 48, 1, 16), jnp.float32)
    x, a, b = _bank(jax.random.fold_in(key, 1), 1, 16, 1, 2 * 16, 1)
    idx = jnp.array([0], jnp.int32)
    got = flash_decode_lora(q, k, v, jnp.array([20], jnp.int32),
                            x, a, b, idx, 3.0, interpret=True)
    _close(got, ref.fused_decode_ref(q, k, v, jnp.array([20], jnp.int32),
                                     x, a, b, idx, 3.0), jnp.float32)


def test_fused_decode_all_base_matches_flash_decode_bitwise():
    # every request id -1: the fused kernel must reduce to plain
    # flash-decode exactly (the masked delta is a literal 0.0 add in f32
    # before the output cast, so outputs are bitwise identical).
    key = jax.random.PRNGKey(12)
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (3, 4, 16), jnp.float32)
    k = jax.random.normal(ks[1], (3, 64, 2, 16), jnp.float32)
    v = jax.random.normal(ks[2], (3, 64, 2, 16), jnp.float32)
    x, a, b = _bank(jax.random.fold_in(key, 1), 3, 32, 8, 4 * 16, 2)
    length = jnp.array([10, 64, 33], jnp.int32)
    idx = jnp.full((3,), -1, jnp.int32)
    fused = flash_decode_lora(q, k, v, length, x, a, b, idx, 1.0,
                              interpret=True)
    plain = flash_decode(q, k, v, length, interpret=True)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(plain))


def test_fused_decode_expand_dim_mismatch_raises():
    key = jax.random.PRNGKey(13)
    q = jnp.zeros((1, 4, 16), jnp.float32)
    k = v = jnp.zeros((1, 32, 2, 16), jnp.float32)
    x, a, b = _bank(key, 1, 16, 4, 4 * 16 + 8, 1)  # o != H*D
    with pytest.raises(ValueError, match="expand dim"):
        flash_decode_lora(q, k, v, 8, x, a, b, jnp.array([0], jnp.int32),
                          interpret=True)


def test_fused_decode_dispatch_entry_point():
    # ops.fused_decode: ref mode == interpret mode == composed oracle,
    # including a ragged bank.
    key = jax.random.PRNGKey(14)
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (2, 4, 16), jnp.float32)
    k = jax.random.normal(ks[1], (2, 64, 2, 16), jnp.float32)
    v = jax.random.normal(ks[2], (2, 64, 2, 16), jnp.float32)
    x, a, b = _bank(jax.random.fold_in(key, 1), 2, 32, 8, 4 * 16, 3)
    ranks = jnp.array([8, 3, 1], jnp.int32)
    idx = jnp.array([2, 0], jnp.int32)
    length = jnp.array([64, 17], jnp.int32)
    am, bm = ref.mask_ragged(a, b, ranks)
    want = ref.fused_decode_ref(q, k, v, length, x, am, bm, idx, 1.0)
    for mode in ("ref", "interpret"):
        got = fused_decode(q, k, v, length, x, a, b, idx, 1.0,
                           ranks=ranks, force=mode)
        _close(got, want, jnp.float32)

"""Per-architecture smoke tests: reduced config of the same family, one
train step + prefill + decode on CPU; output shapes + finiteness."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.models import Model, ShardingPlan, applicable_shapes
from repro.models.layers import pad_vocab
from repro.models.transformer import pad_cache
from repro.training import (AdamWConfig, TrainConfig, init_train_state,
                            make_train_step)

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=32):
    batch = {"tokens": jax.random.randint(KEY, (b, s + 1), 0,
                                          cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["tokens"] = batch["tokens"][:, : s - cfg.n_image_tokens + 1]
        batch["img_embeds"] = jax.random.normal(
            KEY, (b, cfg.n_image_tokens, cfg.d_model), cfg.jnp_dtype)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    # every full config must carry the assigned dimensions
    assert cfg.n_layers >= 16 and cfg.d_model >= 1024
    assert cfg.vocab_size >= 2048
    if cfg.family in ("moe",):
        assert cfg.n_experts == 64 and cfg.top_k in (6, 8)
    if cfg.family == "ssm":
        assert cfg.ssm_state == 128 and cfg.is_attention_free
    if cfg.family == "hybrid":
        assert "rglru" in cfg.block_pattern and "local" in cfg.block_pattern


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = get_reduced(arch)
    model = Model(cfg, ShardingPlan(mode="train"))
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-3, warmup_steps=2))
    params, opt = init_train_state(model, KEY, tcfg)
    step = jax.jit(make_train_step(model, tcfg))
    batch = _batch(cfg)
    params2, opt2, info = step(params, opt, batch)
    assert jnp.isfinite(info["loss"])
    assert jnp.isfinite(info["grad_norm"])
    # params actually changed
    leaves_a = jax.tree.leaves(params)
    leaves_b = jax.tree.leaves(params2)
    assert any(
        not jnp.allclose(a.astype(jnp.float32), b.astype(jnp.float32))
        for a, b in zip(leaves_a, leaves_b))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_smoke(arch):
    cfg = get_reduced(arch)
    m_pre = Model(cfg, ShardingPlan(mode="prefill"))
    m_dec = Model(cfg, ShardingPlan(mode="decode"))
    params = m_pre.init(KEY)
    lora = m_pre.init_lora(KEY, 4, 4)
    b, s = 2, 24
    tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    kwargs = {}
    if cfg.family == "vlm":
        kwargs["img_embeds"] = jax.random.normal(
            KEY, (b, cfg.n_image_tokens, cfg.d_model), cfg.jnp_dtype)
    idx = jnp.array([0, 3], jnp.int32)
    logits, cache = jax.jit(m_pre.prefill)(params, lora, tokens, idx,
                                           **kwargs)
    assert logits.shape == (b, pad_vocab(cfg.vocab_size))
    assert jnp.isfinite(logits).all()
    cache = pad_cache(cache, 4)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    step = jax.jit(m_dec.decode_step)
    for _ in range(3):
        logits, cache = step(params, lora, cache, tok, idx)
        assert jnp.isfinite(logits).all()
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    assert int(cache["pos"]) == (s if cfg.family != "vlm"
                                 else s + cfg.n_image_tokens) + 3


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_shape_applicability(arch):
    cfg = get_config(arch)
    shapes = {s.name for s in applicable_shapes(cfg)}
    assert {"train_4k", "prefill_32k", "decode_32k"} <= shapes
    if arch in ("gemma3_1b", "mamba2_2p7b", "recurrentgemma_9b"):
        assert "long_500k" in shapes
    else:
        assert "long_500k" not in shapes

"""Predictive (model-driven) rebalancing + hot-adapter replication.

Covers the PR's acceptance criteria: predictive >= reactive throughput
under drifting popularity, replication resolves the single-hot-adapter
starvation migration alone cannot fix, the plan vocabulary's router
mechanics (replicate / unreplicate / multi-home degrade on failure),
the EWMA cold-start seed, and the all-stragglers routing fallback.
"""
import sys

import numpy as np
import pytest

sys.path.insert(0, ".")  # for `benchmarks.*` when run from the repo root

from repro.core import (ClusterDigitalTwin, SweepRunner, WorkloadSpec,
                        collect_benchmark, collect_memmax, fit_estimators,
                        label_cluster_scenarios, make_adapter_pool)
from repro.serving import (AdapterLoadTracker, ClusterRouter, FailureEvent,
                           HardwareProfile, RebalancePolicy, Replicate,
                           SyntheticExecutor, Unreplicate,
                           make_replica_specs, plan_initial_placement)
from repro.serving.request import Adapter, Request

from benchmarks.fig_rebalancing import (drift_config, hotspot_config,
                                        placement_model, run_hotspot,
                                        run_mode)


@pytest.fixture(scope="module")
def est():
    profile = HardwareProfile()
    n, slots = 24, 12
    ranks = {i: (8, 16, 32)[i % 3] for i in range(n)}
    ex = SyntheticExecutor(profile, ranks, slots=slots, n_adapters=n, seed=0)
    return fit_estimators(collect_benchmark(ex, slots, n, ranks),
                          collect_memmax(profile), slots, n)


def _req(uid, adapter, arrival=0.0, prompt=100, output=100):
    return Request(uid=uid, adapter=adapter, arrival=arrival,
                   prompt_len=prompt, output_len=output)


# --------------------------------------------------------------------- #
# acceptance: the benchmark's new arms, asserted
# --------------------------------------------------------------------- #

def test_predictive_beats_reactive_under_drift(est):
    """fig_rebalancing acceptance: the model-driven rebalancer's
    throughput >= the reactive EWMA rebalancer's on the drifting point."""
    cfg = drift_config(smoke=True)
    reactive = run_mode(est, "rebalance", cfg)
    predictive = run_mode(est, "predictive", cfg)
    assert predictive.metrics.throughput >= \
        reactive.metrics.throughput - 1e-9
    assert predictive.metrics.n_finished == reactive.metrics.n_finished


def test_replication_resolves_single_hot_adapter_starvation(est):
    """fig_rebalancing acceptance: under hard affinity, migration alone
    starves on one hot adapter; replication serves it from two homes."""
    cfg = hotspot_config(smoke=True)
    mig_only = run_hotspot(est, cfg, replicate=False)
    repl = run_hotspot(est, cfg, replicate=True)
    assert mig_only.metrics.starved
    assert not repl.metrics.starved
    assert len(repl.online.replications) >= 1
    assert repl.metrics.n_finished > mig_only.metrics.n_finished
    # the second home actually served a meaningful share
    fin = sorted(m.n_finished for m in repl.metrics.per_replica)
    assert fin[0] >= 0.25 * fin[1]


def test_predictive_run_deterministic(est):
    cfg = drift_config(smoke=True)
    a = run_mode(est, "predictive", cfg)
    b = run_mode(est, "predictive", cfg)
    assert a.metrics.throughput == b.metrics.throughput
    assert [(m.adapter, m.src, m.dst, m.cost_s) for m in
            a.online.migrations] == \
           [(m.adapter, m.src, m.dst, m.cost_s) for m in
            b.online.migrations]


# --------------------------------------------------------------------- #
# plan-level initial placement (the model's bin-packing, warmed at t=0)
# --------------------------------------------------------------------- #

def test_plan_initial_placement_assigns_whole_pool():
    model = placement_model()
    pool = make_adapter_pool(16, [8, 16], [0.2, 0.05])
    stats = WorkloadSpec(adapters=pool).length_stats()
    plan = plan_initial_placement(model, pool, stats, n_replicas=2)
    assert set(plan) == {a.uid for a in pool}
    assert set(plan.values()) <= {0, 1}
    assert len(set(plan.values())) == 2        # the model spread the load


def test_initial_placement_warms_router_and_engines(est):
    pool = make_adapter_pool(8, [8], [0.2])
    spec = WorkloadSpec(adapters=pool, dataset="small", horizon=20.0,
                        seed=3)
    twin = ClusterDigitalTwin(est, mode="mean")
    router = ClusterRouter(twin.specs_from_slots([4, 4], mean_rank=8.0),
                           policy="affinity")
    placement = {a.uid: a.uid % 2 for a in pool}
    res = twin.simulate_online(spec, router, epoch=5.0, rebalance=False,
                               initial_placement=placement)
    assert res.metrics.n_finished > 0
    # warm beliefs mean the stream's first routes were not cold
    assert res.router_summary["n_cold_routes"] == 0


# --------------------------------------------------------------------- #
# router mechanics: replicate / unreplicate / failure degrade
# --------------------------------------------------------------------- #

def _router(n=2, slots=4):
    return ClusterRouter(make_replica_specs(n, slots, 100_000),
                         policy="affinity")


def test_router_replicate_multi_home_dispatch():
    router = _router()
    router.warm(7, 0)
    router.replicate(7, 0, 1)
    assert router.homes(7) == [0, 1]
    assert router.replicated == {7: {0, 1}}
    # multi-home dispatch: the adapter's traffic splits across homes
    for i in range(20):
        router.route(_req(i, adapter=7))
    assert router.assigned_requests[0] == 10
    assert router.assigned_requests[1] == 10


def test_router_unreplicate_degrades_to_single_home():
    router = _router()
    router.warm(7, 0)
    router.replicate(7, 0, 1)
    router.unreplicate(7, 1)
    assert router.homes(7) == [0]
    assert 7 not in router.replicated
    assert router.n_unreplications == 1


def test_router_lru_spares_replicated_homes():
    """Routing churn must not silently collapse a deliberate multi-home
    placement: the LRU belief eviction prefers non-replicated entries."""
    router = _router(slots=2)
    router.warm(7, 0)
    router.replicate(7, 0, 1)        # replica 1 holds {7}
    for i in range(6):               # churn other adapters through rep 1
        router._commit(1, _req(i, adapter=100 + i))
    assert router.homes(7) == [0, 1]  # 7 survived the belief churn
    assert 7 in router.replicated


def test_mark_dead_on_replicated_peer_degrades_cleanly():
    """Killing one home of a replicated adapter leaves it single-home on
    the survivor, with consistent router state."""
    router = _router()
    router.warm(7, 0)
    router.replicate(7, 0, 1)
    orphans = router.mark_dead(1)
    assert 7 in orphans
    assert router.homes(7) == [0]
    assert 7 not in router.replicated
    # routing still works and lands on the survivor
    assert router.route(_req(0, adapter=7)) == 0


def test_eligible_returns_live_set_when_all_stragglers():
    """The straggler route-away fallback: with *every* live replica
    flagged straggler, eligible() must return the live set, never an
    empty candidate list."""
    router = _router(n=3)
    for i in range(3):
        router.mark_straggler(i, True)
    assert router.eligible() == [0, 1, 2]
    assert router.route(_req(0, adapter=1)) in (0, 1, 2)
    # and with one replica dead on top, the dead one stays excluded
    router.mark_dead(2)
    assert router.eligible() == [0, 1]
    assert router.least_loaded() in (0, 1)


def test_replicated_adapter_survives_home_failure_in_sim(est):
    """Engine-level: kill one home of a replicated adapter mid-run; the
    stream still completes on the survivor (single-home degrade)."""
    cfg = hotspot_config(smoke=True)
    cfg = dict(cfg, horizon=40.0)
    pool = make_adapter_pool(cfg["n_adapters"], [8], [cfg["cold_rate"]])
    pool[0] = Adapter(uid=0, rank=8, rate=cfg["hot_rate"])
    spec = WorkloadSpec(adapters=pool, dataset="medium",
                        horizon=cfg["horizon"], seed=cfg["seed"])
    from repro.core import generate_requests
    reqs = generate_requests(spec)
    twin = ClusterDigitalTwin(est, mode="full",
                              max_running=cfg["max_running"])
    router = ClusterRouter(
        twin.specs_from_slots([4, 4], mean_rank=8.0),
        policy="affinity", overload_factor=1e9, slack=1e9)
    reb = twin.rebalancer(spec, router, replicate=True)
    res = twin.simulate_online(
        spec, router, requests=reqs, epoch=5.0, rebalance=False,
        rebalancer=reb,
        failures=[FailureEvent(replica=1, at=0.6 * cfg["horizon"])])
    assert len(res.online.replications) >= 1       # it did replicate
    assert 1 in res.online.failures_detected       # then lost one home
    assert 0 not in res.router_summary["replicated"]
    assert res.metrics.n_finished == len(reqs)     # and nothing starved


# --------------------------------------------------------------------- #
# rebalancer triggers: replication + decay-based unreplicate
# --------------------------------------------------------------------- #

def test_replication_trigger_and_decay_unreplicate():
    router = _router()
    router.warm(0, 0)
    router.warm(1, 1)
    pol = RebalancePolicy(router, load_cost_fn=lambda uid: 0.01,
                          replicate=True, unreplicate_patience=2)
    # adapter 0 routes 5000 tok/s on replica 0 (hot), adapter 1 trickles
    for t in range(1, 4):
        router.routed_tokens[0][0] = 5000.0 * t
        router.routed_tokens[1][1] = 500.0 * t
        pol.observe(now=float(t), window_s=1.0,
                    served_tokens=[1000.0, 1000.0], backlog=[10, 0])
    acts = pol.propose(now=3.0)
    reps = [a for a in acts if isinstance(a, Replicate)]
    assert reps and reps[0].adapter == 0
    assert reps[0].src == 0 and reps[0].dst == 1
    router.replicate(0, 0, 1)
    pol.commit(reps[0])
    assert pol.report.n_replications == 1

    # the hotspot cools: adapter 0 stops, adapter 1 keeps routing
    seen = []
    for t in range(4, 12):
        router.routed_tokens[1][1] = 500.0 * t
        pol.observe(now=float(t), window_s=1.0,
                    served_tokens=[1000.0, 1000.0], backlog=[0, 0])
        for a in pol.propose(now=float(t)):
            if isinstance(a, Unreplicate):
                seen.append(a)
                router.unreplicate(a.adapter, a.rep)
                pol.commit(a)
    assert len(seen) == 1 and seen[0].adapter == 0
    assert 0 not in router.replicated
    assert pol.report.n_unreplications == 1


def test_predictive_bounded_churn_on_balanced_workload(est):
    """No drift: the planner may mistake a noisy window for drift (it
    has no suffering gate by design) but churn stays bounded and cheap,
    and raising ``imbalance_patience`` suppresses it further."""
    pool = make_adapter_pool(12, [8], [0.1])
    spec = WorkloadSpec(adapters=pool, dataset="medium", horizon=40.0,
                        seed=5)
    twin = ClusterDigitalTwin(est, mode="mean")

    def run(patience):
        router = ClusterRouter(twin.specs_from_slots([6, 6],
                                                     mean_rank=8.0),
                               policy="affinity")
        reb = twin.predictive_rebalancer(spec, router, placement_model(),
                                         imbalance_patience=patience)
        return twin.simulate_online(spec, router, epoch=5.0,
                                    rebalance=False, rebalancer=reb)

    eager, patient = run(1), run(3)
    assert len(eager.online.migrations) <= 3
    assert len(patient.online.migrations) <= len(eager.online.migrations)
    # the noise moves did not cost meaningful throughput
    router0 = ClusterRouter(twin.specs_from_slots([6, 6], mean_rank=8.0),
                            policy="affinity")
    still = twin.simulate_online(spec, router0, epoch=5.0,
                                 rebalance=False)
    assert eager.metrics.throughput >= 0.98 * still.metrics.throughput


# --------------------------------------------------------------------- #
# EWMA cold-start seed (the bounce-back bugfix)
# --------------------------------------------------------------------- #

def test_tracker_seeds_ewma_from_first_observation():
    tracker = AdapterLoadTracker(n_replicas=1, alpha=0.4)
    tracker.update([{0: 100.0}], window_s=1.0)
    # seeded at the observed rate, NOT alpha-blended toward the zero init
    assert tracker.rate[0][0] == 100.0
    tracker.update([{0: 250.0}], window_s=1.0)
    assert tracker.rate[0][0] == pytest.approx(0.4 * 150.0 + 0.6 * 100.0)


def test_tracker_seed_applies_after_migration_move():
    """A migrated adapter's first window on the destination must not
    restart from zero: move() carries the rate, and a *new* adapter on
    the destination seeds from its first observation."""
    tracker = AdapterLoadTracker(n_replicas=2, alpha=0.4)
    tracker.update([{0: 100.0}, {}], window_s=1.0)
    tracker.move(0, 0, 1)
    assert tracker.rate[1][0] == 100.0           # carried, not zeroed
    # a brand-new adapter appearing on replica 1 seeds at full rate
    tracker.update([{0: 100.0}, {7: 80.0}], window_s=1.0)
    assert tracker.rate[1][7] == 80.0


def test_tracker_zero_rate_entries_not_created():
    tracker = AdapterLoadTracker(n_replicas=1, alpha=0.4)
    tracker.update([{0: 0.0}], window_s=1.0)
    assert 0 not in tracker.rate[0]


# --------------------------------------------------------------------- #
# SweepRunner determinism with the predictive arm's scenario grid
# --------------------------------------------------------------------- #

def test_label_determinism_with_predictive_grid(est):
    """The predictive arm's training grid labels identically for any
    SweepRunner pool size (serial included)."""
    from repro.core import Scenario
    scenarios = [
        Scenario(rates=(1.2, 0.3, 0.02), ranks=(8, 16), dataset="medium"),
        Scenario(rates=(0.6, 0.1, 0.02), ranks=(8, 16), dataset="medium"),
    ]
    kw = dict(max_adapters=8, replica_counts=(1, 2), horizon=15.0, seed=7)
    xs_a, ys_a = label_cluster_scenarios(est, scenarios, **kw)
    xs_b, ys_b = label_cluster_scenarios(
        est, scenarios, runner=SweepRunner(est, n_workers=2), **kw)
    xs_c, ys_c = label_cluster_scenarios(
        est, scenarios, runner=SweepRunner(est, n_workers=3), **kw)
    np.testing.assert_array_equal(xs_a, xs_b)
    np.testing.assert_array_equal(ys_a, ys_b)
    np.testing.assert_array_equal(ys_a, ys_c)

"""Placement search properties (paper Fig. 5) + interpretable models."""
import numpy as np
import pytest
pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (pip install -e .[dev])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (DecisionTree, LinearRegression, RandomForest,
                        collect_benchmark, collect_memmax, fit_estimators,
                        find_optimal_placement, make_adapter_pool)
from repro.core.dataset import encode_features, FEATURE_NAMES
from repro.serving import HardwareProfile, SyntheticExecutor


@pytest.fixture(scope="module")
def est():
    profile = HardwareProfile(noise=0.0)
    n, slots = 24, 12
    pool = make_adapter_pool(n, [8, 16, 32], [0.2, 0.1, 0.05])
    ranks = {a.uid: a.rank for a in pool}
    ex = SyntheticExecutor(profile, ranks, slots=slots, n_adapters=n, seed=0)
    return fit_estimators(collect_benchmark(ex, slots, n, ranks),
                          collect_memmax(profile), slots, n)


def test_placement_finds_feasible_point(est):
    pool = make_adapter_pool(64, [8], [0.1])
    res = find_optimal_placement(est, pool, "medium", horizon=100.0)
    assert res.best is not None
    assert 1 <= res.n_adapters <= 64
    assert 1 <= res.slots <= res.n_adapters
    assert res.throughput > 0
    assert not res.best.starved


def test_placement_higher_rate_fewer_adapters(est):
    """Paper Fig. 5: higher per-adapter rates saturate the node with
    fewer adapters but higher max throughput."""
    lo = find_optimal_placement(est, make_adapter_pool(96, [8], [0.05]),
                                "medium", horizon=100.0)
    hi = find_optimal_placement(est, make_adapter_pool(96, [8], [1.6]),
                                "medium", horizon=100.0)
    assert hi.n_adapters <= lo.n_adapters
    assert hi.throughput >= lo.throughput


def test_placement_larger_ranks_not_better(est):
    small = find_optimal_placement(est, make_adapter_pool(64, [8], [0.1]),
                                   "medium", horizon=100.0)
    large = find_optimal_placement(est, make_adapter_pool(64, [32], [0.1]),
                                   "medium", horizon=100.0)
    assert large.throughput <= small.throughput * 1.05


# --------------------------------------------------------------------- #
# interpretable models
# --------------------------------------------------------------------- #

def test_tree_beats_linear_on_stepwise_target():
    rng = np.random.default_rng(0)
    x = rng.uniform(-1, 1, (400, 3))
    y = np.where(x[:, 0] > 0, 10.0, -10.0) + \
        np.where(x[:, 1] > 0.5, 5.0, 0.0)
    tree = DecisionTree(max_depth=4).fit(x[:300], y[:300])
    lin = LinearRegression().fit(x[:300], y[:300])
    err_t = np.mean((tree.predict(x[300:])[:, 0] - y[300:]) ** 2)
    err_l = np.mean((lin.predict(x[300:]) - y[300:]) ** 2)
    assert err_t < err_l * 0.5


def test_forest_multioutput_and_rules():
    rng = np.random.default_rng(1)
    x = rng.uniform(0, 1, (300, 4))
    y = np.stack([x[:, 0] * 3, (x[:, 1] > 0.5).astype(float)], axis=1)
    rf = RandomForest(n_trees=5, max_depth=4).fit(x, y)
    pred = rf.predict(x)
    assert pred.shape == (300, 2)
    assert np.corrcoef(pred[:, 0], y[:, 0])[0, 1] > 0.8
    tree = DecisionTree(max_depth=3).fit(x, y)
    rules = tree.rules(feature_names=list("abcd"),
                       target_names=["t1", "t2"])
    assert rules and all("THEN" in r for r in rules)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_tree_predicts_constant_exactly(seed):
    rng = np.random.default_rng(seed)
    x = rng.uniform(0, 1, (50, 2))
    y = np.full(50, 7.5)
    tree = DecisionTree(max_depth=3).fit(x, y)
    np.testing.assert_allclose(tree.predict(x)[:, 0], 7.5)


def test_feature_encoding_shape():
    f = encode_features([0.1, 0.2], [8, 16],
                        {"in_mean": 250, "in_std": 0,
                         "out_mean": 231, "out_std": 0})
    assert f.shape == (len(FEATURE_NAMES),)

"""End-to-end behaviour: the full paper pipeline on a small scale —
benchmark -> fit -> DT -> dataset -> model -> recommend -> route."""

from repro.core import build_pipeline, make_adapter_pool
from repro.serving import PlacementRouter


def test_full_pipeline_end_to_end():
    pipe = build_pipeline(n_scenarios=8, max_adapters=32, horizon=60.0)
    # estimators exist and are sane
    assert pipe.est.lat_model(8) > pipe.est.lat_model(1) > 0
    assert pipe.est.lat_adapters(8) > 1.0
    rec = pipe.recommend([0.2, 0.1], [8, 16],
                         {"in_mean": 250, "in_std": 0,
                          "out_mean": 231, "out_std": 0})
    assert rec["served_adapters"] >= 1
    assert rec["adapter_slots"] >= 1
    assert rec["throughput"] > 0
    assert rec["inference_ms"] < 50.0      # paper: ~0.12ms

    router = PlacementRouter(pipe, n_replicas=2)
    pool = make_adapter_pool(20, [8, 16], [0.2, 0.1])
    state = router.plan(pool, {"in_mean": 250, "in_std": 0,
                               "out_mean": 231, "out_std": 0})
    assert sum(len(p.adapters) for p in state.plans) == 20

"""S-LoRA serving mode (paper §V-B): dynamic slots with unified
adapter/KV memory and idle-adapter eviction."""

from repro.core import (DigitalTwin, WorkloadSpec, collect_benchmark,
                        collect_memmax, fit_estimators, generate_requests,
                        make_adapter_pool)
from repro.serving import (AdapterSlotCache, EngineConfig, PagedKVCache,
                           ServingEngine, SyntheticExecutor, HardwareProfile)


def test_dynamic_cache_charges_unified_pool():
    kv = PagedKVCache(1024, block_size=16)

    def reserve(uid, dry=False):
        if dry:
            return kv.can_allocate(256)
        return kv.allocate(-(uid + 1), 256)

    def release(uid):
        kv.free(-(uid + 1))

    ac = AdapterSlotCache(0, dynamic=True, reserve=reserve, release=release)
    assert ac.load(1, 0.0) is True
    assert ac.load(2, 1.0) is True
    used_after_two = kv.free_blocks
    assert used_after_two == 1024 // 16 - 2 * (256 // 16)
    # third + fourth fill the pool; fifth must evict the idle LRU
    ac.load(3, 2.0)
    ac.load(4, 3.0)
    assert kv.free_blocks == 0
    ac.load(5, 4.0)
    assert ac.evict_count == 1 and not ac.is_loaded(1)
    assert kv.free_blocks == 0


def test_slora_engine_runs_and_flat_decline():
    """Dynamic mode serves low-rate many-adapter workloads that starve the
    slot-limited engine less (the paper's Fig. 7-right observation)."""
    profile = HardwareProfile(noise=0.0)
    n = 48
    pool = make_adapter_pool(n, [32], [0.05])
    ranks = {a.uid: a.rank for a in pool}
    spec = WorkloadSpec(adapters=pool, dataset="medium", horizon=150.0,
                        seed=4)
    per_adapter = int(profile.kv_tokens_per_rank_slot * 32 / 8)
    cfg_dyn = EngineConfig(
        kv_capacity_tokens=profile.total_kv_tokens, adapter_slots=0,
        dynamic_slots=True,
        adapter_kv_tokens={a.uid: per_adapter for a in pool})
    m_dyn = ServingEngine(cfg_dyn, SyntheticExecutor(
        profile, ranks, slots=n, n_adapters=n)).run(
            generate_requests(spec), horizon=150.0)
    assert m_dyn.n_finished > 0
    assert not m_dyn.starved
    # vLLM-style with pathologically few static slots starves
    cfg_static = EngineConfig(
        kv_capacity_tokens=profile.kv_capacity(2, 32), adapter_slots=2)
    reqs2 = generate_requests(WorkloadSpec(
        adapters=make_adapter_pool(n, [32], [0.4]), dataset="medium",
        horizon=150.0, seed=4))
    m_static = ServingEngine(cfg_static, SyntheticExecutor(
        profile, ranks, slots=2, n_adapters=n)).run(reqs2, horizon=150.0)
    assert m_static.starved


def test_dt_supports_dynamic_mode():
    profile = HardwareProfile()
    n, slots = 24, 12
    pool = make_adapter_pool(n, [8, 16, 32], [0.1])
    ranks = {a.uid: a.rank for a in pool}
    ex = SyntheticExecutor(profile, ranks, slots=slots, n_adapters=n)
    est = fit_estimators(collect_benchmark(ex, slots, n, ranks),
                         collect_memmax(profile), slots, n)
    spec = WorkloadSpec(adapters=pool, dataset="medium", horizon=100.0)
    res = DigitalTwin(est, mode="mean").simulate(spec, slots=n,
                                                 dynamic_slots=True)
    assert res.metrics.throughput > 0

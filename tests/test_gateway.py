"""Async gateway: determinism vs the closed loop, admission control,
drain semantics, streaming, and the stdlib HTTP binding.

No pytest-asyncio dependency: every async scenario runs under a plain
``asyncio.run`` inside a sync test.
"""
import asyncio
import json

from repro.core.workload import (WorkloadSpec, generate_requests, load_trace,
                                 make_adapter_pool, open_loop_arrivals,
                                 replay_trace, save_trace)
from repro.serving import (AdmissionControl, AsyncGateway, EngineConfig,
                           GatewayHTTPServer, HardwareProfile, Rejected,
                           Request, ServingEngine, SyntheticExecutor)


def make_engine(n_adapters=8, slots=4, kv=20_000, max_running=16, seed=0):
    profile = HardwareProfile()
    ranks = {i: 8 for i in range(n_adapters)}
    ex = SyntheticExecutor(profile, ranks, slots=slots,
                          n_adapters=n_adapters, seed=seed)
    return ServingEngine(EngineConfig(
        kv_capacity_tokens=kv, adapter_slots=slots,
        max_running=max_running), ex)


def make_trace(n_adapters=8, rate=0.8, horizon=20.0, seed=3):
    pool = make_adapter_pool(n_adapters, [8], [rate])
    spec = WorkloadSpec(adapters=pool, dataset="medium", horizon=horizon,
                        seed=seed)
    return generate_requests(spec)


# --------------------------------------------------------------------------- #
# determinism: driven gateway == closed-loop engine
# --------------------------------------------------------------------------- #

def test_gateway_matches_closed_loop_run():
    trace = make_trace()
    closed = make_engine(seed=1).run(list(replay_trace(trace)))

    gw = AsyncGateway(make_engine(seed=1))
    rep = asyncio.run(gw.run(replay_trace(trace)))

    assert rep.serving.n_finished == closed.n_finished
    assert rep.serving.n_starved_requests == closed.n_starved_requests
    assert sorted(rep.serving.ttft_samples) == sorted(closed.ttft_samples)
    assert rep.serving.throughput == closed.throughput
    assert rep.serving.duration == closed.duration
    assert rep.gateway.n_admitted == len(trace)
    assert rep.gateway.n_rejected == 0


def test_gateway_matches_closed_loop_at_horizon():
    """No-drain horizon cut matches run(horizon=...) semantics too."""
    trace = make_trace(rate=2.0, horizon=10.0)
    closed = make_engine(seed=2).run(list(replay_trace(trace)),
                                     horizon=10.0)
    gw = AsyncGateway(make_engine(seed=2))
    rep = asyncio.run(gw.run(replay_trace(trace), duration=10.0,
                             drain=False))
    assert rep.serving.n_finished == closed.n_finished
    assert rep.serving.n_starved_requests == closed.n_starved_requests
    assert sorted(rep.serving.ttft_samples) == sorted(closed.ttft_samples)


def test_trace_roundtrip(tmp_path):
    trace = make_trace(horizon=8.0)
    path = tmp_path / "trace.json"
    save_trace(path, trace)
    loaded = load_trace(path)
    assert [(r.uid, r.adapter, r.arrival, r.prompt_len, r.output_len)
            for r in loaded] == \
        [(r.uid, r.adapter, r.arrival, r.prompt_len, r.output_len)
         for r in trace]

    a = make_engine(seed=4).run(list(replay_trace(trace)))
    b = make_engine(seed=4).run(list(replay_trace(loaded)))
    assert a.n_finished == b.n_finished
    assert sorted(a.ttft_samples) == sorted(b.ttft_samples)


def test_open_loop_arrivals_deterministic_and_ordered():
    pool = make_adapter_pool(6, [8], [0.7])
    a = list(open_loop_arrivals(pool, horizon=15.0, seed=9))
    b = list(open_loop_arrivals(pool, horizon=15.0, seed=9))
    assert [(r.adapter, r.arrival) for r in a] == \
        [(r.adapter, r.arrival) for r in b]
    assert all(x.arrival <= y.arrival for x, y in zip(a, a[1:]))
    assert all(r.arrival < 15.0 for r in a)
    assert {r.adapter for r in a} == set(range(6))
    assert [r.uid for r in a] == list(range(len(a)))


# --------------------------------------------------------------------------- #
# edge cases: idle, burst/admission, drain, streaming counters
# --------------------------------------------------------------------------- #

def test_zero_arrival_idle():
    gw = AsyncGateway(make_engine())
    rep = asyncio.run(gw.run(iter([])))
    assert rep.serving.n_finished == 0
    assert rep.gateway.n_submitted == 0
    assert rep.duration == 0.0
    assert gw.state == "stopped"


def test_zero_arrival_idle_tick_live():
    """Live mode with no traffic: the pump ticks without advancing an
    idle engine and shuts down cleanly."""
    async def scenario():
        gw = AsyncGateway(make_engine(), tick=0.005, time_scale=100.0)
        await gw.start()
        await asyncio.sleep(0.05)        # several pump ticks
        return await gw.shutdown()

    rep = asyncio.run(scenario())
    assert rep.serving.n_finished == 0
    assert rep.duration == 0.0           # idle engine never moved


def test_burst_rejects_then_recovers():
    """A burst past the admission budget sheds; once the backlog drains
    a later request is admitted again."""
    adm = AdmissionControl(slo_budget=5.0, service_time=lambda r: 1.0)
    gw = AsyncGateway(make_engine(), admission=adm)
    burst = [Request(uid=i, adapter=i % 4, arrival=0.0, prompt_len=64,
                     output_len=32) for i in range(20)]
    late = Request(uid=99, adapter=0, arrival=500.0, prompt_len=64,
                   output_len=32)
    rep = asyncio.run(gw.run(iter(burst + [late])))

    assert rep.gateway.n_rejected > 0
    assert rep.gateway.n_admitted + rep.gateway.n_rejected == 21
    # queue_depth grows 0,1,2,... during the burst: exactly budget/1.0
    # + 1 requests fit before the predicted backlog trips the gate
    assert rep.gateway.n_admitted == 6 + 1
    assert sum(rep.gateway.rejected_per_adapter.values()) == \
        rep.gateway.n_rejected
    # the late arrival found an empty queue again -> admitted + finished
    assert late.finished_at is not None
    assert rep.serving.n_finished == rep.gateway.n_admitted


def test_rejected_requests_never_reach_engine():
    adm = AdmissionControl(slo_budget=0.5, service_time=lambda r: 1.0)
    engine = make_engine()
    gw = AsyncGateway(engine, admission=adm)
    reqs = [Request(uid=i, adapter=0, arrival=0.0, prompt_len=16,
                    output_len=8) for i in range(5)]
    rep = asyncio.run(gw.run(iter(reqs)))
    # depth 0 admits the first; every later one sees depth >= 1 -> shed
    assert rep.gateway.n_admitted == 1
    assert rep.gateway.n_rejected == 4
    assert rep.gateway.rejected_per_adapter == {0: 4}
    assert len(engine._accepted) == 1


def test_drain_completes_all_admitted():
    trace = make_trace(rate=1.5, horizon=6.0)
    gw = AsyncGateway(make_engine())
    rep = asyncio.run(gw.run(replay_trace(trace)))
    admitted = gw.trace
    assert len(admitted) == len(trace)
    assert all(r.finished_at is not None for r in admitted)
    assert rep.serving.n_finished == rep.gateway.n_admitted
    assert rep.serving.n_starved_requests == 0


def test_offers_rejected_while_draining():
    gw = AsyncGateway(make_engine())
    rep = asyncio.run(gw.run(iter([])))
    assert rep is not None
    res = gw.offer(Request(uid=0, adapter=0, arrival=0.0, prompt_len=8,
                           output_len=4))
    assert isinstance(res, Rejected)
    assert res.status == 503
    assert gw.metrics.n_rejected_draining == 1


def test_streaming_counts_match_serving_metrics():
    """Every generated token fires the callback exactly once: the
    gateway's streamed-token counter equals the engine's output-token
    counter and the metrics' throughput integral."""
    trace = make_trace(rate=1.0, horizon=8.0)
    engine = make_engine()
    gw = AsyncGateway(engine)
    rep = asyncio.run(gw.run(replay_trace(trace),
                             want_stream=lambda r: True))
    assert rep.gateway.n_streams == len(trace)
    assert rep.gateway.n_streamed_tokens == engine.n_tokens_out
    assert rep.gateway.n_streamed_tokens == \
        sum(r.generated for r in gw.trace)
    assert abs(rep.serving.throughput * rep.serving.duration
               - rep.gateway.n_streamed_tokens) < 1e-6


def test_live_stream_chunks():
    """Live mode: a streamed submit yields one chunk per token, the last
    one carrying finish_reason=stop."""
    async def scenario():
        gw = AsyncGateway(make_engine(), tick=0.001, time_scale=500.0)
        await gw.start()
        stream = await gw.submit(adapter=2, prompt_len=16, output_len=5,
                                 stream=True)
        chunks = [c async for c in stream]
        rep = await gw.shutdown()
        return chunks, rep

    chunks, rep = asyncio.run(scenario())
    assert len(chunks) == 5
    assert [c["choices"][0]["finish_reason"] for c in chunks] == \
        [None] * 4 + ["stop"]
    assert chunks[0]["model"] == "adapter-2"
    assert rep.gateway.n_streamed_tokens == 5


# --------------------------------------------------------------------------- #
# HTTP binding
# --------------------------------------------------------------------------- #

async def _post(port, payload, timeout=30.0):
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    body = json.dumps(payload).encode()
    writer.write(b"POST /v1/completions HTTP/1.1\r\nHost: t\r\n"
                 b"Content-Type: application/json\r\n"
                 + f"Content-Length: {len(body)}\r\n\r\n".encode() + body)
    await writer.drain()
    data = await asyncio.wait_for(reader.read(), timeout)
    writer.close()
    return data.decode()


async def _get(port, path, timeout=30.0):
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    writer.write(f"GET {path} HTTP/1.1\r\nHost: t\r\n\r\n".encode())
    await writer.drain()
    data = await asyncio.wait_for(reader.read(), timeout)
    writer.close()
    return data.decode()


def _body(resp: str) -> dict:
    return json.loads(resp.split("\r\n\r\n", 1)[1])


def test_http_completions_and_metrics():
    async def scenario():
        gw = AsyncGateway(make_engine(), tick=0.001, time_scale=500.0)
        await gw.start()
        server = await GatewayHTTPServer(gw, port=0).start()
        out = {}
        out["plain"] = await _post(server.port, {
            "model": "adapter-3", "prompt": "three word prompt",
            "max_tokens": 4})
        out["sse"] = await _post(server.port, {
            "adapter": 1, "prompt_tokens": 8, "max_tokens": 3,
            "stream": True})
        out["metrics"] = await _get(server.port, "/v1/metrics")
        out["health"] = await _get(server.port, "/v1/health")
        out["missing"] = await _get(server.port, "/nope")
        await server.stop()
        await gw.shutdown()
        return out

    out = asyncio.run(scenario())
    assert out["plain"].startswith("HTTP/1.1 200")
    plain = _body(out["plain"])
    assert plain["model"] == "adapter-3"
    assert plain["usage"]["completion_tokens"] == 4
    assert plain["choices"][0]["finish_reason"] == "stop"

    assert out["sse"].startswith("HTTP/1.1 200")
    assert "text/event-stream" in out["sse"]
    chunks = [json.loads(line[len("data: "):])
              for line in out["sse"].splitlines()
              if line.startswith("data: {")]
    assert len(chunks) == 3
    assert chunks[-1]["choices"][0]["finish_reason"] == "stop"
    assert "data: [DONE]" in out["sse"]

    metrics = _body(out["metrics"])
    assert metrics["n_admitted"] == 2
    assert metrics["n_rejected"] == 0
    # prefix-cache counters are always exposed (zero with the cache off)
    for key in ("n_prefix_hits", "n_prefix_misses", "n_prefix_evictions",
                "prefix_tokens_saved"):
        assert metrics[key] == 0
    assert _body(out["health"]) == {"status": "serving"}
    assert out["missing"].startswith("HTTP/1.1 404")


def test_http_backpressure_429():
    """With a zero budget, any nonempty queue sheds: the first (slow,
    streamed) request occupies the engine, the second gets a 429."""
    async def scenario():
        adm = AdmissionControl(slo_budget=0.0,
                               service_time=lambda r: 1000.0)
        # time_scale tiny: virtually nothing finishes during the test
        gw = AsyncGateway(make_engine(), admission=adm, tick=0.01,
                          time_scale=0.001)
        await gw.start()
        server = await GatewayHTTPServer(gw, port=0).start()
        first = asyncio.create_task(_post(server.port, {
            "adapter": 0, "prompt_tokens": 8, "max_tokens": 200,
            "stream": True}))
        while gw.metrics.n_admitted == 0:      # first request in queue
            await asyncio.sleep(0.005)
        second = await _post(server.port, {
            "adapter": 1, "prompt_tokens": 8, "max_tokens": 4})
        await server.stop()
        await gw.shutdown()                     # drains -> first finishes
        return await first, second

    first, second = asyncio.run(scenario())
    assert second.startswith("HTTP/1.1 429")
    err = _body(second)["error"]
    assert err["code"] == 429 and err["type"] == "overloaded"
    assert first.startswith("HTTP/1.1 200")
    assert "data: [DONE]" in first

"""Measured step-time hook: equivalence pin + opt-in behaviour.

The ``measured_step_times`` hook on the twins and the placement sweep is
strictly opt-in.  This suite pins the contract:

* ``measured_step_times=None`` (and plain construction) is BITWISE
  identical to the pre-hook twins on every EXACT_FIELDS metric — the
  hook may not perturb existing results by a single ulp;
* attaching a ``MeasuredStepTimes`` actually changes the simulation (the
  surface is used, not dropped on the floor);
* with the hook on, the legacy ``DigitalTwin`` and the struct-of-arrays
  ``FastTwin`` still agree exactly (the equivalence contract survives);
* ``fit_measured_step_times`` recovers planted coefficients from clean
  rows;
* ``find_optimal_placement`` threads the hook through to the twin.
"""
import numpy as np
import pytest

from repro.core import (DigitalTwin, FastTwin, MeasuredStepTimes,
                        WorkloadSpec, find_optimal_placement,
                        fit_measured_step_times, make_adapter_pool)
from repro.core.estimators import FittedEstimators
from repro.serving.metrics import TWIN_EXACT_FIELDS as EXACT_FIELDS


def mk_est() -> FittedEstimators:
    return FittedEstimators(
        sched=np.array([4e-4, 8e-6, 4e-6, 2.5e-5]),
        model=np.array([2.4e-2, 2.2e-4, 6.5e-6]),
        adapters=np.array([1.06, 0.004]),
        load=np.array([8e-3, 1.1e-3]),
        load_disk_mult=1.7,
        memmax=np.array([120000.0, -60.0]))


def mk_measured() -> MeasuredStepTimes:
    # kernel-ish magnitudes, deliberately NOT equal to mk_est()'s analytic
    # fit so attaching it visibly changes simulation results
    return MeasuredStepTimes(
        decode=np.array([1.8e-2, 1.5e-4, 4e-8, 9e-6]),
        prefill_per_token=5e-6,
        adapters=np.array([1.03, 0.006]))


def mk_spec(seed: int = 3) -> WorkloadSpec:
    pool = make_adapter_pool(24, [8, 16, 32], [0.15])
    return WorkloadSpec(adapters=pool, dataset="medium", horizon=80.0,
                        seed=seed)


def assert_same(a, b):
    for f in EXACT_FIELDS:
        assert getattr(a, f) == getattr(b, f), \
            f"{f}: {getattr(a, f)} != {getattr(b, f)}"


# --------------------------------------------------------------------- #
# the None pin: hook absent == hook never existed, bitwise
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("twin_cls", [DigitalTwin, FastTwin])
def test_measured_none_is_bitwise_noop(twin_cls):
    est, spec = mk_est(), mk_spec()
    plain = twin_cls(est).simulate(spec, slots=8).metrics
    hooked = twin_cls(est, measured_step_times=None) \
        .simulate(spec, slots=8).metrics
    assert_same(plain, hooked)
    assert plain.itl == hooked.itl          # bitwise, not approx


def test_with_measured_none_roundtrip_detaches():
    est = mk_est()
    attached = est.with_measured(mk_measured())
    assert attached.measured is not None
    assert est.measured is None             # original untouched
    detached = attached.with_measured(None)
    assert detached.measured is None
    assert detached.lat_model(8, 0) == est.lat_model(8, 0)


# --------------------------------------------------------------------- #
# opt-in actually changes behaviour, and the twins still agree
# --------------------------------------------------------------------- #

def test_measured_surface_is_used():
    est, spec = mk_est(), mk_spec()
    ms = mk_measured()
    base = FastTwin(est).simulate(spec, slots=8).metrics
    hooked = FastTwin(est, measured_step_times=ms) \
        .simulate(spec, slots=8).metrics
    assert hooked.duration != base.duration
    # the estimator methods themselves must reflect the surface
    attached = est.with_measured(ms)
    assert attached.lat_model(8, 0) == ms.lat_model(8, 0)
    assert attached.lat_adapters(4) == ms.lat_adapters(4)
    assert attached.lat_adapters(0) == 1.0


def test_twin_equivalence_with_measured_on():
    est, spec = mk_est(), mk_spec(seed=11)
    ms = mk_measured()
    legacy = DigitalTwin(est, measured_step_times=ms) \
        .simulate(spec, slots=6).metrics
    fast = FastTwin(est, measured_step_times=ms) \
        .simulate(spec, slots=6).metrics
    assert legacy.n_finished > 0
    assert_same(legacy, fast)
    assert fast.itl == pytest.approx(legacy.itl, rel=1e-9, abs=1e-12)


# --------------------------------------------------------------------- #
# fitting
# --------------------------------------------------------------------- #

def test_fit_recovers_planted_coefficients():
    true = MeasuredStepTimes(decode=np.array([2e-2, 1e-4, 5e-8, 1e-5]),
                             prefill_per_token=4e-6,
                             adapters=np.array([1.02, 0.005]))
    rows = []
    for b in (1, 2, 4, 8, 16):
        for s in (128, 512, 2048):
            for r in (8, 16, 32):
                rows.append(dict(kind="decode", batch=b, seq=s, rank=r,
                                 t=float(true.decode
                                         @ [1.0, b, b * s, b * r])))
    for tok in (128, 512, 2048):
        rows.append(dict(kind="prefill", tokens=tok,
                         t=1e-4 + true.prefill_per_token * tok))
    for a in (1, 2, 4, 8):
        rows.append(dict(kind="adapters", a_unique=a,
                         mult=float(true.adapters @ [1.0, a])))
    fit = fit_measured_step_times(rows)
    np.testing.assert_allclose(fit.decode, true.decode, rtol=1e-6)
    assert fit.prefill_per_token == pytest.approx(true.prefill_per_token,
                                                 rel=1e-6)
    np.testing.assert_allclose(fit.adapters, true.adapters, rtol=1e-6)


def test_fit_requires_decode_rows():
    with pytest.raises(ValueError, match="decode"):
        fit_measured_step_times([dict(kind="prefill", tokens=8, t=1e-4)])


# --------------------------------------------------------------------- #
# placement threading
# --------------------------------------------------------------------- #

def test_placement_threads_measured_hook():
    est = mk_est()
    pool = make_adapter_pool(12, [8, 16], [0.2])
    kw = dict(dataset="medium", horizon=40.0, seed=2, n_grid=[6, 12],
              early_stop=0)
    base = find_optimal_placement(est, pool, **kw)
    hooked_none = find_optimal_placement(est, pool,
                                         measured_step_times=None, **kw)
    # None threads through as a bitwise no-op on the whole sweep
    assert [(-p.n_adapters, p.slots, p.throughput) for p in base.curve] == \
        [(-p.n_adapters, p.slots, p.throughput) for p in hooked_none.curve]
    hooked = find_optimal_placement(est, pool,
                                    measured_step_times=mk_measured(), **kw)
    assert any(a.throughput != b.throughput
               for a, b in zip(base.curve, hooked.curve))

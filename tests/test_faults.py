"""Deterministic fault injection + request reliability.

The contract under test: a seeded ``FaultPlan`` replays *bitwise
identically* — across repeated runs, across the legacy object-mode
``ServingEngine`` and the struct-of-arrays ``FastEngine``, and between
the production cluster loop and the Digital Twin (they are the same
loop).  On top sits the request lifecycle: deadlines, bounded
retry-with-backoff onto survivors, per-replica circuit breakers, crash
snapshot/restore with Fig. 4 reload costs, and client-disconnect
cancellation — with zero lost requests (every admitted request reaches
exactly one terminal state).

An empty plan must leave every engine bitwise identical to the pre-fault
code path (the healthy-path pinning guard).
"""
import asyncio
import json

import numpy as np
import pytest

from repro.core import (ClusterDigitalTwin, WorkloadSpec, generate_requests,
                        make_adapter_pool)
from repro.core.estimators import FittedEstimators
from repro.serving import (AdapterLoadFault, AsyncGateway, CircuitBreaker,
                           ClientDisconnect, ClusterRouter, EngineConfig,
                           FaultPlan, GatewayHTTPServer, HardwareProfile,
                           NoAliveReplicasError, Rejected, ReliabilityPolicy,
                           ReplicaCrash, Request, ServingEngine,
                           StragglerWindow, SyntheticExecutor,
                           generate_fault_plan, parse_chaos_spec)
from repro.serving.metrics import TWIN_EXACT_FIELDS as EXACT_FIELDS


def mk_est() -> FittedEstimators:
    return FittedEstimators(
        sched=np.array([4e-4, 8e-6, 4e-6, 2.5e-5]),
        model=np.array([2.4e-2, 2.2e-4, 6.5e-6]),
        adapters=np.array([1.06, 0.004]),
        load=np.array([8e-3, 1.1e-3]),
        load_disk_mult=1.7,
        memmax=np.array([120000.0, -60.0]))


# --------------------------------------------------------------------------- #
# unit: circuit breaker state machine
# --------------------------------------------------------------------------- #

def test_breaker_opens_at_threshold_and_half_opens_after_cooldown():
    br = CircuitBreaker(threshold=3, cooldown_s=10.0)
    br.record_failure(0.0)
    br.record_failure(1.0)
    assert br.state == CircuitBreaker.CLOSED and not br.blocked
    br.record_failure(2.0)
    assert br.state == CircuitBreaker.OPEN and br.blocked
    assert br.n_opens == 1
    br.tick(11.0)                       # cooldown not yet elapsed (12.0)
    assert br.state == CircuitBreaker.OPEN
    br.tick(12.0)
    assert br.state == CircuitBreaker.HALF_OPEN and not br.blocked
    br.record_success()                 # probe succeeded -> closed, reset
    assert br.state == CircuitBreaker.CLOSED and br.failures == 0


def test_breaker_probe_failure_reopens():
    br = CircuitBreaker(threshold=2, cooldown_s=5.0)
    br.record_failure(0.0)
    br.record_failure(0.0)
    br.tick(5.0)
    assert br.state == CircuitBreaker.HALF_OPEN
    br.record_failure(6.0)              # probe failed -> straight to open
    assert br.state == CircuitBreaker.OPEN
    assert br.n_opens == 2
    assert br.opened_at == 6.0


def test_breaker_routine_success_does_not_erase_failures():
    """A replica that heartbeats fine but fails loads must still trip:
    successes while CLOSED do not reset the failure count."""
    br = CircuitBreaker(threshold=3, cooldown_s=5.0)
    br.record_failure(0.0)
    br.record_success()
    br.record_failure(1.0)
    br.record_success()
    br.record_failure(2.0)
    assert br.state == CircuitBreaker.OPEN


# --------------------------------------------------------------------------- #
# unit: plan generator + --chaos grammar
# --------------------------------------------------------------------------- #

def test_generate_fault_plan_deterministic():
    kw = dict(n_replicas=3, horizon=60.0, seed=7, adapters=[1, 2, 3],
              n_crashes=2, n_adapter_faults=1, n_stragglers=1,
              n_executor_faults=1, n_disconnects=2, n_requests=100)
    a, b = generate_fault_plan(**kw), generate_fault_plan(**kw)
    assert a.events == b.events
    assert a.summary() == {"crashes": 2, "adapter_faults": 1,
                           "straggler_windows": 1, "executor_faults": 1,
                           "disconnects": 2}
    # a different seed must change at least one event time
    c = generate_fault_plan(**{**kw, "seed": 8})
    assert c.events != a.events
    # events are well-formed: within the horizon, valid replica targets
    for ev in a.crashes:
        assert 0 <= ev.replica < 3 and 0 < ev.at < 60.0
        assert ev.recover_at is None or ev.recover_at > ev.at


def test_parse_chaos_spec_grammar():
    plan = parse_chaos_spec("crash:1,loadfail:2,straggler,disconnect:3",
                            n_replicas=2, horizon=40.0, seed=0,
                            adapters=[0, 1], n_requests=50)
    assert plan.summary() == {"crashes": 1, "adapter_faults": 2,
                              "straggler_windows": 1, "executor_faults": 0,
                              "disconnects": 3}
    with pytest.raises(ValueError, match="unknown fault kind"):
        parse_chaos_spec("meteor:1", 2, 40.0)
    # disconnects need a known stream size
    empty = parse_chaos_spec("disconnect:2", 1, 40.0, n_requests=0)
    assert empty.summary()["disconnects"] == 0


# --------------------------------------------------------------------------- #
# contract: NoAliveReplicasError
# --------------------------------------------------------------------------- #

def test_no_alive_replicas_contract():
    est = mk_est()
    twin = ClusterDigitalTwin(est, fast=True)
    router = ClusterRouter(twin.specs_from_slots([4, 4]), policy="affinity")
    router.reset()
    router.mark_dead(0)
    assert router.eligible() == [1]
    with pytest.raises(NoAliveReplicasError, match="all replicas dead"):
        router.mark_dead(1)
    # it must stay a RuntimeError so pre-existing callers keep working
    assert issubclass(NoAliveReplicasError, RuntimeError)
    router.alive = [False, False]
    with pytest.raises(NoAliveReplicasError, match="no alive replicas"):
        router.eligible()


# --------------------------------------------------------------------------- #
# engine: snapshot / restore with reload costs
# --------------------------------------------------------------------------- #

def _mk_engine(seed=0, slots=4):
    profile = HardwareProfile()
    ranks = {i: 8 for i in range(8)}
    ex = SyntheticExecutor(profile, ranks, slots=slots, n_adapters=8,
                          seed=seed)
    return ServingEngine(EngineConfig(
        kv_capacity_tokens=20_000, adapter_slots=slots,
        max_running=16), ex)


def test_snapshot_restore_charges_reload_costs_and_skips_failing():
    eng = _mk_engine()
    eng.reset_stream()
    assert eng.preload_adapter(1) and eng.preload_adapter(2)
    snap = eng.snapshot()
    assert snap["adapters"] == [1, 2]
    eng.drain()                          # crash: halted, cache dropped
    assert eng.halted
    eng.adapters.failing = {2}           # adapter 2 faults during restore
    reloaded = eng.restore(snap, now=50.0, load_cost_fn=lambda uid: 3.0)
    assert not eng.halted
    assert reloaded == [1]
    assert eng.n_load_faults == 1
    assert eng.clock == 53.0             # now + one charged reload
    assert eng.adapters.is_loaded(1) and not eng.adapters.is_loaded(2)


def test_preload_refused_while_adapter_failing():
    eng = _mk_engine()
    eng.reset_stream()
    eng.adapters.failing = {3}
    assert not eng.preload_adapter(3)
    assert eng.n_load_faults == 1
    eng.adapters.failing = set()
    assert eng.preload_adapter(3)


# --------------------------------------------------------------------------- #
# cluster + twin: bitwise fault replay
# --------------------------------------------------------------------------- #

def _workload(horizon=50.0, seed=3, n_adapters=16):
    pool = make_adapter_pool(n_adapters, [8, 16], [0.3, 0.1])
    spec = WorkloadSpec(adapters=pool, dataset="medium", horizon=horizon,
                        seed=seed)
    return pool, spec, generate_requests(spec)


def _storm(pool):
    return FaultPlan(events=(
        ReplicaCrash(replica=1, at=15.0, recover_at=25.0),
        AdapterLoadFault(replica=0, adapter=pool[0].uid, at=10.0,
                         until=30.0),
        StragglerWindow(replica=2, at=20.0, until=30.0, factor=4.0),
        ClientDisconnect(at=12.0, request_index=40),
    ), seed=0)


def _cluster_run(spec, reqs, fast, plan, rel, n_replicas=3):
    twin = ClusterDigitalTwin(mk_est(), mode="full", fast=fast)
    router = ClusterRouter(
        twin.specs_from_slots([4] * n_replicas, mean_rank=12.0),
        policy="affinity")
    return twin.simulate_online(spec, router, requests=reqs, epoch=5.0,
                                rebalance=True, straggler_factor=3.0,
                                fault_plan=plan, reliability=rel)


def _assert_equal_result(a, b):
    for f in EXACT_FIELDS:
        assert getattr(a.metrics, f) == getattr(b.metrics, f), f
    assert a.online.faults.as_dict() == b.online.faults.as_dict()


def test_cluster_faulted_run_repeats_bitwise():
    pool, spec, reqs = _workload()
    plan, rel = _storm(pool), ReliabilityPolicy(timeout_s=10.0)
    a = _cluster_run(spec, reqs, True, plan, rel)
    b = _cluster_run(spec, reqs, True, plan, rel)
    _assert_equal_result(a, b)
    assert a.online.faults.n_crashes == 1
    assert a.online.faults.n_recoveries == 1
    assert a.online.faults.n_disconnects == 1


def test_cluster_faulted_legacy_fast_equivalence():
    """The acceptance bar: the twin (FastEngine replicas) replays the
    cluster's (ServingEngine replicas) faulted run bitwise — metrics
    AND every fault counter."""
    pool, spec, reqs = _workload()
    plan, rel = _storm(pool), ReliabilityPolicy(timeout_s=10.0)
    legacy = _cluster_run(spec, reqs, False, plan, rel)
    fast = _cluster_run(spec, reqs, True, plan, rel)
    _assert_equal_result(legacy, fast)
    assert legacy.online.faults.n_timeouts > 0     # the storm actually bit


def test_cluster_empty_plan_pins_healthy_path():
    """FaultPlan(events=()) + disabled reliability must be bitwise
    indistinguishable from not passing a plan at all, on both engines."""
    _, spec, reqs = _workload(horizon=40.0)
    off = ReliabilityPolicy(timeout_s=0.0)
    for fast in (False, True):
        base = _cluster_run(spec, reqs, fast, None, None)
        empty = _cluster_run(spec, reqs, fast,
                             FaultPlan(events=(), seed=0), off)
        _assert_equal_result(base, empty)
        assert empty.online.faults.as_dict() == \
            {k: 0 for k in empty.online.faults.as_dict()}


def test_cluster_crash_recovery_zero_lost():
    """Crash -> heartbeat-detected death -> restore at recover_at with a
    warm adapter cache: traffic is served afterwards and no admitted
    request is lost (terminal states partition the stream)."""
    pool, spec, reqs = _workload()
    plan = FaultPlan(events=(
        ReplicaCrash(replica=0, at=15.0, recover_at=25.0),), seed=0)
    rel = ReliabilityPolicy(timeout_s=8.0, max_retries=3)
    res = _cluster_run(spec, reqs, True, plan, rel)
    f = res.online.faults
    assert f.n_crashes == 1 and f.n_recoveries == 1
    served = [r for r in reqs]           # deep-copied inside the twin;
    n = len(served)                      # counters live in the metrics
    m = res.metrics
    assert m.n_finished + m.n_failed_requests \
        + f.n_disconnects == n
    assert m.n_finished > 0.9 * n        # recovery actually served work


def test_cluster_timeout_retry_beats_no_retry():
    """With a straggler + load-fault storm, the retry arm must finish
    strictly more requests than the same run with retries disabled."""
    pool, spec, reqs = _workload()
    plan = FaultPlan(events=(
        ReplicaCrash(replica=1, at=15.0),          # no recovery
        StragglerWindow(replica=2, at=10.0, until=40.0, factor=8.0),
    ), seed=0)
    with_retry = _cluster_run(spec, reqs, True, plan,
                              ReliabilityPolicy(timeout_s=6.0,
                                                max_retries=3))
    no_retry = _cluster_run(spec, reqs, True, plan,
                            ReliabilityPolicy(timeout_s=6.0,
                                              max_retries=0))
    assert with_retry.online.faults.n_retries > 0
    assert with_retry.metrics.n_finished > no_retry.metrics.n_finished
    # zero lost on both arms
    for res in (with_retry, no_retry):
        m = res.metrics
        assert m.n_finished + m.n_failed_requests == len(reqs)


# --------------------------------------------------------------------------- #
# gateway: storm replay, disconnects, 503s, shutdown [DONE]
# --------------------------------------------------------------------------- #

def _gw_arrivals(n=40):
    return [Request(uid=i, adapter=i % 3, arrival=i * 0.5,
                    prompt_len=32, output_len=8) for i in range(n)]


def _gw_plan():
    return FaultPlan(events=(
        ReplicaCrash(replica=0, at=5.0, recover_at=9.0),
        AdapterLoadFault(replica=0, adapter=1, at=11.0, until=14.0),
        StragglerWindow(replica=0, at=15.0, until=17.0, factor=4.0),
        ClientDisconnect(at=1.05, request_index=2),
    ), seed=0)


def test_gateway_fault_storm_deterministic_and_zero_lost():
    def run():
        gw = AsyncGateway(
            _mk_engine(), fault_plan=_gw_plan(),
            reliability=ReliabilityPolicy(timeout_s=6.0, max_retries=2,
                                          backoff_base=0.5))
        return asyncio.run(gw.run(iter(_gw_arrivals()), drain=True))

    a, b = run(), run()
    assert a.summary() == b.summary()
    g = a.gateway
    assert g.n_crashes == 1 and g.n_recoveries == 1
    assert g.n_client_disconnects == 1
    assert g.n_rejected > 0              # offers during the down window
    # zero lost: every submitted request has exactly one terminal outcome
    assert a.serving.n_finished + g.n_failed_requests \
        + g.n_client_disconnects + g.n_rejected == g.n_submitted


def test_gateway_empty_plan_pins_healthy_path():
    plain = asyncio.run(AsyncGateway(_mk_engine())
                        .run(iter(_gw_arrivals()), drain=True))
    empty = asyncio.run(AsyncGateway(
        _mk_engine(), fault_plan=FaultPlan(events=(), seed=0),
        reliability=ReliabilityPolicy(timeout_s=0.0))
        .run(iter(_gw_arrivals()), drain=True))
    assert plain.serving == empty.serving


def test_gateway_offer_503_while_crashed():
    gw = AsyncGateway(_mk_engine(),
                      fault_plan=FaultPlan(events=(
                          ReplicaCrash(replica=0, at=1.0, recover_at=8.0),
                      ), seed=0))
    gw.engine.reset_stream()
    gw.state = "serving"
    gw._advance(2.0)                     # past the crash
    assert gw.engine.halted
    res = gw.offer(Request(uid=900, adapter=0, arrival=2.0,
                           prompt_len=8, output_len=4))
    assert isinstance(res, Rejected) and res.status == 503
    assert res.reason == "no alive replicas"
    gw._advance(9.0)                     # past recovery
    assert not gw.engine.halted
    res = gw.offer(Request(uid=901, adapter=0, arrival=9.0,
                           prompt_len=8, output_len=4))
    assert isinstance(res, Request)


def test_gateway_disconnect_cancels_and_accounts():
    """Public ``disconnect``: the engine-side work is cancelled (KV
    freed, request never finishes), the stream closes, and the loss is
    counted — exactly once (idempotent)."""
    async def scenario():
        gw = AsyncGateway(_mk_engine(), tick=0.001, time_scale=0.001)
        await gw.start()
        stream = await gw.submit(adapter=0, prompt_len=16, output_len=500,
                                 stream=True)
        req = stream.request
        assert gw.disconnect(req) is True
        assert gw.disconnect(req) is False
        chunks = [c async for c in stream]       # _END already queued
        rep = await gw.shutdown()
        return req, chunks, rep

    req, chunks, rep = asyncio.run(scenario())
    assert req.disconnected_at is not None and req.finished_at is None
    assert chunks == []
    assert rep.gateway.n_client_disconnects == 1
    assert rep.serving.n_finished == 0


def test_http_client_disconnect_mid_sse_cancels_engine_side():
    """A socket error while writing SSE chunks must cancel the request
    in the engine and count it — not silently leak the stream."""
    class FlakyWriter:
        def __init__(self):
            self.n_drains = 0

        def write(self, data):
            pass

        async def drain(self):
            self.n_drains += 1
            if self.n_drains >= 2:       # headers ok, first chunk dies
                raise ConnectionResetError

    async def scenario():
        gw = AsyncGateway(_mk_engine(), tick=0.001, time_scale=200.0)
        await gw.start()
        server = GatewayHTTPServer(gw)   # no socket needed for _completions
        with pytest.raises(ConnectionResetError):
            await server._completions(FlakyWriter(), {
                "adapter": 0, "prompt_tokens": 8, "max_tokens": 50,
                "stream": True})
        rep = await gw.shutdown()
        return rep

    rep = asyncio.run(scenario())
    assert rep.gateway.n_client_disconnects == 1
    assert rep.serving.n_finished == 0


def test_gateway_shutdown_always_emits_done_for_inflight_sse():
    """Live-mode shutdown with an SSE stream still in flight: the stream
    is closed with ``[DONE]`` rather than left hanging."""
    async def scenario():
        # time_scale ~0: the 200-token request can never finish
        gw = AsyncGateway(_mk_engine(), tick=0.005, time_scale=0.001)
        await gw.start()
        server = await GatewayHTTPServer(gw, port=0).start()
        reader, writer = await asyncio.open_connection("127.0.0.1",
                                                       server.port)
        body = json.dumps({"adapter": 0, "prompt_tokens": 8,
                           "max_tokens": 200, "stream": True}).encode()
        writer.write(b"POST /v1/completions HTTP/1.1\r\nHost: t\r\n"
                     + f"Content-Length: {len(body)}\r\n\r\n".encode()
                     + body)
        await writer.drain()
        while gw.metrics.n_streams == 0:
            await asyncio.sleep(0.005)
        await gw.shutdown(drain=False)
        data = await asyncio.wait_for(reader.read(), 30.0)
        writer.close()
        await server.stop()
        return data.decode()

    resp = asyncio.run(scenario())
    assert resp.startswith("HTTP/1.1 200")
    assert "data: [DONE]" in resp


# --------------------------------------------------------------------------- #
# property-style determinism (skipped when hypothesis is unavailable)
# --------------------------------------------------------------------------- #

def test_fault_plan_replay_property():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    pool, spec, reqs = _workload(horizon=20.0, n_adapters=6)

    @settings(max_examples=5, deadline=None)
    @given(seed=st.integers(0, 1000), n_crashes=st.integers(0, 2),
           n_faults=st.integers(0, 2), timeout=st.sampled_from([0.0, 6.0]))
    def prop(seed, n_crashes, n_faults, timeout):
        plan_kw = dict(n_replicas=2, horizon=20.0, seed=seed,
                       adapters=[a.uid for a in pool],
                       n_crashes=n_crashes, n_adapter_faults=n_faults,
                       n_stragglers=1, n_disconnects=1,
                       n_requests=len(reqs))
        assert generate_fault_plan(**plan_kw).events == \
            generate_fault_plan(**plan_kw).events
        plan = generate_fault_plan(**plan_kw)
        rel = ReliabilityPolicy(timeout_s=timeout)
        legacy = _cluster_run(spec, reqs, False, plan, rel, n_replicas=2)
        fast = _cluster_run(spec, reqs, True, plan, rel, n_replicas=2)
        _assert_equal_result(legacy, fast)

    prop()

"""Cross-adapter shared-prefix KV cache: unit behaviour, block-pool
invariants, engine<->twin bitwise equivalence with the cache on, the
``prefix_share=0`` opt-out pin, prefix-affinity routing, the analytic
hit-rate model, and the placement models' prefix-hit-rate feature.

Also hosts the two regression satellites that ride this PR:

* uid-aware ``PagedKVCache.can_allocate`` — the fragmentation case
  where a requester's slack in its partially-filled last block made the
  uid-blind check refuse an allocation ``allocate`` would accept;
* twin replay of chaos-scarred traces — ``DigitalTwin`` full mode must
  reset the reliability lifecycle (retries/timeouts/failure stamps) on
  its deep copies, never inherit it from the caller's stream.
"""
import json

import numpy as np
import pytest

from repro.core import (DigitalTwin, FastTwin, Scenario, WorkloadSpec,
                        assign_shared_prefixes, expected_prefix_hit_rate,
                        generate_requests, label_scenarios,
                        make_adapter_pool)
from repro.core.dataset import FEATURE_NAMES
from repro.core.estimators import FittedEstimators
from repro.core.forest import RandomForest
from repro.core.placement import train_cluster_placement_model
from repro.core.workload import load_trace, replay_trace, save_trace
from repro.serving import (ClusterRouter, PagedKVCache, SharedPrefixCache,
                           make_replica_specs)
from repro.serving.metrics import TWIN_EXACT_FIELDS as EXACT_FIELDS
from repro.serving.request import Adapter, Request


def mk_est(kv_base: float = 120000.0, kv_slope: float = -60.0
           ) -> FittedEstimators:
    return FittedEstimators(
        sched=np.array([4e-4, 8e-6, 4e-6, 2.5e-5]),
        model=np.array([2.4e-2, 2.2e-4, 6.5e-6]),
        adapters=np.array([1.06, 0.004]),
        load=np.array([8e-3, 1.1e-3]),
        load_disk_mult=1.7,
        memmax=np.array([kv_base, kv_slope]))


def _cache(capacity_tokens=1024, block_size=16):
    pool = PagedKVCache(capacity_tokens, block_size=block_size)
    return SharedPrefixCache(pool), pool


# --------------------------------------------------------------------- #
# cache unit behaviour
# --------------------------------------------------------------------- #

def test_plan_miss_then_hit_and_clamp():
    pc, _ = _cache()
    # cold cache: a miss planning to insert the (clamped) prefix
    assert pc.plan(7, 64, 200) == (0, 64)
    # prefix longer than the prompt clamps to the prompt
    assert pc.plan(7, 500, 120) == (0, 120)
    # degenerate prefixes never touch the cache
    assert pc.plan(7, 0, 200) == (0, 0)
    assert pc.plan(7, 32, 0) == (0, 0)
    pc.commit(holder=1, prefix_id=7, covered=0, insert_tokens=64)
    # warm: covered = min(entry tokens, requested prefix, prompt)
    assert pc.plan(7, 64, 200) == (64, 0)
    assert pc.plan(7, 64, 40) == (40, 0)
    # plan is pure — still exactly one insert recorded
    assert pc.n_inserts == 1


def test_refcount_lifecycle_and_block_invariant():
    pc, pool = _cache(capacity_tokens=1024)
    total = pool.total_blocks

    def invariant():
        held = sum(pool.table.values())
        assert pool.free_blocks + held + pc.cached_blocks == total

    # miss: inserter computes and holds one reference
    cov, ins = pc.plan(3, 48, 100)
    pc.commit(holder=10, prefix_id=3, covered=cov, insert_tokens=ins)
    assert pool.allocate(10, 100 + 1 - ins)
    entry = pc.entries[("base", 3)]
    assert (entry.refs, entry.tokens) == (1, 48)
    invariant()

    # hit from a *different adapter's* request: shared reference
    cov, ins = pc.plan(3, 48, 90)
    assert (cov, ins) == (48, 0)
    pc.commit(holder=11, prefix_id=3, covered=cov, insert_tokens=ins)
    assert pool.allocate(11, 90 + 1 - cov)
    assert entry.refs == 2
    assert pc.n_hits == 1 and pc.tokens_saved == 48
    invariant()

    # releases drop refs but keep the entry warm (evictable at 0)
    pc.release(10)
    pool.free(10)
    pc.release(11)
    pool.free(11)
    assert entry.refs == 0
    assert ("base", 3) in pc.entries
    invariant()
    # double release of an unknown holder is a no-op
    pc.release(99)
    assert entry.refs == 0


def test_eviction_lru_zero_ref_only_and_exclude():
    pc, pool = _cache()
    for pid, holder in ((1, 100), (2, 101), (3, 102)):
        cov, ins = pc.plan(pid, 32, 64)
        pc.commit(holder=holder, prefix_id=pid, covered=cov,
                  insert_tokens=ins)
    pc.release(101)           # pid 2 idle (oldest zero-ref)
    pc.release(102)           # pid 3 idle
    # live-ref entry (pid 1) is never evicted; LRU picks pid 2 first
    assert pc.evict_idle_lru()
    assert ("base", 2) not in pc.entries and ("base", 1) in pc.entries
    # exclude protects the prefix an in-flight admission wants
    assert not pc.evict_idle_lru(exclude=3) or ("base", 3) in pc.entries
    pc.release(100)
    # with everything idle, exclude=1 still lets pid 3 go
    before = pc.n_evictions
    assert pc.evict_idle_lru(exclude=1)
    assert ("base", 1) in pc.entries
    assert pc.n_evictions == before + 1


def test_hit_after_evict_is_a_miss():
    pc, pool = _cache()
    cov, ins = pc.plan(5, 40, 80)
    pc.commit(holder=1, prefix_id=5, covered=cov, insert_tokens=ins)
    pc.release(1)
    free_before = pool.free_blocks
    assert pc.evict_idle_lru()
    assert pool.free_blocks == free_before + pool.blocks_needed(40)
    # the prefix is cold again: next plan is a fresh miss-with-insert
    assert pc.plan(5, 40, 80) == (0, 40)


def test_zero_capacity_pool():
    pc, pool = _cache(capacity_tokens=0)
    assert pool.total_blocks == 0
    cov, ins = pc.plan(1, 16, 32)
    assert (cov, ins) == (0, 16)
    # the admission gate must see the insert cannot fit...
    assert pc.fit_blocks(cov, ins, 32) > pool.free_blocks
    # ...and nothing is idle to evict
    assert not pc.evict_idle_lru()
    # a downgraded (uncached) miss is still counted, allocates nothing
    pc.commit(holder=1, prefix_id=1, covered=0, insert_tokens=0)
    assert (pc.n_misses, pc.n_inserts, pc.cached_blocks) == (1, 0, 0)
    # committing the insert anyway is a caller bug and says so
    with pytest.raises(RuntimeError):
        pc.commit(holder=2, prefix_id=1, covered=0, insert_tokens=16)


def test_wipe_keeps_counters_reset_clears_them():
    pc, pool = _cache()
    cov, ins = pc.plan(1, 32, 64)
    pc.commit(holder=1, prefix_id=1, covered=cov, insert_tokens=ins)
    pc.commit(holder=2, prefix_id=1, covered=32, insert_tokens=0)
    free_total = pool.total_blocks
    pc.wipe()                 # crash recovery: entries gone, metrics stay
    assert not pc.entries and not pc.holders
    assert pool.free_blocks == free_total
    assert (pc.n_hits, pc.n_misses, pc.n_inserts) == (1, 1, 1)
    assert pc.hit_rate == pytest.approx(0.5)
    pc.reset()                # fresh stream: metrics go too
    assert (pc.n_hits, pc.n_misses, pc.tokens_saved) == (0, 0, 0)
    assert pc.hit_rate == 0.0


# --------------------------------------------------------------------- #
# satellite: uid-aware can_allocate (fragmentation regression)
# --------------------------------------------------------------------- #

def test_can_allocate_uid_credits_partial_last_block():
    kv = PagedKVCache(32, block_size=16)          # exactly 2 blocks
    assert kv.allocate(1, 17)                     # 2 blocks, 15 slack
    assert kv.free_blocks == 0
    # uid-blind: prices 15 tokens from an empty table -> 1 block -> no
    assert not kv.can_allocate(15)
    # uid-aware: the requester's last block has the slack -> 0 blocks
    assert kv.can_allocate(15, uid=1)
    assert kv.allocate(1, 15)                     # and allocate agrees
    assert kv.tokens[1] == 32 and kv.free_blocks == 0
    # one token past the boundary needs a real block again
    assert not kv.can_allocate(1, uid=1)
    # unknown uid degrades to the uid-blind price
    assert not kv.can_allocate(1, uid=999)


# --------------------------------------------------------------------- #
# engine <-> twin bitwise with the cache on; share=0 opt-out pin
# --------------------------------------------------------------------- #

def _prefix_spec(share, pool, horizon=40.0, seed=13, prefix_len=160):
    return WorkloadSpec(adapters=pool, dataset="medium", horizon=horizon,
                        seed=seed, prefix_share=share,
                        prefix_len=prefix_len)


def test_equivalence_cache_on_prefix_workload():
    est = mk_est(kv_base=4000.0, kv_slope=-30.0)   # pressured pool
    pool = make_adapter_pool(8, [8, 16], [0.5, 0.25])
    spec = _prefix_spec(0.8, pool)
    reqs = generate_requests(spec)
    legacy = DigitalTwin(est, mode="full", prefix_cache=True) \
        .simulate(spec, slots=3, requests=reqs).metrics
    fast = FastTwin(est, mode="full", prefix_cache=True) \
        .simulate(spec, slots=3, requests=reqs).metrics
    assert legacy.n_prefix_hits > 0
    assert legacy.prefix_tokens_saved > 0
    for f in EXACT_FIELDS:
        assert getattr(legacy, f) == getattr(fast, f), \
            f"{f}: {getattr(legacy, f)} != {getattr(fast, f)}"
    assert fast.itl == pytest.approx(legacy.itl, rel=1e-9, abs=1e-12)


def test_share_zero_is_bitwise_free():
    est = mk_est(kv_base=6000.0, kv_slope=-30.0)
    pool = make_adapter_pool(6, [8, 16], [0.4, 0.2])
    plain = WorkloadSpec(adapters=pool, dataset="medium", horizon=30.0,
                         seed=4)
    tagged = _prefix_spec(0.0, pool, horizon=30.0, seed=4)
    # the carrier RNG is a separate stream: share=0 leaves the requests
    # bitwise identical to a prefix-free spec
    for a, b in zip(generate_requests(plain), generate_requests(tagged)):
        assert (a.uid, a.adapter, a.arrival, a.prompt_len, a.output_len,
                a.prefix_id, a.prefix_len) == \
               (b.uid, b.adapter, b.arrival, b.prompt_len, b.output_len,
                b.prefix_id, b.prefix_len)
    on = DigitalTwin(est, mode="mean", prefix_cache=True) \
        .simulate(tagged, slots=3).metrics
    off = DigitalTwin(est, mode="mean", prefix_cache=False) \
        .simulate(plain, slots=3).metrics
    for f in EXACT_FIELDS:
        assert getattr(on, f) == getattr(off, f), f
    assert on.n_prefix_hits == 0 and on.n_prefix_misses == 0


def test_assign_shared_prefixes_marks_carriers():
    pool = make_adapter_pool(4, [8], [0.5])
    spec = WorkloadSpec(adapters=pool, dataset="medium", horizon=30.0,
                        seed=2)
    base = generate_requests(spec)
    reqs = assign_shared_prefixes(
        [Request(uid=r.uid, adapter=r.adapter, arrival=r.arrival,
                 prompt_len=r.prompt_len, output_len=r.output_len)
         for r in base], share=0.6, prefix_len=100, seed=2)
    carriers = [r for r in reqs if r.prefix_id is not None]
    assert 0 < len(carriers) < len(reqs)
    for r, b in zip(reqs, base):
        if r.prefix_id is not None:
            # one shared prompt per tenant, prompt grew by the prefix
            assert r.prefix_id == r.adapter and r.prefix_len == 100
            assert r.prompt_len == b.prompt_len + 100
        else:
            assert r.prompt_len == b.prompt_len and r.prefix_len == 0


# --------------------------------------------------------------------- #
# analytic hit-rate model
# --------------------------------------------------------------------- #

def test_expected_prefix_hit_rate_math():
    pool = [Adapter(uid=0, rank=8, rate=0.5),
            Adapter(uid=1, rank=8, rate=0.1),
            Adapter(uid=2, rank=8, rate=0.0)]   # inactive: ignored
    spec = WorkloadSpec(adapters=pool, dataset="medium", horizon=20.0,
                        seed=0, prefix_share=0.5, prefix_len=100)
    # per tenant: max(rate*horizon*share - 1, 0) expected hits
    hits = max(0.5 * 20 * 0.5 - 1, 0) + max(0.1 * 20 * 0.5 - 1, 0)
    total = 0.5 * 20 + 0.1 * 20
    assert expected_prefix_hit_rate(spec) == pytest.approx(hits / total)
    # degenerate prefixes model out to zero
    for share, plen in ((0.0, 100), (0.5, 0)):
        s = WorkloadSpec(adapters=pool, dataset="medium", horizon=20.0,
                         seed=0, prefix_share=share, prefix_len=plen)
        assert expected_prefix_hit_rate(s) == 0.0


# --------------------------------------------------------------------- #
# prefix-affinity routing
# --------------------------------------------------------------------- #

def _carrier(uid, adapter, prefix_id, arrival=0.0):
    return Request(uid=uid, adapter=adapter, arrival=arrival,
                   prompt_len=200, output_len=50, prefix_id=prefix_id,
                   prefix_len=120)


def test_prefix_affinity_routes_carriers_home():
    router = ClusterRouter(make_replica_specs(3, 4, 100_000),
                           policy="prefix-affinity")
    first = router.route(_carrier(0, adapter=1, prefix_id=1))
    assert router.n_prefix_cold_routes == 1
    assert router.prefix_homes(1) == [first]
    # a different tenant's carrier lands elsewhere (least-loaded)
    other = router.route(_carrier(1, adapter=2, prefix_id=2))
    assert other != first
    # the next carriers of tenant 1 stick to the warm replica even as
    # other traffic shifts the loads around
    for i in range(3):
        router.route(Request(uid=10 + i, adapter=5 + i, arrival=0.0,
                             prompt_len=150, output_len=50))
    assert router.route(_carrier(20, adapter=1, prefix_id=1)) == first
    assert router.n_prefix_cold_routes == 2   # only the two first touches


def test_prefix_affinity_falls_back_and_forgets_dead():
    router = ClusterRouter(make_replica_specs(2, 4, 100_000),
                           policy="prefix-affinity")
    home = router.route(_carrier(0, adapter=3, prefix_id=3))
    # prefix-free requests use plain adapter affinity
    plain = Request(uid=1, adapter=3, arrival=0.0, prompt_len=100,
                    output_len=50)
    assert router.route(plain) == home
    # a dead replica's prefix cache dies with it: belief cleared, the
    # next carrier is a (counted) cold route on a survivor
    cold_before = router.n_prefix_cold_routes
    router.mark_dead(home)
    assert router.prefix_homes(3) == []
    rep = router.route(_carrier(2, adapter=3, prefix_id=3))
    assert rep != home
    assert router.n_prefix_cold_routes == cold_before + 1


def test_router_summary_reports_prefix_cold_routes():
    router = ClusterRouter(make_replica_specs(2, 4, 100_000),
                           policy="prefix-affinity")
    router.route(_carrier(0, adapter=0, prefix_id=0))
    assert router.summary()["n_prefix_cold_routes"] == 1


# --------------------------------------------------------------------- #
# satellite: chaos-scarred trace replay resets reliability lifecycle
# --------------------------------------------------------------------- #

def test_twin_replay_resets_reliability_fields():
    est = mk_est()
    pool = make_adapter_pool(6, [8, 16], [0.3])
    spec = WorkloadSpec(adapters=pool, dataset="medium", horizon=25.0,
                        seed=6)
    clean = generate_requests(spec)
    scarred = generate_requests(spec)
    for r in scarred:          # a chaos run's leftovers
        r.n_retries, r.n_timeouts = 2, 1
        r.failed_at, r.retry_at, r.disconnected_at = 1.0, 2.0, 3.0
    m_clean = DigitalTwin(est, mode="full").simulate(
        spec, slots=3, requests=clean).metrics
    m_scar = DigitalTwin(est, mode="full").simulate(
        spec, slots=3, requests=scarred).metrics
    # the replay starts every lifecycle clean: bitwise-identical metrics
    # (n_retries/n_timeouts are already in the canonical exact set)
    for f in EXACT_FIELDS:
        assert getattr(m_clean, f) == getattr(m_scar, f), f
    assert m_scar.n_retries == 0 and m_scar.n_timeouts == 0
    # and the caller's scarred stream is untouched (deep copies)
    assert all(r.n_retries == 2 and r.failed_at == 1.0 for r in scarred)


# --------------------------------------------------------------------- #
# trace persistence / replay carries prefix identity
# --------------------------------------------------------------------- #

def test_trace_roundtrip_preserves_prefix_fields(tmp_path):
    pool = make_adapter_pool(4, [8], [0.4])
    spec = WorkloadSpec(adapters=pool, dataset="medium", horizon=20.0,
                        seed=3, prefix_share=0.7, prefix_len=80)
    reqs = generate_requests(spec)
    assert any(r.prefix_id is not None for r in reqs)
    path = tmp_path / "trace.json"
    save_trace(path, reqs)
    loaded = load_trace(path)
    replayed = list(replay_trace(reqs))
    for a, b, c in zip(sorted(reqs, key=lambda r: (r.arrival, r.uid)),
                       sorted(loaded, key=lambda r: (r.arrival, r.uid)),
                       replayed):
        for other in (b, c):
            assert (a.uid, a.prefix_id, a.prefix_len, a.prompt_len) == \
                   (other.uid, other.prefix_id, other.prefix_len,
                    other.prompt_len)
        assert c.generated == 0 and c.finished_at is None


def test_load_trace_accepts_pre_prefix_format(tmp_path):
    rows = [{"uid": 0, "adapter": 1, "arrival": 0.5,
             "prompt_len": 100, "output_len": 20}]
    path = tmp_path / "old.json"
    path.write_text(json.dumps(rows))
    (req,) = load_trace(path)
    assert req.prefix_id is None and req.prefix_len == 0


# --------------------------------------------------------------------- #
# satellite: adapter bank dtype sizing
# --------------------------------------------------------------------- #

def test_adapter_bytes_dtype():
    a = Adapter(uid=0, rank=16)
    bf16 = a.bytes(d_model=4096, n_layers=32)
    assert bf16 == 2 * 2 * 16 * 4096 * 2 * 32
    assert a.bytes(d_model=4096, n_layers=32, dtype_bytes=1) == bf16 // 2


# --------------------------------------------------------------------- #
# placement models learn from the prefix-hit-rate feature
# --------------------------------------------------------------------- #

def _prefix_scenarios():
    shares = (0.0, 0.05, 0.1, 0.15, 0.2, 0.7, 0.75, 0.8, 0.85, 0.9)
    return [Scenario(rates=(0.08, 0.04, 0.02), ranks=(8, 16),
                     dataset="medium", prefix_share=s, prefix_len=200)
            for s in shares]


def test_placement_model_ranks_prefix_hit_rate():
    est = mk_est(kv_base=5000.0, kv_slope=-30.0)
    xs, ys, _ = label_scenarios(est, _prefix_scenarios(), max_adapters=6,
                                horizon=25.0, seed=2)
    assert xs.shape[1] == len(FEATURE_NAMES)
    rf = RandomForest(n_trees=5, max_depth=3, seed=0).fit(xs, ys)
    imp = dict(zip(FEATURE_NAMES, rf.feature_importances().tolist()))
    assert imp["prefix_hit_rate"] > 0.0


def test_cluster_model_ranks_prefix_hit_rate():
    est = mk_est(kv_base=5000.0, kv_slope=-30.0)
    sc = _prefix_scenarios()
    cm = train_cluster_placement_model(
        est, sc[:4] + sc[-4:], max_adapters=6, replica_counts=(1, 2),
        horizon=12.0, seed=2, holdout=0.0)
    assert cm.importances()["prefix_hit_rate"] > 0.0

"""Docs integrity: links + benchmark-table coverage (fast, tier-1) and
fenced-example execution (slow; the CI docs job also runs it directly)."""
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "tools"))

import check_docs  # noqa: E402


def test_internal_links_resolve():
    assert check_docs.check_links() == []


def test_benchmark_table_covers_all_benches():
    assert check_docs.check_benchmark_table() == []


def test_docs_name_every_new_subsystem():
    """The cluster guide documents what the code registers: every
    routing policy and every serve_cluster flag."""
    from repro.serving.cluster import POLICIES
    text = (check_docs.ROOT / "docs" / "cluster.md").read_text()
    for name in ("affinity", "least-loaded", "round-robin"):
        assert name in POLICIES and f"`{name}`" in text
    for flag in ("--online", "--rebalance", "--epoch", "--kill",
                 "--drift", "--straggler-factor"):
        assert flag in text, f"serve_cluster flag {flag} undocumented"


@pytest.mark.slow
def test_fenced_python_examples_execute():
    assert check_docs.check_examples() == []

"""Multi-device equivalence cases, run in a subprocess with 8 host devices.

Usage: python tests/sharded_cases.py <case>   (exit 0 = pass)
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import get_reduced  # noqa: E402
from repro.launch.mesh import make_test_mesh  # noqa: E402
from repro.models import Model, ShardingPlan, make_plan  # noqa: E402
from repro.models.transformer import pad_cache  # noqa: E402

KEY = jax.random.PRNGKey(2)


def use_mesh(mesh):
    """jax.set_mesh on new jax; the Mesh context manager on old jax."""
    return jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh


def put(tree, specs, mesh):
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), tree, specs,
        is_leaf=lambda x: not isinstance(x, (dict, list, tuple)))


def repl(mesh, tree):
    return jax.tree.map(
        lambda x: jax.device_put(
            x, NamedSharding(mesh, P(*([None] * x.ndim)))), tree)


def case_train(arch):
    mesh = make_test_mesh(2, 4)
    cfg = dataclasses.replace(get_reduced(arch), dtype="float32")
    ref_model = Model(cfg, ShardingPlan(mode="train"))
    params = ref_model.init(KEY)
    batch = {"tokens": jax.random.randint(KEY, (4, 33), 0, cfg.vocab_size)}
    loss_ref = jax.jit(ref_model.train_loss)(params, batch)
    plan = make_plan(cfg, mesh, "train", global_batch=4)
    model = Model(cfg, plan)
    params_sh = put(params, plan.param_specs(params), mesh)
    batch_sh = {"tokens": jax.device_put(
        batch["tokens"], NamedSharding(mesh, P("data", None)))}
    with use_mesh(mesh):
        loss_sh = jax.jit(model.train_loss)(params_sh, batch_sh)
    # MoE aux-balance loss is estimated per data shard under EP (different
    # token subsets), so allow a slightly looser budget for MoE families.
    tol = 5e-3 if cfg.n_experts else 5e-4
    assert abs(float(loss_ref) - float(loss_sh)) < tol, \
        (float(loss_ref), float(loss_sh))


def case_grad(arch):
    """Sharded gradients match single-device gradients."""
    mesh = make_test_mesh(2, 4)
    cfg = dataclasses.replace(get_reduced(arch), dtype="float32")
    ref_model = Model(cfg, ShardingPlan(mode="train"))
    params = ref_model.init(KEY)
    batch = {"tokens": jax.random.randint(KEY, (4, 33), 0, cfg.vocab_size)}
    g_ref = jax.jit(jax.grad(ref_model.train_loss))(params, batch)
    plan = make_plan(cfg, mesh, "train", global_batch=4)
    model = Model(cfg, plan)
    params_sh = put(params, plan.param_specs(params), mesh)
    batch_sh = {"tokens": jax.device_put(
        batch["tokens"], NamedSharding(mesh, P("data", None)))}
    with use_mesh(mesh):
        g_sh = jax.jit(jax.grad(model.train_loss))(params_sh, batch_sh)
    errs = jax.tree.map(
        lambda a, b: float(np.max(np.abs(np.asarray(a) - np.asarray(b)))
                           / (np.max(np.abs(np.asarray(a))) + 1e-6)),
        g_ref, g_sh)
    worst = max(jax.tree.leaves(errs))
    assert worst < 5e-3, worst


def case_decode(arch, batch=4):
    mesh = make_test_mesh(2, 4)
    cfg = dataclasses.replace(get_reduced(arch), dtype="float32")
    m_pre = Model(cfg, ShardingPlan(mode="prefill"))
    m_dec = Model(cfg, ShardingPlan(mode="decode"))
    params = m_pre.init(KEY)
    lora = m_pre.init_lora(KEY, 4, 4)
    b, s = batch, 32
    tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    idx = jnp.arange(b, dtype=jnp.int32) % 4
    _, cache = jax.jit(m_pre.prefill)(params, lora, tokens[:, :-1], idx)
    cache = pad_cache(cache, 1)
    logits_ref, _ = jax.jit(m_dec.decode_step)(params, lora, cache,
                                               tokens[:, -1:], idx)
    plan = make_plan(cfg, mesh, "decode", global_batch=b)
    model = Model(cfg, plan)
    params_sh = put(params, plan.param_specs(params), mesh)
    cache_sh = put(cache, plan.cache_specs(cache), mesh)
    dp = plan.batch_axes if plan.batch_axes else None
    tok_sh = jax.device_put(tokens[:, -1:],
                            NamedSharding(mesh, P(dp, None)))
    idx_sh = jax.device_put(idx, NamedSharding(mesh, P(dp)))
    with use_mesh(mesh):
        logits_sh, _ = jax.jit(model.decode_step)(
            params_sh, repl(mesh, lora), cache_sh, tok_sh, idx_sh)
    err = float(jnp.max(jnp.abs(logits_ref - logits_sh)))
    rel = err / (float(jnp.max(jnp.abs(logits_ref))) + 1e-9)
    assert rel < 1e-4, rel


def case_compression():
    """int8 ring all-reduce over 8 shards approximates exact psum."""
    mesh = make_test_mesh(8, 1)
    from repro.training.compression import quantized_psum
    x = jax.random.normal(KEY, (8, 128), jnp.float32)

    def body(xl):
        return quantized_psum(xl[0], "data", 8)

    from repro.models.transformer import shard_map
    f = shard_map(body, mesh=mesh, in_specs=P("data", None),
                  out_specs=P(None), check_vma=False)
    got = np.asarray(f(x))
    want = np.asarray(x.sum(0))
    scale = np.abs(x).max() / 127.0
    assert np.max(np.abs(got - want)) < 8 * scale, \
        (np.max(np.abs(got - want)), scale)


CASES = {
    "train_dense": lambda: case_train("gemma3_1b"),
    "train_moe": lambda: case_train("olmoe_1b_7b"),
    "train_ssm": lambda: case_train("mamba2_2p7b"),
    "train_hybrid": lambda: case_train("recurrentgemma_9b"),
    "grad_dense": lambda: case_grad("phi4_mini_3p8b"),
    "decode_dense": lambda: case_decode("phi4_mini_3p8b"),
    "decode_gqa1": lambda: case_decode("gemma3_1b"),
    "decode_moe": lambda: case_decode("olmoe_1b_7b"),
    "decode_b1": lambda: case_decode("mamba2_2p7b", batch=1),
    "compression": case_compression,
}

if __name__ == "__main__":
    CASES[sys.argv[1]]()
    print(f"{sys.argv[1]} OK")

"""Online rebalancing + fault-tolerant cluster routing.

Covers the living-system acceptance criteria: rebalancing >= static
affinity under drifting popularity, a killed replica's requests all
complete on survivors, and the rebalancer's edge cases (single replica
no-op, net-negative migration declined, determinism under fixed seed).
"""
import sys

import numpy as np
import pytest

sys.path.insert(0, ".")  # for `benchmarks.*` when run from the repo root

from repro.core import (ClusterDigitalTwin, Scenario, WorkloadSpec,
                        collect_benchmark, collect_memmax, fit_estimators,
                        find_cluster_placement_joint,
                        generate_drifting_requests, generate_requests,
                        make_adapter_pool, rotating_hot_phases,
                        train_cluster_placement_model)
from repro.serving import (ClusterRouter, FailureEvent, HardwareProfile,
                           Migration, RebalancePolicy, ServingCluster,
                           SyntheticExecutor, make_replica_specs)

from benchmarks.fig_rebalancing import drift_config, run_mode


@pytest.fixture(scope="module")
def est():
    profile = HardwareProfile()
    n, slots = 24, 12
    ranks = {i: (8, 16, 32)[i % 3] for i in range(n)}
    ex = SyntheticExecutor(profile, ranks, slots=slots, n_adapters=n, seed=0)
    return fit_estimators(collect_benchmark(ex, slots, n, ranks),
                          collect_memmax(profile), slots, n)


def _drift_inputs(est, seed=3, horizon=60.0, n_replicas=2):
    pool = make_adapter_pool(16, [8, 16], [0.02])
    mean_rank = float(np.mean([a.rank for a in pool]))
    phases = rotating_hot_phases(pool, horizon, n_phases=2,
                                 hot_fraction=0.375, hot_rate=1.2,
                                 cold_rate=0.02)
    reqs = generate_drifting_requests(pool, "medium", horizon, phases,
                                      seed=seed)
    twin = ClusterDigitalTwin(est, mode="full")
    specs = twin.specs_from_slots([4] * n_replicas, mean_rank=mean_rank)
    spec = WorkloadSpec(adapters=pool, dataset="medium", horizon=horizon,
                        seed=seed)
    return twin, spec, specs, reqs


# --------------------------------------------------------------------- #
# acceptance: the benchmark's claims, asserted
# --------------------------------------------------------------------- #

def test_rebalancing_beats_static_under_drift(est):
    """fig_rebalancing acceptance: aggregate throughput of rebalancing
    >= static affinity routing on the drifting-popularity workload."""
    cfg = drift_config(smoke=True)
    static = run_mode(est, "static", cfg)
    reb = run_mode(est, "rebalance", cfg)
    assert reb.metrics.throughput >= static.metrics.throughput - 1e-9
    # both served every request to completion (drain mode)
    assert reb.metrics.n_finished == static.metrics.n_finished


def test_killed_replica_requests_complete_on_survivors(est):
    """fig_rebalancing acceptance: killing one replica mid-run starves
    nothing — every routed request finishes on the survivors."""
    cfg = drift_config(smoke=True)
    kill = FailureEvent(replica=0, at=0.4 * cfg["horizon"])
    res = run_mode(est, "rebalance", cfg, failures=[kill])
    rep = res.online
    n_unique = sum(rep.router_summary["assigned_requests"]) - rep.n_rerouted
    assert res.metrics.n_finished == n_unique
    assert rep.n_rerouted > 0
    assert 0 in rep.failures_detected
    assert rep.router_summary["alive"] == [False, True]


# --------------------------------------------------------------------- #
# rebalancer edge cases
# --------------------------------------------------------------------- #

def test_single_replica_rebalance_is_noop(est):
    """One replica: the policy proposes nothing, the run completes."""
    twin, spec, _, reqs = _drift_inputs(est, n_replicas=1)
    mean_rank = float(np.mean([a.rank for a in spec.adapters]))
    router = ClusterRouter(twin.specs_from_slots([8], mean_rank=mean_rank),
                           policy="affinity")
    res = twin.simulate_online(spec, router, requests=reqs, epoch=5.0,
                               rebalance=True)
    assert len(res.online.migrations) == 0
    assert res.metrics.n_finished == len(reqs)


def test_net_negative_migration_declined(est):
    """A migration whose Fig. 4 cost exceeds any possible benefit must be
    declined: same drifted workload, absurd load cost -> zero moves."""
    twin, spec, specs, reqs = _drift_inputs(est)
    router = ClusterRouter(specs, policy="affinity")
    costly = RebalancePolicy(router, load_cost_fn=lambda uid: 1e9)
    res = twin.simulate_online(spec, router, requests=reqs, epoch=5.0,
                               rebalance=False, rebalancer=costly)
    assert len(res.online.migrations) == 0
    # the imbalance was seen and candidates were vetoed on cost
    assert costly.report.n_declined_cost > 0

    # sanity: the identical scenario with a sane cost does migrate
    router2 = ClusterRouter(specs, policy="affinity")
    sane = RebalancePolicy(
        router2, load_cost_fn=lambda uid: est.lat_load(8))
    res2 = twin.simulate_online(spec, router2, requests=reqs, epoch=5.0,
                                rebalance=False, rebalancer=sane)
    assert len(res2.online.migrations) > 0


def test_rebalancing_deterministic_under_fixed_seed(est):
    """Same seed, same config -> identical migrations and metrics."""
    cfg = drift_config(smoke=True)
    a = run_mode(est, "rebalance", cfg)
    b = run_mode(est, "rebalance", cfg)
    assert a.metrics.throughput == b.metrics.throughput
    assert a.metrics.n_finished == b.metrics.n_finished
    assert [tuple(dataclass_tuple(m)) for m in a.online.migrations] == \
           [tuple(dataclass_tuple(m)) for m in b.online.migrations]


def dataclass_tuple(m: Migration):
    return (m.adapter, m.src, m.dst, m.cost_s)


def test_balanced_workload_proposes_no_migrations(est):
    """No drift, no backlog -> the backlog gate keeps the rebalancer
    quiet (migration cost is pure waste when every queue drains)."""
    pool = make_adapter_pool(12, [8], [0.1])
    spec = WorkloadSpec(adapters=pool, dataset="medium", horizon=40.0,
                        seed=5)
    twin = ClusterDigitalTwin(est, mode="mean")
    router = ClusterRouter(twin.specs_from_slots([6, 6], mean_rank=8.0),
                           policy="affinity")
    res = twin.simulate_online(spec, router, epoch=5.0, rebalance=True)
    assert len(res.online.migrations) == 0


# --------------------------------------------------------------------- #
# fault tolerance mechanics
# --------------------------------------------------------------------- #

def test_whole_pool_resident_on_dead_replica(est):
    """Every adapter resident on the replica that dies: the survivor
    cold-loads them and still finishes the entire stream."""
    # a single adapter -> affinity pins the whole pool to one replica
    pool = make_adapter_pool(1, [8], [1.0])
    spec = WorkloadSpec(adapters=pool, dataset="small", horizon=40.0,
                        seed=2)
    reqs = generate_requests(spec)
    twin = ClusterDigitalTwin(est, mode="full")
    router = ClusterRouter(twin.specs_from_slots([4, 4], mean_rank=8.0),
                           policy="affinity")
    res = twin.simulate_online(
        spec, router, requests=reqs, epoch=5.0, rebalance=False,
        failures=[FailureEvent(replica=0, at=15.0)])
    # the first route goes to replica 0 (tie-break), so the kill hits the
    # unique holder of the whole pool
    assert res.online.failures_detected.get(0) is not None
    assert res.metrics.n_finished == len(reqs)
    assert res.metrics.per_replica[1].n_finished > 0


def test_total_outage_degrades_gracefully(est):
    """Killing the last live replica is a fleet outage: the loop stops
    and still returns an honest report (no traceback, no lost state)."""
    pool = make_adapter_pool(2, [8], [0.5])
    spec = WorkloadSpec(adapters=pool, dataset="small", horizon=30.0,
                        seed=1)
    reqs = generate_requests(spec)
    twin = ClusterDigitalTwin(est, mode="full")
    router = ClusterRouter(twin.specs_from_slots([4], mean_rank=8.0),
                           policy="affinity")
    res = twin.simulate_online(
        spec, router, requests=reqs, epoch=5.0,
        rebalance=False, failures=[FailureEvent(replica=0, at=5.0)])
    assert res.router_summary["alive"] == [False]
    assert 0 in res.online.failures_detected
    # what finished before the outage is reported; the rest is unfinished
    assert res.metrics.n_finished < len(reqs)


def test_straggler_flagged_and_routed_away():
    """A replica 4x slower than the fleet gets flagged; new adapters
    route away while it keeps serving what it holds."""
    profile = HardwareProfile()
    slow = HardwareProfile(m_base=profile.m_base * 4,
                           m1=profile.m1 * 4)
    pool = make_adapter_pool(12, [8], [0.3])
    ranks = {a.uid: a.rank for a in pool}
    spec = WorkloadSpec(adapters=pool, dataset="small", horizon=60.0,
                        seed=4)
    specs = make_replica_specs(2, 6, profile.kv_capacity(6, 8))
    router = ClusterRouter(specs, policy="affinity")
    executors = [
        SyntheticExecutor(profile, ranks, slots=6, n_adapters=12, seed=1),
        SyntheticExecutor(slow, ranks, slots=6, n_adapters=12, seed=2),
    ]
    cluster = ServingCluster(router, executors)
    report = cluster.run_online(generate_requests(spec), horizon=60.0,
                                epoch=5.0, straggler_factor=2.0)
    assert report.straggler_epochs.get(1, 0) > 0
    assert router.straggler[1]
    # the straggler kept serving (no starvation of its resident work)
    assert report.metrics.n_finished == \
        sum(report.router_summary["assigned_requests"])


# --------------------------------------------------------------------- #
# cluster-trained placement model (joint twin sweeps)
# --------------------------------------------------------------------- #

def test_joint_cluster_sweep_finds_feasible_point(est):
    pool = make_adapter_pool(16, [8, 16], [0.1])
    res = find_cluster_placement_joint(est, pool, "medium", n_replicas=2,
                                       horizon=40.0, n_grid=[8, 16])
    assert res.best is not None
    assert 1 <= res.n_adapters <= 16
    assert res.slots >= 1
    assert res.throughput > 0
    assert not res.best.starved


def test_cluster_placement_model_trains_and_recommends(est):
    scenarios = [
        Scenario(rates=(0.4, 0.2, 0.1), ranks=(8, 16, 32),
                 dataset="medium"),
        Scenario(rates=(0.2, 0.1, 0.05), ranks=(8, 16, 32),
                 dataset="medium"),
        Scenario(rates=(0.1, 0.05, 0.025), ranks=(8, 16, 32),
                 dataset="small"),
        Scenario(rates=(0.8, 0.4, 0.2), ranks=(8, 16, 32),
                 dataset="small"),
    ]
    model = train_cluster_placement_model(
        est, scenarios, max_adapters=12, replica_counts=(1, 2),
        horizon=30.0, holdout=0.25)
    stats = WorkloadSpec(adapters=[]).length_stats()
    rec = model.recommend([0.2] * 12, [8] * 12, stats, n_replicas=2)
    assert rec["served_adapters"] >= 1
    assert rec["slots_per_replica"] >= 1
    assert rec["total_throughput"] > 0
    # interpretability: importances exist and are a distribution
    imp = model.importances()
    assert set(imp) == set(model.feature_names)
    total = sum(imp.values())
    assert total == pytest.approx(1.0, abs=1e-6) or total == 0.0


def test_forest_feature_importances_find_the_signal():
    rng = np.random.default_rng(0)
    x = rng.uniform(0, 1, (300, 4))
    y = np.where(x[:, 2] > 0.5, 10.0, -10.0)        # only feature 2 matters
    from repro.core import RandomForest
    rf = RandomForest(n_trees=5, max_depth=3).fit(x, y)
    imp = rf.feature_importances()
    assert imp.shape == (4,)
    assert imp[2] == max(imp)
    assert imp[2] > 0.9


def test_online_without_events_matches_offline_closely(est):
    """No failures, no rebalancing, no drift: the online loop is the
    same system as the offline partition run (same engines, same
    router beliefs) up to epoch-boundary effects."""
    pool = make_adapter_pool(12, [8, 16], [0.2])
    mean_rank = float(np.mean([a.rank for a in pool]))
    spec = WorkloadSpec(adapters=pool, dataset="medium", horizon=60.0,
                        seed=9)
    reqs = generate_requests(spec)
    twin = ClusterDigitalTwin(est, mode="full")

    router_a = ClusterRouter(
        twin.specs_from_slots([6, 6], mean_rank=mean_rank),
        policy="affinity")
    offline = twin.simulate(spec, router_a, requests=reqs).metrics

    router_b = ClusterRouter(
        twin.specs_from_slots([6, 6], mean_rank=mean_rank),
        policy="affinity")
    online = twin.simulate_online(spec, router_b, requests=reqs,
                                  epoch=5.0, rebalance=False,
                                  drain=False).metrics
    assert online.n_finished >= 0.9 * offline.n_finished
    assert online.throughput >= 0.85 * offline.throughput

"""Serving engine semantics: scheduler, adapter slots, KV, preemption,
metrics, starvation."""

from repro.serving import (AdapterSlotCache, EngineConfig, PagedKVCache,
                           Request, Scheduler, ServingEngine,
                           SyntheticExecutor, HardwareProfile, smape)
from repro.core import WorkloadSpec, generate_requests, make_adapter_pool


def _req(uid, adapter=0, arrival=0.0, p=4, o=4):
    return Request(uid=uid, adapter=adapter, arrival=arrival,
                   prompt_len=p, output_len=o)


# --------------------------------------------------------------------- #
# KV cache
# --------------------------------------------------------------------- #

def test_kv_greedy_alloc_and_free():
    kv = PagedKVCache(capacity_tokens=64, block_size=16)
    assert kv.total_blocks == 4
    assert kv.allocate(1, 17)          # 2 blocks
    assert kv.free_blocks == 2
    assert kv.allocate(1, 15)          # fills block 2 exactly
    assert kv.free_blocks == 2
    assert kv.allocate(2, 33) is False  # needs 3 blocks -> only 2 left
    kv.free(1)
    assert kv.free_blocks == 4


def test_kv_incremental_token_blocks():
    kv = PagedKVCache(capacity_tokens=32, block_size=16)
    assert kv.allocate(7, 16)
    assert kv.free_blocks == 1
    assert kv.allocate(7, 1)           # 17th token -> new block
    assert kv.free_blocks == 0


# --------------------------------------------------------------------- #
# adapter slots (LRU + pinning)
# --------------------------------------------------------------------- #

def test_adapter_lru_eviction_and_pinning():
    ac = AdapterSlotCache(slots=2)
    assert ac.load(1, now=0.0) is True      # cold
    assert ac.load(2, now=1.0) is True
    ac.pin(1)
    assert ac.can_load(3)                   # 2 evictable
    ac.pin(2)
    assert not ac.can_load(3)               # all pinned
    ac.unpin(1)
    ac.load(3, now=2.0)                     # evicts LRU unpinned = 1
    assert ac.is_loaded(3) and not ac.is_loaded(1)
    assert ac.evict_count == 1


# --------------------------------------------------------------------- #
# scheduler
# --------------------------------------------------------------------- #

def _sched(kv_tokens=1024, slots=2, max_running=8):
    kv = PagedKVCache(kv_tokens, block_size=16)
    ac = AdapterSlotCache(slots)
    return Scheduler(kv, ac, max_running)


def test_fcfs_admission_order():
    s = _sched()
    reqs = [_req(i, adapter=i % 2, arrival=i * 0.1) for i in range(4)]
    s.add(reqs)
    plan = s.schedule(now=1.0)
    assert [r.uid for r in plan.admitted] == [0, 1, 2, 3]


def test_loaded_adapter_priority_when_slots_full():
    """vLLM policy: with no free slots, a later request whose adapter is
    loaded is admitted ahead of an earlier one that needs a new slot."""
    s = _sched(slots=1)
    r0 = _req(0, adapter=0, arrival=0.0)
    s.add([r0])
    s.schedule(now=0.0)                    # adapter 0 occupies the slot
    r1 = _req(1, adapter=1, arrival=1.0)   # needs a slot (pinned by r0)
    r2 = _req(2, adapter=0, arrival=2.0)   # adapter already loaded
    s.add([r1, r2])
    plan = s.schedule(now=2.0)
    assert [r.uid for r in plan.admitted] == [2]
    assert r1 in list(s.waiting)


def test_preemption_on_memory_exhaustion():
    s = _sched(kv_tokens=48, slots=4)      # 3 blocks of 16
    a = _req(0, arrival=0.0, p=16, o=100)  # 2 blocks (17 tokens)
    b = _req(1, arrival=1.0, p=14, o=100)  # 1 block
    s.add([a, b])
    s.schedule(now=1.0)
    assert s.n_running == 2
    # decode until memory forces preemption of the newest request (b)
    preempted = []
    for _ in range(40):
        plan = s.schedule(now=2.0)
        for r in plan.running:
            r.generated += 1
        preempted += plan.preempted
        if preempted:
            break
    assert preempted and preempted[0].uid == 1
    assert b.n_preemptions == 1 and b in list(s.waiting)


def test_scheduler_max_running():
    s = _sched(max_running=2)
    s.add([_req(i) for i in range(5)])
    plan = s.schedule(0.0)
    assert len(plan.admitted) == 2


# --------------------------------------------------------------------- #
# engine end-to-end on the synthetic executor
# --------------------------------------------------------------------- #

def _run_engine(rate, n_adapters=8, slots=8, horizon=120.0, dataset="small"):
    profile = HardwareProfile(noise=0.0)
    pool = make_adapter_pool(n_adapters, [8], [rate])
    ranks = {a.uid: a.rank for a in pool}
    spec = WorkloadSpec(adapters=pool, dataset=dataset, horizon=horizon,
                        seed=3)
    reqs = generate_requests(spec)
    cfg = EngineConfig(kv_capacity_tokens=profile.kv_capacity(slots, 8),
                       adapter_slots=slots)
    eng = ServingEngine(cfg, SyntheticExecutor(
        profile, ranks, slots=slots, n_adapters=n_adapters))
    return eng.run(reqs, horizon=horizon), reqs


def test_engine_low_rate_not_starved():
    m, _ = _run_engine(rate=0.05)
    assert not m.starved
    assert m.n_finished > 0
    assert m.ttft > 0 and m.itl > 0


def test_engine_overload_starves():
    m, _ = _run_engine(rate=20.0, n_adapters=64, slots=4)
    assert m.starved


def test_engine_request_conservation():
    m, reqs = _run_engine(rate=0.05)
    for r in reqs:
        if r.finished_at is not None:
            assert r.generated == r.output_len
            assert len(r.token_times) >= r.output_len
            assert r.first_token_at >= r.arrival


def test_throughput_monotone_in_rate():
    lo, _ = _run_engine(rate=0.02)
    hi, _ = _run_engine(rate=0.2)
    assert hi.throughput > lo.throughput


def test_smape_symmetric():
    assert smape(1.0, 2.0) == smape(2.0, 1.0)
    assert smape(5.0, 5.0) == 0.0

"""Teacher-forcing consistency: decode-with-cache must equal full prefill
logits for every architecture family (validates KV caches, rolling
windows, SSD/RG-LRU states, RoPE positions, MoE dropless decode)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_reduced
from repro.models import Model, ShardingPlan
from repro.models.transformer import pad_cache

KEY = jax.random.PRNGKey(1)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_prefill(arch):
    cfg = dataclasses.replace(get_reduced(arch), dtype="float32")
    m_pre = Model(cfg, ShardingPlan(mode="prefill"))
    m_dec = Model(cfg, ShardingPlan(mode="decode"))
    params = m_pre.init(KEY)
    lora = m_pre.init_lora(KEY, 4, 4)
    b, s = 2, 24
    tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    kwargs = {}
    if cfg.family == "vlm":
        kwargs["img_embeds"] = jax.random.normal(
            KEY, (b, cfg.n_image_tokens, cfg.d_model), cfg.jnp_dtype)
    idx = jnp.array([1, 2], jnp.int32)
    logits_full, _ = jax.jit(m_pre.prefill)(params, lora, tokens, idx,
                                            **kwargs)
    _, cache = jax.jit(m_pre.prefill)(params, lora, tokens[:, :-1], idx,
                                      **kwargs)
    logits_dec, _ = jax.jit(m_dec.decode_step)(
        params, lora, pad_cache(cache, 4), tokens[:, -1:], idx)
    err = float(jnp.max(jnp.abs(logits_dec - logits_full)))
    rel = err / (float(jnp.max(jnp.abs(logits_full))) + 1e-9)
    assert rel < 2e-4, f"{arch}: rel={rel}"


def test_lora_changes_output():
    cfg = dataclasses.replace(get_reduced("phi4_mini_3p8b"),
                              dtype="float32")
    m = Model(cfg, ShardingPlan(mode="prefill"))
    params = m.init(KEY)
    lora = m.init_lora(KEY, 4, 8)
    tokens = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    la, _ = jax.jit(m.prefill)(params, lora, tokens,
                               jnp.array([0, 0], jnp.int32))
    lb, _ = jax.jit(m.prefill)(params, lora, tokens,
                               jnp.array([1, 1], jnp.int32))
    lnone, _ = jax.jit(m.prefill)(params, None, tokens, None)
    assert not jnp.allclose(la, lb)
    assert not jnp.allclose(la, lnone)


def test_per_request_adapters_independent():
    """Adapter of request 0 must not affect logits of request 1."""
    cfg = dataclasses.replace(get_reduced("qwen1p5_4b"), dtype="float32")
    m = Model(cfg, ShardingPlan(mode="prefill"))
    params = m.init(KEY)
    lora = m.init_lora(KEY, 4, 8)
    tokens = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    l1, _ = jax.jit(m.prefill)(params, lora, tokens,
                               jnp.array([0, 2], jnp.int32))
    l2, _ = jax.jit(m.prefill)(params, lora, tokens,
                               jnp.array([1, 2], jnp.int32))
    assert not jnp.allclose(l1[0], l2[0])       # req 0 changed
    assert jnp.allclose(l1[1], l2[1])           # req 1 untouched

"""FastTwin equivalence + SweepRunner determinism.

The fast path's contract is *semantic preservation*: with the
deterministic estimator executor (the twin never has noise), the
struct-of-arrays ``FastTwin``/``FastEngine`` must reproduce the legacy
object-mode ``DigitalTwin``/``ServingEngine`` decisions exactly — same
virtual clock, throughput, finish/preemption/load counts.  Mean ITL is
the one documented tolerance (legacy averages per-token gaps, the fast
path uses the telescoped algebraic equivalent; they differ by float
rounding only).
"""
import numpy as np
import pytest

from repro.core import (ClusterDigitalTwin, DigitalTwin, FastTwin, Scenario,
                        SweepRunner, SweepTask, WorkloadSpec,
                        find_cluster_placement_joint, find_optimal_placement,
                        generate_drifting_requests, generate_requests,
                        label_cluster_scenarios, make_adapter_pool,
                        rotating_hot_phases)
from repro.core.estimators import FittedEstimators
from repro.core.sweep import run_task
from repro.serving import SCHED_POLICIES, ClusterRouter, FailureEvent
from repro.serving.metrics import TWIN_EXACT_FIELDS as EXACT_FIELDS


def mk_est(kv_base: float = 120000.0, kv_slope: float = -60.0
           ) -> FittedEstimators:
    """Hand-built Eq. (1) fits (H100-ish magnitudes): deterministic, no
    benchmark collection needed."""
    return FittedEstimators(
        sched=np.array([4e-4, 8e-6, 4e-6, 2.5e-5]),
        model=np.array([2.4e-2, 2.2e-4, 6.5e-6]),
        adapters=np.array([1.06, 0.004]),
        load=np.array([8e-3, 1.1e-3]),
        load_disk_mult=1.7,
        memmax=np.array([kv_base, kv_slope]))


def assert_equivalent(legacy, fast):
    for f in EXACT_FIELDS:
        assert getattr(legacy, f) == getattr(fast, f), \
            f"{f}: {getattr(legacy, f)} != {getattr(fast, f)}"
    # documented tolerance: telescoped vs per-gap ITL averaging
    assert fast.itl == pytest.approx(legacy.itl, rel=1e-9, abs=1e-12)


def both(est, spec, slots, mode="mean", requests=None,
         sched_policy="fcfs"):
    legacy = DigitalTwin(est, mode=mode, sched_policy=sched_policy) \
        .simulate(spec, slots=slots, requests=requests).metrics
    fast = FastTwin(est, mode=mode, sched_policy=sched_policy) \
        .simulate(spec, slots=slots, requests=requests).metrics
    return legacy, fast


# --------------------------------------------------------------------- #
# noise-off metric equivalence across workload shapes
# --------------------------------------------------------------------- #

def test_equivalence_uniform_rates():
    est = mk_est()
    pool = make_adapter_pool(24, [8, 16, 32], [0.15])
    spec = WorkloadSpec(adapters=pool, dataset="medium", horizon=80.0,
                        seed=3)
    assert_equivalent(*both(est, spec, slots=8))


def test_equivalence_skewed_rates_sharegpt():
    est = mk_est()
    pool = make_adapter_pool(32, [8, 16, 32], [1.6, 0.4, 0.1, 0.025])
    spec = WorkloadSpec(adapters=pool, dataset="sharegpt", horizon=80.0,
                        seed=11)
    legacy, fast = both(est, spec, slots=6)
    assert legacy.n_finished > 0
    assert_equivalent(legacy, fast)


def test_equivalence_drifting_full_mode():
    est = mk_est()
    pool = make_adapter_pool(16, [8, 16], [0.05])
    phases = rotating_hot_phases(pool, 60.0, n_phases=3, hot_fraction=0.25,
                                 hot_rate=1.0, cold_rate=0.02)
    reqs = generate_drifting_requests(pool, "medium", 60.0, phases, seed=5)
    spec = WorkloadSpec(adapters=pool, dataset="medium", horizon=60.0,
                        seed=5)
    assert_equivalent(*both(est, spec, slots=4, mode="full", requests=reqs))


def test_equivalence_full_mode_exact_stream():
    est = mk_est()
    pool = make_adapter_pool(20, [8, 16], [0.2, 0.1])
    spec = WorkloadSpec(adapters=pool, dataset="sharegpt", horizon=70.0,
                        seed=9)
    reqs = generate_requests(spec)
    legacy, fast = both(est, spec, slots=5, mode="full", requests=reqs)
    assert_equivalent(legacy, fast)
    # full mode must not mutate the caller's stream (legacy deep-copies,
    # the fast path reads it immutably)
    assert all(r.generated == 0 and r.finished_at is None for r in reqs)


def test_equivalence_slot_pressure_lru_reloads():
    """Starvation regime: far more adapters than slots — exercises the
    LRU reload churn and the admission scan's short-circuit."""
    est = mk_est()
    pool = make_adapter_pool(48, [8, 16, 32], [0.05])
    spec = WorkloadSpec(adapters=pool, dataset="medium", horizon=120.0,
                        seed=7)
    legacy, fast = both(est, spec, slots=4)
    assert legacy.n_loads > 48          # adapters were reloaded repeatedly
    assert_equivalent(legacy, fast)


def test_equivalence_preemption_path():
    """Tiny KV capacity forces decode-time preemption-by-recompute; the
    fast path's sequential fallback must replay it exactly."""
    est = mk_est(kv_base=5000.0, kv_slope=-5.0)
    pool = make_adapter_pool(12, [8, 16], [0.5, 0.3])
    spec = WorkloadSpec(adapters=pool, dataset="sharegpt", horizon=90.0,
                        seed=5)
    legacy, fast = both(est, spec, slots=6)
    assert legacy.n_preemptions > 0     # the path under test was hit
    assert_equivalent(legacy, fast)


# --------------------------------------------------------------------- #
# per-policy equivalence: every registered scheduling policy must make
# identical decisions in the object-mode twin and the SoA fast path
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("policy", sorted(SCHED_POLICIES))
def test_equivalence_per_sched_policy(policy):
    """Slot-pressure + skew so the admission *ordering* actually binds."""
    est = mk_est()
    pool = make_adapter_pool(24, [8, 16, 32], [1.2, 0.3, 0.08, 0.02])
    spec = WorkloadSpec(adapters=pool, dataset="sharegpt", horizon=70.0,
                        seed=13)
    reqs = generate_requests(spec)
    legacy, fast = both(est, spec, slots=4, mode="full", requests=reqs,
                        sched_policy=policy)
    assert legacy.n_finished > 0
    assert_equivalent(legacy, fast)


@pytest.mark.parametrize("policy", sorted(SCHED_POLICIES))
def test_equivalence_per_sched_policy_preemption(policy):
    """Same, with KV tight enough to hit the preemption fallback."""
    est = mk_est(kv_base=5000.0, kv_slope=-5.0)
    pool = make_adapter_pool(12, [8, 16], [0.5, 0.3])
    spec = WorkloadSpec(adapters=pool, dataset="sharegpt", horizon=60.0,
                        seed=5)
    legacy, fast = both(est, spec, slots=6, sched_policy=policy)
    assert legacy.n_preemptions > 0
    assert_equivalent(legacy, fast)


def test_placement_policy_axis_fast_matches_legacy():
    """The sweep's policy dimension labels identically on both twins."""
    est = mk_est()
    pool = make_adapter_pool(16, [8, 16], [0.3, 0.1])
    kw = dict(horizon=30.0, seed=2, n_grid=[4, 16])
    for policy in ("fcfs", "adapter-fair"):
        a = find_optimal_placement(est, pool, "medium", fast=False,
                                   sched_policy=policy, **kw)
        b = find_optimal_placement(est, pool, "medium", fast=True,
                                   sched_policy=policy, **kw)
        assert (a.n_adapters, a.slots, a.throughput) == \
            (b.n_adapters, b.slots, b.throughput)


# --------------------------------------------------------------------- #
# cluster twin: offline + online (resumable engine surface)
# --------------------------------------------------------------------- #

def _cluster_inputs(est):
    pool = make_adapter_pool(16, [8, 16], [0.02])
    phases = rotating_hot_phases(pool, 50.0, n_phases=2, hot_fraction=0.375,
                                 hot_rate=1.0, cold_rate=0.02)
    reqs = generate_drifting_requests(pool, "medium", 50.0, phases, seed=3)
    spec = WorkloadSpec(adapters=pool, dataset="medium", horizon=50.0,
                        seed=3)
    return pool, spec, reqs


def _cluster_run(est, spec, reqs, fast, failures=()):
    twin = ClusterDigitalTwin(est, mode="full", fast=fast)
    router = ClusterRouter(twin.specs_from_slots([4, 4], mean_rank=12.0),
                           policy="affinity")
    return twin.simulate_online(spec, router, requests=reqs, epoch=5.0,
                                rebalance=True, failures=list(failures))


def test_cluster_online_equivalence_with_migrations():
    est = mk_est()
    _, spec, reqs = _cluster_inputs(est)
    legacy = _cluster_run(est, spec, reqs, fast=False)
    fast = _cluster_run(est, spec, reqs, fast=True)
    assert len(legacy.online.migrations) == len(fast.online.migrations)
    for f in EXACT_FIELDS:
        assert getattr(legacy.metrics, f) == getattr(fast.metrics, f)


def test_cluster_online_equivalence_replica_failure():
    """Kill a replica mid-run: drain + re-route on the fast engines must
    match the object-mode loop event for event."""
    est = mk_est()
    _, spec, reqs = _cluster_inputs(est)
    kill = [FailureEvent(replica=0, at=20.0)]
    legacy = _cluster_run(est, spec, reqs, fast=False, failures=kill)
    fast = _cluster_run(est, spec, reqs, fast=True, failures=kill)
    assert fast.online.n_rerouted == legacy.online.n_rerouted > 0
    assert fast.online.failures_detected == legacy.online.failures_detected
    for f in EXACT_FIELDS:
        assert getattr(legacy.metrics, f) == getattr(fast.metrics, f)
    # every request completed on the survivor (drain semantics; the fast
    # engines' write-back keeps the online loop's completion check honest)
    assert fast.metrics.n_finished == len(reqs)


def _hotspot_run(est, fast, failures=()):
    """Single-hot-adapter run under hard affinity with replication armed
    — exercises Replicate (and the failure path: one home killed)."""
    from repro.serving.request import Adapter
    pool = make_adapter_pool(4, [8], [0.02])
    pool[0] = Adapter(uid=0, rank=8, rate=10.0)
    spec = WorkloadSpec(adapters=pool, dataset="medium", horizon=40.0,
                        seed=11)
    reqs = generate_requests(spec)
    twin = ClusterDigitalTwin(est, mode="full", max_running=64, fast=fast)
    router = ClusterRouter(
        twin.specs_from_slots([4, 4], mean_rank=8.0),
        policy="affinity", overload_factor=1e9, slack=1e9)
    reb = twin.rebalancer(spec, router, replicate=True)
    return twin.simulate_online(spec, router, requests=reqs, epoch=5.0,
                                rebalance=False, rebalancer=reb,
                                failures=list(failures))


def test_cluster_online_equivalence_with_replication():
    """The twin-vs-engine equivalence contract extends to runs with
    Replicate plan actions: identical events, identical metrics."""
    est = mk_est()
    legacy = _hotspot_run(est, fast=False)
    fast = _hotspot_run(est, fast=True)
    assert len(legacy.online.replications) == \
        len(fast.online.replications) >= 1
    assert [(type(a).__name__, a.adapter) for a in legacy.online.migrations] \
        == [(type(a).__name__, a.adapter) for a in fast.online.migrations]
    for f in EXACT_FIELDS:
        assert getattr(legacy.metrics, f) == getattr(fast.metrics, f), f
    # pooled raw TTFT samples agree as multisets (exact percentiles feed
    # off them, so they must match bitwise after sorting)
    assert sorted(t for m in legacy.metrics.per_replica
                  for t in m.ttft_samples) == \
        sorted(t for m in fast.metrics.per_replica for t in m.ttft_samples)


def test_cluster_online_equivalence_replication_home_killed():
    """Kill one home of the replicated adapter mid-run: the single-home
    degrade must replay identically on both engine implementations."""
    est = mk_est()
    kill = [FailureEvent(replica=1, at=25.0)]
    legacy = _hotspot_run(est, fast=False, failures=kill)
    fast = _hotspot_run(est, fast=True, failures=kill)
    assert len(legacy.online.replications) == \
        len(fast.online.replications) >= 1
    assert fast.online.failures_detected == legacy.online.failures_detected
    assert fast.online.n_rerouted == legacy.online.n_rerouted
    assert fast.router_summary["replicated"] == \
        legacy.router_summary["replicated"] == {}
    for f in EXACT_FIELDS:
        assert getattr(legacy.metrics, f) == getattr(fast.metrics, f), f


def test_placement_search_fast_matches_legacy():
    est = mk_est()
    pool = make_adapter_pool(16, [8, 16], [0.3, 0.1])
    kw = dict(horizon=40.0, seed=2, n_grid=[4, 8, 16])
    a = find_optimal_placement(est, pool, "medium", fast=False, **kw)
    b = find_optimal_placement(est, pool, "medium", fast=True, **kw)
    assert (a.n_adapters, a.slots, a.throughput) == \
        (b.n_adapters, b.slots, b.throughput)
    a = find_cluster_placement_joint(est, pool, "medium", n_replicas=2,
                                     fast=False, **kw)
    b = find_cluster_placement_joint(est, pool, "medium", n_replicas=2,
                                     fast=True, **kw)
    assert (a.n_adapters, a.slots, a.throughput) == \
        (b.n_adapters, b.slots, b.throughput)


# --------------------------------------------------------------------- #
# SweepRunner: determinism for any pool size
# --------------------------------------------------------------------- #

def _labels(results):
    return [(r.n_adapters, r.slots, r.throughput) for r in results]


def test_sweep_runner_deterministic_any_pool_size():
    est = mk_est()
    pools = [tuple(make_adapter_pool(12, [8, 16], [r])) for r in
             (0.4, 0.15, 0.05)]
    tasks = [SweepTask(pool=p, dataset="medium", horizon=25.0, seed=31 + i)
             for i, p in enumerate(pools)]
    tasks.append(SweepTask(pool=pools[0], dataset="medium", horizon=25.0,
                           seed=40, n_replicas=2))
    serial = SweepRunner(est, n_workers=0).map(tasks)
    par2 = SweepRunner(est, n_workers=2).map(tasks)
    par3 = SweepRunner(est, n_workers=3).map(tasks)
    assert _labels(serial) == _labels(par2) == _labels(par3)
    # and the serial path equals calling the sweeps directly
    direct = [run_task(est, t) for t in tasks]
    assert _labels(direct) == _labels(serial)


def test_label_cluster_scenarios_runner_matches_serial():
    est = mk_est()
    scenarios = [
        Scenario(rates=(0.4, 0.2, 0.1), ranks=(8, 16, 32),
                 dataset="medium"),
        Scenario(rates=(0.1, 0.05, 0.025), ranks=(8, 16, 32),
                 dataset="small"),
    ]
    kw = dict(max_adapters=8, replica_counts=(1, 2), horizon=20.0, seed=4)
    xs_a, ys_a = label_cluster_scenarios(est, scenarios, **kw)
    xs_b, ys_b = label_cluster_scenarios(
        est, scenarios, runner=SweepRunner(est, n_workers=2), **kw)
    np.testing.assert_array_equal(xs_a, xs_b)
    np.testing.assert_array_equal(ys_a, ys_b)

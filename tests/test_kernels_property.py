"""Property-based kernel test harness.

Hypothesis strategies over (T, d, r, o, N, block sizes, dtype,
adapter-id distributions) asserting interpret-mode Pallas == the pure-jnp
oracles in ``repro.kernels.ref`` within documented tolerance, for every
kernel: bgmv, sgmv (dense + ragged ranks), flash_decode, and the fused
flash-decode+LoRA kernel.

Adapter-id distributions cover the serving engine's real shapes:
``random`` (mixed batch), ``all-same`` (one hot adapter), ``all-distinct``
(worst-case gather), ``with-empty`` (some adapters receive zero tokens),
and ``all-base`` (every token id -1 — base model, zero delta).

Documented tolerances: f32 2e-5 / bf16 3e-2 (fp32 accumulation inside
every kernel; bf16 rounds once on the way out).  The ragged-rank sgmv is
additionally pinned *bitwise* against its own dense path on a
``mask_ragged`` zero-padded bank in tests/test_kernels_edge.py.

Heavier sweeps are marked ``slow`` (nightly full CI job only).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (pip install -e .[dev])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels import ref
from repro.kernels.bgmv import bgmv
from repro.kernels.flash_decode import flash_decode, flash_decode_lora
from repro.kernels.sgmv import sgmv

DTYPES = (jnp.float32, jnp.bfloat16)
ID_KINDS = ("random", "all-same", "all-distinct", "with-empty", "all-base")


def _tol(dtype):
    return 2e-5 if dtype == jnp.float32 else 3e-2


def _assert_close(got, want, dtype, tol_scale: float = 1.0):
    tol = _tol(dtype) * tol_scale
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def _ids(key, kind: str, t: int, n: int):
    """One adapter-id vector of the named distribution."""
    if kind == "all-same":
        return jnp.full((t,), int(jax.random.randint(key, (), 0, n)),
                        jnp.int32)
    if kind == "all-distinct":
        return (jnp.arange(t, dtype=jnp.int32) % n)
    if kind == "with-empty":
        # at most half the adapters receive tokens; the rest stay empty
        used = max(n // 2, 1)
        return jax.random.randint(key, (t,), 0, used).astype(jnp.int32)
    if kind == "all-base":
        return jnp.full((t,), -1, jnp.int32)
    return jax.random.randint(key, (t,), -1, n).astype(jnp.int32)


def _lora_bank(key, t, d, r, o, n, dtype):
    ks = jax.random.split(key, 3)
    x = jax.random.normal(ks[0], (t, d), dtype)
    a = (jax.random.normal(ks[1], (n, d, r), jnp.float32) * 0.1).astype(dtype)
    b = (jax.random.normal(ks[2], (n, r, o), jnp.float32) * 0.1).astype(dtype)
    return x, a, b


# --------------------------------------------------------------------- #
# bgmv
# --------------------------------------------------------------------- #

@settings(max_examples=10, deadline=None)
@given(t=st.integers(1, 12), d=st.sampled_from([16, 64, 96]),
       r=st.sampled_from([1, 4, 16]), o=st.sampled_from([16, 48, 128]),
       n=st.integers(1, 5), kind=st.sampled_from(ID_KINDS),
       dtype=st.sampled_from(DTYPES), seed=st.integers(0, 2 ** 16))
def test_bgmv_property(t, d, r, o, n, kind, dtype, seed):
    key = jax.random.PRNGKey(seed)
    x, a, b = _lora_bank(key, t, d, r, o, n, dtype)
    idx = _ids(jax.random.fold_in(key, 1), kind, t, n)
    got = bgmv(x, a, b, idx, 1.25, interpret=True)
    want = ref.lora_ref(x, a, b, idx, 1.25)
    _assert_close(got, want, dtype)


# --------------------------------------------------------------------- #
# sgmv (dense + ragged ranks)
# --------------------------------------------------------------------- #

@settings(max_examples=8, deadline=None)
@given(t=st.sampled_from([64, 130, 256]), d=st.sampled_from([16, 64]),
       r=st.sampled_from([1, 8, 16]), o=st.sampled_from([32, 96]),
       n=st.integers(1, 6), kind=st.sampled_from(ID_KINDS),
       dtype=st.sampled_from(DTYPES), seed=st.integers(0, 2 ** 16))
def test_sgmv_property(t, d, r, o, n, kind, dtype, seed):
    key = jax.random.PRNGKey(seed)
    x, a, b = _lora_bank(key, t, d, r, o, n, dtype)
    idx = _ids(jax.random.fold_in(key, 1), kind, t, n)
    got = sgmv(x, a, b, idx, 1.0, interpret=True)
    want = ref.lora_ref(x, a, b, idx, 1.0)
    _assert_close(got, want, dtype)


@settings(max_examples=8, deadline=None)
@given(t=st.sampled_from([64, 192]), n=st.integers(1, 6),
       r_max=st.sampled_from([4, 8, 16]), kind=st.sampled_from(ID_KINDS),
       seed=st.integers(0, 2 ** 16))
def test_sgmv_ragged_property(t, n, r_max, kind, seed):
    """Ragged ranks: padded lanes masked in the shrink matmul must equal
    the dense per-rank oracle (and stay bitwise vs the dense kernel on a
    masked bank — pinned in the edge suite; tolerance vs jnp here)."""
    key = jax.random.PRNGKey(seed)
    d, o = 32, 48
    x, a, b = _lora_bank(key, t, d, r_max, o, n, jnp.float32)
    ranks = jax.random.randint(jax.random.fold_in(key, 2), (n,), 1,
                               r_max + 1).astype(jnp.int32)
    idx = _ids(jax.random.fold_in(key, 1), kind, t, n)
    got = sgmv(x, a, b, idx, 1.0, ranks=ranks, interpret=True)
    am, bm = ref.mask_ragged(a, b, ranks)
    dense = sgmv(x, am, bm, idx, 1.0, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(dense))
    want = ref.lora_ref_ragged(x, a, b, idx, ranks, 1.0)
    _assert_close(got, want, jnp.float32)


# --------------------------------------------------------------------- #
# flash decode
# --------------------------------------------------------------------- #

@settings(max_examples=8, deadline=None)
@given(b=st.integers(1, 3), kv=st.sampled_from([1, 2, 4]),
       g=st.sampled_from([1, 2, 4]), d=st.sampled_from([16, 32, 64]),
       s=st.sampled_from([33, 64, 100, 256]),
       block_s=st.sampled_from([16, 32, 64, 512]),
       dtype=st.sampled_from(DTYPES), seed=st.integers(0, 2 ** 16))
def test_flash_decode_property(b, kv, g, d, s, block_s, dtype, seed):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    h = kv * g
    q = jax.random.normal(ks[0], (b, h, d), dtype)
    k = jax.random.normal(ks[1], (b, s, kv, d), dtype)
    v = jax.random.normal(ks[2], (b, s, kv, d), dtype)
    length = jax.random.randint(ks[3], (b,), 1, s + 1).astype(jnp.int32)
    got = flash_decode(q, k, v, length, block_s=block_s, interpret=True)
    want = ref.flash_decode_ref(q, k, v, length)
    _assert_close(got, want, dtype)


# --------------------------------------------------------------------- #
# fused flash-decode + LoRA
# --------------------------------------------------------------------- #

@settings(max_examples=10, deadline=None)
@given(b=st.integers(1, 3), kv=st.sampled_from([1, 2]),
       g=st.sampled_from([1, 4]), d=st.sampled_from([16, 32]),
       s=st.sampled_from([48, 64, 144]),
       block_s=st.sampled_from([16, 32, 512]),
       dx=st.sampled_from([16, 48]), r=st.sampled_from([1, 8]),
       n=st.integers(1, 4), kind=st.sampled_from(ID_KINDS),
       dtype=st.sampled_from(DTYPES), seed=st.integers(0, 2 ** 16))
def test_fused_decode_property(b, kv, g, d, s, block_s, dx, r, n, kind,
                               dtype, seed):
    """The fused kernel must match the *composed* reference
    (ref.flash_decode_ref + ref.lora_ref) across the whole grid,
    including base-model rows (id -1) and partial valid lengths."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    h = kv * g
    q = jax.random.normal(ks[0], (b, h, d), dtype)
    k = jax.random.normal(ks[1], (b, s, kv, d), dtype)
    v = jax.random.normal(ks[2], (b, s, kv, d), dtype)
    x, a, bw = _lora_bank(jax.random.fold_in(key, 1), b, dx, r, h * d,
                          n, dtype)
    idx = _ids(jax.random.fold_in(key, 2), kind, b, n)
    length = jax.random.randint(ks[3], (b,), 1, s + 1).astype(jnp.int32)
    got = flash_decode_lora(q, k, v, length, x, a, bw, idx, 1.5,
                            block_s=block_s, interpret=True)
    want = ref.fused_decode_ref(q, k, v, length, x, a, bw, idx, 1.5)
    _assert_close(got, want, dtype)


# --------------------------------------------------------------------- #
# heavy sweeps — nightly only
# --------------------------------------------------------------------- #

@pytest.mark.slow
@settings(max_examples=60, deadline=None)
@given(t=st.integers(1, 40), d=st.sampled_from([16, 64, 128, 256]),
       r=st.sampled_from([1, 2, 4, 8, 16, 32]),
       o=st.sampled_from([16, 64, 256, 384]), n=st.integers(1, 12),
       kind=st.sampled_from(ID_KINDS), dtype=st.sampled_from(DTYPES),
       seed=st.integers(0, 2 ** 20))
def test_bgmv_property_heavy(t, d, r, o, n, kind, dtype, seed):
    key = jax.random.PRNGKey(seed)
    x, a, b = _lora_bank(key, t, d, r, o, n, dtype)
    idx = _ids(jax.random.fold_in(key, 1), kind, t, n)
    got = bgmv(x, a, b, idx, 0.75, interpret=True)
    want = ref.lora_ref(x, a, b, idx, 0.75)
    _assert_close(got, want, dtype)


@pytest.mark.slow
@settings(max_examples=40, deadline=None)
@given(t=st.integers(129, 700), n=st.integers(1, 16),
       r_max=st.sampled_from([2, 8, 32]), kind=st.sampled_from(ID_KINDS),
       dtype=st.sampled_from(DTYPES), seed=st.integers(0, 2 ** 20))
def test_sgmv_ragged_property_heavy(t, n, r_max, kind, dtype, seed):
    key = jax.random.PRNGKey(seed)
    d, o = 64, 64
    x, a, b = _lora_bank(key, t, d, r_max, o, n, dtype)
    ranks = jax.random.randint(jax.random.fold_in(key, 2), (n,), 1,
                               r_max + 1).astype(jnp.int32)
    idx = _ids(jax.random.fold_in(key, 1), kind, t, n)
    got = sgmv(x, a, b, idx, 1.0, ranks=ranks, interpret=True)
    am, bm = ref.mask_ragged(a, b, ranks)
    dense = sgmv(x, am, bm, idx, 1.0, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(dense))
    want = ref.lora_ref_ragged(x, a, b, idx, ranks, 1.0)
    _assert_close(got, want, dtype)


@pytest.mark.slow
@settings(max_examples=40, deadline=None)
@given(b=st.integers(1, 6), kv=st.sampled_from([1, 2, 4, 8]),
       g=st.sampled_from([1, 2, 4]), d=st.sampled_from([32, 64, 128]),
       s=st.integers(2, 1024), block_s=st.sampled_from([16, 64, 256, 512]),
       dx=st.sampled_from([32, 128]), r=st.sampled_from([1, 8, 32]),
       n=st.integers(1, 8), kind=st.sampled_from(ID_KINDS),
       dtype=st.sampled_from(DTYPES), seed=st.integers(0, 2 ** 20))
def test_fused_decode_property_heavy(b, kv, g, d, s, block_s, dx, r, n,
                                     kind, dtype, seed):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    h = kv * g
    q = jax.random.normal(ks[0], (b, h, d), dtype)
    k = jax.random.normal(ks[1], (b, s, kv, d), dtype)
    v = jax.random.normal(ks[2], (b, s, kv, d), dtype)
    x, a, bw = _lora_bank(jax.random.fold_in(key, 1), b, dx, r, h * d,
                          n, dtype)
    idx = _ids(jax.random.fold_in(key, 2), kind, b, n)
    length = jax.random.randint(ks[3], (b,), 1, s + 1).astype(jnp.int32)
    got = flash_decode_lora(q, k, v, length, x, a, bw, idx, 1.0,
                            block_s=block_s, interpret=True)
    want = ref.fused_decode_ref(q, k, v, length, x, a, bw, idx, 1.0)
    _assert_close(got, want, dtype)

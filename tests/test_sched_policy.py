"""Scheduling-policy layer: registry, per-policy ordering semantics,
FCFS byte-identity + the skip/re-queue ordering regression, and the
drain-termination property (no policy may livelock or starve forever
when arrivals stop)."""
import math

import numpy as np
import pytest

from repro.core import (DigitalTwin, FastTwin, WorkloadSpec,
                        generate_requests, make_adapter_pool)
from repro.core.digital_twin import EstimatorExecutor
from repro.core.estimators import FittedEstimators
from repro.core.fast_twin import FastEngine
from repro.serving import (AdapterSlotCache, EngineConfig, PagedKVCache,
                           Request, SCHED_POLICIES, Scheduler, SchedView,
                           ServingEngine, make_sched_policy)
from repro.serving.policy import (AdapterClusterPolicy, AdapterFairPolicy,
                                  FCFSPolicy, SLOPriorityPolicy)


def mk_est(kv_base: float = 120000.0, kv_slope: float = -60.0
           ) -> FittedEstimators:
    return FittedEstimators(
        sched=np.array([4e-4, 8e-6, 4e-6, 2.5e-5]),
        model=np.array([2.4e-2, 2.2e-4, 6.5e-6]),
        adapters=np.array([1.06, 0.004]),
        load=np.array([8e-3, 1.1e-3]),
        load_disk_mult=1.7,
        memmax=np.array([kv_base, kv_slope]))


def _req(uid, adapter=0, arrival=0.0, p=4, o=4):
    return Request(uid=uid, adapter=adapter, arrival=arrival,
                   prompt_len=p, output_len=o)


# --------------------------------------------------------------------- #
# registry
# --------------------------------------------------------------------- #

def test_registry_contains_the_four_policies():
    assert {"fcfs", "slo-priority", "adapter-fair",
            "adapter-cluster"} <= set(SCHED_POLICIES)


def test_make_sched_policy_resolution():
    assert isinstance(make_sched_policy("fcfs"), FCFSPolicy)
    assert isinstance(make_sched_policy(None), FCFSPolicy)
    p = AdapterFairPolicy()
    assert make_sched_policy(p) is p
    with pytest.raises(ValueError, match="unknown scheduling policy"):
        make_sched_policy("nope")


# --------------------------------------------------------------------- #
# pure ordering semantics (stub view over (arrival, adapter, ctx) tuples)
# --------------------------------------------------------------------- #

class TupleView(SchedView):
    def __init__(self, resident=()):
        self._res = set(resident)

    def arrival(self, it):
        return it[0]

    def adapter(self, it):
        return it[1]

    def context_len(self, it):
        return it[2]

    def resident(self, adapter):
        return adapter in self._res


def test_fcfs_order_is_identity():
    items = [(3.0, 1, 10), (1.0, 2, 10), (2.0, 1, 10)]
    assert FCFSPolicy().order(items, TupleView(), now=5.0) == items


def test_slo_priority_prefers_urgent_class():
    # class 0 (urgent) arrives later than class 3 but goes first
    pol = SLOPriorityPolicy(slo_base=5.0, aging=0.5,
                            priorities={7: 0, 9: 3})
    urgent = (4.0, 7, 10)
    lowly = (3.0, 9, 10)
    assert pol.order([lowly, urgent], TupleView(), now=5.0) == \
        [urgent, lowly]


def test_slo_priority_aging_bounds_the_boost():
    # a low-priority request older than slo_base*class/(1+aging) beats a
    # fresh urgent request: low classes cannot starve
    pol = SLOPriorityPolicy(slo_base=5.0, aging=0.5,
                            priorities={7: 0, 9: 3})
    now = 100.0
    old_lowly = (now - 20.0, 9, 10)     # 20 s > 5*3/1.5 = 10 s
    fresh_urgent = (now - 0.1, 7, 10)
    assert pol.order([fresh_urgent, old_lowly], TupleView(), now=now) == \
        [old_lowly, fresh_urgent]


def test_adapter_fair_interleaves_and_charges_deficit():
    pol = AdapterFairPolicy()
    view = TupleView()
    hot = [(float(i), 1, 50) for i in range(4)]       # adapter 1, 4 deep
    cold = (10.0, 2, 50)                              # adapter 2, 1 deep
    # heads first: hot[0] (older queue head, equal deficit) then cold,
    # then the hot tail — the hot adapter cannot monopolize
    got = pol.order(hot + [cold], view, now=20.0)
    assert got[0] == hot[0] and got[1] == cold
    # after charging adapter 1, the cold head overtakes the hot head
    pol.on_admit(hot[0], view, now=20.0)
    got = pol.order(hot[1:] + [cold], view, now=21.0)
    assert got[0] == cold


def test_adapter_cluster_groups_resident_first():
    pol = AdapterClusterPolicy()
    view = TupleView(resident={5})
    a = (1.0, 3, 10)          # oldest, cold adapter
    b = (2.0, 5, 10)          # resident adapter
    c = (3.0, 5, 10)          # same resident adapter, batches with b
    got = pol.order([a, b, c], view, now=4.0)
    assert got == [b, c, a]


# --------------------------------------------------------------------- #
# FCFS byte-identity + the skip/re-queue ordering regression
# --------------------------------------------------------------------- #

def _sched(kv_tokens=1024, slots=2, max_running=8, policy="fcfs"):
    kv = PagedKVCache(kv_tokens, block_size=16)
    ac = AdapterSlotCache(slots)
    return Scheduler(kv, ac, max_running, policy=policy)


def test_fcfs_queue_order_preserved_across_skip_requeue_cycle():
    """Regression (skip/re-queue path): mixing adapter-skips with a
    max_running stop must leave the waiting queue in FCFS arrival order,
    and the next cycle must admit in that order."""
    s = _sched(slots=1, max_running=2)
    r0 = _req(0, adapter=0, arrival=0.0)
    s.add([r0])
    s.schedule(now=0.0)                        # adapter 0 pins the slot
    r1 = _req(1, adapter=1, arrival=1.0)       # adapter-skip (no slot)
    r2 = _req(2, adapter=0, arrival=2.0)       # admitted (fills max_running)
    r3 = _req(3, adapter=1, arrival=3.0)       # never attempted
    r4 = _req(4, adapter=0, arrival=4.0)       # never attempted
    s.add([r1, r2, r3, r4])
    plan = s.schedule(now=4.0)
    assert [r.uid for r in plan.admitted] == [2]
    assert [r.uid for r in s.waiting] == [1, 3, 4]   # FCFS order intact
    # full cycle: finish the running pair; the freed slots must go to the
    # oldest waiting requests (r1 then r3), not to a later same-adapter one
    for r in list(s.running):
        s.finish(r)
    plan = s.schedule(now=5.0)
    assert [r.uid for r in plan.admitted] == [1, 3]
    assert [r.uid for r in s.waiting] == [4]


def test_fcfs_explicit_equals_default_engine_metrics():
    est = mk_est()
    pool = make_adapter_pool(16, [8, 16], [0.3, 0.1])
    ranks = {a.uid: a.rank for a in pool}
    spec = WorkloadSpec(adapters=pool, dataset="sharegpt", horizon=60.0,
                        seed=5)
    reqs = generate_requests(spec)

    def run(**cfg_kw):
        cfg = EngineConfig(kv_capacity_tokens=est.kv_capacity(4, 12.0),
                           adapter_slots=4, **cfg_kw)
        eng = ServingEngine(cfg, EstimatorExecutor(est, 4, 16, ranks))
        return eng.run([Request(**{f: getattr(r, f) for f in
                                   ("uid", "adapter", "arrival",
                                    "prompt_len", "output_len")})
                        for r in reqs], horizon=60.0)

    default = run()
    explicit = run(sched_policy="fcfs")
    assert default == explicit
    assert default.n_starved_requests == \
        sum(default.starved_per_adapter.values())
    assert default.ttft_p99 >= default.ttft_p50 >= 0.0


# --------------------------------------------------------------------- #
# drain termination: every policy finishes every request once arrivals
# stop (no livelock, no forever-starvation) — object and SoA engines
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("policy", sorted(SCHED_POLICIES))
@pytest.mark.parametrize("engine_cls", [ServingEngine, FastEngine])
def test_drain_termination_under_slot_pressure(policy, engine_cls):
    est = mk_est()
    pool = make_adapter_pool(24, [8, 16, 32], [0.6, 0.15, 0.05])
    ranks = {a.uid: a.rank for a in pool}
    spec = WorkloadSpec(adapters=pool, dataset="medium", horizon=30.0,
                        seed=17)
    reqs = generate_requests(spec)
    cfg = EngineConfig(kv_capacity_tokens=est.kv_capacity(3, 18.7),
                       adapter_slots=3, sched_policy=policy)
    eng = engine_cls(cfg, EstimatorExecutor(est, 3, 24, ranks))
    m = eng.run(reqs, horizon=math.inf)
    assert m.n_finished == len(reqs), \
        f"{policy}/{engine_cls.__name__} left requests unserved"
    assert m.n_starved_requests == 0 and not m.starved_per_adapter


# --------------------------------------------------------------------- #
# policy effect: adapter-fair spreads service across adapters
# --------------------------------------------------------------------- #

def _skewed_run(policy):
    """Rotating-hot-phase skew under slot pressure (the fig_sched_policies
    smoke point): the regime where admission ordering decides which
    adapters ever see a slot."""
    from repro.core import generate_drifting_requests, rotating_hot_phases
    est = mk_est()
    pool = make_adapter_pool(24, [8, 16], [0.05])
    phases = rotating_hot_phases(pool, 60.0, n_phases=2, hot_fraction=0.2,
                                 hot_rate=1.8, cold_rate=0.05)
    reqs = generate_drifting_requests(pool, "medium", 60.0, phases, seed=3)
    spec = WorkloadSpec(adapters=pool, dataset="medium", horizon=60.0,
                        seed=3)
    return FastTwin(est, mode="full", max_running=32,
                    sched_policy=policy).simulate(
        spec, slots=3, requests=reqs).metrics


def test_adapter_fair_starves_fewer_than_fcfs_on_skew():
    fair = _skewed_run("adapter-fair")
    fcfs = _skewed_run("fcfs")
    assert fcfs.n_starved_requests > 0
    assert fair.n_starved_requests < fcfs.n_starved_requests
    # and fewer *adapters* are fully shut out
    assert len(fair.starved_per_adapter) <= len(fcfs.starved_per_adapter)


def test_policy_metrics_match_between_twins():
    """DigitalTwin and FastTwin agree per policy on the skewed point."""
    est = mk_est()
    pool = make_adapter_pool(12, [8, 16], [0.8, 0.1])
    spec = WorkloadSpec(adapters=pool, dataset="medium", horizon=40.0,
                        seed=3)
    reqs = generate_requests(spec)
    for policy in sorted(SCHED_POLICIES):
        legacy = DigitalTwin(est, mode="full", sched_policy=policy) \
            .simulate(spec, slots=3, requests=reqs).metrics
        fast = FastTwin(est, mode="full", sched_policy=policy) \
            .simulate(spec, slots=3, requests=reqs).metrics
        assert legacy.n_starved_requests == fast.n_starved_requests
        assert legacy.starved_per_adapter == fast.starved_per_adapter
        assert legacy.throughput == fast.throughput, policy

"""Dry-run machinery units: HLO collective parsing, probe-depth math,
roofline terms, small-mesh compile of a reduced cell (subprocess)."""
import os
import subprocess
import sys

import pytest

from repro.configs import ARCH_IDS, get_config
from repro.launch.roofline import (CellCost, RooflineTerms, collective_bytes,
                                   model_flops_for)
from repro.models.config import SHAPES

HLO = """
HloModule test
%x.1 = bf16[128,256]{1,0} parameter(0)
%ag.2 = bf16[1024,256]{1,0} all-gather(%x.1), dimensions={0}
%ar.3 = f32[64]{0} all-reduce(%y.9), to_apply=%add
%y.9 = f32[64]{0} parameter(1)
%cp.4 = bf16[128,256]{1,0} collective-permute(%x.1), source_target_pairs={{0,1}}
%rs = f32[16]{0} reduce-scatter(%y.9), dimensions={0}
"""


def test_collective_bytes_parser():
    out = collective_bytes(HLO)
    assert out["all-gather"] == 128 * 256 * 2
    assert out["all-reduce"] == 64 * 4
    assert out["collective-permute"] == 128 * 256 * 2
    assert out["reduce-scatter"] == 64 * 4
    assert out["total"] == sum(
        v for k, v in out.items() if k != "total")


def test_probe_depths_exact_for_all_archs():
    from repro.launch.dryrun import probe_depths
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        pd = probe_depths(cfg)
        plen = len(cfg.block_pattern)
        # probe2 - probe1 == exactly one pattern repeat
        assert pd["probe2"] - pd["probe1"] == plen
        # extrapolation reconstructs the full depth
        assert pd["probe1"] + pd["extra_repeats"] * plen == cfg.n_layers


def test_cell_cost_extrapolation_linear():
    c1 = CellCost(flops=10.0, bytes_accessed=100.0,
                  coll={"all-gather": 4, "total": 4})
    c2 = CellCost(flops=16.0, bytes_accessed=130.0,
                  coll={"all-gather": 6, "total": 6})
    full = c1.extrapolate(c2, extra_repeats=10)
    assert full.flops == 10 + 10 * 6
    assert full.bytes_accessed == 100 + 10 * 30
    assert full.coll["all-gather"] == 4 + 10 * 2


def test_roofline_terms_and_bottleneck():
    cost = CellCost(flops=197e12, bytes_accessed=819e9 * 2,
                    coll={"total": 50e9 * 3})
    t = RooflineTerms.from_cost(cost, n_chips=4, model_flops=4 * 197e12)
    assert abs(t.compute_s - 1.0) < 1e-9
    assert abs(t.memory_s - 2.0) < 1e-9
    assert abs(t.collective_s - 3.0) < 1e-9
    assert t.bottleneck == "collective"
    assert 0 < t.roofline_fraction <= 1.0


def test_model_flops_positive_and_ordered():
    cfg = get_config("internlm2_20b")
    f_train = model_flops_for(cfg, SHAPES["train_4k"])
    f_dec = model_flops_for(cfg, SHAPES["decode_32k"])
    assert f_train > f_dec > 0


@pytest.mark.slow
def test_reduced_cell_compiles_on_small_mesh():
    """A reduced config lowers+compiles on a (2,2) placeholder mesh."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, "src")
import jax
from repro.configs import get_reduced
from repro.models.config import ShapeConfig
from repro.launch.mesh import _mk
from repro.launch.steps import build_step
cfg = get_reduced("gemma3_1b")
shape = ShapeConfig("t", 64, 4, "train")
mesh = _mk((2, 2), ("data", "model"))
b = build_step(cfg, shape, mesh, unroll=False)
c = jax.jit(b.fn, in_shardings=b.in_shardings, out_shardings=b.out_shardings,
            donate_argnums=b.donate_argnums).lower(*b.args).compile()
assert c.memory_analysis() is not None
print("compiled OK")
"""
    proc = subprocess.run([sys.executable, "-c", code], text=True,
                          capture_output=True, timeout=600,
                          cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "compiled OK" in proc.stdout

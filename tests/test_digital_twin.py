"""Digital Twin fidelity + estimator fitting (paper Table I left)."""
import numpy as np
import pytest

from repro.core import (DigitalTwin, WorkloadSpec, collect_benchmark,
                        collect_memmax, fit_estimators, generate_requests,
                        make_adapter_pool)
from repro.serving import (EngineConfig, HardwareProfile, ServingEngine,
                           SyntheticExecutor, smape)


@pytest.fixture(scope="module")
def fitted():
    profile = HardwareProfile()
    n, slots = 24, 12
    pool = make_adapter_pool(n, [8, 16, 32], [0.2, 0.1, 0.05])
    ranks = {a.uid: a.rank for a in pool}
    ex = SyntheticExecutor(profile, ranks, slots=slots, n_adapters=n, seed=0)
    est = fit_estimators(collect_benchmark(ex, slots, n, ranks),
                         collect_memmax(profile), slots, n)
    return profile, pool, ranks, est, slots


def test_estimator_recovery(fitted):
    """Fitted Eq.(1) constants recover the hidden profile within noise."""
    profile, _, _, est, _ = fitted
    assert abs(est.model[1] - profile.m1) / profile.m1 < 0.15
    assert abs(est.model[2] - profile.m2) / profile.m2 < 0.15
    assert abs(est.adapters[1] - profile.a1) < 0.002
    assert abs(est.load[1] - profile.load_cpu_per_rank) \
        / profile.load_cpu_per_rank < 0.2


def test_memmax_estimator_decreases_with_slots(fitted):
    *_, est, _ = fitted
    assert est.kv_capacity(8, 8) > est.kv_capacity(256, 32)


def _real_run(profile, pool, ranks, slots, spec, reqs):
    mean_rank = float(np.mean([a.rank for a in pool]))
    cfg = EngineConfig(
        kv_capacity_tokens=profile.kv_capacity(slots, mean_rank),
        adapter_slots=slots)
    eng = ServingEngine(cfg, SyntheticExecutor(
        profile, ranks, slots=slots, n_adapters=len(pool), seed=9))
    return eng.run(reqs, horizon=spec.horizon)


def test_dt_full_mode_close_to_real(fitted):
    profile, pool, ranks, est, slots = fitted
    spec = WorkloadSpec(adapters=pool, dataset="sharegpt", horizon=200.0,
                        seed=11)
    real = _real_run(profile, pool, ranks, slots, spec,
                     generate_requests(spec))
    dt = DigitalTwin(est, mode="full")
    sim = dt.simulate(spec, slots=slots,
                      requests=generate_requests(spec)).metrics
    assert smape(sim.throughput, real.throughput) < 3.0
    assert smape(sim.itl, real.itl) < 10.0


def test_dt_mean_mode_reasonable(fitted):
    profile, pool, ranks, est, slots = fitted
    spec = WorkloadSpec(adapters=pool, dataset="sharegpt", horizon=200.0,
                        seed=11)
    real = _real_run(profile, pool, ranks, slots, spec,
                     generate_requests(spec))
    sim = DigitalTwin(est, mode="mean").simulate(spec, slots=slots).metrics
    # paper: mean-mode throughput SMAPE ~5%, TTFT much worse (~18%)
    assert smape(sim.throughput, real.throughput) < 15.0
    assert smape(sim.itl, real.itl) < 20.0


def test_dt_requires_no_gpu_and_is_fast(fitted):
    _, pool, _, est, slots = fitted
    spec = WorkloadSpec(adapters=pool, dataset="medium", horizon=120.0)
    res = DigitalTwin(est, mode="mean").simulate(spec, slots=slots)
    # simulated 120s of serving in a tiny fraction of real time
    assert res.sim_wall_time < 30.0
    assert res.metrics.duration > 0


def test_dt_ideal_throughput_bound(fitted):
    """DT throughput never exceeds offered load by more than jitter."""
    _, pool, _, est, slots = fitted
    spec = WorkloadSpec(adapters=pool, dataset="medium", horizon=150.0)
    m = DigitalTwin(est, mode="mean").simulate(spec, slots=slots).metrics
    assert m.throughput <= 1.2 * m.ideal_throughput + 1.0

"""Chaos recovery: seeded fault storm, request reliability, twin replay.

The robustness figure (ours; no paper counterpart — the paper's Digital
Twin is only validated on healthy runs): a 3-replica fleet serves a
skewed workload while a seeded ``FaultPlan`` storm plays out — one
replica crash *with* recovery (snapshot/restore + Fig. 4 reload costs),
one adapter-load fault window on the hottest adapter, one straggler
window, one client disconnect.  Three acceptance claims are asserted:

* **zero lost requests** — with the reliability layer armed, every
  request in the stream reaches exactly one terminal state (finished,
  explicitly failed after the retry budget, or client-disconnected);
  nothing hangs and nothing double-counts;
* **retries earn their keep** — the retry arm finishes strictly more
  requests than the identical run with the retry budget set to zero;
* **the twin replays the storm bitwise** — the object-mode cluster
  (``ServingEngine`` replicas) and the Digital Twin (``FastEngine``
  replicas) agree exactly on finished/starved counts *and* on every
  fault counter, which is what makes faulted runs labelable
  training data.

Results land in ``BENCH_chaos_recovery.json`` at the repo root; the
committed copy is refreshed per PR so the reliability trajectory lives
in its git history.
"""
from __future__ import annotations

import json
from pathlib import Path

from .common import CsvOut, fitted_estimators, is_smoke
from repro.core import (ClusterDigitalTwin, WorkloadSpec, generate_requests,
                        make_adapter_pool)
from repro.serving import (AdapterLoadFault, ClientDisconnect, ClusterRouter,
                           FaultPlan, ReliabilityPolicy, ReplicaCrash,
                           StragglerWindow)

EXACT_FIELDS = ("n_finished", "n_starved_requests", "n_timeouts",
                "n_retries", "n_failed_requests", "n_load_faults",
                "n_loads", "n_preemptions", "throughput", "duration")


def config(smoke: bool) -> dict:
    if smoke:
        return dict(n_replicas=3, n_adapters=12, slots=4, horizon=40.0,
                    epoch=5.0, seed=3, timeout_s=8.0, max_retries=3)
    return dict(n_replicas=3, n_adapters=16, slots=4, horizon=60.0,
                epoch=5.0, seed=3, timeout_s=8.0, max_retries=3)


def storm(cfg: dict, pool, n_requests: int) -> FaultPlan:
    """The seeded storm: every fault class the layer supports, timed so
    the fleet has warm state to break (mid-horizon)."""
    h = cfg["horizon"]
    hot = max(pool, key=lambda a: a.rate).uid
    return FaultPlan(events=(
        ReplicaCrash(replica=1, at=0.3 * h, recover_at=0.55 * h),
        AdapterLoadFault(replica=0, adapter=hot, at=0.2 * h,
                         until=0.6 * h),
        StragglerWindow(replica=2, at=0.35 * h, until=0.65 * h,
                        factor=5.0),
        ClientDisconnect(at=0.25 * h, request_index=min(40,
                                                        n_requests - 1)),
    ), seed=cfg["seed"])


def run_arm(est, cfg: dict, reqs, spec, plan, max_retries: int,
            fast: bool):
    twin = ClusterDigitalTwin(est, mode="full", fast=fast)
    router = ClusterRouter(
        twin.specs_from_slots([cfg["slots"]] * cfg["n_replicas"],
                              mean_rank=12.0),
        policy="affinity")
    rel = ReliabilityPolicy(timeout_s=cfg["timeout_s"],
                            max_retries=max_retries)
    return twin.simulate_online(spec, router, requests=reqs,
                                epoch=cfg["epoch"], rebalance=True,
                                straggler_factor=3.0,
                                fault_plan=plan, reliability=rel)


def main(out: CsvOut) -> None:
    est = fitted_estimators()
    cfg = config(is_smoke())
    pool = make_adapter_pool(cfg["n_adapters"], [8, 16], [0.3, 0.1])
    spec = WorkloadSpec(adapters=pool, dataset="medium",
                        horizon=cfg["horizon"], seed=cfg["seed"])
    reqs = generate_requests(spec)
    plan = storm(cfg, pool, len(reqs))

    retry = run_arm(est, cfg, reqs, spec, plan, cfg["max_retries"],
                    fast=True)
    no_retry = run_arm(est, cfg, reqs, spec, plan, 0, fast=True)
    cluster = run_arm(est, cfg, reqs, spec, plan, cfg["max_retries"],
                      fast=False)

    for tag, res in (("retry", retry), ("no_retry", no_retry)):
        m, f = res.metrics, res.online.faults
        out.row(tag, 1.0,
                f"finished={m.n_finished};failed={m.n_failed_requests};"
                f"timeouts={f.n_timeouts};retries={f.n_retries};"
                f"crashes={f.n_crashes};recoveries={f.n_recoveries};"
                f"disconnects={f.n_disconnects};"
                f"breaker_opens={f.n_breaker_opens}")

    # --- the storm actually contained every fault class ----------------- #
    f = retry.online.faults
    if f.n_crashes < 1 or f.n_recoveries < 1:
        raise RuntimeError(f"storm lost its crash+recovery: {f.as_dict()}")
    if f.n_adapter_faults < 1:
        raise RuntimeError(f"storm lost its adapter-load fault: "
                           f"{f.as_dict()}")
    if not retry.online.straggler_epochs:
        raise RuntimeError("storm lost its straggler window: no epoch "
                           "flagged a straggling replica")
    if f.n_disconnects < 1:
        raise RuntimeError(f"storm lost its client disconnect: "
                           f"{f.as_dict()}")

    # --- zero lost requests on both arms --------------------------------- #
    for tag, res in (("retry", retry), ("no_retry", no_retry)):
        m, ff = res.metrics, res.online.faults
        terminal = m.n_finished + m.n_failed_requests + ff.n_disconnects
        if terminal != len(reqs):
            raise RuntimeError(
                f"{tag}: lost requests — {terminal} terminal of "
                f"{len(reqs)} submitted "
                f"(finished={m.n_finished}, failed={m.n_failed_requests},"
                f" disconnected={ff.n_disconnects})")

    # --- retries earn their keep ----------------------------------------- #
    if retry.metrics.n_finished <= no_retry.metrics.n_finished:
        raise RuntimeError(
            "retry arm finished no more than the no-retry arm: "
            f"{retry.metrics.n_finished} <= "
            f"{no_retry.metrics.n_finished}")

    # --- twin replays the cluster bitwise -------------------------------- #
    for field in EXACT_FIELDS:
        a = getattr(cluster.metrics, field)
        b = getattr(retry.metrics, field)
        if a != b:
            raise RuntimeError(
                f"twin diverged from the cluster on {field}: {a} != {b}")
    if cluster.online.faults.as_dict() != retry.online.faults.as_dict():
        raise RuntimeError(
            "twin fault counters diverged from the cluster: "
            f"{retry.online.faults.as_dict()} != "
            f"{cluster.online.faults.as_dict()}")
    out.row("twin_replay", 1.0, "bitwise=ok")

    payload = {
        "smoke": is_smoke(),
        "config": {k: cfg[k] for k in ("n_replicas", "n_adapters", "slots",
                                       "horizon", "timeout_s",
                                       "max_retries")},
        "n_requests": len(reqs),
        "storm": plan.summary(),
        "retry": {**{k: getattr(retry.metrics, k) for k in
                     ("n_finished", "n_failed_requests", "n_timeouts",
                      "n_retries")},
                  **retry.online.faults.as_dict()},
        "no_retry": {"n_finished": no_retry.metrics.n_finished,
                     "n_failed_requests":
                         no_retry.metrics.n_failed_requests},
        "retry_advantage": retry.metrics.n_finished
        - no_retry.metrics.n_finished,
        "twin_bitwise_match": True,
    }
    path = Path(__file__).resolve().parent.parent \
        / "BENCH_chaos_recovery.json"
    path.write_text(json.dumps(payload, indent=2) + "\n")

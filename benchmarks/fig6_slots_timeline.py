"""Paper Fig. 6: timewise running/waiting behaviour with 1 slot and 3
adapters under two rates — starvation at high rate, healthy at low rate."""
from __future__ import annotations


from .common import CsvOut, fitted_estimators
from repro.core import DigitalTwin, WorkloadSpec, make_adapter_pool


def main(out: CsvOut) -> None:
    est = fitted_estimators()
    for rate in (1.0, 0.1):
        pool = make_adapter_pool(3, [8], [rate])
        spec = WorkloadSpec(adapters=pool, dataset="medium", horizon=180.0,
                            seed=5)
        dt = DigitalTwin(est, mode="mean")
        res = dt.simulate(spec, slots=1)
        m = res.metrics
        out.row(f"rate{rate}_slots1", res.sim_wall_time * 1e6,
                f"thpt={m.throughput:.0f};ideal={m.ideal_throughput:.0f};"
                f"starved={int(m.starved)};max_kv={m.max_kv_used:.2f};"
                f"loads={m.n_loads}")

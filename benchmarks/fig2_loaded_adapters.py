"""Paper Fig. 2: max throughput & batch size vs number of LOADED adapters
(memory overhead).  Uses the Mem_max estimator + saturation workloads."""
from __future__ import annotations

from .common import CsvOut, fitted_estimators
from repro.core import DigitalTwin, WorkloadSpec, make_adapter_pool


def main(out: CsvOut) -> None:
    est = fitted_estimators()
    dt = DigitalTwin(est, mode="mean")
    for rank in (8, 32):
        for n_loaded in (8, 64, 192, 384):
            # slots == adapters (everything resident, as in the figure)
            pool = make_adapter_pool(n_loaded, [rank], [3.2])  # saturating
            spec = WorkloadSpec(adapters=pool, dataset="medium",
                                horizon=120.0, seed=1)
            res = dt.simulate(spec, slots=n_loaded)
            m = res.metrics
            cap = est.kv_capacity(n_loaded, rank)
            out.row(f"rank{rank}_loaded{n_loaded}",
                    res.sim_wall_time * 1e6,
                    f"thpt={m.throughput:.0f};kv_tokens={cap};"
                    f"starved={int(m.starved)}")

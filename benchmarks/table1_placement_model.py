"""Paper Table I (right): placement-model SMAPE (throughput / served
adapters / adapter slots) for linear vs tree models, + inference latency.

Train labels come from DT sweeps (99%); the held-out test labels come
from the REAL engine (the paper's 1% real-serving test)."""
from __future__ import annotations

import time

import numpy as np

from .common import CsvOut, fitted_estimators, run_real
from repro.core import (MODEL_ZOO, SweepRunner, WorkloadSpec,
                        label_scenarios, scenario_grid)
from repro.core.dataset import TARGET_NAMES, encode_features
from repro.serving import smape_vec


def _real_label(scenario, est, max_adapters=96, horizon=120.0):
    """Ground-truth placement measured on the REAL engine (not the DT),
    over the same (N, G) grid the DT labeller sweeps."""
    from repro.core.placement import default_slot_grid
    pool = scenario.pool(max_adapters)
    best = None
    n_grid = sorted({max(1, max_adapters // k) for k in (16, 8, 4, 3, 2)}
                    | {max_adapters})
    for n in n_grid:
        sub = pool[:n]
        for g in default_slot_grid(n):
            m = run_real(sub, scenario.dataset, horizon, g, seed=31)
            if not m.starved and (best is None
                                  or m.throughput > best[0]):
                best = (m.throughput, n, g)
    return best or (0.0, 1, 1)


def main(out: CsvOut, n_scenarios: int = 56, n_test: int = 6) -> None:
    est = fitted_estimators()
    scenarios = scenario_grid(limit=n_scenarios + n_test, seed=7)
    train_sc, test_sc = scenarios[:n_scenarios], scenarios[n_scenarios:]
    # DT labels through the parallel sweep harness (fast twin per point;
    # per-scenario seeds keep labels identical to the serial path)
    xs, ys, _ = label_scenarios(est, train_sc, max_adapters=96,
                                horizon=120.0, seed=7,
                                runner=SweepRunner(est))
    # real-engine test labels
    xt, yt = [], []
    for sc in test_sc:
        pool = sc.pool(96)
        spec = WorkloadSpec(adapters=pool, dataset=sc.dataset)
        xt.append(encode_features([a.rate for a in pool],
                                  [a.rank for a in pool],
                                  spec.length_stats()))
        yt.append(list(_real_label(sc, est)))
    xt, yt = np.asarray(xt), np.asarray(yt)

    for name in ("linear", "ridge", "tree", "forest"):
        model = MODEL_ZOO[name]()
        model.fit(xs, ys)
        t0 = time.perf_counter()
        pred = np.asarray(model.predict(xt))
        dt_us = (time.perf_counter() - t0) / max(len(xt), 1) * 1e6
        parts = [f"{TARGET_NAMES[j]}_smape="
                 f"{smape_vec(pred[:, j], yt[:, j]):.2f}"
                 for j in range(3)]
        out.row(name, dt_us, ";".join(parts))

"""Twin fast-path speed: legacy vs FastTwin steps/sec + sweep points/sec.

The paper's efficiency claim is that the Digital Twin makes training-data
generation cheap; this figure tracks how cheap.  One representative heavy
sweep point (96 adapters, ShareGPT-like lengths — the regime the
placement-model labellers live in) is simulated by the legacy object-mode
``DigitalTwin`` and by the struct-of-arrays ``FastTwin``; both runs must
agree exactly (the equivalence contract) and the fast path must be >=10x
cheaper locally (>=5x enforced in the CI smoke gate, which uses tiny
sizes where fixed overheads bite harder).  A small scenario batch is then
labelled through the ``SweepRunner`` to report end-to-end sweep
points/sec, and the real engine's steps/sec is recorded so the shared
scheduler micro-optimisations (swap-remove running set, O(1)
``can_load``) stay visible in the trajectory.

Results are written to ``BENCH_twin_speed.json`` at the repo root; the
committed copy is refreshed per PR, so the perf trajectory lives in its
git history.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

from .common import CsvOut, fitted_estimators, is_smoke, run_real
from repro.core import (DigitalTwin, FastTwin, SweepRunner, SweepTask,
                        WorkloadSpec, make_adapter_pool, scenario_grid)

MIN_SPEEDUP_SMOKE = 5.0       # CI gate (tiny sizes, noisy runners)
MIN_SPEEDUP_FULL = 10.0       # the local acceptance claim


def _best_of(fn, reps):
    best, result = None, None
    for _ in range(reps):
        t0 = time.perf_counter()
        result = fn()
        dt = time.perf_counter() - t0
        if best is None or dt < best:
            best = dt
    return best, result


def main(out: CsvOut) -> None:
    est = fitted_estimators()
    smoke = is_smoke()
    if smoke:
        n_ad, slots, horizon, reps = 48, 6, 60.0, 2
        rates = [0.3, 0.15, 0.1]     # slot-pressured: the sweep regime
        n_scen, sweep_horizon, workers = 2, 20.0, 2
    else:
        n_ad, slots, horizon, reps = 96, 16, 240.0, 3
        rates = [0.25, 0.1, 0.05]
        n_scen, sweep_horizon, workers = 6, 60.0, None

    # --- single-point twin speed (the unit of every sweep) -------------- #
    pool = make_adapter_pool(n_ad, [8, 16, 32], rates)
    spec = WorkloadSpec(adapters=pool, dataset="sharegpt", horizon=horizon,
                        seed=7)
    legacy = DigitalTwin(est, mode="mean")
    fast = FastTwin(est, mode="mean")
    t_legacy, res_l = _best_of(lambda: legacy.simulate(spec, slots=slots),
                               reps)
    t_fast, res_f = _best_of(lambda: fast.simulate(spec, slots=slots), reps)
    if res_l.metrics.throughput != res_f.metrics.throughput or \
            res_l.metrics.n_finished != res_f.metrics.n_finished:
        raise RuntimeError(
            "fast twin diverged from the legacy oracle: "
            f"{res_f.metrics.throughput} vs {res_l.metrics.throughput}")
    speedup = t_legacy / t_fast
    # simulated-seconds per wall-second: the figure's headline rate
    legacy_rate = res_l.metrics.duration / t_legacy
    fast_rate = res_f.metrics.duration / t_fast
    out.row("twin_legacy", t_legacy * 1e6,
            f"sim_s_per_s={legacy_rate:.0f}")
    out.row("twin_fast", t_fast * 1e6,
            f"sim_s_per_s={fast_rate:.0f};speedup={speedup:.1f}x")

    # --- sweep harness: labelled points/sec ----------------------------- #
    scenarios = scenario_grid(limit=n_scen, seed=13)
    tasks = [SweepTask(pool=tuple(sc.pool(max(n_ad // 2, 8))),
                       dataset=sc.dataset, horizon=sweep_horizon,
                       seed=17 + i)
             for i, sc in enumerate(scenarios)]
    runner = SweepRunner(est, n_workers=workers)
    t0 = time.perf_counter()
    results = runner.map(tasks)
    t_sweep = time.perf_counter() - t0
    pts_per_s = len(results) / t_sweep
    out.row("sweep_runner", t_sweep * 1e6,
            f"points={len(results)};points_per_s={pts_per_s:.2f}")

    # --- real engine step rate (shared scheduler micro-opts) ------------ #
    eng_pool = make_adapter_pool(max(n_ad // 2, 8), [8, 16], [0.2])
    t0 = time.perf_counter()
    m = run_real(eng_pool, "medium", horizon / 2, slots, seed=23)
    t_eng = time.perf_counter() - t0
    eng_rate = m.duration / t_eng
    out.row("engine_real", t_eng * 1e6, f"sim_s_per_s={eng_rate:.0f}")

    # --- persist the trajectory ----------------------------------------- #
    payload = {
        "smoke": smoke,
        "point": {"n_adapters": n_ad, "slots": slots, "horizon": horizon,
                  "dataset": "sharegpt"},
        "legacy_wall_s": round(t_legacy, 4),
        "fast_wall_s": round(t_fast, 4),
        "speedup": round(speedup, 2),
        "legacy_sim_s_per_s": round(legacy_rate, 1),
        "fast_sim_s_per_s": round(fast_rate, 1),
        "sweep_points": len(results),
        "sweep_points_per_s": round(pts_per_s, 3),
        "engine_sim_s_per_s": round(eng_rate, 1),
    }
    path = Path(__file__).resolve().parent.parent / "BENCH_twin_speed.json"
    path.write_text(json.dumps(payload, indent=2) + "\n")

    floor = MIN_SPEEDUP_SMOKE if smoke else MIN_SPEEDUP_FULL
    if speedup < floor:
        raise RuntimeError(
            f"fast twin speedup {speedup:.1f}x below the {floor:.0f}x "
            f"floor ({'smoke' if smoke else 'full'} config)")

"""Paper Table I (left): Digital Twin vs real system SMAPE for
throughput / ITL / TTFT, full and mean modes, across the paper's workload
grid (size distributions x rate distributions), + speedup & resources."""
from __future__ import annotations

import resource
import time

import numpy as np

from .common import CsvOut, fitted_estimators, run_real
from repro.core import DigitalTwin, WorkloadSpec, generate_requests, \
    make_adapter_pool
from repro.serving import smape

SIZE_DISTS = {"r8_16_32": [8, 16, 32], "r8_16": [8, 16]}
RATE_DISTS = {"high": [0.2, 0.1, 0.05], "low": [0.025, 0.0125, 0.00625]}


def main(out: CsvOut) -> None:
    est = fitted_estimators()
    horizon = 400.0
    n_adapters, slots = 48, 24
    smapes = {("full", k): [] for k in ("thpt", "itl", "ttft")}
    smapes.update({("mean", k): [] for k in ("thpt", "itl", "ttft")})
    sim_times, real_times = [], []
    for sname, ranks in SIZE_DISTS.items():
        for rname, rates in RATE_DISTS.items():
            pool = make_adapter_pool(n_adapters, ranks, rates)
            spec = WorkloadSpec(adapters=pool, dataset="sharegpt",
                                horizon=horizon, seed=13)
            t0 = time.perf_counter()
            real = run_real(pool, "sharegpt", horizon, slots, seed=13)
            real_times.append(time.perf_counter() - t0)
            for mode in ("full", "mean"):
                dt = DigitalTwin(est, mode=mode)
                res = dt.simulate(spec, slots=slots,
                                  requests=generate_requests(spec))
                sim_times.append(res.sim_wall_time)
                m = res.metrics
                smapes[(mode, "thpt")].append(smape(m.throughput,
                                                    real.throughput))
                smapes[(mode, "itl")].append(smape(m.itl, real.itl))
                smapes[(mode, "ttft")].append(smape(m.ttft, real.ttft))
                out.row(f"{sname}_{rname}_{mode}",
                        res.sim_wall_time * 1e6,
                        f"thpt_smape={smapes[(mode, 'thpt')][-1]:.2f};"
                        f"itl_smape={smapes[(mode, 'itl')][-1]:.2f};"
                        f"ttft_smape={smapes[(mode, 'ttft')][-1]:.2f}")
    for mode in ("full", "mean"):
        out.row(f"AGG_{mode}", float(np.mean(sim_times)) * 1e6,
                f"thpt_smape={np.mean(smapes[(mode, 'thpt')]):.2f};"
                f"itl_smape={np.mean(smapes[(mode, 'itl')]):.2f};"
                f"ttft_smape={np.mean(smapes[(mode, 'ttft')]):.2f}")
    speedup = horizon / max(np.mean(sim_times), 1e-9)
    rss_mb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024
    out.row("RESOURCES", float(np.mean(sim_times)) * 1e6,
            f"sim_speedup_vs_served_hour={speedup:.0f}x;"
            f"max_rss_mb={rss_mb:.0f};gpu_used=0")

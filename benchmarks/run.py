"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--only fig2,table1_dt]
"""
from __future__ import annotations

import argparse
import sys
import traceback

sys.path.insert(0, "src")

from .common import CsvOut  # noqa: E402

BENCHES = (
    "fig2_loaded_adapters",
    "fig3_unique_adapters",
    "fig4_loading",
    "fig5_placement_variability",
    "fig6_slots_timeline",
    "fig7_slots_and_dynamic",
    "fig9_scale_384",
    "table1_dt_accuracy",
    "table1_placement_model",
    "kernels_bench",
    "roofline_report",
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    only = [s.strip() for s in args.only.split(",") if s.strip()]
    print("name,us_per_call,derived")
    failures = 0
    for name in BENCHES:
        if only and not any(name.startswith(o) for o in only):
            continue
        mod = __import__(f"benchmarks.{name}", fromlist=["main"])
        out = CsvOut(name)
        try:
            mod.main(out)
            out.done()
        except Exception as e:
            failures += 1
            print(f"{name}/ERROR,0,{type(e).__name__}: {e}")
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

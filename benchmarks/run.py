"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--only fig2,table1_dt]

``--smoke`` is the CI path: every benchmark module is imported (so
scripts cannot silently rot) and a fast subset runs end-to-end with
tiny sizes (``REPRO_BENCH_SMOKE=1``, see ``common.is_smoke``).
"""
from __future__ import annotations

import argparse
import importlib
import os
import sys
import traceback

sys.path.insert(0, "src")

from .common import CsvOut  # noqa: E402

BENCHES = (
    "fig2_loaded_adapters",
    "fig3_unique_adapters",
    "fig4_loading",
    "fig5_placement_variability",
    "fig6_slots_timeline",
    "fig7_slots_and_dynamic",
    "fig9_scale_384",
    "fig_chaos_recovery",
    "fig_cluster_scaling",
    "fig_gateway_openloop",
    "fig_prefix_reuse",
    "fig_rebalancing",
    "fig_sched_policies",
    "fig_twin_speed",
    "table1_dt_accuracy",
    "table1_placement_model",
    "kernels_bench",
    "roofline_report",
)

# benchmarks cheap enough to execute end-to-end in the CI smoke gate
SMOKE_BENCHES = (
    "fig2_loaded_adapters",
    "fig4_loading",
    "fig_chaos_recovery",
    "fig_cluster_scaling",
    "fig_gateway_openloop",
    "fig_prefix_reuse",
    "fig_rebalancing",
    "fig_sched_policies",
    "fig_twin_speed",
    "kernels_bench",
    "roofline_report",
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--smoke", action="store_true",
                    help="import every benchmark, run the fast subset "
                         "with tiny sizes (CI gate)")
    args = ap.parse_args()
    only = [s.strip() for s in args.only.split(",") if s.strip()]
    if args.smoke:
        os.environ["REPRO_BENCH_SMOKE"] = "1"
    print("name,us_per_call,derived")
    failures = 0
    for name in BENCHES:
        if only and not any(name.startswith(o) for o in only):
            continue
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
        except Exception as e:
            failures += 1
            print(f"{name}/IMPORT_ERROR,0,{type(e).__name__}: {e}")
            traceback.print_exc(file=sys.stderr)
            if args.smoke:
                raise SystemExit(1)      # CI gate: fail loudly, immediately
            continue
        if not callable(getattr(mod, "main", None)):
            failures += 1
            print(f"{name}/NO_MAIN,0,missing main(out)")
            if args.smoke:
                raise SystemExit(1)
            continue
        if args.smoke and name not in SMOKE_BENCHES:
            print(f"{name}/IMPORT_OK,0,smoke-skipped")
            continue
        out = CsvOut(name)
        try:
            mod.main(out)
            out.done()
        except Exception as e:
            failures += 1
            print(f"{name}/ERROR,0,{type(e).__name__}: {e}")
            traceback.print_exc(file=sys.stderr)
            if args.smoke:
                raise SystemExit(1)      # CI gate: fail loudly, immediately
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

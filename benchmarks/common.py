"""Shared benchmark fixtures: hidden hardware profile ("the H100 node"),
fitted estimators (creation phase), CSV output helpers."""
from __future__ import annotations

import functools
import os
import sys
import time


def is_smoke() -> bool:
    """True when benchmarks run in the CI smoke path (tiny sizes)."""
    return os.environ.get("REPRO_BENCH_SMOKE", "") == "1"

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

from repro.core import (DigitalTwin, collect_benchmark, collect_memmax,  # noqa
                        fit_estimators, make_adapter_pool, WorkloadSpec,
                        generate_requests)
from repro.serving import (EngineConfig, HardwareProfile, ServingEngine,  # noqa
                           SyntheticExecutor)


@functools.lru_cache()
def profile() -> HardwareProfile:
    return HardwareProfile()


@functools.lru_cache()
def fitted_estimators(slots: int = 32, n_adapters: int = 96):
    p = profile()
    ranks = {i: (8, 16, 32)[i % 3] for i in range(n_adapters)}
    ex = SyntheticExecutor(p, ranks, slots=slots, n_adapters=n_adapters,
                           seed=0)
    rows = collect_benchmark(ex, slots, n_adapters, ranks)
    mem = collect_memmax(p)
    return fit_estimators(rows, mem, slots, n_adapters)


def run_real(pool, dataset, horizon, slots, seed=0):
    p = profile()
    ranks = {a.uid: a.rank for a in pool}
    mean_rank = float(np.mean([a.rank for a in pool])) if pool else 8.0
    spec = WorkloadSpec(adapters=pool, dataset=dataset, horizon=horizon,
                        seed=seed)
    reqs = generate_requests(spec)
    cfg = EngineConfig(
        kv_capacity_tokens=p.kv_capacity(slots, mean_rank),
        adapter_slots=slots)
    eng = ServingEngine(cfg, SyntheticExecutor(
        p, ranks, slots=slots, n_adapters=len(pool), seed=seed + 1))
    return eng.run(reqs, horizon=horizon)


class CsvOut:
    def __init__(self, name: str):
        self.name = name
        self.t0 = time.perf_counter()

    def row(self, label: str, us_per_call: float, derived: str = ""):
        print(f"{self.name}/{label},{us_per_call:.3f},{derived}")

    def done(self):
        dt = (time.perf_counter() - self.t0) * 1e6
        print(f"{self.name}/TOTAL,{dt:.0f},wall_us")

"""Paper Fig. 5: optimal placement (throughput-vs-#adapters curves) under
varying adapter sizes, rates and request output lengths."""
from __future__ import annotations

from .common import CsvOut, fitted_estimators
from repro.core import find_optimal_placement, make_adapter_pool


def main(out: CsvOut) -> None:
    est = fitted_estimators()
    # vary rank
    for rank in (8, 16, 32):
        res = find_optimal_placement(
            est, make_adapter_pool(192, [rank], [0.05]), "medium",
            horizon=120.0)
        out.row(f"rank{rank}", 1.0,
                f"opt_adapters={res.n_adapters};opt_slots={res.slots};"
                f"thpt={res.throughput:.0f}")
    # vary rate
    for rate in (0.0125, 0.05, 0.4, 1.6):
        res = find_optimal_placement(
            est, make_adapter_pool(256, [8], [rate]), "medium",
            horizon=120.0)
        out.row(f"rate{rate}", 1.0,
                f"opt_adapters={res.n_adapters};opt_slots={res.slots};"
                f"thpt={res.throughput:.0f}")
    # vary output length (dataset)
    for ds in ("small", "medium", "large"):
        res = find_optimal_placement(
            est, make_adapter_pool(192, [8], [0.05]), ds, horizon=120.0)
        out.row(f"dataset_{ds}", 1.0,
                f"opt_adapters={res.n_adapters};opt_slots={res.slots};"
                f"thpt={res.throughput:.0f}")

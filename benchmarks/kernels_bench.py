"""Kernel-layer microbenchmarks: BGMV / SGMV / flash-decode XLA-fallback
wall time on CPU + analytical VMEM footprints of the Pallas tilings
(the TPU target is compile-time validated by the dry-run)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from .common import CsvOut
from repro.kernels import ops, ref


def _time(fn, *args, reps=5):
    # warm up exactly once and block on that output (block_until_ready
    # handles pytrees, tuples included)
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def main(out: CsvOut) -> None:
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    # BGMV decode shapes (B tokens, one adapter each)
    for (t, d, r, o, n) in [(32, 2048, 16, 2048, 32),
                            (128, 3072, 16, 3072, 32)]:
        x = jax.random.normal(ks[0], (t, d), jnp.bfloat16)
        a = jax.random.normal(ks[1], (n, d, r), jnp.bfloat16)
        b = jax.random.normal(ks[2], (n, r, o), jnp.bfloat16)
        idx = jax.random.randint(ks[3], (t,), 0, n)
        f = jax.jit(lambda x, a, b, i: ops.lora_apply(x, a, b, i))
        us = _time(f, x, a, b, idx)
        vmem_kb = (d * r + r * o + d + o) * 2 / 1024
        out.row(f"bgmv_t{t}_d{d}", us, f"vmem_per_step_kb={vmem_kb:.0f}")
    # SGMV prefill shapes
    for (t, d, r, o, n) in [(4096, 2048, 16, 2048, 32)]:
        x = jax.random.normal(ks[0], (t, d), jnp.bfloat16)
        a = jax.random.normal(ks[1], (n, d, r), jnp.bfloat16)
        b = jax.random.normal(ks[2], (n, r, o), jnp.bfloat16)
        idx = jax.random.randint(ks[3], (t,), 0, n)
        f = jax.jit(lambda x, a, b, i: ref.lora_ref_bucketed(x, a, b, i))
        us = _time(f, x, a, b, idx)
        vmem_kb = (128 * d + d * r + r * o + 128 * o) * 2 / 1024
        out.row(f"sgmv_t{t}_d{d}", us, f"vmem_per_tile_kb={vmem_kb:.0f}")
    # flash decode
    for (b, h, kv, d, s) in [(8, 32, 8, 128, 4096)]:
        q = jax.random.normal(ks[0], (b, h, d), jnp.bfloat16)
        k = jax.random.normal(ks[1], (b, s, kv, d), jnp.bfloat16)
        v = jax.random.normal(ks[2], (b, s, kv, d), jnp.bfloat16)
        f = jax.jit(lambda q, k, v: ops.flash_decode(q, k, v, s))
        us = _time(f, q, k, v)
        vmem_kb = (512 * kv * d * 2 * 2 + h * d * 4) / 1024
        out.row(f"flashdec_b{b}_s{s}", us,
                f"vmem_per_block_kb={vmem_kb:.0f}")

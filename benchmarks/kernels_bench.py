"""Kernel-layer microbenchmarks + the twin's kernel measurement mode.

Three jobs:

1. **Microbenchmarks** — BGMV / SGMV / flash-decode / fused-decode wall
   time on the XLA fallback path (CPU; the TPU target is compile-time
   validated by the dry-run), with analytical VMEM footprints of the
   Pallas tilings.  The fused-vs-unfused arms time one fused
   ``ops.fused_decode`` launch against the base-then-adapter sequence
   (``ops.flash_decode`` + ``ops.lora_apply`` + add) at the same shape.

2. **Stable timing** — ``_time`` warms up, then takes min-of-k round
   means and reports the coefficient of variation across rounds.
   Rounds polluted by thermal/background noise (CV above the gate) are
   re-measured with the slowest round dropped, so fitted step-time
   coefficients are stable across runs; the CV is printed in the derived
   column so instability is visible in CI logs.

3. **Measurement mode** — ``collect_kernel_rows`` runs the fused decode
   kernel over a per-(rank, batch, seq) grid (plus SGMV prefill and
   unique-adapter arms) and ``measured_step_times`` fits the rows into a
   ``repro.core.MeasuredStepTimes`` surface, the opt-in
   ``measured_step_times=`` hook on the twin/placement path — closing
   the loop from real kernel costs back to Eq. (1).
"""
from __future__ import annotations

import dataclasses
import statistics
import time

import jax
import jax.numpy as jnp

from .common import CsvOut, is_smoke
from repro.core import MeasuredStepTimes, fit_measured_step_times
from repro.kernels import ops


@dataclasses.dataclass
class Timing:
    us: float            # min-of-k per-launch wall time (microseconds)
    cv: float            # coefficient of variation across kept rounds
    rejected: int        # rounds discarded by the CV gate

    @property
    def derived(self) -> str:
        return f"cv={self.cv:.3f};rejected_rounds={self.rejected}"


def _time(fn, *args, reps: int = 5, rounds: int = 3, cv_gate: float = 0.30,
          max_rounds: int = 8) -> Timing:
    """Per-launch wall time, robust to thermally-polluted samples.

    Warms up exactly once and blocks on the real output
    (``block_until_ready`` handles pytrees, tuples included).  Then takes
    ``rounds`` rounds of ``reps`` launches each; if the coefficient of
    variation of the round means exceeds ``cv_gate``, the slowest round
    (the thermally-polluted one — pollution is one-sided) is dropped and
    a fresh round is measured, up to ``max_rounds`` total.  Returns the
    **min** of the kept round means (the least-disturbed estimate — the
    right statistic for fitting step-time coefficients) plus the final
    CV and the number of rejected rounds.
    """
    jax.block_until_ready(fn(*args))

    def one_round() -> float:
        t0 = time.perf_counter()
        for _ in range(reps):
            jax.block_until_ready(fn(*args))
        return (time.perf_counter() - t0) / reps * 1e6

    means = [one_round() for _ in range(rounds)]
    rejected = 0
    budget = max_rounds - rounds

    def cv(xs) -> float:
        m = statistics.fmean(xs)
        return (statistics.pstdev(xs) / m) if m > 0 else 0.0

    while len(means) >= 2 and cv(means) > cv_gate and budget > 0:
        means.remove(max(means))
        means.append(one_round())
        rejected += 1
        budget -= 1
    return Timing(us=min(means), cv=cv(means), rejected=rejected)


# --------------------------------------------------------------------- #
# measurement mode: kernel launches -> MeasuredStepTimes rows
# --------------------------------------------------------------------- #

def _decode_data(key, bsz, s, rank, n, h=8, kv=2, d=64, dx=128,
                 dtype=jnp.bfloat16):
    ks = jax.random.split(key, 6)
    q = jax.random.normal(ks[0], (bsz, h, d), dtype)
    k = jax.random.normal(ks[1], (bsz, s, kv, d), dtype)
    v = jax.random.normal(ks[2], (bsz, s, kv, d), dtype)
    x = jax.random.normal(ks[3], (bsz, dx), dtype)
    a = jax.random.normal(ks[4], (n, dx, rank), dtype)
    b = jax.random.normal(ks[5], (n, rank, h * d), dtype)
    idx = jax.random.randint(ks[0], (bsz,), 0, n)
    return q, k, v, x, a, b, idx


def collect_kernel_rows(mode: str = "ref", smoke: bool | None = None,
                        seed: int = 0) -> list:
    """Run the kernels over a per-(rank, batch, seq) grid; return fit rows.

    ``mode`` is the ops dispatch override ('ref' on CPU is the XLA
    fallback — same math, honest relative costs; 'pallas' on TPU times
    the real kernels).  Rows feed ``fit_measured_step_times``.
    """
    if smoke is None:
        smoke = is_smoke()
    key = jax.random.PRNGKey(seed)
    if smoke:
        b_grid, s_grid, r_grid = (1, 4), (128, 256), (8, 16)
        pf_grid, a_grid = (256, 512), (1, 2, 4)
        reps, rounds = 2, 2
    else:
        b_grid, s_grid, r_grid = (1, 8, 32), (256, 1024, 4096), (8, 16, 32)
        pf_grid, a_grid = (512, 2048, 4096), (1, 2, 8, 32)
        reps, rounds = 5, 3
    rows = []

    # decode surface: one fused launch per (batch, seq, rank) point
    for bsz in b_grid:
        for s in s_grid:
            for rank in r_grid:
                q, k, v, x, a, b, idx = _decode_data(key, bsz, s, rank,
                                                     n=max(a_grid))
                f = jax.jit(lambda q, k, v, x, a, b, i, _s=s: ops.fused_decode(
                    q, k, v, _s, x, a, b, i, force=mode))
                t = _time(f, q, k, v, x, a, b, idx, reps=reps,
                          rounds=rounds)
                rows.append(dict(kind="decode", batch=bsz, seq=s,
                                 rank=rank, t=t.us * 1e-6, cv=t.cv))

    # prefill: SGMV launch cost per token count
    for tokens in pf_grid:
        d, rank, o, n = 128, 16, 128, max(a_grid)
        ks = jax.random.split(key, 4)
        x = jax.random.normal(ks[0], (tokens, d), jnp.bfloat16)
        a = jax.random.normal(ks[1], (n, d, rank), jnp.bfloat16)
        b = jax.random.normal(ks[2], (n, rank, o), jnp.bfloat16)
        it = jax.random.randint(ks[3], (tokens,), 0, n)
        f = jax.jit(lambda x, a, b, i: ops.lora_apply(x, a, b, i,
                                                      force=mode))
        t = _time(f, x, a, b, it, reps=reps, rounds=rounds)
        rows.append(dict(kind="prefill", tokens=tokens, t=t.us * 1e-6,
                         cv=t.cv))

    # unique-adapter multiplier: same shape, growing distinct adapters
    bsz, s, rank = max(b_grid), max(s_grid), 16
    base_t = None
    for a_unique in a_grid:
        q, k, v, x, a, b, _ = _decode_data(key, bsz, s, rank,
                                           n=max(a_grid))
        idx = jnp.arange(bsz, dtype=jnp.int32) % a_unique
        f = jax.jit(lambda q, k, v, x, a, b, i, _s=s: ops.fused_decode(
            q, k, v, _s, x, a, b, i, force=mode))
        t = _time(f, q, k, v, x, a, b, idx, reps=reps, rounds=rounds)
        if base_t is None:
            base_t = t.us
        rows.append(dict(kind="adapters", a_unique=a_unique,
                         mult=t.us / base_t, cv=t.cv))
    return rows


def measured_step_times(mode: str = "ref", smoke: bool | None = None,
                        seed: int = 0) -> MeasuredStepTimes:
    """One-call measurement mode: kernel launches -> fitted surface for
    the twin's ``measured_step_times=`` hook."""
    rows = collect_kernel_rows(mode=mode, smoke=smoke, seed=seed)
    seqs = [r["seq"] for r in rows if r["kind"] == "decode"]
    ranks = [r["rank"] for r in rows if r["kind"] == "decode"]
    return fit_measured_step_times(
        rows, mean_seq=statistics.fmean(seqs),
        mean_rank=statistics.fmean(ranks))


# --------------------------------------------------------------------- #
# the benchmark
# --------------------------------------------------------------------- #

def main(out: CsvOut) -> None:
    smoke = is_smoke()
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)

    # BGMV decode shapes (B tokens, one adapter each)
    bgmv_shapes = [(8, 256, 16, 256, 8)] if smoke else \
        [(32, 2048, 16, 2048, 32), (128, 3072, 16, 3072, 32)]
    for (t, d, r, o, n) in bgmv_shapes:
        x = jax.random.normal(ks[0], (t, d), jnp.bfloat16)
        a = jax.random.normal(ks[1], (n, d, r), jnp.bfloat16)
        b = jax.random.normal(ks[2], (n, r, o), jnp.bfloat16)
        idx = jax.random.randint(ks[3], (t,), 0, n)
        f = jax.jit(lambda x, a, b, i: ops.lora_apply(x, a, b, i))
        tm = _time(f, x, a, b, idx)
        vmem_kb = (d * r + r * o + d + o) * 2 / 1024
        out.row(f"bgmv_t{t}_d{d}", tm.us,
                f"vmem_per_step_kb={vmem_kb:.0f};{tm.derived}")

    # SGMV prefill shapes — dense and ragged-rank arms
    sgmv_shapes = [(512, 256, 16, 256, 8)] if smoke else \
        [(4096, 2048, 16, 2048, 32)]
    for (t, d, r, o, n) in sgmv_shapes:
        x = jax.random.normal(ks[0], (t, d), jnp.bfloat16)
        a = jax.random.normal(ks[1], (n, d, r), jnp.bfloat16)
        b = jax.random.normal(ks[2], (n, r, o), jnp.bfloat16)
        idx = jax.random.randint(ks[3], (t,), 0, n)
        ranks = (jnp.arange(n, dtype=jnp.int32) % 3 + 1) * (r // 4)
        f = jax.jit(lambda x, a, b, i: ops.lora_apply(x, a, b, i))
        tm = _time(f, x, a, b, idx)
        vmem_kb = (128 * d + d * r + r * o + 128 * o) * 2 / 1024
        out.row(f"sgmv_t{t}_d{d}", tm.us,
                f"vmem_per_tile_kb={vmem_kb:.0f};{tm.derived}")
        fr = jax.jit(lambda x, a, b, i, rk: ops.lora_apply(x, a, b, i,
                                                           ranks=rk))
        tr = _time(fr, x, a, b, idx, ranks)
        out.row(f"sgmv_ragged_t{t}_d{d}", tr.us,
                f"ranks<=r_max={r};{tr.derived}")

    # flash decode + the fused-vs-unfused arms
    fd_shapes = [(4, 8, 2, 64, 512, 128, 16, 8)] if smoke else \
        [(8, 32, 8, 128, 4096, 4096, 16, 32),
         (32, 32, 8, 128, 2048, 4096, 16, 32)]
    for (b, h, kv, d, s, dx, r, n) in fd_shapes:
        q = jax.random.normal(ks[0], (b, h, d), jnp.bfloat16)
        k = jax.random.normal(ks[1], (b, s, kv, d), jnp.bfloat16)
        v = jax.random.normal(ks[2], (b, s, kv, d), jnp.bfloat16)
        x = jax.random.normal(ks[3], (b, dx), jnp.bfloat16)
        aw = jax.random.normal(ks[1], (n, dx, r), jnp.bfloat16)
        bw = jax.random.normal(ks[2], (n, r, h * d), jnp.bfloat16)
        idx = jax.random.randint(ks[3], (b,), 0, n)

        f_attn = jax.jit(lambda q, k, v: ops.flash_decode(q, k, v, s))
        t_attn = _time(f_attn, q, k, v)
        vmem_kb = (512 * kv * d * 2 * 2 + h * d * 4) / 1024
        out.row(f"flashdec_b{b}_s{s}", t_attn.us,
                f"vmem_per_block_kb={vmem_kb:.0f};{t_attn.derived}")

        # unfused: base attention, separate LoRA launch, add-back
        def unfused(q, k, v, x, aw, bw, i, _s=s, _b=b, _h=h, _d=d):
            attn = ops.flash_decode(q, k, v, _s)
            delta = ops.lora_apply(x, aw, bw, i)
            return attn + delta.reshape(_b, _h, _d).astype(attn.dtype)
        t_unf = _time(jax.jit(unfused), q, k, v, x, aw, bw, idx)
        out.row(f"decode_unfused_b{b}_s{s}", t_unf.us, t_unf.derived)

        f_fused = jax.jit(lambda q, k, v, x, aw, bw, i, _s=s:
                          ops.fused_decode(q, k, v, _s, x, aw, bw, i))
        t_fus = _time(f_fused, q, k, v, x, aw, bw, idx)
        out.row(f"decode_fused_b{b}_s{s}", t_fus.us,
                f"vs_unfused={t_unf.us / max(t_fus.us, 1e-9):.2f}x;"
                f"{t_fus.derived}")

    # measurement mode: fit the MeasuredStepTimes surface from real
    # launches and print the coefficients (the twin hook's input)
    mst = measured_step_times(smoke=smoke)
    c = mst.decode
    out.row("measured_fit_decode", c[0] * 1e6,
            f"cB_us={c[1] * 1e6:.3f};cBS_ns={c[2] * 1e9:.4f};"
            f"cBr_us={c[3] * 1e6:.4f};"
            f"prefill_us_per_tok={mst.prefill_per_token * 1e6:.4f};"
            f"adapter_mult_slope={mst.adapters[1]:.4f}")

"""Paper Fig. 9: DT vs real at full scale — 384 adapters (ranks 8/16),
sweeping adapter slots and rates; throughput/ITL/TTFT SMAPE per point."""
from __future__ import annotations

from .common import CsvOut, fitted_estimators, run_real
from repro.core import DigitalTwin, WorkloadSpec, generate_requests, \
    make_adapter_pool
from repro.serving import smape


def main(out: CsvOut) -> None:
    est = fitted_estimators()
    n = 384
    horizon = 200.0
    for rates, tag in (([0.05, 0.025], "hi"), ([0.0125, 0.00625], "lo")):
        pool = make_adapter_pool(n, [8, 16], rates)
        spec = WorkloadSpec(adapters=pool, dataset="sharegpt",
                            horizon=horizon, seed=17)
        for slots in (48, 192, 384):
            real = run_real(pool, "sharegpt", horizon, slots, seed=17)
            sim = DigitalTwin(est, mode="full").simulate(
                spec, slots=slots,
                requests=generate_requests(spec)).metrics
            out.row(f"{tag}_slots{slots}", 1.0,
                    f"thpt_smape={smape(sim.throughput, real.throughput):.2f};"
                    f"itl_smape={smape(sim.itl, real.itl):.2f};"
                    f"ttft_smape={smape(sim.ttft, real.ttft):.2f};"
                    f"real_thpt={real.throughput:.0f}")

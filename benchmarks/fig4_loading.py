"""Paper Fig. 4: adapter loading time relative to request latency, by
adapter size and storage tier (CPU vs disk)."""
from __future__ import annotations

from .common import CsvOut, fitted_estimators
from repro.core.workload import DATASETS


def main(out: CsvOut) -> None:
    est = fitted_estimators()
    for dataset, (_, out_len) in DATASETS.items():
        tpot = est.lat_model(1) * est.lat_adapters(1)
        req_latency = tpot * max(out_len - 1, 1)
        for rank in (8, 16, 32):
            for loc in ("cpu", "disk"):
                t_load = est.lat_load(rank, loc)
                rel = 100.0 * t_load / req_latency
                out.row(f"{dataset}_rank{rank}_{loc}", t_load * 1e6,
                        f"rel_latency_pct={rel:.2f}")

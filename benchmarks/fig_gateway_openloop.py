"""Open-loop gateway vs closed-loop engine under the same offered load.

Ours (no paper counterpart — the paper's pipeline is closed-loop; this
figure gates the async serving front-end, ROADMAP item 1): the same
overloaded multi-adapter trace is served three ways on identical
engines:

* ``closed``    — ``ServingEngine.run`` (every request exists up front);
* ``open``      — the ``AsyncGateway`` driven by the trace replayed as
                  open-loop arrivals, admission control off.  This arm
                  doubles as the determinism guard: its end-state
                  metrics must match ``closed`` exactly;
* ``admission`` — the same gateway with the fitted-estimator admission
                  controller armed.  Shedding keeps the queue bounded,
                  so requests that do get admitted reach their first
                  token — the acceptance gate is strictly fewer starved
                  requests than the no-admission arm (which must starve,
                  or the overload point is vacuous).

All three arms stop at the same virtual horizon without draining (an
overloaded open-loop system never drains; a drained run cannot starve).
"""
from __future__ import annotations

import asyncio

from .common import CsvOut, fitted_estimators, is_smoke, profile
from repro.core import (WorkloadSpec, generate_requests, make_adapter_pool,
                        replay_trace)
from repro.serving import (AsyncGateway, EngineConfig, ServingEngine,
                           ServingMetrics, SyntheticExecutor,
                           estimator_admission)


def gateway_config(smoke: bool) -> dict:
    if smoke:
        return dict(n_adapters=12, slots=4, max_running=24, rate=2.0,
                    horizon=15.0, slo_budget=40.0, seed=5)
    return dict(n_adapters=16, slots=4, max_running=24, rate=2.0,
                horizon=40.0, slo_budget=60.0, seed=11)


def build_engine(cfg: dict) -> ServingEngine:
    p = profile()
    ranks = {i: 8 for i in range(cfg["n_adapters"])}
    ex = SyntheticExecutor(p, ranks, slots=cfg["slots"],
                          n_adapters=cfg["n_adapters"], seed=cfg["seed"])
    return ServingEngine(EngineConfig(
        kv_capacity_tokens=p.kv_capacity(cfg["slots"], 8),
        adapter_slots=cfg["slots"], max_running=cfg["max_running"]),
        ex)


def fmt(m: ServingMetrics, extra: str = "") -> str:
    return (f"thpt={m.throughput:.0f};finished={m.n_finished};"
            f"starved_reqs={m.n_starved_requests};"
            f"ttft_p50={m.ttft_p50 * 1e3:.0f}ms;"
            f"ttft_p99={m.ttft_p99 * 1e3:.0f}ms" + extra)


def main(out: CsvOut) -> None:
    cfg = gateway_config(is_smoke())
    pool = make_adapter_pool(cfg["n_adapters"], [8], [cfg["rate"]])
    spec = WorkloadSpec(adapters=pool, dataset="medium",
                        horizon=cfg["horizon"], seed=cfg["seed"])
    trace = generate_requests(spec)
    horizon = cfg["horizon"]

    closed = build_engine(cfg).run(list(replay_trace(trace)),
                                   horizon=horizon)
    out.row("closed", 1.0, fmt(closed))

    gw_open = AsyncGateway(build_engine(cfg))
    open_rep = asyncio.run(gw_open.run(replay_trace(trace),
                                       duration=horizon, drain=False))
    out.row("open", 1.0, fmt(open_rep.serving))

    adm = estimator_admission(fitted_estimators(), spec.length_stats(),
                              cfg["slo_budget"])
    gw_adm = AsyncGateway(build_engine(cfg), admission=adm)
    adm_rep = asyncio.run(gw_adm.run(replay_trace(trace),
                                     duration=horizon, drain=False))
    out.row("admission", 1.0,
            fmt(adm_rep.serving,
                f";rejected={adm_rep.gateway.n_rejected}"))

    # determinism guard: the no-admission gateway is the closed loop
    if (open_rep.serving.n_finished != closed.n_finished
            or open_rep.serving.n_starved_requests
            != closed.n_starved_requests
            or sorted(open_rep.serving.ttft_samples)
            != sorted(closed.ttft_samples)):
        raise RuntimeError(
            "open-loop gateway diverged from the closed-loop engine on "
            f"the same trace: finished {open_rep.serving.n_finished} vs "
            f"{closed.n_finished}, starved "
            f"{open_rep.serving.n_starved_requests} vs "
            f"{closed.n_starved_requests}")
    # acceptance gate: admission control must shed, not just reject
    if open_rep.serving.n_starved_requests == 0:
        raise RuntimeError("overload point did not starve without "
                           "admission control — the comparison is "
                           "vacuous")
    if adm_rep.gateway.n_rejected == 0:
        raise RuntimeError("admission controller never rejected at "
                           "overload — budget is too loose")
    if (adm_rep.serving.n_starved_requests
            >= open_rep.serving.n_starved_requests):
        raise RuntimeError(
            "admission control did not reduce starvation: "
            f"{adm_rep.serving.n_starved_requests} >= "
            f"{open_rep.serving.n_starved_requests} starved requests")

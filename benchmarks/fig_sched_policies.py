"""Scheduling-policy frontier: throughput vs starvation under skew.

Ours (no paper counterpart — the paper fixes vLLM's FCFS scheduler; this
figure is why the reproduction grew a policy axis): the same
rotating-hot-phase skewed workload under slot pressure is served once
per registered scheduling policy, and each row reports the two
quantities a policy trades between — aggregate throughput and
request-level starvation (arrived but never got a first token), plus
the TTFT tail.

The acceptance gate: ``adapter-fair`` (deficit round-robin) must starve
strictly fewer requests than ``fcfs`` on the skewed point — admission
ordering, not placement, decides which adapters ever see a slot in this
regime.
"""
from __future__ import annotations

from typing import Dict

from .common import CsvOut, fitted_estimators, is_smoke
from repro.core import (FastTwin, WorkloadSpec, generate_drifting_requests,
                        make_adapter_pool, rotating_hot_phases)
from repro.serving import SCHED_POLICIES, ServingMetrics


def sched_config(smoke: bool) -> dict:
    if smoke:
        return dict(n_adapters=24, slots=3, max_running=32, horizon=60.0,
                    n_phases=2, hot_fraction=0.2, hot_rate=1.8,
                    cold_rate=0.05, seed=3)
    return dict(n_adapters=24, slots=3, max_running=32, horizon=90.0,
                n_phases=3, hot_fraction=0.2, hot_rate=1.8,
                cold_rate=0.05, seed=7)


def run_policy(est, policy: str, cfg: dict) -> ServingMetrics:
    pool = make_adapter_pool(cfg["n_adapters"], [8, 16],
                             [cfg["cold_rate"]])
    phases = rotating_hot_phases(pool, cfg["horizon"],
                                 n_phases=cfg["n_phases"],
                                 hot_fraction=cfg["hot_fraction"],
                                 hot_rate=cfg["hot_rate"],
                                 cold_rate=cfg["cold_rate"])
    reqs = generate_drifting_requests(pool, "medium", cfg["horizon"],
                                      phases, seed=cfg["seed"])
    spec = WorkloadSpec(adapters=pool, dataset="medium",
                        horizon=cfg["horizon"], seed=cfg["seed"])
    twin = FastTwin(est, mode="full", max_running=cfg["max_running"],
                    sched_policy=policy)
    return twin.simulate(spec, slots=cfg["slots"], requests=reqs).metrics


def main(out: CsvOut) -> None:
    est = fitted_estimators()
    cfg = sched_config(is_smoke())
    results: Dict[str, ServingMetrics] = {}
    for policy in sorted(SCHED_POLICIES):
        m = run_policy(est, policy, cfg)
        results[policy] = m
        worst = max(m.starved_per_adapter.values(), default=0)
        out.row(policy, 1.0,
                f"thpt={m.throughput:.0f};ideal={m.ideal_throughput:.0f};"
                f"starved_reqs={m.n_starved_requests};"
                f"starved_adapters={len(m.starved_per_adapter)};"
                f"worst_adapter={worst};finished={m.n_finished};"
                f"ttft_p50={m.ttft_p50 * 1e3:.0f}ms;"
                f"ttft_p99={m.ttft_p99 * 1e3:.0f}ms")

    fcfs, fair = results["fcfs"], results["adapter-fair"]
    if fcfs.n_starved_requests == 0:
        raise RuntimeError("skewed point did not starve under fcfs — the "
                           "frontier comparison is vacuous")
    if fair.n_starved_requests >= fcfs.n_starved_requests:
        raise RuntimeError(
            "adapter-fair did not reduce starvation vs fcfs on the skewed "
            f"point: {fair.n_starved_requests} >= "
            f"{fcfs.n_starved_requests} starved requests")

"""Paper Fig. 3: throughput & ITL vs number of UNIQUE adapters in the
running batch (compute overhead Lat_adapters)."""
from __future__ import annotations

from .common import CsvOut, fitted_estimators


def main(out: CsvOut) -> None:
    est = fitted_estimators()
    r_run = 64
    base = est.lat_model(r_run) * est.lat_adapters(0)
    for a in (0, 1, 2, 4, 8, 16, 32, 64):
        lat = est.lat_model(r_run) * est.lat_adapters(min(a, r_run))
        thpt = r_run / lat
        itl = lat
        out.row(f"unique{a}", lat * 1e6,
                f"thpt={thpt:.0f};itl_ms={itl * 1e3:.2f};"
                f"slowdown={lat / base:.3f}")

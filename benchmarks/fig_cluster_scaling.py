"""Cluster scaling sweep: replicas x adapters x rate x routing policy.

For each point the ClusterDigitalTwin reports aggregate throughput, the
starvation boundary and total adapter loads — showing (a) near-linear
throughput scaling with replicas until the per-replica starvation
boundary, and (b) affinity routing beating round-robin on adapter-load
count once adapters outnumber per-replica slots.
"""
from __future__ import annotations

from .common import CsvOut, fitted_estimators, is_smoke
from repro.core import ClusterDigitalTwin, WorkloadSpec, make_adapter_pool
from repro.serving import ClusterRouter

POLICIES = ("affinity", "least-loaded", "round-robin")


def main(out: CsvOut) -> None:
    est = fitted_estimators()
    # fast=True: replicas run on the struct-of-arrays FastEngine (same
    # metrics as the object-mode engines, ~10x cheaper per point)
    twin = ClusterDigitalTwin(est, mode="mean", fast=True)
    if is_smoke():
        reps_grid, ad_grid, rate_grid, horizon = (1, 2), (16,), (0.1,), 40.0
    else:
        reps_grid, ad_grid, rate_grid, horizon = \
            (1, 2, 4), (32, 96), (0.05, 0.15), 150.0
    for n_rep in reps_grid:
        for n_ad in ad_grid:
            for rate in rate_grid:
                pool = make_adapter_pool(n_ad, [8, 16], [rate])
                mean_rank = sum(a.rank for a in pool) / len(pool)
                spec = WorkloadSpec(adapters=pool, dataset="medium",
                                    horizon=horizon, seed=5)
                slots = max(n_ad // (4 * n_rep), 2)
                for policy in POLICIES:
                    router = ClusterRouter(
                        twin.specs_from_slots([slots] * n_rep,
                                              mean_rank=mean_rank),
                        policy=policy)
                    res = twin.simulate(spec, router)
                    m = res.metrics
                    out.row(
                        f"r{n_rep}_a{n_ad}_q{rate}_{policy}", 1.0,
                        f"thpt={m.throughput:.0f};"
                        f"ideal={m.ideal_throughput:.0f};"
                        f"loads={m.n_loads};starved={m.starved};"
                        f"imbalance={m.imbalance:.2f}")

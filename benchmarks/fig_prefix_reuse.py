"""Shared-prefix KV reuse: prefix-share x KV-pressure sweep, twin replay.

The prefix-cache figure (ours; no paper counterpart — the paper's
workloads share nothing across requests): a single KV-pressured engine
serves prefix-structured workloads with the cross-adapter shared-prefix
cache ON vs OFF on the *identical* request stream.  Hits skip re-prefill
of the cached prefix (Eq. (1)'s ``pf`` term shrinks) and skip KV
allocation of the covered blocks, so under pressure the reuse arm both
finishes more requests and reaches first tokens sooner.  Three
acceptance claims are asserted:

* **reuse earns its keep** — pooled over the (prefix-share x KV budget)
  grid, the cache-ON arm finishes strictly more requests than the
  cache-OFF arm and its pooled TTFT p99 is strictly lower;
* **OFF is bitwise free** — at ``prefix_share=0`` the cache-ON run is
  bitwise identical to cache-OFF (hits = misses = 0): opting out of the
  feature costs nothing;
* **the twin replays reuse bitwise** — the object-mode engine
  (``ServingEngine``) and the struct-of-arrays twin (``FastEngine``)
  agree exactly on every metric *including the prefix counters*, which
  is what makes prefix-heavy runs labelable training data.

Results land in ``BENCH_prefix_reuse.json`` at the repo root; the
committed copy is refreshed per PR so the reuse trajectory lives in its
git history.
"""
from __future__ import annotations

import json
from pathlib import Path

import numpy as np

from .common import CsvOut, fitted_estimators, is_smoke
from repro.core import (EstimatorExecutor, WorkloadSpec, generate_requests,
                        make_adapter_pool)
from repro.core.fast_twin import FastEngine
from repro.serving import EngineConfig, ServingEngine

EXACT_FIELDS = ("throughput", "ideal_throughput", "duration", "n_finished",
                "n_preemptions", "n_loads", "max_kv_used", "ttft",
                "ttft_p50", "ttft_p99", "n_starved_requests",
                "n_prefix_hits", "n_prefix_misses", "n_prefix_evictions",
                "prefix_tokens_saved")


def config(smoke: bool) -> dict:
    if smoke:
        return dict(n_adapters=6, slots=3, horizon=30.0, seed=5,
                    prefix_len=200, shares=(0.0, 0.8),
                    kv_budgets=(3900,), rates=(0.5, 0.25))
    return dict(n_adapters=8, slots=4, horizon=60.0, seed=5,
                prefix_len=200, shares=(0.0, 0.5, 0.9),
                kv_budgets=(3900, 6500), rates=(0.4, 0.2))


def run_arm(est, cfg: dict, pool, share: float, kv_tokens: int,
            cache_on: bool, fast: bool = True):
    """One grid cell: the engine (fast or object-mode) on the cell's
    deterministic stream.  Streams are regenerated per arm — same seed,
    same spec, bitwise the same requests — so arms never share mutable
    request state."""
    spec = WorkloadSpec(adapters=pool, dataset="medium",
                        horizon=cfg["horizon"], seed=cfg["seed"],
                        prefix_share=share, prefix_len=cfg["prefix_len"])
    reqs = generate_requests(spec)
    ranks = {a.uid: a.rank for a in pool}
    ecfg = EngineConfig(kv_capacity_tokens=kv_tokens,
                        adapter_slots=cfg["slots"],
                        prefix_cache=cache_on)
    ex = EstimatorExecutor(est, cfg["slots"], len(pool), ranks)
    engine = (FastEngine(ecfg, ex, track_requests=False) if fast
              else ServingEngine(ecfg, ex))
    return engine.run(reqs, horizon=cfg["horizon"]), len(reqs)


def pooled_p99(cells) -> float:
    samples = np.concatenate([np.asarray(m.ttft_samples, float)
                              for m in cells if m.ttft_samples])
    return float(np.percentile(samples, 99))


def main(out: CsvOut) -> None:
    est = fitted_estimators()
    cfg = config(is_smoke())
    pool = make_adapter_pool(cfg["n_adapters"], [8, 16], list(cfg["rates"]))

    on_cells, off_cells, grid = [], [], []
    for share in cfg["shares"]:
        for kv in cfg["kv_budgets"]:
            m_on, n_reqs = run_arm(est, cfg, pool, share, kv, True)
            m_off, _ = run_arm(est, cfg, pool, share, kv, False)
            on_cells.append(m_on)
            off_cells.append(m_off)
            grid.append({
                "prefix_share": share, "kv_tokens": kv,
                "n_requests": n_reqs,
                "on": {"n_finished": m_on.n_finished,
                       "ttft_p99": m_on.ttft_p99,
                       "throughput": m_on.throughput,
                       "n_prefix_hits": m_on.n_prefix_hits,
                       "n_prefix_misses": m_on.n_prefix_misses,
                       "n_prefix_evictions": m_on.n_prefix_evictions,
                       "prefix_tokens_saved": m_on.prefix_tokens_saved},
                "off": {"n_finished": m_off.n_finished,
                        "ttft_p99": m_off.ttft_p99,
                        "throughput": m_off.throughput},
            })
            out.row(f"share{share}_kv{kv}", 1.0,
                    f"fin_on={m_on.n_finished};fin_off={m_off.n_finished};"
                    f"hits={m_on.n_prefix_hits};"
                    f"saved={m_on.prefix_tokens_saved}")

            # --- OFF is bitwise free at share=0 ------------------------- #
            if share == 0.0:
                for field in EXACT_FIELDS:
                    a, b = getattr(m_on, field), getattr(m_off, field)
                    if a != b:
                        raise RuntimeError(
                            f"share=0 cache-ON diverged from OFF on "
                            f"{field}: {a} != {b}")
                if m_on.n_prefix_hits or m_on.n_prefix_misses:
                    raise RuntimeError(
                        "share=0 run touched the prefix cache: "
                        f"hits={m_on.n_prefix_hits} "
                        f"misses={m_on.n_prefix_misses}")
            else:
                if m_on.n_prefix_hits < 1:
                    raise RuntimeError(
                        f"share={share} kv={kv}: reuse arm recorded no "
                        "prefix hits")

    # --- reuse earns its keep, pooled over the grid ---------------------- #
    fin_on = sum(m.n_finished for m in on_cells)
    fin_off = sum(m.n_finished for m in off_cells)
    if fin_on <= fin_off:
        raise RuntimeError(
            f"reuse arm finished no more than baseline: {fin_on} <= "
            f"{fin_off}")
    p99_on, p99_off = pooled_p99(on_cells), pooled_p99(off_cells)
    if p99_on >= p99_off:
        raise RuntimeError(
            f"reuse arm's pooled TTFT p99 not lower: {p99_on:.4f} >= "
            f"{p99_off:.4f}")
    out.row("pooled", 1.0,
            f"fin_on={fin_on};fin_off={fin_off};"
            f"p99_on={p99_on:.4f};p99_off={p99_off:.4f}")

    # --- twin replays reuse bitwise (heaviest cell, cache ON) ------------ #
    share, kv = max(cfg["shares"]), min(cfg["kv_budgets"])
    m_fast, _ = run_arm(est, cfg, pool, share, kv, True, fast=True)
    m_obj, _ = run_arm(est, cfg, pool, share, kv, True, fast=False)
    for field in EXACT_FIELDS:
        a, b = getattr(m_obj, field), getattr(m_fast, field)
        if a != b:
            raise RuntimeError(
                f"twin diverged from the engine on {field}: {a} != {b}")
    if m_obj.ttft_samples != m_fast.ttft_samples:
        raise RuntimeError("twin TTFT samples diverged from the engine")
    out.row("twin_replay", 1.0, "bitwise=ok")

    payload = {
        "smoke": is_smoke(),
        "config": {k: cfg[k] for k in ("n_adapters", "slots", "horizon",
                                       "prefix_len")},
        "grid": grid,
        "pooled": {"n_finished_on": fin_on, "n_finished_off": fin_off,
                   "ttft_p99_on": p99_on, "ttft_p99_off": p99_off,
                   "finish_advantage": fin_on - fin_off},
        "twin_bitwise_match": True,
    }
    path = Path(__file__).resolve().parent.parent \
        / "BENCH_prefix_reuse.json"
    path.write_text(json.dumps(payload, indent=2) + "\n")

"""Online rebalancing under drifting adapter popularity.

The rebalancing figure (ours; no paper counterpart — this is the cluster
extension of Fig. 5's placement sensitivity): a workload whose hot
adapter set rotates between phases is served by the same affinity router
under four regimes —

  * ``static``     — affinity routing only; residency earned in one
                     phase is wrong for the next,
  * ``rebalance``  — the EWMA ``RebalancePolicy`` migrates resident
                     adapters as load drifts (Fig. 4 cost charged),
  * ``predictive`` — ``PredictiveRebalancer``: EWMA rate *forecasts*
                     through the trained ``ClusterPlacementModel`` plan
                     migrations ahead of drift, and the model's
                     bin-packing is the fleet's warm initial placement,
  * ``oracle``     — per-phase LPT assignment computed from the *true*
                     phase rates (perfect future knowledge upper bound).

A second run kills one replica mid-stream with rebalancing on and
verifies every request still completes on the survivors (the
fault-tolerance acceptance).  A third scenario pins a single hot adapter
under *hard* affinity (placement-driven routing, as in weight-pinned
deployments): migration alone cannot split one adapter's load — the
migration-only arm starves — while the ``Replicate`` plan action serves
it from two homes and completes the workload.
"""
from __future__ import annotations

import bisect
import dataclasses
import functools
from typing import Dict, Sequence

from .common import CsvOut, fitted_estimators, is_smoke
from repro.core import (ClusterDigitalTwin, Scenario, WorkloadSpec,
                        generate_drifting_requests, generate_requests,
                        make_adapter_pool, rotating_hot_phases,
                        split_pool_by_rate, train_cluster_placement_model)
from repro.core.cluster_twin import ClusterDTResult
from repro.serving import ClusterRouter, FailureEvent
from repro.serving.cluster import RoutingPolicy, register_policy
from repro.serving.predictive import plan_initial_placement
from repro.serving.request import Adapter


@register_policy
class OracleDriftPolicy(RoutingPolicy):
    """Per-phase LPT assignment from the *true* phase rates — the
    clairvoyant upper bound a reactive rebalancer chases."""
    name = "oracle-drift"

    def __init__(self, router: ClusterRouter,
                 assignment: Dict[int, Dict[int, int]] = None,
                 phase_starts: Sequence[float] = ()):
        super().__init__(router)
        self.assignment = assignment or {}
        self.phase_starts = list(phase_starts)

    def choose(self, req) -> int:
        k = bisect.bisect_right(self.phase_starts, req.arrival) - 1
        rep = self.assignment.get(max(k, 0), {}).get(req.adapter)
        if rep is None or not self.router.alive[rep]:
            return self.router.least_loaded()
        return rep


def oracle_assignment(pool: Sequence[Adapter], phases,
                      n_replicas: int) -> Dict[int, Dict[int, int]]:
    """LPT-balance each phase's true rates across replicas."""
    out: Dict[int, Dict[int, int]] = {}
    for k, ph in enumerate(phases):
        phase_pool = [Adapter(uid=a.uid, rank=a.rank,
                              rate=ph.rates.get(a.uid, a.rate))
                      for a in pool]
        bins = split_pool_by_rate(phase_pool, n_replicas)
        out[k] = {a.uid: i for i, part in enumerate(bins) for a in part}
    return out


# --------------------------------------------------------------------------- #

def drift_config(smoke: bool) -> dict:
    if smoke:
        return dict(n_replicas=2, n_adapters=16, slots=4, horizon=60.0,
                    n_phases=2, hot_fraction=0.375, hot_rate=1.2,
                    cold_rate=0.02, epoch=5.0, seed=3)
    return dict(n_replicas=2, n_adapters=16, slots=4, horizon=90.0,
                n_phases=3, hot_fraction=0.375, hot_rate=1.2,
                cold_rate=0.02, epoch=5.0, seed=3)


@functools.lru_cache()
def placement_model():
    """The tiny trained cluster placement model the predictive arm runs
    on (deterministic: fixed scenarios, seeds and forest)."""
    est = fitted_estimators()
    scenarios = [
        Scenario(rates=(1.2, 0.3, 0.02), ranks=(8, 16), dataset="medium"),
        Scenario(rates=(0.6, 0.1, 0.02), ranks=(8, 16), dataset="medium"),
        Scenario(rates=(0.3, 0.05, 0.01), ranks=(8, 16), dataset="medium"),
    ]
    return train_cluster_placement_model(
        est, scenarios, max_adapters=16, replica_counts=(1, 2),
        horizon=20.0, seed=7, holdout=0.0)


def run_mode(est, mode: str, cfg: dict,
             failures: Sequence[FailureEvent] = ()) -> ClusterDTResult:
    """One drifting-popularity run of the ClusterDigitalTwin online loop
    under ``mode`` in {static, rebalance, predictive, oracle}."""
    pool = make_adapter_pool(cfg["n_adapters"], [8, 16], [cfg["cold_rate"]])
    mean_rank = sum(a.rank for a in pool) / len(pool)
    phases = rotating_hot_phases(pool, cfg["horizon"],
                                 n_phases=cfg["n_phases"],
                                 hot_fraction=cfg["hot_fraction"],
                                 hot_rate=cfg["hot_rate"],
                                 cold_rate=cfg["cold_rate"])
    reqs = generate_drifting_requests(pool, "medium", cfg["horizon"],
                                      phases, seed=cfg["seed"])
    twin = ClusterDigitalTwin(est, mode="full")
    specs = twin.specs_from_slots([cfg["slots"]] * cfg["n_replicas"],
                                  mean_rank=mean_rank)
    if mode == "oracle":
        router = ClusterRouter(
            specs, policy="oracle-drift",
            assignment=oracle_assignment(pool, phases, cfg["n_replicas"]),
            phase_starts=[p.start for p in phases])
    else:
        router = ClusterRouter(specs, policy="affinity")
    spec = WorkloadSpec(adapters=pool, dataset="medium",
                        horizon=cfg["horizon"], seed=cfg["seed"])
    rebalancer = None
    initial = None
    if mode == "predictive":
        model = placement_model()
        rebalancer = twin.predictive_rebalancer(spec, router, model)
        # the model's bin-packing on the *initial* popularity becomes the
        # fleet's warm start (replaces first-touch affinity scatter)
        plan_pool = [dataclasses.replace(
            a, rate=phases[0].rates.get(a.uid, a.rate)) for a in pool]
        initial = plan_initial_placement(model, plan_pool,
                                         spec.length_stats(),
                                         cfg["n_replicas"])
    return twin.simulate_online(
        spec, router, requests=reqs, epoch=cfg["epoch"],
        rebalance=(mode == "rebalance"), rebalancer=rebalancer,
        failures=failures, initial_placement=initial)


# --------------------------------------------------------------------------- #
# single-hot-adapter hotspot: migration cannot split one adapter's load
# --------------------------------------------------------------------------- #

def hotspot_config(smoke: bool) -> dict:
    # max_running caps each replica's continuous batch (a realistic
    # per-node concurrency limit) so one home genuinely cannot absorb
    # the hot adapter by growing its batch without bound
    if smoke:
        return dict(n_replicas=2, n_adapters=4, slots=4, horizon=60.0,
                    hot_rate=10.0, cold_rate=0.02, epoch=5.0, seed=11,
                    max_running=64)
    return dict(n_replicas=2, n_adapters=4, slots=4, horizon=90.0,
                hot_rate=10.0, cold_rate=0.02, epoch=5.0, seed=11,
                max_running=64)


def run_hotspot(est, cfg: dict, replicate: bool) -> ClusterDTResult:
    """One adapter hot enough to saturate a whole replica, under *hard*
    affinity (no overload spill — routing follows placement, as it must
    when adapter weights are pinned).  The migration-only rebalancer can
    relocate but never split the hotspot; ``replicate=True`` arms the
    ``Replicate`` plan action so a second home shares the load."""
    pool = make_adapter_pool(cfg["n_adapters"], [8], [cfg["cold_rate"]])
    pool[0] = Adapter(uid=0, rank=8, rate=cfg["hot_rate"])
    spec = WorkloadSpec(adapters=pool, dataset="medium",
                        horizon=cfg["horizon"], seed=cfg["seed"])
    reqs = generate_requests(spec)
    twin = ClusterDigitalTwin(est, mode="full",
                              max_running=cfg["max_running"])
    router = ClusterRouter(
        twin.specs_from_slots([cfg["slots"]] * cfg["n_replicas"],
                              mean_rank=8.0),
        policy="affinity", overload_factor=1e9, slack=1e9)
    rebalancer = twin.rebalancer(spec, router, replicate=replicate)
    return twin.simulate_online(
        spec, router, requests=reqs, epoch=cfg["epoch"],
        rebalance=False, rebalancer=rebalancer, drain=False)


def main(out: CsvOut) -> None:
    est = fitted_estimators()
    cfg = drift_config(is_smoke())
    results: Dict[str, ClusterDTResult] = {}
    for mode in ("static", "rebalance", "predictive", "oracle"):
        res = run_mode(est, mode, cfg)
        results[mode] = res
        m = res.metrics
        out.row(mode, 1.0,
                f"thpt={m.throughput:.0f};ideal={m.ideal_throughput:.0f};"
                f"loads={m.n_loads};finished={m.n_finished};"
                f"migrations={len(res.online.migrations)};"
                f"imbalance={m.imbalance:.2f}")
    if results["rebalance"].metrics.throughput < \
            results["static"].metrics.throughput:
        raise RuntimeError(
            "rebalancing lost to static affinity routing: "
            f"{results['rebalance'].metrics.throughput:.1f} < "
            f"{results['static'].metrics.throughput:.1f} tok/s")
    if results["predictive"].metrics.throughput < \
            results["rebalance"].metrics.throughput:
        raise RuntimeError(
            "model-driven (predictive) rebalancing lost to reactive: "
            f"{results['predictive'].metrics.throughput:.1f} < "
            f"{results['rebalance'].metrics.throughput:.1f} tok/s")

    # single-hot-adapter hotspot: migration alone starves, replication
    # completes (the S-LoRA/Punica observation, asserted)
    hcfg = hotspot_config(is_smoke())
    mig_only = run_hotspot(est, hcfg, replicate=False)
    repl = run_hotspot(est, hcfg, replicate=True)
    for tag, res in (("hotspot_migration_only", mig_only),
                     ("hotspot_replicate", repl)):
        m = res.metrics
        out.row(tag, 1.0,
                f"thpt={m.throughput:.0f};ideal={m.ideal_throughput:.0f};"
                f"finished={m.n_finished};starved={m.starved};"
                f"replications={len(res.online.replications)};"
                f"per_replica={[r.n_finished for r in m.per_replica]}")
    if not mig_only.metrics.starved:
        raise RuntimeError(
            "hotspot case lost its teeth: migration-only run no longer "
            f"starves ({mig_only.metrics.throughput:.1f} of "
            f"{mig_only.metrics.ideal_throughput:.1f} tok/s)")
    if repl.metrics.starved or not repl.online.replications:
        raise RuntimeError("replication failed to resolve the single-hot-"
                           "adapter starvation migration cannot fix")
    if repl.metrics.n_finished <= mig_only.metrics.n_finished:
        raise RuntimeError(
            "replication finished no more requests than migration-only: "
            f"{repl.metrics.n_finished} <= {mig_only.metrics.n_finished}")

    # kill one replica at 40% of the horizon, rebalancing on
    kill = FailureEvent(replica=0, at=0.4 * cfg["horizon"])
    res = run_mode(est, "rebalance", cfg, failures=[kill])
    m = res.metrics
    # route() is called again for each drained request, so unique request
    # count = total routed commits - re-routes
    n_unique = sum(res.online.router_summary["assigned_requests"]) \
        - res.online.n_rerouted
    out.row("rebalance_kill", 1.0,
            f"thpt={m.throughput:.0f};finished={m.n_finished};"
            f"requests={n_unique};rerouted={res.online.n_rerouted};"
            f"detected_at={res.online.failures_detected.get(0, -1):.0f}")
    if m.n_finished < n_unique:
        raise RuntimeError("requests starved after replica failure")

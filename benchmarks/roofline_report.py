"""Roofline table from the dry-run's JSONL records (§Roofline in
EXPERIMENTS.md). Reads dryrun_pod1.jsonl written by launch/dryrun.py."""
from __future__ import annotations

import json
import os

from .common import CsvOut


def main(out: CsvOut, path: str = "dryrun_pod1.jsonl") -> None:
    if not os.path.exists(path):
        out.row("missing", 0.0, f"run launch/dryrun.py first ({path})")
        return
    for line in open(path):
        r = json.loads(line)
        if not r.get("ok") or "roofline" not in r:
            continue
        rf = r["roofline"]
        out.row(r["cell"], rf["step_time_s"] * 1e6,
                f"compute={rf['compute_s']:.3e};memory={rf['memory_s']:.3e};"
                f"collective={rf['collective_s']:.3e};"
                f"bottleneck={rf['bottleneck']};"
                f"useful_ratio={rf['useful_ratio']:.3f}")

"""Roofline tables: (a) the decode-step kernel roofline — fused
flash-decode+LoRA vs the unfused base-then-adapter sequence — and
(b) the dry-run's JSONL records (§Roofline in EXPERIMENTS.md, written by
launch/dryrun.py) when present.

The kernel arms are analytic (HBM bytes + launch overheads on nominal
accelerator numbers; NanoFlow's intra-device overlap analysis is the
framing: decode attention is memory-bound, so the bound is bytes/BW).
Fusing the LoRA delta into the flash-decode epilogue removes one kernel
launch and the HBM round-trip of both the attention output and the
delta, so the fused bound must beat the unfused bound at every shape —
asserted when run under ``--smoke`` (the CI gate).
"""
from __future__ import annotations

import json
import os

from .common import CsvOut, is_smoke

# nominal accelerator numbers (TPU v5e-class): the roofline *ratio* is
# what the gate asserts, so absolute calibration only scales the table.
HBM_GBPS = 819.0
LAUNCH_US = 2.0          # per-kernel-launch overhead
BYTES_PER = 2            # bf16


def decode_rooflines(b: int, h: int, kv: int, d: int, s: int,
                     dx: int, r: int, n_unique: int) -> dict:
    """Analytic HBM traffic + time bounds for one decode step.

    fused:   read q, K, V, x, A, B; write out            (1 launch)
    unfused: flash (read q,K,V; write attn) + bgmv (read x,A,B; write
             delta) + add (read attn,delta; write out)   (3 launches)

    The unfused sequence pays 2 extra (B,H,D) transfers for the
    attention output and 2 extra (B,o) = (B,H,D) transfers for the
    delta, plus two extra launches.
    """
    out_b = b * h * d * BYTES_PER
    attn_b = (b * h * d + 2 * b * s * kv * d) * BYTES_PER + out_b
    lora_b = (b * dx + n_unique * (dx * r + r * h * d)) * BYTES_PER + out_b
    fused_bytes = attn_b + lora_b - out_b          # one output write
    unfused_bytes = attn_b + lora_b + 2 * out_b    # attn + delta bounce
    fused_us = fused_bytes / HBM_GBPS / 1e3 + LAUNCH_US
    unfused_us = unfused_bytes / HBM_GBPS / 1e3 + 3 * LAUNCH_US
    return dict(fused_bytes=fused_bytes, unfused_bytes=unfused_bytes,
                fused_us=fused_us, unfused_us=unfused_us,
                speedup=unfused_us / fused_us)


def main(out: CsvOut, path: str = "dryrun_pod1.jsonl") -> None:
    # ---- kernel roofline: fused vs unfused decode step ---------------- #
    shapes = [(4, 8, 2, 64, 512, 128, 16, 4)] if is_smoke() else \
        [(8, 32, 8, 128, 4096, 4096, 16, 8),
         (32, 32, 8, 128, 2048, 4096, 16, 16),
         (128, 32, 8, 128, 1024, 4096, 32, 32)]
    for (b, h, kv, d, s, dx, r, n) in shapes:
        rf = decode_rooflines(b, h, kv, d, s, dx, r, n)
        out.row(f"decode_unfused_b{b}_s{s}", rf["unfused_us"],
                f"hbm_bytes={rf['unfused_bytes']};launches=3")
        out.row(f"decode_fused_b{b}_s{s}", rf["fused_us"],
                f"hbm_bytes={rf['fused_bytes']};launches=1;"
                f"roofline_speedup={rf['speedup']:.3f}x")
        if is_smoke():
            # CI gate: the fused kernel's roofline target — strictly less
            # HBM traffic and a strictly better time bound
            assert rf["fused_bytes"] < rf["unfused_bytes"], \
                "fused kernel must move strictly fewer HBM bytes"
            assert rf["speedup"] > 1.0, \
                "fused roofline bound must beat unfused"

    # ---- dry-run records (optional) ----------------------------------- #
    if not os.path.exists(path):
        out.row("dryrun_missing", 0.0,
                f"run launch/dryrun.py first ({path})")
        return
    for line in open(path):
        r = json.loads(line)
        if not r.get("ok") or "roofline" not in r:
            continue
        rf = r["roofline"]
        out.row(r["cell"], rf["step_time_s"] * 1e6,
                f"compute={rf['compute_s']:.3e};memory={rf['memory_s']:.3e};"
                f"collective={rf['collective_s']:.3e};"
                f"bottleneck={rf['bottleneck']};"
                f"useful_ratio={rf['useful_ratio']:.3f}")

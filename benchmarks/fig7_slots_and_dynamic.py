"""Paper Fig. 7: (left) optimal placement as adapter slots vary — many
more adapters than slots can be served, but too-few slots starve;
(right) S-LoRA-style fully dynamic slot allocation for comparison."""
from __future__ import annotations

from .common import CsvOut, fitted_estimators
from repro.core import DigitalTwin, WorkloadSpec, make_adapter_pool


def main(out: CsvOut) -> None:
    est = fitted_estimators()
    dt = DigitalTwin(est, mode="mean")
    n = 96
    pool = make_adapter_pool(n, [8], [0.0125])
    spec = WorkloadSpec(adapters=pool, dataset="medium", horizon=150.0,
                        seed=2)
    for slots in (2, 6, 12, 24, 48, 96):
        m = dt.simulate(spec, slots=slots).metrics
        out.row(f"slots{slots}_adapters{n}", 1.0,
                f"thpt={m.throughput:.0f};starved={int(m.starved)}")
    # S-LoRA mode: unified adapter/KV memory, dynamic on-demand slots with
    # idle-adapter eviction (paper §V-B) at rank 32, across rates — the
    # throughput decline with rate is much flatter than vLLM's
    for rate in (0.2, 0.05, 0.0125, 0.003125):
        pool32 = make_adapter_pool(n, [32], [rate])
        spec32 = WorkloadSpec(adapters=pool32, dataset="medium",
                              horizon=150.0, seed=2)
        m = dt.simulate(spec32, slots=n, dynamic_slots=True).metrics
        out.row(f"slora_rate{rate}", 1.0,
                f"thpt={m.throughput:.0f};starved={int(m.starved)}")

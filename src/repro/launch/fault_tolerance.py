"""Fault-tolerant step-loop wrapper for the train/serve launchers.

Production semantics, exercised here in-process:
  * periodic async checkpoints with atomic commit (CheckpointManager),
  * crash -> restart from latest committed step (optionally on a
    DIFFERENT mesh: elastic restore re-sharding via device_put),
  * straggler watchdog: a step slower than `straggler_factor` x the
    rolling median is logged and counted (on a real fleet this triggers
    hot-spare swap; here it feeds the router's straggler policy),
  * failure injection hooks for the integration tests.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from ..checkpoint import CheckpointManager


@dataclasses.dataclass
class FTConfig:
    checkpoint_dir: str
    checkpoint_every: int = 50
    keep: int = 3
    straggler_factor: float = 3.0
    max_restarts: int = 3


@dataclasses.dataclass
class FTReport:
    steps_run: int = 0
    restarts: int = 0
    resumed_from: Optional[int] = None
    stragglers: List[int] = dataclasses.field(default_factory=list)
    step_times: List[float] = dataclasses.field(default_factory=list)


class FaultTolerantLoop:
    def __init__(self, cfg: FTConfig, state_skeleton: Dict[str, Any],
                 shardings: Optional[Any] = None):
        self.cfg = cfg
        self.mgr = CheckpointManager(cfg.checkpoint_dir, keep=cfg.keep)
        self.skeleton = state_skeleton
        self.shardings = shardings
        self.report = FTReport()

    def resume_or_init(self, init_fn: Callable[[], Dict[str, Any]]
                       ) -> Dict[str, Any]:
        latest = self.mgr.latest_step()
        if latest is None:
            return init_fn()
        self.report.resumed_from = latest
        return self.mgr.restore(self.skeleton, latest,
                                shardings=self.shardings)

    def run(self, state: Dict[str, Any], step_fn: Callable,
            batch_fn: Callable[[int], Any], n_steps: int,
            start_step: int = 0,
            failure_at: Optional[int] = None) -> Dict[str, Any]:
        """Run steps [start_step, n_steps); `failure_at` injects a crash."""
        step = start_step
        while step < n_steps:
            if failure_at is not None and step == failure_at:
                failure_at = None  # fail once
                raise RuntimeError(f"injected failure at step {step}")
            t0 = time.perf_counter()
            state = step_fn(state, batch_fn(step))
            dt = time.perf_counter() - t0
            self.report.step_times.append(dt)
            med = float(np.median(self.report.step_times[-20:]))
            if len(self.report.step_times) > 5 and \
                    dt > self.cfg.straggler_factor * med:
                self.report.stragglers.append(step)
            step += 1
            self.report.steps_run += 1
            if step % self.cfg.checkpoint_every == 0 or step == n_steps:
                self.mgr.save(step, state)
        self.mgr.wait()
        return state

    def run_with_restarts(self, init_fn, step_fn, batch_fn, n_steps: int,
                          failure_at: Optional[int] = None
                          ) -> Dict[str, Any]:
        restarts = 0
        while True:
            state = self.resume_or_init(init_fn)
            start = self.mgr.latest_step() or 0
            try:
                return self.run(state, step_fn, batch_fn, n_steps,
                                start_step=start, failure_at=failure_at)
            except RuntimeError:
                restarts += 1
                self.report.restarts = restarts
                failure_at = None
                if restarts > self.cfg.max_restarts:
                    raise

"""Step builders: one (arch x shape x mesh) cell -> a jit-able step function
with abstract inputs and explicit in/out shardings.

Used by the multi-pod dry-run (lower+compile), the roofline probes
(reduced-depth unrolled variants of the same cell) and the train/serve
launchers (with real arrays instead of ShapeDtypeStructs).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.shapes import (DRYRUN_ADAPTER_SLOTS, DRYRUN_LORA_RANK,
                              input_specs)
from ..models import Model, make_plan
from ..models.config import ModelConfig, ShapeConfig
from ..training import AdamWConfig, TrainConfig, adamw_init, make_train_step


@dataclasses.dataclass
class StepBundle:
    fn: Any
    args: Tuple[Any, ...]                 # ShapeDtypeStructs (abstract)
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    donate_argnums: Tuple[int, ...]
    plan: Any
    model: Model
    meta: Dict[str, Any]


def _ns(mesh: Optional[Mesh], spec):
    if mesh is None:
        return None
    return NamedSharding(mesh, spec)


def _tree_shardings(mesh, specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def _replicated_like(mesh, tree):
    return jax.tree.map(lambda x: NamedSharding(mesh, P(*([None] * x.ndim))),
                        tree)


def build_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Optional[Mesh],
               *, unroll: bool = False, remat: bool = True,
               layers_override: Optional[int] = None,
               plan_overrides: Optional[Dict[str, Any]] = None) -> StepBundle:
    if layers_override:
        cfg = dataclasses.replace(cfg, n_layers=layers_override)
    plan = make_plan(cfg, mesh, shape.kind, unroll=unroll,
                     remat=remat and shape.kind == "train",
                     global_batch=shape.global_batch)
    if plan_overrides:
        plan = dataclasses.replace(plan, **plan_overrides)
    model = Model(cfg, plan)
    key = jax.random.PRNGKey(0)
    params_sds = jax.eval_shape(model.init, key)
    pspecs = plan.param_specs(params_sds)
    inputs = input_specs(cfg, shape)
    dp = plan.dp()

    if shape.kind == "train":
        tcfg = TrainConfig(optimizer=AdamWConfig())
        opt_sds = jax.eval_shape(lambda p: adamw_init(p, tcfg.optimizer),
                                 params_sds)
        # moments inherit param shardings; scalars replicated
        ospecs = {
            "step": P(),
            "m": pspecs, "v": pspecs,
        }
        step = make_train_step(model, tcfg)
        batch_specs = {"tokens": P(dp, None)}
        if "img_embeds" in inputs:
            batch_specs["img_embeds"] = P(dp, None, None)
        args = (params_sds, opt_sds, inputs)
        if mesh is None:
            in_sh = out_sh = None
        else:
            in_sh = (_tree_shardings(mesh, pspecs),
                     _tree_shardings(mesh, ospecs),
                     _tree_shardings(mesh, batch_specs))
            info_sh = {"loss": _ns(mesh, P()), "grad_norm": _ns(mesh, P()),
                       "lr": _ns(mesh, P())}
            out_sh = (in_sh[0], in_sh[1], info_sh)
        return StepBundle(step, args, in_sh, out_sh, (0, 1), plan, model,
                          {"kind": "train"})

    # serving cells share LoRA adapters (the paper's scenario)
    lora_sds = jax.eval_shape(
        lambda k: model.init_lora(k, DRYRUN_ADAPTER_SLOTS, DRYRUN_LORA_RANK),
        key)
    lora_sh = _replicated_like(mesh, lora_sds) if mesh is not None else None

    if shape.kind == "prefill":
        def step(params, lora, tokens, adapter_idx, img_embeds=None):
            return model.prefill(params, lora, tokens, adapter_idx,
                                 img_embeds=img_embeds)

        args = [params_sds, lora_sds, inputs["tokens"],
                inputs["adapter_idx"]]
        in_sh = None
        out_sh = None
        if mesh is not None:
            in_list = [_tree_shardings(mesh, pspecs), lora_sh,
                       _ns(mesh, P(dp, None)), _ns(mesh, P(dp))]
            if "img_embeds" in inputs:
                in_list.append(_ns(mesh, P(dp, None, None)))
            in_sh = tuple(in_list)
        if "img_embeds" in inputs:
            args.append(inputs["img_embeds"])
        cache_sds = jax.eval_shape(step, *args)[1]
        if mesh is not None:
            cspecs = plan.cache_specs(cache_sds)
            out_sh = (_ns(mesh, P(dp, None)),
                      _tree_shardings(mesh, cspecs))
        return StepBundle(step, tuple(args), in_sh, out_sh, (), plan, model,
                          {"kind": "prefill"})

    # decode: one new token against a cache of length shape.seq_len
    cache_sds = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len))

    def step(params, lora, cache, tokens, adapter_idx):
        return model.decode_step(params, lora, cache, tokens, adapter_idx)

    args = (params_sds, lora_sds, cache_sds, inputs["tokens"],
            inputs["adapter_idx"])
    in_sh = out_sh = None
    if mesh is not None:
        cspecs = plan.cache_specs(cache_sds)
        csh = _tree_shardings(mesh, cspecs)
        in_sh = (_tree_shardings(mesh, pspecs), lora_sh, csh,
                 _ns(mesh, P(dp, None)), _ns(mesh, P(dp)))
        out_sh = (_ns(mesh, P(dp, None)), csh)
    return StepBundle(step, args, in_sh, out_sh, (2,), plan, model,
                      {"kind": "decode"})


def cell_id(arch: str, shape_name: str, multi_pod: bool) -> str:
    return f"{arch}:{shape_name}:{'pod2' if multi_pod else 'pod1'}"

"""Cluster serving launcher: N engine replicas behind the adapter-
affinity router, on CPU via the synthetic executor (full-scale fleet
behaviour without a GPU) or the real JAX executor per replica.

    python -m repro.launch.serve_cluster --replicas 2
    python -m repro.launch.serve_cluster --replicas 4 --adapters 64 \
        --slots 8,8,4,4 --policy affinity --compare-policies
"""
from __future__ import annotations

import argparse
from typing import List

from ..core.workload import WorkloadSpec, generate_requests, make_adapter_pool
from ..serving import (ClusterMetrics, ClusterRouter, HardwareProfile,
                       ServingCluster, SyntheticExecutor,
                       make_replica_specs)
from ..serving.cluster import POLICIES


def _int_list(text: str, n: int, name: str) -> List[int]:
    vals = [int(v) for v in text.split(",") if v.strip()]
    if len(vals) == 1:
        vals = vals * n
    if len(vals) != n:
        raise SystemExit(f"--{name}: expected 1 or {n} values, got "
                         f"{len(vals)}")
    return vals


def _report(tag: str, m: ClusterMetrics) -> None:
    print(f"[{tag}] throughput={m.throughput:.1f} tok/s "
          f"(ideal {m.ideal_throughput:.1f}) | itl={m.itl * 1e3:.1f}ms "
          f"| ttft={m.ttft * 1e3:.1f}ms | finished={m.n_finished} "
          f"| adapter_loads={m.n_loads} | preemptions={m.n_preemptions} "
          f"| imbalance={m.imbalance:.2f} | starved={m.starved}")


def run_once(args, policy: str, verbose: bool = True) -> ClusterMetrics:
    profile = HardwareProfile()
    slots = _int_list(args.slots, args.replicas, "slots")
    if args.kv_tokens:
        kvs = _int_list(args.kv_tokens, args.replicas, "kv-tokens")
    else:
        kvs = [profile.kv_capacity(g, args.rank) for g in slots]
    specs = make_replica_specs(args.replicas, slots, kvs)

    pool = make_adapter_pool(args.adapters, [args.rank], [args.rate])
    ranks = {a.uid: a.rank for a in pool}
    spec = WorkloadSpec(adapters=pool, dataset=args.dataset,
                        horizon=args.horizon, seed=args.seed)
    reqs = generate_requests(spec)

    router = ClusterRouter(specs, policy=policy)
    executors = [SyntheticExecutor(profile, ranks, slots=s.adapter_slots,
                                   n_adapters=args.adapters,
                                   seed=args.seed + i)
                 for i, s in enumerate(specs)]
    cluster = ServingCluster(router, executors)
    metrics = cluster.run(reqs, horizon=args.horizon)
    if verbose:
        for i, (s, m) in enumerate(zip(specs, metrics.per_replica)):
            print(f"  replica {i}: slots={s.adapter_slots} "
                  f"kv={s.kv_capacity_tokens} -> "
                  f"thpt={m.throughput:.1f} tok/s finished={m.n_finished} "
                  f"loads={m.n_loads} starved={m.starved}")
    _report(policy, metrics)
    return metrics


def main() -> None:
    ap = argparse.ArgumentParser(
        description="serve a multi-adapter workload on a replica cluster")
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--adapters", type=int, default=24)
    ap.add_argument("--rate", type=float, default=0.1)
    ap.add_argument("--rank", type=int, default=8)
    ap.add_argument("--slots", default="8",
                    help="per-replica adapter slots (scalar or comma list)")
    ap.add_argument("--kv-tokens", default="",
                    help="per-replica KV capacity override (comma list)")
    ap.add_argument("--policy", default="affinity",
                    choices=sorted(POLICIES))
    ap.add_argument("--compare-policies", action="store_true",
                    help="run every routing policy on the same workload")
    ap.add_argument("--dataset", default="medium")
    ap.add_argument("--horizon", type=float, default=60.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.compare_policies:
        for policy in sorted(POLICIES):
            run_once(args, policy, verbose=False)
    else:
        run_once(args, args.policy)


if __name__ == "__main__":
    main()

"""Cluster serving launcher: N engine replicas behind the adapter-
affinity router, on CPU via the synthetic executor (full-scale fleet
behaviour without a GPU) or the real JAX executor per replica.

Offline (route everything up front, then serve):

    python -m repro.launch.serve_cluster --replicas 2
    python -m repro.launch.serve_cluster --replicas 4 --adapters 64 \\
        --slots 8,8,4,4 --policy affinity --compare-policies

Online (epoch loop: heartbeats, failure drain, optional rebalancing):

    python -m repro.launch.serve_cluster --replicas 2 --online --rebalance
    python -m repro.launch.serve_cluster --replicas 3 --online --rebalance \\
        --drift 3 --kill 1@30 --epoch 5

Model-driven (predictive) rebalancing + hot-adapter replication:

    python -m repro.launch.serve_cluster --replicas 2 --online \\
        --rebalance predictive --plan-initial --drift 3
    python -m repro.launch.serve_cluster --replicas 2 --online \\
        --rebalance reactive --replicate
"""
from __future__ import annotations

import argparse
import dataclasses
from typing import List

from ..core.workload import (WorkloadSpec, generate_drifting_requests,
                             generate_requests, make_adapter_pool,
                             rotating_hot_phases)
from ..serving import (ClusterMetrics, ClusterRouter, FailureEvent,
                       HardwareProfile, PredictiveRebalancer,
                       RebalancePolicy, ReliabilityPolicy, ServingCluster,
                       SyntheticExecutor, make_replica_specs,
                       parse_chaos_spec, plan_initial_placement)
from ..serving.cluster import POLICIES
from ..serving.policy import SCHED_POLICIES


def _int_list(text: str, n: int, name: str) -> List[int]:
    vals = [int(v) for v in text.split(",") if v.strip()]
    if len(vals) == 1:
        vals = vals * n
    if len(vals) != n:
        raise SystemExit(f"--{name}: expected 1 or {n} values, got "
                         f"{len(vals)}")
    return vals


def _failures(specs: List[str], n_replicas: int) -> List[FailureEvent]:
    out = []
    for s in specs:
        try:
            rep, at = s.split("@")
            out.append(FailureEvent(replica=int(rep), at=float(at)))
        except ValueError:
            raise SystemExit(f"--kill: expected REPLICA@TIME, got {s!r}")
        if not 0 <= out[-1].replica < n_replicas:
            raise SystemExit(f"--kill: replica {out[-1].replica} out of "
                             f"range for --replicas {n_replicas}")
    return out


def _report(tag: str, m: ClusterMetrics) -> None:
    print(f"[{tag}] throughput={m.throughput:.1f} tok/s "
          f"(ideal {m.ideal_throughput:.1f}) | itl={m.itl * 1e3:.1f}ms "
          f"| ttft={m.ttft * 1e3:.1f}ms "
          f"(p50 {m.ttft_p50 * 1e3:.1f} / p99 {m.ttft_p99 * 1e3:.1f}) "
          f"| finished={m.n_finished} "
          f"| adapter_loads={m.n_loads} | preemptions={m.n_preemptions} "
          f"| imbalance={m.imbalance:.2f} | starved={m.starved} "
          f"| starved_reqs={m.n_starved_requests}")
    if m.starved_per_adapter:
        worst = sorted(m.starved_per_adapter.items(),
                       key=lambda kv: -kv[1])[:5]
        print("  starved requests by adapter: "
              + ", ".join(f"{a}:{c}" for a, c in worst))


_MODEL_CACHE: dict = {}


def _placement_model(args, profile):
    """Train the (tiny) cluster placement model the predictive path
    feeds EWMA forecasts through — the CLI's creation phase.  Memoized:
    the --compare-* loops call run_once per policy with identical
    workload arguments, and the model only depends on those."""
    key = (args.replicas, args.adapters, args.rank, args.rate,
           args.dataset, args.seed, args.slots)
    if key in _MODEL_CACHE:
        return _MODEL_CACHE[key]
    from ..core import (Scenario, collect_benchmark, collect_memmax,
                        fit_estimators, train_cluster_placement_model)
    slots = max(_int_list(args.slots, args.replicas, "slots"))
    ranks = {i: args.rank for i in range(args.adapters)}
    ex = SyntheticExecutor(profile, ranks, slots=slots,
                          n_adapters=args.adapters, seed=args.seed)
    est = fit_estimators(collect_benchmark(ex, slots, args.adapters, ranks),
                         collect_memmax(profile), slots, args.adapters)
    r = args.rate
    scenarios = [
        Scenario(rates=(r * 8, r, r / 4), ranks=(args.rank,),
                 dataset=args.dataset),
        Scenario(rates=(r * 4, r, r / 2), ranks=(args.rank,),
                 dataset=args.dataset),
        Scenario(rates=(r * 2, r, r), ranks=(args.rank,),
                 dataset=args.dataset),
    ]
    model = train_cluster_placement_model(
        est, scenarios, max_adapters=args.adapters,
        replica_counts=(1, args.replicas), horizon=20.0, seed=args.seed,
        holdout=0.0)
    _MODEL_CACHE[key] = model
    return model


def run_once(args, policy: str, verbose: bool = True) -> ClusterMetrics:
    profile = HardwareProfile()
    slots = _int_list(args.slots, args.replicas, "slots")
    if args.kv_tokens:
        kvs = _int_list(args.kv_tokens, args.replicas, "kv-tokens")
    else:
        kvs = [profile.kv_capacity(g, args.rank) for g in slots]
    use_prefix = args.prefix_cache or (
        args.prefix_share > 0 and args.prefix_len > 0)
    specs = make_replica_specs(args.replicas, slots, kvs,
                               block_size=args.block_size,
                               sched_policy=args.sched_policy,
                               prefix_cache=use_prefix)

    pool = make_adapter_pool(args.adapters, [args.rank], [args.rate])
    ranks = {a.uid: a.rank for a in pool}
    spec = WorkloadSpec(adapters=pool, dataset=args.dataset,
                        horizon=args.horizon, seed=args.seed,
                        prefix_share=args.prefix_share,
                        prefix_len=args.prefix_len)
    phases = None
    if args.drift > 0:
        phases = rotating_hot_phases(pool, args.horizon,
                                     n_phases=args.drift,
                                     hot_rate=max(args.rate * 8, 0.2),
                                     cold_rate=args.rate / 4)
        reqs = generate_drifting_requests(pool, args.dataset, args.horizon,
                                          phases, seed=args.seed,
                                          prefix_share=args.prefix_share,
                                          prefix_len=args.prefix_len)
    else:
        reqs = generate_requests(spec)

    router = ClusterRouter(specs, policy=policy)
    executors = [SyntheticExecutor(profile, ranks, slots=s.adapter_slots,
                                   n_adapters=args.adapters,
                                   seed=args.seed + i)
                 for i, s in enumerate(specs)]
    cluster = ServingCluster(router, executors)

    online = args.online or args.rebalance or args.kill \
        or args.drift > 0 or args.replicate or args.plan_initial \
        or args.chaos or args.request_timeout > 0
    if online:
        rebalancer = None
        model = None
        if args.rebalance == "predictive" or args.plan_initial:
            model = _placement_model(args, profile)
        load_cost = profile.load_cpu_base + \
            profile.load_cpu_per_rank * args.rank
        if args.rebalance == "predictive":
            rebalancer = PredictiveRebalancer(
                router, model=model, pool=pool,
                length_stats=spec.length_stats(),
                load_cost_fn=lambda uid: load_cost,
                replicate=args.replicate)
        elif args.rebalance or args.replicate:
            rebalancer = RebalancePolicy(
                router, load_cost_fn=lambda uid: load_cost,
                replicate=args.replicate)
        initial = None
        if args.plan_initial:
            # under drift, pack on the *initial* (phase-0) popularity —
            # the uniform base rates would make the bin-packing blind to
            # the hot set the stream actually opens with
            plan_pool = pool if phases is None else [
                dataclasses.replace(a, rate=phases[0].rates.get(a.uid,
                                                                a.rate))
                for a in pool]
            initial = plan_initial_placement(
                model, plan_pool, spec.length_stats(), args.replicas,
                sched_policy=args.sched_policy)
        fault_plan = None
        if args.chaos:
            try:
                fault_plan = parse_chaos_spec(
                    args.chaos, args.replicas, args.horizon,
                    seed=args.seed, adapters=[a.uid for a in pool],
                    n_requests=len(reqs))
            except ValueError as exc:
                raise SystemExit(str(exc))
        reliability = None
        if args.request_timeout > 0:
            reliability = ReliabilityPolicy(
                timeout_s=args.request_timeout,
                max_retries=args.max_retries,
                load_cost_fn=lambda uid: load_cost)
        report = cluster.run_online(
            reqs, horizon=args.horizon, epoch=args.epoch,
            rebalancer=rebalancer,
            failures=_failures(args.kill, args.replicas),
            straggler_factor=args.straggler_factor,
            initial_placement=initial,
            fault_plan=fault_plan, reliability=reliability)
        metrics = report.metrics
        if verbose and (fault_plan is not None or reliability is not None):
            f = report.faults
            print(f"  faults: crashes={f.n_crashes} "
                  f"recoveries={f.n_recoveries} "
                  f"load_faults={f.n_load_faults} "
                  f"timeouts={f.n_timeouts} retries={f.n_retries} "
                  f"failed={f.n_failed_requests} "
                  f"disconnects={f.n_disconnects} "
                  f"breaker_opens={f.n_breaker_opens}")
        if verbose:
            # report.migrations is the full executed-plan log; count the
            # actual migrations separately from (un)replications
            n_migs = len(report.migrations) - len(report.replications) \
                - len(report.unreplications)
            print(f"  online: epochs={report.n_epochs} "
                  f"migrations={n_migs} "
                  f"replications={len(report.replications)} "
                  f"rerouted={report.n_rerouted} "
                  f"failures_detected={report.failures_detected}")
    else:
        metrics = cluster.run(reqs, horizon=args.horizon)
    if verbose:
        for i, (s, m) in enumerate(zip(specs, metrics.per_replica)):
            print(f"  replica {i}: slots={s.adapter_slots} "
                  f"kv={s.kv_capacity_tokens} -> "
                  f"thpt={m.throughput:.1f} tok/s finished={m.n_finished} "
                  f"loads={m.n_loads} starved={m.starved}")
    tag = policy
    if args.sched_policy != "fcfs":
        tag += f"/{args.sched_policy}"
    _report(tag + ("+online" if online else ""), metrics)
    return metrics


def build_parser() -> argparse.ArgumentParser:
    """The CLI surface (exposed so tools/check_docs.py can cross-check
    documented flags against the real parser)."""
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.serve_cluster",
        description="serve a multi-adapter workload on a replica cluster")
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--adapters", type=int, default=24)
    ap.add_argument("--rate", type=float, default=0.1)
    ap.add_argument("--rank", type=int, default=8)
    ap.add_argument("--slots", default="8",
                    help="per-replica adapter slots (scalar or comma list)")
    ap.add_argument("--kv-tokens", default="",
                    help="per-replica KV capacity override (comma list)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="KV paging block size (tokens per block)")
    ap.add_argument("--policy", default="affinity",
                    choices=sorted(POLICIES))
    ap.add_argument("--sched-policy", default="fcfs",
                    choices=sorted(SCHED_POLICIES),
                    help="per-replica engine admission/preemption policy")
    ap.add_argument("--compare-policies", action="store_true",
                    help="run every routing policy on the same workload")
    ap.add_argument("--compare-sched-policies", action="store_true",
                    help="run every scheduling policy on the same workload")
    ap.add_argument("--prefix-share", type=float, default=0.0,
                    help="fraction of requests carrying their adapter's "
                         "shared prompt prefix (enables the prefix cache)")
    ap.add_argument("--prefix-len", type=int, default=0,
                    help="shared-prefix length in tokens")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="enable the cross-adapter shared-prefix KV cache "
                         "even when the synthetic workload has no prefixes")
    ap.add_argument("--dataset", default="medium")
    ap.add_argument("--horizon", type=float, default=60.0)
    ap.add_argument("--seed", type=int, default=0)
    # online loop -------------------------------------------------------- #
    ap.add_argument("--online", action="store_true",
                    help="epoch-driven loop (heartbeats, failure drain)")
    ap.add_argument("--rebalance", nargs="?", const="reactive", default="",
                    choices=("reactive", "predictive"),
                    help="enable adapter rebalancing (implies --online): "
                         "'reactive' (bare --rebalance) reacts to EWMA "
                         "drift; 'predictive' plans migrations ahead of "
                         "drift by feeding EWMA forecasts through the "
                         "trained cluster placement model")
    ap.add_argument("--replicate", action="store_true",
                    help="arm hot-adapter replication: an adapter whose "
                         "EWMA rate exceeds a per-replica traffic share "
                         "is served from two homes (implies --online and "
                         "the reactive rebalancer unless --rebalance "
                         "predictive is given)")
    ap.add_argument("--plan-initial", action="store_true",
                    help="warm the fleet with the placement model's "
                         "bin-packing before serving starts (implies "
                         "--online)")
    ap.add_argument("--epoch", type=float, default=5.0,
                    help="online loop window length (s)")
    ap.add_argument("--kill", action="append", default=[],
                    metavar="REPLICA@TIME",
                    help="inject a replica failure, e.g. --kill 1@30 "
                         "(implies --online; repeatable)")
    ap.add_argument("--drift", type=int, default=0, metavar="N_PHASES",
                    help="drifting-popularity workload with N phases "
                         "(implies --online)")
    ap.add_argument("--straggler-factor", type=float, default=0.0,
                    help="flag replicas slower than FACTOR x fleet "
                         "median step time (0 = off)")
    # fault injection / reliability --------------------------------------- #
    ap.add_argument("--chaos", default="", metavar="SPEC",
                    help="seeded fault storm: comma list of kind[:count] "
                         "over crash, loadfail, straggler, stall, "
                         "disconnect — e.g. 'crash:1,loadfail:2' "
                         "(deterministic per --seed; implies --online)")
    ap.add_argument("--request-timeout", type=float, default=0.0,
                    help="per-request deadline in virtual seconds; "
                         "expired requests are retried with exponential "
                         "backoff on a surviving replica (0 = off; "
                         "implies --online)")
    ap.add_argument("--max-retries", type=int, default=2,
                    help="retry budget per request once --request-timeout "
                         "is armed; exhausted requests are failed and "
                         "counted")
    return ap


def main() -> None:
    args = build_parser().parse_args()

    if args.compare_policies:
        for policy in sorted(POLICIES):
            run_once(args, policy, verbose=False)
    elif args.compare_sched_policies:
        for sched in sorted(SCHED_POLICIES):
            args.sched_policy = sched
            run_once(args, args.policy, verbose=False)
    else:
        run_once(args, args.policy)


if __name__ == "__main__":
    main()

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import: jax locks the device
# count at first init.  (Tests may shrink the placeholder fleet via
# REPRO_DRYRUN_DEVICES before importing this module.)
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

"""Multi-pod dry-run: prove every (architecture x input-shape x mesh) cell
lowers, SPMD-partitions and compiles on the production mesh, and extract
the roofline terms from the compiled artifacts.

Per cell:
  1. FULL compile (scan-over-layers, compact HLO) on the requested mesh ->
     memory_analysis() (fits-on-chip proof) + compile proof.
  2. Two PROBE compiles (reduced depth, all loops unrolled) -> exact
     per-repeat FLOPs / bytes / collective-bytes, linearly extrapolated to
     full depth (see launch/roofline.py).

Usage:
  python -m repro.launch.dryrun --arch phi4-mini-3.8b --shape train_4k
  python -m repro.launch.dryrun --arch all --multi-pod --out dryrun.jsonl
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict

import jax

from ..configs import ARCH_IDS, canonical, get_config
from ..models.config import SHAPES, applicable_shapes
from .mesh import make_production_mesh
from .roofline import RooflineTerms, cost_from_compiled, model_flops_for
from .steps import StepBundle, build_step, cell_id


def _compile(bundle: StepBundle):
    jitted = jax.jit(bundle.fn,
                     in_shardings=bundle.in_shardings,
                     out_shardings=bundle.out_shardings,
                     donate_argnums=bundle.donate_argnums)
    lowered = jitted.lower(*bundle.args)
    compiled = lowered.compile()
    return lowered, compiled


def _memory_report(compiled) -> Dict[str, float]:
    try:
        ma = compiled.memory_analysis()
        if ma is None:
            return {}
        out = {}
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes"):
            if hasattr(ma, attr):
                out[attr] = float(getattr(ma, attr))
        out["total_bytes_per_device"] = (
            out.get("argument_size_in_bytes", 0.0)
            + out.get("output_size_in_bytes", 0.0)
            + out.get("temp_size_in_bytes", 0.0)
            - out.get("alias_size_in_bytes", 0.0))
        return out
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}


def probe_depths(cfg) -> Dict[str, int]:
    plen = len(cfg.block_pattern)
    rem = cfg.n_layers % plen
    full_repeats = cfg.n_layers // plen
    return {"probe1": plen + rem, "probe2": 2 * plen + rem,
            "extra_repeats": full_repeats - 1}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             probes: bool = True, full: bool = True,
             mesh=None, plan_overrides=None) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rec: Dict[str, Any] = {"cell": cell_id(arch, shape_name, multi_pod),
                           "arch": arch, "shape": shape_name,
                           "multi_pod": multi_pod, "ok": False}
    if shape not in applicable_shapes(cfg):
        rec["skipped"] = ("long_500k needs sub-quadratic attention; "
                          f"{cfg.name} is full-attention (see DESIGN.md)")
        rec["ok"] = True
        return rec
    mesh = mesh if mesh is not None else make_production_mesh(
        multi_pod=multi_pod)
    n_chips = mesh.size
    t0 = time.time()
    try:
        if full:
            bundle = build_step(cfg, shape, mesh, unroll=False,
                                plan_overrides=plan_overrides)
            lowered, compiled = _compile(bundle)
            rec["memory"] = _memory_report(compiled)
            rec["full_compile_s"] = round(time.time() - t0, 1)
            del lowered, compiled
        if probes:
            pd = probe_depths(cfg)
            costs = []
            for depth in (pd["probe1"], pd["probe2"]):
                b = build_step(cfg, shape, mesh, unroll=True,
                               layers_override=depth,
                               plan_overrides=plan_overrides)
                lw, cp = _compile(b)
                # collectives only exist post-SPMD-partitioning
                costs.append(cost_from_compiled(cp, cp.as_text()))
                del lw, cp
            cost = costs[0].extrapolate(costs[1], pd["extra_repeats"])
            rec["cost"] = {"flops_per_chip": cost.flops,
                           "bytes_per_chip": cost.bytes_accessed,
                           "collectives": cost.coll}
            mf = model_flops_for(cfg, shape)
            terms = RooflineTerms.from_cost(cost, n_chips, mf)
            rec["roofline"] = {
                "compute_s": terms.compute_s,
                "memory_s": terms.memory_s,
                "collective_s": terms.collective_s,
                "bottleneck": terms.bottleneck,
                "model_flops": mf,
                "hlo_flops_global": terms.hlo_flops_global,
                "useful_ratio": terms.useful_ratio,
                "step_time_s": terms.step_time_s,
                "roofline_fraction": terms.roofline_fraction,
            }
        rec["ok"] = True
        rec["elapsed_s"] = round(time.time() - t0, 1)
    except Exception as e:
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--no-full", action="store_true")
    ap.add_argument("--out", default="")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else [canonical(args.arch)]
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    print(f"mesh: {dict(mesh.shape)} ({mesh.size} chips)")
    records = []
    for arch in archs:
        cfg = get_config(arch)
        shapes = ([s.name for s in applicable_shapes(cfg)]
                  if args.shape == "all" else [args.shape])
        for shape_name in shapes:
            rec = run_cell(arch, shape_name, args.multi_pod,
                           probes=not args.no_probes,
                           full=not args.no_full, mesh=mesh)
            records.append(rec)
            status = "OK " if rec["ok"] else "FAIL"
            extra = ""
            if "memory" in rec:
                extra += (f" mem/dev={rec['memory'].get('total_bytes_per_device', 0) / 2 ** 30:.2f}GiB")
            if "roofline" in rec:
                r = rec["roofline"]
                extra += (f" terms(c/m/t)={r['compute_s']:.3e}/"
                          f"{r['memory_s']:.3e}/{r['collective_s']:.3e}"
                          f" bottleneck={r['bottleneck']}")
            if "skipped" in rec:
                extra = " SKIP: " + rec["skipped"][:60]
            if "error" in rec:
                extra = " ERR: " + rec["error"][:160]
            print(f"{status} {rec['cell']}{extra}", flush=True)
            if args.out:
                with open(args.out, "a") as f:
                    f.write(json.dumps(rec) + "\n")
    n_ok = sum(r["ok"] for r in records)
    print(f"{n_ok}/{len(records)} cells OK")
    if n_ok < len(records):
        raise SystemExit(1)


if __name__ == "__main__":
    main()

"""Mesh construction for single-pod and multi-pod deployments.

All constructors are FUNCTIONS (no module-level device access) so importing
this module never locks the jax device count — required for the dry-run's
``xla_force_host_platform_device_count`` dance.
"""
from __future__ import annotations

import jax

try:                                      # jax >= 0.5
    from jax.sharding import AxisType
except ImportError:                       # older jax: meshes are Auto-typed
    AxisType = None


def _mk(shape, axes):
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """v5e production mesh: 16x16 per pod (256 chips), 2 pods multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_test_mesh(data: int = 2, model: int = 4, pod: int = 0):
    """Small mesh for host-device unit tests (requires the XLA flag)."""
    if pod:
        return _mk((pod, data, model), ("pod", "data", "model"))
    return _mk((data, model), ("data", "model"))


def make_single_device_mesh():
    return _mk((1, 1), ("data", "model"))

"""Roofline analysis from compiled artifacts (no real hardware).

Terms per (arch x shape x mesh), all per chip:
    compute    = HLO_FLOPs / peak_FLOPs          (197 TFLOP/s bf16, v5e)
    memory     = HLO_bytes / HBM_bw              (819 GB/s)
    collective = collective_bytes / link_bw      (~50 GB/s/link ICI)

``cost_analysis`` counts while-loop bodies ONCE, so scanned-layer programs
undercount.  We therefore lower two *probe* variants of each cell with all
inner loops unrolled — depth = (pattern + remainder) and (2x pattern +
remainder) — and extrapolate linearly: probes differ by exactly one
pattern repeat, so  total = probe1 + (repeats_full - 1) * (probe2 - probe1)
is exact.  Collective bytes are parsed from the probes' HLO text (operand
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute), which is loop-free by construction.

Memory feasibility comes from the FULL compile's ``memory_analysis()``.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
LINK_BW = 50e9               # B/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "opaque": 0,
}

_DEF_RE = re.compile(
    r"%?([\w\.\-]+)\s*=\s*\(?([a-z0-9]+)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes of every collective op, by op kind."""
    sizes: Dict[str, int] = {}
    for m in _DEF_RE.finditer(hlo_text):
        name, dtype, dims = m.groups()
        if dtype in _DTYPE_BYTES or dtype.startswith(("f", "s", "u", "b")):
            sizes[name] = _shape_bytes(dtype, dims)
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"%?[\w\.\-]+\s*=\s*[^=]*?\b"
                     r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                     r"collective-permute)(?:-start)?\(", stripped)
        if not m:
            continue
        kind = m.group(1)
        args = stripped[stripped.index("(") + 1:]
        ops = re.findall(r"%?([\w\.\-]+)(?:,|\))", args.split("->")[0])
        total = 0
        for op in ops:
            if op in sizes:
                total += sizes[op]
        out[kind] += total
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


@dataclasses.dataclass
class CellCost:
    flops: float                 # per chip
    bytes_accessed: float        # per chip
    coll: Dict[str, int]         # per chip, by kind

    def extrapolate(self, other: "CellCost", extra_repeats: int
                    ) -> "CellCost":
        """self = 1-repeat probe, other = 2-repeat probe."""
        d_flops = other.flops - self.flops
        d_bytes = other.bytes_accessed - self.bytes_accessed
        coll = {k: int(self.coll.get(k, 0) + extra_repeats
                       * (other.coll.get(k, 0) - self.coll.get(k, 0)))
                for k in set(self.coll) | set(other.coll)}
        return CellCost(self.flops + extra_repeats * d_flops,
                        self.bytes_accessed + extra_repeats * d_bytes,
                        coll)


def cost_from_compiled(compiled, hlo_text: str) -> CellCost:
    ca = compiled.cost_analysis()
    return CellCost(flops=float(ca.get("flops", 0.0)),
                    bytes_accessed=float(ca.get("bytes accessed", 0.0)),
                    coll=collective_bytes(hlo_text))


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float           # 6ND / 2ND analytical, global
    hlo_flops_global: float
    bottleneck: str = ""
    useful_ratio: float = 0.0

    @staticmethod
    def from_cost(cost: CellCost, n_chips: int, model_flops: float
                  ) -> "RooflineTerms":
        c = cost.flops / PEAK_FLOPS
        m = cost.bytes_accessed / HBM_BW
        t = cost.coll.get("total", 0) / LINK_BW
        terms = RooflineTerms(
            compute_s=c, memory_s=m, collective_s=t,
            model_flops=model_flops,
            hlo_flops_global=cost.flops * n_chips)
        terms.bottleneck = max(
            (("compute", c), ("memory", m), ("collective", t)),
            key=lambda kv: kv[1])[0]
        terms.useful_ratio = (model_flops / terms.hlo_flops_global
                              if terms.hlo_flops_global else 0.0)
        return terms

    @property
    def step_time_s(self) -> float:
        """Roofline step time = max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute fraction of the roofline-limited step: the score
        §Perf optimizes.  = (model_flops/chips/peak) / step_time."""
        if self.step_time_s == 0:
            return 0.0
        n_chips = self.hlo_flops_global / max(self.compute_s * PEAK_FLOPS, 1)
        ideal = self.model_flops / max(n_chips, 1) / PEAK_FLOPS
        return ideal / self.step_time_s


def model_flops_for(cfg, shape) -> float:
    """Analytical useful FLOPs for the cell (6ND train, 2ND inference;
    MoE counts active experts only; + attention quadratic term)."""
    n_active = cfg.param_count(active_only=True)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    flops = mult * n_active * tokens
    # attention score/value FLOPs
    hd, nq = cfg.resolved_head_dim, cfg.n_heads
    kinds = cfg.layer_kinds
    for k in kinds:
        if k == "global":
            if shape.kind == "decode":
                flops += mult / 2 * 2 * 2 * shape.global_batch * nq * hd \
                    * shape.seq_len
            else:
                flops += mult / 2 * 2 * 2 * tokens * nq * hd \
                    * shape.seq_len / 2
        elif k == "local":
            w = min(cfg.local_window, shape.seq_len)
            if shape.kind == "decode":
                flops += mult / 2 * 2 * 2 * shape.global_batch * nq * hd * w
            else:
                flops += mult / 2 * 2 * 2 * tokens * nq * hd * w
    return flops

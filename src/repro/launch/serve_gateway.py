"""Open-loop gateway launcher: async serving front-end over one engine
replica (synthetic executor), fed by per-adapter Poisson traffic or a
recorded trace.

Driven mode (default — as fast as the virtual clock allows):

    python -m repro.launch.serve_gateway --adapters 8 --rate 0.5 \\
        --duration 30
    python -m repro.launch.serve_gateway --rate 2.0 --duration 30 \\
        --slo-budget 20                  # arm admission control
    python -m repro.launch.serve_gateway --record-trace /tmp/trace.json
    python -m repro.launch.serve_gateway --trace /tmp/trace.json

Live HTTP mode (OpenAI-style /v1/completions on localhost):

    python -m repro.launch.serve_gateway --http 8080 --duration 60 \\
        --time-scale 10
"""
from __future__ import annotations

import argparse
import asyncio

from ..core.workload import (WorkloadSpec, load_trace, make_adapter_pool,
                             open_loop_arrivals, replay_trace, save_trace)
from ..serving import (AsyncGateway, EngineConfig, GatewayHTTPServer,
                       HardwareProfile, ReliabilityPolicy, ServingEngine,
                       SyntheticExecutor, estimator_admission,
                       parse_chaos_spec)
from ..serving.policy import SCHED_POLICIES


def build_parser() -> argparse.ArgumentParser:
    """The CLI surface (exposed so tools/check_docs.py can cross-check
    documented flags against the real parser)."""
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.serve_gateway",
        description="open-loop async serving gateway over one engine")
    ap.add_argument("--adapters", type=int, default=8)
    ap.add_argument("--rank", type=int, default=8)
    ap.add_argument("--rate", type=float, default=0.5,
                    help="per-adapter Poisson arrival rate (req/s)")
    ap.add_argument("--dataset", default="medium")
    ap.add_argument("--duration", type=float, default=30.0,
                    help="arrival horizon (virtual s); admitted work "
                         "drains past it")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--kv-tokens", type=int, default=0,
                    help="KV capacity override (0 = hardware profile)")
    ap.add_argument("--max-running", type=int, default=256)
    ap.add_argument("--sched-policy", default="fcfs",
                    choices=sorted(SCHED_POLICIES))
    ap.add_argument("--slo-budget", type=float, default=0.0,
                    help="admission control: reject when queue_depth x "
                         "predicted service time exceeds this many "
                         "seconds (0 = admit everything)")
    ap.add_argument("--trace", default="",
                    help="replay a recorded trace instead of Poisson "
                         "arrivals (see --record-trace)")
    ap.add_argument("--record-trace", default="", metavar="PATH",
                    help="save the served arrival stream as JSON for "
                         "later --trace replay")
    ap.add_argument("--http", type=int, default=0, metavar="PORT",
                    help="live mode: serve OpenAI-style /v1/completions "
                         "on this port for --duration wall-clock "
                         "seconds (0 = driven mode)")
    ap.add_argument("--time-scale", type=float, default=1.0,
                    help="live mode: virtual seconds per wall second")
    # fault injection / reliability --------------------------------------- #
    ap.add_argument("--chaos", default="", metavar="SPEC",
                    help="seeded fault storm: comma list of kind[:count] "
                         "over crash, loadfail, straggler, stall, "
                         "disconnect — e.g. 'crash:1,disconnect:2' "
                         "(deterministic per --seed)")
    ap.add_argument("--request-timeout", type=float, default=0.0,
                    help="per-request deadline in virtual seconds; "
                         "expired requests are retried with exponential "
                         "backoff (0 = off)")
    ap.add_argument("--max-retries", type=int, default=2,
                    help="retry budget per request once --request-timeout "
                         "is armed; exhausted requests are failed and "
                         "counted")
    return ap


def build_gateway(args) -> AsyncGateway:
    profile = HardwareProfile()
    ranks = {i: args.rank for i in range(args.adapters)}
    executor = SyntheticExecutor(profile, ranks, slots=args.slots,
                                 n_adapters=args.adapters, seed=args.seed)
    kv = args.kv_tokens or profile.kv_capacity(args.slots, args.rank)
    engine = ServingEngine(EngineConfig(
        kv_capacity_tokens=kv, adapter_slots=args.slots,
        max_running=args.max_running, sched_policy=args.sched_policy),
        executor)
    admission = None
    if args.slo_budget > 0:
        from ..core import collect_benchmark, collect_memmax, fit_estimators
        est = fit_estimators(
            collect_benchmark(executor, args.slots, args.adapters, ranks),
            collect_memmax(profile), args.slots, args.adapters)
        pool = make_adapter_pool(args.adapters, [args.rank], [args.rate])
        stats = WorkloadSpec(adapters=pool,
                             dataset=args.dataset).length_stats()
        admission = estimator_admission(est, stats, args.slo_budget)
    fault_plan = None
    if args.chaos:
        # the arrival stream is lazy, so bound disconnect indices by the
        # expected request count of the Poisson process
        n_expected = max(int(args.adapters * args.rate * args.duration), 1)
        try:
            fault_plan = parse_chaos_spec(
                args.chaos, 1, args.duration, seed=args.seed,
                adapters=list(range(args.adapters)),
                n_requests=n_expected)
        except ValueError as exc:
            raise SystemExit(str(exc))
    reliability = None
    if args.request_timeout > 0:
        reliability = ReliabilityPolicy(
            timeout_s=args.request_timeout, max_retries=args.max_retries,
            load_cost_fn=lambda uid: profile.load_cpu_base
            + profile.load_cpu_per_rank * args.rank)
    return AsyncGateway(engine, admission=admission,
                        time_scale=args.time_scale,
                        fault_plan=fault_plan, reliability=reliability)


def _print_report(report) -> None:
    s = report.summary()
    print(f"[gateway] duration={s['duration_s']:.1f}s virtual | "
          f"throughput={s['throughput_tok_s']:.1f} tok/s | "
          f"ttft p50={s['ttft_p50_ms']:.1f}ms "
          f"p99={s['ttft_p99_ms']:.1f}ms | "
          f"finished={s['n_finished']} starved={s['n_starved']} | "
          f"admitted={s['n_admitted']} rejected={s['n_rejected']} | "
          f"streamed_tokens={s['n_streamed_tokens']}")
    if s["rejected_per_adapter"]:
        worst = sorted(s["rejected_per_adapter"].items(),
                       key=lambda kv: -kv[1])[:5]
        print("  rejections by adapter: "
              + ", ".join(f"{a}:{c}" for a, c in worst))
    if any(s[k] for k in ("n_crashes", "n_recoveries", "n_timeouts",
                          "n_retries", "n_failed_requests",
                          "n_client_disconnects")):
        print(f"  faults: crashes={s['n_crashes']} "
              f"recoveries={s['n_recoveries']} "
              f"timeouts={s['n_timeouts']} retries={s['n_retries']} "
              f"failed={s['n_failed_requests']} "
              f"disconnects={s['n_client_disconnects']}")


async def _run_driven(args, gateway: AsyncGateway):
    if args.trace:
        arrivals = replay_trace(load_trace(args.trace))
    else:
        pool = make_adapter_pool(args.adapters, [args.rank], [args.rate])
        arrivals = open_loop_arrivals(pool, dataset=args.dataset,
                                      horizon=args.duration,
                                      seed=args.seed)
    report = await gateway.run(arrivals, duration=args.duration)
    if args.record_trace:
        save_trace(args.record_trace, gateway.trace)
        print(f"recorded {len(gateway.trace)} arrivals -> "
              f"{args.record_trace}")
    return report


async def _run_live(args, gateway: AsyncGateway):
    await gateway.start()
    server = await GatewayHTTPServer(gateway, port=args.http).start()
    print(f"serving http://127.0.0.1:{server.port}/v1/completions "
          f"for {args.duration:.0f}s wall "
          f"(x{gateway.time_scale:g} virtual)")
    try:
        await asyncio.sleep(args.duration)
    finally:
        await server.stop()
    return await gateway.shutdown()


def main() -> None:
    args = build_parser().parse_args()
    gateway = build_gateway(args)
    runner = _run_live if args.http else _run_driven
    report = asyncio.run(runner(args, gateway))
    _print_report(report)


if __name__ == "__main__":
    main()

"""Serving launcher: run the multi-adapter engine on a reduced model with
the real JAX executor, under a Poisson multi-adapter workload.

    python -m repro.launch.serve --arch phi4-mini-3.8b --adapters 8 \
        --slots 4 --rate 0.5 --horizon 30
"""
from __future__ import annotations

import argparse

import jax

from ..configs import get_reduced
from ..core.workload import WorkloadSpec, generate_requests, make_adapter_pool
from ..models import Model, ShardingPlan
from ..serving import EngineConfig, JaxExecutor, ServingEngine
from ..serving.policy import SCHED_POLICIES


def build_parser() -> argparse.ArgumentParser:
    """The CLI surface (exposed so tools/check_docs.py can cross-check
    documented flags against the real parser)."""
    ap = argparse.ArgumentParser(prog="python -m repro.launch.serve")
    ap.add_argument("--arch", default="phi4-mini-3.8b")
    ap.add_argument("--adapters", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--rate", type=float, default=0.5)
    ap.add_argument("--rank", type=int, default=8)
    ap.add_argument("--horizon", type=float, default=30.0)
    ap.add_argument("--dataset", default="small")
    ap.add_argument("--kv-tokens", type=int, default=4096)
    ap.add_argument("--sched-policy", default="fcfs",
                    choices=sorted(SCHED_POLICIES),
                    help="admission/preemption scheduling policy")
    return ap


def main() -> None:
    args = build_parser().parse_args()

    cfg = get_reduced(args.arch)
    model = Model(cfg, ShardingPlan(mode="decode"))
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    lora = model.init_lora(key, max(args.slots, 1), args.rank)
    executor = JaxExecutor(model, params, lora, max_batch=8, cache_len=512)

    pool = make_adapter_pool(args.adapters, [args.rank], [args.rate])
    spec = WorkloadSpec(adapters=pool, dataset=args.dataset,
                        horizon=args.horizon)
    reqs = generate_requests(spec)
    engine = ServingEngine(EngineConfig(
        kv_capacity_tokens=args.kv_tokens, adapter_slots=args.slots,
        sched_policy=args.sched_policy),
        executor)
    m = engine.run(reqs, horizon=args.horizon)
    print(f"served {m.n_finished} requests | throughput={m.throughput:.1f} "
          f"tok/s (ideal {m.ideal_throughput:.1f}) | itl={m.itl * 1e3:.1f}ms "
          f"| ttft={m.ttft * 1e3:.1f}ms "
          f"(p50 {m.ttft_p50 * 1e3:.1f} / p99 {m.ttft_p99 * 1e3:.1f}) "
          f"| preemptions={m.n_preemptions} "
          f"| loads={m.n_loads} | starved={m.starved} "
          f"| starved_reqs={m.n_starved_requests}")
    if m.starved_per_adapter:
        worst = sorted(m.starved_per_adapter.items(),
                       key=lambda kv: -kv[1])[:5]
        print("  starved requests by adapter: "
              + ", ".join(f"{a}:{c}" for a, c in worst))


if __name__ == "__main__":
    main()

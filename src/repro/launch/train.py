"""Training launcher.

CPU demo:          python -m repro.launch.train --arch phi4-mini-3.8b \
                       --reduced --steps 50 --batch 8 --seq 128
Production lower:  the dry-run (launch/dryrun.py) lowers this exact step
                   on the 16x16 / 2x16x16 meshes.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get_config, get_reduced
from ..data import DataConfig, TokenPipeline
from ..models import Model, ShardingPlan
from ..training import (AdamWConfig, TrainConfig, init_train_state,
                        make_train_step)
from .fault_tolerance import FTConfig, FaultTolerantLoop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi4-mini-3.8b")
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized config (smoke/demo)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=25)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    model = Model(cfg, ShardingPlan(mode="train"))
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=args.lr, warmup_steps=10))
    step_fn = jax.jit(make_train_step(model, tcfg))
    pipe = TokenPipeline(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, n_image_tokens=cfg.n_image_tokens,
        d_model=cfg.d_model))

    params, opt = init_train_state(model, jax.random.PRNGKey(0), tcfg)
    state = {"params": params, "opt": opt}
    ft = FaultTolerantLoop(
        FTConfig(args.checkpoint_dir,
                 checkpoint_every=args.checkpoint_every), state)
    state = ft.resume_or_init(lambda: state)
    start = ft.mgr.latest_step() or 0
    if start:
        print(f"resumed from step {start}")

    def one(state, batch):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        if "img_embeds" in batch:
            batch["img_embeds"] = batch["img_embeds"].astype(cfg.jnp_dtype)
        p, o, info = step_fn(state["params"], state["opt"], batch)
        one.last_info = info
        return {"params": p, "opt": o}

    t0 = time.time()
    for step in range(start, args.steps):
        state = one(state, pipe.batch_at(step))
        if step % 10 == 0 or step == args.steps - 1:
            info = one.last_info
            print(f"step {step:5d} loss={float(info['loss']):.4f} "
                  f"gnorm={float(info['grad_norm']):.3f} "
                  f"({(time.time() - t0):.1f}s)", flush=True)
        if (step + 1) % args.checkpoint_every == 0:
            ft.mgr.save(step + 1, state)
    ft.mgr.save(args.steps, state)
    ft.mgr.wait()
    print(f"done: {args.steps} steps in {time.time() - t0:.1f}s; "
          f"checkpoints at {args.checkpoint_dir}")


if __name__ == "__main__":
    main()

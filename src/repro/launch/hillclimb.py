import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb driver: re-measure one cell under a named plan variant
and append the record (with the variant tag) to a JSONL log.

    python -m repro.launch.hillclimb --cell phi4_mini_3p8b:decode_32k \
        --variant kv_int8 --out hillclimb.jsonl
"""
import argparse
import json

VARIANTS = {
    "baseline": {},
    "kv_int8": {"kv_quant": True},
    "attn_batch": {"attn_batch_shard": True},
    "attn_batch+kv_int8": {"attn_batch_shard": True, "kv_quant": True},
    "no_remat": {"remat": False},
    "attn_batch+no_remat": {"attn_batch_shard": True, "remat": False},
    "kv_int8+w8_experts": {"kv_quant": True, "expert_quant": True},
}


def main() -> None:
    from .dryrun import run_cell  # after XLA flags
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True)   # arch:shape
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--no-full", action="store_true")
    ap.add_argument("--out", default="hillclimb.jsonl")
    args = ap.parse_args()
    arch, shape = args.cell.split(":")
    rec = run_cell(arch, shape, False, probes=True, full=not args.no_full,
                   plan_overrides=VARIANTS[args.variant] or None)
    rec["variant"] = args.variant
    with open(args.out, "a") as f:
        f.write(json.dumps(rec) + "\n")
    r = rec.get("roofline", {})
    m = rec.get("memory", {}).get("total_bytes_per_device", 0) / 2 ** 30
    status = "OK " if rec.get("ok") else "FAIL " + rec.get("error", "")[:200]
    print(f"{status} {args.cell} [{args.variant}] mem/dev={m:.2f}GiB "
          f"c/m/t={r.get('compute_s', 0):.3e}/{r.get('memory_s', 0):.3e}/"
          f"{r.get('collective_s', 0):.3e}")


if __name__ == "__main__":
    main()

"""Sharded checkpointing with atomic commits, async writes, retention, and
elastic restore (reshard on load).

Layout: ``<dir>/step_<n>/`` with one ``.npy`` per pytree leaf (path-encoded
filename) + ``manifest.json`` (treedef, shapes, dtypes, step, user
metadata).  A ``_COMMITTED`` sentinel makes partially written checkpoints
invisible to ``latest_step`` — a crash mid-save can never corrupt restore
(the fault-tolerance contract the multi-pod launcher relies on).

On a multi-host deployment each host writes only the leaves it owns
(addressable shards); here (single process) that degenerates to the whole
tree — the format and protocol are identical.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional

import jax
import ml_dtypes
import numpy as np

_NATIVE = set("bool int8 int16 int32 int64 uint8 uint16 uint32 uint64 "
              "float16 float32 float64".split())


def _flatten(tree, prefix=()):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], prefix + (str(k),)))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, prefix + (str(i),)))
    else:
        out["/".join(prefix)] = tree
    return out


def _unflatten_into(skeleton, flat, prefix=()):
    if isinstance(skeleton, dict):
        return {k: _unflatten_into(v, flat, prefix + (str(k),))
                for k, v in skeleton.items()}
    if isinstance(skeleton, tuple):
        return tuple(_unflatten_into(v, flat, prefix + (str(i),))
                     for i, v in enumerate(skeleton))
    if isinstance(skeleton, list):
        return [_unflatten_into(v, flat, prefix + (str(i),))
                for i, v in enumerate(skeleton)]
    return flat["/".join(prefix)]


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ #
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def steps(self) -> List[int]:
        self.wait()  # join any in-flight async write before listing
        out = []
        for name in os.listdir(self.dir):
            path = os.path.join(self.dir, name)
            if name.startswith("step_") and \
                    os.path.exists(os.path.join(path, "_COMMITTED")):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    # ------------------------------------------------------------------ #
    def wait(self) -> None:
        t = self._thread
        if t is not None and t is not threading.current_thread():
            t.join()
            self._thread = None

    def save(self, step: int, tree: Dict[str, Any],
             metadata: Optional[dict] = None) -> None:
        # snapshot to host memory synchronously (cheap), write async
        flat = _flatten(tree)
        host = {k: np.asarray(v) for k, v in flat.items()}
        self.wait()

        def _write():
            tmp = self._step_dir(step) + ".tmp"
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp)
            manifest = {"step": step, "metadata": metadata or {},
                        "leaves": {}}
            for k, v in host.items():
                fname = k.replace("/", "__") + ".npy"
                logical = str(v.dtype)
                if logical not in _NATIVE:
                    # e.g. bfloat16: store the raw bits, tag logical dtype
                    np.save(os.path.join(tmp, fname),
                            v.view(np.uint16 if v.dtype.itemsize == 2
                                   else np.uint8))
                else:
                    np.save(os.path.join(tmp, fname), v)
                manifest["leaves"][k] = {
                    "file": fname, "shape": list(v.shape),
                    "dtype": logical}
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            with open(os.path.join(tmp, "_COMMITTED"), "w") as f:
                f.write("ok")
            final = self._step_dir(step)
            shutil.rmtree(final, ignore_errors=True)
            os.rename(tmp, final)
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------------ #
    def restore(self, skeleton: Dict[str, Any], step: Optional[int] = None,
                shardings: Optional[Any] = None) -> Dict[str, Any]:
        """Restore into `skeleton`'s structure; optionally device_put with
        new shardings (elastic resharding: the mesh may have changed)."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError("no committed checkpoint")
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        flat = {}
        for k, info in manifest["leaves"].items():
            arr = np.load(os.path.join(d, info["file"]))
            if info["dtype"] not in _NATIVE:
                arr = arr.view(np.dtype(getattr(ml_dtypes, info["dtype"])))
            flat[k] = arr
        tree = _unflatten_into(skeleton, flat)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        return tree

    def metadata(self, step: Optional[int] = None) -> dict:
        step = step if step is not None else self.latest_step()
        with open(os.path.join(self._step_dir(step), "manifest.json")) as f:
            return json.load(f)["metadata"]

from .manager import CheckpointManager  # noqa

"""repro: multi-tenant LLM-adapter serving framework in JAX.

Implements "A Data-driven ML Approach for Maximizing Performance in
LLM-Adapter Serving" (Agullo et al., 2025) and grows it to a fleet.
Three layers (see docs/architecture.md):

  * engine       — ``repro.serving``: continuous-batching multi-LoRA
                   engine (scheduler, paged KV, adapter slots) with two
                   front-ends: the async open-loop gateway
                   (``repro.serving.gateway``: live arrivals, SSE
                   streaming, admission control, an OpenAI-style HTTP
                   binding) and the cluster (``ClusterRouter`` routing
                   policies, the epoch-driven online loop with
                   heartbeats/failover, the EWMA adapter rebalancer);
  * digital twin — ``repro.core``: Eq. (1) estimators fitted from
                   engine benchmarks, single-node and cluster twins,
                   placement search, interpretable placement models;
  * substrate    — ``repro.models`` / ``repro.kernels`` /
                   ``repro.training``: reduced JAX model zoo, Pallas
                   kernels, training + fault-tolerant checkpointing.
"""
__version__ = "1.0.0"

"""repro: multi-tenant LLM-adapter serving framework in JAX.

Implements "A Data-driven ML Approach for Maximizing Performance in
LLM-Adapter Serving" (Agullo et al., 2025): a Digital Twin of an online
LLM-adapter serving system plus an ML placement pipeline, on top of a
production-grade JAX serving/training substrate with Pallas TPU kernels.
"""
__version__ = "1.0.0"

"""Mamba2 / SSD (state-space duality) mixer.

Chunked SSD algorithm (arXiv:2405.21060): within a chunk the output is a
masked quadratic form (MXU-friendly); across chunks a linear recurrence on
the (heads, head_dim, state) tensor carries history.  Heads are independent,
so the block shards cleanly head→``model`` with zero collectives inside the
mixer; only the in/out projections touch the sharded width.  Projections are
kept as separate parameters (w_z / w_x / w_bc / w_dt) so each shards on
exactly one dimension — no slicing across shard boundaries.

Decode is the O(1) recurrent update on the carried state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers


def ssd_dims(cfg):
    d_inner = cfg.d_inner
    n_heads = d_inner // cfg.ssm_head_dim
    return d_inner, n_heads, cfg.ssm_head_dim, cfg.ssm_state


def init_ssd(key, cfg, dtype, stack: tuple = ()):
    d = cfg.d_model
    d_inner, n_heads, hd, state = ssd_dims(cfg)
    ks = jax.random.split(key, 7)
    return {
        "w_z": layers.dense_init(ks[0], (*stack, d, d_inner), dtype),
        "w_x": layers.dense_init(ks[1], (*stack, d, d_inner), dtype),
        "w_bc": layers.dense_init(ks[2], (*stack, d, 2 * state), dtype),
        "w_dt": layers.dense_init(ks[3], (*stack, d, n_heads), dtype),
        "w_out": layers.dense_init(ks[4], (*stack, d_inner, d), dtype,
                                   fan_in=d_inner),
        "conv_x": (jax.random.normal(ks[5], (*stack, cfg.conv_width, d_inner),
                                     jnp.float32) * 0.1).astype(dtype),
        "conv_bc": (jax.random.normal(ks[6], (*stack, cfg.conv_width, 2 * state),
                                      jnp.float32) * 0.1).astype(dtype),
        "a_log": jnp.zeros((*stack, n_heads), jnp.float32),
        "dt_bias": jnp.full((*stack, n_heads), -2.0, jnp.float32),
        "d_skip": jnp.ones((*stack, n_heads), jnp.float32),
    }


def causal_conv(x, w, state=None, activate: bool = True):
    """Depthwise causal conv. x: (B,S,C); w: (W,C); state: (B,W-1,C)|None.

    Returns (out, new_state) where new_state holds the last W-1 inputs.
    """
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None, :].astype(x.dtype)
              for i in range(width))
    new_state = xp[:, -(width - 1):] if width > 1 else None
    if activate:
        out = jax.nn.silu(out.astype(jnp.float32)).astype(x.dtype)
    return out, new_state


def ssd_chunked(x, b, c, dt, a_log, *, chunk: int, unroll: bool = False,
                init_state=None):
    """Chunked SSD scan.

    x: (B,S,H,P) values; b,c: (B,S,N); dt: (B,S,H) (post-softplus);
    a_log: (H,).  Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    bs, s, h, p = x.shape
    n = b.shape[-1]
    chunk = min(chunk, s)
    while s % chunk:
        chunk -= 1
    nc = s // chunk
    q = chunk

    a = -jnp.exp(a_log)                                   # (H,) negative
    dta = dt * a[None, None, :]                           # (B,S,H)
    xr = x.reshape(bs, nc, q, h, p)
    br = b.reshape(bs, nc, q, n).astype(jnp.float32)
    cr = c.reshape(bs, nc, q, n).astype(jnp.float32)
    dtr = dt.reshape(bs, nc, q, h)
    dtar = dta.reshape(bs, nc, q, h)

    cum = jnp.cumsum(dtar, axis=2)                        # (B,nc,q,H)
    seg_sum = cum[:, :, -1]                               # (B,nc,H)
    decay_to_end = jnp.exp(seg_sum[:, :, None] - cum)     # (B,nc,q,H)
    # contribution of the incoming state to token i decays by a_1..a_i
    decay_from_start = jnp.exp(cum)                       # (B,nc,q,H)

    # intra-chunk: L[i,j] = exp(cum_i - cum_j) for i >= j, weighted by dt_j
    li = cum[:, :, :, None, :] - cum[:, :, None, :, :]    # (B,nc,q,q,H)
    causal = jnp.tril(jnp.ones((q, q), bool))
    lmat = jnp.where(causal[None, None, :, :, None], jnp.exp(li), 0.0)
    cb = jnp.einsum("bcin,bcjn->bcij", cr, br)            # (B,nc,q,q)
    gates = cb[..., None] * lmat * dtr[:, :, None, :, :]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", gates, xr.astype(jnp.float32))

    # per-chunk contributed state: (B,nc,H,P,N)
    xdt = xr.astype(jnp.float32) * (dtr * decay_to_end)[..., None]
    chunk_states = jnp.einsum("bcqhp,bcqn->bchpn", xdt, br)

    # inter-chunk recurrence
    decay_chunk = jnp.exp(seg_sum)                        # (B,nc,H)
    s0 = (jnp.zeros((bs, h, p, n), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(carry, xs):
        dchunk, cstate = xs
        new = carry * dchunk[:, :, None, None] + cstate
        return new, carry                                 # emit state BEFORE chunk

    xs = (decay_chunk.swapaxes(0, 1), chunk_states.swapaxes(0, 1))
    if unroll:
        carry, prev = s0, []
        for i in range(nc):
            carry, out = step(carry, (xs[0][i], xs[1][i]))
            prev.append(out)
        prev = jnp.stack(prev)
    else:
        carry, prev = jax.lax.scan(step, s0, xs)
    prev = prev.swapaxes(0, 1)                            # (B,nc,H,P,N)

    y_inter = jnp.einsum("bcqn,bchpn->bcqhp", cr, prev)
    y_inter = y_inter * decay_from_start[..., None]
    y = (y_intra + y_inter).reshape(bs, s, h, p)
    return y, carry


def apply_ssd(p, x, cfg, *, chunk: int = 0, unroll: bool = False,
              conv_state=None, ssm_state=None):
    """Full Mamba2 mixer body (norm handled by the caller).

    Train/prefill: x (B,S,d) -> (y (B,S,d), (conv_x, conv_bc, ssm_state)).
    Decode: S == 1 and states provided -> O(1) update.
    conv_state (when decoding) is a tuple (conv_x_state, conv_bc_state).
    """
    d_inner, n_heads, hd, state = ssd_dims(cfg)
    bs, s, _ = x.shape
    z = jnp.einsum("bsd,dk->bsk", x, p["w_z"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    xi = jnp.einsum("bsd,dk->bsk", x, p["w_x"],
                    preferred_element_type=jnp.float32).astype(x.dtype)
    bc = jnp.einsum("bsd,dk->bsk", x, p["w_bc"],
                    preferred_element_type=jnp.float32).astype(x.dtype)
    dt = jnp.einsum("bsd,dk->bsk", x, p["w_dt"],
                    preferred_element_type=jnp.float32)
    dt = jax.nn.softplus(dt + p["dt_bias"])

    decode = ssm_state is not None and s == 1
    cx_state, cbc_state = conv_state if decode else (None, None)
    xi, new_cx = causal_conv(xi, p["conv_x"], state=cx_state)
    bc, new_cbc = causal_conv(bc, p["conv_bc"], state=cbc_state)
    xi = xi.reshape(bs, s, n_heads, hd)
    b = bc[..., :state]
    c = bc[..., state:]

    if decode:
        a = -jnp.exp(p["a_log"])
        da = jnp.exp(dt[:, 0] * a[None, :])               # (B,H)
        upd = jnp.einsum("bhp,bn->bhpn",
                         (xi[:, 0] * dt[:, 0, :, None]).astype(jnp.float32),
                         b[:, 0].astype(jnp.float32))
        new_state = ssm_state.astype(jnp.float32) * da[:, :, None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", c[:, 0].astype(jnp.float32), new_state)
        y = y[:, None]                                    # (B,1,H,P)
    else:
        y, new_state = ssd_chunked(xi, b, c, dt, p["a_log"],
                                   chunk=chunk or cfg.ssm_chunk,
                                   unroll=unroll, init_state=ssm_state)

    y = y + xi.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(bs, s, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)  # gate
    out = jnp.einsum("bsk,kd->bsd", y, p["w_out"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return out, (new_cx, new_cbc, new_state.astype(jnp.float32))

"""Primitive layers shared by every architecture family.

All parameters are plain dict pytrees; all functions are pure.  Matmuls
accumulate in fp32 (``preferred_element_type``) — the MXU-native convention.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

# --------------------------------------------------------------------------- #
# init helpers
# --------------------------------------------------------------------------- #

def dense_init(key, shape, dtype, fan_in: Optional[int] = None):
    fan_in = fan_in if fan_in is not None else shape[-2]
    std = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# --------------------------------------------------------------------------- #
# norms
# --------------------------------------------------------------------------- #

def init_norm(kind: str, width: int, dtype):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((width,), dtype)}
    return {"scale": jnp.ones((width,), dtype), "bias": jnp.zeros((width,), dtype)}


def apply_norm(kind: str, p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    else:  # layernorm
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps)
        out = out * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------- #
# positional embeddings
# --------------------------------------------------------------------------- #

def rope_freqs(head_dim: int, theta: float):
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)  # (head_dim//2,)


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    angles = angles[..., None, :]                              # (..., S, 1, D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos_emb(positions, width: int):
    """positions: (..., S) -> (..., S, width)."""
    half = width // 2
    freqs = jnp.exp(-math.log(10_000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(angles), jnp.cos(angles)], axis=-1)


# --------------------------------------------------------------------------- #
# MLP (dense FFN)
# --------------------------------------------------------------------------- #

def init_mlp(key, kind: str, d_model: int, d_ff: int, dtype, stack: tuple = ()):
    ks = jax.random.split(key, 3)
    if kind == "swiglu":
        return {
            "w_gate": dense_init(ks[0], (*stack, d_model, d_ff), dtype),
            "w_up": dense_init(ks[1], (*stack, d_model, d_ff), dtype),
            "w_down": dense_init(ks[2], (*stack, d_ff, d_model), dtype, fan_in=d_ff),
        }
    return {
        "w_up": dense_init(ks[0], (*stack, d_model, d_ff), dtype),
        "w_down": dense_init(ks[1], (*stack, d_ff, d_model), dtype, fan_in=d_ff),
    }


def apply_mlp(kind: str, p, x):
    if kind == "swiglu":
        gate = jnp.einsum("...d,df->...f", x, p["w_gate"],
                          preferred_element_type=jnp.float32)
        up = jnp.einsum("...d,df->...f", x, p["w_up"],
                        preferred_element_type=jnp.float32)
        h = (jax.nn.silu(gate) * up).astype(x.dtype)
    else:
        up = jnp.einsum("...d,df->...f", x, p["w_up"],
                        preferred_element_type=jnp.float32)
        h = jax.nn.gelu(up).astype(x.dtype)
    out = jnp.einsum("...f,fd->...d", h, p["w_down"],
                     preferred_element_type=jnp.float32)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------- #
# embedding / unembedding
# --------------------------------------------------------------------------- #

def pad_vocab(v: int, multiple: int = 256) -> int:
    return ((v + multiple - 1) // multiple) * multiple


def init_embed(key, vocab: int, d_model: int, dtype, tie: bool):
    ks = jax.random.split(key, 2)
    p = {"embed": embed_init(ks[0], (pad_vocab(vocab), d_model), dtype)}
    if not tie:
        p["unembed"] = dense_init(ks[1], (d_model, pad_vocab(vocab)), dtype)
    return p


def embed_tokens(p, tokens):
    return jnp.take(p["embed"], tokens, axis=0)


def unembed(p, x, softcap: float = 0.0):
    if "unembed" in p:
        logits = jnp.einsum("...d,dv->...v", x, p["unembed"],
                            preferred_element_type=jnp.float32)
    else:
        logits = jnp.einsum("...d,vd->...v", x, p["embed"],
                            preferred_element_type=jnp.float32)
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    return logits


def cross_entropy(logits, labels, mask=None):
    """logits (..., V) fp32; labels int (...,). Returns mean loss."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(nll.dtype)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)

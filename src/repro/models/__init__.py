from .config import ModelConfig, ShapeConfig, SHAPES, applicable_shapes  # noqa
from .sharding import ShardingPlan, make_plan  # noqa
from .transformer import Model, build_segments  # noqa

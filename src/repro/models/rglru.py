"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

The recurrence h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t) is a
per-channel gated linear recurrence: channels are independent, so the block
shards width→``model`` with no collectives in the mixer.  Following Griffin,
the recurrence/input gates use *block-diagonal* weights (``n_gate_blocks``
blocks) — which also makes them embarrassingly shardable.  Training/prefill
uses ``jax.lax.associative_scan`` (log-depth, fully counted by HLO cost
analysis); decode is the O(1) update.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers
from .ssm import causal_conv

C_RGLRU = 8.0
N_GATE_BLOCKS = 16


def lru_width(cfg) -> int:
    return cfg.lru_width or cfg.d_model


def init_rglru(key, cfg, dtype, stack: tuple = ()):
    d = cfg.d_model
    w = lru_width(cfg)
    nb = N_GATE_BLOCKS
    wb = w // nb
    ks = jax.random.split(key, 6)
    return {
        "w_x": layers.dense_init(ks[0], (*stack, d, w), dtype),
        "w_gate": layers.dense_init(ks[1], (*stack, d, w), dtype),
        "conv_w": (jax.random.normal(ks[2], (*stack, cfg.conv_width, w),
                                     jnp.float32) * 0.1).astype(dtype),
        "w_a": layers.dense_init(ks[3], (*stack, nb, wb, wb), dtype, fan_in=wb),
        "w_i": layers.dense_init(ks[4], (*stack, nb, wb, wb), dtype, fan_in=wb),
        "a_param": jnp.full((*stack, w), 1.0, jnp.float32),
        "w_out": layers.dense_init(ks[5], (*stack, w, d), dtype, fan_in=w),
    }


def _block_gate(u, w):
    """Block-diagonal linear: u (B,S,W), w (nb, wb, wb) -> (B,S,W) fp32.

    Computed in fp32: gate precision matters for the recurrence, and the
    CPU backend lacks a batched bf16xbf16->f32 dot (TPU MXU has it natively).
    """
    b, s, width = u.shape
    nb, wb, _ = w.shape
    ub = u.reshape(b, s, nb, wb).astype(jnp.float32)
    out = jnp.einsum("bsnw,nwk->bsnk", ub, w.astype(jnp.float32))
    return out.reshape(b, s, width)


def rglru_scan(a, bx, h0=None):
    """h_t = a_t * h_{t-1} + bx_t via associative scan. a/bx: (B,S,W) fp32."""
    def combine(u, v):
        a1, b1 = u
        a2, b2 = v
        return a2 * a1, a2 * b1 + b2
    if h0 is not None:
        bx = bx.at[:, 0].add(a[:, 0] * h0)
    _, bv = jax.lax.associative_scan(combine, (a, bx), axis=1)
    return bv                                              # (B,S,W) = h_t


def apply_rglru(p, x, *, conv_state=None, lru_state=None):
    """x: (B,S,d) -> (y (B,S,d), (conv_state, lru_state))."""
    gate_b = jnp.einsum("bsd,dw->bsw", x, p["w_gate"],
                        preferred_element_type=jnp.float32)
    gate_b = jax.nn.gelu(gate_b).astype(x.dtype)

    u = jnp.einsum("bsd,dw->bsw", x, p["w_x"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    decode = lru_state is not None and x.shape[1] == 1
    u, new_conv = causal_conv(u, p["conv_w"],
                              state=conv_state if decode else None,
                              activate=False)

    r = jax.nn.sigmoid(_block_gate(u, p["w_a"]))
    i = jax.nn.sigmoid(_block_gate(u, p["w_i"]))
    log_a = -C_RGLRU * jax.nn.softplus(p["a_param"])[None, None, :] * r
    a = jnp.exp(log_a)
    gated_x = u.astype(jnp.float32) * i
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9)) * gated_x

    if decode:
        h = a[:, 0] * lru_state.astype(jnp.float32) + b[:, 0]
        new_state = h
        h = h[:, None]
    else:
        h = rglru_scan(a, b, None if lru_state is None
                       else lru_state.astype(jnp.float32))
        new_state = h[:, -1]

    y = h.astype(x.dtype) * gate_b
    out = jnp.einsum("bsw,wd->bsd", y, p["w_out"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return out, (new_conv, new_state.astype(jnp.float32))

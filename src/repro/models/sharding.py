"""Sharding plans: how each (family × step-kind) maps onto the mesh.

Baseline layouts (see EXPERIMENTS.md §Perf for the hillclimbed variants):

  * attention-family **train/prefill**: batch→batch_axes, seq→``seq_axis``
    (ring attention over `model`), weights fully sharded over
    (data×model) on their largest dim (ZeRO-3 / FSDP — all-gathered per
    layer, overlappable on TPU), optimizer state sharded identically.
  * ssm/hybrid **train**: width→``width_axis`` TP (heads / LRU channels are
    embarrassingly parallel) + FSDP over `data` on the other weight dim.
  * all **decode**: batch→batch_axes, weights row/col-sharded over
    ``width_axis`` (resident TP — no per-step weight gathers), KV cache
    seq-sharded over ``cache_seq_axes`` with LSE-combined partial attention
    (supports every GQA kv-head count, incl. kv=1); for global_batch=1
    (long_500k) the cache seq-shards over BOTH (data, model).

All specs are produced here so a hillclimb iteration is a plan edit, not a
model edit.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingPlan:
    mesh: Optional[Mesh] = None
    batch_axes: Tuple[str, ...] = ()       # activation batch dim
    seq_axis: str = ""                     # activation seq dim (train/prefill)
    width_axis: str = ""                   # TP width axis (ssm/hybrid, serve)
    fsdp_axes: Tuple[str, ...] = ()        # weight-shard axes (train)
    cache_seq_axes: Tuple[str, ...] = ()   # KV-cache seq dim (serve)
    kv_quant: bool = False                 # int8 KV cache (beyond-paper)
    expert_quant: bool = False             # weight-only int8 experts (serve)
    attn_batch_shard: bool = False         # reshard attn batch over seq axis
                                           # (kills ring traffic when
                                           #  B % (data*model) == 0)
    remat: bool = False
    unroll: bool = False                   # analysis mode: unroll inner loops
    mode: str = "train"                    # train | prefill | decode

    # ------------------------------------------------------------------ #
    def axis_size(self, *names: str) -> int:
        if self.mesh is None:
            return 1
        n = 1
        for name in names:
            if name:
                n *= self.mesh.shape[name]
        return n

    @property
    def n_seq(self) -> int:
        return self.axis_size(self.seq_axis)

    @property
    def n_width(self) -> int:
        return self.axis_size(self.width_axis)

    @property
    def n_cache(self) -> int:
        return self.axis_size(*self.cache_seq_axes)

    def _fits(self, dim: int, axes) -> bool:
        axes = axes if isinstance(axes, tuple) else (axes,)
        return dim % max(self.axis_size(*axes), 1) == 0

    # ------------------------------------------------------------------ #
    def dp(self):
        return self.batch_axes if self.batch_axes else None

    def act_spec(self, ndim: int = 3):
        """(B, S, d) activation spec."""
        seq = self.seq_axis or None
        return P(self.dp(), seq, *([None] * (ndim - 2)))

    def constrain(self, x, spec=None):
        if self.mesh is None:
            return x
        spec = spec if spec is not None else self.act_spec(x.ndim)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))

    # ------------------------------------------------------------------ #
    # parameter specs
    # ------------------------------------------------------------------ #
    _COL = ("w_gate", "w_up", "w_z", "w_x", "w_dt", "wq", "wk", "wv",
            "unembed", "w_gate_in")
    _ROW = ("w_down", "w_out", "wo")
    _SMALL = ("router", "scale", "bias", "a_log", "dt_bias", "d_skip",
              "a_param", "w_bc", "conv_bc", "bq", "bk", "bv")

    def param_spec(self, path: Tuple[str, ...], shape: Tuple[int, ...]):
        name = path[-1]
        is_expert = "moe" in path
        is_lora = "lora" in path or name.startswith(("a_", "b_")) and \
            name in ("a_q", "b_q", "a_v", "b_v")
        nd = len(shape)
        none = [None] * nd

        if is_lora:
            return P(*none)
        if name in self._SMALL and not is_expert:
            return P(*none)

        w = self.width_axis or None

        if is_expert and name in ("w_gate", "w_up", "w_down", "router",
                                  "w_gate_scale", "w_up_scale",
                                  "w_down_scale"):
            if name == "router":
                return P(*none)
            if name.endswith("_scale"):     # (R?, E, 1, ff): E over EP axis
                spec = list(none)
                ep = (self.width_axis or self.seq_axis) or None
                if ep and self._fits(shape[nd - 3], ep):
                    spec[nd - 3] = ep
                return P(*spec)
            # (R?, E, d, ff) / (R?, E, ff, d): experts over EP axis
            spec = list(none)
            ep = (self.width_axis or self.seq_axis) or None
            e_dim = nd - 3
            if ep and self._fits(shape[e_dim], ep):
                spec[e_dim] = ep
            if self.fsdp_axes:
                ff_dim = nd - 1 if name != "w_down" else nd - 2
                if spec[ff_dim] is None and self._fits(shape[ff_dim], "data"):
                    spec[ff_dim] = "data"
            return P(*spec)

        spec = list(none)
        if name in ("w_a", "w_i"):  # (R?, nb, wb, wb) block-diagonal gates
            if w and self._fits(shape[nd - 3], w):
                spec[nd - 3] = w
            return P(*spec)
        if name in ("conv_x", "conv_w"):
            if w and self._fits(shape[nd - 1], w):
                spec[nd - 1] = w
            return P(*spec)
        if name == "embed":
            if self.mode == "train" and self.fsdp_axes and \
                    self._fits(shape[0], self.fsdp_axes):
                return P(self.fsdp_axes, None)
            return P(*none)

        if w and name in self._COL and self._fits(shape[nd - 1], w):
            spec[nd - 1] = w
        elif w and name in self._ROW and self._fits(shape[nd - 2], w):
            spec[nd - 2] = w

        if self.mode == "train" and self.fsdp_axes:
            # FSDP: shard the largest still-unsharded dim
            cands = sorted(range(max(nd - 2, 0), nd),
                           key=lambda i: -shape[i])
            for i in cands:
                if spec[i] is None and self._fits(shape[i], self.fsdp_axes):
                    spec[i] = self.fsdp_axes
                    break
        return P(*spec)

    def param_specs(self, params):
        def walk(path, leaf):
            names = tuple(
                p.key if hasattr(p, "key") else str(p) for p in path)
            return self.param_spec(names, leaf.shape)
        return jax.tree_util.tree_map_with_path(walk, params)

    def shardings(self, tree_of_specs):
        if self.mesh is None:
            return None
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s),
                            tree_of_specs,
                            is_leaf=lambda x: isinstance(x, P))

    # ------------------------------------------------------------------ #
    # cache specs
    # ------------------------------------------------------------------ #
    def cache_spec(self, path: Tuple[str, ...], shape: Tuple[int, ...]):
        name = path[-1]
        nd = len(shape)
        if name == "pos":
            return P()
        dp = self.dp()
        w = self.width_axis or None
        cache_seq = self.cache_seq_axes if self.cache_seq_axes else None
        if name in ("k", "v"):             # (R, B, S, KV, D) global layers
            return P(None, dp, cache_seq, None, None)
        if name in ("k_scale", "v_scale"):  # (R, B, S, KV) int8-KV scales
            return P(None, dp, cache_seq, None)
        if name in ("k_loc", "v_loc"):     # (R, B, W, KV, D) rolling
            return P(None, dp, None, None, None)
        if name in ("conv_x", "conv"):     # (R, B, cw-1, C@width)
            sp = [None] * nd
            sp[1] = dp
            if w and shape[-1] % max(self.axis_size(w), 1) == 0:
                sp[-1] = w
            return P(*sp)
        if name == "conv_bc":
            return P(None, dp, None, None)
        if name == "ssm":                  # (R, B, H@width, p, n)
            sp = [None, dp, None, None, None]
            if w and shape[2] % max(self.axis_size(w), 1) == 0:
                sp[2] = w
            return P(*sp)
        if name == "lru":                  # (R, B, W@width)
            sp = [None, dp, None]
            if w and shape[2] % max(self.axis_size(w), 1) == 0:
                sp[2] = w
            return P(*sp)
        return P(*([None] * nd))

    def cache_specs(self, cache):
        def walk(path, leaf):
            names = tuple(
                p.key if hasattr(p, "key") else str(p) for p in path)
            return self.cache_spec(names, leaf.shape)
        return jax.tree_util.tree_map_with_path(walk, cache)


# --------------------------------------------------------------------------- #
# canonical plans
# --------------------------------------------------------------------------- #

def make_plan(cfg, mesh: Optional[Mesh], kind: str, *,
              unroll: bool = False, remat: bool = False,
              global_batch: int = 1, kv_quant: bool = False) -> ShardingPlan:
    """Baseline plan for (family, step kind)."""
    if mesh is None:
        return ShardingPlan(mode="train" if kind == "train" else kind,
                            unroll=unroll, remat=remat, kv_quant=kv_quant)
    axes = dict(mesh.shape)
    has_pod = "pod" in axes
    # ssm/hybrid keep full seq (recurrence) and use width-TP everywhere
    width_tp_family = cfg.family in ("ssm", "hybrid")

    batch_axes: Tuple[str, ...] = ("pod", "data") if has_pod else ("data",)
    n_batch = 1
    for a in batch_axes:
        n_batch *= axes[a]
    if global_batch % max(n_batch, 1) != 0 or global_batch < n_batch:
        batch_axes = ("data",) if global_batch % axes.get("data", 1) == 0 \
            and global_batch >= axes.get("data", 1) else ()

    if kind == "train":
        return ShardingPlan(
            mesh=mesh, batch_axes=batch_axes,
            seq_axis="" if width_tp_family else "model",
            width_axis="model" if width_tp_family else "",
            fsdp_axes=("data",) if width_tp_family else ("data", "model"),
            remat=remat, unroll=unroll, mode="train")
    if kind == "prefill":
        return ShardingPlan(
            mesh=mesh, batch_axes=batch_axes,
            seq_axis="" if width_tp_family else "model",
            width_axis="model",
            cache_seq_axes=("model",), kv_quant=kv_quant,
            unroll=unroll, mode="prefill")
    # decode
    cache_axes: Tuple[str, ...] = ("model",)
    if not batch_axes:  # global_batch=1 (long_500k): seq over data too
        cache_axes = ("data", "model")
    return ShardingPlan(
        mesh=mesh, batch_axes=batch_axes,
        seq_axis="", width_axis="model",
        cache_seq_axes=cache_axes, kv_quant=kv_quant,
        unroll=unroll, mode="decode")

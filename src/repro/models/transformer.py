"""Unified decoder stack for all assigned architecture families.

Layers are organised as *segments*: a segment is a repeating pattern of block
kinds (e.g. recurrentgemma repeats ``(rglru, rglru, local)``), whose
parameters are stacked over the repeat dimension and executed with
``lax.scan`` — one trace per segment regardless of depth, which keeps the
multi-hundred-layer dry-runs compilable.  A remainder segment picks up
``n_layers % len(pattern)`` layers.

Three entry points (all pure):
  * ``train_loss``  — full causal LM loss (chunked CE over the vocab).
  * ``prefill``     — runs the prompt, emits last-position logits + cache.
  * ``decode_step`` — one token per running request with per-request LoRA
                      adapters (the paper's serving hot path).

Distribution is injected through a :class:`~repro.models.sharding.ShardingPlan`;
attention/MoE use explicit ``shard_map`` bodies, everything else is
pjit-auto with sharding constraints.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import attention, layers, moe as moe_lib, rglru as rglru_lib, ssm
from .config import ModelConfig
from .sharding import ShardingPlan

try:  # jax >= 0.8
    _shard_map = jax.shard_map
    _CHECK_KW = "check_vma"
except AttributeError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


def shard_map(*args, check_vma=False, **kwargs):
    """jax.shard_map across jax versions (check_vma was check_rep)."""
    kwargs[_CHECK_KW] = check_vma
    return _shard_map(*args, **kwargs)


@dataclasses.dataclass(frozen=True)
class Segment:
    kinds: Tuple[str, ...]
    repeats: int


def build_segments(cfg: ModelConfig) -> List[Segment]:
    pat = cfg.block_pattern
    full, rem = divmod(cfg.n_layers, len(pat))
    segs = []
    if full:
        segs.append(Segment(tuple(pat), full))
    if rem:
        segs.append(Segment(tuple(pat[:rem]), 1))
    return segs


class Model:
    def __init__(self, cfg: ModelConfig, plan: Optional[ShardingPlan] = None):
        self.cfg = cfg
        self.plan = plan or ShardingPlan()
        self.segments = build_segments(cfg)

    # ------------------------------------------------------------------ #
    # init
    # ------------------------------------------------------------------ #
    def _init_block(self, key, kind: str, repeats: int):
        cfg = self.cfg
        d, dt = cfg.d_model, cfg.jnp_dtype
        stack = (repeats,)
        ks = iter(jax.random.split(key, 12))
        if kind in ("global", "local"):
            hd, nq, nkv = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
            p = {
                "norm1": init_stack_norm(cfg.norm, d, dt, stack),
                "wq": layers.dense_init(next(ks), (*stack, d, nq * hd), dt),
                "wk": layers.dense_init(next(ks), (*stack, d, nkv * hd), dt),
                "wv": layers.dense_init(next(ks), (*stack, d, nkv * hd), dt),
                "wo": layers.dense_init(next(ks), (*stack, nq * hd, d), dt,
                                        fan_in=nq * hd),
                "norm2": init_stack_norm(cfg.norm, d, dt, stack),
            }
            if cfg.qkv_bias:
                p["bq"] = jnp.zeros((*stack, nq * hd), dt)
                p["bk"] = jnp.zeros((*stack, nkv * hd), dt)
                p["bv"] = jnp.zeros((*stack, nkv * hd), dt)
            if cfg.n_experts:
                p["moe"] = moe_lib.init_moe(
                    next(ks), d, cfg.d_ff, cfg.n_experts, dt, stack,
                    quant=self.plan.expert_quant)
            else:
                p["mlp"] = layers.init_mlp(next(ks), cfg.mlp, d, cfg.d_ff,
                                           dt, stack)
            return p
        if kind == "ssd":
            return {
                "norm1": init_stack_norm(cfg.norm, d, dt, stack),
                "ssd": ssm.init_ssd(next(ks), cfg, dt, stack),
            }
        if kind == "rglru":
            return {
                "norm1": init_stack_norm(cfg.norm, d, dt, stack),
                "rglru": rglru_lib.init_rglru(next(ks), cfg, dt, stack),
                "norm2": init_stack_norm(cfg.norm, d, dt, stack),
                "mlp": layers.init_mlp(next(ks), cfg.mlp, d, cfg.d_ff,
                                       dt, stack),
            }
        raise ValueError(kind)

    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        keys = jax.random.split(key, len(self.segments) + 2)
        params = {
            "embed": layers.init_embed(keys[0], cfg.vocab_size, cfg.d_model,
                                       cfg.jnp_dtype, cfg.tie_embeddings),
            "final_norm": init_stack_norm(cfg.norm, cfg.d_model,
                                          cfg.jnp_dtype, ()),
            "segments": [],
        }
        for seg, k in zip(self.segments, keys[1:]):
            bks = jax.random.split(k, len(seg.kinds))
            params["segments"].append({
                "blocks": tuple(self._init_block(bk, kind, seg.repeats)
                                for bk, kind in zip(bks, seg.kinds))})
        return params

    def init_lora(self, key, n_adapters: int, rank: int) -> Dict[str, Any]:
        """Per-adapter LoRA weights on the configured targets (q, v)."""
        cfg = self.cfg
        d, dt = cfg.d_model, cfg.jnp_dtype
        hd = cfg.resolved_head_dim
        out_dims = {"q": cfg.n_heads * hd, "v": cfg.n_kv_heads * hd}
        segs = []
        for seg in self.segments:
            blocks = []
            for kind in seg.kinds:
                if kind in ("global", "local"):
                    p = {}
                    for t in cfg.lora_targets:
                        key, k1, k2 = jax.random.split(key, 3)
                        p[f"a_{t}"] = layers.dense_init(
                            k1, (seg.repeats, n_adapters, d, rank), dt)
                        p[f"b_{t}"] = layers.dense_init(
                            k2, (seg.repeats, n_adapters, rank, out_dims[t]),
                            dt, fan_in=rank)
                    blocks.append(p)
                else:
                    blocks.append({"_": jnp.zeros((seg.repeats, 1), dt)})
            segs.append({"blocks": tuple(blocks)})
        return {"segments": segs}

    # ------------------------------------------------------------------ #
    # cache
    # ------------------------------------------------------------------ #
    def _cache_block(self, kind: str, repeats: int, batch: int,
                     cache_len: int):
        cfg = self.cfg
        dt = cfg.jnp_dtype
        stack = (repeats, batch)
        if kind == "global":
            hd, nkv = cfg.resolved_head_dim, cfg.n_kv_heads
            if self.plan.kv_quant:
                return {
                    "k": jnp.zeros((*stack, cache_len, nkv, hd), jnp.int8),
                    "v": jnp.zeros((*stack, cache_len, nkv, hd), jnp.int8),
                    "k_scale": jnp.zeros((*stack, cache_len, nkv),
                                         jnp.float16),
                    "v_scale": jnp.zeros((*stack, cache_len, nkv),
                                         jnp.float16),
                }
            return {"k": jnp.zeros((*stack, cache_len, nkv, hd), dt),
                    "v": jnp.zeros((*stack, cache_len, nkv, hd), dt)}
        if kind == "local":
            hd, nkv = cfg.resolved_head_dim, cfg.n_kv_heads
            w = min(cfg.local_window, cache_len)
            return {"k_loc": jnp.zeros((*stack, w, nkv, hd), dt),
                    "v_loc": jnp.zeros((*stack, w, nkv, hd), dt)}
        if kind == "ssd":
            d_inner, nh, hd, st = ssm.ssd_dims(cfg)
            cw = cfg.conv_width
            return {"conv_x": jnp.zeros((*stack, cw - 1, d_inner), dt),
                    "conv_bc": jnp.zeros((*stack, cw - 1, 2 * st), dt),
                    "ssm": jnp.zeros((*stack, nh, hd, st), jnp.float32)}
        if kind == "rglru":
            w = rglru_lib.lru_width(cfg)
            cw = cfg.conv_width
            return {"conv": jnp.zeros((*stack, cw - 1, w), dt),
                    "lru": jnp.zeros((*stack, w), jnp.float32)}
        raise ValueError(kind)

    def init_cache(self, batch: int, cache_len: int) -> Dict[str, Any]:
        segs = []
        for seg in self.segments:
            segs.append({"blocks": tuple(
                self._cache_block(kind, seg.repeats, batch, cache_len)
                for kind in seg.kinds)})
        return {"pos": jnp.zeros((), jnp.int32), "segments": segs}

    # ------------------------------------------------------------------ #
    # block bodies
    # ------------------------------------------------------------------ #
    def _attn_proj(self, p, lora_p, h, name, adapter_idx):
        w = {"q": "wq", "k": "wk", "v": "wv"}[name]
        out = jnp.einsum("bsd,dk->bsk", h, p[w],
                         preferred_element_type=jnp.float32).astype(h.dtype)
        if self.cfg.qkv_bias:
            out = out + p[f"b{name}"].astype(h.dtype)
        if lora_p is not None and f"a_{name}" in lora_p and \
                adapter_idx is not None:
            from .. import kernels
            delta = kernels.ops.lora_apply(
                h, lora_p[f"a_{name}"], lora_p[f"b_{name}"], adapter_idx)
            out = out + delta.astype(out.dtype)
        return out

    def _attention_mixer(self, p, lora_p, cache, x, kind, adapter_idx):
        cfg, plan = self.cfg, self.plan
        b, s, _ = x.shape
        hd, nq, nkv = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
        h = layers.apply_norm(cfg.norm, p["norm1"], x)
        q = self._attn_proj(p, lora_p, h, "q", adapter_idx)
        k = self._attn_proj(p, lora_p, h, "k", adapter_idx)
        v = self._attn_proj(p, lora_p, h, "v", adapter_idx)
        q = q.reshape(b, s, nq, hd)
        k = k.reshape(b, s, nkv, hd)
        v = v.reshape(b, s, nkv, hd)
        scale = 1.0 / math.sqrt(hd)

        decode = plan.mode == "decode"
        if decode:
            pos = cache["pos"]
            positions = jnp.full((b, 1), pos)
        else:
            positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        if cfg.pos_emb == "rope":
            q = layers.apply_rope(q, positions, cfg.rope_theta)
            k = layers.apply_rope(k, positions, cfg.rope_theta)

        new_cache = None
        if not decode:
            out = self._attend_train(q, k, v, kind, scale)
            if plan.mode == "prefill":
                new_cache = self._prefill_cache(k, v, kind, s)
        else:
            out, new_cache = self._attend_decode(q, k, v, cache, kind, scale)
        out = out.reshape(b, s, nq * hd)
        out = jnp.einsum("bsk,kd->bsd", out, p["wo"],
                         preferred_element_type=jnp.float32).astype(x.dtype)
        return out, new_cache

    def _attend_train(self, q, k, v, kind, scale):
        plan, cfg = self.plan, self.cfg
        n = plan.n_seq
        b = q.shape[0]
        n_flat = max(plan.axis_size(*plan.batch_axes), 1) * max(n, 1)
        if plan.attn_batch_shard and n > 1 and b % n_flat == 0:
            # beyond-paper: reshard so attention is batch-parallel over
            # BOTH axes and fully device-local (one all-to-all each way
            # instead of streaming the whole KV around the ring)
            spec = P((*plan.batch_axes, plan.seq_axis), None, None, None)
            q = plan.constrain(q, spec)
            k = plan.constrain(k, spec)
            v = plan.constrain(v, spec)
            window = cfg.local_window if kind == "local" else 0
            m, lse, acc = attention._attend_chunked(
                q, k, v, jnp.arange(q.shape[1]), jnp.arange(k.shape[1]),
                scale, window, 256, plan.unroll)
            out = attention._finalize(m, lse, acc, q.dtype)
            return plan.constrain(out, P(plan.dp(), plan.seq_axis,
                                         None, None))
        if kind == "local":
            body = functools.partial(
                attention.local_attention, axis_name=plan.seq_axis,
                n_shards=n, scale=scale, window=cfg.local_window,
                unroll=plan.unroll)
        else:
            body = functools.partial(
                attention.ring_attention, axis_name=plan.seq_axis,
                n_shards=n, scale=scale, unroll=plan.unroll)
        if n == 1:
            return body(q, k, v)
        spec = P(plan.dp(), plan.seq_axis, None, None)
        q = plan.constrain(q, spec)
        k = plan.constrain(k, spec)
        v = plan.constrain(v, spec)
        return shard_map(body, mesh=plan.mesh, in_specs=(spec,) * 3,
                         out_specs=spec, check_vma=False)(q, k, v)

    def _prefill_cache(self, k, v, kind, s):
        cfg = self.cfg
        if kind == "global":
            if self.plan.kv_quant:
                # quantize over D per (token, head): vmap the (B, KV, D)
                # quantizer over the seq axis
                kq, ks = jax.vmap(attention.quantize_kv, in_axes=1,
                                  out_axes=1)(k)
                vq, vs = jax.vmap(attention.quantize_kv, in_axes=1,
                                  out_axes=1)(v)
                return {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}
            return {"k": k, "v": v}
        w = min(cfg.local_window, s)
        shift = (s - w) % max(w, 1)

        def to_rolling(arr):
            tail = arr[:, -w:]
            return jnp.roll(tail, shift=shift, axis=1)
        return {"k_loc": to_rolling(k), "v_loc": to_rolling(v)}

    def _attend_decode(self, q, k, v, cache, kind, scale):
        plan, cfg = self.plan, self.cfg
        q1, k1, v1 = q[:, 0], k[:, 0], v[:, 0]
        pos = cache["pos"]
        if kind == "local":
            out, nk, nv = attention.decode_attention_rolling(
                q1, cache["k_loc"], cache["v_loc"], k1, v1, pos,
                scale=scale, window=cfg.local_window)
            return out[:, None], {"k_loc": nk, "v_loc": nv}
        n = plan.n_cache
        quant = plan.kv_quant
        if n == 1:
            outs = attention.decode_attention_sharded(
                q1, cache["k"], cache["v"], k1, v1, pos,
                axis_name="", n_shards=1, scale=scale,
                k_scale=cache.get("k_scale") if quant else None,
                v_scale=cache.get("v_scale") if quant else None)
            return outs[0][:, None], _pack_kv(outs, quant)
        axes = plan.cache_seq_axes
        axis = axes if len(axes) > 1 else axes[0]
        dp = plan.dp()
        qspec = P(dp, None, None)
        cspec = P(dp, axes, None, None)
        sspec = P(dp, axes, None)
        body = functools.partial(
            attention.decode_attention_sharded, axis_name=axis,
            n_shards=n, scale=scale)
        in_specs = [qspec, cspec, cspec, qspec, qspec, P()]
        out_specs = [qspec, cspec, cspec]
        args = [plan.constrain(q1, qspec), cache["k"], cache["v"],
                plan.constrain(k1, qspec), plan.constrain(v1, qspec), pos]
        if quant:
            body = functools.partial(body)
            in_specs += [sspec, sspec]
            out_specs += [sspec, sspec]
            args += [cache["k_scale"], cache["v_scale"]]

            def body(q, kc, vc, nk, nv, p, ks, vs):  # noqa: F811
                return attention.decode_attention_sharded(
                    q, kc, vc, nk, nv, p, axis_name=axis, n_shards=n,
                    scale=scale, k_scale=ks, v_scale=vs)
        outs = shard_map(body, mesh=plan.mesh, in_specs=tuple(in_specs),
                         out_specs=tuple(out_specs), check_vma=False)(*args)
        return outs[0][:, None], _pack_kv(outs, quant)

    def _ffn(self, p, x):
        """MLP or MoE sublayer (post-norm residual handled by caller)."""
        cfg, plan = self.cfg, self.plan
        if not cfg.n_experts:
            return layers.apply_mlp(cfg.mlp, p["mlp"], x), 0.0
        ep_axis = plan.width_axis or plan.seq_axis
        n = plan.axis_size(ep_axis)
        b, s, d = x.shape
        if n == 1:
            out, aux = moe_lib.apply_moe(
                p["moe"], x.reshape(b * s, d), top_k=cfg.top_k,
                n_experts=cfg.n_experts, capacity_factor=cfg.capacity_factor)
            return out.reshape(b, s, d), aux

        seq_sharded = bool(plan.seq_axis)
        dp = plan.dp()
        xspec = P(dp, plan.seq_axis or None, None)
        espec = {"router": P(None, None),
                 "w_gate": P(ep_axis, None, None),
                 "w_up": P(ep_axis, None, None),
                 "w_down": P(ep_axis, None, None)}
        for nm in ("w_gate", "w_up", "w_down"):
            if f"{nm}_scale" in p["moe"]:
                espec[f"{nm}_scale"] = P(ep_axis, None, None)

        def body(ep, xl):
            bl, sl, _ = xl.shape
            out, aux = moe_lib.apply_moe(
                ep, xl.reshape(bl * sl, d), top_k=cfg.top_k,
                n_experts=cfg.n_experts, capacity_factor=cfg.capacity_factor,
                axis_name=ep_axis, n_shards=n, gather=seq_sharded)
            for ax in plan.batch_axes:  # aux must be identical on all shards
                aux = jax.lax.pmean(aux, ax)
            return out.reshape(bl, sl, d), aux

        moe_p = {k: plan.constrain(v, espec[k]) for k, v in p["moe"].items()}
        out, aux = shard_map(
            body, mesh=plan.mesh, in_specs=(espec, xspec),
            out_specs=(xspec, P()), check_vma=False)(
                moe_p, plan.constrain(x, xspec))
        return out, aux

    def _apply_block(self, kind, p, lora_p, cache, x, adapter_idx):
        cfg, plan = self.cfg, self.plan
        aux = 0.0
        if kind in ("global", "local"):
            out, new_cache = self._attention_mixer(
                p, lora_p, cache, x, kind, adapter_idx)
            x = plan.constrain(x + out)
            h = layers.apply_norm(cfg.norm, p["norm2"], x)
            f, aux = self._ffn(p, h)
            x = plan.constrain(x + f)
            return x, new_cache, aux
        if kind == "ssd":
            h = layers.apply_norm(cfg.norm, p["norm1"], x)
            decode = plan.mode == "decode" and cache is not None
            conv_state = ((cache["conv_x"], cache["conv_bc"])
                          if decode else (None, None))
            out, (ncx, ncbc, nssm) = ssm.apply_ssd(
                p["ssd"], h, cfg, unroll=plan.unroll,
                conv_state=conv_state if decode else (None, None),
                ssm_state=cache["ssm"] if decode else None)
            x = plan.constrain(x + out)
            new_cache = None
            if plan.mode in ("prefill", "decode"):
                new_cache = {"conv_x": ncx, "conv_bc": ncbc, "ssm": nssm}
            return x, new_cache, aux
        if kind == "rglru":
            h = layers.apply_norm(cfg.norm, p["norm1"], x)
            decode = plan.mode == "decode" and cache is not None
            out, (nconv, nlru) = rglru_lib.apply_rglru(
                p["rglru"], h,
                conv_state=cache["conv"] if decode else None,
                lru_state=cache["lru"] if decode else None)
            x = plan.constrain(x + out)
            h2 = layers.apply_norm(cfg.norm, p["norm2"], x)
            x = plan.constrain(x + layers.apply_mlp(cfg.mlp, p["mlp"], h2))
            new_cache = None
            if plan.mode in ("prefill", "decode"):
                new_cache = {"conv": nconv, "lru": nlru}
            return x, new_cache, aux
        raise ValueError(kind)

    # ------------------------------------------------------------------ #
    # segment scan
    # ------------------------------------------------------------------ #
    def _run_segments(self, params, lora, cache, x, adapter_idx):
        """Returns (x, new_cache_segments_or_None, aux)."""
        plan = self.plan
        aux_total = 0.0
        new_segs = [] if cache is not None or plan.mode == "prefill" else None

        aux_total = jnp.zeros((), jnp.float32)
        for si, seg in enumerate(self.segments):
            nk = len(seg.kinds)

            def body(carry, xs, seg=seg, nk=nk):
                xx, aux = carry
                pb = xs["p"]
                lb = xs["l"] if "l" in xs else (None,) * nk
                cb = xs["c"] if "c" in xs else (None,) * nk
                new_cb = []
                for i, kind in enumerate(seg.kinds):
                    ci = cb[i]
                    if isinstance(ci, dict):
                        ci = dict(ci)
                        ci["pos"] = cache["pos"]
                    xx, nc, a = self._apply_block(
                        kind, pb[i], lb[i], ci, xx, adapter_idx)
                    aux = aux + a
                    new_cb.append(nc if nc is not None else 0)
                return (xx, aux), tuple(new_cb)

            xs = {"p": params["segments"][si]["blocks"]}
            if lora is not None:
                xs["l"] = lora["segments"][si]["blocks"]
            if cache is not None:
                xs["c"] = cache["segments"][si]["blocks"]

            if plan.remat:
                body = jax.checkpoint(body)

            if plan.unroll:
                carry = (x, aux_total)
                ys = []
                for r in range(seg.repeats):
                    xr = jax.tree.map(lambda a: a[r], xs)
                    carry, y = body(carry, xr)
                    ys.append(y)
                x, aux_total = carry
                ys = (jax.tree.map(lambda *a: jnp.stack(a), *ys)
                      if new_segs is not None else None)
            else:
                (x, aux_total), ys = jax.lax.scan(body, (x, aux_total), xs)

            if new_segs is not None:
                new_segs.append({"blocks": ys})
        return x, new_segs, aux_total

    # ------------------------------------------------------------------ #
    # public entry points
    # ------------------------------------------------------------------ #
    def _embed_in(self, params, tokens, img_embeds=None):
        cfg, plan = self.cfg, self.plan
        x = layers.embed_tokens(params["embed"], tokens)
        if img_embeds is not None:
            x = jnp.concatenate([img_embeds.astype(x.dtype), x], axis=1)
        if cfg.pos_emb == "sinusoidal":
            positions = jnp.arange(x.shape[1])[None]
            pe = layers.sinusoidal_pos_emb(positions, cfg.d_model)
            x = x + pe.astype(x.dtype)
        return plan.constrain(x)

    def train_loss(self, params, batch):
        """batch: {'tokens': (B, T+1) int32, 'img_embeds': (B, I, d)?}."""
        cfg, plan = self.cfg, self.plan
        tokens = batch["tokens"]
        inp, labels = tokens[:, :-1], tokens[:, 1:]
        img = batch.get("img_embeds")
        x = self._embed_in(params, inp, img)
        x, _, aux = self._run_segments(params, None, None, x, None)
        x = layers.apply_norm(cfg.norm, params["final_norm"], x)
        if img is not None:
            x = x[:, img.shape[1]:]
        loss = self._chunked_ce(params, x, labels)
        if cfg.n_experts:
            loss = loss + 0.01 * aux / max(cfg.n_layers, 1)
        return loss

    def _chunked_ce(self, params, x, labels, max_logit_bytes=2 ** 28):
        cfg, plan = self.cfg, self.plan
        b, s, d = x.shape
        v = layers.pad_vocab(cfg.vocab_size)
        ns = max(plan.n_seq, 1)
        n_b = max(plan.axis_size(*plan.batch_axes), 1)
        s_loc = s // ns
        # chunk the per-shard seq so PER-DEVICE logits stay bounded
        # (probes relax the bound: they unroll, and memory feasibility is
        # proven by the full compile, not the probes)
        budget = max_logit_bytes * (8 if plan.unroll else 1)
        chunk = s_loc
        while chunk > 1 and (b // n_b) * chunk * v * 4 > budget:
            chunk //= 2
        nc = s_loc // chunk

        def ce(xc, lc):
            logits = layers.unembed(params["embed"], xc, cfg.logit_softcap)
            logits = jnp.where(
                (jnp.arange(v) < cfg.vocab_size)[None, None], logits, -1e30)
            return layers.cross_entropy(logits, lc)

        if nc <= 1:
            return ce(x, labels)
        xr = x.reshape(b, ns, nc, chunk, d).swapaxes(0, 2)      # (nc,ns,b,..)
        lr = labels.reshape(b, ns, nc, chunk).swapaxes(0, 2)

        @jax.checkpoint  # recompute chunk logits in backward: O(1) residuals
        def one(carry, xs):
            xc, lc = xs
            xc = xc.swapaxes(0, 1).reshape(b, ns * chunk, d)
            lc = lc.swapaxes(0, 1).reshape(b, ns * chunk)
            return carry + ce(xc, lc), None

        if plan.unroll:
            tot = jnp.zeros((), jnp.float32)
            for i in range(nc):
                tot, _ = one(tot, (xr[i], lr[i]))
        else:
            tot, _ = jax.lax.scan(one, jnp.zeros((), jnp.float32), (xr, lr))
        return tot / nc

    def prefill(self, params, lora, tokens, adapter_idx=None, img_embeds=None):
        """Returns (last-token logits (B, V), cache)."""
        cfg, plan = self.cfg, self.plan
        x = self._embed_in(params, tokens, img_embeds)
        s = x.shape[1]
        x, new_segs, _ = self._run_segments(params, lora, None, x, adapter_idx)
        x = layers.apply_norm(cfg.norm, params["final_norm"], x)
        logits = layers.unembed(params["embed"], x[:, -1:], cfg.logit_softcap)
        cache = {"pos": jnp.asarray(s, jnp.int32), "segments": new_segs}
        return logits[:, 0], cache

    def decode_step(self, params, lora, cache, tokens, adapter_idx=None):
        """tokens: (B, 1). Returns (logits (B, V), new cache)."""
        cfg, plan = self.cfg, self.plan
        x = layers.embed_tokens(params["embed"], tokens)
        if cfg.pos_emb == "sinusoidal":
            pe = layers.sinusoidal_pos_emb(cache["pos"][None, None], cfg.d_model)
            x = x + pe.astype(x.dtype)
        x = plan.constrain(x)
        x, new_segs, _ = self._run_segments(params, lora, cache, x, adapter_idx)
        x = layers.apply_norm(cfg.norm, params["final_norm"], x)
        logits = layers.unembed(params["embed"], x, cfg.logit_softcap)
        new_cache = {"pos": cache["pos"] + 1, "segments": new_segs}
        return logits[:, 0], new_cache


def _pack_kv(outs, quant: bool):
    if quant:
        return {"k": outs[1], "v": outs[2],
                "k_scale": outs[3], "v_scale": outs[4]}
    return {"k": outs[1], "v": outs[2]}


def pad_cache(cache, extra: int):
    """Grow the global-attention KV capacity of a prefill cache by `extra`
    slots (rolling/state caches are fixed-size and pass through)."""
    segs = []
    for seg in cache["segments"]:
        blocks = []
        for bd in seg["blocks"]:
            nb = {}
            for k, v in bd.items():
                if k in ("k", "v", "k_scale", "v_scale"):
                    pad = jnp.zeros(v.shape[:2] + (extra,) + v.shape[3:],
                                    v.dtype)
                    nb[k] = jnp.concatenate([v, pad], axis=2)
                else:
                    nb[k] = v
            blocks.append(nb)
        segs.append({"blocks": tuple(blocks)})
    return {"pos": cache["pos"], "segments": segs}


def init_stack_norm(kind, width, dtype, stack):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((*stack, width), dtype)}
    return {"scale": jnp.ones((*stack, width), dtype),
            "bias": jnp.zeros((*stack, width), dtype)}

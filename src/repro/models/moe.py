"""Capacity-based expert-parallel MoE (top-k routing).

Layout: experts are sharded over the ``model`` axis (EP); token activations
arrive seq-sharded over ``model`` (the dense-block layout).  The block
all-gathers tokens over ``model``, routes, gathers each local expert's tokens
into a fixed-capacity buffer (scatter via position-in-expert cumsum — no
(T,E,C) dispatch tensor is ever materialized), runs the expert FFN, and
scatter-adds weighted results back; a ``psum_scatter`` returns the seq-sharded
layout.  Collectives per layer: one all-gather + one reduce-scatter of
(T, d) — identical asymptotics to a Megatron MLP psum.

FLOPs are ~active-expert FLOPs × capacity_factor: no dense all-expert waste.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers


def init_moe(key, d_model: int, d_ff: int, n_experts: int, dtype,
             stack: tuple = (), quant: bool = False):
    ks = jax.random.split(key, 4)
    p = {
        "router": layers.dense_init(ks[0], (*stack, d_model, n_experts),
                                    jnp.float32),
        "w_gate": layers.dense_init(ks[1], (*stack, n_experts, d_model, d_ff),
                                    dtype),
        "w_up": layers.dense_init(ks[2], (*stack, n_experts, d_model, d_ff),
                                  dtype),
        "w_down": layers.dense_init(ks[3], (*stack, n_experts, d_ff, d_model),
                                    dtype, fan_in=d_ff),
    }
    if quant:
        p = quantize_experts(p)
    return p


def quantize_experts(p):
    """Weight-only int8 experts with per-(expert, out-column) scales —
    expert streaming is ~half the MoE decode memory floor (beyond-paper
    serving optimization; dequant happens in-register on TPU)."""
    out = {"router": p["router"]}
    for name in ("w_gate", "w_up", "w_down"):
        w = p[name].astype(jnp.float32)
        scale = jnp.max(jnp.abs(w), axis=-2, keepdims=True) / 127.0 + 1e-8
        out[name] = jnp.clip(jnp.round(w / scale), -127,
                             127).astype(jnp.int8)
        out[f"{name}_scale"] = scale.astype(jnp.float16)
    return out


def _dequant(p, name, like_dtype):
    w = p[name]
    if w.dtype == jnp.int8:
        return (w.astype(jnp.bfloat16)
                * p[f"{name}_scale"].astype(jnp.bfloat16)).astype(like_dtype)
    return w


def apply_moe(p, x, *, top_k: int, n_experts: int, capacity_factor: float,
              axis_name: str = "", n_shards: int = 1, gather: bool = True):
    """Per-device body (inside shard_map when n_shards > 1).

    x: (T_loc, d) local tokens; expert params in `p` are the LOCAL shard
    (E_loc = n_experts / n_shards experts per device).  ``gather=True``
    means x is seq-sharded over `axis_name` (train/prefill: all-gather in,
    reduce-scatter out); ``gather=False`` means x is already replicated
    over `axis_name` (decode: plain psum out).
    Returns (out (T_loc, d), aux_loss scalar).
    """
    t_loc, d = x.shape
    e_local = p["w_gate"].shape[0]

    if n_shards > 1:
        my = jax.lax.axis_index(axis_name)
        x_all = (jax.lax.all_gather(x, axis_name, axis=0, tiled=True)
                 if gather else x)
    else:
        x_all, my = x, 0
    t = x_all.shape[0]

    # --- routing (replicated over the EP axis; router is tiny) -------------
    logits = jnp.einsum("td,de->te", x_all.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    weights, experts = jax.lax.top_k(probs, top_k)          # (T, k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)

    # aux load-balancing loss (Switch-style)
    density = jnp.mean(
        jax.nn.one_hot(experts[:, 0], n_experts, dtype=jnp.float32), axis=0)
    density_proxy = jnp.mean(probs, axis=0)
    aux = n_experts * jnp.sum(density * density_proxy)

    # --- dispatch to the local experts -------------------------------------
    # Small token counts (decode steps) run dropless; large (train/prefill)
    # use the standard capacity-factor bound.
    if t * top_k <= 4096:
        capacity = t * top_k
    else:
        capacity = max(-(-t * top_k * capacity_factor // n_experts), 1)
    capacity = int(capacity)
    flat_e = experts.reshape(-1)                            # (T*k,)
    flat_w = weights.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(t), top_k)
    local_e = flat_e - my * e_local
    is_local = (local_e >= 0) & (local_e < e_local)
    local_e = jnp.clip(local_e, 0, e_local - 1)
    onehot = jax.nn.one_hot(jnp.where(is_local, local_e, e_local),
                            e_local + 1, dtype=jnp.int32)[:, :e_local]
    pos = jnp.cumsum(onehot, axis=0) - onehot               # exclusive cumsum
    pos = jnp.sum(pos * onehot, axis=1)                     # (T*k,)
    keep = is_local & (pos < capacity)
    pos = jnp.where(keep, pos, capacity)                    # overflow slot

    buf = jnp.zeros((e_local, capacity + 1, d), x.dtype)
    buf = buf.at[local_e, pos].add(jnp.where(keep[:, None], x_all[flat_t], 0))
    buf = buf[:, :capacity]

    # --- expert FFN (swiglu; weights may be int8 weight-only quantized) ----
    w_gate = _dequant(p, "w_gate", x.dtype)
    w_up = _dequant(p, "w_up", x.dtype)
    w_down = _dequant(p, "w_down", x.dtype)
    gate = jnp.einsum("ecd,edf->ecf", buf, w_gate,
                      preferred_element_type=jnp.float32)
    up = jnp.einsum("ecd,edf->ecf", buf, w_up,
                    preferred_element_type=jnp.float32)
    h = (jax.nn.silu(gate) * up).astype(x.dtype)
    y = jnp.einsum("ecf,efd->ecd", h, w_down,
                   preferred_element_type=jnp.float32).astype(x.dtype)

    # --- combine ------------------------------------------------------------
    y_tok = y[local_e, pos]                                 # (T*k, d)
    y_tok = jnp.where(keep[:, None], y_tok, 0) * flat_w[:, None].astype(x.dtype)
    out_all = jnp.zeros((t, d), x.dtype).at[flat_t].add(y_tok)

    if n_shards > 1:
        if gather:
            out = jax.lax.psum_scatter(out_all, axis_name,
                                       scatter_dimension=0, tiled=True)
        else:
            out = jax.lax.psum(out_all, axis_name)
        aux = jax.lax.pmean(aux, axis_name)
    else:
        out = out_all
    return out, aux


def apply_moe_ref(p_full, x, *, top_k: int, n_experts: int):
    """Dropless single-device oracle: exact top-k expert mixture."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), p_full["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    weights, experts = jax.lax.top_k(probs, top_k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for j in range(top_k):
        wg = p_full["w_gate"][experts[:, j]]                # (T, d, f)
        wu = p_full["w_up"][experts[:, j]]
        wd = p_full["w_down"][experts[:, j]]
        gate = jnp.einsum("td,tdf->tf", x, wg, preferred_element_type=jnp.float32)
        up = jnp.einsum("td,tdf->tf", x, wu, preferred_element_type=jnp.float32)
        h = (jax.nn.silu(gate) * up).astype(x.dtype)
        y = jnp.einsum("tf,tfd->td", h, wd, preferred_element_type=jnp.float32)
        out = out + y * weights[:, j:j + 1]
    return out.astype(x.dtype)

"""Attention variants.

Distribution layout (baseline plan, see EXPERIMENTS.md §Perf for evolution):
  * train / prefill: activations are sharded batch→``data``, seq→``model``.
    Global-attention layers run **ring attention** over the ``model`` axis
    (each device owns an S/n slice of Q and streams KV shards around the
    ring with ``ppermute``) — this supports every GQA head count (1..48)
    on a 16-way axis, unlike head-sharded TP.
  * local (sliding-window) layers gather only ceil(w/S_loc) neighbour
    chunks — O(window) communication instead of the full ring.
  * decode: the KV cache is sharded seq→``model``; each device computes
    partial attention over its slice and the result is combined with
    log-sum-exp weights via one tiny ``psum``.

All functions here are *per-device* bodies meant to run inside
``jax.shard_map``; pure single-device references live next to them for the
(1,1)-mesh smoke/unit tests — the shard-mapped path degenerates to the
reference when the axis size is 1.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1.0e30


# --------------------------------------------------------------------------- #
# flash-style block update
# --------------------------------------------------------------------------- #

def _flash_block(q, k, v, mask, scale, m, lse, acc):
    """One online-softmax update.

    q: (B, C, KV, G, D)   k/v: (B, S, KV, D)   mask: (C, S) or (B, C, S)
    m, lse: (B, C, KV, G)   acc: (B, C, KV, G, D)  (all fp32)
    """
    s = jnp.einsum("bckgd,bskd->bckgs", q, k,
                   preferred_element_type=jnp.float32) * scale
    if mask.ndim == 2:
        mask_b = mask[None, :, None, None, :]
    else:
        mask_b = mask[:, :, None, None, :]
    s = jnp.where(mask_b, s, NEG_INF)
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    p = jnp.where(mask_b, p, 0.0)
    corr = jnp.exp(m - m_new)
    l_new = lse * corr + jnp.sum(p, axis=-1)
    pv = jnp.einsum("bckgs,bskd->bckgd", p, v.astype(jnp.float32),
                    preferred_element_type=jnp.float32)
    acc_new = acc * corr[..., None] + pv
    return m_new, l_new, acc_new


def _split_heads(q, n_kv):
    b, s, h, d = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, d)


def _merge_heads(x):
    b, s, kv, g, d = x.shape
    return x.reshape(b, s, kv * g, d)


def _chunk_count(seq: int, chunk: int) -> int:
    chunk = min(chunk, seq) if chunk else seq
    while seq % chunk:
        chunk -= 1
    return seq // chunk


# Recompute the softmax block in backward (FA2-style): without this, AD
# stores the (B, C, KV, G, S) probability tensor for every (ring x q-chunk)
# block — hundreds of GB at production shapes.
_flash_block_ckpt = jax.checkpoint(_flash_block, static_argnums=(4,))


def _attend_chunked(q, k, v, q_pos, kv_pos, scale, window: int,
                    q_chunk: int, unroll: bool):
    """Chunked (over Q) causal attention of local q against a kv buffer.

    q: (B, Sq, H, D); k/v: (B, Skv, KV, D); q_pos: (Sq,); kv_pos: (Skv,)
    Returns fp32 (m, lse, acc) with shapes ((B,Sq,KV,G), ..., (B,Sq,KV,G,D)).
    """
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qs = _split_heads(q, kvh)

    def mask_for(qp):
        m = qp[:, None] >= kv_pos[None, :]
        m &= kv_pos[None, :] >= 0
        if window:
            m &= (qp[:, None] - kv_pos[None, :]) < window
        return m

    nc = _chunk_count(sq, q_chunk)
    c = sq // nc
    m0 = jnp.full((b, sq, kvh, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, sq, kvh, g), jnp.float32)
    a0 = jnp.zeros((b, sq, kvh, g, d), jnp.float32)
    if nc == 1:
        return _flash_block_ckpt(qs, k, v, mask_for(q_pos), scale, m0, l0, a0)

    qc = qs.reshape(b, nc, c, kvh, g, d).swapaxes(0, 1)
    pc = q_pos.reshape(nc, c)

    def one(_, xs):
        qi, pi = xs
        mi = jnp.full((b, c, kvh, g), NEG_INF, jnp.float32)
        li = jnp.zeros((b, c, kvh, g), jnp.float32)
        ai = jnp.zeros((b, c, kvh, g, d), jnp.float32)
        return None, _flash_block_ckpt(qi, k, v, mask_for(pi), scale,
                                       mi, li, ai)

    if unroll:
        outs = [one(None, (qc[i], pc[i]))[1] for i in range(nc)]
        m, lse, acc = (jnp.stack([o[j] for o in outs]) for j in range(3))
    else:
        _, (m, lse, acc) = jax.lax.scan(one, None, (qc, pc))
    m = m.swapaxes(0, 1).reshape(b, sq, kvh, g)
    lse = lse.swapaxes(0, 1).reshape(b, sq, kvh, g)
    acc = acc.swapaxes(0, 1).reshape(b, sq, kvh, g, d)
    return m, lse, acc


def _merge_state(state_a, state_b):
    """Combine two online-softmax partial states."""
    m_a, l_a, a_a = state_a
    m_b, l_b, a_b = state_b
    m = jnp.maximum(m_a, m_b)
    ca, cb = jnp.exp(m_a - m), jnp.exp(m_b - m)
    return m, l_a * ca + l_b * cb, a_a * ca[..., None] + a_b * cb[..., None]


def _finalize(m, lse, acc, dtype):
    out = acc / jnp.maximum(lse, 1e-20)[..., None]
    return _merge_heads(out).astype(dtype)


# --------------------------------------------------------------------------- #
# ring attention (global layers, seq sharded over `axis_name`)
# --------------------------------------------------------------------------- #

def ring_attention(q, k, v, *, axis_name: str, n_shards: int, scale: float,
                   q_chunk: int = 256, unroll: bool = False):
    """Per-device body. q: (B, Sq_loc, H, D); k/v: (B, Skv_loc, KV, D)."""
    b, sq, h, d = q.shape
    skv = k.shape[1]
    if n_shards == 1:
        q_pos = jnp.arange(sq)
        m, lse, acc = _attend_chunked(q, k, v, q_pos, jnp.arange(skv), scale,
                                    0, q_chunk, unroll)
        return _finalize(m, lse, acc, q.dtype)

    my = jax.lax.axis_index(axis_name)
    q_pos = my * sq + jnp.arange(sq)
    perm = [(i, (i + 1) % n_shards) for i in range(n_shards)]

    def compute(j, k, v, state):
        src = (my - j) % n_shards
        kv_pos = src * skv + jnp.arange(skv)
        st = _attend_chunked(q, k, v, q_pos, kv_pos, scale, 0, q_chunk, unroll)
        return _merge_state(state, st)

    state = (jnp.full((b, sq, k.shape[2], h // k.shape[2]), NEG_INF, jnp.float32),
             jnp.zeros((b, sq, k.shape[2], h // k.shape[2]), jnp.float32),
             jnp.zeros((b, sq, k.shape[2], h // k.shape[2], d), jnp.float32))

    if unroll:
        for j in range(n_shards):
            state = compute(j, k, v, state)
            if j != n_shards - 1:
                k = jax.lax.ppermute(k, axis_name, perm)
                v = jax.lax.ppermute(v, axis_name, perm)
    else:
        def ring_step(j, carry):
            k, v, state = carry
            state = compute(j, k, v, state)
            k = jax.lax.ppermute(k, axis_name, perm)
            v = jax.lax.ppermute(v, axis_name, perm)
            return (k, v, state)

        k, v, state = jax.lax.fori_loop(0, n_shards - 1, ring_step,
                                        (k, v, state))
        state = compute(n_shards - 1, k, v, state)
    return _finalize(*state, q.dtype)


# --------------------------------------------------------------------------- #
# local (sliding-window) attention, seq sharded over `axis_name`
# --------------------------------------------------------------------------- #

def local_attention(q, k, v, *, axis_name: str, n_shards: int, scale: float,
                    window: int, q_chunk: int = 256, unroll: bool = False):
    """Per-device body. Gathers ceil(window/S_loc) neighbour KV chunks."""
    b, sq, h, d = q.shape
    skv = k.shape[1]
    my = jax.lax.axis_index(axis_name) if n_shards > 1 else 0
    q_pos = my * sq + jnp.arange(sq)

    n_prev = min(-(-window // skv), n_shards - 1)  # ceil, capped
    parts_k, parts_v = [k], [v]
    if n_shards > 1 and n_prev > 0:
        perm = [(i, (i + 1) % n_shards) for i in range(n_shards)]
        pk, pv = k, v
        for _ in range(n_prev):
            pk = jax.lax.ppermute(pk, axis_name, perm)
            pv = jax.lax.ppermute(pv, axis_name, perm)
            parts_k.insert(0, pk)
            parts_v.insert(0, pv)
    k_ext = jnp.concatenate(parts_k, axis=1)
    v_ext = jnp.concatenate(parts_v, axis=1)
    start = (my - len(parts_k) + 1) * skv
    kv_pos = start + jnp.arange(k_ext.shape[1])  # negative => masked
    m, lse, acc = _attend_chunked(q, k_ext, v_ext, q_pos, kv_pos, scale,
                                window, q_chunk, unroll)
    return _finalize(m, lse, acc, q.dtype)


# --------------------------------------------------------------------------- #
# decode: one new token against a seq-sharded KV cache
# --------------------------------------------------------------------------- #

def quantize_kv(x):
    """Per-(token, head) symmetric int8 quantization.

    x: (B, KV, D) -> (int8 (B, KV, D), f16 scale (B, KV)).
    Beyond-paper optimization: KV streaming dominates the decode memory
    roofline term; int8 storage halves it vs bf16 with <0.5% logit error
    (validated in tests/test_consistency_int8.py).
    """
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0 + 1e-8
    # quantize against the f16-rounded scale that dequantization will use,
    # so the s/2 round-off bound holds for the stored representation
    scale = scale.astype(jnp.float16)
    q = jnp.clip(jnp.round(x.astype(jnp.float32)
                           / scale[..., None].astype(jnp.float32)),
                 -127, 127).astype(jnp.int8)
    return q, scale


def decode_update_cache(cache, new, pos, my, s_loc):
    """Masked append of `new` (B, KV, ...) into the local slice
    (B, S_loc, KV, ...) — works for values (4-d) and scales (3-d)."""
    local = pos - my * s_loc
    ok = (local >= 0) & (local < s_loc)
    idx = jnp.clip(local, 0, s_loc - 1)
    start = (0, idx) + (0,) * (cache.ndim - 2)
    upd = jax.lax.dynamic_update_slice(
        cache, new[:, None].astype(cache.dtype), start)
    return jnp.where(ok, upd, cache)


def decode_attention_sharded(q, k_cache, v_cache, new_k, new_v, pos, *,
                             axis_name: str, n_shards: int, scale: float,
                             k_scale=None, v_scale=None):
    """Per-device body.

    q: (B, H, D) replicated over `axis_name`; caches: (B, S_loc, KV, D) local
    slice; new_k/new_v: (B, KV, D) replicated; pos: scalar index being written.
    With ``k_scale``/``v_scale`` (B, S_loc, KV) the caches are int8 and
    dequantized on the fly (scores scale by k_scale; p scales by v_scale).
    Returns ((B, H, D) out, updated caches [, updated scales]).
    """
    b, h, d = q.shape
    kvh = k_cache.shape[2]
    g = h // kvh
    s_loc = k_cache.shape[1]
    my = jax.lax.axis_index(axis_name) if n_shards > 1 else 0
    quant = k_scale is not None

    if quant:
        nk, nks = quantize_kv(new_k)
        nv, nvs = quantize_kv(new_v)
        k_cache = decode_update_cache(k_cache, nk, pos, my, s_loc)
        v_cache = decode_update_cache(v_cache, nv, pos, my, s_loc)
        k_scale = decode_update_cache(k_scale, nks, pos, my, s_loc)
        v_scale = decode_update_cache(v_scale, nvs, pos, my, s_loc)
    else:
        k_cache = decode_update_cache(k_cache, new_k, pos, my, s_loc)
        v_cache = decode_update_cache(v_cache, new_v, pos, my, s_loc)

    kv_pos = my * s_loc + jnp.arange(s_loc)
    mask = (kv_pos <= pos)[None, None, None, :]                # (1,1,1,S)
    qs = q.reshape(b, kvh, g, d)
    kk = k_cache.astype(jnp.bfloat16) if quant else k_cache
    s = jnp.einsum("bkgd,bskd->bkgs", qs, kk,
                   preferred_element_type=jnp.float32) * scale
    if quant:
        s = s * k_scale.astype(jnp.float32).transpose(0, 2, 1)[:, :, None]
    s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=-1)
    p = jnp.where(mask, jnp.exp(s - m[..., None]), 0.0)
    lse = jnp.sum(p, axis=-1)
    if quant:
        pv = p * v_scale.astype(jnp.float32).transpose(0, 2, 1)[:, :, None]
        acc = jnp.einsum("bkgs,bskd->bkgd", pv.astype(jnp.bfloat16),
                         v_cache.astype(jnp.bfloat16),
                         preferred_element_type=jnp.float32)
    else:
        acc = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32),
                         preferred_element_type=jnp.float32)
    if n_shards > 1:
        m_g = jax.lax.pmax(m, axis_name)
        corr = jnp.exp(m - m_g)
        lse = jax.lax.psum(lse * corr, axis_name)
        acc = jax.lax.psum(acc * corr[..., None], axis_name)
    out = acc / jnp.maximum(lse, 1e-20)[..., None]
    outs = (out.reshape(b, h, d).astype(q.dtype), k_cache, v_cache)
    if quant:
        outs += (k_scale, v_scale)
    return outs


def decode_attention_rolling(q, k_cache, v_cache, new_k, new_v, pos, *,
                             scale: float, window: int):
    """Rolling-window cache decode (local-attention layers).

    q: (B, H, D); caches: (B, W, KV, D) rolling; pos: current position.
    """
    b, h, d = q.shape
    kvh = k_cache.shape[2]
    g = h // kvh
    w = k_cache.shape[1]
    slot = pos % w
    k_cache = jax.lax.dynamic_update_slice(
        k_cache, new_k[:, None].astype(k_cache.dtype), (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(
        v_cache, new_v[:, None].astype(v_cache.dtype), (0, slot, 0, 0))
    slots = jnp.arange(w)
    # global position stored in each slot (largest p <= pos with p % w == slot)
    kv_pos = pos - ((pos - slots) % w)
    mask = ((kv_pos >= 0) & (kv_pos <= pos)
            & ((pos - kv_pos) < window))[None, None, None, :]
    qs = q.reshape(b, kvh, g, d)
    s = jnp.einsum("bkgd,bskd->bkgs", qs, k_cache,
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=-1)
    p = jnp.where(mask, jnp.exp(s - m[..., None]), 0.0)
    lse = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    out = acc / jnp.maximum(lse, 1e-20)[..., None]
    return out.reshape(b, h, d).astype(q.dtype), k_cache, v_cache


# --------------------------------------------------------------------------- #
# single-device reference (tests)
# --------------------------------------------------------------------------- #

def attention_ref(q, k, v, scale: float, window: int = 0, causal: bool = True):
    """Naive softmax attention oracle. q: (B,S,H,D); k/v: (B,S,KV,D)."""
    b, s, h, d = q.shape
    kvh = k.shape[2]
    qs = q.reshape(b, s, kvh, h // kvh, d)
    logits = jnp.einsum("bqkgd,bskd->bqkgs", qs.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qp = jnp.arange(s)[:, None]
    kp = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((s, k.shape[1]), bool)
    if causal:
        mask &= qp >= kp
    if window:
        mask &= (qp - kp) < window
    logits = jnp.where(mask[None, :, None, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bqkgs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(b, s, h, d).astype(q.dtype)

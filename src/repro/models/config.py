"""Unified model configuration covering all assigned architecture families.

One dataclass describes dense / MoE / SSM / hybrid / VLM / audio decoder
stacks.  Blocks are laid out as a repeating ``block_pattern`` of mixer kinds
(``global`` attention, ``local`` attention, ``rglru`` recurrence, ``ssd``
Mamba2 mixer) so e.g. gemma3's 5:1 local:global and recurrentgemma's 2:1
rglru:local schedules are first-class.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp

MixerKind = str  # 'global' | 'local' | 'rglru' | 'ssd'


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int                     # 0 for attention-free archs
    n_kv_heads: int
    d_ff: int                        # dense FFN width (per-expert width for MoE)
    vocab_size: int

    head_dim: int = 0                # 0 -> d_model // n_heads
    block_pattern: Tuple[MixerKind, ...] = ("global",)
    local_window: int = 4096
    qkv_bias: bool = False
    mlp: str = "swiglu"              # swiglu | gelu
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    pos_emb: str = "rope"            # rope | sinusoidal | none
    rope_theta: float = 10_000.0
    logit_softcap: float = 0.0
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    conv_width: int = 4

    # RG-LRU (recurrentgemma)
    lru_width: int = 0               # 0 -> d_model

    # VLM stub frontend
    n_image_tokens: int = 0          # prepended precomputed patch embeddings

    # LoRA serving
    lora_targets: Tuple[str, ...] = ("q", "v")
    max_lora_rank: int = 64

    dtype: str = "bfloat16"

    # ------------------------------------------------------------------ #
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def attn_dim(self) -> int:
        return self.n_heads * self.resolved_head_dim

    @property
    def d_inner(self) -> int:
        """Mamba2 inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_n_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state else 0

    @property
    def layer_kinds(self) -> Tuple[MixerKind, ...]:
        """Mixer kind for each of the n_layers blocks (pattern repeats)."""
        pat = self.block_pattern
        return tuple(pat[i % len(pat)] for i in range(self.n_layers))

    @property
    def jnp_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def is_attention_free(self) -> bool:
        return all(k in ("rglru", "ssd") for k in self.layer_kinds)

    @property
    def has_full_attention(self) -> bool:
        return any(k == "global" for k in self.layer_kinds)

    @property
    def sub_quadratic(self) -> bool:
        """True if per-token decode cost does not grow ~linearly in context
        with a dense per-layer KV cache (SSM/recurrent/local-dominated)."""
        kinds = self.layer_kinds
        n_global = sum(k == "global" for k in kinds)
        return n_global <= max(1, len(kinds) // 5)

    # Parameter count (embedding included once) -- used for roofline 6ND.
    def param_count(self, active_only: bool = False) -> int:
        d, h = self.d_model, self.resolved_head_dim
        n_q, n_kv = self.n_heads, self.n_kv_heads
        total = self.vocab_size * d  # embed
        if not self.tie_embeddings:
            total += self.vocab_size * d  # unembed
        for kind in self.layer_kinds:
            total += 2 * d  # norms
            if kind in ("global", "local"):
                total += d * n_q * h + 2 * d * n_kv * h + n_q * h * d
                if self.qkv_bias:
                    total += (n_q + 2 * n_kv) * h
            elif kind == "ssd":
                di = self.d_inner
                nh = self.ssm_n_heads
                total += d * (2 * di + 2 * self.ssm_state + nh)  # in_proj
                total += di * d                                   # out_proj
                total += self.conv_width * (di + 2 * self.ssm_state)
                total += 2 * nh                                   # A, D
            elif kind == "rglru":
                w = self.lru_width or d
                total += d * w * 2 + w * d      # in (x,gate) + out proj
                total += self.conv_width * w    # temporal conv
                total += 2 * w                  # lru gates (a, input gate)
            if self.n_experts:
                total += d * self.n_experts  # router
                e = self.top_k if active_only else self.n_experts
                total += e * 3 * d * self.d_ff
            else:
                mult = 3 if self.mlp == "swiglu" else 2
                total += mult * d * self.d_ff
        return int(total)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def applicable_shapes(cfg: ModelConfig):
    """The assignment: long_500k only for sub-quadratic archs."""
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.sub_quadratic:
        out.append(LONG_500K)
    return out

"""DT-generated training dataset for the placement model (paper §VII-B).

Scenarios = combinations of (three rates out of the paper's rate set) x
(ranks out of {8,16,32}) x dataset profile.  Each scenario is labelled by
the starvation-bounded optimal placement found with the Digital Twin.
Features encode the workload condition as max/min/mean/std of each varying
characteristic — exactly the paper's encoding.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..serving.policy import sched_policy_index
from ..serving.request import Adapter
from .estimators import FittedEstimators
from .placement import PlacementResult, find_optimal_placement
from .workload import (WorkloadSpec, expected_prefix_hit_rate,
                       make_adapter_pool)

PAPER_RATES = (3.2, 1.6, 0.8, 0.4, 0.1, 0.05, 0.025,
               0.0125, 0.00625, 0.003125)
PAPER_RANKS = (8, 16, 32)

FEATURE_NAMES = (
    "rate_max", "rate_min", "rate_mean", "rate_std",
    "rank_max", "rank_min", "rank_mean", "rank_std",
    "in_mean", "in_std", "out_mean", "out_std",
    "sched_policy", "prefix_hit_rate",
)
TARGET_NAMES = ("throughput", "served_adapters", "adapter_slots")


def encode_features(rates: Sequence[float], ranks: Sequence[int],
                    stats: Dict[str, float],
                    sched_policy: str = "fcfs",
                    prefix_hit_rate: float = 0.0) -> np.ndarray:
    # ``prefix_hit_rate``: expected shared-prefix cache hit rate of the
    # workload (repro.core.workload.expected_prefix_hit_rate); 0.0 = no
    # shared prefixes (the paper's original encoding)
    r = np.asarray(rates, float)
    k = np.asarray(ranks, float)
    return np.array([
        r.max(), r.min(), r.mean(), r.std(),
        k.max(), k.min(), k.mean(), k.std(),
        stats["in_mean"], stats["in_std"],
        stats["out_mean"], stats["out_std"],
        float(sched_policy_index(sched_policy)),
        float(prefix_hit_rate),
    ])


@dataclasses.dataclass
class Scenario:
    rates: Tuple[float, ...]
    ranks: Tuple[int, ...]
    dataset: str
    sched_policy: str = "fcfs"
    # shared-prefix workload statistics (0.0/0 = the paper's original
    # prefix-free scenarios)
    prefix_share: float = 0.0
    prefix_len: int = 0

    def pool(self, max_adapters: int) -> List[Adapter]:
        return make_adapter_pool(max_adapters, self.ranks, self.rates)


def scenario_grid(rate_set: Sequence[float] = PAPER_RATES,
                  rank_set: Sequence[int] = PAPER_RANKS,
                  datasets: Sequence[str] = ("medium",),
                  n_rates: int = 3,
                  limit: Optional[int] = None,
                  seed: int = 0,
                  sched_policies: Sequence[str] = ("fcfs",)
                  ) -> List[Scenario]:
    """Scenario grid; ``sched_policies`` adds the scheduling-policy
    dimension (the default keeps the paper's FCFS-only grid)."""
    combos = list(itertools.combinations_with_replacement(rate_set, n_rates))
    out = []
    for rates in combos:
        for ds in datasets:
            for sp in sched_policies:
                out.append(Scenario(rates=tuple(rates),
                                    ranks=tuple(rank_set),
                                    dataset=ds, sched_policy=sp))
    rng = np.random.default_rng(seed)
    rng.shuffle(out)
    if limit:
        out = out[:limit]
    return out


def label_scenarios(est: FittedEstimators, scenarios: Sequence[Scenario],
                    max_adapters: int = 96, horizon: float = 200.0,
                    seed: int = 0, verbose: bool = False, runner=None
                    ) -> Tuple[np.ndarray, np.ndarray, List[PlacementResult]]:
    """Label scenarios with twin placement sweeps.  ``runner`` (a
    ``repro.core.sweep.SweepRunner``) distributes scenarios across a
    process pool; per-scenario seeds keep the labels identical to the
    serial path for any pool size."""
    if runner is not None:
        from .sweep import SweepTask
        tasks = [SweepTask(pool=tuple(sc.pool(max_adapters)),
                           dataset=sc.dataset, horizon=horizon,
                           seed=seed + i, sched_policy=sc.sched_policy,
                           prefix_share=sc.prefix_share,
                           prefix_len=sc.prefix_len)
                 for i, sc in enumerate(scenarios)]
        results = runner.map(tasks)
    else:
        results = [find_optimal_placement(est, sc.pool(max_adapters),
                                          sc.dataset, horizon=horizon,
                                          seed=seed + i,
                                          sched_policy=sc.sched_policy,
                                          prefix_share=sc.prefix_share,
                                          prefix_len=sc.prefix_len)
                   for i, sc in enumerate(scenarios)]
    xs, ys = [], []
    for i, (sc, res) in enumerate(zip(scenarios, results)):
        pool = sc.pool(max_adapters)
        spec = WorkloadSpec(adapters=pool, dataset=sc.dataset,
                            prefix_share=sc.prefix_share,
                            prefix_len=sc.prefix_len)
        feats = encode_features([a.rate for a in pool],
                                [a.rank for a in pool], spec.length_stats(),
                                sched_policy=sc.sched_policy,
                                prefix_hit_rate=expected_prefix_hit_rate(
                                    spec))
        xs.append(feats)
        ys.append([res.throughput, res.n_adapters, res.slots])
        if verbose and (i + 1) % 10 == 0:
            print(f"  labelled {i + 1}/{len(scenarios)}")
    return np.asarray(xs), np.asarray(ys), results

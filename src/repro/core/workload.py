"""Workload generation: multi-adapter request streams (paper §IV-A Setup).

Each adapter has an independent Poisson arrival process; request lengths
come from the paper's datasets: the three synthetic single-length profiles
(SmallRequest 23/27, MediumRequest 250/231, LargeRequest 423/358 — P25 /
mean / P75 of cleaned ShareGPT) or a ShareGPT-like lognormal sampler.
"""
from __future__ import annotations

import dataclasses
import heapq
import json
import math
from pathlib import Path
from typing import Dict, Iterable, Iterator, List, Sequence, Tuple, Union

import numpy as np

from ..serving.request import Adapter, Request

DATASETS: Dict[str, Tuple[int, int]] = {
    "small": (23, 27),
    "medium": (250, 231),
    "large": (423, 358),
}

# lognormal parameters roughly matching cleaned-ShareGPT in/out lengths
SHAREGPT_IN = (5.0, 1.0)     # mu, sigma  (median ~148, mean ~244)
SHAREGPT_OUT = (5.0, 0.9)


@dataclasses.dataclass
class WorkloadSpec:
    adapters: List[Adapter]
    dataset: str = "medium"           # small | medium | large | sharegpt
    horizon: float = 600.0
    seed: int = 0
    # shared-prefix statistics: each adapter (tenant) owns one system
    # prompt of ``prefix_len`` tokens; a ``prefix_share`` fraction of its
    # requests carry it (prepended to the sampled prompt).  0.0/0 = no
    # prefixes — generated streams are bitwise identical to before.
    prefix_share: float = 0.0
    prefix_len: int = 0

    @property
    def total_rate(self) -> float:
        return sum(a.rate for a in self.adapters)

    def length_stats(self) -> Dict[str, float]:
        """Aggregate stats for the DT *mean* mode."""
        if self.dataset in DATASETS:
            i, o = DATASETS[self.dataset]
            return {"in_mean": i, "in_std": 0.0, "out_mean": o, "out_std": 0.0}
        mi, si = SHAREGPT_IN
        mo, so = SHAREGPT_OUT
        return {
            "in_mean": math.exp(mi + si ** 2 / 2),
            "in_std": math.exp(mi + si ** 2 / 2)
            * math.sqrt(math.exp(si ** 2) - 1),
            "out_mean": math.exp(mo + so ** 2 / 2),
            "out_std": math.exp(mo + so ** 2 / 2)
            * math.sqrt(math.exp(so ** 2) - 1),
        }


def _sample_lengths(dataset: str, n: int, rng) -> Tuple[np.ndarray, np.ndarray]:
    if dataset in DATASETS:
        i, o = DATASETS[dataset]
        return np.full(n, i, int), np.full(n, o, int)
    if dataset == "sharegpt":
        i = np.clip(rng.lognormal(*SHAREGPT_IN, n), 4, 4096).astype(int)
        o = np.clip(rng.lognormal(*SHAREGPT_OUT, n), 4, 2048).astype(int)
        return i, o
    raise ValueError(dataset)


def assign_shared_prefixes(reqs: List[Request], share: float,
                           prefix_len: int, seed: int = 0) -> List[Request]:
    """Mark a ``share`` fraction of requests as carrying their tenant's
    shared system prompt: the carrier's ``prompt_len`` grows by
    ``prefix_len`` and ``prefix_id`` is set to the adapter uid (one
    prompt per tenant, shared across all its requests).

    Carrier selection uses its own RNG stream (``seed + 7919``), so the
    base arrival/length draws are untouched — ``share=0`` leaves the
    stream bitwise identical, and two shares of the same stream differ
    only in the prefix fields."""
    if share <= 0 or prefix_len <= 0 or not reqs:
        return reqs
    rng = np.random.default_rng(seed + 7919)
    carrier = rng.random(len(reqs)) < share
    for r, c in zip(reqs, carrier):
        if c:
            r.prefix_id = r.adapter
            r.prefix_len = prefix_len
            r.prompt_len += prefix_len
    return reqs


def expected_prefix_hit_rate(spec: WorkloadSpec) -> float:
    """Analytic prefix-cache hit-rate estimate from workload statistics
    (the twin-side model and the placement features consume this): per
    tenant, every carrier after the first is an expected hit, so the
    expected hit count is ``max(rate * horizon * share - 1, 0)``,
    normalized by total offered requests.  Ignores capacity evictions —
    an upper bound that tightens as slot pressure falls."""
    if spec.prefix_share <= 0 or spec.prefix_len <= 0:
        return 0.0
    total = sum(a.rate * spec.horizon for a in spec.adapters if a.rate > 0)
    if total <= 0:
        return 0.0
    hits = sum(max(a.rate * spec.horizon * spec.prefix_share - 1.0, 0.0)
               for a in spec.adapters if a.rate > 0)
    return hits / total


def generate_requests(spec: WorkloadSpec) -> List[Request]:
    rng = np.random.default_rng(spec.seed)
    reqs: List[Request] = []
    uid = 0
    for ad in spec.adapters:
        if ad.rate <= 0:
            continue
        t = 0.0
        arrivals = []
        while True:
            t += rng.exponential(1.0 / ad.rate)
            if t >= spec.horizon:
                break
            arrivals.append(t)
        ins, outs = _sample_lengths(spec.dataset, len(arrivals), rng)
        for a, i, o in zip(arrivals, ins, outs):
            reqs.append(Request(uid=uid, adapter=ad.uid, arrival=a,
                                prompt_len=int(i), output_len=max(int(o), 1)))
            uid += 1
    reqs.sort(key=lambda r: r.arrival)
    for i, r in enumerate(reqs):
        r.uid = i
    return assign_shared_prefixes(reqs, spec.prefix_share, spec.prefix_len,
                                  seed=spec.seed)


def _moment_sampler(mean: float, std: float, rng, lo: int):
    """Positive-valued sampler matching (mean, std) via a lognormal
    (method of moments) — request lengths are heavy-tailed, so this
    preserves queueing behaviour far better than a clipped normal."""
    if std <= 0:
        return lambda: max(int(mean), lo)
    sigma2 = math.log(1.0 + (std / mean) ** 2)
    mu = math.log(mean) - sigma2 / 2.0
    sig = math.sqrt(sigma2)
    return lambda: max(int(rng.lognormal(mu, sig)), lo)


def resample_requests(spec: WorkloadSpec, stats: Dict[str, float],
                      seed_shift: int = 1) -> List[Request]:
    """DT *mean* mode: regenerate a statistically equivalent stream from
    aggregate in/out length stats and the adapter rates."""
    rng = np.random.default_rng(spec.seed + seed_shift)
    sample_in = _moment_sampler(stats["in_mean"], stats["in_std"], rng, 4)
    sample_out = _moment_sampler(stats["out_mean"], stats["out_std"], rng, 1)
    reqs: List[Request] = []
    uid = 0
    for ad in spec.adapters:
        if ad.rate <= 0:
            continue
        t = 0.0
        while True:
            t += rng.exponential(1.0 / ad.rate)
            if t >= spec.horizon:
                break
            reqs.append(Request(uid=uid, adapter=ad.uid, arrival=t,
                                prompt_len=sample_in(), output_len=sample_out()))
            uid += 1
    reqs.sort(key=lambda r: r.arrival)
    for i, r in enumerate(reqs):
        r.uid = i
    return assign_shared_prefixes(reqs, spec.prefix_share, spec.prefix_len,
                                  seed=spec.seed + seed_shift)


def make_adapter_pool(n: int, ranks: Sequence[int], rates: Sequence[float],
                      location: str = "cpu") -> List[Adapter]:
    """Round-robin rank/rate assignment (paper's 'equal distribution')."""
    return [Adapter(uid=i, rank=ranks[i % len(ranks)],
                    rate=rates[i % len(rates)], location=location)
            for i in range(n)]


# --------------------------------------------------------------------------- #
# open-loop arrival drivers (the async gateway's inputs)
# --------------------------------------------------------------------------- #

def open_loop_arrivals(pool: Sequence[Adapter], dataset: str = "medium",
                       horizon: float = math.inf, seed: int = 0,
                       start_uid: int = 0, prefix_share: float = 0.0,
                       prefix_len: int = 0) -> Iterator[Request]:
    """Lazy merged per-adapter Poisson arrival process.

    Unlike ``generate_requests`` (which materializes a closed horizon up
    front), this yields requests one at a time in arrival order via a
    heap merge of the per-adapter exponential clocks — so it works with
    an unbounded ``horizon`` and never holds the stream in memory.  The
    gateway consumes it directly.  Deterministic per seed; note the RNG
    draw order differs from ``generate_requests``, so the two produce
    different (equally valid) streams for the same seed.
    """
    rng = np.random.default_rng(seed)
    # carrier flags come from a separate RNG stream (matching
    # ``assign_shared_prefixes``): prefix_share=0 draws nothing, so the
    # base arrival/length stream stays bitwise identical
    prng = np.random.default_rng(seed + 7919) \
        if prefix_share > 0 and prefix_len > 0 else None
    heap: List[Tuple[float, int, float]] = []
    for ad in pool:
        if ad.rate <= 0:
            continue
        heapq.heappush(
            heap, (rng.exponential(1.0 / ad.rate), ad.uid, ad.rate))
    uid = start_uid
    while heap:
        t, adapter_uid, rate = heapq.heappop(heap)
        if t >= horizon:
            continue                     # this adapter's clock is done
        ins, outs = _sample_lengths(dataset, 1, rng)
        req = Request(uid=uid, adapter=adapter_uid, arrival=float(t),
                      prompt_len=int(ins[0]),
                      output_len=max(int(outs[0]), 1))
        if prng is not None and prng.random() < prefix_share:
            req.prefix_id = adapter_uid
            req.prefix_len = prefix_len
            req.prompt_len += prefix_len
        yield req
        uid += 1
        heapq.heappush(
            heap, (t + rng.exponential(1.0 / rate), adapter_uid, rate))


# ``Request`` fields that are serving *progress*, not arrival identity:
# traces persist only identity, so these are deliberately absent from
# ``save_trace``/``load_trace``/``replay_trace``.  The trace-request-
# fields lint rule in ``repro.analysis`` reads this tuple — a new
# ``Request`` field must either be threaded through all three trace
# functions or added here, so it can never be silently dropped.
TRACE_PROGRESS_FIELDS = (
    "generated", "admitted_at", "first_token_at", "finished_at",
    "token_times", "n_preemptions",
    "n_retries", "n_timeouts", "failed_at", "retry_at", "disconnected_at",
)


def replay_trace(requests: Iterable[Request]) -> Iterator[Request]:
    """Trace-replay driver: yield *fresh* copies (generation progress
    reset) of a recorded request stream, in arrival order.  Feeding the
    same trace to a closed-loop ``ServingEngine.run`` and to the gateway
    is the deterministic-equivalence guard in tests/test_gateway.py."""
    for r in sorted(requests, key=lambda r: (r.arrival, r.uid)):
        yield Request(uid=r.uid, adapter=r.adapter, arrival=r.arrival,
                      prompt_len=r.prompt_len, output_len=r.output_len,
                      prefix_id=r.prefix_id, prefix_len=r.prefix_len)


def save_trace(path: Union[str, Path],
               requests: Iterable[Request]) -> None:
    """Persist an arrival trace as JSON (only the immutable request
    identity — uid/adapter/arrival/lengths — not serving progress)."""
    rows = [{"uid": r.uid, "adapter": r.adapter, "arrival": r.arrival,
             "prompt_len": r.prompt_len, "output_len": r.output_len,
             "prefix_id": r.prefix_id, "prefix_len": r.prefix_len}
            for r in requests]
    Path(path).write_text(json.dumps(rows))


def load_trace(path: Union[str, Path]) -> List[Request]:
    """Load a ``save_trace`` JSON back into replayable requests."""
    rows = json.loads(Path(path).read_text())
    return [Request(uid=int(r["uid"]), adapter=int(r["adapter"]),
                    arrival=float(r["arrival"]),
                    prompt_len=int(r["prompt_len"]),
                    output_len=max(int(r["output_len"]), 1),
                    # absent in pre-prefix traces -> None/0 (no prefix)
                    prefix_id=(None if r.get("prefix_id") is None
                               else int(r["prefix_id"])),
                    prefix_len=int(r.get("prefix_len", 0) or 0))
            for r in rows]


# --------------------------------------------------------------------------- #
# drifting adapter popularity (the rebalancing workload)
# --------------------------------------------------------------------------- #

@dataclasses.dataclass
class DriftPhase:
    """Piecewise-constant adapter rates on [start, <next phase start>)."""
    start: float
    rates: Dict[int, float]              # adapter uid -> req/s


def rotating_hot_phases(pool: Sequence[Adapter], horizon: float,
                        n_phases: int = 3, hot_fraction: float = 0.25,
                        hot_rate: float = 0.5,
                        cold_rate: float = 0.02) -> List[DriftPhase]:
    """The drifting-popularity scenario: in each phase a different
    contiguous slice of the pool is 'hot' (skewed traffic), everything
    else trickles.  Phase k's hot set is disjoint from phase k+1's, so
    residency earned in one phase is exactly wrong for the next — the
    workload static routing degrades on and a rebalancer fixes."""
    if n_phases < 1:
        raise ValueError("need at least one phase")
    uids = [a.uid for a in pool]
    hot_n = max(int(len(uids) * hot_fraction), 1)
    phases: List[DriftPhase] = []
    for k in range(n_phases):
        start = horizon * k / n_phases
        hot = {uids[(k * hot_n + j) % len(uids)] for j in range(hot_n)}
        phases.append(DriftPhase(
            start=start,
            rates={u: (hot_rate if u in hot else cold_rate)
                   for u in uids}))
    return phases


def generate_drifting_requests(pool: Sequence[Adapter], dataset: str,
                               horizon: float, phases: Sequence[DriftPhase],
                               seed: int = 0, prefix_share: float = 0.0,
                               prefix_len: int = 0) -> List[Request]:
    """Poisson arrivals with piecewise-constant per-adapter rates."""
    rng = np.random.default_rng(seed)
    phases = sorted(phases, key=lambda p: p.start)
    reqs: List[Request] = []
    uid = 0
    for i, ph in enumerate(phases):
        end = phases[i + 1].start if i + 1 < len(phases) else horizon
        for ad in pool:
            rate = ph.rates.get(ad.uid, ad.rate)
            if rate <= 0:
                continue
            t = ph.start
            arrivals = []
            while True:
                t += rng.exponential(1.0 / rate)
                if t >= end:
                    break
                arrivals.append(t)
            ins, outs = _sample_lengths(dataset, len(arrivals), rng)
            for a, in_len, out_len in zip(arrivals, ins, outs):
                reqs.append(Request(uid=uid, adapter=ad.uid, arrival=a,
                                    prompt_len=int(in_len),
                                    output_len=max(int(out_len), 1)))
                uid += 1
    reqs.sort(key=lambda r: (r.arrival, r.uid))
    for i, r in enumerate(reqs):
        r.uid = i
    return assign_shared_prefixes(reqs, prefix_share, prefix_len, seed=seed)

"""The paper's primary contribution: Digital Twin + ML placement pipeline."""
from .digital_twin import DigitalTwin, DTResult, EstimatorExecutor  # noqa
from .estimators import (FittedEstimators, collect_benchmark,  # noqa
                         collect_memmax, fit_estimators)
from .forest import (MODEL_ZOO, DecisionTree, LinearRegression,  # noqa
                     RandomForest, Ridge)
from .cluster_twin import ClusterDigitalTwin, ClusterDTResult  # noqa
from .placement import (ClusterPlacementResult, PlacementPoint,  # noqa
                        PlacementResult, ReplicaPlacement,
                        find_cluster_placement, find_optimal_placement,
                        split_pool_by_rate)
from .pipeline import PlacementPipeline, build_pipeline  # noqa
from .dataset import (FEATURE_NAMES, PAPER_RANKS, PAPER_RATES,  # noqa
                      TARGET_NAMES, Scenario, encode_features,
                      label_scenarios, scenario_grid)
from .workload import (DATASETS, WorkloadSpec, generate_requests,  # noqa
                       make_adapter_pool, resample_requests)

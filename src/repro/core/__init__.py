"""The paper's primary contribution: Digital Twin + ML placement pipeline."""
from .digital_twin import DigitalTwin, DTResult, EstimatorExecutor  # noqa
from .fast_twin import FastEngine, FastTwin  # noqa
from .sweep import SweepRunner, SweepTask  # noqa
from .estimators import (FittedEstimators, MeasuredStepTimes,  # noqa
                         collect_benchmark, collect_memmax,
                         fit_estimators, fit_measured_step_times)
from .forest import (MODEL_ZOO, DecisionTree, LinearRegression,  # noqa
                     RandomForest, Ridge)
from .cluster_twin import ClusterDigitalTwin, ClusterDTResult  # noqa
from .placement import (CLUSTER_FEATURE_NAMES, CLUSTER_TARGET_NAMES,  # noqa
                        ClusterModelNodeView, ClusterPlacementModel,
                        ClusterPlacementResult, PlacementPoint,
                        PlacementResult, ReplicaPlacement,
                        encode_cluster_features, find_cluster_placement,
                        find_cluster_placement_joint,
                        find_optimal_placement, label_cluster_scenarios,
                        split_pool_by_rate, train_cluster_placement_model)
from .pipeline import PlacementPipeline, build_pipeline  # noqa
from .dataset import (FEATURE_NAMES, PAPER_RANKS, PAPER_RATES,  # noqa
                      TARGET_NAMES, Scenario, encode_features,
                      label_scenarios, scenario_grid)
from .workload import (DATASETS, DriftPhase, WorkloadSpec,  # noqa
                       assign_shared_prefixes, expected_prefix_hit_rate,
                       generate_drifting_requests, generate_requests,
                       load_trace, make_adapter_pool, open_loop_arrivals,
                       replay_trace, resample_requests,
                       rotating_hot_phases, save_trace)

"""End-to-end pipeline (paper Fig. 1).

Creation phase:
  1. benchmark the real serving engine (controlled probes),
  2. fit the Eq. (1) estimators,
  3. sweep the Digital Twin over scenario grids -> labelled dataset,
  4. train the interpretable placement model (RF by default).

Production phase:
  ``recommend(rates, ranks, length_stats)`` -> (throughput, N*, G*) in
  sub-millisecond time, suitable for routers / autoscalers.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..serving.executor import HardwareProfile, SyntheticExecutor
from ..serving.metrics import smape_vec
from .dataset import (FEATURE_NAMES, TARGET_NAMES, Scenario, encode_features,
                      label_scenarios, scenario_grid)
from .estimators import (FittedEstimators, collect_benchmark, collect_memmax,
                         fit_estimators)
from .forest import MODEL_ZOO


@dataclasses.dataclass
class PlacementPipeline:
    est: FittedEstimators
    model: object
    model_name: str
    feature_names: Tuple[str, ...] = FEATURE_NAMES
    target_names: Tuple[str, ...] = TARGET_NAMES
    fit_report: Dict[str, float] = dataclasses.field(default_factory=dict)

    # ------------------------------------------------------------------ #
    def recommend(self, rates: Sequence[float], ranks: Sequence[int],
                  length_stats: Dict[str, float],
                  sched_policy: str = "fcfs",
                  prefix_hit_rate: float = 0.0) -> Dict[str, float]:
        x = encode_features(rates, ranks, length_stats,
                            sched_policy=sched_policy,
                            prefix_hit_rate=prefix_hit_rate)[None]
        t0 = time.perf_counter()
        y = np.asarray(self.model.predict(x))[0]
        dt = time.perf_counter() - t0
        return {
            "throughput": float(y[0]),
            "served_adapters": max(int(round(y[1])), 1),
            "adapter_slots": max(int(round(y[2])), 1),
            "inference_ms": dt * 1e3,
        }


def build_pipeline(
        profile: Optional[HardwareProfile] = None,
        slots_for_bench: int = 32, n_adapters_for_bench: int = 96,
        scenarios: Optional[List[Scenario]] = None,
        n_scenarios: int = 40, max_adapters: int = 96,
        horizon: float = 150.0, model_name: str = "forest",
        seed: int = 0, verbose: bool = False,
        n_workers: int = 0,
        sched_policies: Sequence[str] = ("fcfs",)) -> PlacementPipeline:
    """Creation phase end-to-end (sizes default to test-scale; the Table-I
    benchmark scales them up).  ``n_workers > 1`` fans the DT scenario
    sweeps across a ``SweepRunner`` process pool (identical labels).
    ``sched_policies`` widens the scenario grid with the scheduling-policy
    axis, so the model can learn e.g. that ``adapter-fair`` shifts N*."""
    profile = profile or HardwareProfile()
    ranks = {i: (8, 16, 32)[i % 3] for i in range(n_adapters_for_bench)}
    executor = SyntheticExecutor(profile, ranks, slots=slots_for_bench,
                                 n_adapters=n_adapters_for_bench, seed=seed)
    step_rows = collect_benchmark(executor, slots_for_bench,
                                  n_adapters_for_bench, ranks)
    mem_rows = collect_memmax(profile, seed=seed)
    est = fit_estimators(step_rows, mem_rows, slots_for_bench,
                         n_adapters_for_bench)

    scenarios = scenarios or scenario_grid(limit=n_scenarios, seed=seed,
                                           sched_policies=sched_policies)
    runner = None
    if n_workers > 1:
        from .sweep import SweepRunner
        runner = SweepRunner(est, n_workers=n_workers)
    xs, ys, _ = label_scenarios(est, scenarios, max_adapters=max_adapters,
                                horizon=horizon, seed=seed, verbose=verbose,
                                runner=runner)

    model = MODEL_ZOO[model_name]()
    n_train = max(int(0.8 * len(xs)), 1)
    model.fit(xs[:n_train], ys[:n_train])
    report: Dict[str, float] = {}
    if len(xs) > n_train:
        pred = np.asarray(model.predict(xs[n_train:]))
        for j, name in enumerate(TARGET_NAMES):
            report[f"smape_{name}"] = smape_vec(pred[:, j], ys[n_train:, j])
    return PlacementPipeline(est=est, model=model, model_name=model_name,
                             fit_report=report)

"""Cluster Digital Twin: the paper's offline simulator, lifted to a fleet.

Reuses the *same* ``ClusterRouter`` as the online ``ServingCluster`` and
the same per-replica scheduling machinery as the single-engine
``DigitalTwin`` — each replica is a ``ServingEngine`` driven by an
``EstimatorExecutor`` whose step times come from the fitted Eq. (1)
estimators.  That makes cluster-level placement searches (per-replica
served-adapter counts and slot configurations) as cheap to label as the
paper's single-GPU sweeps: single process, no accelerator.

``simulate`` is the offline path (route everything, then serve);
``simulate_online`` drives the *same epoch loop* as the production
``ServingCluster.run_online`` — online rebalancing, replica failures and
straggler route-away — with every migration charged the *fitted* Fig. 4
load cost (``est.lat_load``), so rebalancing decisions labelled by the
twin pay the same price the real fleet would.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional, Sequence

from ..serving.cluster import (ClusterMetrics, ClusterRouter, FailureEvent,
                               OnlineReport, ReplicaSpec, ServingCluster)
from ..serving.engine import ServingEngine
from ..serving.metrics import ServingMetrics
from ..serving.rebalance import RebalancePolicy
from ..serving.request import Request
from .digital_twin import EstimatorExecutor
from .estimators import FittedEstimators
from .fast_twin import FastEngine
from .workload import WorkloadSpec, resample_requests


@dataclasses.dataclass
class ClusterDTResult:
    metrics: ClusterMetrics            # per-replica view: metrics.per_replica
    router_summary: Dict[str, object]
    sim_wall_time: float
    mode: str
    online: Optional[OnlineReport] = None


class ClusterDigitalTwin:
    def __init__(self, est: FittedEstimators, mode: str = "mean",
                 max_running: int = 256, fast: bool = True):
        """``fast`` (default) runs every replica on the struct-of-arrays
        ``repro.core.fast_twin.FastEngine`` — same scheduling semantics
        and metrics as the object-mode ``ServingEngine`` replicas
        (``fast=False``, the equivalence oracle), ~10x cheaper, which is
        what makes joint fleet sweeps affordable as training labels."""
        assert mode in ("full", "mean")
        self.est = est
        self.mode = mode
        self.max_running = max_running
        self.fast = fast

    # ------------------------------------------------------------------ #
    def specs_from_slots(self, slots: Sequence[int],
                         mean_rank: float = 8.0,
                         sched_policy: str = "fcfs",
                         prefix_cache: bool = False) -> List[ReplicaSpec]:
        """Build replica specs whose KV capacity comes from the fitted
        Mem_max estimator — the DT analogue of probing each node."""
        return [ReplicaSpec(
            adapter_slots=g,
            kv_capacity_tokens=self.est.kv_capacity(g, mean_rank),
            max_running=self.max_running,
            sched_policy=sched_policy,
            prefix_cache=prefix_cache) for g in slots]

    # ------------------------------------------------------------------ #
    def simulate(self, spec: WorkloadSpec, router: ClusterRouter,
                 requests: Optional[List[Request]] = None,
                 horizon: Optional[float] = None) -> ClusterDTResult:
        t0 = time.perf_counter()
        ranks = {a.uid: a.rank for a in spec.adapters}
        if self.mode == "mean" or requests is None:
            requests = resample_requests(spec, spec.length_stats())
        else:
            # full mode gets the exact stream (deep copy to keep caller's);
            # progress AND reliability lifecycle restart clean — replaying
            # a chaos run's stream must not inherit its retry state
            requests = [dataclasses.replace(
                r, generated=0, admitted_at=None, first_token_at=None,
                finished_at=None, token_times=[], n_preemptions=0,
                n_retries=0, n_timeouts=0, failed_at=None, retry_at=None,
                disconnected_at=None)
                for r in requests]
        router.reset()
        parts = router.partition(requests)
        per: List[ServingMetrics] = []
        for rspec, part in zip(router.specs, parts):
            # the estimator's G/N term sees the adapters this replica
            # actually serves, not the whole joint pool
            n_rep = max(len({r.adapter for r in part}), 1)
            ex = EstimatorExecutor(self.est, rspec.adapter_slots, n_rep,
                                   ranks)
            engine = (FastEngine(rspec.engine_config(), ex,
                                 track_requests=False)
                      if self.fast else
                      ServingEngine(rspec.engine_config(), ex))
            per.append(engine.run(part, horizon=horizon or spec.horizon))
        return ClusterDTResult(
            metrics=ClusterMetrics.aggregate(per),
            router_summary=router.summary(),
            sim_wall_time=time.perf_counter() - t0,
            mode=self.mode)

    # ------------------------------------------------------------------ #
    def rebalancer(self, spec: WorkloadSpec, router: ClusterRouter,
                   **kwargs) -> RebalancePolicy:
        """A ``RebalancePolicy`` whose migration cost is the *fitted*
        Fig. 4 load estimator — the twin's honesty guarantee."""
        ranks = {a.uid: a.rank for a in spec.adapters}
        return RebalancePolicy(
            router,
            load_cost_fn=lambda uid: self.est.lat_load(ranks.get(uid, 8)),
            **kwargs)

    def predictive_rebalancer(self, spec: WorkloadSpec,
                              router: ClusterRouter, model,
                              **kwargs) -> "PredictiveRebalancer":
        """A ``PredictiveRebalancer`` (model-driven planning) with the
        same fitted Fig. 4 migration cost as :meth:`rebalancer`."""
        from ..serving.predictive import PredictiveRebalancer
        ranks = {a.uid: a.rank for a in spec.adapters}
        return PredictiveRebalancer(
            router, model=model, pool=spec.adapters,
            length_stats=spec.length_stats(),
            load_cost_fn=lambda uid: self.est.lat_load(ranks.get(uid, 8)),
            **kwargs)

    def simulate_online(self, spec: WorkloadSpec, router: ClusterRouter,
                        requests: Optional[List[Request]] = None,
                        epoch: float = 5.0, rebalance: bool = True,
                        rebalancer: Optional[RebalancePolicy] = None,
                        failures: Sequence[FailureEvent] = (),
                        straggler_factor: float = 0.0,
                        horizon: Optional[float] = None,
                        drain: bool = True,
                        max_drain_epochs: int = 1000,
                        initial_placement: Optional[Dict[int, int]] = None,
                        fault_plan=None,
                        reliability=None
                        ) -> ClusterDTResult:
        """Epoch-driven fleet simulation: the production ``run_online``
        loop over estimator-backed engines.

        Unlike ``simulate``, an explicitly provided request stream is
        honoured in *both* DT modes: online runs exist to study
        non-stationary streams (drift, failures), which a mean-mode
        resample would silently flatten back to stationary Poisson.

        ``fault_plan`` / ``reliability`` pass straight through to
        ``run_online``: the twin replays the identical fault schedule
        bitwise (same epoch-granular timeline, same engine hooks), so a
        faulted run is as labelable as a healthy one.
        """
        t0 = time.perf_counter()
        ranks = {a.uid: a.rank for a in spec.adapters}
        if requests is None:
            requests = resample_requests(spec, spec.length_stats())
        else:
            requests = [dataclasses.replace(
                r, generated=0, admitted_at=None, first_token_at=None,
                finished_at=None, token_times=[], n_preemptions=0,
                n_retries=0, n_timeouts=0, failed_at=None, retry_at=None,
                disconnected_at=None)
                for r in requests]
        # expected per-replica share of the pool for the estimator's G/N
        # term (the online partition is not known up front)
        n_share = max(math.ceil(len(spec.adapters) / router.n_replicas), 1)
        executors = [EstimatorExecutor(self.est, rspec.adapter_slots,
                                       n_share, ranks)
                     for rspec in router.specs]
        cluster = ServingCluster(
            router, executors,
            engine_factory=FastEngine if self.fast else None)
        if rebalancer is None and rebalance:
            rebalancer = self.rebalancer(spec, router)
        if reliability is not None and reliability.load_cost_fn is None:
            # honesty default: recovery reloads pay the fitted Fig. 4 cost
            reliability = dataclasses.replace(
                reliability,
                load_cost_fn=lambda uid: self.est.lat_load(
                    ranks.get(uid, 8)))
        report = cluster.run_online(
            requests, horizon=horizon or spec.horizon, epoch=epoch,
            rebalancer=rebalancer, failures=failures,
            straggler_factor=straggler_factor, drain=drain,
            max_drain_epochs=max_drain_epochs,
            initial_placement=initial_placement,
            fault_plan=fault_plan, reliability=reliability)
        return ClusterDTResult(
            metrics=report.metrics,
            router_summary=report.router_summary,
            sim_wall_time=time.perf_counter() - t0,
            mode=self.mode,
            online=report)

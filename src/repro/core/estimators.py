"""The five estimators of Eq. (1) + their fitting from benchmark data.

    Lat_step     = Lat_sched + Lat_load + Lat_model * Lat_adapters
    Lat_sched    = K0 + K1*R_running + K2*R_waiting + K3*R_waiting*(G/N)
    Lat_model    = K4*R_running + K4p*prefill_tokens + K5
    Lat_adapters = K6*A_running + K7
    Lat_load     = per-rank linear (CPU->GPU; disk is a multiplier)
    Mem_max      = KV-token capacity ~ base - c*(slots * mean_rank)

K4p (prefill-token term) is our extension over the paper's Lat_model — the
paper folds prefill into K4*R; we found the explicit term necessary once
prompts exceed a few hundred tokens (recorded as a deviation in DESIGN.md).
Setting ``prefill_term=False`` recovers the paper-exact form.

All constants are FITTED from benchmark rows collected on the real engine
(`collect_benchmark` below drives the engine's executor over controlled
grids, mirroring the paper's §V controlled settings).  The Digital Twin
only ever sees these fits — never the executor's hidden profile.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from ..serving.executor import StepTiming
from ..serving.scheduler import StepPlan
from ..serving.request import Request


@dataclasses.dataclass
class MeasuredStepTimes:
    """Per-step decode-time surface fitted from kernel microbenchmarks.

    The analytic ``Lat_model``/``Lat_adapters`` terms of Eq. (1) come
    from controlled probes of a (possibly synthetic) executor;
    ``MeasuredStepTimes`` replaces them with coefficients fitted from
    *actual kernel launches* (``benchmarks/kernels_bench.py``'s
    measurement mode: the fused flash-decode+LoRA kernel over a
    (rank, batch, seq) grid), so twin/placement decisions reflect what
    the hardware kernels really cost:

        Lat_model(B, pf)  = c0 + cB·B + cBS·B·mean_seq + cBr·B·mean_rank
                            + prefill_per_token · pf
        Lat_adapters(A)   = m0 + m1·A   (unique-adapter multiplier)

    All coefficients are seconds (multiplier dimensionless).  The hook is
    strictly opt-in: a ``FittedEstimators`` with ``measured=None`` is
    bitwise-identical to one fitted before this class existed (pinned by
    ``tests/test_measured_step_times.py``).
    """
    decode: np.ndarray          # [c0, cB, cBS, cBr] seconds
    prefill_per_token: float    # seconds per prefill token
    adapters: np.ndarray        # [m0, m1] unique-adapter multiplier
    mean_seq: float = 512.0     # decode context the surface is centred on
    mean_rank: float = 8.0
    source: str = "kernels_bench"

    def lat_model(self, r_run: int, prefill_tokens: int = 0) -> float:
        feats = [1.0, r_run, r_run * self.mean_seq, r_run * self.mean_rank]
        return float(self.decode @ feats) \
            + self.prefill_per_token * prefill_tokens

    def lat_adapters(self, a_run: int) -> float:
        if a_run == 0:
            return 1.0
        return float(self.adapters @ [1.0, a_run])


def fit_measured_step_times(rows: List[dict], mean_seq: float = 512.0,
                            mean_rank: float = 8.0) -> MeasuredStepTimes:
    """Fit the measured step-time surface from kernel benchmark rows.

    ``rows`` come from ``benchmarks.kernels_bench.collect_kernel_rows``:

    * ``kind='decode'``   — batch, seq, rank, t (seconds): one fused
      decode-step launch;
    * ``kind='prefill'``  — tokens, t: one SGMV prefill launch;
    * ``kind='adapters'`` — a_unique, mult: step-time multiplier versus
      the single-adapter launch at the same shape.
    """
    dec = [r for r in rows if r["kind"] == "decode"]
    if not dec:
        raise ValueError("no decode rows to fit a step-time surface from")
    fd = np.array([[1.0, r["batch"], r["batch"] * r["seq"],
                    r["batch"] * r["rank"]] for r in dec])
    decode, *_ = np.linalg.lstsq(fd, np.array([r["t"] for r in dec]),
                                 rcond=None)

    pf = [r for r in rows if r["kind"] == "prefill"]
    if pf:
        fp = np.array([[1.0, r["tokens"]] for r in pf])
        coef, *_ = np.linalg.lstsq(fp, np.array([r["t"] for r in pf]),
                                   rcond=None)
        prefill_per_token = max(float(coef[1]), 0.0)
    else:
        prefill_per_token = 0.0

    ad = [r for r in rows if r["kind"] == "adapters"]
    if ad:
        fa = np.array([[1.0, r["a_unique"]] for r in ad])
        adapters, *_ = np.linalg.lstsq(
            fa, np.array([r["mult"] for r in ad]), rcond=None)
    else:
        adapters = np.array([1.0, 0.0])

    return MeasuredStepTimes(decode=decode,
                             prefill_per_token=prefill_per_token,
                             adapters=adapters, mean_seq=mean_seq,
                             mean_rank=mean_rank)


@dataclasses.dataclass
class FittedEstimators:
    sched: np.ndarray           # [K0, K1, K2, K3]
    model: np.ndarray           # [K5, K4, K4p]
    adapters: np.ndarray        # [K7, K6]
    load: np.ndarray            # [base, per_rank] (cpu)
    load_disk_mult: float
    memmax: np.ndarray          # [base_tokens, per_slot_rank]
    prefill_term: bool = True
    # opt-in: measured kernel step-time surface replacing the analytic
    # Lat_model × Lat_adapters terms (None = paper-exact analytic path)
    measured: Optional[MeasuredStepTimes] = None

    # ------------------------------------------------------------------ #
    def with_measured(self, measured: Optional[MeasuredStepTimes]
                      ) -> "FittedEstimators":
        """Copy of these fits with the measured-kernel surface attached
        (or detached, with ``None``)."""
        return dataclasses.replace(self, measured=measured)

    def lat_sched(self, r_run: int, r_wait: int, slots: int, n: int) -> float:
        g_ratio = slots / max(n, 1)
        return float(self.sched @ [1.0, r_run, r_wait, r_wait * g_ratio])

    def lat_model(self, r_run: int, prefill_tokens: int = 0) -> float:
        if self.measured is not None:
            return self.measured.lat_model(r_run, prefill_tokens)
        pf = prefill_tokens if self.prefill_term else 0
        return float(self.model @ [1.0, r_run, pf])

    def lat_adapters(self, a_run: int) -> float:
        if self.measured is not None:
            return self.measured.lat_adapters(a_run)
        if a_run == 0:
            return 1.0
        return float(self.adapters @ [1.0, a_run])

    def lat_load(self, rank: int, location: str = "cpu") -> float:
        base = float(self.load @ [1.0, rank])
        return base * (self.load_disk_mult if location == "disk" else 1.0)

    def kv_capacity(self, slots: int, mean_rank: float) -> int:
        cap = self.memmax @ [1.0, slots * mean_rank]
        return max(int(cap), 0)

    def lat_step(self, plan: StepPlan, n_waiting: int, slots: int, n: int,
                 ranks: Dict[int, int]) -> StepTiming:
        load = sum(self.lat_load(ranks.get(u, 8)) for u in plan.cold_loads)
        model = self.lat_model(len(plan.running), plan.prefill_tokens)
        model *= self.lat_adapters(len(plan.unique_adapters))
        return StepTiming(
            sched=self.lat_sched(len(plan.running), n_waiting, slots, n),
            load=load, model=model)


# --------------------------------------------------------------------------- #
# benchmark collection (controlled probes of the real engine's executor)
# --------------------------------------------------------------------------- #

def _mk_plan(r_run: int, n_unique: int, prefill_tokens: int,
             cold_loads: Sequence[int] = ()) -> StepPlan:
    running = [Request(uid=i, adapter=i % max(n_unique, 1), arrival=0.0,
                       prompt_len=1, output_len=8) for i in range(r_run)]
    admitted = []
    if prefill_tokens and running:
        running[0].prompt_len = prefill_tokens
        admitted = [running[0]]
    return StepPlan(admitted=admitted, preempted=[],
                    cold_loads=list(cold_loads), running=running)


def collect_benchmark(executor, slots: int, n_adapters: int,
                      ranks: Dict[int, int],
                      r_grid: Sequence[int] = (1, 2, 4, 8, 16, 32, 64, 128),
                      a_grid: Sequence[int] = (0, 1, 2, 4, 8, 16, 32),
                      w_grid: Sequence[int] = (0, 8, 64, 256),
                      pf_grid: Sequence[int] = (0, 128, 512, 2048),
                      reps: int = 3) -> List[dict]:
    """Probe the executor over controlled grids; returns benchmark rows."""
    rows: List[dict] = []
    for _ in range(reps):
        for r in r_grid:
            for a in [x for x in a_grid if x <= r] or [1]:
                for w in w_grid:
                    plan = _mk_plan(r, max(a, 1) if a else 0, 0)
                    if a == 0:
                        plan = StepPlan([], [], [], [
                            Request(uid=i, adapter=-1, arrival=0.0,
                                    prompt_len=1, output_len=8)
                            for i in range(r)])
                    t = executor.step(plan, w)
                    rows.append(dict(kind="step", r_run=r, a_run=a,
                                     n_wait=w, prefill=0,
                                     sched=t.sched, model=t.model))
            for pf in pf_grid[1:]:
                plan = _mk_plan(r, 1, pf)
                t = executor.step(plan, 0)
                rows.append(dict(kind="step", r_run=r, a_run=1, n_wait=0,
                                 prefill=pf, sched=t.sched, model=t.model))
        for rank in sorted(set(ranks.values()) or {8, 16, 32}):
            plan = _mk_plan(1, 1, 0, cold_loads=[0])
            executor.ranks = dict(executor.ranks) if hasattr(
                executor, "ranks") else {}
            if hasattr(executor, "ranks"):
                executor.ranks[0] = rank
            t = executor.step(plan, 0)
            rows.append(dict(kind="load", rank=rank, load=t.load))
    return rows


def collect_memmax(profile, slot_grid=(8, 32, 128, 384),
                   rank_grid=(8, 16, 32), seed: int = 0) -> List[dict]:
    """Measure observed KV capacity per (slots, rank) — in a real deployment
    this is the max-batch-before-OOM probe; here it queries the engine's
    memory accounting (with measurement noise)."""
    rng = np.random.default_rng(seed)
    rows = []
    for s in slot_grid:
        for rk in rank_grid:
            cap = profile.kv_capacity(s, rk)
            cap = int(cap * (1.0 + rng.normal(0, 0.01)))
            rows.append(dict(slots=s, rank=rk, capacity=cap))
    return rows


# --------------------------------------------------------------------------- #
# fitting
# --------------------------------------------------------------------------- #

def _lstsq(feats: np.ndarray, y: np.ndarray) -> np.ndarray:
    coef, *_ = np.linalg.lstsq(feats, y, rcond=None)
    return coef


def fit_estimators(step_rows: List[dict], mem_rows: List[dict],
                   slots: int, n_adapters: int,
                   load_disk_mult: float = 1.7,
                   prefill_term: bool = True) -> FittedEstimators:
    srows = [r for r in step_rows if r["kind"] == "step"]
    lrows = [r for r in step_rows if r["kind"] == "load"]

    # scheduler: K0 + K1 R + K2 W + K3 W*(G/N)
    g_ratio = slots / max(n_adapters, 1)
    fs = np.array([[1.0, r["r_run"], r["n_wait"], r["n_wait"] * g_ratio]
                   for r in srows])
    sched = _lstsq(fs, np.array([r["sched"] for r in srows]))

    # model+adapters (joint): model_obs = (K5 + K4 R + K4p pf) * (K7 + K6 A)
    # two-stage: fit base on A<=1 rows, then fit multiplier.
    base_rows = [r for r in srows if r["a_run"] <= 1]
    fb = np.array([[1.0, r["r_run"], r["prefill"]] for r in base_rows])
    model = _lstsq(fb, np.array([r["model"] for r in base_rows]))
    if not prefill_term:
        model = np.array([model[0], model[1], 0.0])

    multi_rows = [r for r in srows if r["a_run"] >= 1 and r["prefill"] == 0]
    base_pred = np.array([[1.0, r["r_run"], r["prefill"]] for r in multi_rows]
                         ) @ model
    ratio = np.array([r["model"] for r in multi_rows]) / np.maximum(
        base_pred, 1e-9)
    fa = np.array([[1.0, r["a_run"]] for r in multi_rows])
    adapters = _lstsq(fa, ratio)

    # base was fitted on A==1 rows which already include the 1-adapter
    # multiplier; renormalise so (adapters @ [1, a]) is the multiplier on
    # the adapterless base.
    one = float(adapters @ [1.0, 1.0])
    if one > 0:
        model = model / one * 1.0
        # refit multiplier against the adapterless base
        base_pred = np.array(
            [[1.0, r["r_run"], r["prefill"]] for r in multi_rows]) @ model
        ratio = np.array([r["model"] for r in multi_rows]) / np.maximum(
            base_pred, 1e-9)
        adapters = _lstsq(fa, ratio)

    fl = np.array([[1.0, r["rank"]] for r in lrows]) if lrows else \
        np.array([[1.0, 8.0]])
    load = _lstsq(fl, np.array([r["load"] for r in lrows])) if lrows else \
        np.array([0.008, 0.001])

    fm = np.array([[1.0, r["slots"] * r["rank"]] for r in mem_rows])
    memmax = _lstsq(fm, np.array([float(r["capacity"]) for r in mem_rows]))

    return FittedEstimators(sched=sched, model=model, adapters=adapters,
                            load=load, load_disk_mult=load_disk_mult,
                            memmax=memmax, prefill_term=prefill_term)

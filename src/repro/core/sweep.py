"""SweepRunner: parallel, reproducible twin sweeps for training-data
generation.

The placement model's creation phase labels (scenario x fleet-size) grid
points with Digital Twin sweeps — embarrassingly parallel work that the
legacy path ran serially.  ``SweepRunner`` fans ``SweepTask``s across a
process pool:

  * **reproducible** — every task carries its own workload seed, so the
    labels are a pure function of (estimators, task); results return in
    task order regardless of pool size or worker scheduling.  Serial
    (``n_workers<=1``) and parallel runs produce identical labels
    (``tests/test_fast_twin.py`` enforces it).
  * **memoized estimator fits** — the fitted estimators are shipped to
    each worker exactly once (pool initializer), not per task.
  * **robust** — on any pool-creation failure the runner degrades to the
    serial path (same results, no parallelism).

The default ``spawn`` start method keeps workers clean of whatever
threads the parent accumulated (JAX's XLA client makes ``fork`` unsafe
mid-benchmark); pass ``mp_context="fork"`` for the cheapest start-up in
pure-numpy parents.
"""
from __future__ import annotations

import dataclasses
import multiprocessing
import os
from concurrent.futures import BrokenExecutor, ProcessPoolExecutor
from typing import List, Optional, Sequence, Tuple

from ..serving.request import Adapter
from .estimators import FittedEstimators
from .placement import (PlacementResult, find_cluster_placement_joint,
                        find_optimal_placement)


@dataclasses.dataclass(frozen=True)
class SweepTask:
    """One twin sweep: a single-node (N*, G*) search when
    ``n_replicas == 0``, a joint cluster sweep otherwise."""
    pool: Tuple[Adapter, ...]
    dataset: str
    horizon: float
    seed: int
    n_replicas: int = 0
    n_grid: Optional[Tuple[int, ...]] = None
    dt_mode: str = "mean"
    early_stop: int = 2
    policy: str = "affinity"
    sched_policy: str = "fcfs"
    prefix_share: float = 0.0
    prefix_len: int = 0


def run_task(est: FittedEstimators, task: SweepTask) -> PlacementResult:
    """Evaluate one sweep task (the unit of work a worker executes)."""
    n_grid = list(task.n_grid) if task.n_grid is not None else None
    if task.n_replicas:
        return find_cluster_placement_joint(
            est, list(task.pool), task.dataset, n_replicas=task.n_replicas,
            horizon=task.horizon, seed=task.seed, n_grid=n_grid,
            policy=task.policy, early_stop=task.early_stop,
            sched_policy=task.sched_policy,
            prefix_share=task.prefix_share, prefix_len=task.prefix_len)
    return find_optimal_placement(
        est, list(task.pool), task.dataset, horizon=task.horizon,
        seed=task.seed, n_grid=n_grid, dt_mode=task.dt_mode,
        early_stop=task.early_stop, sched_policy=task.sched_policy,
        prefix_share=task.prefix_share, prefix_len=task.prefix_len)


_WORKER_EST: Optional[FittedEstimators] = None


def _init_worker(est: FittedEstimators) -> None:
    global _WORKER_EST
    _WORKER_EST = est


def _run_in_worker(task: SweepTask) -> PlacementResult:
    return run_task(_WORKER_EST, task)


class SweepRunner:
    """Fan sweep tasks across a process pool; fall back to serial."""

    def __init__(self, est: FittedEstimators,
                 n_workers: Optional[int] = None,
                 mp_context: str = "spawn"):
        self.est = est
        if n_workers is None:
            n_workers = min(os.cpu_count() or 1, 8)
        self.n_workers = max(int(n_workers), 0)
        self.mp_context = mp_context

    def map(self, tasks: Sequence[SweepTask]) -> List[PlacementResult]:
        """Evaluate every task; results are returned in task order and
        are identical for any worker count (including serial)."""
        tasks = list(tasks)
        if self.n_workers <= 1 or len(tasks) <= 1:
            return [run_task(self.est, t) for t in tasks]
        try:
            ctx = multiprocessing.get_context(self.mp_context)
            with ProcessPoolExecutor(
                    max_workers=min(self.n_workers, len(tasks)),
                    mp_context=ctx, initializer=_init_worker,
                    initargs=(self.est,)) as pool:
                return list(pool.map(_run_in_worker, tasks))
        except (OSError, PermissionError, ValueError, ImportError,
                BrokenExecutor):
            # restricted environments (no fork/spawn, or workers killed at
            # startup — pool creation is lazy, so that surfaces as
            # BrokenProcessPool from map): serial fallback, same labels
            return [run_task(self.est, t) for t in tasks]

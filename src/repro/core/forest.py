"""Interpretable models, reimplemented in numpy (sklearn/imodels are not
available offline): linear / ridge regression and CART / random-forest
regressors with multi-output targets.

The paper's best model is a random forest with <= 10 trees and depth <= 5 —
small enough that an exact-split CART is instant and the learned rules can
be printed (``DecisionTree.rules()``).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np


class LinearRegression:
    def __init__(self, l2: float = 0.0):
        self.l2 = l2
        self.coef: Optional[np.ndarray] = None

    def fit(self, x: np.ndarray, y: np.ndarray) -> "LinearRegression":
        x = np.asarray(x, float)
        y = np.asarray(y, float)
        xb = np.concatenate([x, np.ones((len(x), 1))], axis=1)
        if self.l2:
            a = xb.T @ xb + self.l2 * np.eye(xb.shape[1])
            self.coef = np.linalg.solve(a, xb.T @ y)
        else:
            self.coef, *_ = np.linalg.lstsq(xb, y, rcond=None)
        return self

    def predict(self, x: np.ndarray) -> np.ndarray:
        xb = np.concatenate([np.asarray(x, float),
                             np.ones((len(x), 1))], axis=1)
        return xb @ self.coef


def Ridge(l2: float = 1.0) -> LinearRegression:
    return LinearRegression(l2=l2)


@dataclasses.dataclass
class _Node:
    feature: int = -1
    threshold: float = 0.0
    left: int = -1
    right: int = -1
    value: Optional[np.ndarray] = None   # leaf mean (targets,)

    @property
    def is_leaf(self) -> bool:
        return self.feature < 0


class DecisionTree:
    """Exact-split CART regressor (variance reduction, multi-output)."""

    def __init__(self, max_depth: int = 5, min_samples_leaf: int = 3,
                 max_features: Optional[int] = None, seed: int = 0):
        self.max_depth = max_depth
        self.min_samples_leaf = min_samples_leaf
        self.max_features = max_features
        self.rng = np.random.default_rng(seed)
        self.nodes: List[_Node] = []

    def _best_split(self, x, y):
        n, f = x.shape
        feats = np.arange(f)
        if self.max_features and self.max_features < f:
            feats = self.rng.choice(f, self.max_features, replace=False)
        best = (None, None, np.inf)
        for j in feats:
            order = np.argsort(x[:, j], kind="stable")
            xs, ys = x[order, j], y[order]
            csum = np.cumsum(ys, axis=0)
            csum2 = np.cumsum(ys ** 2, axis=0)
            tot, tot2 = csum[-1], csum2[-1]
            ks = np.arange(1, n)
            valid = xs[1:] > xs[:-1]
            ks = ks[valid & (ks >= self.min_samples_leaf)
                    & (ks <= n - self.min_samples_leaf)]
            if len(ks) == 0:
                continue
            left2 = csum2[ks - 1] - csum[ks - 1] ** 2 / ks[:, None]
            nr = n - ks
            right2 = (tot2 - csum2[ks - 1]) - \
                (tot - csum[ks - 1]) ** 2 / nr[:, None]
            sse = left2.sum(axis=1) + right2.sum(axis=1)
            i = int(np.argmin(sse))
            if sse[i] < best[2]:
                k = ks[i]
                thr = 0.5 * (xs[k - 1] + xs[k])
                best = (int(j), float(thr), float(sse[i]))
        return best

    def _build(self, x, y, depth) -> int:
        node_id = len(self.nodes)
        self.nodes.append(_Node(value=y.mean(axis=0)))
        if depth >= self.max_depth or len(x) < 2 * self.min_samples_leaf \
                or np.allclose(y.var(axis=0).sum(), 0.0):
            return node_id
        j, thr, sse = self._best_split(x, y)
        if j is None:
            return node_id
        mask = x[:, j] <= thr
        base_sse = ((y - y.mean(axis=0)) ** 2).sum()
        if base_sse - sse < 1e-12:
            return node_id
        self._importance[j] += base_sse - sse
        node = self.nodes[node_id]
        node.feature, node.threshold = j, thr
        node.left = self._build(x[mask], y[mask], depth + 1)
        node.right = self._build(x[~mask], y[~mask], depth + 1)
        return node_id

    def fit(self, x, y) -> "DecisionTree":
        x = np.asarray(x, float)
        y = np.asarray(y, float)
        if y.ndim == 1:
            y = y[:, None]
        self.nodes = []
        self._importance = np.zeros(x.shape[1])
        self._build(x, y, 0)
        return self

    def feature_importances(self) -> np.ndarray:
        """Impurity-decrease importances, normalised to sum to 1 (the
        paper's interpretability, quantified)."""
        tot = self._importance.sum()
        if tot <= 0:
            return np.zeros_like(self._importance)
        return self._importance / tot

    def predict(self, x) -> np.ndarray:
        x = np.asarray(x, float)
        out = np.zeros((len(x), len(self.nodes[0].value)))
        for i, row in enumerate(x):
            nid = 0
            while not self.nodes[nid].is_leaf:
                nd = self.nodes[nid]
                nid = nd.left if row[nd.feature] <= nd.threshold else nd.right
            out[i] = self.nodes[nid].value
        return out

    def rules(self, feature_names: Optional[Sequence[str]] = None,
              target_names: Optional[Sequence[str]] = None) -> List[str]:
        """Human-readable decision rules (the paper's interpretability)."""
        names = feature_names or [f"x{i}" for i in range(100)]
        lines: List[str] = []

        def walk(nid, path):
            nd = self.nodes[nid]
            if nd.is_leaf:
                tgt = ", ".join(
                    f"{(target_names or ['y'] * len(nd.value))[i]}="
                    f"{v:.3g}" for i, v in enumerate(nd.value))
                lines.append(("IF " + " AND ".join(path) if path
                              else "ALWAYS") + f" THEN {tgt}")
                return
            walk(nd.left, path + [f"{names[nd.feature]} <= {nd.threshold:.3g}"])
            walk(nd.right, path + [f"{names[nd.feature]} > {nd.threshold:.3g}"])

        walk(0, [])
        return lines


class RandomForest:
    """Bagged CART ensemble (default: paper's 10 trees, depth 5)."""

    def __init__(self, n_trees: int = 10, max_depth: int = 5,
                 min_samples_leaf: int = 3,
                 max_features: Optional[str] = None, seed: int = 0):
        self.n_trees = n_trees
        self.max_depth = max_depth
        self.min_samples_leaf = min_samples_leaf
        self.max_features = max_features
        self.seed = seed
        self.trees: List[DecisionTree] = []

    def fit(self, x, y) -> "RandomForest":
        x = np.asarray(x, float)
        y = np.asarray(y, float)
        if y.ndim == 1:
            y = y[:, None]
        rng = np.random.default_rng(self.seed)
        n, f = x.shape
        mf = None
        if self.max_features == "sqrt":
            mf = max(int(np.sqrt(f)), 1)
        self.trees = []
        for t in range(self.n_trees):
            idx = rng.integers(0, n, n)          # bootstrap
            tree = DecisionTree(self.max_depth, self.min_samples_leaf,
                                max_features=mf, seed=self.seed + t + 1)
            tree.fit(x[idx], y[idx])
            self.trees.append(tree)
        return self

    def predict(self, x) -> np.ndarray:
        preds = [t.predict(x) for t in self.trees]
        return np.mean(preds, axis=0)

    def feature_importances(self) -> np.ndarray:
        """Mean of the trees' normalised impurity-decrease importances,
        renormalised (stump trees contribute zeros)."""
        if not self.trees:
            raise RuntimeError("fit before feature_importances")
        imp = np.mean([t.feature_importances() for t in self.trees],
                      axis=0)
        tot = imp.sum()
        return imp / tot if tot > 0 else imp


MODEL_ZOO = {
    "linear": lambda: LinearRegression(),
    "ridge": lambda: Ridge(1.0),
    "tree": lambda: DecisionTree(max_depth=5),
    "forest": lambda: RandomForest(n_trees=10, max_depth=5),
}

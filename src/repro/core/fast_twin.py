"""FastTwin: the Digital Twin's struct-of-arrays fast path (paper §VI).

The legacy ``DigitalTwin`` replays every simulated step through
per-request Python objects — ``Request`` dataclasses, attribute access,
``token_times`` list appends, per-step list copies.  Training-data
generation (the placement-model sweeps of §VII) is bounded by how cheap
one twin evaluation is, so this module re-implements the same
continuous-batching semantics over preallocated numpy arrays:

  * the request stream lives in struct-of-arrays columns (arrival,
    prompt/output lengths, adapter, generated, admitted/first-token/
    finished timestamps, KV tokens/blocks held);
  * the per-step decode allocation advances the whole running batch with
    vectorized ops when memory suffices, falling back to the engine's
    exact sequential preempt-by-recompute loop only under pressure;
  * Eq. (1) step times are memoized per (R_run, R_wait, prefill,
    A_unique) key — each distinct key is computed once through the very
    same ``FittedEstimators`` methods the legacy twin calls, so cached
    values are bitwise identical to the object-mode twin's;
  * the starvation-regime admission scan short-circuits when no waiting
    request's adapter is resident and no slot can be freed (the legacy
    engine walks the whole waiting queue every step in that state).

Equivalence contract (enforced by ``tests/test_fast_twin.py``): with the
deterministic estimator executor (the twin never has noise), ``FastTwin``
reproduces ``DigitalTwin`` *exactly* — same scheduling decisions, same
virtual clock, same throughput/TTFT/finish/preemption/load counts.  The
one documented tolerance is mean ITL: the legacy twin averages per-token
gaps (``sum(spans)/len``) while the fast path uses the algebraically
equal telescoped form ``(last - first)/(n - 1)``, which differs by float
rounding only (≲1e-9 relative).

``FastEngine`` implements the resumable engine surface
(``submit``/``run_until``/``finalize``/``drain``/``preload_adapter``/
``evict_adapter``) so the ``ClusterDigitalTwin``'s offline and online
fleet simulations run on it replica-for-replica.  S-LoRA dynamic-slot
mode stays on the legacy twin (``FastTwin.simulate`` delegates).
"""
from __future__ import annotations

import math
import time
from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np

from ..serving.engine import EngineConfig, StepTrace
from ..serving.metrics import ServingMetrics, ttft_percentiles
from ..serving.policy import (SchedView, make_sched_policy,
                              overrides_on_admit, overrides_victim)
from ..serving.prefix_cache import SharedPrefixCache
from ..serving.request import Request
from .digital_twin import DigitalTwin, DTResult, EstimatorExecutor
from .estimators import FittedEstimators
from .workload import WorkloadSpec, resample_requests

_NAN = float("nan")


class _StepTimes:
    """Memoized Eq. (1) step-time components.

    Every cache miss is computed by the *same* ``FittedEstimators``
    method the legacy ``EstimatorExecutor`` calls, so memoized values are
    bitwise identical — the fast twin's clock advances through exactly
    the float additions the legacy twin performs.
    """

    __slots__ = ("est", "slots", "n", "ranks", "_sched", "_base", "_mult",
                 "_load")

    def __init__(self, est: FittedEstimators, slots: int, n_adapters: int,
                 ranks: Dict[int, int]):
        self.est = est
        self.slots = slots
        self.n = n_adapters
        self.ranks = ranks
        self._sched: Dict[tuple, float] = {}
        self._base: Dict[tuple, float] = {}
        self._mult: Dict[int, float] = {}
        self._load: Dict[int, float] = {}

    def sched(self, r_run: int, n_wait: int) -> float:
        key = (r_run, n_wait)
        v = self._sched.get(key)
        if v is None:
            v = self._sched[key] = self.est.lat_sched(
                r_run, n_wait, self.slots, self.n)
        return v

    def model(self, r_run: int, prefill: int, a_run: int) -> float:
        key = (r_run, prefill)
        b = self._base.get(key)
        if b is None:
            b = self._base[key] = self.est.lat_model(r_run, prefill)
        m = self._mult.get(a_run)
        if m is None:
            m = self._mult[a_run] = self.est.lat_adapters(a_run)
        return b * m

    def load(self, uid: int) -> float:
        v = self._load.get(uid)
        if v is None:
            v = self._load[uid] = self.est.lat_load(self.ranks.get(uid, 8))
        return v


class _FastAdapterCache:
    """Mirror of ``AdapterSlotCache`` (fixed-slot mode) on plain dicts.

    Same LRU/pinning semantics and tie-breaks (dict insertion order);
    ``can_load`` is O(1) because pinned adapters are always loaded, so an
    idle resident adapter exists iff ``len(pinned) < len(loaded)``.
    """

    __slots__ = ("slots", "loaded", "pinned", "load_count", "evict_count",
                 "failing")

    def __init__(self, slots: int):
        self.slots = slots
        self.loaded: Dict[int, float] = {}     # adapter uid -> last-use time
        self.pinned: Dict[int, int] = {}       # adapter uid -> #running reqs
        self.load_count = 0
        self.evict_count = 0
        self.failing: set = set()              # uids whose loads fault-fail

    def is_loaded(self, uid: int) -> bool:
        return uid in self.loaded

    def can_load(self, uid: int) -> bool:
        if uid in self.loaded:
            return True
        if uid in self.failing:
            return False
        return (len(self.loaded) < self.slots
                or len(self.pinned) < len(self.loaded))

    def evict_idle_lru(self) -> Optional[int]:
        lru, best = None, None
        for a, ts in self.loaded.items():
            if a not in self.pinned and (best is None or ts < best):
                lru, best = a, ts
        if lru is None:
            return None
        del self.loaded[lru]
        self.evict_count += 1
        return lru

    def load(self, uid: int, now: float) -> bool:
        if uid in self.loaded:
            self.loaded[uid] = now
            return False
        if len(self.loaded) >= self.slots:
            if self.evict_idle_lru() is None:
                raise RuntimeError("no evictable adapter slot")
        self.loaded[uid] = now
        self.load_count += 1
        return True

    def evict(self, uid: int) -> bool:
        if uid not in self.loaded or self.pinned.get(uid, 0) > 0:
            return False
        del self.loaded[uid]
        self.evict_count += 1
        return True

    def pin(self, uid: int) -> None:
        self.pinned[uid] = self.pinned.get(uid, 0) + 1

    def unpin(self, uid: int) -> None:
        n = self.pinned.get(uid, 0) - 1
        if n <= 0:
            self.pinned.pop(uid, None)
        else:
            self.pinned[uid] = n

    def touch(self, uid: int, now: float) -> None:
        if uid in self.loaded:
            self.loaded[uid] = now


class _FastKVPool:
    """``PagedKVCache``'s block-accounting surface over ``FastEngine``'s
    scalar free-block counter — the very same ``SharedPrefixCache``
    instance class drives both engines, so cache decisions are identical
    by construction."""

    __slots__ = ("_eng",)

    def __init__(self, eng: "FastEngine"):
        self._eng = eng

    def blocks_needed(self, n_tokens: int) -> int:
        return -(-n_tokens // self._eng._block_size)

    @property
    def free_blocks(self) -> int:
        return self._eng._free_blocks

    def reserve_blocks(self, n_blocks: int) -> bool:
        if n_blocks > self._eng._free_blocks:
            return False
        self._eng._free_blocks -= n_blocks
        return True

    def release_blocks(self, n_blocks: int) -> None:
        self._eng._free_blocks += n_blocks


class _RowView(SchedView):
    """Policy accessors over struct-of-arrays row ids.

    Returns the very same values the object-mode ``_RequestView`` yields
    for the corresponding ``Request`` (arrivals are float64 both sides),
    so a policy's ordering decisions are bit-identical across engines.
    """

    __slots__ = ("_eng",)

    def __init__(self, eng: "FastEngine"):
        self._eng = eng

    def arrival(self, i: int) -> float:
        return float(self._eng._arrival[i])

    def adapter(self, i: int) -> int:
        return self._eng._ads[i]

    def context_len(self, i: int) -> int:
        return self._eng._prompts[i] + int(self._eng._generated[i])

    def resident(self, adapter: int) -> bool:
        return adapter in self._eng._adapters.loaded


class _SchedCounts:
    """Duck-typed stand-in for ``engine.scheduler`` queue-depth reads."""

    __slots__ = ("_eng",)

    def __init__(self, eng: "FastEngine"):
        self._eng = eng

    @property
    def n_waiting(self) -> int:
        return len(self._eng.waiting)

    @property
    def n_running(self) -> int:
        return self._eng._n_run


class FastEngine:
    """Struct-of-arrays replica of ``ServingEngine`` over an
    ``EstimatorExecutor`` (fixed-slot mode).

    Presents the same resumable surface (``submit``/``run_until``/
    ``finalize``/``drain``/``preload_adapter``/``evict_adapter``/``run``)
    and the same counters (``clock``/``busy_time``/``n_exec_steps``/
    ``n_tokens_out``), so the cluster's online epoch loop drives it
    unchanged.  ``track_requests=True`` (the default) keeps references to
    submitted ``Request`` objects and writes progress back whenever a
    request finishes or is drained — required by the online loop's
    completion checks.  ``FastTwin`` disables it for pure offline sweeps.

    Deviations from ``ServingEngine`` (documented, not observable in any
    supported path): ``token_times`` is not populated (first/last token
    timestamps are tracked instead — mean ITL is derived from those), and
    ``reset_stream`` fully reinitializes KV/adapter state rather than
    leaking a prior stream's running set.
    """

    SMALL_BATCH = 12          # below this, scalar loops beat numpy dispatch

    def __init__(self, cfg: EngineConfig, executor,
                 track_requests: bool = True):
        if cfg.dynamic_slots:
            raise NotImplementedError(
                "FastEngine covers fixed-slot mode; use ServingEngine / "
                "DigitalTwin for S-LoRA dynamic-slot simulations")
        if not isinstance(executor, EstimatorExecutor):
            raise TypeError(
                "FastEngine requires an EstimatorExecutor (fitted Eq. (1) "
                f"step times); got {type(executor).__name__}")
        self.cfg = cfg
        self.executor = executor
        self._times = _StepTimes(executor.est, executor.slots,
                                 executor.n_adapters, executor.ranks)
        self._track = track_requests
        self._block_size = cfg.block_size
        self._total_blocks = max(int(cfg.kv_capacity_tokens)
                                 // cfg.block_size, 0)
        self._max_running = cfg.max_running
        self.trace: List[StepTrace] = []
        self._sched_view = _SchedCounts(self)
        self._policy_view = _RowView(self)
        self.reset_stream()

    # ------------------------------------------------------------------ #
    # stream state
    # ------------------------------------------------------------------ #
    def reset_stream(self) -> None:
        # fresh policy per stream; a passed-through instance is reset
        # instead (mirrors ServingEngine.reset_stream -> policy.reset)
        self._policy = make_sched_policy(self.cfg.sched_policy)
        self._policy.reset()
        self._policy_is_fcfs = self._policy.name == "fcfs"
        self._admit_hook = overrides_on_admit(self._policy)
        self._victim_hook = overrides_victim(self._policy)
        self.clock = 0.0
        self.halted = False
        self._iters = 0
        self._max_kv = 0.0
        self.busy_time = 0.0
        self.n_exec_steps = 0
        self.n_tokens_out = 0
        # fault-injection state (mirrors ServingEngine)
        self.slow_factor = 1.0
        self.n_load_faults = 0
        self._row_of: Dict[int, int] = {}      # request uid -> latest row
        # struct-of-arrays request table (rows appended per submit)
        self._n_rows = 0
        cap = 256
        self._arrival = np.empty(cap)
        self._prompt = np.empty(cap, np.int64)
        self._out_len = np.empty(cap, np.int64)
        self._adapter = np.empty(cap, np.int64)
        # plain-list mirrors of the static columns: the admission scan
        # reads them per waiting row, where list indexing beats numpy
        # scalar extraction ~3x
        self._ads: List[int] = []
        self._prompts: List[int] = []
        self._outs: List[int] = []
        # finish-check countdown: min output tokens remaining across the
        # running batch; the per-step done-scan only runs when it can hit 0
        self._rem_min = math.inf
        self._admitted_rows: List[int] = []
        self._adm_min = math.inf
        self._generated = np.empty(cap, np.int64)
        self._admitted_at = np.empty(cap)
        self._first_tok = np.empty(cap)
        self._last_tok = np.empty(cap)
        self._finished = np.empty(cap)
        self._n_pre = np.empty(cap, np.int64)
        self._kv_tokens = np.zeros(cap, np.int64)
        self._kv_blocks = np.zeros(cap, np.int64)
        self._drained = np.zeros(cap, bool)
        self._refs: List[Optional[Request]] = []
        # queues
        self._pend = np.empty(0, np.int64)      # row ids sorted by arrival
        self._pend_arr = np.empty(0)            # their arrival times
        self._pend_list: List[int] = []
        self._next = 0
        self.waiting: Deque[int] = deque()
        self._wait_ads: Dict[int, int] = {}     # adapter -> #waiting rows
        self._run = np.empty(self._max_running, np.int64)
        self._n_run = 0
        self._rpos: Dict[int, int] = {}         # row id -> slot in _run
        self._free_blocks = self._total_blocks
        self._adapters = _FastAdapterCache(self.cfg.adapter_slots)
        # shared-prefix cache over the scalar block pool; holder ids are
        # row indices (the object engine uses request uids — equivalent,
        # both are stable per in-flight request)
        self._pfx_id: List[Optional[int]] = []
        self._pfx_len: List[int] = []
        self.prefix: Optional[SharedPrefixCache] = \
            SharedPrefixCache(_FastKVPool(self)) \
            if self.cfg.prefix_cache else None

    @property
    def scheduler(self) -> _SchedCounts:
        return self._sched_view

    @property
    def adapters(self) -> _FastAdapterCache:
        return self._adapters

    @property
    def queue_depth(self) -> int:
        """Admitted-but-unfinished requests, mirroring
        ``ServingEngine.queue_depth``: waiting + running rows plus
        submitted arrivals the clock has not reached yet."""
        return (len(self.waiting) + self._n_run
                + len(self._pend) - self._next)

    # ------------------------------------------------------------------ #
    def _grow(self, need: int) -> None:
        cap = len(self._arrival)
        new = cap
        while new < need:
            new *= 2
        for name in ("_arrival", "_admitted_at", "_first_tok", "_last_tok",
                     "_finished"):
            a = np.empty(new)
            a[:cap] = getattr(self, name)
            setattr(self, name, a)
        for name in ("_prompt", "_out_len", "_adapter", "_generated",
                     "_n_pre", "_kv_tokens", "_kv_blocks"):
            a = np.zeros(new, np.int64)
            a[:cap] = getattr(self, name)
            setattr(self, name, a)
        d = np.zeros(new, bool)
        d[:cap] = self._drained
        self._drained = d

    def submit(self, requests: List[Request], fresh: bool = False) -> None:
        """Enqueue arrivals.  ``fresh=True`` zeroes progress fields (the
        twin's semantics — the legacy ``DigitalTwin`` deep-copies the
        stream with progress reset); otherwise current request progress
        is carried over, matching ``ServingEngine.submit``."""
        if not requests:
            return
        n0, n1 = self._n_rows, self._n_rows + len(requests)
        if n1 > len(self._arrival):
            self._grow(n1)
        for i, r in enumerate(requests, start=n0):
            self._arrival[i] = r.arrival
            self._prompt[i] = r.prompt_len
            self._out_len[i] = r.output_len
            self._adapter[i] = r.adapter
            self._ads.append(r.adapter)
            self._prompts.append(r.prompt_len)
            self._outs.append(r.output_len)
            self._pfx_id.append(r.prefix_id)
            self._pfx_len.append(r.prefix_len)
            if fresh:
                self._generated[i] = 0
                self._n_pre[i] = 0
                self._admitted_at[i] = _NAN
                self._first_tok[i] = _NAN
                self._finished[i] = _NAN
            else:
                self._generated[i] = r.generated
                self._n_pre[i] = r.n_preemptions
                self._admitted_at[i] = (_NAN if r.admitted_at is None
                                        else r.admitted_at)
                self._first_tok[i] = (_NAN if r.first_token_at is None
                                      else r.first_token_at)
                self._finished[i] = (_NAN if r.finished_at is None
                                     else r.finished_at)
            self._last_tok[i] = _NAN
            self._kv_tokens[i] = 0
            self._kv_blocks[i] = 0
        if self._track:
            self._refs.extend(requests)
            for i, r in enumerate(requests, start=n0):
                self._row_of[r.uid] = i
        self._n_rows = n1
        new = np.arange(n0, n1, dtype=np.int64)
        merged = np.concatenate([self._pend[self._next:], new])
        order = np.argsort(self._arrival[merged], kind="stable")
        self._pend = merged[order]
        self._pend_arr = self._arrival[self._pend]
        self._pend_list = self._pend.tolist()
        self._next = 0

    # ------------------------------------------------------------------ #
    # KV + running-set bookkeeping (mirrors PagedKVCache / Scheduler)
    # ------------------------------------------------------------------ #
    def _kv_alloc(self, i: int, n_tokens: int) -> bool:
        held = int(self._kv_tokens[i])
        bs = self._block_size
        need = -(-(held + n_tokens) // bs) - int(self._kv_blocks[i])
        if need > self._free_blocks:
            return False
        self._free_blocks -= need
        self._kv_blocks[i] += need
        self._kv_tokens[i] = held + n_tokens
        return True

    def _kv_free(self, i: int) -> None:
        self._free_blocks += int(self._kv_blocks[i])
        self._kv_blocks[i] = 0
        self._kv_tokens[i] = 0

    def _append_running(self, i: int) -> None:
        self._rpos[i] = self._n_run
        self._run[self._n_run] = i
        self._n_run += 1

    def _remove_running(self, i: int) -> None:
        s = self._rpos.pop(i)
        self._n_run -= 1
        if s < self._n_run:
            last = int(self._run[self._n_run])
            self._run[s] = last
            self._rpos[last] = s

    def _preempt_one(self) -> Optional[int]:
        n = self._n_run
        if not n:
            return None
        run = self._run[:n]
        if self._victim_hook:
            # policy-chosen victim; running order matches the object
            # scheduler's list, so a custom rule sees identical input
            victim = self._policy.victim([int(x) for x in run],
                                         self._policy_view)
            if victim is None:
                return None
        else:
            victim = int(run[np.argmax(self._arrival[run])])
        self._remove_running(victim)
        self._kv_free(victim)
        self._adapters.unpin(int(self._adapter[victim]))
        if self.prefix is not None:
            self.prefix.release(victim)
        self._n_pre[victim] += 1
        self.waiting.appendleft(victim)
        ad = int(self._adapter[victim])
        self._wait_ads[ad] = self._wait_ads.get(ad, 0) + 1
        return victim

    def _decode_alloc_slow(self, snapshot: List[int]) -> List[int]:
        """Sequential decode allocation under memory pressure — a faithful
        transcription of the scheduler's preempt-by-recompute loop,
        including its semantics for requests preempted mid-scan."""
        preempted: List[int] = []
        for i in snapshot:
            while not self._kv_alloc(i, 1):
                # idle (zero-ref) shared prefixes are reclaimed before any
                # request is preempted (mirrors Scheduler.schedule; the
                # vectorized fast path never reaches here when blocks
                # suffice, in which case the object loop would not evict
                # either)
                if self.prefix is not None and self.prefix.evict_idle_lru():
                    continue
                victim = self._preempt_one()
                if victim is None:
                    break
                preempted.append(victim)
                if victim == i:
                    break
        return preempted

    # ------------------------------------------------------------------ #
    def _schedule(self, now: float):
        """One scheduler pass; returns (r_run, n_wait, prefill, a_run,
        load_lat) for the step-time model."""
        bs = self._block_size
        cache = self._adapters
        kv_tokens = self._kv_tokens
        preempted: List[int] = []
        self._admitted_rows.clear()
        self._adm_min = math.inf

        # 1. decode allocation for the running batch
        n = self._n_run
        if n:
            if n < self.SMALL_BATCH:
                snapshot = [int(self._run[s]) for s in range(n)]
                need = 0
                for i in snapshot:
                    if kv_tokens[i] % bs == 0:
                        need += 1
                if need <= self._free_blocks:
                    kb = self._kv_blocks
                    for i in snapshot:
                        if kv_tokens[i] % bs == 0:
                            kb[i] += 1
                        kv_tokens[i] += 1
                    self._free_blocks -= need
                else:
                    preempted = self._decode_alloc_slow(snapshot)
            else:
                run = self._run[:n]
                mask = kv_tokens[run] % bs == 0
                need = int(np.count_nonzero(mask))
                if need <= self._free_blocks:
                    self._kv_blocks[run] += mask
                    kv_tokens[run] += 1
                    self._free_blocks -= need
                else:
                    preempted = self._decode_alloc_slow(
                        [int(x) for x in run])

        # 2. admissions in the policy's order (FCFS walks the queue as
        # is), with the shared mechanical rules: loaded-adapter priority
        # skip, KV head-of-line break, max_running.  Fast exit for the
        # starvation regime: slots exhausted, every resident adapter
        # pinned, and no waiting request's adapter resident -> no
        # ordering can admit anything, so the whole scan (and the
        # policy's sort) is skipped.
        pf = 0
        load_lat = 0.0
        waiting = self.waiting
        loaded = cache.loaded
        pinned = cache.pinned
        if waiting and self._n_run < self._max_running and not (
                len(loaded) >= cache.slots
                and len(pinned) >= len(loaded)
                and self._wait_ads.keys().isdisjoint(loaded)):
            candidates = waiting if self._policy_is_fcfs else \
                self._policy.order(waiting, self._policy_view, now)
            just_pre = set(preempted) if preempted else None
            gen = self._generated
            ads = self._ads
            prompts = self._prompts
            outs = self._outs
            pc = self.prefix
            pfx_ids = self._pfx_id
            pfx_lens = self._pfx_len
            wa = self._wait_ads
            max_running = self._max_running
            adm_rows = self._admitted_rows
            adm_min = math.inf
            admitted: Optional[set] = None
            # "a non-resident adapter can get a slot" only *falls* during
            # a scan (admissions consume free slots and pin idle
            # residents), so the predicate is recomputed per admission,
            # not per skipped row
            can_new = (len(loaded) < cache.slots
                       or len(pinned) < len(loaded))
            failing = cache.failing
            for i in candidates:
                if self._n_run >= max_running:
                    break
                if just_pre is not None and i in just_pre:
                    continue
                a = ads[i]
                if a not in loaded and (not can_new or a in failing):
                    continue
                g = int(gen[i])
                ctx = prompts[i] + g
                # uid-aware need (mirrors PagedKVCache.can_allocate with
                # uid=): rows preempted mid-decode-scan can hold a
                # residual block that must be credited, not re-counted
                held_t = int(kv_tokens[i])
                held_b = int(self._kv_blocks[i])
                if pc is None:
                    if -(-(held_t + ctx + 1) // bs) - held_b \
                            > self._free_blocks:
                        break
                    covered = want_insert = 0
                    pfx_active = False
                else:
                    # prefix-aware KV gate — the retry chain (evict idle
                    # prefix -> serve uncached -> head-of-line stop) is a
                    # faithful transcription of Scheduler.schedule's
                    pid = pfx_ids[i]
                    pfx_active = pid is not None \
                        and 0 < min(pfx_lens[i], prompts[i])
                    covered = want_insert = 0
                    if pfx_active:
                        covered, want_insert = pc.plan(
                            pid, pfx_lens[i], prompts[i])
                    stop = False
                    while True:
                        if covered or want_insert:
                            fits = pc.fit_blocks(covered, want_insert,
                                                 ctx) <= self._free_blocks
                        else:
                            fits = -(-(held_t + ctx + 1) // bs) - held_b \
                                <= self._free_blocks
                        if fits:
                            break
                        if pc.evict_idle_lru(exclude=pid):
                            continue
                        if want_insert:
                            want_insert = 0
                            continue
                        stop = True
                        break
                    if stop:
                        break
                if cache.load(a, now):               # cold load
                    load_lat += self._times.load(a)
                cache.pin(a)
                if pfx_active:
                    pc.commit(i, pid, covered, want_insert)
                self._kv_alloc(i, ctx + 1 - covered - want_insert)
                # result unused — the
                # engine admits unconditionally once slots+KV checks passed
                self._admitted_at[i] = now
                self._append_running(i)
                adm_rows.append(i)
                rem = outs[i] - g
                if rem < adm_min:
                    adm_min = rem
                if admitted is None:
                    admitted = set()
                admitted.add(i)
                if self._admit_hook:
                    self._policy.on_admit(i, self._policy_view, now)
                c = wa[a] - 1
                if c:
                    wa[a] = c
                else:
                    del wa[a]
                pf += ctx - covered
                can_new = (len(loaded) < cache.slots
                           or len(pinned) < len(loaded))
            self._adm_min = adm_min
            if admitted is not None:
                self.waiting = deque(
                    w for w in waiting if w not in admitted)

        # 3. touch residency of every adapter with running work
        loaded = cache.loaded
        for a in cache.pinned:
            loaded[a] = now
        return (self._n_run, len(self.waiting), pf, len(cache.pinned),
                load_lat)

    # ------------------------------------------------------------------ #
    def _finish_step(self, t: float) -> None:
        """Per-token bookkeeping for the just-executed step."""
        n = self._n_run
        gen = self._generated
        first = self._first_tok
        # first-token timestamps can only be missing on rows admitted this
        # step (any earlier running step already stamped them)
        for i in self._admitted_rows:
            if first[i] != first[i]:                 # isnan
                first[i] = t
        rem_min = self._rem_min - 1
        if self._adm_min - 1 < rem_min:
            rem_min = self._adm_min - 1
        fin_rows: List[int] = []
        if n < self.SMALL_BATCH:
            last = self._last_tok
            out = self._outs
            for s in range(n):
                i = int(self._run[s])
                gen[i] += 1
                last[i] = t
                if rem_min <= 0 and gen[i] >= out[i]:
                    fin_rows.append(i)
        else:
            run = self._run[:n]
            gen[run] += 1
            self._last_tok[run] = t
            if rem_min <= 0:
                rem = self._out_len[run] - gen[run]
                done = rem <= 0
                fin_rows = [int(x) for x in run[done]]
        if rem_min <= 0:
            # a finish may have happened: remove done rows, refresh the
            # countdown from the survivors
            pc = self.prefix
            for i in fin_rows:
                self._finished[i] = t
                self._remove_running(i)
                self._kv_free(i)
                self._adapters.unpin(self._ads[i])
                if pc is not None:
                    pc.release(i)
            if fin_rows and self._track:
                self._sync_rows(fin_rows)
            m = self._n_run
            if m:
                run = self._run[:m]
                rem_min = int((self._out_len[run] - gen[run]).min())
            else:
                rem_min = math.inf
        self._rem_min = rem_min

    def _sync_rows(self, rows) -> None:
        """Write progress back to the tracked ``Request`` objects."""
        for i in rows:
            r = self._refs[i]
            r.generated = int(self._generated[i])
            v = float(self._admitted_at[i])
            r.admitted_at = None if v != v else v
            v = float(self._first_tok[i])
            r.first_token_at = None if v != v else v
            v = float(self._finished[i])
            r.finished_at = None if v != v else v
            r.n_preemptions = int(self._n_pre[i])

    # ------------------------------------------------------------------ #
    def run_until(self, t_end: Optional[float] = None,
                  record_trace: bool = False, strict: bool = False) -> None:
        """Advance the continuous-batching loop (see
        ``ServingEngine.run_until`` — identical control flow)."""
        if self.halted:
            return
        max_steps = self.cfg.max_steps
        pend_arr = self._pend_arr
        n_pend = len(pend_arr)
        total_blocks = self._total_blocks
        while self._iters < max_steps:
            self._iters += 1
            t = self.clock
            if t_end is not None and t >= t_end:
                return
            # idle fast-forward
            if not (self.waiting or self._n_run):
                if self._next >= n_pend:
                    return
                nxt = float(pend_arr[self._next])
                if strict and t_end is not None and nxt >= t_end:
                    self.clock = max(self.clock, min(nxt, t_end))
                    return
                t = max(t, nxt)
            # pull arrivals with arrival <= t
            if self._next < n_pend and pend_arr[self._next] <= t:
                hi = int(pend_arr.searchsorted(t, side="right"))
                wa = self._wait_ads
                ads = self._ads
                append = self.waiting.append
                for i in self._pend_list[self._next:hi]:
                    append(i)
                    a = ads[i]
                    wa[a] = wa.get(a, 0) + 1
                self._next = hi
            r_run, n_wait, pf, a_run, load_lat = self._schedule(t)
            if not r_run:
                # blocked (waiting requests that cannot be admitted yet)
                if self._next < n_pend:
                    nxt = float(pend_arr[self._next])
                    if strict and t_end is not None and nxt >= t_end:
                        self.clock = max(self.clock, min(nxt, t_end))
                        return
                    self.clock = max(t, nxt)
                    continue
                self.clock = t
                return
            total = (self._times.sched(r_run, n_wait) + load_lat) \
                + self._times.model(r_run, pf, a_run)
            # same guarded multiply as ServingEngine.run_until: both
            # engines scale the identical float by the identical factor
            if self.slow_factor != 1.0:
                total *= self.slow_factor
            t += total
            self.busy_time += total
            self.n_exec_steps += 1
            self.n_tokens_out += r_run
            kv_used = (1.0 - self._free_blocks / total_blocks) \
                if total_blocks else 1.0
            if kv_used > self._max_kv:
                self._max_kv = kv_used
            if record_trace:
                self.trace.append(StepTrace(
                    t, r_run, n_wait, kv_used, total))
            self._finish_step(t)
            self.clock = t

    # ------------------------------------------------------------------ #
    def finalize(self) -> ServingMetrics:
        duration = max(self.clock, 1e-9)
        n = self._n_rows
        acc = ~self._drained[:n]
        arr = self._arrival[:n]
        gen = self._generated[:n]
        out = self._out_len[:n]
        fin = self._finished[:n]
        first = self._first_tok[:n]
        arrived = acc & (arr <= duration)
        offered = int(out[arrived].sum())
        out_tokens = int(gen[acc].sum())
        fin_mask = acc & ~np.isnan(fin)
        itl_mask = fin_mask & (gen >= 2)
        itls = ((self._last_tok[:n][itl_mask] - first[itl_mask])
                / (gen[itl_mask] - 1))
        ttft_mask = acc & ~np.isnan(first)
        ttfts = first[ttft_mask] - arr[ttft_mask]
        pct = ttft_percentiles(ttfts)
        starved_rows = np.flatnonzero(arrived & np.isnan(first))
        starved_per_adapter: Dict[int, int] = {}
        for i in starved_rows:
            a = self._ads[i]
            starved_per_adapter[a] = starved_per_adapter.get(a, 0) + 1
        # reliability counters live on the tracked Request objects (the
        # cluster loop mutates them); sum over accounted rows exactly as
        # the object engine's summarize() does over _accepted
        n_timeouts = n_retries = n_failed = 0
        if self._track:
            for i in range(n):
                if self._drained[i]:
                    continue
                r = self._refs[i]
                n_timeouts += r.n_timeouts
                n_retries += r.n_retries
                if r.failed_at is not None:
                    n_failed += 1
        return ServingMetrics(
            throughput=out_tokens / duration,
            itl=float(np.mean(itls)) if len(itls) else 0.0,
            ttft=float(np.mean(ttfts)) if len(ttfts) else 0.0,
            ideal_throughput=offered / duration,
            duration=duration,
            n_finished=int(np.count_nonzero(fin_mask)),
            n_preemptions=int(self._n_pre[:n][acc].sum()),
            max_kv_used=self._max_kv,
            n_loads=self._adapters.load_count,
            ttft_p50=pct["p50"],
            ttft_p99=pct["p99"],
            n_starved_requests=int(len(starved_rows)),
            starved_per_adapter=starved_per_adapter,
            n_timeouts=n_timeouts,
            n_retries=n_retries,
            n_failed_requests=n_failed,
            n_load_faults=self.n_load_faults,
            n_prefix_hits=self.prefix.n_hits if self.prefix else 0,
            n_prefix_misses=self.prefix.n_misses if self.prefix else 0,
            n_prefix_evictions=self.prefix.n_evictions if self.prefix else 0,
            prefix_tokens_saved=self.prefix.tokens_saved
            if self.prefix else 0,
            ttft_samples=[float(t) for t in ttfts],
        )

    # ------------------------------------------------------------------ #
    # fault-tolerance / rebalancing hooks (mirror ServingEngine)
    # ------------------------------------------------------------------ #
    def drain(self) -> List[Request]:
        if not self._track:
            raise RuntimeError(
                "drain() needs track_requests=True (the online loop's "
                "re-routing works on Request objects)")
        orphan_rows = ([int(self._run[s]) for s in range(self._n_run)]
                       + list(self.waiting)
                       + [int(x) for x in self._pend[self._next:]])
        for s in range(self._n_run):
            i = int(self._run[s])
            self._kv_free(i)
            self._adapters.unpin(int(self._adapter[i]))
            if self.prefix is not None:
                self.prefix.release(i)
        self._n_run = 0
        self._rpos.clear()
        self._rem_min = math.inf
        self.waiting.clear()
        self._wait_ads.clear()
        self._pend = np.empty(0, np.int64)
        self._pend_arr = np.empty(0)
        self._pend_list = []
        self._next = 0
        self._drained[orphan_rows] = True
        self._sync_rows(orphan_rows)
        self.halted = True
        return [self._refs[i] for i in orphan_rows]

    def preload_adapter(self, uid: int, cost_s: float = 0.0) -> bool:
        if self._adapters.is_loaded(uid):
            self._adapters.touch(uid, self.clock)
            return True
        if uid in self._adapters.failing:
            self.n_load_faults += 1
            return False
        if not self._adapters.can_load(uid):
            return False
        self._adapters.load(uid, self.clock)
        self.clock += cost_s
        return True

    def evict_adapter(self, uid: int) -> bool:
        return self._adapters.evict(uid)

    def stall_until(self, t: float) -> None:
        """Transient executor fault: clock jump, no service (mirrors
        ``ServingEngine.stall_until``)."""
        self.clock = max(self.clock, t)

    def snapshot(self) -> dict:
        return {"clock": self.clock,
                "adapters": sorted(self._adapters.loaded)}

    def restore(self, snap: dict, now: float, load_cost_fn=None
                ) -> List[int]:
        """Crash recovery (mirrors ``ServingEngine.restore``): un-halt,
        clock to ``now``, reload the snapshot's adapter set at Fig. 4
        cost, skipping (and counting) fault-failing uids."""
        self.halted = False
        self.clock = max(now, self.clock)
        self._adapters.loaded.clear()
        self._adapters.pinned.clear()
        if self.prefix is not None:
            self.prefix.wipe()
        reloaded: List[int] = []
        for uid in snap.get("adapters", []):
            if uid in self._adapters.failing:
                self.n_load_faults += 1
                continue
            self._adapters.load(uid, self.clock)
            if load_cost_fn is not None:
                self.clock += load_cost_fn(uid)
            reloaded.append(uid)
        return reloaded

    def cancel(self, uid: int, forget: bool = False) -> Optional[Request]:
        """Pull one request out (mirrors ``ServingEngine.cancel``).
        Needs request tracking — cancellation hands the object back to
        the cluster/gateway reliability layer."""
        if not self._track:
            raise RuntimeError("cancel() needs track_requests=True")
        row = self._row_of.get(uid)
        if row is None or self._drained[row] \
                or self._finished[row] == self._finished[row]:  # finished
            return None
        if row in self._rpos:
            self._remove_running(row)
            self._kv_free(row)
            self._adapters.unpin(self._ads[row])
            if self.prefix is not None:
                self.prefix.release(row)
            m = self._n_run
            if m:
                run = self._run[:m]
                self._rem_min = int(
                    (self._out_len[run] - self._generated[run]).min())
            else:
                self._rem_min = math.inf
        elif row in self.waiting:
            self.waiting = deque(w for w in self.waiting if w != row)
            a = self._ads[row]
            c = self._wait_ads.get(a, 0) - 1
            if c > 0:
                self._wait_ads[a] = c
            else:
                self._wait_ads.pop(a, None)
        else:
            keep = self._pend[self._next:]
            mask = keep != row
            if mask.all():
                return None                     # already cancelled earlier
            keep = keep[mask]
            self._pend = keep
            self._pend_arr = self._arrival[keep]
            self._pend_list = keep.tolist()
            self._next = 0
        if forget:
            self._drained[row] = True
        self._sync_rows([row])
        return self._refs[row]

    # ------------------------------------------------------------------ #
    def run(self, requests: List[Request], horizon: Optional[float] = None,
            record_trace: bool = False,
            fresh: bool = False) -> ServingMetrics:
        self.reset_stream()
        self.submit(requests, fresh=fresh)
        self.run_until(horizon if horizon is not None else math.inf,
                       record_trace=record_trace)
        return self.finalize()


class FastTwin:
    """Drop-in ``DigitalTwin`` on the struct-of-arrays fast engine.

    Same constructor and ``simulate`` signature; S-LoRA dynamic-slot
    simulations delegate to the legacy object-mode twin.
    """

    def __init__(self, est: FittedEstimators, mode: str = "full",
                 max_running: int = 256, sched_policy: str = "fcfs",
                 measured_step_times=None, prefix_cache: bool = False):
        assert mode in ("full", "mean")
        # same opt-in hook as DigitalTwin: attach the measured kernel
        # step-time surface to the fits (dynamic-slot delegation passes
        # self.est on, so the hook follows automatically)
        if measured_step_times is not None:
            est = est.with_measured(measured_step_times)
        self.est = est
        self.mode = mode
        self.max_running = max_running
        self.sched_policy = sched_policy
        self.prefix_cache = prefix_cache

    def simulate(self, spec: WorkloadSpec, slots: int,
                 requests: Optional[List[Request]] = None,
                 horizon: Optional[float] = None,
                 dynamic_slots: bool = False) -> DTResult:
        if dynamic_slots:
            return DigitalTwin(self.est, self.mode, self.max_running,
                               sched_policy=self.sched_policy,
                               prefix_cache=self.prefix_cache) \
                .simulate(spec, slots, requests, horizon,
                          dynamic_slots=True)
        t0 = time.perf_counter()
        ranks = {a.uid: a.rank for a in spec.adapters}
        mean_rank = (sum(ranks.values()) / len(ranks)) if ranks else 8.0
        n = len(spec.adapters)
        if self.mode == "mean" or requests is None:
            requests = resample_requests(spec, spec.length_stats())
        cfg = EngineConfig(
            kv_capacity_tokens=self.est.kv_capacity(slots, mean_rank),
            adapter_slots=slots, max_running=self.max_running,
            sched_policy=self.sched_policy,
            prefix_cache=self.prefix_cache)
        engine = FastEngine(cfg, EstimatorExecutor(self.est, slots, n,
                                                   ranks),
                            track_requests=False)
        metrics = engine.run(requests, horizon=horizon or spec.horizon,
                             fresh=True)
        return DTResult(metrics=metrics,
                        sim_wall_time=time.perf_counter() - t0,
                        mode=self.mode)

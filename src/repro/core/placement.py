"""Optimal-placement search (paper §IV / §VII-B).

Given a workload condition (a pool of adapters with rates/ranks and request
length characteristics), find the placement that maximizes throughput
without starvation: the number of served adapters N* and the adapter-slot
count G* at which throughput peaks while staying >= 90% of the offered
(ideal) rate.  The search sweeps the Digital Twin — the whole point of the
paper is that this sweep is cheap enough to label tens of thousands of
scenarios for the ML model.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from ..serving.request import Adapter
from .digital_twin import DigitalTwin
from .estimators import FittedEstimators
from .workload import WorkloadSpec


@dataclasses.dataclass
class PlacementPoint:
    n_adapters: int
    slots: int
    throughput: float
    ideal: float
    starved: bool


@dataclasses.dataclass
class PlacementResult:
    best: Optional[PlacementPoint]
    curve: List[PlacementPoint]

    @property
    def n_adapters(self) -> int:
        return self.best.n_adapters if self.best else 0

    @property
    def slots(self) -> int:
        return self.best.slots if self.best else 0

    @property
    def throughput(self) -> float:
        return self.best.throughput if self.best else 0.0


def default_slot_grid(n: int) -> List[int]:
    grid = sorted({max(1, n // 8), max(1, n // 4), max(1, n // 2), n})
    return grid


def find_optimal_placement(
        est: FittedEstimators, pool: Sequence[Adapter], dataset: str,
        horizon: float = 300.0, seed: int = 0,
        n_grid: Optional[Sequence[int]] = None,
        slot_grid=default_slot_grid, dt_mode: str = "mean",
        early_stop: int = 2) -> PlacementResult:
    """Sweep served-adapter counts (and slots) through the DT."""
    dt = DigitalTwin(est, mode=dt_mode)
    if n_grid is None:
        n_grid = sorted({max(1, len(pool) // k) for k in
                         (16, 8, 4, 3, 2)} | {len(pool)})
        n_grid = [n for n in n_grid if n >= 1]
    curve: List[PlacementPoint] = []
    best: Optional[PlacementPoint] = None
    drops = 0
    for n in sorted(n_grid):
        adapters = list(pool[:n])
        spec = WorkloadSpec(adapters=adapters, dataset=dataset,
                            horizon=horizon, seed=seed)
        best_at_n: Optional[PlacementPoint] = None
        for g in slot_grid(n):
            res = dt.simulate(spec, slots=g)
            pt = PlacementPoint(
                n_adapters=n, slots=g,
                throughput=res.metrics.throughput,
                ideal=res.metrics.ideal_throughput,
                starved=res.metrics.starved)
            curve.append(pt)
            if not pt.starved and (best_at_n is None
                                   or pt.throughput > best_at_n.throughput):
                best_at_n = pt
        if best_at_n is None:
            drops += 1
            if best is not None and drops >= early_stop:
                break
            continue
        if best is None or best_at_n.throughput >= best.throughput:
            best = best_at_n
            drops = 0
        else:
            drops += 1
            if drops >= early_stop:
                break
    return PlacementResult(best=best, curve=curve)

"""Optimal-placement search (paper §IV / §VII-B).

Given a workload condition (a pool of adapters with rates/ranks and request
length characteristics), find the placement that maximizes throughput
without starvation: the number of served adapters N* and the adapter-slot
count G* at which throughput peaks while staying >= 90% of the offered
(ideal) rate.  The search sweeps the Digital Twin — the whole point of the
paper is that this sweep is cheap enough to label tens of thousands of
scenarios for the ML model.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

from ..serving.request import Adapter
from .digital_twin import DigitalTwin
from .estimators import FittedEstimators
from .workload import WorkloadSpec


@dataclasses.dataclass
class PlacementPoint:
    n_adapters: int
    slots: int
    throughput: float
    ideal: float
    starved: bool


@dataclasses.dataclass
class PlacementResult:
    best: Optional[PlacementPoint]
    curve: List[PlacementPoint]

    @property
    def n_adapters(self) -> int:
        return self.best.n_adapters if self.best else 0

    @property
    def slots(self) -> int:
        return self.best.slots if self.best else 0

    @property
    def throughput(self) -> float:
        return self.best.throughput if self.best else 0.0


def default_slot_grid(n: int) -> List[int]:
    grid = sorted({max(1, n // 8), max(1, n // 4), max(1, n // 2), n})
    return grid


def split_pool_by_rate(pool: Sequence[Adapter],
                       n_replicas: int) -> List[List[Adapter]]:
    """LPT greedy partition: heaviest-rate adapter to the lightest bin.

    The cluster analogue of the paper's 'equal distribution' — balances
    offered request rate across replicas before each replica's own
    (concurrent, parallel) sweep."""
    if n_replicas < 1:
        raise ValueError("need at least one replica")
    bins: List[List[Adapter]] = [[] for _ in range(n_replicas)]
    loads = [0.0] * n_replicas
    for a in sorted(pool, key=lambda x: -x.rate):
        i = min(range(n_replicas), key=lambda j: (loads[j], j))
        bins[i].append(a)
        loads[i] += a.rate
    return bins


@dataclasses.dataclass
class ReplicaPlacement:
    replica: int
    adapters: List[Adapter]
    placement: PlacementResult


@dataclasses.dataclass
class ClusterPlacementResult:
    """Per-replica (concurrent, parallel) predictions for a joint pool."""
    replicas: List[ReplicaPlacement]

    @property
    def n_adapters(self) -> List[int]:
        return [r.placement.n_adapters for r in self.replicas]

    @property
    def slots(self) -> List[int]:
        return [r.placement.slots for r in self.replicas]

    @property
    def total_throughput(self) -> float:
        return sum(r.placement.throughput for r in self.replicas)


def find_cluster_placement(
        est: FittedEstimators, pool: Sequence[Adapter], dataset: str,
        n_replicas: int, horizon: float = 300.0, seed: int = 0,
        n_grid: Optional[Sequence[int]] = None,
        slot_grid=default_slot_grid, dt_mode: str = "mean",
        early_stop: int = 2) -> ClusterPlacementResult:
    """Predict each replica's (N*, G*) from the joint workload: rate-
    balance the pool across replicas, then run the paper's single-node
    DT sweep per replica partition."""
    parts = split_pool_by_rate(pool, n_replicas)
    replicas: List[ReplicaPlacement] = []
    for i, part in enumerate(parts):
        res = find_optimal_placement(
            est, part, dataset, horizon=horizon, seed=seed + i,
            n_grid=n_grid, slot_grid=slot_grid, dt_mode=dt_mode,
            early_stop=early_stop)
        replicas.append(ReplicaPlacement(replica=i, adapters=part,
                                         placement=res))
    return ClusterPlacementResult(replicas=replicas)


def find_optimal_placement(
        est: FittedEstimators, pool: Sequence[Adapter], dataset: str,
        horizon: float = 300.0, seed: int = 0,
        n_grid: Optional[Sequence[int]] = None,
        slot_grid=default_slot_grid, dt_mode: str = "mean",
        early_stop: int = 2) -> PlacementResult:
    """Sweep served-adapter counts (and slots) through the DT."""
    dt = DigitalTwin(est, mode=dt_mode)
    if n_grid is None:
        n_grid = sorted({max(1, len(pool) // k) for k in
                         (16, 8, 4, 3, 2)} | {len(pool)})
        n_grid = [n for n in n_grid if n >= 1]
    curve: List[PlacementPoint] = []
    best: Optional[PlacementPoint] = None
    drops = 0
    for n in sorted(n_grid):
        adapters = list(pool[:n])
        spec = WorkloadSpec(adapters=adapters, dataset=dataset,
                            horizon=horizon, seed=seed)
        best_at_n: Optional[PlacementPoint] = None
        for g in slot_grid(n):
            res = dt.simulate(spec, slots=g)
            pt = PlacementPoint(
                n_adapters=n, slots=g,
                throughput=res.metrics.throughput,
                ideal=res.metrics.ideal_throughput,
                starved=res.metrics.starved)
            curve.append(pt)
            if not pt.starved and (best_at_n is None
                                   or pt.throughput > best_at_n.throughput):
                best_at_n = pt
        if best_at_n is None:
            drops += 1
            if best is not None and drops >= early_stop:
                break
            continue
        if best is None or best_at_n.throughput >= best.throughput:
            best = best_at_n
            drops = 0
        else:
            drops += 1
            if drops >= early_stop:
                break
    return PlacementResult(best=best, curve=curve)

"""Optimal-placement search (paper §IV / §VII-B), single-node and cluster.

Given a workload condition (a pool of adapters with rates/ranks and request
length characteristics), find the placement that maximizes throughput
without starvation: the number of served adapters N* and the adapter-slot
count G* at which throughput peaks while staying >= 90% of the offered
(ideal) rate.  The search sweeps the Digital Twin — the whole point of the
paper is that this sweep is cheap enough to label tens of thousands of
scenarios for the ML model.

Cluster level, two flavours:

* ``find_cluster_placement`` — per-replica *reuse* of the paper's
  single-node sweep: rate-balance the pool, sweep each partition alone.
* ``find_cluster_placement_joint`` — sweep the ``ClusterDigitalTwin``
  on the *joint* workload (the same router the online fleet uses routes
  every candidate configuration), yielding per-replica (N*, G*) labels
  that account for cross-replica routing effects.  These labels feed
  ``train_cluster_placement_model`` — the cluster-level analogue of the
  paper's RF, one ``recommend()`` call per fleet-sizing decision.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..serving.cluster import ClusterRouter
from ..serving.metrics import smape_vec
from ..serving.policy import sched_policy_index
from ..serving.request import Adapter
from .cluster_twin import ClusterDigitalTwin
from .digital_twin import DigitalTwin
from .estimators import FittedEstimators
from .fast_twin import FastTwin
from .forest import RandomForest
from .workload import WorkloadSpec, expected_prefix_hit_rate


@dataclasses.dataclass
class PlacementPoint:
    n_adapters: int
    slots: int
    throughput: float
    ideal: float
    starved: bool


@dataclasses.dataclass
class PlacementResult:
    best: Optional[PlacementPoint]
    curve: List[PlacementPoint]

    @property
    def n_adapters(self) -> int:
        return self.best.n_adapters if self.best else 0

    @property
    def slots(self) -> int:
        return self.best.slots if self.best else 0

    @property
    def throughput(self) -> float:
        return self.best.throughput if self.best else 0.0


def default_slot_grid(n: int) -> List[int]:
    grid = sorted({max(1, n // 8), max(1, n // 4), max(1, n // 2), n})
    return grid


def split_pool_by_rate(pool: Sequence[Adapter],
                       n_replicas: int) -> List[List[Adapter]]:
    """LPT greedy partition: heaviest-rate adapter to the lightest bin.

    The cluster analogue of the paper's 'equal distribution' — balances
    offered request rate across replicas before each replica's own
    (concurrent, parallel) sweep."""
    if n_replicas < 1:
        raise ValueError("need at least one replica")
    bins: List[List[Adapter]] = [[] for _ in range(n_replicas)]
    loads = [0.0] * n_replicas
    for a in sorted(pool, key=lambda x: -x.rate):
        i = min(range(n_replicas), key=lambda j: (loads[j], j))
        bins[i].append(a)
        loads[i] += a.rate
    return bins


@dataclasses.dataclass
class ReplicaPlacement:
    replica: int
    adapters: List[Adapter]
    placement: PlacementResult


@dataclasses.dataclass
class ClusterPlacementResult:
    """Per-replica (concurrent, parallel) predictions for a joint pool."""
    replicas: List[ReplicaPlacement]

    @property
    def n_adapters(self) -> List[int]:
        return [r.placement.n_adapters for r in self.replicas]

    @property
    def slots(self) -> List[int]:
        return [r.placement.slots for r in self.replicas]

    @property
    def total_throughput(self) -> float:
        return sum(r.placement.throughput for r in self.replicas)


def find_cluster_placement(
        est: FittedEstimators, pool: Sequence[Adapter], dataset: str,
        n_replicas: int, horizon: float = 300.0, seed: int = 0,
        n_grid: Optional[Sequence[int]] = None,
        slot_grid=default_slot_grid, dt_mode: str = "mean",
        early_stop: int = 2) -> ClusterPlacementResult:
    """Predict each replica's (N*, G*) from the joint workload: rate-
    balance the pool across replicas, then run the paper's single-node
    DT sweep per replica partition."""
    parts = split_pool_by_rate(pool, n_replicas)
    replicas: List[ReplicaPlacement] = []
    for i, part in enumerate(parts):
        res = find_optimal_placement(
            est, part, dataset, horizon=horizon, seed=seed + i,
            n_grid=n_grid, slot_grid=slot_grid, dt_mode=dt_mode,
            early_stop=early_stop)
        replicas.append(ReplicaPlacement(replica=i, adapters=part,
                                         placement=res))
    return ClusterPlacementResult(replicas=replicas)


# --------------------------------------------------------------------------- #
# joint cluster sweep + the cluster-level placement model
# --------------------------------------------------------------------------- #

CLUSTER_FEATURE_NAMES = (
    "rate_max", "rate_min", "rate_mean", "rate_std",
    "rank_max", "rank_min", "rank_mean", "rank_std",
    "in_mean", "in_std", "out_mean", "out_std",
    "n_replicas", "pool_size", "total_rate", "sched_policy",
    "prefix_hit_rate",
)
CLUSTER_TARGET_NAMES = ("total_throughput", "served_adapters",
                        "slots_per_replica")


def encode_cluster_features(rates: Sequence[float], ranks: Sequence[int],
                            stats: Dict[str, float], n_replicas: int,
                            sched_policy: str = "fcfs",
                            prefix_hit_rate: float = 0.0) -> np.ndarray:
    # ``prefix_hit_rate``: expected shared-prefix cache hit rate of the
    # workload; 0.0 = prefix-free (the pre-cache encoding)
    r = np.asarray(rates, float)
    k = np.asarray(ranks, float)
    return np.array([
        r.max(), r.min(), r.mean(), r.std(),
        k.max(), k.min(), k.mean(), k.std(),
        stats["in_mean"], stats["in_std"],
        stats["out_mean"], stats["out_std"],
        float(n_replicas), float(len(r)), float(r.sum()),
        float(sched_policy_index(sched_policy)),
        float(prefix_hit_rate),
    ])


def find_cluster_placement_joint(
        est: FittedEstimators, pool: Sequence[Adapter], dataset: str,
        n_replicas: int, horizon: float = 150.0, seed: int = 0,
        n_grid: Optional[Sequence[int]] = None,
        slot_grid=default_slot_grid, policy: str = "affinity",
        early_stop: int = 2, fast: bool = True,
        sched_policy: str = "fcfs",
        prefix_share: float = 0.0,
        prefix_len: int = 0) -> PlacementResult:
    """Sweep (served adapters N, per-replica slots G) through the
    ``ClusterDigitalTwin`` on the *joint* workload — candidate configs
    are scored with the same router the online fleet uses, so the labels
    include routing/affinity effects the per-replica reuse misses.
    ``fast`` selects the struct-of-arrays replica engines (same labels);
    ``sched_policy`` is every replica engine's admission policy.
    ``prefix_share``/``prefix_len`` make the workload's shared-prefix
    structure a sweep axis: replica engines enable the shared-prefix KV
    cache whenever the workload carries prefixes, so the labels include
    the cache's admission-capacity effect."""
    twin = ClusterDigitalTwin(est, mode="mean", fast=fast)
    use_prefix = prefix_share > 0 and prefix_len > 0
    if n_grid is None:
        n_grid = sorted({max(1, len(pool) // k) for k in
                         (8, 4, 2)} | {len(pool)})
    curve: List[PlacementPoint] = []
    best: Optional[PlacementPoint] = None
    drops = 0
    for n in sorted(n_grid):
        served = list(pool[:n])
        mean_rank = sum(a.rank for a in served) / len(served)
        spec = WorkloadSpec(adapters=served, dataset=dataset,
                            horizon=horizon, seed=seed,
                            prefix_share=prefix_share,
                            prefix_len=prefix_len)
        best_at_n: Optional[PlacementPoint] = None
        for g in slot_grid(max(n // n_replicas, 1)):
            router = ClusterRouter(
                twin.specs_from_slots([g] * n_replicas,
                                      mean_rank=mean_rank,
                                      sched_policy=sched_policy,
                                      prefix_cache=use_prefix),
                policy=policy)
            m = twin.simulate(spec, router).metrics
            pt = PlacementPoint(
                n_adapters=n, slots=g, throughput=m.throughput,
                ideal=m.ideal_throughput, starved=m.starved)
            curve.append(pt)
            if not pt.starved and (best_at_n is None
                                   or pt.throughput > best_at_n.throughput):
                best_at_n = pt
        if best_at_n is None:
            drops += 1
            if best is not None and drops >= early_stop:
                break
            continue
        if best is None or best_at_n.throughput >= best.throughput:
            best = best_at_n
            drops = 0
        else:
            drops += 1
            if drops >= early_stop:
                break
    return PlacementResult(best=best, curve=curve)


def label_cluster_scenarios(
        est: FittedEstimators, scenarios: Sequence, max_adapters: int,
        replica_counts: Sequence[int] = (1, 2, 4),
        horizon: float = 100.0, seed: int = 0, verbose: bool = False,
        runner=None) -> Tuple[np.ndarray, np.ndarray]:
    """Label (scenario x fleet size) grid points with the joint sweep.

    ``scenarios`` are ``repro.core.dataset.Scenario`` objects; each row's
    features append (n_replicas, pool size, total rate) to the paper's
    workload encoding, and its targets are the joint-sweep optimum
    (cluster throughput, served adapters N*, per-replica slots G*).

    ``runner`` (a ``repro.core.sweep.SweepRunner``) fans the grid points
    across a process pool; each point keeps its own derived seed, so
    labels are identical to the serial path for any pool size."""
    grid = [(sc, n_rep) for sc in scenarios for n_rep in replica_counts]
    xs, ys = [], []
    if runner is not None:
        from .sweep import SweepTask
        tasks = [SweepTask(pool=tuple(sc.pool(max_adapters)),
                           dataset=sc.dataset, horizon=horizon,
                           seed=seed + i, n_replicas=n_rep,
                           sched_policy=sc.sched_policy,
                           prefix_share=getattr(sc, "prefix_share", 0.0),
                           prefix_len=getattr(sc, "prefix_len", 0))
                 for i, (sc, n_rep) in enumerate(grid)]
        results = runner.map(tasks)
    else:
        results = [find_cluster_placement_joint(
            est, sc.pool(max_adapters), sc.dataset, n_replicas=n_rep,
            horizon=horizon, seed=seed + i, sched_policy=sc.sched_policy,
            prefix_share=getattr(sc, "prefix_share", 0.0),
            prefix_len=getattr(sc, "prefix_len", 0))
            for i, (sc, n_rep) in enumerate(grid)]
    for i, ((sc, n_rep), res) in enumerate(zip(grid, results)):
        pool = sc.pool(max_adapters)
        spec = WorkloadSpec(adapters=pool, dataset=sc.dataset,
                            prefix_share=getattr(sc, "prefix_share", 0.0),
                            prefix_len=getattr(sc, "prefix_len", 0))
        xs.append(encode_cluster_features(
            [a.rate for a in pool], [a.rank for a in pool],
            spec.length_stats(), n_rep, sched_policy=sc.sched_policy,
            prefix_hit_rate=expected_prefix_hit_rate(spec)))
        ys.append([res.throughput, res.n_adapters, res.slots])
        if verbose and (i + 1) % 10 == 0:
            print(f"  labelled {i + 1} cluster points")
    return np.asarray(xs), np.asarray(ys)


@dataclasses.dataclass
class ClusterPlacementModel:
    """RF trained on ClusterDigitalTwin joint sweeps: one sub-millisecond
    ``recommend()`` per fleet-sizing decision (production phase)."""
    model: RandomForest
    feature_names: Tuple[str, ...] = CLUSTER_FEATURE_NAMES
    target_names: Tuple[str, ...] = CLUSTER_TARGET_NAMES
    fit_report: Dict[str, float] = dataclasses.field(default_factory=dict)

    def recommend(self, rates: Sequence[float], ranks: Sequence[int],
                  length_stats: Dict[str, float], n_replicas: int,
                  sched_policy: str = "fcfs",
                  prefix_hit_rate: float = 0.0) -> Dict[str, float]:
        x = encode_cluster_features(rates, ranks, length_stats,
                                    n_replicas,
                                    sched_policy=sched_policy,
                                    prefix_hit_rate=prefix_hit_rate)[None]
        y = np.asarray(self.model.predict(x))[0]
        return {
            "total_throughput": float(y[0]),
            "served_adapters": max(int(round(y[1])), 1),
            "slots_per_replica": max(int(round(y[2])), 1),
        }

    def importances(self) -> Dict[str, float]:
        imp = self.model.feature_importances()
        return dict(zip(self.feature_names, imp.tolist()))

    def as_node_pipeline(self, sched_policy: str = "fcfs"
                         ) -> "ClusterModelNodeView":
        """Per-node inference view: the same trained forest queried at
        ``n_replicas=1`` behind the ``PlacementPipeline.recommend``
        signature, so plan-level consumers (``PlacementRouter.plan``,
        ``repro.serving.predictive.plan_initial_placement``) can reuse
        the cluster model online for "how much fits on ONE replica"
        questions.  ``sched_policy`` bakes the fleet's scheduling policy
        into the view — callers without the parameter (e.g.
        ``PlacementRouter.plan``) still query the right feature."""
        return ClusterModelNodeView(self, sched_policy=sched_policy)


@dataclasses.dataclass
class ClusterModelNodeView:
    """``PlacementPipeline``-shaped facade over a ``ClusterPlacementModel``
    answering per-node capacity queries (``n_replicas=1``)."""
    model: ClusterPlacementModel
    sched_policy: str = "fcfs"

    def recommend(self, rates: Sequence[float], ranks: Sequence[int],
                  length_stats: Dict[str, float],
                  sched_policy: Optional[str] = None,
                  prefix_hit_rate: float = 0.0) -> Dict[str, float]:
        rec = self.model.recommend(
            rates, ranks, length_stats, n_replicas=1,
            sched_policy=sched_policy or self.sched_policy,
            prefix_hit_rate=prefix_hit_rate)
        return {
            "throughput": rec["total_throughput"],
            "served_adapters": rec["served_adapters"],
            "adapter_slots": rec["slots_per_replica"],
        }


def train_cluster_placement_model(
        est: FittedEstimators, scenarios: Sequence, max_adapters: int,
        replica_counts: Sequence[int] = (1, 2, 4),
        horizon: float = 100.0, seed: int = 0,
        n_trees: int = 10, max_depth: int = 5,
        holdout: float = 0.2, verbose: bool = False,
        runner=None) -> ClusterPlacementModel:
    """Creation phase for the fleet: label with the joint twin sweep
    (optionally fanned across a ``SweepRunner`` pool — same labels),
    fit the paper-sized RF, report holdout SMAPE per target."""
    xs, ys = label_cluster_scenarios(
        est, scenarios, max_adapters, replica_counts=replica_counts,
        horizon=horizon, seed=seed, verbose=verbose, runner=runner)
    model = RandomForest(n_trees=n_trees, max_depth=max_depth, seed=seed)
    n_train = max(int((1.0 - holdout) * len(xs)), 1)
    model.fit(xs[:n_train], ys[:n_train])
    report: Dict[str, float] = {}
    if len(xs) > n_train:
        pred = np.asarray(model.predict(xs[n_train:]))
        for j, name in enumerate(CLUSTER_TARGET_NAMES):
            report[f"smape_{name}"] = smape_vec(pred[:, j],
                                                ys[n_train:, j])
    return ClusterPlacementModel(model=model, fit_report=report)


def find_optimal_placement(
        est: FittedEstimators, pool: Sequence[Adapter], dataset: str,
        horizon: float = 300.0, seed: int = 0,
        n_grid: Optional[Sequence[int]] = None,
        slot_grid=default_slot_grid, dt_mode: str = "mean",
        early_stop: int = 2, fast: bool = True,
        sched_policy: str = "fcfs",
        measured_step_times=None,
        prefix_share: float = 0.0,
        prefix_len: int = 0) -> PlacementResult:
    """Sweep served-adapter counts (and slots) through the DT.

    ``fast`` (default) runs each point on the struct-of-arrays
    ``FastTwin`` — identical labels to the legacy object-mode twin
    (``fast=False``, kept as the equivalence oracle), ~10x cheaper.
    ``sched_policy`` makes the scheduling policy a sweep axis: the same
    workload can have a different (N*, G*) under e.g. ``adapter-fair``.
    ``measured_step_times`` (a ``MeasuredStepTimes``) swaps the analytic
    Lat_model/Lat_adapters terms for kernel-measured fits, so the chosen
    (N*, G*) reflects real kernel costs; ``None`` is bitwise the
    pre-hook sweep.  ``prefix_share``/``prefix_len`` give the synthetic
    workload a shared-prefix structure and enable the twin's shared-prefix
    KV cache, so (N*, G*) reflects the cache's admission-capacity gain."""
    use_prefix = prefix_share > 0 and prefix_len > 0
    dt = (FastTwin if fast else DigitalTwin)(
        est, mode=dt_mode, sched_policy=sched_policy,
        measured_step_times=measured_step_times,
        prefix_cache=use_prefix)
    if n_grid is None:
        n_grid = sorted({max(1, len(pool) // k) for k in
                         (16, 8, 4, 3, 2)} | {len(pool)})
        n_grid = [n for n in n_grid if n >= 1]
    curve: List[PlacementPoint] = []
    best: Optional[PlacementPoint] = None
    drops = 0
    for n in sorted(n_grid):
        adapters = list(pool[:n])
        spec = WorkloadSpec(adapters=adapters, dataset=dataset,
                            horizon=horizon, seed=seed,
                            prefix_share=prefix_share,
                            prefix_len=prefix_len)
        best_at_n: Optional[PlacementPoint] = None
        for g in slot_grid(n):
            res = dt.simulate(spec, slots=g)
            pt = PlacementPoint(
                n_adapters=n, slots=g,
                throughput=res.metrics.throughput,
                ideal=res.metrics.ideal_throughput,
                starved=res.metrics.starved)
            curve.append(pt)
            if not pt.starved and (best_at_n is None
                                   or pt.throughput > best_at_n.throughput):
                best_at_n = pt
        if best_at_n is None:
            drops += 1
            if best is not None and drops >= early_stop:
                break
            continue
        if best is None or best_at_n.throughput >= best.throughput:
            best = best_at_n
            drops = 0
        else:
            drops += 1
            if drops >= early_stop:
                break
    return PlacementResult(best=best, curve=curve)

"""The Digital Twin: an offline simulator of the online adapter-serving
system (paper §VI).

Architecture mirrors Fig. 8: the continuous-batching loop with scheduler,
adapter cache and model components — implemented by *reusing the engine's
scheduling machinery verbatim* (that is the replication) while every step
time and the KV capacity come from the fitted estimators of Eq. (1).

Modes:
  * ``full`` — exact per-request prompt/output lengths are known.
  * ``mean`` — only aggregate length stats (mean/std) are known; the DT
    resamples a statistically equivalent request stream (production mode).

Resource footprint matches the paper's claims trivially: single process,
no accelerator, O(requests) memory.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

from ..serving.engine import EngineConfig, ServingEngine
from ..serving.executor import StepTiming
from ..serving.metrics import ServingMetrics
from ..serving.request import Request
from .estimators import FittedEstimators
from .workload import WorkloadSpec, resample_requests


class EstimatorExecutor:
    """Executor whose step times come from Eq. (1) fits."""

    def __init__(self, est: FittedEstimators, slots: int, n_adapters: int,
                 ranks: Dict[int, int]):
        self.est = est
        self.slots = slots
        self.n_adapters = n_adapters
        self.ranks = ranks

    def step(self, plan, n_waiting: int) -> StepTiming:
        return self.est.lat_step(plan, n_waiting, self.slots,
                                 self.n_adapters, self.ranks)


@dataclasses.dataclass
class DTResult:
    metrics: ServingMetrics
    sim_wall_time: float
    mode: str


class DigitalTwin:
    def __init__(self, est: FittedEstimators, mode: str = "full",
                 max_running: int = 256, sched_policy: str = "fcfs",
                 measured_step_times=None, prefix_cache: bool = False):
        assert mode in ("full", "mean")
        # opt-in hook: a MeasuredStepTimes surface (fitted from real
        # kernel launches by benchmarks/kernels_bench.py) replaces the
        # analytic Lat_model x Lat_adapters terms.  None is provably a
        # no-op (tests/test_measured_step_times.py pins bitwise equality).
        if measured_step_times is not None:
            est = est.with_measured(measured_step_times)
        self.est = est
        self.mode = mode
        self.max_running = max_running
        self.sched_policy = sched_policy
        self.prefix_cache = prefix_cache

    def simulate(self, spec: WorkloadSpec, slots: int,
                 requests: Optional[List[Request]] = None,
                 horizon: Optional[float] = None,
                 dynamic_slots: bool = False) -> DTResult:
        t0 = time.perf_counter()
        ranks = {a.uid: a.rank for a in spec.adapters}
        mean_rank = (sum(ranks.values()) / len(ranks)) if ranks else 8.0
        n = len(spec.adapters)
        if self.mode == "mean" or requests is None:
            requests = resample_requests(spec, spec.length_stats())
        else:
            # full mode gets the exact stream (deep copy to keep caller's);
            # progress AND reliability lifecycle restart clean — replaying
            # a chaos run's stream must not inherit its retry state
            requests = [dataclasses.replace(
                r, generated=0, admitted_at=None, first_token_at=None,
                finished_at=None, token_times=[], n_preemptions=0,
                n_retries=0, n_timeouts=0, failed_at=None, retry_at=None,
                disconnected_at=None)
                for r in requests]
        if dynamic_slots:
            # S-LoRA mode: the whole pool is available; each loaded adapter
            # is charged its Mem_max-estimated KV-token footprint.
            per_rank = max(-float(self.est.memmax[1]), 0.0)
            cfg = EngineConfig(
                kv_capacity_tokens=self.est.kv_capacity(0, mean_rank),
                adapter_slots=0, max_running=self.max_running,
                sched_policy=self.sched_policy, dynamic_slots=True,
                adapter_kv_tokens={u: max(int(per_rank * r), 1)
                                   for u, r in ranks.items()},
                prefix_cache=self.prefix_cache)
            slots_for_est = n
        else:
            cfg = EngineConfig(
                kv_capacity_tokens=self.est.kv_capacity(slots, mean_rank),
                adapter_slots=slots, max_running=self.max_running,
                sched_policy=self.sched_policy,
                prefix_cache=self.prefix_cache)
            slots_for_est = slots
        engine = ServingEngine(cfg, EstimatorExecutor(
            self.est, slots_for_est, n, ranks))
        metrics = engine.run(requests, horizon=horizon or spec.horizon)
        return DTResult(metrics=metrics,
                        sim_wall_time=time.perf_counter() - t0,
                        mode=self.mode)

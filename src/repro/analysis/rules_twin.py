"""Twin-contract sync rules.

``repro.serving.metrics.TWIN_EXACT_FIELDS`` is the canonical statement
of the paper's twin-fidelity contract: the fields on which the
object-mode engine and the SoA fast twin must agree bitwise.  These
rules keep the three places that consume the contract from drifting:

* the ``ServingMetrics`` dataclass itself (every field accounted for),
* ``ClusterMetrics.aggregate`` (every exact field summed/merged
  across replicas),
* the gateway ``/v1/metrics`` body (every exact field emitted to
  operators).
"""
from __future__ import annotations

from typing import List, Optional, Tuple

from .core import (Finding, Repo, call_kwargs, dataclass_fields,
                   find_class, find_def, rule, str_dict_keys,
                   tuple_assign)

METRICS_PATH = "src/repro/serving/metrics.py"
CLUSTER_PATH = "src/repro/serving/cluster.py"
GATEWAY_PATH = "src/repro/serving/gateway.py"

CONTRACT_TUPLES = ("TWIN_EXACT_FIELDS", "TWIN_TOLERANT_FIELDS",
                   "TWIN_SAMPLE_FIELDS")


def _exact_fields(repo: Repo) -> Optional[Tuple[List[str], int]]:
    return tuple_assign(repo.tree(METRICS_PATH), "TWIN_EXACT_FIELDS")


@rule("twin-metrics-fields",
      "every ServingMetrics field is classified in TWIN_EXACT_FIELDS / "
      "TWIN_TOLERANT_FIELDS / TWIN_SAMPLE_FIELDS")
def check_metrics_fields(repo: Repo) -> List[Finding]:
    findings: List[Finding] = []
    tree = repo.tree(METRICS_PATH)
    cls = find_class(tree, "ServingMetrics")
    if cls is None:
        return [Finding("twin-metrics-fields", METRICS_PATH, 1,
                        "ServingMetrics dataclass not found",
                        key="missing-class")]
    tuples = {}
    for name in CONTRACT_TUPLES:
        got = tuple_assign(tree, name)
        if got is None:
            findings.append(Finding(
                "twin-metrics-fields", METRICS_PATH, 1,
                f"contract tuple {name} missing from metrics.py",
                key=f"missing-{name}"))
        else:
            tuples[name] = got
    classified = {f for elems, _ in tuples.values() for f in elems}
    fields = dataclass_fields(cls)
    field_names = {n for n, _ in fields}
    for fname, lineno in fields:
        if fname not in classified:
            findings.append(Finding(
                "twin-metrics-fields", METRICS_PATH, lineno,
                f"ServingMetrics.{fname} is not classified in any twin "
                "contract tuple — add it to TWIN_EXACT_FIELDS (or the "
                "tolerant/sample exclusions) so twin tests compare it",
                key=f"unclassified-{fname}"))
    seen = set()
    for tname, (elems, lineno) in tuples.items():
        for fname in elems:
            if fname not in field_names:
                findings.append(Finding(
                    "twin-metrics-fields", METRICS_PATH, lineno,
                    f"{tname} lists {fname!r} which is not a "
                    "ServingMetrics field (stale contract entry)",
                    key=f"stale-{fname}"))
            if fname in seen:
                findings.append(Finding(
                    "twin-metrics-fields", METRICS_PATH, lineno,
                    f"{fname!r} appears in more than one contract tuple",
                    key=f"dup-{fname}"))
            seen.add(fname)
    return findings


@rule("twin-cluster-aggregate",
      "every TWIN_EXACT_FIELDS entry is a ClusterMetrics field and is "
      "merged in ClusterMetrics.aggregate")
def check_cluster_aggregate(repo: Repo) -> List[Finding]:
    exact = _exact_fields(repo)
    if exact is None:       # twin-metrics-fields reports the root cause
        return []
    findings: List[Finding] = []
    tree = repo.tree(CLUSTER_PATH)
    cls = find_class(tree, "ClusterMetrics")
    if cls is None:
        return [Finding("twin-cluster-aggregate", CLUSTER_PATH, 1,
                        "ClusterMetrics dataclass not found",
                        key="missing-class")]
    cluster_fields = {n for n, _ in dataclass_fields(cls)}
    agg = find_def(cls.body, "aggregate")
    if agg is None:
        return [Finding("twin-cluster-aggregate", CLUSTER_PATH,
                        cls.lineno, "ClusterMetrics.aggregate not found",
                        key="missing-aggregate")]
    kwargs = call_kwargs(agg, ("cls", "ClusterMetrics"))
    for fname in exact[0]:
        if fname not in cluster_fields:
            findings.append(Finding(
                "twin-cluster-aggregate", CLUSTER_PATH, cls.lineno,
                f"TWIN_EXACT_FIELDS entry {fname!r} has no "
                "ClusterMetrics field — cluster runs would drop it",
                key=f"no-field-{fname}"))
        elif fname not in kwargs:
            findings.append(Finding(
                "twin-cluster-aggregate", CLUSTER_PATH, agg.lineno,
                f"ClusterMetrics.aggregate never passes {fname!r} — the "
                "cluster aggregate would silently use the default",
                key=f"not-aggregated-{fname}"))
    return findings


@rule("twin-gateway-metrics",
      "every TWIN_EXACT_FIELDS entry is a literal key in the gateway "
      "/v1/metrics body (AsyncGateway.snapshot)")
def check_gateway_metrics(repo: Repo) -> List[Finding]:
    exact = _exact_fields(repo)
    if exact is None:
        return []
    tree = repo.tree(GATEWAY_PATH)
    cls = find_class(tree, "AsyncGateway")
    if cls is None:
        return [Finding("twin-gateway-metrics", GATEWAY_PATH, 1,
                        "AsyncGateway not found", key="missing-class")]
    snap = find_def(cls.body, "snapshot")
    if snap is None:
        return [Finding("twin-gateway-metrics", GATEWAY_PATH, cls.lineno,
                        "AsyncGateway.snapshot not found",
                        key="missing-snapshot")]
    keys = str_dict_keys(snap)
    findings: List[Finding] = []
    for fname in exact[0]:
        if fname not in keys:
            findings.append(Finding(
                "twin-gateway-metrics", GATEWAY_PATH, snap.lineno,
                f"/v1/metrics body never emits {fname!r} — operators "
                "cannot see a field the twin contract validates",
                key=f"not-emitted-{fname}"))
    return findings

"""Mirror-coverage rules: the fast twin and the kernel oracles must
keep pace with the surfaces they mirror.

``FastEngine`` is a struct-of-arrays re-implementation of
``ServingEngine``; a public method added to one but not the other means
twin tests quietly stop covering that surface.  Likewise every Pallas
kernel entry point dispatches to a pure-jnp ``ref.py`` oracle — the
thing property tests compare against — so an op without a wired oracle
is an op nothing can validate.
"""
from __future__ import annotations

import ast
from typing import List

from .core import Finding, Repo, dotted_name, find_class, rule

ENGINE_PATH = "src/repro/serving/engine.py"
FAST_PATH = "src/repro/core/fast_twin.py"
OPS_PATH = "src/repro/kernels/ops.py"
REF_PATH = "src/repro/kernels/ref.py"


def _public_names(cls: ast.ClassDef) -> dict:
    out = {}
    for node in cls.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and not node.name.startswith("_"):
            out.setdefault(node.name, node.lineno)
    return out


@rule("mirror-engine-surface",
      "every public ServingEngine method/property has a FastEngine "
      "counterpart")
def check_engine_surface(repo: Repo) -> List[Finding]:
    eng = find_class(repo.tree(ENGINE_PATH), "ServingEngine")
    fast = find_class(repo.tree(FAST_PATH), "FastEngine")
    if eng is None or fast is None:
        return [Finding("mirror-engine-surface", FAST_PATH, 1,
                        "ServingEngine or FastEngine class not found",
                        key="missing-class")]
    eng_names = _public_names(eng)
    fast_names = _public_names(fast)
    findings: List[Finding] = []
    for name, lineno in sorted(eng_names.items()):
        if name not in fast_names:
            findings.append(Finding(
                "mirror-engine-surface", FAST_PATH, fast.lineno,
                f"ServingEngine.{name} (engine.py:{lineno}) has no "
                "FastEngine counterpart — twin tests cannot cover it",
                key=f"missing-{name}"))
    return findings


@rule("mirror-kernel-oracle",
      "every kernel entry point dispatches to an existing ref.py "
      "oracle, and KERNEL_MODES keeps the 'ref' mode")
def check_kernel_oracle(repo: Repo) -> List[Finding]:
    ops = repo.tree(OPS_PATH)
    ref = repo.tree(REF_PATH)
    ref_defs = {n.name for n in ref.body
                if isinstance(n, ast.FunctionDef)}
    findings: List[Finding] = []

    modes = None
    for node in ops.body:
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name) \
                and node.targets[0].id == "KERNEL_MODES" \
                and isinstance(node.value, (ast.Tuple, ast.List)):
            modes = [e.value for e in node.value.elts
                     if isinstance(e, ast.Constant)]
    if modes is None or "ref" not in modes:
        findings.append(Finding(
            "mirror-kernel-oracle", OPS_PATH, 1,
            "KERNEL_MODES must exist and keep the 'ref' oracle mode",
            key="kernel-modes-ref"))

    for node in ops.body:
        if not isinstance(node, ast.FunctionDef) \
                or node.name.startswith("_"):
            continue
        ref_calls = set()
        for sub in ast.walk(node):
            if isinstance(sub, ast.Attribute):
                name = dotted_name(sub)
                if name.startswith("ref."):
                    ref_calls.add(name.split(".", 1)[1])
        if not ref_calls:
            findings.append(Finding(
                "mirror-kernel-oracle", OPS_PATH, node.lineno,
                f"kernel entry point {node.name}() never dispatches to "
                "a ref.py oracle — nothing can validate it",
                key=f"no-oracle-{node.name}"))
        for called in sorted(ref_calls):
            if called not in ref_defs:
                findings.append(Finding(
                    "mirror-kernel-oracle", OPS_PATH, node.lineno,
                    f"{node.name}() dispatches to ref.{called} which "
                    "does not exist in ref.py",
                    key=f"dangling-{called}"))
    return findings

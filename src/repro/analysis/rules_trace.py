"""Trace round-trip rule: a ``Request`` field is either persisted by
the trace functions or declared serving progress.

PR 9 added prefix fields to ``Request`` and had to hand-thread them
through ``save_trace``/``load_trace``/``replay_trace``; forgetting any
one of the three silently drops the field on replay and the
gateway-vs-closed-loop equivalence guard stops meaning anything.  A new
field must appear in all three functions, or be listed in
``TRACE_PROGRESS_FIELDS`` in ``workload.py`` (fields that are serving
*outcomes*, deliberately reset on replay).
"""
from __future__ import annotations

import ast
from typing import List, Set

from .core import (Finding, Repo, dataclass_fields, find_class,
                   find_def, rule, tuple_assign)

REQUEST_PATH = "src/repro/serving/request.py"
WORKLOAD_PATH = "src/repro/core/workload.py"


def _str_constants(node: ast.AST) -> Set[str]:
    return {n.value for n in ast.walk(node)
            if isinstance(n, ast.Constant) and isinstance(n.value, str)}


def _request_kwargs(node: ast.AST) -> Set[str]:
    out: Set[str] = set()
    for n in ast.walk(node):
        if isinstance(n, ast.Call) and isinstance(n.func, ast.Name) \
                and n.func.id == "Request":
            out |= {kw.arg for kw in n.keywords if kw.arg}
    return out


@rule("trace-request-fields",
      "every Request field is persisted by save/load/replay_trace or "
      "listed in TRACE_PROGRESS_FIELDS")
def check_trace_fields(repo: Repo) -> List[Finding]:
    req = find_class(repo.tree(REQUEST_PATH), "Request")
    if req is None:
        return [Finding("trace-request-fields", REQUEST_PATH, 1,
                        "Request dataclass not found", key="missing-class")]
    tree = repo.tree(WORKLOAD_PATH)
    progress = tuple_assign(tree, "TRACE_PROGRESS_FIELDS")
    if progress is None:
        return [Finding("trace-request-fields", WORKLOAD_PATH, 1,
                        "TRACE_PROGRESS_FIELDS tuple missing from "
                        "workload.py", key="missing-progress-tuple")]
    fns = {}
    for name in ("save_trace", "load_trace", "replay_trace"):
        fn = find_def(tree.body, name)
        if fn is None:
            return [Finding("trace-request-fields", WORKLOAD_PATH, 1,
                            f"{name} not found in workload.py",
                            key=f"missing-{name}")]
        fns[name] = fn

    saved = _str_constants(fns["save_trace"])
    loaded = _str_constants(fns["load_trace"]) \
        | _request_kwargs(fns["load_trace"])
    replayed = _request_kwargs(fns["replay_trace"])
    field_names = {n for n, _ in dataclass_fields(req)}

    findings: List[Finding] = []
    for fname, lineno in dataclass_fields(req):
        if fname in progress[0]:
            continue
        missing = [name for name, got in
                   (("save_trace", saved), ("load_trace", loaded),
                    ("replay_trace", replayed)) if fname not in got]
        if missing:
            findings.append(Finding(
                "trace-request-fields", REQUEST_PATH, lineno,
                f"Request.{fname} is not handled by "
                f"{'/'.join(missing)} — traces would silently drop it "
                "(or list it in TRACE_PROGRESS_FIELDS)",
                key=f"dropped-{fname}"))
    for fname in progress[0]:
        if fname not in field_names:
            findings.append(Finding(
                "trace-request-fields", WORKLOAD_PATH, progress[1],
                f"TRACE_PROGRESS_FIELDS lists {fname!r} which is not a "
                "Request field (stale entry)",
                key=f"stale-{fname}"))
    return findings

"""repro-lint: AST-based repo-invariant checker (``python -m
repro.analysis``).

Complements ruff: ruff checks each file in isolation, repro-lint checks
*contracts between files* — the twin-equivalence field set, determinism
of simulation paths, engine→cluster→CLI config threading, fast-twin and
kernel-oracle mirror coverage, async safety in the gateway, and trace
round-trip completeness.  See ``docs/analysis.md`` for the rule
catalog.
"""
from .core import (DEFAULT_BASELINE, REPO_ROOT, RULES, Finding, Repo,
                   Report, load_baseline, run_repo, run_rules,
                   save_baseline)

# importing the rule modules populates the RULES registry
from . import rules_determinism  # noqa: F401
from . import rules_twin         # noqa: F401
from . import rules_config      # noqa: F401
from . import rules_mirror      # noqa: F401
from . import rules_async       # noqa: F401
from . import rules_trace       # noqa: F401

__all__ = ["DEFAULT_BASELINE", "REPO_ROOT", "RULES", "Finding", "Repo",
           "Report", "load_baseline", "run_repo", "run_rules",
           "save_baseline"]

"""CLI for repro-lint (``python -m repro.analysis`` / ``repro-lint``).

Exit status: 0 when no *new* findings (inline-suppressed and baselined
ones are reported but do not fail), 1 otherwise — same contract as
``tools/check_docs.py``, so CI wires it as one more gate.
"""
from __future__ import annotations

import argparse
from pathlib import Path
from typing import Dict, Optional

from . import core


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="repro-lint",
        description="AST-based repo-invariant checker for the twin-"
                    "equivalence, determinism and config-threading "
                    "contracts.")
    ap.add_argument("--root", type=Path, default=None,
                    help="repo root to lint (default: this checkout)")
    ap.add_argument("--baseline", type=Path, default=None,
                    help=f"baseline file (default: "
                         f"{core.DEFAULT_BASELINE})")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline (report everything as new)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="rewrite the baseline from current findings "
                         "and exit 0 (reasons become TODO stubs)")
    ap.add_argument("--rules", default="",
                    help="comma-separated rule ids to run (default: all)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    ap.add_argument("-q", "--quiet", action="store_true",
                    help="suppress per-finding output, print summary only")
    return ap


def main(argv=None, overrides: Optional[Dict[str, str]] = None) -> int:
    """``overrides`` maps repo-relative paths to replacement file text —
    the hook ``tests/test_analysis.py`` uses to drive negative fixtures
    through the real CLI path."""
    args = build_parser().parse_args(argv)
    if args.list_rules:
        for rid in sorted(core.RULES):
            print(f"{rid:26s} {core.RULES[rid].synopsis}")
        return 0

    repo = core.Repo(args.root, overrides)
    baseline_path = args.baseline or repo.root / core.DEFAULT_BASELINE
    rules = [r.strip() for r in args.rules.split(",") if r.strip()] or None
    baseline = [] if (args.no_baseline or args.write_baseline) \
        else core.load_baseline(baseline_path)
    report = core.run_rules(repo, rules, baseline)

    if args.write_baseline:
        core.save_baseline(baseline_path, report.new)
        print(f"wrote {len(report.new)} baseline entr"
              f"{'y' if len(report.new) == 1 else 'ies'} to "
              f"{baseline_path} — fill in the reason fields")
        return 0

    if not args.quiet:
        for f in report.new:
            print(f.render())
        for f in report.baselined:
            print(f"{f.render()}  [baselined]")
    # baseline delta: what the committed exemptions absorbed this run,
    # and which entries no longer match anything (candidates to delete)
    print(f"repro-lint: {len(report.new)} new, "
          f"{len(report.baselined)} baselined, "
          f"{len(report.suppressed)} suppressed inline"
          + (f", {len(report.stale_baseline)} stale baseline entr"
             f"{'y' if len(report.stale_baseline) == 1 else 'ies'}"
             if report.stale_baseline else ""))
    for key in report.stale_baseline:
        print(f"  stale baseline entry (delete it): {key}")
    return 1 if report.new else 0


if __name__ == "__main__":
    raise SystemExit(main())

"""repro-lint core: findings, rule registry, suppressions, baseline.

The linter checks *repo invariants* — contracts between files that ruff
cannot see (twin-equivalence field sets, determinism of sim paths,
config threading engine→cluster→CLI).  It mirrors the structure of the
``tools/check_docs.py`` gate: small check functions that return plain
findings, a ``main`` that prints them and exits non-zero.

Three escape hatches, in increasing ceremony:

* inline ``# repro-lint: ignore[rule-id]`` on the flagged line (or the
  line above) suppresses one finding at its source;
* the committed baseline file (``tools/repro_lint_baseline.json``)
  records known, justified exemptions by stable key — findings matching
  a baseline entry are reported but do not fail the run;
* ``--rules`` narrows a run to a comma-separated subset while
  iterating locally.

Rules operate on a :class:`Repo` view that can overlay in-memory file
contents (``overrides``), which is how ``tests/test_analysis.py`` feeds
negative fixtures through the real rule code without touching disk.
"""
from __future__ import annotations

import ast
import dataclasses
import fnmatch
import json
import re
from pathlib import Path
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

# src/repro/analysis/core.py -> repo root is three levels above src/
REPO_ROOT = Path(__file__).resolve().parents[3]
DEFAULT_BASELINE = "tools/repro_lint_baseline.json"

_SUPPRESS_RE = re.compile(r"#\s*repro-lint:\s*ignore\[([\w\-*,\s]*)\]")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation, anchored to a file:line.

    ``key`` is the line-number-independent identity used for baseline
    matching (so a baseline survives unrelated edits above the finding);
    it defaults to the message when a rule does not provide one.
    """
    rule: str
    path: str        # repo-relative, posix separators
    line: int
    message: str
    key: str = ""

    @property
    def baseline_key(self) -> str:
        return f"{self.rule}::{self.path}::{self.key or self.message}"

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


@dataclasses.dataclass
class RuleInfo:
    rule_id: str
    synopsis: str
    func: Callable[["Repo"], List[Finding]]


RULES: Dict[str, RuleInfo] = {}


def rule(rule_id: str, synopsis: str):
    """Register ``func(repo) -> List[Finding]`` under ``rule_id``."""
    def deco(func):
        if rule_id in RULES:
            raise ValueError(f"duplicate rule id {rule_id!r}")
        RULES[rule_id] = RuleInfo(rule_id, synopsis, func)
        return func
    return deco


class Repo:
    """Parsed-file view of the repository with optional text overlays."""

    def __init__(self, root: Optional[Path] = None,
                 overrides: Optional[Dict[str, str]] = None):
        self.root = Path(root) if root is not None else REPO_ROOT
        self.overrides = dict(overrides or {})
        self._text: Dict[str, str] = {}
        self._tree: Dict[str, ast.Module] = {}

    def exists(self, rel: str) -> bool:
        return rel in self.overrides or (self.root / rel).is_file()

    def text(self, rel: str) -> str:
        if rel not in self._text:
            if rel in self.overrides:
                self._text[rel] = self.overrides[rel]
            else:
                self._text[rel] = (self.root / rel).read_text()
        return self._text[rel]

    def tree(self, rel: str) -> ast.Module:
        if rel not in self._tree:
            self._tree[rel] = ast.parse(self.text(rel), filename=rel)
        return self._tree[rel]

    def files(self, *patterns: str) -> List[str]:
        """Repo-relative .py paths matching any glob pattern, merged
        with override-only virtual paths (so test fixtures can inject
        files that do not exist on disk)."""
        out = set()
        for pat in patterns:
            for p in self.root.glob(pat):
                if p.is_file():
                    out.add(p.relative_to(self.root).as_posix())
            for rel in self.overrides:
                if fnmatch.fnmatch(rel, pat):
                    out.add(rel)
        return sorted(out)


# --------------------------------------------------------------------------- #
# shared AST helpers used by the rule modules
# --------------------------------------------------------------------------- #

def dotted_name(node: ast.AST) -> str:
    """``a.b.c`` for Name/Attribute chains, '' for anything else."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def find_class(tree: ast.Module, name: str) -> Optional[ast.ClassDef]:
    for node in tree.body:
        if isinstance(node, ast.ClassDef) and node.name == name:
            return node
    return None


def find_def(body: Iterable[ast.stmt], name: str):
    for node in body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and node.name == name:
            return node
    return None


def dataclass_fields(cls: ast.ClassDef) -> List[Tuple[str, int]]:
    """(name, lineno) of annotated assignments in a dataclass body."""
    out = []
    for node in cls.body:
        if isinstance(node, ast.AnnAssign) and isinstance(node.target,
                                                          ast.Name):
            out.append((node.target.id, node.lineno))
    return out


def tuple_assign(tree: ast.Module, name: str
                 ) -> Optional[Tuple[List[str], int]]:
    """String elements of a module-level ``NAME = ("a", "b", ...)``."""
    for node in tree.body:
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name) \
                and node.targets[0].id == name \
                and isinstance(node.value, (ast.Tuple, ast.List)):
            elems = [e.value for e in node.value.elts
                     if isinstance(e, ast.Constant) and isinstance(e.value,
                                                                   str)]
            return elems, node.lineno
    return None


def str_dict_keys(node: ast.AST) -> Dict[str, int]:
    """All string dict-literal keys anywhere under ``node`` -> lineno."""
    out: Dict[str, int] = {}
    for n in ast.walk(node):
        if isinstance(n, ast.Dict):
            for k in n.keys:
                if isinstance(k, ast.Constant) and isinstance(k.value, str):
                    out.setdefault(k.value, k.lineno)
    return out


def call_kwargs(node: ast.AST, func_names: Sequence[str]) -> Dict[str, int]:
    """Keyword names of calls to any of ``func_names`` under ``node``."""
    out: Dict[str, int] = {}
    for n in ast.walk(node):
        if isinstance(n, ast.Call) and dotted_name(n.func) in func_names:
            for kw in n.keywords:
                if kw.arg:
                    out.setdefault(kw.arg, n.lineno)
    return out


def arg_names(fn) -> List[str]:
    a = fn.args
    names = [x.arg for x in a.posonlyargs + a.args + a.kwonlyargs]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return names


# --------------------------------------------------------------------------- #
# runner
# --------------------------------------------------------------------------- #

@dataclasses.dataclass
class Report:
    new: List[Finding]
    suppressed: List[Finding]
    baselined: List[Finding]
    stale_baseline: List[str]    # baseline keys matching nothing


def _suppressed_ids(repo: Repo, f: Finding) -> List[str]:
    try:
        lines = repo.text(f.path).splitlines()
    except (OSError, KeyError):
        return []
    ids: List[str] = []
    for ln in (f.line, f.line - 1):
        if 1 <= ln <= len(lines):
            m = _SUPPRESS_RE.search(lines[ln - 1])
            if m:
                ids += [s.strip() for s in m.group(1).split(",") if s.strip()]
    return ids


def load_baseline(path: Path) -> List[dict]:
    if not path.is_file():
        return []
    data = json.loads(path.read_text())
    return list(data.get("suppressions", []))


def save_baseline(path: Path, findings: Sequence[Finding]) -> None:
    entries = [{"rule": f.rule, "path": f.path,
                "key": f.key or f.message,
                "reason": "TODO: justify this exemption"}
               for f in sorted(findings, key=lambda f: f.baseline_key)]
    path.write_text(json.dumps(
        {"version": 1, "suppressions": entries}, indent=2) + "\n")


def run_rules(repo: Repo, rules: Optional[Sequence[str]] = None,
              baseline: Optional[Sequence[dict]] = None) -> Report:
    ids = list(rules) if rules else sorted(RULES)
    unknown = [r for r in ids if r not in RULES]
    if unknown:
        raise KeyError(f"unknown rule(s): {', '.join(unknown)}")
    findings: List[Finding] = []
    for rid in ids:
        findings.extend(RULES[rid].func(repo))
    findings.sort(key=lambda f: (f.path, f.line, f.rule))

    new: List[Finding] = []
    suppressed: List[Finding] = []
    baselined: List[Finding] = []
    base_keys = {f"{e['rule']}::{e['path']}::{e['key']}"
                 for e in (baseline or [])}
    hit_keys = set()
    for f in findings:
        ids_here = _suppressed_ids(repo, f)
        if f.rule in ids_here or "*" in ids_here:
            suppressed.append(f)
        elif f.baseline_key in base_keys:
            baselined.append(f)
            hit_keys.add(f.baseline_key)
        else:
            new.append(f)
    stale = sorted(base_keys - hit_keys)
    return Report(new=new, suppressed=suppressed, baselined=baselined,
                  stale_baseline=stale)


def run_repo(root: Optional[Path] = None,
             overrides: Optional[Dict[str, str]] = None,
             rules: Optional[Sequence[str]] = None,
             baseline_path: Optional[Path] = None) -> Report:
    """Lint the repo (or an overlaid view of it) against the baseline."""
    repo = Repo(root, overrides)
    if baseline_path is None:
        baseline_path = repo.root / DEFAULT_BASELINE
    return run_rules(repo, rules, load_baseline(Path(baseline_path)))

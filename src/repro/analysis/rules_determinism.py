"""Determinism rules: simulation paths must be wall-clock-free and
seed-deterministic.

The twin-fidelity claim (object engine == SoA twin, bitwise) and every
replay/snapshot test in this repo rest on runs being pure functions of
(workload seed, config).  A single ``time.time()`` or module-global
``np.random`` draw on a sim path breaks that silently — results still
*look* plausible, they just stop being reproducible.

``time.perf_counter`` is special-cased: ``src/repro/core`` twins are
allowed to time their own wall cost (the ``sim_wall_time`` metadata the
speedup tables report) because that reading never feeds back into the
virtual clock.  In ``src/repro/serving`` and ``src/repro/kernels`` it
is forbidden too — those layers run entirely on the virtual clock; the
one legitimate exception (``JaxExecutor`` measuring the *real* model it
wraps) is carried in the committed baseline.
"""
from __future__ import annotations

import ast
from typing import Dict, List

from .core import Finding, Repo, dotted_name, rule

SIM_SCOPES = ("src/repro/core/*.py", "src/repro/serving/*.py",
              "src/repro/kernels/*.py")
# layers where even perf_counter is off-limits (pure virtual clock)
VIRTUAL_CLOCK_PREFIXES = ("src/repro/serving/", "src/repro/kernels/")

WALL_CLOCK = {"time.time", "time.time_ns", "time.monotonic",
              "time.monotonic_ns", "time.localtime", "time.gmtime"}
WALL_CLOCK_SUFFIXES = ("datetime.now", "datetime.today",
                       "datetime.utcnow", "date.today")
PERF_COUNTER = {"time.perf_counter", "time.perf_counter_ns"}

# module-global numpy RNG entry points (stateful, seed-order-fragile)
NP_GLOBAL_RNG = {"seed", "random", "rand", "randn", "randint", "choice",
                 "shuffle", "permutation", "normal", "uniform",
                 "exponential", "poisson", "standard_normal"}


def _enclosing_map(tree: ast.Module) -> Dict[int, str]:
    """lineno -> dotted def/class qualname, for stable finding keys."""
    out: Dict[int, str] = {}

    def visit(node, stack):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                visit(child, stack + [child.name])
            else:
                visit(child, stack)
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            qual = ".".join(stack)
            lo = node.lineno
            hi = max((n.lineno for n in ast.walk(node)
                      if hasattr(n, "lineno")), default=lo)
            for ln in range(lo, hi + 1):
                out.setdefault(ln, qual)
    visit(tree, [])
    return out


def _imports_module(tree: ast.Module, name: str) -> bool:
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            if any(a.name == name for a in node.names):
                return True
    return False


def _imports_from(tree: ast.Module, module: str, name: str) -> bool:
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom):
            if node.module == module and any(a.name == name
                                             for a in node.names):
                return True
    return False


@rule("determinism-wallclock",
      "no wall-clock reads (time.time / datetime.now / ...) on sim paths")
def check_wallclock(repo: Repo) -> List[Finding]:
    findings: List[Finding] = []
    for rel in repo.files(*SIM_SCOPES):
        tree = repo.tree(rel)
        enclosing = _enclosing_map(tree)
        virtual = rel.startswith(VIRTUAL_CLOCK_PREFIXES)
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            name = dotted_name(node.func)
            bad = (name in WALL_CLOCK
                   or name.endswith(WALL_CLOCK_SUFFIXES)
                   or (virtual and name in PERF_COUNTER))
            if bad:
                where = enclosing.get(node.lineno, "<module>")
                findings.append(Finding(
                    rule="determinism-wallclock", path=rel,
                    line=node.lineno,
                    message=f"wall-clock call {name}() in {where} — "
                            "sim paths must run on the virtual clock",
                    key=f"{name}@{where}"))
    return findings


@rule("determinism-rng",
      "no global/unseeded RNGs (random.*, np.random.*, default_rng()) "
      "on sim paths")
def check_rng(repo: Repo) -> List[Finding]:
    findings: List[Finding] = []
    for rel in repo.files(*SIM_SCOPES):
        tree = repo.tree(rel)
        enclosing = _enclosing_map(tree)
        has_random = _imports_module(tree, "random")
        bare_default_rng = (
            _imports_from(tree, "numpy.random", "default_rng"))
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            name = dotted_name(node.func)
            where = enclosing.get(node.lineno, "<module>")
            msg = None
            if has_random and name.startswith("random."):
                msg = (f"stdlib global RNG {name}() — use a seeded "
                       "np.random.default_rng instead")
            elif name.startswith(("np.random.", "numpy.random.")):
                leaf = name.rsplit(".", 1)[1]
                if leaf in NP_GLOBAL_RNG:
                    msg = (f"module-global numpy RNG {name}() — draw "
                           "from a seeded Generator instead")
                elif leaf == "default_rng" and not (node.args
                                                    or node.keywords):
                    msg = f"unseeded {name}() — pass an explicit seed"
            elif (name == "default_rng" and bare_default_rng
                    and not (node.args or node.keywords)):
                msg = "unseeded default_rng() — pass an explicit seed"
            if msg:
                findings.append(Finding(
                    rule="determinism-rng", path=rel, line=node.lineno,
                    message=f"{msg} (in {where})",
                    key=f"{name}@{where}"))
    return findings

"""Config-threading rules: every engine knob reaches the cluster spec
and the command line.

PRs that add an ``EngineConfig`` field but forget to thread it through
``ReplicaSpec`` / ``make_replica_specs`` / a launcher ``--flag`` create
knobs that exist but cannot be set — the drift class these rules catch.
Deliberate single-engine-only knobs live in the documented
``NON_REPLICA_FIELDS`` tuple in ``cluster.py``.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Set

from .core import (Finding, Repo, arg_names, call_kwargs,
                   dataclass_fields, find_class, find_def, rule,
                   tuple_assign)

ENGINE_PATH = "src/repro/serving/engine.py"
CLUSTER_PATH = "src/repro/serving/cluster.py"
LAUNCH_GLOB = "src/repro/launch/*.py"

# engine field -> accepted CLI spellings (beyond the mechanical
# ``--field-name`` translation)
FLAG_ALIASES: Dict[str, tuple] = {
    "kv_capacity_tokens": ("--kv-tokens",),
    "adapter_slots": ("--slots",),
}


def _engine_fields(repo: Repo):
    cls = find_class(repo.tree(ENGINE_PATH), "EngineConfig")
    return dataclass_fields(cls) if cls is not None else None


def _excluded(repo: Repo) -> Set[str]:
    got = tuple_assign(repo.tree(CLUSTER_PATH), "NON_REPLICA_FIELDS")
    return set(got[0]) if got else set()


@rule("config-replica-threading",
      "every EngineConfig field (minus NON_REPLICA_FIELDS) appears in "
      "ReplicaSpec, make_replica_specs and ReplicaSpec.engine_config")
def check_replica_threading(repo: Repo) -> List[Finding]:
    fields = _engine_fields(repo)
    if fields is None:
        return [Finding("config-replica-threading", ENGINE_PATH, 1,
                        "EngineConfig dataclass not found",
                        key="missing-engineconfig")]
    findings: List[Finding] = []
    tree = repo.tree(CLUSTER_PATH)
    spec = find_class(tree, "ReplicaSpec")
    maker = find_def(tree.body, "make_replica_specs")
    if spec is None or maker is None:
        return [Finding("config-replica-threading", CLUSTER_PATH, 1,
                        "ReplicaSpec / make_replica_specs not found",
                        key="missing-replicaspec")]
    spec_fields = {n for n, _ in dataclass_fields(spec)}
    maker_args = set(arg_names(maker))
    eng_cfg = find_def(spec.body, "engine_config")
    cfg_kwargs = set(call_kwargs(eng_cfg, ("EngineConfig",))) \
        if eng_cfg is not None else set()
    excluded = _excluded(repo)
    for fname, _lineno in fields:
        if fname in excluded:
            continue
        if fname not in spec_fields:
            findings.append(Finding(
                "config-replica-threading", CLUSTER_PATH, spec.lineno,
                f"EngineConfig.{fname} has no ReplicaSpec field (add it "
                "or list it in NON_REPLICA_FIELDS with a justification)",
                key=f"spec-{fname}"))
            continue
        if fname not in maker_args:
            findings.append(Finding(
                "config-replica-threading", CLUSTER_PATH, maker.lineno,
                f"make_replica_specs cannot set ReplicaSpec.{fname} — "
                "callers are stuck with the default",
                key=f"maker-{fname}"))
        if fname not in cfg_kwargs:
            findings.append(Finding(
                "config-replica-threading", CLUSTER_PATH,
                eng_cfg.lineno if eng_cfg else spec.lineno,
                f"ReplicaSpec.engine_config never forwards {fname} to "
                "EngineConfig — the spec value is ignored",
                key=f"forward-{fname}"))
    return findings


def _parser_flags(repo: Repo) -> Set[str]:
    flags: Set[str] = set()
    for rel in repo.files(LAUNCH_GLOB):
        tree = repo.tree(rel)
        bp = None
        for node in tree.body:
            if isinstance(node, ast.FunctionDef) \
                    and node.name == "build_parser":
                bp = node
                break
        if bp is None:
            continue
        for node in ast.walk(bp):
            if isinstance(node, ast.Call) and isinstance(
                    node.func, ast.Attribute) \
                    and node.func.attr == "add_argument":
                for a in node.args:
                    if isinstance(a, ast.Constant) and isinstance(
                            a.value, str) and a.value.startswith("--"):
                        flags.add(a.value)
    return flags


@rule("config-cli-threading",
      "every EngineConfig field (minus NON_REPLICA_FIELDS) is settable "
      "via a --flag in at least one launcher build_parser")
def check_cli_threading(repo: Repo) -> List[Finding]:
    fields = _engine_fields(repo)
    if fields is None:
        return []
    flags = _parser_flags(repo)
    excluded = _excluded(repo)
    findings: List[Finding] = []
    for fname, lineno in fields:
        if fname in excluded:
            continue
        accepted = FLAG_ALIASES.get(fname, ()) \
            + ("--" + fname.replace("_", "-"),)
        if not any(f in flags for f in accepted):
            findings.append(Finding(
                "config-cli-threading", ENGINE_PATH, lineno,
                f"EngineConfig.{fname} has no launcher flag (expected "
                f"one of {', '.join(accepted)} in some build_parser)",
                key=f"flag-{fname}"))
    return findings

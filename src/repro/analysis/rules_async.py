"""Async-safety rule: no blocking calls inside ``async def`` bodies in
the serving layer.

The gateway multiplexes every client stream on one event loop; a single
``time.sleep`` or synchronous socket/file call inside a coroutine
stalls *all* streams (and, under the virtual clock, deadlocks the
driven-clock tests).  Blocking work belongs in an executor thread or
behind ``asyncio.to_thread``.
"""
from __future__ import annotations

import ast
from typing import List

from .core import Finding, Repo, dotted_name, rule

SCOPES = ("src/repro/serving/*.py",)

BLOCKING_CALLS = {"time.sleep", "os.system", "input",
                  "urllib.request.urlopen"}
BLOCKING_PREFIXES = ("socket.", "subprocess.", "requests.")
BLOCKING_METHODS = {"read_text", "write_text", "read_bytes",
                    "write_bytes"}


@rule("async-blocking-call",
      "no blocking calls (time.sleep, sync socket/file IO) inside "
      "async def bodies in the serving layer")
def check_async_blocking(repo: Repo) -> List[Finding]:
    findings: List[Finding] = []
    for rel in repo.files(*SCOPES):
        tree = repo.tree(rel)
        for fn in ast.walk(tree):
            if not isinstance(fn, ast.AsyncFunctionDef):
                continue
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                name = dotted_name(node.func)
                method = (node.func.attr
                          if isinstance(node.func, ast.Attribute) else "")
                blocking = (name in BLOCKING_CALLS
                            or name == "open"
                            or name.startswith(BLOCKING_PREFIXES)
                            or method in BLOCKING_METHODS)
                if blocking:
                    what = name or method
                    findings.append(Finding(
                        rule="async-blocking-call", path=rel,
                        line=node.lineno,
                        message=f"blocking call {what}() inside async "
                                f"def {fn.name} — stalls the event loop; "
                                "use asyncio primitives or to_thread",
                        key=f"{what}@{fn.name}"))
    return findings

# Pallas TPU kernels for the paper's serving hot-spots (Punica-style
# multi-adapter LoRA matmuls + flash decode), with pure-jnp oracles.
from . import ops, ref  # noqa: F401

"""BGMV — batched-gather LoRA matmul for decode (TPU adaptation of Punica).

One grid step per token block: the per-token adapter id arrives via scalar
prefetch and drives the A/B BlockSpec index maps, so each step DMAs exactly
one adapter's (d, r) shrink and (r, o) expand matrices into VMEM and runs
two MXU matmuls.  CUDA-Punica's warp-gather has no TPU analogue; the
data-dependent index_map is the TPU-native equivalent (the gather happens in
the DMA engine, overlapped with compute by the Pallas pipeline).

Tokens inside a block share the gathered adapter, so the wrapper pads the
token axis to the block size and uses block=1 tokens for the fully general
case (decode batches are small — this is exactly Punica's BGMV regime).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _bgmv_kernel(idx_ref, x_ref, a_ref, b_ref, o_ref, *, scale: float):
    i = pl.program_id(0)
    x = x_ref[...]                                    # (1, d)
    a = a_ref[0]                                      # (d, r)
    b = b_ref[0]                                      # (r, o)
    h = jnp.dot(x.astype(jnp.float32), a.astype(jnp.float32),
                preferred_element_type=jnp.float32)   # (1, r)
    y = jnp.dot(h, b.astype(jnp.float32),
                preferred_element_type=jnp.float32)   # (1, o)
    # idx < 0 = base-model token: the index map clamped the DMA to
    # adapter 0; mask its contribution to a zero delta here.
    y = jnp.where(idx_ref[i] >= 0, y * scale, 0.0)
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def bgmv(x, a, b, idx, scale: float = 1.0, interpret: bool = False):
    """y[t] = scale * x[t] @ A[idx[t]] @ B[idx[t]].

    x: (T, d); a: (N, d, r); b: (N, r, o); idx: (T,) int32 -> (T, o).
    Tokens with idx < 0 (base model, no adapter) get a zero delta.
    """
    t, d = x.shape
    n, _, r = a.shape
    o = b.shape[-1]
    grid = (t,)

    def _ab_map(i, idx_ref):
        return (jnp.maximum(idx_ref[i], 0), 0, 0)

    out = pl.pallas_call(
        functools.partial(_bgmv_kernel, scale=scale),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, d), lambda i, idx_ref: (i, 0)),
                pl.BlockSpec((1, d, r), _ab_map),
                pl.BlockSpec((1, r, o), _ab_map),
            ],
            out_specs=pl.BlockSpec((1, o), lambda i, idx_ref: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((t, o), x.dtype),
        interpret=interpret,
    )(idx.astype(jnp.int32), x, a, b)
    return out

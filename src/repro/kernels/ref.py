"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

These are also the XLA fallback used on non-TPU backends and inside the
multi-pod dry-run (Pallas lowers only for TPU targets; the dry-run compiles
for the host platform).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mask_ragged(a, b, ranks):
    """Zero the padded LoRA lanes of a ragged-rank adapter bank.

    a: (N, d, r_max); b: (N, r_max, o); ranks: (N,) with ranks[i] <= r_max.
    Returns (a', b') where adapter i keeps only its first ranks[i] lanes —
    the *dense per-rank oracle* weights: running any dense kernel on them
    is exactly the ragged computation (padded lanes contribute literal
    zeros).
    """
    r = a.shape[-1]
    valid = jnp.arange(r)[None, :] < jnp.asarray(ranks)[:, None]   # (N, r)
    return (jnp.where(valid[:, None, :], a, 0),
            jnp.where(valid[:, :, None], b, 0))


def lora_shrink_ref(x, a, idx):
    """x: (T, d); a: (N, d, r); idx: (T,) -> (T, r)."""
    return jnp.einsum("td,tdr->tr", x, a[idx],
                      preferred_element_type=jnp.float32).astype(x.dtype)


def lora_expand_ref(h, b, idx):
    """h: (T, r); b: (N, r, o); idx: (T,) -> (T, o)."""
    return jnp.einsum("tr,tro->to", h, b[idx],
                      preferred_element_type=jnp.float32).astype(h.dtype)


def lora_ref(x, a, b, idx, scale: float = 1.0):
    """Fused y = scale * (x @ A[idx]) @ B[idx].

    x: (T, d); a: (N, d, r); b: (N, r, o); idx: (T,) -> (T, o).
    Tokens with idx < 0 ("no adapter") get a zero delta.
    """
    idx = jnp.asarray(idx)
    idx0 = jnp.maximum(idx, 0)
    h = lora_shrink_ref(x, a, idx0)
    y = lora_expand_ref(h, b, idx0) * jnp.asarray(scale, x.dtype)
    return jnp.where((idx >= 0)[:, None], y, 0)


def lora_ref_ragged(x, a, b, idx, ranks, scale: float = 1.0):
    """Ragged-rank oracle: adapter i uses only its first ranks[i] lanes.

    Defined as the dense oracle over `mask_ragged` weights, so any kernel
    claiming ragged support can be tested *bitwise* against its own dense
    path on the masked bank.
    """
    am, bm = mask_ragged(a, b, ranks)
    return lora_ref(x, am, bm, idx, scale)


def lora_ref_bucketed(x, a, b, idx, scale: float = 1.0,
                      overprovision: float = 2.0):
    """Capacity-bucketed formulation (the SGMV math in pure XLA).

    The naive `a[idx]` gather materializes a (T, d, r) tensor — 2r x the
    activation itself — which is catastrophic at prefill sizes.  Instead,
    bucket tokens by adapter into an (N, C, d) buffer and run two dense
    batched matmuls (exact same scheme as the Pallas SGMV kernel).
    Tokens over capacity fall back to 0 delta (C defaults to 2x the mean
    load + slack, so this only triggers under extreme skew — the kernel
    path has the same contract).
    """
    t, d = x.shape
    n, _, r = a.shape
    idx = jnp.asarray(idx)
    cap = min(t, int(overprovision * -(-t // n)) + 8)
    onehot = jax.nn.one_hot(idx, n, dtype=jnp.int32)   # idx<0 -> all-zero row
    pos = jnp.cumsum(onehot, axis=0) - onehot
    pos = jnp.sum(pos * onehot, axis=1)
    keep = (pos < cap) & (idx >= 0)
    posc = jnp.where(keep, pos, cap)
    idx0 = jnp.maximum(idx, 0)
    buf = jnp.zeros((n, cap + 1, d), x.dtype)
    buf = buf.at[idx0, posc].set(jnp.where(keep[:, None], x, 0))
    h = jnp.einsum("ncd,ndr->ncr", buf[:, :cap], a,
                   preferred_element_type=jnp.float32).astype(x.dtype)
    y = jnp.einsum("ncr,nro->nco", h, b,
                   preferred_element_type=jnp.float32).astype(x.dtype)
    out = y[idx0, posc.clip(0, cap - 1)]
    out = jnp.where(keep[:, None], out, 0)
    return out * jnp.asarray(scale, x.dtype)


def flash_decode_ref(q, k, v, length):
    """Single-token attention against a contiguous cache.

    q: (B, H, D); k/v: (B, S, KV, D); length: scalar or (B,) valid length.
    """
    b, h, d = q.shape
    s, kv = k.shape[1], k.shape[2]
    g = h // kv
    qs = q.reshape(b, kv, g, d)
    logits = jnp.einsum("bkgd,bskd->bkgs", qs.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(float(d))
    pos = jnp.arange(s)
    ln = jnp.asarray(length)
    mask = pos[None, :] < (ln[:, None] if ln.ndim else ln[None, None])
    logits = jnp.where(mask[:, None, None, :], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    return out.reshape(b, h, d).astype(q.dtype)


def fused_decode_ref(q, k, v, length, x, a, b, idx, scale: float = 1.0):
    """Composed oracle for the fused decode kernel:

        attn(q, K, V)  +  scale * x @ A[idx] @ B[idx]   (reshaped (H, D))

    q: (B, H, D); k/v: (B, S, KV, D); x: (B, dx); a: (N, dx, r);
    b: (N, r, H*D); idx: (B,) per-request adapter ids, -1 = base model
    (zero delta).  This is literally ``flash_decode_ref`` + ``lora_ref``
    — the fused kernel is tested against this composition.
    """
    bsz, h, d = q.shape
    attn = flash_decode_ref(q, k, v, length)
    delta = lora_ref(x, a, b, idx, scale).reshape(bsz, h, d)
    return (attn.astype(jnp.float32)
            + delta.astype(jnp.float32)).astype(q.dtype)

"""SGMV — segmented LoRA matmul for prefill (TPU adaptation of Punica).

CUDA-Punica walks ragged per-adapter segments with warp-level gathers.  The
TPU-native formulation: bucket tokens by adapter into a fixed-capacity
buffer (one-hot cumsum positions, same dispatch primitive as our MoE), then
run a *dense grouped matmul* over grid (adapters × capacity blocks) with
128-aligned tiles — full MXU utilisation and zero in-kernel gathers — and
scatter the results back to token order.

The capacity buffer costs O(N·C·d) HBM but C is bounded by the wrapper to
ceil(T/N)·overprovision, and prefill T is large exactly when the buffer is
efficient (the paper's serving regime batches many requests per adapter).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sgmv_kernel(x_ref, a_ref, b_ref, o_ref, *, scale: float):
    x = x_ref[0]                                      # (Cb, d)
    a = a_ref[0]                                      # (d, r)
    b = b_ref[0]                                      # (r, o)
    h = jnp.dot(x.astype(jnp.float32), a.astype(jnp.float32),
                preferred_element_type=jnp.float32)   # (Cb, r)
    y = jnp.dot(h, b.astype(jnp.float32),
                preferred_element_type=jnp.float32)   # (Cb, o)
    o_ref[0] = (y * scale).astype(o_ref.dtype)


def _grouped_matmul(xbuf, a, b, scale: float, interpret: bool,
                    block_c: int = 128):
    """xbuf: (N, C, d) -> (N, C, o) with per-group A/B."""
    n, c, d = xbuf.shape
    r, o = a.shape[-1], b.shape[-1]
    nc = max(c // block_c, 1)
    block_c = c // nc
    return pl.pallas_call(
        functools.partial(_sgmv_kernel, scale=scale),
        grid=(n, nc),
        in_specs=[
            pl.BlockSpec((1, block_c, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, d, r), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, r, o), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_c, o), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((n, c, o), xbuf.dtype),
        interpret=interpret,
    )(xbuf, a, b)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def sgmv(x, a, b, idx, scale: float = 1.0, interpret: bool = False):
    """y[t] = scale * x[t] @ A[idx[t]] @ B[idx[t]] (prefill-sized T).

    x: (T, d); a: (N, d, r); b: (N, r, o); idx: (T,) -> (T, o).
    """
    t, d = x.shape
    n = a.shape[0]
    # bucket tokens by adapter (dropless: capacity covers the worst case
    # sized by 2x mean + 128, clamped to T)
    cap = min(t, int(2 * -(-t // n)) + 128)
    cap = -(-cap // 128) * 128
    onehot = jax.nn.one_hot(idx, n, dtype=jnp.int32)       # (T, N)
    pos = jnp.cumsum(onehot, axis=0) - onehot
    pos = jnp.sum(pos * onehot, axis=1)                    # (T,)
    keep = pos < cap
    posc = jnp.where(keep, pos, cap)
    xbuf = jnp.zeros((n, cap + 1, d), x.dtype)
    xbuf = xbuf.at[idx, posc].set(jnp.where(keep[:, None], x, 0))
    ybuf = _grouped_matmul(xbuf[:, :cap], a, b, scale, interpret)
    y = ybuf[idx, posc.clip(0, cap - 1)]
    return jnp.where(keep[:, None], y, 0).astype(x.dtype)

"""SGMV — segmented LoRA matmul for prefill (TPU adaptation of Punica).

CUDA-Punica walks ragged per-adapter segments with warp-level gathers.  The
TPU-native formulation: bucket tokens by adapter into a fixed-capacity
buffer (one-hot cumsum positions, same dispatch primitive as our MoE), then
run a *dense grouped matmul* over grid (adapters × capacity blocks) with
128-aligned tiles — full MXU utilisation and zero in-kernel gathers — and
scatter the results back to token order.

The capacity buffer costs O(N·C·d) HBM but C is bounded by the wrapper to
ceil(T/N)·overprovision, and prefill T is large exactly when the buffer is
efficient (the paper's serving regime batches many requests per adapter).

Ragged per-adapter ranks: pass ``ranks`` (shape (N,), ranks[i] <= r_max)
and adapter i uses only its first ranks[i] LoRA lanes.  The per-adapter
rank arrives via scalar prefetch and masks the padded lanes of A's columns
and B's rows *before* the shrink/expand matmuls, so the result is bitwise
equal to running the dense kernel on a zero-padded bank
(``ref.mask_ragged`` is exactly that oracle).  This is the S-LoRA
heterogeneous-rank batched regime: one bank sized r_max, no per-rank
re-bucketing, no wasted FLOP correctness hazard.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _sgmv_kernel(x_ref, a_ref, b_ref, o_ref, *, scale: float):
    x = x_ref[0]                                      # (Cb, d)
    a = a_ref[0]                                      # (d, r)
    b = b_ref[0]                                      # (r, o)
    h = jnp.dot(x.astype(jnp.float32), a.astype(jnp.float32),
                preferred_element_type=jnp.float32)   # (Cb, r)
    y = jnp.dot(h, b.astype(jnp.float32),
                preferred_element_type=jnp.float32)   # (Cb, o)
    o_ref[0] = (y * scale).astype(o_ref.dtype)


def _sgmv_ragged_kernel(rank_ref, x_ref, a_ref, b_ref, o_ref, *,
                        scale: float):
    i = pl.program_id(0)
    x = x_ref[0]                                      # (Cb, d)
    a = a_ref[0]                                      # (d, r_max)
    b = b_ref[0]                                      # (r_max, o)
    r = a.shape[-1]
    # mask padded lanes BEFORE the matmuls: the arithmetic then matches
    # the dense kernel on mask_ragged-ed weights value-for-value, which
    # makes ragged == dense-on-masked-bank a bitwise identity.
    lane_cols = jax.lax.broadcasted_iota(jnp.int32, (1, r), 1)
    lane_rows = jax.lax.broadcasted_iota(jnp.int32, (r, 1), 0)
    a = jnp.where(lane_cols < rank_ref[i], a, 0)
    b = jnp.where(lane_rows < rank_ref[i], b, 0)
    h = jnp.dot(x.astype(jnp.float32), a.astype(jnp.float32),
                preferred_element_type=jnp.float32)   # (Cb, r_max)
    y = jnp.dot(h, b.astype(jnp.float32),
                preferred_element_type=jnp.float32)   # (Cb, o)
    o_ref[0] = (y * scale).astype(o_ref.dtype)


def _grouped_matmul(xbuf, a, b, scale: float, interpret: bool,
                    ranks=None, block_c: int = 128):
    """xbuf: (N, C, d) -> (N, C, o) with per-group A/B.

    ``ranks`` (N,) enables the ragged kernel: per-adapter rank rides the
    scalar-prefetch path and masks the padded lanes in-kernel.
    """
    n, c, d = xbuf.shape
    r, o = a.shape[-1], b.shape[-1]
    nc = max(c // block_c, 1)
    block_c = c // nc
    if ranks is None:
        return pl.pallas_call(
            functools.partial(_sgmv_kernel, scale=scale),
            grid=(n, nc),
            in_specs=[
                pl.BlockSpec((1, block_c, d), lambda i, j: (i, j, 0)),
                pl.BlockSpec((1, d, r), lambda i, j: (i, 0, 0)),
                pl.BlockSpec((1, r, o), lambda i, j: (i, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, block_c, o), lambda i, j: (i, j, 0)),
            out_shape=jax.ShapeDtypeStruct((n, c, o), xbuf.dtype),
            interpret=interpret,
        )(xbuf, a, b)
    return pl.pallas_call(
        functools.partial(_sgmv_ragged_kernel, scale=scale),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n, nc),
            in_specs=[
                pl.BlockSpec((1, block_c, d), lambda i, j, rk: (i, j, 0)),
                pl.BlockSpec((1, d, r), lambda i, j, rk: (i, 0, 0)),
                pl.BlockSpec((1, r, o), lambda i, j, rk: (i, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, block_c, o),
                                   lambda i, j, rk: (i, j, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((n, c, o), xbuf.dtype),
        interpret=interpret,
    )(jnp.asarray(ranks, jnp.int32), xbuf, a, b)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def sgmv(x, a, b, idx, scale: float = 1.0, ranks=None,
         interpret: bool = False):
    """y[t] = scale * x[t] @ A[idx[t]] @ B[idx[t]] (prefill-sized T).

    x: (T, d); a: (N, d, r); b: (N, r, o); idx: (T,) -> (T, o).
    Tokens with idx < 0 get a zero delta.  ``ranks`` (N,) makes the bank
    ragged: adapter i uses only its first ranks[i] <= r lanes.
    """
    t, d = x.shape
    n = a.shape[0]
    idx = jnp.asarray(idx)
    # bucket tokens by adapter (dropless: capacity covers the worst case
    # sized by 2x mean + 128, clamped to T)
    cap = min(t, int(2 * -(-t // n)) + 128)
    cap = -(-cap // 128) * 128
    onehot = jax.nn.one_hot(idx, n, dtype=jnp.int32)       # idx<0 -> zeros
    pos = jnp.cumsum(onehot, axis=0) - onehot
    pos = jnp.sum(pos * onehot, axis=1)                    # (T,)
    keep = (pos < cap) & (idx >= 0)
    posc = jnp.where(keep, pos, cap)
    idx0 = jnp.maximum(idx, 0)
    xbuf = jnp.zeros((n, cap + 1, d), x.dtype)
    xbuf = xbuf.at[idx0, posc].set(jnp.where(keep[:, None], x, 0))
    ybuf = _grouped_matmul(xbuf[:, :cap], a, b, scale, interpret,
                           ranks=ranks)
    y = ybuf[idx0, posc.clip(0, cap - 1)]
    return jnp.where(keep[:, None], y, 0).astype(x.dtype)

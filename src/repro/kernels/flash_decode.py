"""Flash-decode — single-token attention over a contiguous KV cache.

Grid (batch, kv blocks); the kv-block axis is the innermost (sequential)
grid dimension, so online-softmax state (m, l, acc) lives in fp32 VMEM
scratch and is carried across blocks; the output is written once on the
last block.  Per-batch valid length arrives via scalar prefetch and masks
the tail block.  KV blocks are (block_s, KV, D) slabs — contiguous in HBM,
DMA-friendly, 128-aligned in the minor dimension.

This is the serving engine's per-step attention hot spot: the Digital
Twin's ``Lat_model`` estimator is dominated by exactly this kernel's
memory-bound KV streaming.

``flash_decode_lora`` fuses the per-request multi-adapter LoRA delta
(BGMV) into the epilogue: one Pallas launch per decode step produces
``attn(q, K, V) + scale * x @ A[idx] @ B[idx]``.  The per-request adapter
id rides the same scalar-prefetch path as the valid lengths and drives
the A/B BlockSpec index maps (the gather happens in the DMA engine, like
``bgmv``); the online-softmax scratch is carried across KV blocks exactly
as in the unfused kernel, and the delta is added once on the last block.
Requests with ``idx < 0`` serve the base model (zero delta).  Versus the
unfused base-then-adapter sequence this saves one kernel launch plus a
round-trip of both the attention output and the delta through HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _fd_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
               m_ref, l_ref, acc_ref, *, block_s: int, n_blocks: int):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                   # (H, D)
    k = k_ref[0].astype(jnp.float32)                   # (Sb, KV, D)
    v = v_ref[0].astype(jnp.float32)
    h, d = q.shape
    sb, kv, _ = k.shape
    g = h // kv
    scale = 1.0 / (d ** 0.5)

    qs = q.reshape(kv, g, d)
    s = jax.lax.dot_general(
        qs, k, (((2,), (2,)), ((0,), (1,))),
        preferred_element_type=jnp.float32) * scale     # (KV, G, Sb)
    pos = j * block_s + jax.lax.broadcasted_iota(jnp.int32, (1, 1, sb), 2)
    mask = pos < len_ref[b]
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                 # (KV, G)
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.where(mask, jnp.exp(s - m_new[..., None]), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1)
    pv = jax.lax.dot_general(
        p, v, (((2,), (0,)), ((0,), (1,))),
        preferred_element_type=jnp.float32)             # (KV, G, D)
    acc_ref[...] = acc_ref[...] * corr[..., None] + pv
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(j == n_blocks - 1)
    def _done():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-20)[..., None]
        o_ref[0] = out.reshape(h, d).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def flash_decode(q, k, v, length, block_s: int = 512,
                 interpret: bool = False):
    """q: (B, H, D); k/v: (B, S, KV, D); length: (B,) or scalar."""
    b, h, d = q.shape
    s, kv = k.shape[1], k.shape[2]
    g = h // kv
    block_s = min(block_s, s)
    while s % block_s:
        block_s //= 2
    n_blocks = s // block_s
    lengths = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (b,))
    return pl.pallas_call(
        functools.partial(_fd_kernel, block_s=block_s, n_blocks=n_blocks),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b, n_blocks),
            in_specs=[
                pl.BlockSpec((1, h, d), lambda i, j, len_ref: (i, 0, 0)),
                pl.BlockSpec((1, block_s, kv, d),
                             lambda i, j, len_ref: (i, j, 0, 0)),
                pl.BlockSpec((1, block_s, kv, d),
                             lambda i, j, len_ref: (i, j, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, h, d), lambda i, j, len_ref: (i, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((kv, g), jnp.float32),
                pltpu.VMEM((kv, g), jnp.float32),
                pltpu.VMEM((kv, g, d), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        interpret=interpret,
    )(lengths, q, k, v)


def _fd_lora_kernel(len_ref, idx_ref, q_ref, k_ref, v_ref,
                    x_ref, a_ref, b_ref, o_ref,
                    m_ref, l_ref, acc_ref, *, block_s: int, n_blocks: int,
                    scale: float):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                   # (H, D)
    k = k_ref[0].astype(jnp.float32)                   # (Sb, KV, D)
    v = v_ref[0].astype(jnp.float32)
    h, d = q.shape
    sb, kv, _ = k.shape
    g = h // kv
    qscale = 1.0 / (d ** 0.5)

    qs = q.reshape(kv, g, d)
    s = jax.lax.dot_general(
        qs, k, (((2,), (2,)), ((0,), (1,))),
        preferred_element_type=jnp.float32) * qscale    # (KV, G, Sb)
    pos = j * block_s + jax.lax.broadcasted_iota(jnp.int32, (1, 1, sb), 2)
    mask = pos < len_ref[b]
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                 # (KV, G)
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.where(mask, jnp.exp(s - m_new[..., None]), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1)
    pv = jax.lax.dot_general(
        p, v, (((2,), (0,)), ((0,), (1,))),
        preferred_element_type=jnp.float32)             # (KV, G, D)
    acc_ref[...] = acc_ref[...] * corr[..., None] + pv
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(j == n_blocks - 1)
    def _done():
        attn = acc_ref[...] / jnp.maximum(l_ref[...], 1e-20)[..., None]
        attn = attn.reshape(h, d)
        # LoRA epilogue: the A/B blocks for this request's adapter were
        # DMAed by the index maps; two tiny MXU matmuls, then mask base
        # requests (idx < 0) to a zero delta.
        x = x_ref[...].astype(jnp.float32)              # (1, dx)
        hh = jnp.dot(x, a_ref[0].astype(jnp.float32),
                     preferred_element_type=jnp.float32)   # (1, r)
        delta = jnp.dot(hh, b_ref[0].astype(jnp.float32),
                        preferred_element_type=jnp.float32)  # (1, H*D)
        delta = jnp.where(idx_ref[b] >= 0, delta * scale, 0.0)
        o_ref[0] = (attn + delta.reshape(h, d)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "block_s",
                                             "interpret"))
def flash_decode_lora(q, k, v, length, x, a, b, idx, scale: float = 1.0,
                      block_s: int = 512, interpret: bool = False):
    """Fused decode step: ``attn(q,K,V) + scale * x @ A[idx] @ B[idx]``.

    q: (B, H, D); k/v: (B, S, KV, D); length: (B,) or scalar;
    x: (B, dx); a: (N, dx, r); b: (N, r, H*D); idx: (B,) int32 adapter
    ids (idx < 0 -> base model, zero delta).  One Pallas launch per step.
    """
    bsz, h, d = q.shape
    s, kv = k.shape[1], k.shape[2]
    g = h // kv
    dx, r = a.shape[1], a.shape[2]
    if b.shape[-1] != h * d:
        raise ValueError(f"expand dim {b.shape[-1]} != H*D = {h * d}")
    block_s = min(block_s, s)
    while s % block_s:
        block_s //= 2
    n_blocks = s // block_s
    lengths = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (bsz,))
    ids = jnp.asarray(idx, jnp.int32)

    def _ab_map(i, j, len_ref, idx_ref):
        # clamp: base requests (id -1) must still name a DMA-able block;
        # their delta is masked in the epilogue.
        return (jnp.maximum(idx_ref[i], 0), 0, 0)

    return pl.pallas_call(
        functools.partial(_fd_lora_kernel, block_s=block_s,
                          n_blocks=n_blocks, scale=scale),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(bsz, n_blocks),
            in_specs=[
                pl.BlockSpec((1, h, d), lambda i, j, ln, ix: (i, 0, 0)),
                pl.BlockSpec((1, block_s, kv, d),
                             lambda i, j, ln, ix: (i, j, 0, 0)),
                pl.BlockSpec((1, block_s, kv, d),
                             lambda i, j, ln, ix: (i, j, 0, 0)),
                pl.BlockSpec((1, dx), lambda i, j, ln, ix: (i, 0)),
                pl.BlockSpec((1, dx, r), _ab_map),
                pl.BlockSpec((1, r, h * d), _ab_map),
            ],
            out_specs=pl.BlockSpec((1, h, d),
                                   lambda i, j, ln, ix: (i, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((kv, g), jnp.float32),
                pltpu.VMEM((kv, g), jnp.float32),
                pltpu.VMEM((kv, g, d), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((bsz, h, d), q.dtype),
        interpret=interpret,
    )(lengths, ids, q, k, v, x, a, b)

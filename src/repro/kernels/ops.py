"""Public kernel entry points with backend dispatch.

On TPU these call the Pallas kernels (`bgmv.py`, `sgmv.py`,
`flash_decode.py`); everywhere else (CPU tests, host-platform dry-run) they
fall back to the pure-jnp oracles in `ref.py`.  `force` overrides dispatch
('pallas' | 'ref' | 'interpret') — 'interpret' runs the Pallas kernel body
in interpreter mode, which is how the kernel unit tests validate on CPU.

Shared conventions across every entry point:

* adapter ids < 0 mean "base model, no adapter" -> zero LoRA delta;
* ``ranks`` (shape (N,), ranks[i] <= r_max) makes the adapter bank
  ragged: adapter i uses only its first ranks[i] LoRA lanes (padded
  lanes are masked so results are bitwise the dense kernel on a
  ``ref.mask_ragged`` zero-padded bank).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from . import ref

KERNEL_MODES = ("pallas", "ref", "interpret")


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover
        return False


def lora_apply(x, a, b, idx, scale: float = 1.0, ranks=None,
               force: str = ""):
    """Multi-adapter LoRA delta: y[t] = scale * x[t] @ A[idx[t]] @ B[idx[t]].

    x: (..., d); idx: per-token adapter ids broadcastable to x's leading
    dims — or per-REQUEST ids of shape (B,) for x of shape (B, S, d).
    a: (N, d, r); b: (N, r, o).  Returns (..., o).  ids < 0 -> zero
    delta; ``ranks`` (N,) enables ragged per-adapter ranks.
    """
    lead = x.shape[:-1]
    d = x.shape[-1]
    mode = force or ("pallas" if _on_tpu() else "ref")

    if mode == "ref" and x.ndim == 3 and idx.shape == (x.shape[0],):
        # per-request adapters (the serving engine's layout): gather A/B at
        # request granularity — (B, d, r) is tiny — and keep (B, S, d)
        # intact so sharded dims are never reshaped together.
        if ranks is not None:
            a, b = ref.mask_ragged(a, b, ranks)
        idx0 = jnp.maximum(idx, 0)
        ag = jnp.take(a, idx0, axis=0)
        bg = jnp.take(b, idx0, axis=0)
        h = jnp.einsum("bsd,bdr->bsr", x, ag,
                       preferred_element_type=jnp.float32).astype(x.dtype)
        y = jnp.einsum("bsr,bro->bso", h, bg,
                       preferred_element_type=jnp.float32).astype(x.dtype)
        y = jnp.where((idx >= 0)[:, None, None], y, 0)
        return y * jnp.asarray(scale, x.dtype)

    xt = x.reshape(-1, d)
    it = jnp.broadcast_to(idx.reshape(-1, *([1] * (len(lead) - idx.ndim))),
                          lead).reshape(-1) if idx.shape != lead else idx.reshape(-1)
    if mode == "ref":
        if ranks is not None:
            a, b = ref.mask_ragged(a, b, ranks)
        if xt.shape[0] >= 4 * a.shape[0]:
            # token-level ids at prefill size: bucketed SGMV math
            out = ref.lora_ref_bucketed(xt, a, b, it, scale)
        else:
            out = ref.lora_ref(xt, a, b, it, scale)
    else:
        from . import bgmv, sgmv  # lazy: only touch Pallas when requested
        if xt.shape[0] <= a.shape[0] * 4:
            # decode-sized problems -> BGMV (per-token gather); ragged
            # banks are pre-masked (N is small at decode size, the
            # masked bank is cheap and keeps BGMV single-purpose)
            if ranks is not None:
                a, b = ref.mask_ragged(a, b, ranks)
            out = bgmv.bgmv(xt, a, b, it, scale,
                            interpret=(mode == "interpret"))
        else:
            # prefill-sized -> SGMV; interpret follows the same routing
            # so CPU tests exercise the kernel Pallas actually runs
            out = sgmv.sgmv(xt, a, b, it, scale, ranks=ranks,
                            interpret=(mode == "interpret"))
    return out.reshape(*lead, -1)


def flash_decode(q, k, v, length, force: str = ""):
    """Single-token attention against a contiguous KV cache.

    q: (B, H, D); k/v: (B, S, KV, D); length: valid prefix length.
    """
    mode = force or ("pallas" if _on_tpu() else "ref")
    if mode == "ref":
        return ref.flash_decode_ref(q, k, v, length)
    from . import flash_decode as fd
    return fd.flash_decode(q, k, v, length, interpret=(mode == "interpret"))


def fused_decode(q, k, v, length, x, a, b, idx, scale: float = 1.0,
                 ranks=None, force: str = ""):
    """Fused decode step: ``attn(q,K,V) + scale * x @ A[idx] @ B[idx]``.

    One kernel launch per decode step instead of base-then-adapter.
    q: (B, H, D); k/v: (B, S, KV, D); x: (B, dx); a: (N, dx, r);
    b: (N, r, H*D); idx: (B,) adapter ids (< 0 -> base model);
    ``ranks`` (N,) enables ragged per-adapter ranks.  Returns (B, H, D).
    """
    mode = force or ("pallas" if _on_tpu() else "ref")
    if ranks is not None:
        a, b = ref.mask_ragged(a, b, ranks)
    if mode == "ref":
        return ref.fused_decode_ref(q, k, v, length, x, a, b, idx, scale)
    from . import flash_decode as fd
    return fd.flash_decode_lora(q, k, v, length, x, a, b, idx, scale,
                                interpret=(mode == "interpret"))

"""internvl2-2b [vlm] — arXiv:2404.16821 (hf).

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553 — InternViT +
InternLM2 backbone.  The InternViT frontend is a STUB: ``input_specs()``
supplies 256 precomputed patch embeddings (B, 256, d_model) that are
prepended to the token embeddings.
"""
import dataclasses

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8,
    d_ff=8192, vocab_size=92553, head_dim=128,
    n_image_tokens=256,
    block_pattern=("global",), mlp="swiglu", norm="rmsnorm", pos_emb="rope",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="internvl2-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=512, head_dim=16,
        n_image_tokens=8)

"""phi4-mini-3.8b [dense] — arXiv:2412.08905 (hf).

32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064 — RoPE SwiGLU GQA.
"""
import dataclasses

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8,
    d_ff=8192, vocab_size=200064, head_dim=128,
    block_pattern=("global",), mlp="swiglu", norm="rmsnorm", pos_emb="rope",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="phi4-mini-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=512, head_dim=16)

"""Input ShapeDtypeStruct stand-ins for every (arch × shape) dry-run cell.

Nothing here allocates: these are the abstract inputs handed to
``jax.jit(step).lower(...)``.  LoRA serving parameters for the decode cells
follow the paper's setting (adapter slots resident on device, rank-16
adapters on q/v).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig, ShapeConfig

SDS = jax.ShapeDtypeStruct

# paper-facing LoRA serving defaults for the dry-run decode/prefill cells
DRYRUN_ADAPTER_SLOTS = 32
DRYRUN_LORA_RANK = 16


def train_inputs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    batch = {"tokens": SDS((b, s + 1), jnp.int32)}
    if cfg.family == "vlm":
        batch["tokens"] = SDS((b, s - cfg.n_image_tokens + 1), jnp.int32)
        batch["img_embeds"] = SDS((b, cfg.n_image_tokens, cfg.d_model),
                                  cfg.jnp_dtype)
    return batch


def prefill_inputs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    out: Dict[str, Any] = {
        "tokens": SDS((b, s), jnp.int32),
        "adapter_idx": SDS((b,), jnp.int32),
    }
    if cfg.family == "vlm":
        out["tokens"] = SDS((b, s - cfg.n_image_tokens), jnp.int32)
        out["img_embeds"] = SDS((b, cfg.n_image_tokens, cfg.d_model),
                                cfg.jnp_dtype)
    return out


def decode_inputs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    b = shape.global_batch
    return {
        "tokens": SDS((b, 1), jnp.int32),
        "adapter_idx": SDS((b,), jnp.int32),
    }


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    if shape.kind == "train":
        return train_inputs(cfg, shape)
    if shape.kind == "prefill":
        return prefill_inputs(cfg, shape)
    return decode_inputs(cfg, shape)

"""Architecture registry: the 10 assigned configs + reduced smoke variants.

Every entry exposes:
  * ``CONFIG``    — the exact published configuration,
  * ``reduced()`` — a structurally identical, CPU-sized variant for smoke
                    tests (same family/pattern, tiny widths).
"""
from __future__ import annotations

import importlib
from typing import Dict

from ..models.config import ModelConfig

ARCH_IDS = (
    "phi4_mini_3p8b",
    "internlm2_20b",
    "gemma3_1b",
    "qwen1p5_4b",
    "musicgen_medium",
    "moonshot_v1_16b_a3b",
    "olmoe_1b_7b",
    "mamba2_2p7b",
    "internvl2_2b",
    "recurrentgemma_9b",
)

ALIASES = {
    "phi4-mini-3.8b": "phi4_mini_3p8b",
    "internlm2-20b": "internlm2_20b",
    "gemma3-1b": "gemma3_1b",
    "qwen1.5-4b": "qwen1p5_4b",
    "musicgen-medium": "musicgen_medium",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "mamba2-2.7b": "mamba2_2p7b",
    "internvl2-2b": "internvl2_2b",
    "recurrentgemma-9b": "recurrentgemma_9b",
}


def canonical(arch: str) -> str:
    return ALIASES.get(arch, arch.replace("-", "_").replace(".", "p"))


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f".{canonical(arch)}", __package__)
    return mod.CONFIG


def get_reduced(arch: str) -> ModelConfig:
    mod = importlib.import_module(f".{canonical(arch)}", __package__)
    return mod.reduced()


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}

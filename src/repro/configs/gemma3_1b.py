"""gemma3-1b [dense] — hf:google/gemma-3-1b-pt (unverified).

26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144 — 5:1 local:global.
Sliding window 512, tied embeddings, head_dim=256 (attn_dim != d_model).
"""
import dataclasses

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b", family="dense",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1,
    d_ff=6912, vocab_size=262144, head_dim=256,
    block_pattern=("local", "local", "local", "local", "local", "global"),
    local_window=512, rope_theta=1_000_000.0,
    mlp="swiglu", norm="rmsnorm", pos_emb="rope", tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="gemma3-smoke", n_layers=8, d_model=48, n_heads=2,
        n_kv_heads=1, d_ff=96, vocab_size=512, head_dim=16, local_window=16)

"""olmoe-1b-7b [moe] — arXiv:2409.02060 (hf).

16L d_model=2048 16H (MHA kv=16) per-expert d_ff=1024 vocab=50304,
MoE 64 experts top-8.
"""
import dataclasses

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1024, vocab_size=50304, head_dim=128,
    n_experts=64, top_k=8,
    block_pattern=("global",), mlp="swiglu", norm="rmsnorm", pos_emb="rope",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="olmoe-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=32, vocab_size=512, head_dim=16,
        n_experts=8, top_k=2)

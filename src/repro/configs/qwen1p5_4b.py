"""qwen1.5-4b [dense] — hf:Qwen/Qwen1.5 family (hf).

40L d_model=2560 20H (GQA kv=20, i.e. MHA) d_ff=6912 vocab=151936 — QKV bias.
"""
import dataclasses

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b", family="dense",
    n_layers=40, d_model=2560, n_heads=20, n_kv_heads=20,
    d_ff=6912, vocab_size=151936, head_dim=128, qkv_bias=True,
    block_pattern=("global",), mlp="swiglu", norm="rmsnorm", pos_emb="rope",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="qwen1.5-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab_size=512, head_dim=16)

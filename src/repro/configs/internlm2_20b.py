"""internlm2-20b [dense] — arXiv:2403.17297 (hf).

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92544 — GQA.
"""
import dataclasses

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-20b", family="dense",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab_size=92544, head_dim=128,
    block_pattern=("global",), mlp="swiglu", norm="rmsnorm", pos_emb="rope",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="internlm2-smoke", n_layers=2, d_model=64, n_heads=8,
        n_kv_heads=2, d_ff=192, vocab_size=512, head_dim=8)

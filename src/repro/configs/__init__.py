from .registry import (ARCH_IDS, ALIASES, all_configs, canonical,  # noqa
                       get_config, get_reduced)
from .shapes import input_specs  # noqa

"""musicgen-medium [audio] — arXiv:2306.05284 (hf).

48L d_model=1536 24H (MHA kv=24) d_ff=6144 vocab=2048 — decoder-only over
EnCodec tokens.  The EnCodec frontend is a STUB: ``input_specs()`` supplies
token ids in the 2048-entry codebook vocabulary directly (the transformer
backbone is what is specified).  LayerNorm + GELU + sinusoidal positions,
matching the MusicGen decoder.
"""
import dataclasses

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24,
    d_ff=6144, vocab_size=2048, head_dim=64,
    block_pattern=("global",), mlp="gelu", norm="layernorm",
    pos_emb="sinusoidal",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="musicgen-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab_size=256, head_dim=16)

"""recurrentgemma-9b [hybrid] — arXiv:2402.19427 (unverified).

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000 — RG-LRU + local
attention in a 2:1 (rglru, rglru, local) repeating pattern, window 2048.
"""
import dataclasses

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1,
    d_ff=12288, vocab_size=256000, head_dim=256,
    block_pattern=("rglru", "rglru", "local"),
    local_window=2048, lru_width=4096, conv_width=4,
    mlp="gelu", norm="rmsnorm", pos_emb="rope", tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="recurrentgemma-smoke", n_layers=5, d_model=64,
        n_heads=2, n_kv_heads=1, d_ff=128, vocab_size=512, head_dim=16,
        local_window=16, lru_width=64)

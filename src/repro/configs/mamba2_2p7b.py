"""mamba2-2.7b [ssm] — arXiv:2405.21060 (unverified).

64L d_model=2560 (attention-free) vocab=50280, ssm_state=128 — SSD
(state-space duality), expand=2 (d_inner=5120), head_dim=64 (80 heads).
"""
import dataclasses

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab_size=50280,
    block_pattern=("ssd",), norm="rmsnorm", pos_emb="none",
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256,
    conv_width=4,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="mamba2-smoke", n_layers=2, d_model=64,
        vocab_size=512, ssm_state=16, ssm_head_dim=16, ssm_chunk=8)

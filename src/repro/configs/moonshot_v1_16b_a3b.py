"""moonshot-v1-16b-a3b [moe] — hf:moonshotai/Moonlight-16B-A3B (hf).

48L d_model=2048 16H (MHA kv=16) per-expert d_ff=1408 vocab=163840,
MoE 64 experts top-6.
"""
import dataclasses

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab_size=163840, head_dim=128,
    n_experts=64, top_k=6,
    block_pattern=("global",), mlp="swiglu", norm="rmsnorm", pos_emb="rope",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="moonshot-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=32, vocab_size=512, head_dim=16,
        n_experts=8, top_k=2)

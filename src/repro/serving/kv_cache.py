"""Block-paged KV cache manager with vLLM-style greedy allocation.

Capacity is expressed in *tokens* (block-granular).  The engine sizes it
from real device memory minus weights minus adapter slots; the Digital Twin
sizes it from the fitted ``Mem_max`` estimator.  Allocation is greedy (one
token at a time during decode, the whole prompt at admission), so running
requests can exhaust memory and force preemption — exactly the vLLM
behaviour the paper analyses (Fig. 5's output-length effect).
"""
from __future__ import annotations

from typing import Dict, Optional


class PagedKVCache:
    def __init__(self, capacity_tokens: int, block_size: int = 16):
        self.block_size = block_size
        self.total_blocks = max(int(capacity_tokens) // block_size, 0)
        self.free_blocks = self.total_blocks
        self.table: Dict[int, int] = {}        # request uid -> #blocks held
        self.tokens: Dict[int, int] = {}       # request uid -> #tokens held

    # ------------------------------------------------------------------ #
    def blocks_needed(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)

    def can_allocate(self, n_tokens: int,
                     uid: Optional[int] = None) -> bool:
        """Whether ``n_tokens`` more tokens fit.  With ``uid``, the check
        mirrors ``allocate``'s delta charging: a requester with slack in
        its partially-filled last block needs fewer (possibly zero) new
        blocks, where the uid-blind form over-conservatively prices the
        tokens from an empty table."""
        if uid is None:
            return self.blocks_needed(n_tokens) <= self.free_blocks
        held_t = self.tokens.get(uid, 0)
        need = self.blocks_needed(held_t + n_tokens) - self.table.get(uid, 0)
        return need <= self.free_blocks

    def allocate(self, uid: int, n_tokens: int) -> bool:
        """Reserve blocks for `n_tokens` more tokens of request `uid`."""
        held_t = self.tokens.get(uid, 0)
        need = self.blocks_needed(held_t + n_tokens) - self.table.get(uid, 0)
        if need > self.free_blocks:
            return False
        self.free_blocks -= need
        self.table[uid] = self.table.get(uid, 0) + need
        self.tokens[uid] = held_t + n_tokens
        return True

    def free(self, uid: int) -> None:
        self.free_blocks += self.table.pop(uid, 0)
        self.tokens.pop(uid, None)

    # ------------------------------------------------------------------ #
    # raw block reservations (the shared-prefix cache's pool surface —
    # cache-owned blocks sit beside request tables in the same pool, so
    # they count toward used_fraction like any other KV)
    # ------------------------------------------------------------------ #
    def reserve_blocks(self, n_blocks: int) -> bool:
        if n_blocks > self.free_blocks:
            return False
        self.free_blocks -= n_blocks
        return True

    def release_blocks(self, n_blocks: int) -> None:
        self.free_blocks += n_blocks

    # ------------------------------------------------------------------ #
    @property
    def used_fraction(self) -> float:
        if self.total_blocks == 0:
            return 1.0
        return 1.0 - self.free_blocks / self.total_blocks

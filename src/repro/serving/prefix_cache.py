"""Cross-adapter shared-prefix KV cache (per-tenant system prompts).

Every adapter shares the base model, so the KV blocks of a prompt prefix
computed once are valid for *every* request that starts with the same
tokens — regardless of which LoRA adapter decorates the suffix (the
Activated-LoRA observation; S-LoRA's unified paging supplies the memory
pool).  ``SharedPrefixCache`` layers that reuse on the engine's block
pool:

* cache entries are keyed ``(base_model, prefix_id)`` — a ``prefix_id``
  names one shared system prompt (typically per tenant), carried by
  ``Request.prefix_id`` / ``Request.prefix_len``;
* an entry's blocks are **ref-counted**: every admitted request that
  reuses (or just computed) the prefix holds one reference until it
  finishes, is preempted, cancelled or drained — concurrent requests of
  *different adapters* share the same blocks;
* eviction is LRU over **zero-ref entries only**; blocks with live
  references are never reclaimed;
* on a **hit**, admission charges only the un-cached prompt suffix: the
  request allocates ``context_len + 1 - covered`` tokens of KV and the
  Eq. (1) prefill term drops by ``covered`` tokens (``StepPlan.
  prefill_covered``), so both prefill time and memory shrink;
* on a **miss**, the admitting request computes the full prompt; the
  prefix's blocks are inserted into the cache (owned by the cache, one
  reference held by the inserter) so the *next* request of any adapter
  hits.  When the pool is too tight to cache even after evicting idle
  entries, the request is served uncached (a counted miss, no insert).

The same class instance drives both the object-mode ``ServingEngine``
(over ``PagedKVCache``) and the struct-of-arrays ``FastEngine`` (over a
block-pool shim) — identical decisions by construction, which is what
keeps the legacy<->fast equivalence contract bitwise with the cache on.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass
class PrefixEntry:
    """One cached shared prefix: ``tokens`` of KV in ``blocks`` blocks."""
    tokens: int
    blocks: int
    refs: int
    seq: int                   # LRU clock (monotone; bumped on every use)


class SharedPrefixCache:
    """Paged, ref-counted shared-prefix cache over a block pool.

    ``pool`` needs the ``PagedKVCache`` block-accounting surface:
    ``blocks_needed(n_tokens)``, ``free_blocks``, ``reserve_blocks(n)``,
    ``release_blocks(n)``.  The cache never touches per-request tables —
    its blocks live beside them in the same pool, so cache occupancy
    shows up in ``used_fraction`` / ``max_kv_used`` like any other KV.
    """

    def __init__(self, pool, base_model: str = "base"):
        self.pool = pool
        self.base_model = base_model
        self.entries: Dict[Tuple[str, int], PrefixEntry] = {}
        self.holders: Dict[int, Tuple[str, int]] = {}  # holder id -> key
        self.n_hits = 0
        self.n_misses = 0
        self.n_inserts = 0
        self.n_evictions = 0
        self.tokens_saved = 0      # prefill tokens skipped via hits
        self._seq = 0

    # ------------------------------------------------------------------ #
    # admission planning (pure; no side effects)
    # ------------------------------------------------------------------ #
    def plan(self, prefix_id: int, prefix_len: int,
             prompt_len: int) -> Tuple[int, int]:
        """Plan one admission: returns ``(covered, insert_tokens)``.

        ``covered`` — cached prefix tokens this request can reuse (a hit
        when > 0); ``insert_tokens`` — prefix tokens a miss would insert.
        Exactly one of the two is nonzero (both zero for degenerate
        prefixes)."""
        pl = min(prefix_len, prompt_len)
        if pl <= 0:
            return 0, 0
        e = self.entries.get((self.base_model, prefix_id))
        if e is not None:
            return min(e.tokens, pl), 0
        return 0, pl

    def fit_blocks(self, covered: int, insert_tokens: int,
                   context_len: int) -> int:
        """Pool blocks an admission with this plan must find free.

        A miss-with-insert splits prefix and suffix into separate block
        runs (prefix blocks must be shareable), so it can round up one
        block more than the fused allocation would."""
        bn = self.pool.blocks_needed
        if insert_tokens:
            return bn(insert_tokens) + bn(context_len + 1 - insert_tokens)
        return bn(context_len + 1 - covered)

    # ------------------------------------------------------------------ #
    # admission commit / release
    # ------------------------------------------------------------------ #
    def commit(self, holder: int, prefix_id: int, covered: int,
               insert_tokens: int) -> None:
        """Record the admission the scheduler decided on: take a
        reference on a hit, insert-and-hold on a miss, or just count the
        miss when the pool was too tight to cache."""
        key = (self.base_model, prefix_id)
        if covered > 0:
            e = self.entries[key]
            e.refs += 1
            self._seq += 1
            e.seq = self._seq
            self.holders[holder] = key
            self.n_hits += 1
            self.tokens_saved += covered
            return
        self.n_misses += 1
        if insert_tokens > 0:
            blocks = self.pool.blocks_needed(insert_tokens)
            if not self.pool.reserve_blocks(blocks):
                raise RuntimeError(
                    "prefix insert without room: the admission gate must "
                    "check fit_blocks() before commit()")
            self._seq += 1
            self.entries[key] = PrefixEntry(
                tokens=insert_tokens, blocks=blocks, refs=1, seq=self._seq)
            self.holders[holder] = key
            self.n_inserts += 1

    def release(self, holder: int) -> None:
        """Drop ``holder``'s reference (finish / preempt / cancel /
        drain).  The entry stays cached at zero refs — evictable, warm."""
        key = self.holders.pop(holder, None)
        if key is None:
            return
        e = self.entries.get(key)
        if e is not None and e.refs > 0:
            e.refs -= 1

    # ------------------------------------------------------------------ #
    # eviction / teardown
    # ------------------------------------------------------------------ #
    def evict_idle_lru(self, exclude: Optional[int] = None) -> bool:
        """Free the least-recently-used zero-ref entry's blocks back to
        the pool.  ``exclude`` protects one prefix id (the entry an
        in-flight admission plans to reuse).  Returns True if an entry
        was evicted."""
        lru_key, lru_seq = None, None
        for key, e in self.entries.items():
            if e.refs > 0:
                continue
            if exclude is not None and key[1] == exclude:
                continue
            if lru_seq is None or e.seq < lru_seq:
                lru_key, lru_seq = key, e.seq
        if lru_key is None:
            return False
        e = self.entries.pop(lru_key)
        self.pool.release_blocks(e.blocks)
        self.n_evictions += 1
        return True

    def reset(self) -> None:
        """Drop every entry and counter (fresh stream / crash wipe of
        the GPU pool).  Blocks go back to the pool; held references are
        forgotten — callers tear down requests separately."""
        for e in self.entries.values():
            self.pool.release_blocks(e.blocks)
        self.entries.clear()
        self.holders.clear()
        self.n_hits = 0
        self.n_misses = 0
        self.n_inserts = 0
        self.n_evictions = 0
        self.tokens_saved = 0
        self._seq = 0

    def wipe(self) -> None:
        """Crash recovery: the GPU KV pool is gone — forget entries and
        holders, return blocks, but keep the lifetime counters (they are
        metrics, not state)."""
        for e in self.entries.values():
            self.pool.release_blocks(e.blocks)
        self.entries.clear()
        self.holders.clear()

    # ------------------------------------------------------------------ #
    @property
    def cached_blocks(self) -> int:
        return sum(e.blocks for e in self.entries.values())

    @property
    def cached_tokens(self) -> int:
        return sum(e.tokens for e in self.entries.values())

    @property
    def hit_rate(self) -> float:
        total = self.n_hits + self.n_misses
        return self.n_hits / total if total else 0.0

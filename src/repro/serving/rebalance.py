"""Online adapter rebalancing: EWMA load drift -> honest migrations.

S-LoRA-style multi-adapter serving makes adapter *residency* the dominant
cluster cost: once traffic drifts away from the distribution the router
saw when adapters first landed, the hot set can concentrate on one
replica while others idle.  ``RebalancePolicy`` watches the router's
per-(replica, adapter) routed-token counters through an EWMA, and when
the fleet's capacity-normalised load imbalance exceeds a threshold it
proposes migrating resident adapters from the most- to the least-loaded
replica.

Migrations are *honest*: each one carries the Fig. 4 adapter-load cost
(``load_cost_fn``, e.g. the fitted ``FittedEstimators.lat_load``), which
the online loop charges to the destination replica's clock, and the
policy declines any migration whose cost exceeds its expected benefit
(the tokens the adapter is forecast to route in the next
``gain_window_s``, converted to seconds through the destination's
observed service rate).  A cluster with a single live replica, balanced
load, or only net-negative candidates proposes nothing.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional


@dataclasses.dataclass(frozen=True)
class Migration:
    """Move ``adapter``'s residency from replica ``src`` to ``dst``,
    paying ``cost_s`` (the Fig. 4 load) on the destination."""
    adapter: int
    src: int
    dst: int
    cost_s: float


@dataclasses.dataclass
class RebalanceReport:
    n_proposed: int = 0
    n_committed: int = 0
    n_declined_cost: int = 0
    n_rounds_balanced: int = 0


class AdapterLoadTracker:
    """EWMA of per-(replica, adapter) routed token *rates* from the
    router's cumulative counters."""

    def __init__(self, n_replicas: int, alpha: float = 0.4):
        self.alpha = alpha
        self.rate: List[Dict[int, float]] = [{} for _ in range(n_replicas)]
        self._last: List[Dict[int, float]] = [{} for _ in range(n_replicas)]

    def update(self, routed_cum: List[Dict[int, float]],
               window_s: float) -> None:
        if window_s <= 0:
            return
        a = self.alpha
        for rep, cum in enumerate(routed_cum):
            last = self._last[rep]
            rates = self.rate[rep]
            for uid in set(cum) | set(rates):
                delta = cum.get(uid, 0.0) - last.get(uid, 0.0)
                inst = max(delta, 0.0) / window_s
                rates[uid] = a * inst + (1 - a) * rates.get(uid, 0.0)
            self._last[rep] = dict(cum)

    def move(self, adapter: int, src: int, dst: int) -> None:
        """Transfer an adapter's learned rate with its migration.

        The ``_last`` baselines are NOT touched: they mirror the
        router's per-replica cumulative counters, which a migration
        does not change — future routed tokens keep diffing correctly
        on both sides."""
        r = self.rate[src].pop(adapter, 0.0)
        self.rate[dst][adapter] = self.rate[dst].get(adapter, 0.0) + r

    def replica_rate(self, rep: int) -> float:
        return sum(self.rate[rep].values())


class RebalancePolicy:
    """Greedy donor->recipient adapter migration under an imbalance
    threshold, with a cost/benefit veto.

    Decision rule per round (deterministic):
      1. capacity-normalised EWMA load per live replica; if
         ``max <= threshold * mean`` the fleet is balanced -> no moves.
      2. donor = most loaded, recipient = least loaded eligible replica.
      3. candidate = the hottest adapter resident on the donor whose
         normalised rate fits inside half the donor-recipient gap (so the
         move cannot invert the imbalance).
      4. benefit = EWMA tokens/s * gain_window_s; cost = load_cost_fn
         seconds * recipient's observed tokens/s.  Decline when
         ``cost >= benefit`` (net-negative migration).
    """

    def __init__(self, router, load_cost_fn: Optional[
            Callable[[int], float]] = None,
            threshold: float = 1.25, alpha: float = 0.4,
            gain_window_s: Optional[float] = None,
            max_moves_per_round: int = 2,
            min_adapter_rate: float = 1e-6,
            min_backlog: int = 4, backlog_ratio: float = 2.0):
        self.router = router
        self.load_cost_fn = load_cost_fn or (lambda uid: 0.02)
        self.threshold = threshold
        self.gain_window_s = gain_window_s
        self.max_moves = max_moves_per_round
        self.min_adapter_rate = min_adapter_rate
        self.min_backlog = min_backlog
        self.backlog_ratio = backlog_ratio
        self.tracker = AdapterLoadTracker(router.n_replicas, alpha=alpha)
        self.report = RebalanceReport()
        # observed per-replica service rate (tokens/s EWMA) for the
        # cost->tokens conversion, and per-replica queue depth EWMA (the
        # heartbeat payload; smoothed so transient Poisson bursts don't
        # trigger migrations) — both fed by observe()
        self._service_rate: List[float] = [0.0] * router.n_replicas
        self._backlog: List[float] = [0.0] * router.n_replicas
        self._last_window_s = 0.0

    # ------------------------------------------------------------------ #
    def observe(self, now: float, window_s: float,
                served_tokens: Optional[List[float]] = None,
                backlog: Optional[List[int]] = None) -> None:
        """Ingest one epoch of router counters plus the heartbeat
        payload: the engines' served-token counts (service-rate EWMA)
        and queue depths (the suffering signal)."""
        self.tracker.update(self.router.routed_tokens, window_s)
        self._last_window_s = window_s
        if served_tokens is not None and window_s > 0:
            a = self.tracker.alpha
            for i, tok in enumerate(served_tokens):
                inst = max(tok, 0.0) / window_s
                self._service_rate[i] = \
                    a * inst + (1 - a) * self._service_rate[i]
        if backlog is not None:
            a = self.tracker.alpha
            self._backlog = [a * b + (1 - a) * prev
                             for b, prev in zip(backlog, self._backlog)]

    # ------------------------------------------------------------------ #
    def _norm(self, rep: int, rate: float) -> float:
        return rate / max(self.router.specs[rep].kv_capacity_tokens, 1)

    def propose(self, now: float) -> List[Migration]:
        r = self.router
        live = [i for i in r.live_replicas()]
        if len(live) < 2:
            return []
        gain_window = self.gain_window_s or max(self._last_window_s, 1e-9)
        # working copy of normalised per-replica load rates
        loads = {i: self._norm(i, self.tracker.replica_rate(i))
                 for i in live}
        moved: List[Migration] = []
        for _ in range(self.max_moves):
            mean = sum(loads.values()) / len(loads)
            donor = max(live, key=lambda i: (loads[i], -i))
            recips = [i for i in live if not r.straggler[i]] or live
            recip = min(recips, key=lambda i: (loads[i], i))
            if donor == recip or mean <= 0:
                break
            if loads[donor] <= self.threshold * mean:
                self.report.n_rounds_balanced += 1
                break
            # only act when the donor is actually suffering: migration is
            # pointless (and its load cost pure waste) while every queue
            # drains within the epoch
            if self._backlog[donor] < self.min_backlog or \
                    self._backlog[donor] < self.backlog_ratio * \
                    max(self._backlog[recip], 1):
                self.report.n_rounds_balanced += 1
                break
            gap = loads[donor] - loads[recip]
            mig = self._pick(donor, recip, gap, gain_window)
            if mig is None:
                break
            moved.append(mig)
            rate = self.tracker.rate[donor].get(mig.adapter, 0.0)
            loads[donor] -= self._norm(donor, rate)
            loads[recip] += self._norm(recip, rate)
        return moved

    def _pick(self, donor: int, recip: int, gap: float,
              gain_window: float) -> Optional[Migration]:
        r = self.router
        rates = self.tracker.rate[donor]
        # hottest first; only adapters the router believes resident on the
        # donor and not already resident on the recipient
        cands = sorted(
            (uid for uid in r.resident[donor]
             if uid not in r.resident[recip]
             and rates.get(uid, 0.0) > self.min_adapter_rate),
            key=lambda uid: (-rates.get(uid, 0.0), uid))
        for uid in cands:
            rate = rates.get(uid, 0.0)
            # no-inversion guard: the donor sheds norm(donor) while the
            # recipient gains norm(recip) (different on heterogeneous
            # fleets) — the move must not flip who is more loaded
            if self._norm(donor, rate) + self._norm(recip, rate) > gap:
                continue                      # would overshoot the gap
            self.report.n_proposed += 1
            cost_s = float(self.load_cost_fn(uid))
            benefit_tokens = rate * gain_window
            srv = self._service_rate[recip]
            if srv <= 0:
                vals = [v for v in self._service_rate if v > 0]
                srv = sum(vals) / len(vals) if vals else 0.0
            cost_tokens = cost_s * srv if srv > 0 \
                else (math.inf if cost_s > gain_window else 0.0)
            if cost_tokens >= benefit_tokens:
                self.report.n_declined_cost += 1
                continue                      # net-negative migration
            return Migration(adapter=uid, src=donor, dst=recip,
                             cost_s=cost_s)
        return None

    def commit(self, mig: Migration) -> None:
        """The online loop executed this migration; update the tracker."""
        self.tracker.move(mig.adapter, mig.src, mig.dst)
        self.report.n_committed += 1

"""Online adapter rebalancing: EWMA load drift -> honest migrations.

S-LoRA-style multi-adapter serving makes adapter *residency* the dominant
cluster cost: once traffic drifts away from the distribution the router
saw when adapters first landed, the hot set can concentrate on one
replica while others idle.  ``RebalancePolicy`` watches the router's
per-(replica, adapter) routed-token counters through an EWMA, and when
the fleet's capacity-normalised load imbalance exceeds a threshold it
proposes migrating resident adapters from the most- to the least-loaded
replica.

Migrations are *honest*: each one carries the Fig. 4 adapter-load cost
(``load_cost_fn``, e.g. the fitted ``FittedEstimators.lat_load``), which
the online loop charges to the destination replica's clock, and the
policy declines any migration whose cost exceeds its expected benefit
(the tokens the adapter is forecast to route in the next
``gain_window_s``, converted to seconds through the destination's
observed service rate).  A cluster with a single live replica, balanced
load, or only net-negative candidates proposes nothing.

The plan vocabulary goes beyond migration: when one adapter's EWMA rate
alone exceeds a per-replica share of the fleet's traffic, *no* migration
can relieve its home (S-LoRA / Punica both observe this), so the policy
may propose ``Replicate`` — serve the adapter from a second home, with
the router's weighted multi-home dispatch splitting its traffic — and a
decay-based ``Unreplicate`` collapses it back once the hotspot cools.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Union


@dataclasses.dataclass(frozen=True)
class Migration:
    """Move ``adapter``'s residency from replica ``src`` to ``dst``,
    paying ``cost_s`` (the Fig. 4 load) on the destination."""
    adapter: int
    src: int
    dst: int
    cost_s: float


@dataclasses.dataclass(frozen=True)
class Replicate:
    """Serve ``adapter`` from ``dst`` *in addition to* ``src`` (the
    router's multi-home dispatch then splits its traffic), paying
    ``cost_s`` (the Fig. 4 load) on the destination."""
    adapter: int
    src: int
    dst: int
    cost_s: float


@dataclasses.dataclass(frozen=True)
class Unreplicate:
    """Collapse ``adapter`` back to single-home by dropping the home on
    ``rep`` (free: eviction costs nothing)."""
    adapter: int
    rep: int
    cost_s: float = 0.0


PlanAction = Union[Migration, Replicate, Unreplicate]


@dataclasses.dataclass
class RebalanceReport:
    n_proposed: int = 0
    n_committed: int = 0
    n_declined_cost: int = 0
    n_rounds_balanced: int = 0
    n_replications: int = 0
    n_unreplications: int = 0


class AdapterLoadTracker:
    """EWMA of per-(replica, adapter) routed token *rates* from the
    router's cumulative counters."""

    def __init__(self, n_replicas: int, alpha: float = 0.4):
        self.alpha = alpha
        self.rate: List[Dict[int, float]] = [{} for _ in range(n_replicas)]
        self._last: List[Dict[int, float]] = [{} for _ in range(n_replicas)]

    def update(self, routed_cum: List[Dict[int, float]],
               window_s: float) -> None:
        if window_s <= 0:
            return
        a = self.alpha
        for rep, cum in enumerate(routed_cum):
            last = self._last[rep]
            rates = self.rate[rep]
            for uid in set(cum) | set(rates):
                delta = cum.get(uid, 0.0) - last.get(uid, 0.0)
                inst = max(delta, 0.0) / window_s
                if uid in rates:
                    rates[uid] = a * inst + (1 - a) * rates[uid]
                elif inst > 0.0:
                    # cold-start seed: a first observation IS the best
                    # estimate.  Blending it toward the zero init would
                    # underestimate a freshly migrated/replicated
                    # adapter's load for several windows and let the
                    # rebalancer bounce it right back.
                    rates[uid] = inst
            self._last[rep] = dict(cum)

    def move(self, adapter: int, src: int, dst: int) -> None:
        """Transfer an adapter's learned rate with its migration.

        The ``_last`` baselines are NOT touched: they mirror the
        router's per-replica cumulative counters, which a migration
        does not change — future routed tokens keep diffing correctly
        on both sides."""
        r = self.rate[src].pop(adapter, 0.0)
        self.rate[dst][adapter] = self.rate[dst].get(adapter, 0.0) + r

    def replica_rate(self, rep: int) -> float:
        return sum(self.rate[rep].values())

    def adapter_rate(self, adapter: int) -> float:
        """Fleet-wide EWMA rate of one adapter (all homes summed)."""
        return sum(r.get(adapter, 0.0) for r in self.rate)


class RebalancePolicy:
    """Greedy donor->recipient adapter migration under an imbalance
    threshold, with a cost/benefit veto.

    Decision rule per round (deterministic):
      1. capacity-normalised EWMA load per live replica; if
         ``max <= threshold * mean`` the fleet is balanced -> no moves.
      2. donor = most loaded, recipient = least loaded eligible replica.
      3. candidate = the hottest adapter resident on the donor whose
         normalised rate fits inside half the donor-recipient gap (so the
         move cannot invert the imbalance).
      4. benefit = EWMA tokens/s * gain_window_s; cost = load_cost_fn
         seconds * recipient's observed tokens/s.  Decline when
         ``cost >= benefit`` (net-negative migration).

    ``replicate=True`` additionally arms the hot-adapter replication
    trigger: an adapter whose fleet-wide EWMA rate exceeds
    ``replicate_factor`` x the per-replica traffic share (total fleet
    rate / live replicas) while its home queue suffers cannot be helped
    by migration (moving it just moves the hotspot) — it gets a second
    home instead.  A replicated adapter whose rate decays below
    ``unreplicate_factor`` x that share for ``unreplicate_patience``
    consecutive rounds collapses back to single-home.
    """

    def __init__(self, router, load_cost_fn: Optional[
            Callable[[int], float]] = None,
            threshold: float = 1.25, alpha: float = 0.4,
            gain_window_s: Optional[float] = None,
            max_moves_per_round: int = 2,
            min_adapter_rate: float = 1e-6,
            min_backlog: int = 4, backlog_ratio: float = 2.0,
            replicate: bool = False, replicate_factor: float = 1.0,
            unreplicate_factor: float = 0.5,
            unreplicate_patience: int = 2):
        self.router = router
        self.load_cost_fn = load_cost_fn or (lambda uid: 0.02)
        self.threshold = threshold
        self.gain_window_s = gain_window_s
        self.max_moves = max_moves_per_round
        self.min_adapter_rate = min_adapter_rate
        self.min_backlog = min_backlog
        self.backlog_ratio = backlog_ratio
        self.replicate = replicate
        self.replicate_factor = replicate_factor
        self.unreplicate_factor = unreplicate_factor
        self.unreplicate_patience = unreplicate_patience
        # adapter uid -> consecutive cold rounds (unreplicate decay)
        self._cold_rounds: Dict[int, int] = {}
        self.tracker = AdapterLoadTracker(router.n_replicas, alpha=alpha)
        self.report = RebalanceReport()
        # observed per-replica service rate (tokens/s EWMA) for the
        # cost->tokens conversion, and per-replica queue depth EWMA (the
        # heartbeat payload; smoothed so transient Poisson bursts don't
        # trigger migrations) — both fed by observe()
        self._service_rate: List[float] = [0.0] * router.n_replicas
        self._backlog: List[float] = [0.0] * router.n_replicas
        self._last_window_s = 0.0

    # ------------------------------------------------------------------ #
    def observe(self, now: float, window_s: float,
                served_tokens: Optional[List[float]] = None,
                backlog: Optional[List[int]] = None) -> None:
        """Ingest one epoch of router counters plus the heartbeat
        payload: the engines' served-token counts (service-rate EWMA)
        and queue depths (the suffering signal)."""
        self.tracker.update(self.router.routed_tokens, window_s)
        self._last_window_s = window_s
        if served_tokens is not None and window_s > 0:
            a = self.tracker.alpha
            for i, tok in enumerate(served_tokens):
                inst = max(tok, 0.0) / window_s
                self._service_rate[i] = \
                    a * inst + (1 - a) * self._service_rate[i]
        if backlog is not None:
            a = self.tracker.alpha
            self._backlog = [a * b + (1 - a) * prev
                             for b, prev in zip(backlog, self._backlog)]

    # ------------------------------------------------------------------ #
    def _norm(self, rep: int, rate: float) -> float:
        return rate / max(self.router.specs[rep].kv_capacity_tokens, 1)

    def propose(self, now: float) -> List[PlanAction]:
        actions: List[PlanAction] = []
        if self.replicate:
            actions.extend(self._propose_replication(now))
        # an adapter with a Replicate pending this round must not also be
        # migrated: the migration's _drop_home would dissolve the brand-new
        # multi-home registration right after the loop executes it
        skip = frozenset(a.adapter for a in actions
                         if isinstance(a, Replicate))
        actions.extend(self._propose_migrations(now, skip=skip))
        return actions

    def _propose_migrations(self, now: float,
                            skip: frozenset = frozenset()
                            ) -> List[Migration]:
        r = self.router
        live = [i for i in r.live_replicas()]
        if len(live) < 2:
            return []
        gain_window = self.gain_window_s or max(self._last_window_s, 1e-9)
        # working copy of normalised per-replica load rates
        loads = {i: self._norm(i, self.tracker.replica_rate(i))
                 for i in live}
        moved: List[Migration] = []
        for _ in range(self.max_moves):
            mean = sum(loads.values()) / len(loads)
            donor = max(live, key=lambda i: (loads[i], -i))
            recips = [i for i in live if not r.straggler[i]] or live
            recip = min(recips, key=lambda i: (loads[i], i))
            if donor == recip or mean <= 0:
                break
            if loads[donor] <= self.threshold * mean:
                self.report.n_rounds_balanced += 1
                break
            # only act when the donor is actually suffering: migration is
            # pointless (and its load cost pure waste) while every queue
            # drains within the epoch
            if self._backlog[donor] < self.min_backlog or \
                    self._backlog[donor] < self.backlog_ratio * \
                    max(self._backlog[recip], 1):
                self.report.n_rounds_balanced += 1
                break
            gap = loads[donor] - loads[recip]
            mig = self._pick(donor, recip, gap, gain_window, skip=skip)
            if mig is None:
                break
            moved.append(mig)
            rate = self.tracker.rate[donor].get(mig.adapter, 0.0)
            loads[donor] -= self._norm(donor, rate)
            loads[recip] += self._norm(recip, rate)
        return moved

    def _pick(self, donor: int, recip: int, gap: float,
              gain_window: float,
              skip: frozenset = frozenset()) -> Optional[Migration]:
        r = self.router
        rates = self.tracker.rate[donor]
        # hottest first; only adapters the router believes resident on the
        # donor and not already resident on the recipient
        cands = sorted(
            (uid for uid in r.resident[donor]
             if uid not in r.resident[recip]
             and uid not in r.replicated    # multi-home: split, not moved
             and uid not in skip            # Replicate pending this round
             and rates.get(uid, 0.0) > self.min_adapter_rate),
            key=lambda uid: (-rates.get(uid, 0.0), uid))
        for uid in cands:
            rate = rates.get(uid, 0.0)
            # no-inversion guard: the donor sheds norm(donor) while the
            # recipient gains norm(recip) (different on heterogeneous
            # fleets) — the move must not flip who is more loaded
            if self._norm(donor, rate) + self._norm(recip, rate) > gap:
                continue                      # would overshoot the gap
            self.report.n_proposed += 1
            cost_s = float(self.load_cost_fn(uid))
            benefit_tokens = rate * gain_window
            if self._cost_tokens(cost_s, recip, gain_window) \
                    >= benefit_tokens:
                self.report.n_declined_cost += 1
                continue                      # net-negative migration
            return Migration(adapter=uid, src=donor, dst=recip,
                             cost_s=cost_s)
        return None

    def _cost_tokens(self, cost_s: float, dst: int,
                     gain_window: float) -> float:
        """Convert a Fig. 4 load cost (seconds) into tokens through the
        destination's observed service rate (fleet mean fallback)."""
        srv = self._service_rate[dst]
        if srv <= 0:
            vals = [v for v in self._service_rate if v > 0]
            srv = sum(vals) / len(vals) if vals else 0.0
        if srv > 0:
            return cost_s * srv
        return math.inf if cost_s > gain_window else 0.0

    # ------------------------------------------------------------------ #
    # hot-adapter replication (one adapter too hot for any single home)
    # ------------------------------------------------------------------ #
    def _propose_replication(self, now: float) -> List[PlanAction]:
        r = self.router
        live = r.live_replicas()
        out: List[PlanAction] = []
        total = sum(self.tracker.replica_rate(i) for i in live)
        if not live or total <= 0:
            return out
        share = total / len(live)
        gain_window = self.gain_window_s or max(self._last_window_s, 1e-9)

        # decay-based unreplicate first (frees a slot before replicating)
        for uid in sorted(r.replicated):
            homes = [h for h in sorted(r.replicated[uid]) if r.alive[h]]
            if len(homes) < 2:
                continue
            if self.tracker.adapter_rate(uid) \
                    < self.unreplicate_factor * share:
                c = self._cold_rounds.get(uid, 0) + 1
                self._cold_rounds[uid] = c
                if c >= self.unreplicate_patience:
                    # drop the colder home (deterministic tie-break);
                    # the counter is cleared in commit(), not here — a
                    # failed engine evict (adapter pinned at the epoch
                    # boundary) must retry next round, not restart the
                    # whole decay clock
                    drop = min(homes, key=lambda h: (
                        self.tracker.rate[h].get(uid, 0.0), -h))
                    self.report.n_proposed += 1
                    out.append(Unreplicate(adapter=uid, rep=drop))
            else:
                self._cold_rounds.pop(uid, None)

        if len(live) < 2:
            return out
        # hottest single-home adapter past the per-replica share whose
        # home is actually suffering gets a second home
        cands = sorted(
            ((self.tracker.adapter_rate(uid), uid)
             for uid in {u for rep in live for u in r.resident[rep]}
             if uid not in r.replicated),
            key=lambda t: (-t[0], t[1]))
        for rate, uid in cands:
            if rate <= self.replicate_factor * share:
                break                          # sorted: nothing hotter left
            homes = r.homes(uid)
            if len(homes) != 1:
                continue
            home = homes[0]
            if self._backlog[home] < self.min_backlog:
                continue                       # hot but not suffering
            others = [i for i in live
                      if i != home and not r.straggler[i]] or \
                     [i for i in live if i != home]
            if not others:
                continue
            dst = min(others, key=lambda i: (
                self._norm(i, self.tracker.replica_rate(i)), i))
            self.report.n_proposed += 1
            cost_s = float(self.load_cost_fn(uid))
            # the second home absorbs about half the adapter's traffic
            benefit_tokens = 0.5 * rate * gain_window
            if self._cost_tokens(cost_s, dst, gain_window) \
                    >= benefit_tokens:
                self.report.n_declined_cost += 1
                continue
            out.append(Replicate(adapter=uid, src=home, dst=dst,
                                 cost_s=cost_s))
            break                              # at most one new home/round
        return out

    def commit(self, act: PlanAction) -> None:
        """The online loop executed this plan action; update the tracker.

        ``n_committed`` counts every executed plan action (the invariant
        ``n_proposed ~ n_committed + n_declined_cost`` holds with
        replication armed); ``n_replications``/``n_unreplications`` are
        the per-type breakdowns."""
        self.report.n_committed += 1
        if isinstance(act, Replicate):
            # the new home has no routed history yet; the tracker's
            # cold-start seeding picks up its traffic split next window.
            # A decay counter left over from a previous multi-home spell
            # (dissolved by failure/migration) must not shortchange this
            # fresh replication's patience window.
            self._cold_rounds.pop(act.adapter, None)
            self.report.n_replications += 1
        elif isinstance(act, Unreplicate):
            # fold the dropped home's learned rate into the survivor
            rate = self.tracker.rate[act.rep].pop(act.adapter, 0.0)
            left = [h for h in self.router.homes(act.adapter)
                    if h != act.rep]
            if left:
                dst = self.tracker.rate[left[0]]
                dst[act.adapter] = dst.get(act.adapter, 0.0) + rate
            self._cold_rounds.pop(act.adapter, None)
            self.report.n_unreplications += 1
        else:
            self.tracker.move(act.adapter, act.src, act.dst)

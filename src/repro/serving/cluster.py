"""Multi-replica adapter-affinity serving cluster.

Scales the single-engine system to a fleet: a ``ClusterRouter`` fronts N
``ServingEngine`` replicas (heterogeneous ``adapter_slots`` /
``kv_capacity_tokens`` per replica) and routes each request with a
pluggable policy:

  * ``affinity``     — prefer replicas that already hold the request's
                       adapter (minimising cold CPU->GPU adapter loads,
                       the Fig. 4 cost), falling back to least-loaded,
                       and spilling away from overloaded replicas;
  * ``least-loaded`` — pick the replica with the lowest capacity-
                       normalised assigned work (heterogeneity-aware);
  * ``round-robin``  — cycle replicas (the affinity-blind baseline).

The router keeps a per-replica model of resident adapters (an LRU capped
at the replica's slot count — mirroring ``AdapterSlotCache`` semantics)
and of assigned work (prompt+output tokens, normalised by the replica's
KV capacity so a half-size replica receives half the load).  It also
tracks per-replica liveness (heartbeats), straggler flags, and
per-adapter routed-token counters — the inputs of the online rebalancer
(``repro.serving.rebalance``).

``ServingCluster`` runs the routed partitions through real engines.
``ServingCluster.run`` is the one-shot offline path (route everything,
then serve); ``ServingCluster.run_online`` is the epoch-driven living
system: requests are routed as they arrive, replicas heartbeat each
epoch, a dead or straggling replica is drained and its requests
re-served by survivors, and an optional ``RebalancePolicy`` migrates
resident adapters between replicas when load drifts.
``repro.core.cluster_twin.ClusterDigitalTwin`` runs the *same router and
loop* over estimator-backed engines so cluster-level placement can be
labelled offline exactly as the paper does for one GPU.

The epoch loop is one of two front-ends over the engines' resumable
surface — the other is the open-loop async gateway
(``repro.serving.gateway``), which admits live arrivals one by one and
streams tokens instead of serving pre-generated windows.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple, Type, Union

from .engine import EngineConfig, ServingEngine
from .faults import (CircuitBreaker, FaultPlan, FaultStats,
                     NoAliveReplicasError, ReliabilityPolicy)
from .metrics import ServingMetrics, ttft_percentiles
from .rebalance import Replicate, Unreplicate
from .request import Request


# --------------------------------------------------------------------------- #
# replica description
# --------------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class ReplicaSpec:
    """Static description of one serving replica (one GPU/node)."""
    adapter_slots: int
    kv_capacity_tokens: int
    max_running: int = 256
    block_size: int = 16
    # admission/preemption policy of this replica's engine
    # (repro.serving.policy registry)
    sched_policy: str = "fcfs"
    # cross-adapter shared-prefix KV cache (repro.serving.prefix_cache)
    prefix_cache: bool = False

    def engine_config(self) -> EngineConfig:
        return EngineConfig(
            kv_capacity_tokens=self.kv_capacity_tokens,
            adapter_slots=self.adapter_slots,
            max_running=self.max_running,
            block_size=self.block_size,
            sched_policy=self.sched_policy,
            prefix_cache=self.prefix_cache)


# ``EngineConfig`` knobs with deliberately no ``ReplicaSpec`` mirror
# (the config-threading lint rules in ``repro.analysis`` read this
# tuple): ``max_steps`` is an internal runaway-loop bound, and
# ``dynamic_slots``/``adapter_kv_tokens`` are the single-engine S-LoRA
# memory-pool mode that cluster replicas do not expose.
NON_REPLICA_FIELDS = ("max_steps", "dynamic_slots", "adapter_kv_tokens")


def make_replica_specs(
        n: int, adapter_slots: Union[int, Sequence[int]],
        kv_capacity_tokens: Union[int, Sequence[int]],
        max_running: int = 256,
        block_size: int = 16,
        sched_policy: str = "fcfs",
        prefix_cache: bool = False) -> List[ReplicaSpec]:
    """Uniform or heterogeneous specs from scalars / per-replica lists."""
    def expand(v, name):
        vs = [v] * n if isinstance(v, int) else list(v)
        if len(vs) != n:
            raise ValueError(f"{name}: expected {n} values, got {len(vs)}")
        return vs
    slots = expand(adapter_slots, "adapter_slots")
    kvs = expand(kv_capacity_tokens, "kv_capacity_tokens")
    return [ReplicaSpec(adapter_slots=s, kv_capacity_tokens=k,
                        max_running=max_running, block_size=block_size,
                        sched_policy=sched_policy,
                        prefix_cache=prefix_cache)
            for s, k in zip(slots, kvs)]


# --------------------------------------------------------------------------- #
# routing policies (pluggable)
# --------------------------------------------------------------------------- #

POLICIES: Dict[str, Type["RoutingPolicy"]] = {}


def register_policy(cls: Type["RoutingPolicy"]) -> Type["RoutingPolicy"]:
    POLICIES[cls.name] = cls
    return cls


class RoutingPolicy:
    """Chooses a replica index for each incoming request."""
    name = ""

    def __init__(self, router: "ClusterRouter"):
        self.router = router

    def reset(self) -> None:
        pass

    def choose(self, req: Request) -> int:
        raise NotImplementedError


@register_policy
class RoundRobinPolicy(RoutingPolicy):
    name = "round-robin"

    def __init__(self, router: "ClusterRouter"):
        super().__init__(router)
        self._next = 0

    def reset(self) -> None:
        self._next = 0

    def choose(self, req: Request) -> int:
        live = self.router.eligible()
        rep = live[self._next % len(live)]
        self._next += 1
        return rep


@register_policy
class LeastLoadedPolicy(RoutingPolicy):
    name = "least-loaded"

    def choose(self, req: Request) -> int:
        return self.router.least_loaded()


@register_policy
class AffinityPolicy(RoutingPolicy):
    """Adapter affinity with overload spill.

    Route to the least-loaded replica already holding the adapter unless
    its normalised load exceeds ``overload_factor`` x the fleet minimum
    plus ``slack`` (absolute headroom, in fractions of KV capacity) — in
    which case fall back to the least-loaded replica.
    """
    name = "affinity"

    def __init__(self, router: "ClusterRouter",
                 overload_factor: float = 1.5, slack: float = 0.1):
        super().__init__(router)
        self.overload_factor = overload_factor
        self.slack = slack

    def choose(self, req: Request) -> int:
        r = self.router
        # stragglers stay eligible for adapters they already hold (warm
        # routing is mitigation without migration); dead replicas never are
        holders = [i for i in range(r.n_replicas)
                   if r.alive[i] and not r.breaker_blocked(i)
                   and req.adapter in r.resident[i]]
        if holders:
            rep = min(holders, key=lambda i: (r.load(i), i))
            floor = r.load(r.least_loaded())
            if r.load(rep) <= self.overload_factor * floor + self.slack:
                return rep
        return r.least_loaded()


@register_policy
class PrefixAffinityPolicy(AffinityPolicy):
    """Shared-prefix affinity with adapter-affinity fallback.

    A request carrying a shared prefix prefers the least-loaded replica
    whose prefix cache the router believes holds that prefix warm —
    re-hitting a resident prefix skips its whole prefill, a bigger win
    than adapter residency (prompt tokens vs a Fig. 4 load).  The same
    overload spill as :class:`AffinityPolicy` guards against piling a
    hot tenant onto one replica.  Requests without a prefix, and
    prefix-cold ones, fall back to plain adapter affinity.
    """
    name = "prefix-affinity"

    def choose(self, req: Request) -> int:
        r = self.router
        if req.prefix_id is not None:
            holders = [i for i in range(r.n_replicas)
                       if r.alive[i] and not r.breaker_blocked(i)
                       and req.prefix_id in r.prefix_resident[i]]
            if holders:
                rep = min(holders, key=lambda i: (r.load(i), i))
                floor = r.load(r.least_loaded())
                if r.load(rep) <= self.overload_factor * floor + self.slack:
                    return rep
        return super().choose(req)


# --------------------------------------------------------------------------- #
# router
# --------------------------------------------------------------------------- #

class ClusterRouter:
    """Routes requests across replicas; tracks residency + assigned load.

    The residency model is an LRU over adapter uids capped at each
    replica's ``adapter_slots`` — the router's belief of what the
    replica's ``AdapterSlotCache`` holds.  Assigned load is cumulative
    prompt+output tokens normalised by KV capacity, so heterogeneous
    replicas are compared on relative utilisation.

    Liveness: ``alive``/``straggler`` flags gate policy choices (dead
    replicas are never routable; stragglers receive no *new* adapters but
    keep serving ones they already hold).  ``heartbeat``/``dead_replicas``
    implement the online loop's failure detector; ``migrate`` moves a
    residency entry between replicas on behalf of the rebalancer.
    """

    def __init__(self, specs: Sequence[ReplicaSpec],
                 policy: Union[str, RoutingPolicy] = "affinity",
                 **policy_kwargs):
        self.specs = list(specs)
        if not self.specs:
            raise ValueError("need at least one replica spec")
        if isinstance(policy, str):
            if policy not in POLICIES:
                raise ValueError(
                    f"unknown policy {policy!r}; have {sorted(POLICIES)}")
            self.policy: RoutingPolicy = POLICIES[policy](
                self, **policy_kwargs)
        else:
            self.policy = policy
        self.reset()

    # ------------------------------------------------------------------ #
    def reset(self) -> None:
        n = self.n_replicas
        # adapter uid -> last-touch sequence number, per replica
        self.resident: List[Dict[int, int]] = [{} for _ in range(n)]
        self.assigned_tokens = [0.0] * n
        self.assigned_requests = [0] * n
        # adapter uid -> cumulative routed tokens, per replica (rebalancer)
        self.routed_tokens: List[Dict[int, float]] = [{} for _ in range(n)]
        self.assignments: Dict[int, int] = {}     # request uid -> replica
        # adapter uid -> its home replicas, for adapters served from more
        # than one replica (hot-adapter replication); the affinity
        # policy's least-loaded-holder rule dispatches a multi-home
        # adapter's requests across its homes weighted by each home's
        # capacity-normalised load
        self.replicated: Dict[int, set] = {}
        # shared-prefix residency belief: prefix id -> last-touch seq,
        # per replica (the replica engine's prefix cache keeps a prefix
        # warm after its first carrier; routing the next carrier back
        # turns that into a hit)
        self.prefix_resident: List[Dict[int, int]] = [{} for _ in range(n)]
        self.n_prefix_cold_routes = 0  # carrier routed to prefix-cold replica
        self.n_cold_routes = 0    # routed to a replica not holding adapter
        self.n_migrations = 0
        self.n_replications = 0
        self.n_unreplications = 0
        self.alive: List[bool] = [True] * n
        self.straggler: List[bool] = [False] * n
        self.last_heartbeat: List[float] = [0.0] * n
        # per-replica circuit breakers, next to the straggler flag: a
        # replica accumulating failures (timeouts, refused adapter
        # loads) is cut out of routing until its cooldown probe passes
        self.breakers: List[CircuitBreaker] = [CircuitBreaker()
                                               for _ in range(n)]
        self._seq = 0
        self.policy.reset()

    @property
    def n_replicas(self) -> int:
        return len(self.specs)

    def live_replicas(self) -> List[int]:
        return [i for i in range(self.n_replicas) if self.alive[i]]

    def eligible(self) -> List[int]:
        """Replicas new adapters may be routed to: alive and, when at
        least one unimpaired replica is alive, neither straggling nor
        circuit-broken.  Raises :class:`NoAliveReplicasError` when the
        fleet has no alive replica at all — callers (gateway, cluster)
        translate that to a 503."""
        live = self.live_replicas()
        if not live:
            raise NoAliveReplicasError("no alive replicas")
        fast = [i for i in live if not self.straggler[i]
                and not self.breakers[i].blocked]
        return fast or live

    def load(self, rep: int) -> float:
        """Capacity-normalised cumulative assigned work."""
        return self.assigned_tokens[rep] / max(
            self.specs[rep].kv_capacity_tokens, 1)

    def least_loaded(self) -> int:
        return min(self.eligible(), key=lambda i: (self.load(i), i))

    # ------------------------------------------------------------------ #
    # liveness / failure detection
    # ------------------------------------------------------------------ #
    def heartbeat(self, rep: int, now: float) -> None:
        self.last_heartbeat[rep] = max(self.last_heartbeat[rep], now)

    def dead_replicas(self, now: float, timeout: float) -> List[int]:
        """Alive replicas whose last heartbeat is older than ``timeout``."""
        return [i for i in self.live_replicas()
                if now - self.last_heartbeat[i] > timeout]

    def mark_dead(self, rep: int) -> List[int]:
        """Drain a replica from the routing tables; returns the adapters
        the router believed resident there (for re-warming elsewhere).
        A replicated adapter that loses this home degrades cleanly to
        single-home on its surviving peer."""
        self.alive[rep] = False
        orphaned = sorted(self.resident[rep])
        self.resident[rep] = {}
        # its prefix cache dies with it (and restore() wipes it), so the
        # belief is cleared rather than re-seeded on revive
        self.prefix_resident[rep] = {}
        for a in orphaned:
            self._drop_home(a, rep)
        if not any(self.alive):
            raise NoAliveReplicasError("all replicas dead")
        return orphaned

    def mark_straggler(self, rep: int, flag: bool = True) -> None:
        self.straggler[rep] = flag

    # ------------------------------------------------------------------ #
    # circuit breaker + crash recovery
    # ------------------------------------------------------------------ #
    def breaker_blocked(self, rep: int) -> bool:
        return self.breakers[rep].blocked

    def record_failure(self, rep: int, now: float) -> None:
        self.breakers[rep].record_failure(now)

    def record_success(self, rep: int) -> None:
        self.breakers[rep].record_success()

    def revive(self, rep: int, adapters: Sequence[int], now: float) -> None:
        """Rejoin a recovered replica: alive again, fresh heartbeat,
        breaker reset, and residency beliefs re-seeded from the adapter
        set its engine actually restored."""
        self.alive[rep] = True
        self.straggler[rep] = False
        self.last_heartbeat[rep] = max(self.last_heartbeat[rep], now)
        self.breakers[rep].reset()
        for a in adapters:
            self._admit_resident(a, rep)

    # ------------------------------------------------------------------ #
    # residency plumbing (shared by routing, migration and replication)
    # ------------------------------------------------------------------ #
    def _drop_home(self, adapter: int, rep: int) -> None:
        """Forget one home of a replicated adapter; a single survivor
        means the adapter is simply resident there (no longer special)."""
        homes = self.replicated.get(adapter)
        if homes is None:
            return
        homes.discard(rep)
        if len(homes) < 2:
            del self.replicated[adapter]

    def _evict_lru(self, rep: int) -> None:
        """Evict the LRU residency belief, sparing replicated homes: the
        rebalancer multi-homed those deliberately, and letting routing
        churn silently collapse them would undo its plan.  Only when
        every entry is a replicated home does the plain LRU fall back
        (and the dropped one degrades to single-home)."""
        res = self.resident[rep]
        spared = [a for a in res
                  if a not in self.replicated
                  or rep not in self.replicated[a]]
        lru = min(spared or res, key=res.get)
        del res[lru]
        self._drop_home(lru, rep)

    def _admit_resident(self, adapter: int, rep: int) -> None:
        self._seq += 1
        res = self.resident[rep]
        slots = self.specs[rep].adapter_slots
        if adapter not in res and slots > 0 and len(res) >= slots:
            self._evict_lru(rep)
        res[adapter] = self._seq

    def homes(self, adapter: int) -> List[int]:
        """Alive replicas currently believed to hold ``adapter``."""
        return [i for i in range(self.n_replicas)
                if self.alive[i] and adapter in self.resident[i]]

    def prefix_homes(self, prefix_id: int) -> List[int]:
        """Alive replicas believed to hold ``prefix_id`` warm."""
        return [i for i in range(self.n_replicas)
                if self.alive[i] and prefix_id in self.prefix_resident[i]]

    def warm(self, adapter: int, rep: int) -> None:
        """Seed a residency belief (plan-level initial placement) —
        neither a cold route nor a migration."""
        self._admit_resident(adapter, rep)

    # ------------------------------------------------------------------ #
    # migration / replication (rebalancer side-channel)
    # ------------------------------------------------------------------ #
    def migrate(self, adapter: int, src: int, dst: int) -> None:
        """Move an adapter's believed residency from ``src`` to ``dst``."""
        self.resident[src].pop(adapter, None)
        self._drop_home(adapter, src)
        self._admit_resident(adapter, dst)
        self.n_migrations += 1

    def replicate(self, adapter: int, src: int, dst: int) -> None:
        """Give ``adapter`` a second home on ``dst`` (``src`` keeps
        serving it); routing splits its traffic across the homes."""
        self._admit_resident(adapter, dst)
        homes = self.replicated.setdefault(adapter, set())
        homes.update((src, dst))
        self.n_replications += 1

    def unreplicate(self, adapter: int, rep: int) -> None:
        """Drop one home of a replicated adapter (back to single-home)."""
        self.resident[rep].pop(adapter, None)
        self._drop_home(adapter, rep)
        self.n_unreplications += 1

    # ------------------------------------------------------------------ #
    def route(self, req: Request) -> int:
        rep = self.policy.choose(req)
        if not 0 <= rep < self.n_replicas:
            raise ValueError(f"policy chose invalid replica {rep}")
        if not self.alive[rep]:
            raise ValueError(f"policy chose dead replica {rep}")
        self._commit(rep, req)
        return rep

    def _commit(self, rep: int, req: Request) -> None:
        if req.adapter not in self.resident[rep]:
            self.n_cold_routes += 1
        self._admit_resident(req.adapter, rep)
        if req.prefix_id is not None and req.prefix_len > 0:
            pres = self.prefix_resident[rep]
            if req.prefix_id not in pres:
                self.n_prefix_cold_routes += 1
            self._seq += 1
            pres[req.prefix_id] = self._seq
        tokens = req.prompt_len + req.output_len
        self.assigned_tokens[rep] += tokens
        self.assigned_requests[rep] += 1
        rt = self.routed_tokens[rep]
        rt[req.adapter] = rt.get(req.adapter, 0.0) + tokens
        self.assignments[req.uid] = rep

    def partition(self, requests: Sequence[Request]) -> List[List[Request]]:
        """Route a full stream (in arrival order) into per-replica lists."""
        parts: List[List[Request]] = [[] for _ in range(self.n_replicas)]
        for req in sorted(requests, key=lambda r: r.arrival):
            parts[self.route(req)].append(req)
        return parts

    def summary(self) -> Dict[str, object]:
        return {
            "policy": self.policy.name,
            "assigned_requests": list(self.assigned_requests),
            "assigned_tokens": list(self.assigned_tokens),
            "loads": [self.load(i) for i in range(self.n_replicas)],
            "n_cold_routes": self.n_cold_routes,
            "n_prefix_cold_routes": self.n_prefix_cold_routes,
            "n_migrations": self.n_migrations,
            "n_replications": self.n_replications,
            "n_unreplications": self.n_unreplications,
            "replicated": {a: sorted(h)
                           for a, h in sorted(self.replicated.items())},
            "alive": list(self.alive),
        }


# --------------------------------------------------------------------------- #
# cluster-level metrics
# --------------------------------------------------------------------------- #

@dataclasses.dataclass
class ClusterMetrics:
    """Cluster aggregate + per-replica breakdown.

    Replicas run on independent clocks; the cluster duration is the
    longest replica run, throughput/ideal are total tokens over that
    duration, and latency means are weighted by finished requests.
    """
    per_replica: List[ServingMetrics]
    throughput: float
    itl: float
    ttft: float
    ideal_throughput: float
    duration: float
    n_finished: int
    n_preemptions: int
    max_kv_used: float
    n_loads: int
    # TTFT tail: exact percentiles over the pooled per-replica samples
    # (falls back to the finished-weighted mean of per-replica
    # percentiles only for sample-free hand-built metrics)
    ttft_p50: float = 0.0
    ttft_p99: float = 0.0
    n_starved_requests: int = 0
    starved_per_adapter: Dict[int, int] = dataclasses.field(
        default_factory=dict)
    # reliability counters (0 on the healthy path)
    n_timeouts: int = 0
    n_retries: int = 0
    n_failed_requests: int = 0
    n_load_faults: int = 0
    # shared-prefix cache counters (0 with the cache off)
    n_prefix_hits: int = 0
    n_prefix_misses: int = 0
    n_prefix_evictions: int = 0
    prefix_tokens_saved: int = 0

    @property
    def starved(self) -> bool:
        if self.ideal_throughput <= 0:
            return False
        return self.throughput < 0.9 * self.ideal_throughput

    @property
    def imbalance(self) -> float:
        """Max/mean offered-token share across replicas (1.0 = even)."""
        tokens = [m.ideal_throughput * m.duration for m in self.per_replica]
        mean = sum(tokens) / len(tokens) if tokens else 0.0
        return max(tokens) / mean if mean > 0 else 0.0

    @classmethod
    def aggregate(cls, per: Sequence[ServingMetrics]) -> "ClusterMetrics":
        per = list(per)
        duration = max((m.duration for m in per), default=0.0)
        out_tokens = sum(m.throughput * m.duration for m in per)
        offered = sum(m.ideal_throughput * m.duration for m in per)
        weights = [m.n_finished for m in per]
        wsum = sum(weights)

        def wmean(vals):
            if wsum <= 0:
                return 0.0
            return sum(v * w for v, w in zip(vals, weights)) / wsum

        starved_per_adapter: Dict[int, int] = {}
        for m in per:
            for a, c in m.starved_per_adapter.items():
                starved_per_adapter[a] = starved_per_adapter.get(a, 0) + c

        # exact cluster percentiles from the pooled raw TTFT samples —
        # but only when every replica with TTFT evidence brought its
        # samples; a mixed set (one engine-built, one hand-built without
        # samples) would silently drop the sample-free replica, so it
        # falls back to the finished-weighted approximation instead
        pooled = [t for m in per for t in m.ttft_samples]
        mixed = any(not m.ttft_samples and (m.ttft_p50 or m.ttft_p99)
                    for m in per)
        if pooled and not mixed:
            pct = ttft_percentiles(pooled)
            p50, p99 = pct["p50"], pct["p99"]
        else:
            p50 = wmean([m.ttft_p50 for m in per])
            p99 = wmean([m.ttft_p99 for m in per])

        return cls(
            per_replica=per,
            throughput=out_tokens / duration if duration > 0 else 0.0,
            itl=wmean([m.itl for m in per]),
            ttft=wmean([m.ttft for m in per]),
            ideal_throughput=offered / duration if duration > 0 else 0.0,
            duration=duration,
            n_finished=sum(m.n_finished for m in per),
            n_preemptions=sum(m.n_preemptions for m in per),
            max_kv_used=max((m.max_kv_used for m in per), default=0.0),
            n_loads=sum(m.n_loads for m in per),
            ttft_p50=p50,
            ttft_p99=p99,
            n_starved_requests=sum(m.n_starved_requests for m in per),
            starved_per_adapter=starved_per_adapter,
            n_timeouts=sum(m.n_timeouts for m in per),
            n_retries=sum(m.n_retries for m in per),
            n_failed_requests=sum(m.n_failed_requests for m in per),
            n_load_faults=sum(m.n_load_faults for m in per),
            n_prefix_hits=sum(m.n_prefix_hits for m in per),
            n_prefix_misses=sum(m.n_prefix_misses for m in per),
            n_prefix_evictions=sum(m.n_prefix_evictions for m in per),
            prefix_tokens_saved=sum(m.prefix_tokens_saved for m in per),
        )


# --------------------------------------------------------------------------- #
# the cluster itself
# --------------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class FailureEvent:
    """Kill ``replica`` at virtual time ``at`` (it stops stepping and
    heartbeating; the failure detector finds out later)."""
    replica: int
    at: float


@dataclasses.dataclass
class OnlineReport:
    """Outcome of one ``run_online``: aggregate metrics + the living-system
    event log (executed plan actions, detected failures, straggler
    epochs).  ``migrations`` is the full executed-plan log — it holds
    ``Migration | Replicate | Unreplicate`` actions in execution order."""
    metrics: Optional[ClusterMetrics]
    n_epochs: int
    migrations: List[object]
    failures_detected: Dict[int, float]        # replica -> detection time
    n_rerouted: int
    straggler_epochs: Dict[int, int]           # replica -> #epochs flagged
    router_summary: Dict[str, object]
    # everything the fault layer did (all-zero when no FaultPlan /
    # ReliabilityPolicy was attached)
    faults: FaultStats = dataclasses.field(default_factory=FaultStats)

    @property
    def replications(self) -> List[object]:
        return [a for a in self.migrations if isinstance(a, Replicate)]

    @property
    def unreplications(self) -> List[object]:
        return [a for a in self.migrations if isinstance(a, Unreplicate)]


class ServingCluster:
    """N ``ServingEngine`` replicas behind a ``ClusterRouter``.

    Each replica is an independent machine with its own executor and
    virtual clock.  ``run`` is the offline path: the router partitions
    the full stream up front and each engine serves its partition.
    ``run_online`` is the epoch-driven living system: arrivals are routed
    window by window, replicas heartbeat, failures are detected and
    drained onto survivors, and a pluggable rebalancer migrates resident
    adapters as traffic drifts.
    """

    def __init__(self, router: ClusterRouter, executors: Sequence,
                 engine_factory=None):
        """``engine_factory(cfg, executor)`` builds one replica engine;
        defaults to ``ServingEngine``.  The ``ClusterDigitalTwin`` passes
        ``repro.core.fast_twin.FastEngine`` here so offline fleet sweeps
        run on the struct-of-arrays fast path."""
        if len(executors) != router.n_replicas:
            raise ValueError(
                f"{router.n_replicas} replicas but {len(executors)} "
                "executors")
        factory = engine_factory or ServingEngine
        self.router = router
        self.engines = [factory(spec.engine_config(), ex)
                        for spec, ex in zip(router.specs, executors)]

    def run(self, requests: Sequence[Request],
            horizon: Optional[float] = None) -> ClusterMetrics:
        # fresh routing state per run: a router scored offline (e.g. by the
        # ClusterDigitalTwin) carries cumulative loads/residency from that
        # stream, which must not skew this one
        self.router.reset()
        parts = self.router.partition(requests)
        per = [eng.run(part, horizon=horizon)
               for eng, part in zip(self.engines, parts)]
        return ClusterMetrics.aggregate(per)

    # ------------------------------------------------------------------ #
    # online (epoch-driven) serving
    # ------------------------------------------------------------------ #
    def run_online(self, requests: Sequence[Request], horizon: float,
                   epoch: float = 5.0, rebalancer=None,
                   failures: Sequence[FailureEvent] = (),
                   heartbeat_timeout: Optional[float] = None,
                   straggler_factor: float = 0.0,
                   drain: bool = True,
                   max_drain_epochs: int = 1000,
                   initial_placement: Optional[Dict[int, int]] = None,
                   fault_plan: Optional[FaultPlan] = None,
                   reliability: Optional[ReliabilityPolicy] = None
                   ) -> OnlineReport:
        """Serve the stream in ``epoch``-long windows.

        Per window: (1) route the window's arrivals with the router's
        *current* residency/liveness beliefs, (2) advance every live
        engine's clock to the window end (a killed engine stops at its
        kill time and goes silent), (3) detect replicas whose heartbeat
        is older than ``heartbeat_timeout`` (default ``1.5 * epoch``),
        drain their unfinished requests and re-route them to survivors
        (recompute semantics: progress reset, preemption counted),
        (4) flag stragglers (mean executed-step time above
        ``straggler_factor`` x the fleet median; 0 disables) so new
        adapters route away from them, and (5) let ``rebalancer`` migrate
        resident adapters, charging each migration's Fig. 4 load cost to
        the destination replica's clock.

        With ``drain`` the loop keeps running windows past ``horizon``
        (no new arrivals) until every routed request finished — this is
        what "a dead replica's requests complete on survivors" means.

        ``initial_placement`` (adapter uid -> replica) warms the fleet
        before serving starts — typically ``PlacementRouter.plan``'s
        model-predicted bin-packing (see
        ``repro.serving.predictive.plan_initial_placement``) instead of
        letting first-touch affinity scatter the pool.  Warm-up happens
        at t=0, before any request, so no Fig. 4 cost is charged.

        ``fault_plan`` injects a deterministic fault schedule
        (:class:`repro.serving.faults.FaultPlan`): crashes take effect
        like ``failures`` kills but may *recover* — the engine restores
        its pre-crash adapter snapshot (Fig. 4 reload costs via
        ``reliability.load_cost_fn``) and rejoins through the heartbeat
        path; straggler windows scale the replica's step times;
        adapter-load faults make a (replica, adapter) pair refuse loads;
        executor faults stall a replica (no service, no heartbeat);
        client disconnects cancel an in-flight request.  All fault
        timing is epoch-granular, which is what lets
        ``ClusterDigitalTwin.simulate_online`` replay the identical plan
        bitwise.

        ``reliability`` arms per-request deadlines: a request that has
        not finished ``timeout_s`` after its (re)submission is cancelled
        and retried on an eligible replica after exponential backoff, up
        to ``max_retries`` times, then explicitly failed (``failed_at``
        set — never silently dropped).  Replicas causing timeouts or
        refusing adapter loads accumulate circuit-breaker failures and
        are cut out of routing while their breaker is open.
        """
        if epoch <= 0:
            raise ValueError(f"epoch must be positive, got {epoch}")
        router = self.router
        router.reset()
        for eng in self.engines:
            eng.reset_stream()
        if initial_placement:
            for uid in sorted(initial_placement):
                rep = initial_placement[uid]
                if 0 <= rep < router.n_replicas and router.alive[rep] \
                        and self.engines[rep].preload_adapter(uid, 0.0):
                    router.warm(uid, rep)
        hb_timeout = (1.5 * epoch) if heartbeat_timeout is None \
            else heartbeat_timeout
        killed_at = {f.replica: f.at for f in failures}
        stream = sorted(requests, key=lambda r: r.arrival)
        idx = 0
        report = OnlineReport(
            metrics=None, n_epochs=0, migrations=[],
            failures_detected={}, n_rerouted=0, straggler_epochs={},
            router_summary={})
        # per-replica (busy_time, exec_steps) snapshots for stragglers
        snap: List[Tuple[float, int]] = [(0.0, 0) for _ in self.engines]
        tok_snap: List[int] = [0] * len(self.engines)

        # --- fault-injection / reliability setup (all inert when no
        # plan/policy is attached — the healthy path stays byte-identical)
        stats = report.faults
        injecting = fault_plan is not None
        rel = reliability
        rel_enabled = rel is not None and rel.enabled
        if rel is not None:
            for b in router.breakers:
                b.threshold = max(int(rel.breaker_threshold), 1)
                b.cooldown_s = rel.breaker_cooldown_s
        load_cost_fn = rel.load_cost_fn if rel is not None else None
        straggler_evs = fault_plan.straggler_windows if injecting else []
        adapter_evs = fault_plan.adapter_faults if injecting else []
        exec_evs = fault_plan.executor_faults if injecting else []
        disconnects = list(fault_plan.disconnects) if injecting else []
        pending_recover = []
        if injecting:
            for c in fault_plan.crashes:
                killed_at[c.replica] = min(
                    killed_at.get(c.replica, math.inf), c.at)
                if c.recover_at is not None:
                    pending_recover.append(c)
            pending_recover.sort(key=lambda c: c.recover_at)
        # last known-good engine checkpoints (crash recovery source)
        ckpt = [eng.snapshot() for eng in self.engines] if injecting \
            else None
        lf_snap = [0] * len(self.engines)
        crash_seen: set = set()
        ev_seen: set = set()
        retry_q: List[Request] = []

        t = 0.0
        extra = 0
        while t < horizon or (drain and extra < max_drain_epochs
                              and any(r.finished_at is None
                                      and r.failed_at is None
                                      and r.disconnected_at is None
                                      for r in stream)):
            if t >= horizon:
                extra += 1
            t1 = min(t + epoch, horizon) if t < horizon else t + epoch
            report.n_epochs += 1

            # (0) window-start fault activation: straggler slow factors,
            # adapter-fault failing sets, executor stalls, breaker ticks
            stalled: set = set()
            if injecting:
                for i, eng in enumerate(self.engines):
                    f = 1.0
                    for ev in straggler_evs:
                        if ev.replica == i and ev.at <= t < ev.until:
                            f = ev.factor
                    eng.slow_factor = f
                    fs = {ev.adapter for ev in adapter_evs
                          if ev.replica == i and ev.at <= t < ev.until}
                    eng.adapters.failing = fs
                for ev in adapter_evs:
                    if ev.at <= t < ev.until and ev not in ev_seen:
                        ev_seen.add(ev)
                        stats.n_adapter_faults += 1
                for ev in exec_evs:
                    if ev.at < t1 and ev.at + ev.duration > t:
                        stalled.add(ev.replica)
                        if ev not in ev_seen:
                            ev_seen.add(ev)
                            stats.n_executor_faults += 1
            if rel is not None:
                for b in router.breakers:
                    b.tick(t)
            failed_reps: set = set()

            # (1) route this window's arrivals (batched per engine: one
            # submit-sort per replica per window, not per request), plus
            # any retried requests whose backoff expires this window
            window: List[List[Request]] = [[] for _ in self.engines]
            if retry_q:
                due = [r for r in retry_q if r.retry_at <= t1]
                if due:
                    retry_q = [r for r in retry_q if r.retry_at > t1]
                    for req in due:
                        window[router.route(req)].append(req)
            while idx < len(stream) and stream[idx].arrival < t1:
                req = stream[idx]
                window[router.route(req)].append(req)
                idx += 1
            for eng, batch in zip(self.engines, window):
                eng.submit(batch)

            # (2) advance engines; heartbeat the ones that survive it
            for i, eng in enumerate(self.engines):
                if not router.alive[i]:
                    continue
                kill = killed_at.get(i, math.inf)
                if kill <= t1 and i not in crash_seen:
                    crash_seen.add(i)
                    stats.n_crashes += 1
                if kill <= t:
                    continue                      # silently dead already
                if i in stalled:
                    # transient executor fault: the clock jumps, nothing
                    # is served and no heartbeat goes out this window
                    eng.stall_until(min(t1, kill))
                    continue
                eng.run_until(min(t1, kill), strict=True)
                if kill > t1:
                    router.heartbeat(i, t1)
                    if injecting:
                        ckpt[i] = eng.snapshot()

            # (3) failure detection -> drain + re-route on survivors
            fleet_down = False
            for i in router.dead_replicas(now=t1, timeout=hb_timeout):
                if len(router.live_replicas()) == 1:
                    # the last live replica died: total outage.  Degrade
                    # gracefully — report what finished; its unfinished
                    # requests stay in its accounting (nowhere to go)
                    router.alive[i] = False
                    router.resident[i] = {}
                    report.failures_detected[i] = t1
                    self.engines[i].halted = True
                    fleet_down = True
                    break
                router.mark_dead(i)
                report.failures_detected[i] = t1
                failed_reps.add(i)
                orphans = self.engines[i].drain()
                rerouted: List[List[Request]] = [[] for _ in self.engines]
                for req in sorted(orphans, key=lambda r: r.arrival):
                    req.generated = 0
                    req.admitted_at = None
                    req.first_token_at = None
                    req.finished_at = None
                    req.token_times = []
                    req.n_preemptions += 1
                    rerouted[router.route(req)].append(req)
                    report.n_rerouted += 1
                for eng, batch in zip(self.engines, rerouted):
                    eng.submit(batch)
            if fleet_down:
                break

            # (3b) crash recovery: restore the engine's pre-crash adapter
            # snapshot (Fig. 4 reload costs) and rejoin via heartbeat
            while pending_recover and pending_recover[0].recover_at <= t1:
                c = pending_recover.pop(0)
                i = c.replica
                eng = self.engines[i]
                killed_at.pop(i, None)
                crash_seen.discard(i)
                if not router.alive[i]:
                    # already detected dead: orphans were re-routed at
                    # detection time, so restore + revive is enough
                    reloaded = eng.restore(ckpt[i], t1, load_cost_fn)
                    router.revive(i, reloaded, t1)
                else:
                    # recovered before the detector noticed: in-flight
                    # state is lost all the same — drain, restore,
                    # re-route the orphans (self included in eligible)
                    orphans = eng.drain()
                    reloaded = eng.restore(ckpt[i], t1, load_cost_fn)
                    router.heartbeat(i, t1)
                    rerouted = [[] for _ in self.engines]
                    for req in sorted(orphans, key=lambda r: r.arrival):
                        req.generated = 0
                        req.admitted_at = None
                        req.first_token_at = None
                        req.finished_at = None
                        req.token_times = []
                        req.n_preemptions += 1
                        rerouted[router.route(req)].append(req)
                        report.n_rerouted += 1
                    for e, batch in zip(self.engines, rerouted):
                        e.submit(batch)
                stats.n_recoveries += 1

            # (3c) per-request deadlines: cancel + retry with backoff on
            # an eligible replica, or explicitly fail when retries are
            # spent (the request is never silently dropped)
            if rel_enabled:
                in_backoff = {r.uid for r in retry_q}
                for r in stream[:idx]:
                    if r.finished_at is not None or r.failed_at is not None \
                            or r.disconnected_at is not None \
                            or r.uid in in_backoff:
                        continue
                    started = r.retry_at if r.retry_at is not None \
                        else r.arrival
                    if t1 - started <= rel.timeout_s:
                        continue
                    rep = router.assignments.get(r.uid)
                    if rep is None or self.engines[rep].halted:
                        continue
                    will_retry = r.n_retries < rel.max_retries
                    got = self.engines[rep].cancel(r.uid, forget=will_retry)
                    if got is None:
                        continue          # raced with a finish this window
                    r.n_timeouts += 1
                    stats.n_timeouts += 1
                    failed_reps.add(rep)
                    if will_retry:
                        r.n_retries += 1
                        stats.n_retries += 1
                        r.generated = 0
                        r.admitted_at = None
                        r.first_token_at = None
                        r.finished_at = None
                        r.token_times = []
                        r.retry_at = t1 + rel.backoff(r.n_retries)
                        retry_q.append(r)
                    else:
                        r.failed_at = t1
                        stats.n_failed_requests += 1

            # (3d) client disconnects: cancel the engine-side work and
            # account the request (it stays in its engine's metrics)
            if disconnects:
                rest = []
                for ev in disconnects:
                    if ev.at > t1:
                        rest.append(ev)
                        continue
                    if not 0 <= ev.request_index < len(stream):
                        continue
                    r = stream[ev.request_index]
                    if r.arrival > t1:
                        rest.append(ev)   # client not even connected yet
                        continue
                    if r.finished_at is None and r.failed_at is None \
                            and r.disconnected_at is None:
                        rep = router.assignments.get(r.uid)
                        if rep is not None:
                            self.engines[rep].cancel(r.uid, forget=False)
                        retry_q = [q for q in retry_q if q.uid != r.uid]
                        r.disconnected_at = t1
                        stats.n_disconnects += 1
                disconnects = rest

            # (4) straggler flags from observed per-window step times
            if straggler_factor > 0:
                means = {}
                for i, eng in enumerate(self.engines):
                    if not router.alive[i]:
                        continue
                    db = eng.busy_time - snap[i][0]
                    ds = eng.n_exec_steps - snap[i][1]
                    if ds > 0:
                        means[i] = db / ds
                if len(means) >= 2:
                    vals = sorted(means.values())
                    med = vals[(len(vals) - 1) // 2]   # lower median: a
                    # 2-replica fleet compares the slow one to the fast one
                    for i, m in means.items():
                        slow = m > straggler_factor * med
                        router.mark_straggler(i, slow)
                        if slow:
                            report.straggler_epochs[i] = \
                                report.straggler_epochs.get(i, 0) + 1
            snap = [(eng.busy_time, eng.n_exec_steps)
                    for eng in self.engines]

            # (5) online rebalancing (migration cost charged on preload)
            if rebalancer is not None:
                served = [eng.n_tokens_out - tok_snap[i]
                          for i, eng in enumerate(self.engines)]
                backlog = [eng.scheduler.n_waiting + eng.scheduler.n_running
                           for eng in self.engines]
                rebalancer.observe(now=t1, window_s=t1 - t,
                                   served_tokens=served, backlog=backlog)
                for act in rebalancer.propose(now=t1):
                    if isinstance(act, Replicate):
                        if self.engines[act.dst].preload_adapter(
                                act.adapter, act.cost_s):
                            router.replicate(act.adapter, act.src, act.dst)
                            rebalancer.commit(act)
                            report.migrations.append(act)
                    elif isinstance(act, Unreplicate):
                        if self.engines[act.rep].evict_adapter(act.adapter):
                            router.unreplicate(act.adapter, act.rep)
                            rebalancer.commit(act)
                            report.migrations.append(act)
                    elif self.engines[act.dst].preload_adapter(
                            act.adapter, act.cost_s):
                        self.engines[act.src].evict_adapter(act.adapter)
                        router.migrate(act.adapter, act.src, act.dst)
                        rebalancer.commit(act)
                        report.migrations.append(act)
            # (6) window-end breaker accounting: refused adapter loads
            # count as replica failures; a clean half-open window closes
            if injecting:
                for i, eng in enumerate(self.engines):
                    d = eng.n_load_faults - lf_snap[i]
                    if d > 0:
                        stats.n_load_faults += d
                        failed_reps.add(i)
                    lf_snap[i] = eng.n_load_faults
            if rel is not None:
                for i in range(router.n_replicas):
                    b = router.breakers[i]
                    if i in failed_reps:
                        b.record_failure(t1)
                    elif router.alive[i] and b.state == b.HALF_OPEN:
                        b.record_success()
            tok_snap = [eng.n_tokens_out for eng in self.engines]
            t = t1

        stats.n_breaker_opens = sum(b.n_opens for b in router.breakers)
        report.metrics = ClusterMetrics.aggregate(
            [eng.finalize() for eng in self.engines])
        report.router_summary = router.summary()
        return report

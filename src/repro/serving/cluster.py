"""Multi-replica adapter-affinity serving cluster.

Scales the single-engine system to a fleet: a ``ClusterRouter`` fronts N
``ServingEngine`` replicas (heterogeneous ``adapter_slots`` /
``kv_capacity_tokens`` per replica) and routes each request with a
pluggable policy:

  * ``affinity``     — prefer replicas that already hold the request's
                       adapter (minimising cold CPU->GPU adapter loads,
                       the Fig. 4 cost), falling back to least-loaded,
                       and spilling away from overloaded replicas;
  * ``least-loaded`` — pick the replica with the lowest capacity-
                       normalised assigned work (heterogeneity-aware);
  * ``round-robin``  — cycle replicas (the affinity-blind baseline).

The router keeps a per-replica model of resident adapters (an LRU capped
at the replica's slot count — mirroring ``AdapterSlotCache`` semantics)
and of assigned work (prompt+output tokens, normalised by the replica's
KV capacity so a half-size replica receives half the load).

``ServingCluster`` runs the routed partitions through real engines;
``repro.core.cluster_twin.ClusterDigitalTwin`` runs the *same router*
over estimator-backed engines so cluster-level placement can be labelled
offline exactly as the paper does for one GPU.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Type, Union

from .engine import EngineConfig, ServingEngine
from .metrics import ServingMetrics
from .request import Request


# --------------------------------------------------------------------------- #
# replica description
# --------------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class ReplicaSpec:
    """Static description of one serving replica (one GPU/node)."""
    adapter_slots: int
    kv_capacity_tokens: int
    max_running: int = 256
    block_size: int = 16

    def engine_config(self) -> EngineConfig:
        return EngineConfig(
            kv_capacity_tokens=self.kv_capacity_tokens,
            adapter_slots=self.adapter_slots,
            max_running=self.max_running,
            block_size=self.block_size)


def make_replica_specs(
        n: int, adapter_slots: Union[int, Sequence[int]],
        kv_capacity_tokens: Union[int, Sequence[int]],
        max_running: int = 256) -> List[ReplicaSpec]:
    """Uniform or heterogeneous specs from scalars / per-replica lists."""
    def expand(v, name):
        vs = [v] * n if isinstance(v, int) else list(v)
        if len(vs) != n:
            raise ValueError(f"{name}: expected {n} values, got {len(vs)}")
        return vs
    slots = expand(adapter_slots, "adapter_slots")
    kvs = expand(kv_capacity_tokens, "kv_capacity_tokens")
    return [ReplicaSpec(adapter_slots=s, kv_capacity_tokens=k,
                        max_running=max_running)
            for s, k in zip(slots, kvs)]


# --------------------------------------------------------------------------- #
# routing policies (pluggable)
# --------------------------------------------------------------------------- #

POLICIES: Dict[str, Type["RoutingPolicy"]] = {}


def register_policy(cls: Type["RoutingPolicy"]) -> Type["RoutingPolicy"]:
    POLICIES[cls.name] = cls
    return cls


class RoutingPolicy:
    """Chooses a replica index for each incoming request."""
    name = ""

    def __init__(self, router: "ClusterRouter"):
        self.router = router

    def reset(self) -> None:
        pass

    def choose(self, req: Request) -> int:
        raise NotImplementedError


@register_policy
class RoundRobinPolicy(RoutingPolicy):
    name = "round-robin"

    def __init__(self, router: "ClusterRouter"):
        super().__init__(router)
        self._next = 0

    def reset(self) -> None:
        self._next = 0

    def choose(self, req: Request) -> int:
        rep = self._next % self.router.n_replicas
        self._next += 1
        return rep


@register_policy
class LeastLoadedPolicy(RoutingPolicy):
    name = "least-loaded"

    def choose(self, req: Request) -> int:
        return self.router.least_loaded()


@register_policy
class AffinityPolicy(RoutingPolicy):
    """Adapter affinity with overload spill.

    Route to the least-loaded replica already holding the adapter unless
    its normalised load exceeds ``overload_factor`` x the fleet minimum
    plus ``slack`` (absolute headroom, in fractions of KV capacity) — in
    which case fall back to the least-loaded replica.
    """
    name = "affinity"

    def __init__(self, router: "ClusterRouter",
                 overload_factor: float = 1.5, slack: float = 0.1):
        super().__init__(router)
        self.overload_factor = overload_factor
        self.slack = slack

    def choose(self, req: Request) -> int:
        r = self.router
        holders = [i for i in range(r.n_replicas)
                   if req.adapter in r.resident[i]]
        if holders:
            rep = min(holders, key=lambda i: (r.load(i), i))
            floor = r.load(r.least_loaded())
            if r.load(rep) <= self.overload_factor * floor + self.slack:
                return rep
        return r.least_loaded()


# --------------------------------------------------------------------------- #
# router
# --------------------------------------------------------------------------- #

class ClusterRouter:
    """Routes requests across replicas; tracks residency + assigned load.

    The residency model is an LRU over adapter uids capped at each
    replica's ``adapter_slots`` — the router's belief of what the
    replica's ``AdapterSlotCache`` holds.  Assigned load is cumulative
    prompt+output tokens normalised by KV capacity, so heterogeneous
    replicas are compared on relative utilisation.
    """

    def __init__(self, specs: Sequence[ReplicaSpec],
                 policy: Union[str, RoutingPolicy] = "affinity",
                 **policy_kwargs):
        self.specs = list(specs)
        if not self.specs:
            raise ValueError("need at least one replica spec")
        if isinstance(policy, str):
            if policy not in POLICIES:
                raise ValueError(
                    f"unknown policy {policy!r}; have {sorted(POLICIES)}")
            self.policy: RoutingPolicy = POLICIES[policy](
                self, **policy_kwargs)
        else:
            self.policy = policy
        self.reset()

    # ------------------------------------------------------------------ #
    def reset(self) -> None:
        n = self.n_replicas
        # adapter uid -> last-touch sequence number, per replica
        self.resident: List[Dict[int, int]] = [{} for _ in range(n)]
        self.assigned_tokens = [0.0] * n
        self.assigned_requests = [0] * n
        self.assignments: Dict[int, int] = {}     # request uid -> replica
        self.n_cold_routes = 0    # routed to a replica not holding adapter
        self._seq = 0
        self.policy.reset()

    @property
    def n_replicas(self) -> int:
        return len(self.specs)

    def load(self, rep: int) -> float:
        """Capacity-normalised cumulative assigned work."""
        return self.assigned_tokens[rep] / max(
            self.specs[rep].kv_capacity_tokens, 1)

    def least_loaded(self) -> int:
        return min(range(self.n_replicas), key=lambda i: (self.load(i), i))

    # ------------------------------------------------------------------ #
    def route(self, req: Request) -> int:
        rep = self.policy.choose(req)
        if not 0 <= rep < self.n_replicas:
            raise ValueError(f"policy chose invalid replica {rep}")
        self._commit(rep, req)
        return rep

    def _commit(self, rep: int, req: Request) -> None:
        self._seq += 1
        res = self.resident[rep]
        if req.adapter not in res:
            self.n_cold_routes += 1
            slots = self.specs[rep].adapter_slots
            if slots > 0 and len(res) >= slots:
                lru = min(res, key=res.get)
                del res[lru]
        res[req.adapter] = self._seq
        self.assigned_tokens[rep] += req.prompt_len + req.output_len
        self.assigned_requests[rep] += 1
        self.assignments[req.uid] = rep

    def partition(self, requests: Sequence[Request]) -> List[List[Request]]:
        """Route a full stream (in arrival order) into per-replica lists."""
        parts: List[List[Request]] = [[] for _ in range(self.n_replicas)]
        for req in sorted(requests, key=lambda r: r.arrival):
            parts[self.route(req)].append(req)
        return parts

    def summary(self) -> Dict[str, object]:
        return {
            "policy": self.policy.name,
            "assigned_requests": list(self.assigned_requests),
            "assigned_tokens": list(self.assigned_tokens),
            "loads": [self.load(i) for i in range(self.n_replicas)],
            "n_cold_routes": self.n_cold_routes,
        }


# --------------------------------------------------------------------------- #
# cluster-level metrics
# --------------------------------------------------------------------------- #

@dataclasses.dataclass
class ClusterMetrics:
    """Cluster aggregate + per-replica breakdown.

    Replicas run on independent clocks; the cluster duration is the
    longest replica run, throughput/ideal are total tokens over that
    duration, and latency means are weighted by finished requests.
    """
    per_replica: List[ServingMetrics]
    throughput: float
    itl: float
    ttft: float
    ideal_throughput: float
    duration: float
    n_finished: int
    n_preemptions: int
    max_kv_used: float
    n_loads: int

    @property
    def starved(self) -> bool:
        if self.ideal_throughput <= 0:
            return False
        return self.throughput < 0.9 * self.ideal_throughput

    @property
    def imbalance(self) -> float:
        """Max/mean offered-token share across replicas (1.0 = even)."""
        tokens = [m.ideal_throughput * m.duration for m in self.per_replica]
        mean = sum(tokens) / len(tokens) if tokens else 0.0
        return max(tokens) / mean if mean > 0 else 0.0

    @classmethod
    def aggregate(cls, per: Sequence[ServingMetrics]) -> "ClusterMetrics":
        per = list(per)
        duration = max((m.duration for m in per), default=0.0)
        out_tokens = sum(m.throughput * m.duration for m in per)
        offered = sum(m.ideal_throughput * m.duration for m in per)
        weights = [m.n_finished for m in per]
        wsum = sum(weights)

        def wmean(vals):
            if wsum <= 0:
                return 0.0
            return sum(v * w for v, w in zip(vals, weights)) / wsum

        return cls(
            per_replica=per,
            throughput=out_tokens / duration if duration > 0 else 0.0,
            itl=wmean([m.itl for m in per]),
            ttft=wmean([m.ttft for m in per]),
            ideal_throughput=offered / duration if duration > 0 else 0.0,
            duration=duration,
            n_finished=sum(m.n_finished for m in per),
            n_preemptions=sum(m.n_preemptions for m in per),
            max_kv_used=max((m.max_kv_used for m in per), default=0.0),
            n_loads=sum(m.n_loads for m in per),
        )


# --------------------------------------------------------------------------- #
# the cluster itself
# --------------------------------------------------------------------------- #

class ServingCluster:
    """N ``ServingEngine`` replicas behind a ``ClusterRouter``.

    Each replica is an independent machine with its own executor and
    virtual clock; the router decides the partition of the request
    stream, the engines serve their partitions, and the per-replica
    metrics are aggregated into ``ClusterMetrics``.
    """

    def __init__(self, router: ClusterRouter, executors: Sequence):
        if len(executors) != router.n_replicas:
            raise ValueError(
                f"{router.n_replicas} replicas but {len(executors)} "
                "executors")
        self.router = router
        self.engines = [ServingEngine(spec.engine_config(), ex)
                        for spec, ex in zip(router.specs, executors)]

    def run(self, requests: Sequence[Request],
            horizon: Optional[float] = None) -> ClusterMetrics:
        # fresh routing state per run: a router scored offline (e.g. by the
        # ClusterDigitalTwin) carries cumulative loads/residency from that
        # stream, which must not skew this one
        self.router.reset()
        parts = self.router.partition(requests)
        per = [eng.run(part, horizon=horizon)
               for eng, part in zip(self.engines, parts)]
        return ClusterMetrics.aggregate(per)

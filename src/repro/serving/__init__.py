from .adapter_cache import AdapterSlotCache  # noqa
from .engine import EngineConfig, ServingEngine  # noqa
from .executor import (HardwareProfile, JaxExecutor, StepTiming,  # noqa
                       SyntheticExecutor)
from .kv_cache import PagedKVCache  # noqa
from .prefix_cache import PrefixEntry, SharedPrefixCache  # noqa
from .metrics import ServingMetrics, smape, smape_vec, summarize  # noqa
from .request import Adapter, Request  # noqa
from .scheduler import Scheduler, StepPlan  # noqa
from .policy import (SCHED_POLICIES, SchedulingPolicy, SchedView,  # noqa
                     make_sched_policy, register_sched_policy,
                     sched_policy_index)
from .router import PlacementRouter, ReplicaPlan, RouterState  # noqa
from .cluster import (POLICIES, ClusterMetrics, ClusterRouter,  # noqa
                      FailureEvent, OnlineReport, ReplicaSpec,
                      RoutingPolicy, ServingCluster, make_replica_specs,
                      register_policy)
from .faults import (AdapterLoadFault, CircuitBreaker,  # noqa
                     ClientDisconnect, ExecutorFault, FaultPlan,
                     FaultStats, NoAliveReplicasError, ReliabilityPolicy,
                     ReplicaCrash, StragglerWindow, generate_fault_plan,
                     parse_chaos_spec)
from .rebalance import (AdapterLoadTracker, Migration,  # noqa
                        PlanAction, RebalancePolicy, RebalanceReport,
                        Replicate, Unreplicate)
from .predictive import (PredictiveRebalancer,  # noqa
                         plan_initial_placement)
from .gateway import (AdmissionControl, AsyncGateway, Completion,  # noqa
                      CompletionStream, GatewayHTTPServer, GatewayMetrics,
                      GatewayReport, Rejected, completion_chunk,
                      estimator_admission, sse_format)

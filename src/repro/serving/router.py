"""Plan-level placement router (model-predicted bin-packing).

Uses the placement pipeline's predictions (per-node adapter capacity +
optimal slot count) to (a) pack adapters onto replicas (greedy bin-pack on
predicted capacity, cf. dLoRA's proactive placement), (b) configure each
replica's ``adapter_slots``, and (c) admission-control so no replica is
pushed past its predicted starvation boundary.

NOTE: the request-level fleet path lives in ``repro.serving.cluster`` —
``ClusterRouter`` + ``ServingCluster.run_online`` absorbed this module's
heartbeat/straggler semantics (dead replicas are drained onto survivors;
stragglers stop receiving new adapters) and add online rebalancing
(``repro.serving.rebalance``).  ``PlacementRouter`` remains the
*plan-level* tool: one model call decides the initial adapter->replica
packing that the online loop then keeps healthy.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from .request import Adapter


@dataclasses.dataclass
class ReplicaPlan:
    replica: int
    adapters: List[Adapter]
    slots: int
    predicted_throughput: float
    alive: bool = True
    straggler: bool = False


@dataclasses.dataclass
class RouterState:
    plans: List[ReplicaPlan]
    assignment: Dict[int, int]      # adapter uid -> replica

    def replica_for(self, adapter_uid: int) -> Optional[int]:
        return self.assignment.get(adapter_uid)


class PlacementRouter:
    def __init__(self, pipeline, n_replicas: int,
                 straggler_factor: float = 2.0):
        self.pipeline = pipeline
        self.n_replicas = n_replicas
        self.straggler_factor = straggler_factor
        self.state: Optional[RouterState] = None

    # ------------------------------------------------------------------ #
    def plan(self, pool: Sequence[Adapter], length_stats: Dict[str, float]
             ) -> RouterState:
        """Greedy bin-pack: fill replicas up to the model-predicted
        per-node capacity, highest-rate adapters first."""
        pool = sorted(pool, key=lambda a: -a.rate)
        plans: List[ReplicaPlan] = []
        assignment: Dict[int, int] = {}
        remaining = list(pool)
        for rep in range(self.n_replicas):
            if not remaining:
                plans.append(ReplicaPlan(rep, [], 1, 0.0))
                continue
            # ask the model how many of the remaining adapters this node
            # can serve at max throughput without starvation
            rates = [a.rate for a in remaining]
            ranks = [a.rank for a in remaining]
            rec = self.pipeline.recommend(rates, ranks, length_stats)
            take = min(len(remaining), max(rec["served_adapters"], 1))
            # spread the load: do not put everything on one node if the
            # fleet has room
            fair = -(-len(pool) // self.n_replicas)
            take = min(take, max(fair, 1)) if rep < self.n_replicas - 1 \
                else take
            chosen = remaining[:take]
            remaining = remaining[take:]
            for a in chosen:
                assignment[a.uid] = rep
            plans.append(ReplicaPlan(
                rep, chosen, rec["adapter_slots"],
                rec["throughput"]))
        # overflow: round-robin any leftovers (over capacity -> flagged)
        for i, a in enumerate(remaining):
            rep = i % self.n_replicas
            plans[rep].adapters.append(a)
            assignment[a.uid] = rep
        self.state = RouterState(plans=plans, assignment=assignment)
        return self.state

    # ------------------------------------------------------------------ #
    def route(self, adapter_uid: int) -> int:
        assert self.state is not None
        rep = self.state.replica_for(adapter_uid)
        if rep is None or not self.state.plans[rep].alive:
            live = [p.replica for p in self.state.plans
                    if p.alive and not p.straggler]
            rep = live[adapter_uid % len(live)] if live else 0
        return rep

    def report_failure(self, replica: int, pool: Sequence[Adapter],
                       length_stats: Dict[str, float]) -> RouterState:
        """Drain a dead replica and re-pack its adapters on survivors."""
        assert self.state is not None
        dead = self.state.plans[replica]
        dead.alive = False
        orphans = dead.adapters
        dead.adapters = []
        survivors = [p for p in self.state.plans if p.alive]
        for i, a in enumerate(sorted(orphans, key=lambda x: -x.rate)):
            tgt = min(survivors,
                      key=lambda p: sum(x.rate for x in p.adapters))
            tgt.adapters.append(a)
            self.state.assignment[a.uid] = tgt.replica
        return self.state

    def observe_itl(self, itls: Dict[int, float]) -> List[int]:
        """Mark stragglers: replicas whose ITL exceeds factor x median."""
        assert self.state is not None
        vals = [v for v in itls.values() if v > 0]
        if not vals:
            return []
        med = float(np.median(vals))
        out = []
        for rep, itl in itls.items():
            bad = itl > self.straggler_factor * med
            self.state.plans[rep].straggler = bad
            if bad:
                out.append(rep)
        return out

"""The online LLM-adapter serving engine (our vLLM analogue).

Continuous-batching loop on a virtual clock advanced by executor-reported
step times: mixed prefill+decode batches, FCFS + loaded-adapter priority,
greedy paged-KV allocation with preemption-by-recompute, LRU adapter slots.

This is the "real system" that the Digital Twin (repro.core.digital_twin)
replicates: identical scheduling semantics, real (measured or
hidden-profile) step times.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from .adapter_cache import AdapterSlotCache
from .executor import StepTiming
from .kv_cache import PagedKVCache
from .metrics import ServingMetrics, summarize
from .request import Request
from .scheduler import Scheduler


@dataclasses.dataclass
class EngineConfig:
    kv_capacity_tokens: int
    adapter_slots: int
    max_running: int = 256
    block_size: int = 16
    max_steps: int = 2_000_000
    # S-LoRA mode (paper §V-B): no fixed slots; adapter weights share the
    # unified paged pool, charged per adapter in KV-token equivalents.
    dynamic_slots: bool = False
    adapter_kv_tokens: Dict[int, int] = dataclasses.field(
        default_factory=dict)


@dataclasses.dataclass
class StepTrace:
    t: float
    n_running: int
    n_waiting: int
    kv_used: float
    lat: float


class ServingEngine:
    def __init__(self, cfg: EngineConfig, executor):
        self.cfg = cfg
        self.executor = executor
        self.kv = PagedKVCache(cfg.kv_capacity_tokens, cfg.block_size)
        if cfg.dynamic_slots:
            def reserve(uid: int, dry: bool = False) -> bool:
                toks = cfg.adapter_kv_tokens.get(uid, 256)
                if dry:
                    return self.kv.can_allocate(toks)
                return self.kv.allocate(-(uid + 1), toks)

            def release(uid: int) -> None:
                self.kv.free(-(uid + 1))

            self.adapters = AdapterSlotCache(
                0, dynamic=True, reserve=reserve, release=release)
        else:
            self.adapters = AdapterSlotCache(cfg.adapter_slots)
        self.scheduler = Scheduler(self.kv, self.adapters, cfg.max_running)
        self.trace: List[StepTrace] = []

    def run(self, requests: List[Request], horizon: Optional[float] = None,
            record_trace: bool = False) -> ServingMetrics:
        pending = sorted(requests, key=lambda r: r.arrival)
        t = 0.0
        i = 0
        max_kv = 0.0
        steps = 0
        while steps < self.cfg.max_steps:
            steps += 1
            if horizon is not None and t >= horizon:
                break
            # idle fast-forward
            if not self.scheduler.has_work:
                if i >= len(pending):
                    break
                t = max(t, pending[i].arrival)
            while i < len(pending) and pending[i].arrival <= t:
                self.scheduler.add([pending[i]])
                i += 1
            plan = self.scheduler.schedule(t)
            if not plan.running:
                # blocked (e.g. waiting requests that cannot be admitted yet)
                if i < len(pending):
                    t = max(t, pending[i].arrival)
                    continue
                break
            timing: StepTiming = self.executor.step(
                plan, self.scheduler.n_waiting)
            t += timing.total
            max_kv = max(max_kv, self.kv.used_fraction)
            if record_trace:
                self.trace.append(StepTrace(
                    t, len(plan.running), self.scheduler.n_waiting,
                    self.kv.used_fraction, timing.total))
            for req in list(plan.running):
                req.generated += 1
                req.token_times.append(t)
                if req.first_token_at is None:
                    req.first_token_at = t
                if req.done:
                    req.finished_at = t
                    self.scheduler.finish(req)
        duration = max(t, 1e-9)
        arrived = [r for r in requests if r.arrival <= duration]
        offered = sum(r.output_len for r in arrived)
        return summarize(requests, duration, offered, max_kv,
                         self.adapters.load_count)

"""The online LLM-adapter serving engine (our vLLM analogue).

Continuous-batching loop on a virtual clock advanced by executor-reported
step times: mixed prefill+decode batches, FCFS + loaded-adapter priority,
greedy paged-KV allocation with preemption-by-recompute, LRU adapter slots.

This is the "real system" that the Digital Twin (repro.core.digital_twin)
replicates: identical scheduling semantics, real (measured or
hidden-profile) step times.

The loop is *resumable*: ``submit()`` enqueues arrivals, ``run_until()``
advances the virtual clock to a bound and returns, ``finalize()``
summarizes.  Two front-ends drive the resumable surface: the cluster's
epoch loop (``ServingCluster.run_online`` interleaves replicas window by
window) and the open-loop async gateway
(``repro.serving.gateway.AsyncGateway`` submits arrivals as they happen
and advances the engine between them, streaming tokens through the
``on_token`` hook).  ``run()`` composes the three calls and keeps the
original single-shot closed-loop semantics — it is a convenience
entrypoint, not the only serving path.  Fault-tolerance hooks:
``drain()`` pulls every unfinished request off a dead replica for
re-routing; ``preload_adapter()`` / ``evict_adapter()`` let a rebalancer
migrate adapter residency between replicas, charging the migration's
load cost to this replica's clock.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional

from .adapter_cache import AdapterSlotCache
from .executor import StepTiming
from .kv_cache import PagedKVCache
from .metrics import ServingMetrics, summarize
from .prefix_cache import SharedPrefixCache
from .request import Request
from .scheduler import Scheduler


@dataclasses.dataclass
class EngineConfig:
    kv_capacity_tokens: int
    adapter_slots: int
    max_running: int = 256
    block_size: int = 16
    max_steps: int = 2_000_000
    # admission/preemption policy (repro.serving.policy registry); "fcfs"
    # is the paper's fixed vLLM scheduler and the byte-identical default
    sched_policy: str = "fcfs"
    # S-LoRA mode (paper §V-B): no fixed slots; adapter weights share the
    # unified paged pool, charged per adapter in KV-token equivalents.
    dynamic_slots: bool = False
    adapter_kv_tokens: Dict[int, int] = dataclasses.field(
        default_factory=dict)
    # cross-adapter shared-prefix KV reuse (repro.serving.prefix_cache);
    # off by default — requests with prefix_id=None behave identically
    # either way, so False keeps every pre-existing run bitwise-pinned
    prefix_cache: bool = False


@dataclasses.dataclass
class StepTrace:
    t: float
    n_running: int
    n_waiting: int
    kv_used: float
    lat: float


class ServingEngine:
    def __init__(self, cfg: EngineConfig, executor):
        self.cfg = cfg
        self.executor = executor
        self.kv = PagedKVCache(cfg.kv_capacity_tokens, cfg.block_size)
        if cfg.dynamic_slots:
            def reserve(uid: int, dry: bool = False) -> bool:
                toks = cfg.adapter_kv_tokens.get(uid, 256)
                if dry:
                    # uid-aware: a re-reserve for an adapter with block
                    # slack must not be priced from an empty table
                    return self.kv.can_allocate(toks, uid=-(uid + 1))
                return self.kv.allocate(-(uid + 1), toks)

            def release(uid: int) -> None:
                self.kv.free(-(uid + 1))

            self.adapters = AdapterSlotCache(
                0, dynamic=True, reserve=reserve, release=release)
        else:
            self.adapters = AdapterSlotCache(cfg.adapter_slots)
        self.prefix: Optional[SharedPrefixCache] = \
            SharedPrefixCache(self.kv) if cfg.prefix_cache else None
        self.scheduler = Scheduler(self.kv, self.adapters, cfg.max_running,
                                   policy=cfg.sched_policy,
                                   prefix=self.prefix)
        self.trace: List[StepTrace] = []
        # streaming hook: called as ``on_token(req, t)`` for every token
        # the step loop generates (the async gateway fans these out to
        # per-request SSE streams).  None = no overhead on the hot loop.
        self.on_token: Optional[Callable[[Request, float], None]] = None
        self.reset_stream()

    # ------------------------------------------------------------------ #
    # resumable stream state
    # ------------------------------------------------------------------ #
    def reset_stream(self) -> None:
        """Start a fresh request stream (clock back to zero)."""
        self.scheduler.policy.reset()
        if self.prefix is not None:
            self.prefix.reset()
        self.clock = 0.0
        self.halted = False
        self._pending: List[Request] = []
        self._next = 0
        self._accepted: List[Request] = []
        self._iters = 0
        self._max_kv = 0.0
        # busy-time / executed-step / output-token counters (straggler
        # detection + the rebalancer's observed service rate)
        self.busy_time = 0.0
        self.n_exec_steps = 0
        self.n_tokens_out = 0
        # fault-injection state: >1.0 slows every step (straggler
        # window); n_load_faults counts refused preloads/restores
        self.slow_factor = 1.0
        self.n_load_faults = 0

    def submit(self, requests: List[Request]) -> None:
        """Enqueue arrivals (any order); may be called between epochs."""
        if not requests:
            return
        rest = self._pending[self._next:]
        self._pending = sorted(rest + list(requests), key=lambda r: r.arrival)
        self._next = 0
        self._accepted.extend(requests)

    def run_until(self, t_end: Optional[float] = None,
                  record_trace: bool = False, strict: bool = False) -> None:
        """Advance the continuous-batching loop until the clock reaches
        ``t_end`` (None = run the submitted stream to completion).

        ``strict`` keeps the clock from fast-forwarding past ``t_end``
        toward future arrivals — the online epoch loop needs that so a
        replica idle *this* epoch is still at ``t_end`` when the next
        epoch submits more work.  Non-strict mode reproduces the original
        single-shot ``run()`` semantics exactly.
        """
        if self.halted:
            return
        while self._iters < self.cfg.max_steps:
            self._iters += 1
            t = self.clock
            if t_end is not None and t >= t_end:
                return
            # idle fast-forward
            if not self.scheduler.has_work:
                if self._next >= len(self._pending):
                    return
                nxt = self._pending[self._next].arrival
                if strict and t_end is not None and nxt >= t_end:
                    self.clock = max(self.clock, min(nxt, t_end))
                    return
                t = max(t, nxt)
            while self._next < len(self._pending) and \
                    self._pending[self._next].arrival <= t:
                self.scheduler.add([self._pending[self._next]])
                self._next += 1
            plan = self.scheduler.schedule(t)
            if not plan.running:
                # blocked (e.g. waiting requests that cannot be admitted yet)
                if self._next < len(self._pending):
                    nxt = self._pending[self._next].arrival
                    if strict and t_end is not None and nxt >= t_end:
                        self.clock = max(self.clock, min(nxt, t_end))
                        return
                    self.clock = max(t, nxt)
                    continue
                self.clock = t
                return
            timing: StepTiming = self.executor.step(
                plan, self.scheduler.n_waiting)
            total = timing.total
            # guarded multiply: float * 1.0 is an identity but the guard
            # keeps the healthy path free of any fp op (bitwise pinning)
            if self.slow_factor != 1.0:
                total *= self.slow_factor
            t += total
            self.busy_time += total
            self.n_exec_steps += 1
            self.n_tokens_out += len(plan.running)
            self._max_kv = max(self._max_kv, self.kv.used_fraction)
            if record_trace:
                self.trace.append(StepTrace(
                    t, len(plan.running), self.scheduler.n_waiting,
                    self.kv.used_fraction, total))
            # plan.running is already a snapshot; finish() mutates only the
            # scheduler's own list, so no per-step defensive copy is needed
            on_token = self.on_token
            for req in plan.running:
                req.generated += 1
                req.token_times.append(t)
                if req.first_token_at is None:
                    req.first_token_at = t
                if req.done:
                    req.finished_at = t
                    self.scheduler.finish(req)
                if on_token is not None:
                    on_token(req, t)
            self.clock = t

    @property
    def queue_depth(self) -> int:
        """Admitted-but-unfinished requests on this engine: the scheduler's
        waiting + running sets plus submitted arrivals the clock has not
        reached yet.  The gateway's admission controller multiplies this
        by a predicted per-request service time to estimate backlog."""
        return (self.scheduler.n_waiting + self.scheduler.n_running
                + len(self._pending) - self._next)

    def finalize(self) -> ServingMetrics:
        duration = max(self.clock, 1e-9)
        arrived = [r for r in self._accepted if r.arrival <= duration]
        offered = sum(r.output_len for r in arrived)
        pc = self.prefix
        return summarize(self._accepted, duration, offered, self._max_kv,
                         self.adapters.load_count, self.n_load_faults,
                         n_prefix_hits=pc.n_hits if pc else 0,
                         n_prefix_misses=pc.n_misses if pc else 0,
                         n_prefix_evictions=pc.n_evictions if pc else 0,
                         prefix_tokens_saved=pc.tokens_saved if pc else 0)

    # ------------------------------------------------------------------ #
    # fault-tolerance / rebalancing hooks
    # ------------------------------------------------------------------ #
    def drain(self) -> List[Request]:
        """Pull every unfinished request off this (dead) replica.

        Frees their KV blocks and adapter pins, halts the engine, and
        removes them from this engine's accounting so the survivor that
        re-serves them is the only replica counting them.  Progress is
        NOT reset here — the re-router decides recompute semantics.
        """
        orphans = (list(self.scheduler.running)
                   + list(self.scheduler.waiting)
                   + self._pending[self._next:])
        for req in list(self.scheduler.running):
            self.kv.free(req.uid)
            self.adapters.unpin(req.adapter)
            if self.prefix is not None:
                self.prefix.release(req.uid)
        self.scheduler.clear()
        self._pending = []
        self._next = 0
        dead_uids = {r.uid for r in orphans}
        self._accepted = [r for r in self._accepted
                          if r.uid not in dead_uids]
        self.halted = True
        return orphans

    def preload_adapter(self, uid: int, cost_s: float = 0.0) -> bool:
        """Warm-load an adapter (migration target side), charging the
        Fig. 4 load cost to this replica's clock.  An adapter already
        resident here is a free success (the migration is belief-only).
        Returns False when the cache has no loadable slot (migration
        must be declined)."""
        if self.adapters.is_loaded(uid):
            self.adapters.touch(uid, self.clock)
            return True
        if uid in self.adapters.failing:
            self.n_load_faults += 1
            return False
        if not self.adapters.can_load(uid):
            return False
        self.adapters.load(uid, self.clock)
        # the clock pays the Fig. 4 cost, but busy_time stays pure step
        # execution time: it feeds the straggler detector's mean-step
        # estimate, which a migration must not inflate
        self.clock += cost_s
        return True

    def evict_adapter(self, uid: int) -> bool:
        """Drop an adapter's residency (migration source side)."""
        return self.adapters.evict(uid)

    def stall_until(self, t: float) -> None:
        """Transient executor fault: jump the clock to ``t`` without
        serving anything (no busy time, no heartbeat-worthy progress)."""
        self.clock = max(self.clock, t)

    def snapshot(self) -> dict:
        """Crash-recovery checkpoint: clock + resident adapter set.
        Request state is NOT captured — orphans re-route via drain()."""
        return {"clock": self.clock,
                "adapters": sorted(self.adapters.loaded)}

    def restore(self, snap: dict, now: float,
                load_cost_fn: Optional[Callable[[int], float]] = None
                ) -> List[int]:
        """Rejoin after a crash: un-halt, advance the clock to ``now``
        and reload the snapshot's adapter set, charging the Fig. 4 cost
        per adapter via ``load_cost_fn``.  Adapters currently
        fault-failing are skipped (counted ``n_load_faults``).  Returns
        the uids actually reloaded."""
        self.halted = False
        self.clock = max(now, self.clock)
        # the crash wiped GPU state: residency/pins restart from the
        # snapshot without counting phantom evictions; cached prefixes
        # are gone too (counters survive — they are lifetime metrics)
        self.adapters.loaded.clear()
        self.adapters.pinned.clear()
        if self.prefix is not None:
            self.prefix.wipe()
        reloaded: List[int] = []
        for uid in snap.get("adapters", []):
            if uid in self.adapters.failing:
                self.n_load_faults += 1
                continue
            self.adapters.load(uid, self.clock)
            if load_cost_fn is not None:
                self.clock += load_cost_fn(uid)
            reloaded.append(uid)
        return reloaded

    def cancel(self, uid: int, forget: bool = False) -> Optional[Request]:
        """Pull one request out of the engine (timeout retry / client
        disconnect).  Frees its KV blocks and adapter pin if running.
        ``forget`` also removes it from this engine's accounting — used
        when the request is re-submitted elsewhere (no double-count);
        a finally-failed request stays accounted here."""
        found: Optional[Request] = None
        for i in range(self._next, len(self._pending)):
            if self._pending[i].uid == uid:
                found = self._pending.pop(i)
                break
        if found is None:
            for req in self.scheduler.waiting:
                if req.uid == uid:
                    found = req
                    break
            if found is not None:
                self.scheduler.waiting = type(self.scheduler.waiting)(
                    r for r in self.scheduler.waiting if r.uid != uid)
        if found is None and uid in self.scheduler._pos:
            found = self.scheduler.running[self.scheduler._pos[uid]]
            self.scheduler._remove_running(found)
            self.kv.free(uid)
            self.adapters.unpin(found.adapter)
            if self.prefix is not None:
                self.prefix.release(uid)
        if found is not None and forget:
            self._accepted = [r for r in self._accepted if r.uid != uid]
        return found

    # ------------------------------------------------------------------ #
    def run(self, requests: List[Request], horizon: Optional[float] = None,
            record_trace: bool = False) -> ServingMetrics:
        """Single-shot: submit the whole stream, run to horizon/completion,
        summarize.  Identical semantics to the pre-resumable engine."""
        self.reset_stream()
        self.submit(requests)
        self.run_until(horizon if horizon is not None else math.inf,
                       record_trace=record_trace)
        return self.finalize()

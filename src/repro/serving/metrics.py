"""Serving metrics: throughput / ITL / TTFT + starvation detection."""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from .request import Request


@dataclasses.dataclass
class ServingMetrics:
    throughput: float          # output tokens / s
    itl: float                 # mean inter-token latency (s)
    ttft: float                # mean time-to-first-token (s)
    ideal_throughput: float    # offered output tokens / s
    duration: float
    n_finished: int
    n_preemptions: int
    max_kv_used: float = 0.0
    n_loads: int = 0

    @property
    def starved(self) -> bool:
        """Paper definition: observed < 90% of ideal throughput."""
        if self.ideal_throughput <= 0:
            return False
        return self.throughput < 0.9 * self.ideal_throughput


def summarize(reqs: List[Request], duration: float,
              offered_tokens: float, max_kv_used: float = 0.0,
              n_loads: int = 0) -> ServingMetrics:
    finished = [r for r in reqs if r.finished_at is not None]
    out_tokens = sum(r.generated for r in reqs)
    itls = [r.itl for r in finished if r.itl is not None]
    ttfts = [r.ttft for r in reqs if r.ttft is not None]
    return ServingMetrics(
        throughput=out_tokens / duration if duration > 0 else 0.0,
        itl=float(np.mean(itls)) if itls else 0.0,
        ttft=float(np.mean(ttfts)) if ttfts else 0.0,
        ideal_throughput=offered_tokens / duration if duration > 0 else 0.0,
        duration=duration,
        n_finished=len(finished),
        n_preemptions=sum(r.n_preemptions for r in reqs),
        max_kv_used=max_kv_used,
        n_loads=n_loads,
    )


def smape(a: float, b: float) -> float:
    """Symmetric mean absolute percentage error of two scalars (%)."""
    if a == 0 and b == 0:
        return 0.0
    return 100.0 * abs(a - b) / ((abs(a) + abs(b)) / 2.0)


def smape_vec(xs, ys) -> float:
    xs, ys = np.asarray(xs, float), np.asarray(ys, float)
    denom = (np.abs(xs) + np.abs(ys)) / 2.0
    mask = denom > 0
    if not mask.any():
        return 0.0
    return float(100.0 * np.mean(np.abs(xs - ys)[mask] / denom[mask]))

"""Serving metrics: throughput / ITL / TTFT + starvation detection.

Beyond the paper's aggregate starvation rule (<90% of offered
throughput), metrics carry the request-level view scheduling policies
are compared on: per-adapter starved-request counters (a request that
arrived inside the measured window but never received its first token)
and the TTFT tail (p50/p99) — a policy can hold aggregate throughput
while quietly starving one adapter, and these fields expose it.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

from .request import Request


@dataclasses.dataclass
class ServingMetrics:
    throughput: float          # output tokens / s
    itl: float                 # mean inter-token latency (s)
    ttft: float                # mean time-to-first-token (s)
    ideal_throughput: float    # offered output tokens / s
    duration: float
    n_finished: int
    n_preemptions: int
    max_kv_used: float = 0.0
    n_loads: int = 0
    ttft_p50: float = 0.0      # TTFT median (s), 0 when nothing served
    ttft_p99: float = 0.0      # TTFT 99th percentile (s)
    n_starved_requests: int = 0  # arrived but never got a first token
    starved_per_adapter: Dict[int, int] = dataclasses.field(
        default_factory=dict)  # adapter uid -> starved request count
    # reliability counters (all 0 on the healthy path — defaults keep
    # pre-fault-layer runs bitwise-identical)
    n_timeouts: int = 0        # deadline expiries observed
    n_retries: int = 0         # re-submissions performed
    n_failed_requests: int = 0  # requests explicitly failed (retries spent)
    n_load_faults: int = 0     # adapter preloads/restores refused by faults
    # shared-prefix cache counters (all 0 with the cache off — defaults
    # keep pre-prefix-cache runs bitwise-identical)
    n_prefix_hits: int = 0       # admissions that reused a cached prefix
    n_prefix_misses: int = 0     # prefix-carrying admissions that did not
    n_prefix_evictions: int = 0  # idle (zero-ref) entries reclaimed
    prefix_tokens_saved: int = 0  # prefill tokens skipped via hits
    # raw per-request TTFT samples: ``ClusterMetrics.aggregate`` pools
    # these across replicas to compute *exact* cluster percentiles (a
    # finished-weighted mean of per-replica percentiles is biased
    # whenever replicas see different TTFT distributions)
    ttft_samples: List[float] = dataclasses.field(default_factory=list)

    @property
    def starved(self) -> bool:
        """Paper definition: observed < 90% of ideal throughput."""
        if self.ideal_throughput <= 0:
            return False
        return self.throughput < 0.9 * self.ideal_throughput


# --- canonical twin-equivalence contract ------------------------------
# Every ``ServingMetrics`` field must appear in exactly one of the three
# tuples below; ``repro.analysis`` (rule twin-metrics-fields) fails the
# build otherwise.  Tests compare object-mode engines/twins against the
# SoA fast twins field-by-field over TWIN_EXACT_FIELDS and require
# bitwise equality — this tuple IS the paper's twin-fidelity contract.
TWIN_EXACT_FIELDS = (
    "throughput", "ideal_throughput", "duration", "n_finished",
    "n_preemptions", "n_loads", "max_kv_used", "ttft",
    "ttft_p50", "ttft_p99", "n_starved_requests", "starved_per_adapter",
    "n_timeouts", "n_retries", "n_failed_requests", "n_load_faults",
    "n_prefix_hits", "n_prefix_misses", "n_prefix_evictions",
    "prefix_tokens_saved",
)

# Compared with a float tolerance only: the object path averages ITL
# per request then over requests, the SoA path telescopes token gaps —
# algebraically equal, but the summation orders differ in the last ulp.
TWIN_TOLERANT_FIELDS = ("itl",)

# Raw per-request sample pools (order-sensitive lists, not aggregates):
# consumed by ``ClusterMetrics.aggregate`` for exact cluster
# percentiles, compared as multisets where tests need them.
TWIN_SAMPLE_FIELDS = ("ttft_samples",)


def ttft_percentiles(ttfts) -> Dict[str, float]:
    """p50/p99 of a TTFT sample (0.0 when empty) — shared by the
    object-mode ``summarize`` and the fast twin's vectorized finalize so
    both compute bit-identical values."""
    if len(ttfts) == 0:
        return {"p50": 0.0, "p99": 0.0}
    arr = np.asarray(ttfts, float)
    return {"p50": float(np.percentile(arr, 50)),
            "p99": float(np.percentile(arr, 99))}


def summarize(reqs: List[Request], duration: float,
              offered_tokens: float, max_kv_used: float = 0.0,
              n_loads: int = 0, n_load_faults: int = 0,
              n_prefix_hits: int = 0, n_prefix_misses: int = 0,
              n_prefix_evictions: int = 0,
              prefix_tokens_saved: int = 0) -> ServingMetrics:
    finished = [r for r in reqs if r.finished_at is not None]
    out_tokens = sum(r.generated for r in reqs)
    itls = [r.itl for r in finished if r.itl is not None]
    ttfts = [r.ttft for r in reqs if r.ttft is not None]
    pct = ttft_percentiles(ttfts)
    starved_per_adapter: Dict[int, int] = {}
    for r in reqs:
        if r.arrival <= duration and r.first_token_at is None:
            starved_per_adapter[r.adapter] = \
                starved_per_adapter.get(r.adapter, 0) + 1
    return ServingMetrics(
        throughput=out_tokens / duration if duration > 0 else 0.0,
        itl=float(np.mean(itls)) if itls else 0.0,
        ttft=float(np.mean(ttfts)) if ttfts else 0.0,
        ideal_throughput=offered_tokens / duration if duration > 0 else 0.0,
        duration=duration,
        n_finished=len(finished),
        n_preemptions=sum(r.n_preemptions for r in reqs),
        max_kv_used=max_kv_used,
        n_loads=n_loads,
        ttft_p50=pct["p50"],
        ttft_p99=pct["p99"],
        n_starved_requests=sum(starved_per_adapter.values()),
        starved_per_adapter=starved_per_adapter,
        n_timeouts=sum(r.n_timeouts for r in reqs),
        n_retries=sum(r.n_retries for r in reqs),
        n_failed_requests=sum(1 for r in reqs if r.failed_at is not None),
        n_load_faults=n_load_faults,
        n_prefix_hits=n_prefix_hits,
        n_prefix_misses=n_prefix_misses,
        n_prefix_evictions=n_prefix_evictions,
        prefix_tokens_saved=prefix_tokens_saved,
        ttft_samples=[float(t) for t in ttfts],
    )


def smape(a: float, b: float) -> float:
    """Symmetric mean absolute percentage error of two scalars (%)."""
    if a == 0 and b == 0:
        return 0.0
    return 100.0 * abs(a - b) / ((abs(a) + abs(b)) / 2.0)


def smape_vec(xs, ys) -> float:
    xs, ys = np.asarray(xs, float), np.asarray(ys, float)
    denom = (np.abs(xs) + np.abs(ys)) / 2.0
    mask = denom > 0
    if not mask.any():
        return 0.0
    return float(100.0 * np.mean(np.abs(xs - ys)[mask] / denom[mask]))

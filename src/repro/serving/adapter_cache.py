"""Adapter slot cache: fixed GPU slots, LRU eviction (vLLM semantics).

``slots`` is the paper's tunable server hyper-parameter: set below the
number of served adapters it time-shares GPU slots via CPU<->GPU swaps
(with the Fig. 4 loading cost); set too low under high rates it starves
(Fig. 6).  Adapters pinned by running requests cannot be evicted.
"""
from __future__ import annotations

from typing import Dict, Optional


class AdapterSlotCache:
    """vLLM mode: a fixed number of pre-allocated GPU adapter slots.

    S-LoRA mode (``dynamic=True``, paper §V-B): no fixed slot count —
    adapter weights share the unified paged memory pool with KV blocks.
    The engine passes a ``reserve(uid)/release(uid)`` pair that charges
    the adapter's footprint against the KV pool; idle adapters are
    evicted LRU under memory pressure (see Scheduler.free_adapter_memory).
    """

    def __init__(self, slots: int, dynamic: bool = False,
                 reserve=None, release=None):
        self.slots = slots
        self.dynamic = dynamic
        self._reserve = reserve
        self._release = release
        self.loaded: Dict[int, float] = {}     # adapter uid -> last-use time
        self.pinned: Dict[int, int] = {}       # adapter uid -> #running reqs
        self.load_count = 0
        self.evict_count = 0
        self.failing: set = set()              # uids whose loads fault-fail

    def is_loaded(self, uid: int) -> bool:
        return uid in self.loaded

    def can_load(self, uid: int) -> bool:
        # pinned adapters are always loaded (pin follows load; a pinned
        # adapter is unevictable), so "some loaded adapter is unpinned"
        # reduces to an O(1) size comparison — this predicate runs once
        # per waiting request per step, the engine's hottest path.
        if uid in self.loaded:
            return True
        if uid in self.failing:
            return False
        if self.dynamic:
            return self._reserve is None or self._reserve(uid, dry=True) \
                or len(self.pinned) < len(self.loaded)
        if len(self.loaded) < self.slots:
            return True
        return len(self.pinned) < len(self.loaded)

    def evict(self, uid: int) -> bool:
        """Evict a specific adapter (migration source side).  Refuses when
        the adapter is pinned by running requests or not resident."""
        if uid not in self.loaded or self.pinned.get(uid, 0) > 0:
            return False
        del self.loaded[uid]
        self.evict_count += 1
        if self.dynamic and self._release is not None:
            self._release(uid)
        return True

    def evict_idle_lru(self) -> Optional[int]:
        victims = [a for a in self.loaded if self.pinned.get(a, 0) == 0]
        if not victims:
            return None
        lru = min(victims, key=lambda a: self.loaded[a])
        del self.loaded[lru]
        self.evict_count += 1
        if self.dynamic and self._release is not None:
            self._release(lru)
        return lru

    def load(self, uid: int, now: float) -> bool:
        """Returns True if a (cold) load happened."""
        if uid in self.loaded:
            self.loaded[uid] = now
            return False
        if self.dynamic:
            while self._reserve is not None and not self._reserve(uid):
                if self.evict_idle_lru() is None:
                    raise RuntimeError("no memory for adapter weights")
        elif len(self.loaded) >= self.slots:
            if self.evict_idle_lru() is None:
                raise RuntimeError("no evictable adapter slot")
        self.loaded[uid] = now
        self.load_count += 1
        return True

    def pin(self, uid: int) -> None:
        self.pinned[uid] = self.pinned.get(uid, 0) + 1

    def unpin(self, uid: int) -> None:
        n = self.pinned.get(uid, 0) - 1
        if n <= 0:
            self.pinned.pop(uid, None)
        else:
            self.pinned[uid] = n

    def touch(self, uid: int, now: float) -> None:
        if uid in self.loaded:
            self.loaded[uid] = now

"""Executors: supply the step-time components of Eq. (1) to the engine.

``JaxExecutor`` actually runs a (reduced) model's prefill/decode with
per-request LoRA adapters through the real JAX code path and reports
measured wall times — the honest closed loop used by the tests.

``SyntheticExecutor`` reports times from a hidden hardware profile
(defaults calibrated to the paper's H100 + Llama-3.1-8B magnitudes).  It
lets the engine play the role of the paper's *real system* at full scale
(hour-long horizons, hundreds of adapters) on a CPU-only box: the Digital
Twin never sees the profile constants — it must recover them from
benchmark data, exactly as the paper fits its estimators from real
benchmarks.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional

import numpy as np

from .scheduler import StepPlan


@dataclasses.dataclass
class StepTiming:
    sched: float
    load: float
    model: float

    @property
    def total(self) -> float:
        return self.sched + self.load + self.model


@dataclasses.dataclass
class HardwareProfile:
    """Hidden ground-truth constants of the synthetic serving node."""
    name: str = "h100-llama8b"
    # Lat_sched = s1*R_run + s2*R_wait + s3*R_wait*(slots/adapters)
    s1: float = 8e-6
    s2: float = 4e-6
    s3: float = 2.5e-5
    sched_base: float = 4e-4
    # Lat_model = m1*R_run + m2*prefill_tokens + m_base
    m1: float = 2.2e-4
    m2: float = 6.5e-6
    m_base: float = 2.4e-2
    # Lat_adapters (multiplicative) = 1 + a1*A_unique (+a0 if any adapter)
    a0: float = 0.06
    a1: float = 0.004
    # loading: seconds per rank unit from cpu / disk
    load_cpu_per_rank: float = 1.1e-3
    load_cpu_base: float = 8e-3
    load_disk_mult: float = 1.7
    # memory model (tokens of KV per device after weights)
    total_kv_tokens: int = 200_000
    kv_tokens_per_rank_slot: float = 220.0
    noise: float = 0.015

    def kv_capacity(self, slots: int, mean_rank: float) -> int:
        cap = self.total_kv_tokens - \
            int(slots * mean_rank / 8.0 * self.kv_tokens_per_rank_slot)
        return max(cap, 0)


class SyntheticExecutor:
    def __init__(self, profile: Optional[HardwareProfile] = None,
                 ranks: Optional[Dict[int, int]] = None,
                 slots: int = 0, n_adapters: int = 1, seed: int = 0):
        self.profile = profile or HardwareProfile()
        self.ranks = ranks or {}
        self.slots = max(slots, 1)
        self.n_adapters = max(n_adapters, 1)
        self.rng = np.random.default_rng(seed)

    def _noise(self) -> float:
        p = self.profile
        return float(1.0 + self.rng.normal(0.0, p.noise)) if p.noise else 1.0

    def step(self, plan: StepPlan, n_waiting: int) -> StepTiming:
        p = self.profile
        r_run = len(plan.running)
        sched = (p.sched_base + p.s1 * r_run + p.s2 * n_waiting
                 + p.s3 * n_waiting * (self.slots / self.n_adapters))
        load = 0.0
        for uid in plan.cold_loads:
            rank = self.ranks.get(uid, 8)
            load += (p.load_cpu_base + p.load_cpu_per_rank * rank)
        model = p.m_base + p.m1 * r_run + p.m2 * plan.prefill_tokens
        a = len(plan.unique_adapters)
        adapters_mult = 1.0 + (p.a0 + p.a1 * a if a > 0 else 0.0)
        model *= adapters_mult
        return StepTiming(sched=sched * self._noise(),
                          load=load * self._noise(),
                          model=model * self._noise())


class JaxExecutor:
    """Runs a real reduced model on CPU, one decode step per engine step.

    Uses padded static batch shapes (requests packed into a fixed-capacity
    batch with an active mask) so every step hits the same jit cache entry.
    """

    def __init__(self, model, params, lora, max_batch: int = 8,
                 cache_len: int = 256):
        import jax
        import jax.numpy as jnp
        self.jax, self.jnp = jax, jnp
        self.model = model
        self.params = params
        self.lora = lora
        self.max_batch = max_batch
        self.cache = model.init_cache(max_batch, cache_len)
        self.tokens = jnp.zeros((max_batch, 1), jnp.int32)
        self._decode = jax.jit(model.decode_step)
        self._slot_of: Dict[int, int] = {}
        # warmup
        idx = jnp.zeros((max_batch,), jnp.int32)
        out = self._decode(params, lora, self.cache, self.tokens, idx)
        jax.block_until_ready(out[0])

    def step(self, plan: StepPlan, n_waiting: int) -> StepTiming:
        jnp = self.jnp
        t0 = time.perf_counter()
        idx = np.zeros((self.max_batch,), np.int32)
        for i, req in enumerate(plan.running[: self.max_batch]):
            idx[i] = req.adapter % max(self.lora_count(), 1)
        t_sched = time.perf_counter() - t0

        t1 = time.perf_counter()
        logits, self.cache = self._decode(
            self.params, self.lora, self.cache, self.tokens,
            jnp.asarray(idx))
        self.jax.block_until_ready(logits)
        # emulate prefill cost: extra decode steps pro-rated by tokens
        t_model = time.perf_counter() - t1
        if plan.prefill_tokens:
            t_model *= 1.0 + plan.prefill_tokens / max(len(plan.running), 1)
        t_load = 0.002 * len(plan.cold_loads)
        return StepTiming(sched=t_sched, load=t_load, model=t_model)

    def lora_count(self) -> int:
        seg = self.lora["segments"][0]["blocks"][0]
        for v in seg.values():
            return v.shape[1]
        return 1

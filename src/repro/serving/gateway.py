"""Async open-loop serving gateway over the resumable ``ServingEngine``.

Everything below ``repro.serving`` runs on a *virtual* clock advanced by
executor-reported step times; until this module the only ways to drive
it were closed-loop: ``ServingEngine.run`` (every request exists up
front) and the cluster's epoch windows (``ServingCluster.run_online``).
``AsyncGateway`` is the open-loop front-end: requests are ``submit()``ed
as they arrive, the engine advances via ``run_until`` between arrivals,
and per-token streaming callbacks fire off the step loop (the engine's
``on_token`` hook) into per-request SSE-shaped chunk streams.

Layers, mirroring Ray Serve's ``LLMRouter``/``LLMServer`` split:

* ``AsyncGateway``      — lifecycle + admission control over one engine
                          replica (the ``LLMServer`` side);
* ``GatewayHTTPServer`` — optional OpenAI-style ``/v1/completions``
                          binding on stdlib ``asyncio.start_server``
                          (the router/ingress side; no new deps);
* arrival drivers       — ``repro.core.workload.open_loop_arrivals``
                          (lazy per-adapter Poisson) and
                          ``replay_trace`` (recorded-trace replay).

**Admission control / backpressure** (S-LoRA-style early rejection): a
request is refused with a 429-equivalent ``Rejected`` result when
``queue_depth x predicted_service_time`` exceeds the SLO budget, where
the service time comes from the fitted Eq. (1) estimators
(``estimator_admission``).  Rejections are counted per adapter in
``GatewayMetrics``.

**Determinism guard**: in driven mode with admission control off, the
gateway executes exactly the step sequence of a closed-loop
``ServingEngine.run`` on the same request list — end-state
``ServingMetrics`` (finished counts, token counters, pooled TTFT
samples) are identical (``tests/test_gateway.py`` pins this).  With
admission control on, rejected requests never reach the engine, which
is the documented divergence.
"""
from __future__ import annotations

import asyncio
import dataclasses
import json
from typing import Callable, Dict, Iterable, List, Optional, Union

from .engine import ServingEngine
from .metrics import ServingMetrics
from .request import Request

_END = object()          # stream sentinel


# --------------------------------------------------------------------------- #
# results: completions, streams, rejections
# --------------------------------------------------------------------------- #

def completion_chunk(req: Request, t: float) -> dict:
    """One OpenAI-style streaming chunk for one generated token.

    The simulation has no detokenizer, so ``text`` is a placeholder
    token; ``created`` is the *virtual* clock (deterministic), not wall
    time."""
    return {
        "id": f"cmpl-{req.uid}",
        "object": "text_completion.chunk",
        "created": round(t, 6),
        "model": f"adapter-{req.adapter}",
        "choices": [{
            "index": 0,
            "text": "tok",
            "token_index": req.generated - 1,
            "finish_reason": "stop" if req.done else None,
        }],
    }


@dataclasses.dataclass
class Completion:
    """A finished non-streaming completion."""
    request: Request

    def to_json(self) -> dict:
        req = self.request
        return {
            "id": f"cmpl-{req.uid}",
            "object": "text_completion",
            "created": round(req.finished_at or 0.0, 6),
            "model": f"adapter-{req.adapter}",
            "choices": [{
                "index": 0,
                "text": " ".join(["tok"] * req.generated),
                "finish_reason": "stop" if req.done else "length",
            }],
            "usage": {
                "prompt_tokens": req.prompt_len,
                "completion_tokens": req.generated,
                "total_tokens": req.prompt_len + req.generated,
            },
        }


@dataclasses.dataclass
class Rejected:
    """429-equivalent admission refusal (503 while draining)."""
    request: Request
    reason: str
    status: int = 429

    def to_json(self) -> dict:
        return {"error": {
            "message": self.reason,
            "type": ("unavailable" if self.status == 503
                     else "overloaded"),
            "code": self.status,
        }}


class CompletionStream:
    """Async iterator of SSE-shaped chunks for one streamed request.

    Chunks are pushed synchronously off the engine step loop (the
    ``on_token`` hook) and consumed with ``async for``; iteration ends
    after the request's final token (or at gateway shutdown, for a
    request cut off by a horizon)."""

    def __init__(self, request: Request):
        self.request = request
        self._q: asyncio.Queue = asyncio.Queue()
        self.n_chunks = 0

    def _push(self, item) -> None:
        self._q.put_nowait(item)

    def __aiter__(self) -> "CompletionStream":
        return self

    async def __anext__(self) -> dict:
        item = await self._q.get()
        if item is _END:
            raise StopAsyncIteration
        self.n_chunks += 1
        return item


# --------------------------------------------------------------------------- #
# admission control
# --------------------------------------------------------------------------- #

@dataclasses.dataclass
class AdmissionControl:
    """Backpressure gate: refuse a request when the engine's predicted
    backlog — ``queue_depth x service_time(request)`` seconds — exceeds
    ``slo_budget``.  ``service_time`` predicts the marginal seconds one
    queued request adds (see ``estimator_admission`` for the fitted
    Eq. (1) version)."""
    slo_budget: float
    service_time: Callable[[Request], float]

    def decide(self, engine: ServingEngine, req: Request) -> Optional[str]:
        """None = admit; otherwise the rejection reason."""
        predicted = engine.queue_depth * float(self.service_time(req))
        if predicted > self.slo_budget:
            return (f"predicted backlog {predicted:.2f}s exceeds SLO "
                    f"budget {self.slo_budget:.2f}s "
                    f"(queue_depth={engine.queue_depth})")
        return None


def estimator_admission(est, length_stats: Dict[str, float],
                        slo_budget: float) -> AdmissionControl:
    """Admission control with the per-request service time predicted by
    the fitted Eq. (1) estimators: one batch-of-one prefill step at the
    mean prompt length plus one decode step per mean output token — a
    conservative (serial) upper bound on the marginal backlog cost of
    one queued request."""
    out_mean = max(float(length_stats.get("out_mean", 1.0)), 1.0)
    in_mean = int(length_stats.get("in_mean", 1.0))
    per_request = (est.lat_model(1, in_mean)
                   + (out_mean - 1.0) * est.lat_model(1, 0)) \
        * est.lat_adapters(1)
    return AdmissionControl(slo_budget=slo_budget,
                            service_time=lambda req: per_request)


# --------------------------------------------------------------------------- #
# gateway metrics / report
# --------------------------------------------------------------------------- #

@dataclasses.dataclass
class GatewayMetrics:
    """Front-end counters (the engine's ``ServingMetrics`` cover the
    admitted stream; these cover what happened at the door)."""
    n_submitted: int = 0
    n_admitted: int = 0
    n_rejected: int = 0                  # admission-control refusals
    n_rejected_draining: int = 0         # refused because shutting down
    rejected_per_adapter: Dict[int, int] = dataclasses.field(
        default_factory=dict)
    n_streamed_tokens: int = 0           # on_token callback firings
    n_streams: int = 0                   # streaming requests opened

    def reject(self, adapter: int, draining: bool = False) -> None:
        self.n_rejected += 1
        if draining:
            self.n_rejected_draining += 1
        self.rejected_per_adapter[adapter] = \
            self.rejected_per_adapter.get(adapter, 0) + 1


@dataclasses.dataclass
class GatewayReport:
    """Outcome of one gateway lifetime: the engine's end-state metrics
    plus the front-end counters."""
    serving: ServingMetrics
    gateway: GatewayMetrics
    duration: float

    def summary(self) -> dict:
        s, g = self.serving, self.gateway
        return {
            "duration_s": round(self.duration, 3),
            "throughput_tok_s": round(s.throughput, 1),
            "ttft_p50_ms": round(s.ttft_p50 * 1e3, 1),
            "ttft_p99_ms": round(s.ttft_p99 * 1e3, 1),
            "n_finished": s.n_finished,
            "n_starved": s.n_starved_requests,
            "n_admitted": g.n_admitted,
            "n_rejected": g.n_rejected,
            "rejected_per_adapter": dict(g.rejected_per_adapter),
            "n_streamed_tokens": g.n_streamed_tokens,
        }


# --------------------------------------------------------------------------- #
# the gateway
# --------------------------------------------------------------------------- #

class AsyncGateway:
    """Asyncio open-loop front-end over one resumable ``ServingEngine``.

    Two driving modes (one gateway instance serves one lifetime; build a
    fresh gateway + engine per run):

    * **driven** — ``await gateway.run(arrivals)``: iterate an arrival
      process (any iterable of ``Request`` in arrival order, e.g.
      ``open_loop_arrivals`` or ``replay_trace``), advancing the engine
      to each arrival with ``run_until(arrival, strict=True)`` before
      offering it, then drain.  Deterministic: with admission off this
      reproduces ``ServingEngine.run`` bit-for-bit.
    * **live** — ``await gateway.start()`` arms a pump task that ticks
      the engine's virtual clock against wall time (``time_scale``
      virtual seconds per wall second); ``await gateway.submit(...)``
      stamps each caller's request with the current virtual time (this
      is what the HTTP binding calls); ``await gateway.shutdown()``
      stops admitting, drains in-flight work, and flushes metrics.
    """

    def __init__(self, engine: ServingEngine,
                 admission: Optional[AdmissionControl] = None,
                 tick: float = 0.02, time_scale: float = 1.0):
        self.engine = engine
        self.admission = admission
        self.tick = tick                  # live-mode pump period (wall s)
        self.time_scale = time_scale      # live-mode virtual s per wall s
        self.metrics = GatewayMetrics()
        self.state = "idle"               # idle|serving|draining|stopped
        self.trace: List[Request] = []    # every offered request, in order
        self._streams: Dict[int, CompletionStream] = {}
        self._done_events: Dict[int, asyncio.Event] = {}
        self._pump_task: Optional[asyncio.Task] = None
        self._t0: Optional[float] = None
        self._uid = 0
        engine.on_token = self._on_token

    # ------------------------------------------------------------------ #
    # token fan-out (called synchronously off the engine step loop)
    # ------------------------------------------------------------------ #
    def _on_token(self, req: Request, t: float) -> None:
        self.metrics.n_streamed_tokens += 1
        stream = self._streams.get(req.uid)
        if stream is not None:
            stream._push(completion_chunk(req, t))
            if req.done:
                stream._push(_END)
                del self._streams[req.uid]
        if req.done:
            ev = self._done_events.pop(req.uid, None)
            if ev is not None:
                ev.set()

    # ------------------------------------------------------------------ #
    # admission (shared by both modes)
    # ------------------------------------------------------------------ #
    def offer(self, req: Request, stream: bool = False
              ) -> Union[Request, CompletionStream, Rejected]:
        """Synchronous admission decision + enqueue for one arrival.

        Returns the request itself (admitted), a ``CompletionStream``
        (admitted, ``stream=True``), or a ``Rejected`` (admission gate
        tripped, or the gateway is draining — status 503)."""
        self.metrics.n_submitted += 1
        self.trace.append(req)
        if self.state in ("draining", "stopped"):
            self.metrics.reject(req.adapter, draining=True)
            return Rejected(req, "gateway is draining", status=503)
        if self.admission is not None:
            reason = self.admission.decide(self.engine, req)
            if reason is not None:
                self.metrics.reject(req.adapter)
                return Rejected(req, reason)
        self.engine.submit([req])
        self.metrics.n_admitted += 1
        if stream:
            s = CompletionStream(req)
            self._streams[req.uid] = s
            self.metrics.n_streams += 1
            return s
        return req

    # ------------------------------------------------------------------ #
    # driven mode
    # ------------------------------------------------------------------ #
    async def run(self, arrivals: Iterable[Request],
                  duration: Optional[float] = None, drain: bool = True,
                  want_stream: Optional[Callable[[Request], bool]] = None
                  ) -> GatewayReport:
        """Serve an open-loop arrival process end to end (driven mode).

        ``arrivals`` yields requests in nondecreasing arrival order; the
        engine is advanced to each arrival (``run_until(arrival,
        strict=True)``) before the admission decision, so the controller
        always sees the queue depth *at* the arrival instant.  Arrivals
        at or past ``duration`` are dropped at the door.  With ``drain``
        every admitted request is finished before the report; otherwise
        the engine stops once its clock reaches ``duration`` (matching
        closed-loop ``run(horizon=duration)`` semantics)."""
        if self.state != "idle":
            raise RuntimeError(f"gateway already {self.state}")
        self.engine.reset_stream()
        self.state = "serving"
        for req in arrivals:
            if duration is not None and req.arrival >= duration:
                break
            self.engine.run_until(req.arrival, strict=True)
            self.offer(req, stream=bool(want_stream and want_stream(req)))
            await asyncio.sleep(0)       # let stream consumers breathe
        return await self.shutdown(duration=duration, drain=drain)

    # ------------------------------------------------------------------ #
    # live mode
    # ------------------------------------------------------------------ #
    async def start(self) -> None:
        """Arm live mode: a background pump advances the engine's
        virtual clock against wall time until ``shutdown``."""
        if self.state != "idle":
            raise RuntimeError(f"gateway already {self.state}")
        self.engine.reset_stream()
        self.state = "serving"
        self._t0 = asyncio.get_running_loop().time()
        self._pump_task = asyncio.create_task(self._pump())

    async def _pump(self) -> None:
        loop = asyncio.get_running_loop()
        while True:
            await asyncio.sleep(self.tick)
            target = (loop.time() - self._t0) * self.time_scale
            self.engine.run_until(target, strict=True)

    def _virtual_now(self) -> float:
        if self._t0 is None:
            return self.engine.clock
        elapsed = asyncio.get_running_loop().time() - self._t0
        return max(self.engine.clock, elapsed * self.time_scale)

    async def submit(self, adapter: int, prompt_len: int, output_len: int,
                     stream: bool = False,
                     arrival: Optional[float] = None
                     ) -> Union[Completion, CompletionStream, Rejected]:
        """Live-mode entry point (what the HTTP handlers call): stamp
        the request with the current virtual time, admit or reject, and
        either return the chunk stream immediately or await the
        completed request."""
        req = Request(uid=self._next_uid(), adapter=adapter,
                      arrival=self._virtual_now() if arrival is None
                      else arrival,
                      prompt_len=max(int(prompt_len), 1),
                      output_len=max(int(output_len), 1))
        res = self.offer(req, stream=stream)
        if isinstance(res, (Rejected, CompletionStream)):
            return res
        ev = asyncio.Event()
        self._done_events[req.uid] = ev
        await ev.wait()
        return Completion(req)

    def _next_uid(self) -> int:
        self._uid += 1
        return self._uid - 1

    # ------------------------------------------------------------------ #
    # shutdown / drain
    # ------------------------------------------------------------------ #
    async def shutdown(self, duration: Optional[float] = None,
                       drain: bool = True) -> GatewayReport:
        """Graceful drain: stop admitting (new offers get a 503
        ``Rejected``), finish in-flight work, flush metrics."""
        self.state = "draining"
        if self._pump_task is not None:
            self._pump_task.cancel()
            try:
                await self._pump_task
            except asyncio.CancelledError:
                pass
            self._pump_task = None
        if drain:
            self.engine.run_until(None)
        elif duration is not None:
            self.engine.run_until(duration)
        serving = self.engine.finalize()
        # close any stream cut off by a no-drain horizon
        for s in self._streams.values():
            s._push(_END)
        self._streams.clear()
        for ev in self._done_events.values():
            ev.set()
        self._done_events.clear()
        self.state = "stopped"
        return GatewayReport(serving=serving, gateway=self.metrics,
                             duration=self.engine.clock)

    # ------------------------------------------------------------------ #
    def snapshot(self) -> dict:
        """Live counters (the ``/v1/metrics`` endpoint)."""
        return {
            "state": self.state,
            "clock_s": round(self.engine.clock, 3),
            "queue_depth": self.engine.queue_depth,
            "n_submitted": self.metrics.n_submitted,
            "n_admitted": self.metrics.n_admitted,
            "n_rejected": self.metrics.n_rejected,
            "rejected_per_adapter": dict(
                self.metrics.rejected_per_adapter),
            "n_streamed_tokens": self.metrics.n_streamed_tokens,
        }


# --------------------------------------------------------------------------- #
# stdlib HTTP binding (optional; no new runtime deps)
# --------------------------------------------------------------------------- #

def sse_format(data) -> bytes:
    """One Server-Sent-Events frame (``data: <json>\\n\\n``)."""
    payload = data if isinstance(data, str) else json.dumps(data)
    return b"data: " + payload.encode() + b"\n\n"


class GatewayHTTPServer:
    """Minimal OpenAI-style HTTP/1.1 binding over ``asyncio.start_server``.

    Routes:

    * ``POST /v1/completions`` — body keys: ``adapter`` (int) or
      ``model`` (``"adapter-<uid>"``), ``prompt`` (string; whitespace
      tokens) or ``prompt_tokens`` (int), ``max_tokens``, ``stream``.
      Responds 200 JSON, 200 ``text/event-stream`` of chunks terminated
      by ``data: [DONE]``, 429 when admission control rejects, or 503
      while draining.
    * ``GET /v1/metrics`` (or ``/metrics``) — gateway counters snapshot.
    * ``GET /v1/health`` — lifecycle state.

    Deliberately *not* a production HTTP server — it exists so the
    gateway can be driven by real sockets without adding a web-framework
    dependency."""

    def __init__(self, gateway: AsyncGateway, host: str = "127.0.0.1",
                 port: int = 0):
        self.gateway = gateway
        self.host = host
        self.port = port
        self._server: Optional[asyncio.AbstractServer] = None

    async def start(self) -> "GatewayHTTPServer":
        self._server = await asyncio.start_server(
            self._handle, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        return self

    async def stop(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None

    # ------------------------------------------------------------------ #
    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        try:
            parsed = await self._read_request(reader)
            if parsed is None:
                return
            method, path, payload = parsed
            if method == "POST" and path == "/v1/completions":
                await self._completions(writer, payload)
            elif method == "GET" and path in ("/v1/metrics", "/metrics"):
                await self._respond(writer, 200, self.gateway.snapshot())
            elif method == "GET" and path == "/v1/health":
                await self._respond(writer, 200,
                                    {"status": self.gateway.state})
            else:
                await self._respond(writer, 404, {"error": {
                    "message": f"no route for {method} {path}",
                    "code": 404}})
        except (ConnectionResetError, BrokenPipeError):
            pass
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionResetError, BrokenPipeError):
                pass

    async def _read_request(self, reader: asyncio.StreamReader):
        line = await reader.readline()
        if not line:
            return None
        parts = line.decode("latin-1").split()
        if len(parts) < 2:
            return None
        method, path = parts[0].upper(), parts[1]
        headers: Dict[str, str] = {}
        while True:
            h = await reader.readline()
            if h in (b"\r\n", b"\n", b""):
                break
            key, _, val = h.decode("latin-1").partition(":")
            headers[key.strip().lower()] = val.strip()
        length = int(headers.get("content-length", 0) or 0)
        body = await reader.readexactly(length) if length else b""
        try:
            payload = json.loads(body) if body else {}
        except json.JSONDecodeError:
            payload = None
        return method, path, payload

    async def _completions(self, writer, payload) -> None:
        if not isinstance(payload, dict):
            await self._respond(writer, 400, {"error": {
                "message": "body must be a JSON object", "code": 400}})
            return
        adapter = payload.get("adapter")
        if adapter is None:
            tail = str(payload.get("model", "adapter-0")).rsplit("-", 1)[-1]
            adapter = int(tail) if tail.isdigit() else 0
        prompt_len = int(payload.get("prompt_tokens", 0) or 0)
        if prompt_len <= 0:
            prompt_len = max(len(str(payload.get("prompt", "")).split()), 1)
        max_tokens = max(int(payload.get("max_tokens", 16)), 1)
        stream = bool(payload.get("stream", False))
        res = await self.gateway.submit(
            adapter=int(adapter), prompt_len=prompt_len,
            output_len=max_tokens, stream=stream)
        if isinstance(res, Rejected):
            await self._respond(writer, res.status, res.to_json())
        elif isinstance(res, CompletionStream):
            writer.write(self._head(200, "text/event-stream"))
            await writer.drain()
            async for chunk in res:
                writer.write(sse_format(chunk))
                await writer.drain()
            writer.write(sse_format("[DONE]"))
            await writer.drain()
        else:
            await self._respond(writer, 200, res.to_json())

    # ------------------------------------------------------------------ #
    _STATUS = {200: "OK", 400: "Bad Request", 404: "Not Found",
               429: "Too Many Requests", 503: "Service Unavailable"}

    def _head(self, status: int, ctype: str,
              length: Optional[int] = None) -> bytes:
        lines = [f"HTTP/1.1 {status} {self._STATUS.get(status, 'OK')}",
                 f"Content-Type: {ctype}", "Connection: close"]
        if length is not None:
            lines.append(f"Content-Length: {length}")
        return ("\r\n".join(lines) + "\r\n\r\n").encode()

    async def _respond(self, writer, status: int, obj: dict) -> None:
        body = json.dumps(obj).encode()
        writer.write(self._head(status, "application/json", len(body)))
        writer.write(body)
        await writer.drain()

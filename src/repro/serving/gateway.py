"""Async open-loop serving gateway over the resumable ``ServingEngine``.

Everything below ``repro.serving`` runs on a *virtual* clock advanced by
executor-reported step times; until this module the only ways to drive
it were closed-loop: ``ServingEngine.run`` (every request exists up
front) and the cluster's epoch windows (``ServingCluster.run_online``).
``AsyncGateway`` is the open-loop front-end: requests are ``submit()``ed
as they arrive, the engine advances via ``run_until`` between arrivals,
and per-token streaming callbacks fire off the step loop (the engine's
``on_token`` hook) into per-request SSE-shaped chunk streams.

Layers, mirroring Ray Serve's ``LLMRouter``/``LLMServer`` split:

* ``AsyncGateway``      — lifecycle + admission control over one engine
                          replica (the ``LLMServer`` side);
* ``GatewayHTTPServer`` — optional OpenAI-style ``/v1/completions``
                          binding on stdlib ``asyncio.start_server``
                          (the router/ingress side; no new deps);
* arrival drivers       — ``repro.core.workload.open_loop_arrivals``
                          (lazy per-adapter Poisson) and
                          ``replay_trace`` (recorded-trace replay).

**Admission control / backpressure** (S-LoRA-style early rejection): a
request is refused with a 429-equivalent ``Rejected`` result when
``queue_depth x predicted_service_time`` exceeds the SLO budget, where
the service time comes from the fitted Eq. (1) estimators
(``estimator_admission``).  Rejections are counted per adapter in
``GatewayMetrics``.

**Determinism guard**: in driven mode with admission control off, the
gateway executes exactly the step sequence of a closed-loop
``ServingEngine.run`` on the same request list — end-state
``ServingMetrics`` (finished counts, token counters, pooled TTFT
samples) are identical (``tests/test_gateway.py`` pins this).  With
admission control on, rejected requests never reach the engine, which
is the documented divergence.
"""
from __future__ import annotations

import asyncio
import dataclasses
import json
import math
from typing import Callable, Dict, Iterable, List, Optional, Union

from .engine import ServingEngine
from .faults import FaultPlan, NoAliveReplicasError, ReliabilityPolicy
from .metrics import ServingMetrics
from .request import Request

_END = object()          # stream sentinel


# --------------------------------------------------------------------------- #
# results: completions, streams, rejections
# --------------------------------------------------------------------------- #

def completion_chunk(req: Request, t: float) -> dict:
    """One OpenAI-style streaming chunk for one generated token.

    The simulation has no detokenizer, so ``text`` is a placeholder
    token; ``created`` is the *virtual* clock (deterministic), not wall
    time."""
    return {
        "id": f"cmpl-{req.uid}",
        "object": "text_completion.chunk",
        "created": round(t, 6),
        "model": f"adapter-{req.adapter}",
        "choices": [{
            "index": 0,
            "text": "tok",
            "token_index": req.generated - 1,
            "finish_reason": "stop" if req.done else None,
        }],
    }


@dataclasses.dataclass
class Completion:
    """A finished non-streaming completion."""
    request: Request

    def to_json(self) -> dict:
        req = self.request
        return {
            "id": f"cmpl-{req.uid}",
            "object": "text_completion",
            "created": round(req.finished_at or 0.0, 6),
            "model": f"adapter-{req.adapter}",
            "choices": [{
                "index": 0,
                "text": " ".join(["tok"] * req.generated),
                "finish_reason": "stop" if req.done else "length",
            }],
            "usage": {
                "prompt_tokens": req.prompt_len,
                "completion_tokens": req.generated,
                "total_tokens": req.prompt_len + req.generated,
            },
        }


@dataclasses.dataclass
class Rejected:
    """429-equivalent admission refusal (503 while draining)."""
    request: Request
    reason: str
    status: int = 429

    def to_json(self) -> dict:
        return {"error": {
            "message": self.reason,
            "type": ("unavailable" if self.status == 503
                     else "overloaded"),
            "code": self.status,
        }}


class CompletionStream:
    """Async iterator of SSE-shaped chunks for one streamed request.

    Chunks are pushed synchronously off the engine step loop (the
    ``on_token`` hook) and consumed with ``async for``; iteration ends
    after the request's final token (or at gateway shutdown, for a
    request cut off by a horizon)."""

    def __init__(self, request: Request):
        self.request = request
        self._q: asyncio.Queue = asyncio.Queue()
        self.n_chunks = 0

    def _push(self, item) -> None:
        self._q.put_nowait(item)

    def __aiter__(self) -> "CompletionStream":
        return self

    async def __anext__(self) -> dict:
        item = await self._q.get()
        if item is _END:
            raise StopAsyncIteration
        self.n_chunks += 1
        return item


# --------------------------------------------------------------------------- #
# admission control
# --------------------------------------------------------------------------- #

@dataclasses.dataclass
class AdmissionControl:
    """Backpressure gate: refuse a request when the engine's predicted
    backlog — ``queue_depth x service_time(request)`` seconds — exceeds
    ``slo_budget``.  ``service_time`` predicts the marginal seconds one
    queued request adds (see ``estimator_admission`` for the fitted
    Eq. (1) version)."""
    slo_budget: float
    service_time: Callable[[Request], float]

    def decide(self, engine: ServingEngine, req: Request) -> Optional[str]:
        """None = admit; otherwise the rejection reason."""
        predicted = engine.queue_depth * float(self.service_time(req))
        if predicted > self.slo_budget:
            return (f"predicted backlog {predicted:.2f}s exceeds SLO "
                    f"budget {self.slo_budget:.2f}s "
                    f"(queue_depth={engine.queue_depth})")
        return None


def estimator_admission(est, length_stats: Dict[str, float],
                        slo_budget: float) -> AdmissionControl:
    """Admission control with the per-request service time predicted by
    the fitted Eq. (1) estimators: one batch-of-one prefill step at the
    mean prompt length plus one decode step per mean output token — a
    conservative (serial) upper bound on the marginal backlog cost of
    one queued request."""
    out_mean = max(float(length_stats.get("out_mean", 1.0)), 1.0)
    in_mean = int(length_stats.get("in_mean", 1.0))
    per_request = (est.lat_model(1, in_mean)
                   + (out_mean - 1.0) * est.lat_model(1, 0)) \
        * est.lat_adapters(1)
    return AdmissionControl(slo_budget=slo_budget,
                            service_time=lambda req: per_request)


# --------------------------------------------------------------------------- #
# gateway metrics / report
# --------------------------------------------------------------------------- #

@dataclasses.dataclass
class GatewayMetrics:
    """Front-end counters (the engine's ``ServingMetrics`` cover the
    admitted stream; these cover what happened at the door)."""
    n_submitted: int = 0
    n_admitted: int = 0
    n_rejected: int = 0                  # admission-control refusals
    n_rejected_draining: int = 0         # refused because shutting down
    rejected_per_adapter: Dict[int, int] = dataclasses.field(
        default_factory=dict)
    n_streamed_tokens: int = 0           # on_token callback firings
    n_streams: int = 0                   # streaming requests opened
    # reliability counters (0 unless a FaultPlan / ReliabilityPolicy is
    # armed or a client actually disconnects)
    n_client_disconnects: int = 0        # cancelled: client went away
    n_timeouts: int = 0                  # per-request deadline expiries
    n_retries: int = 0                   # engine re-submissions
    n_failed_requests: int = 0           # retries spent -> explicit fail
    n_crashes: int = 0                   # engine crash events injected
    n_recoveries: int = 0                # engine restore + rejoin events

    def reject(self, adapter: int, draining: bool = False) -> None:
        self.n_rejected += 1
        if draining:
            self.n_rejected_draining += 1
        self.rejected_per_adapter[adapter] = \
            self.rejected_per_adapter.get(adapter, 0) + 1


@dataclasses.dataclass
class GatewayReport:
    """Outcome of one gateway lifetime: the engine's end-state metrics
    plus the front-end counters."""
    serving: ServingMetrics
    gateway: GatewayMetrics
    duration: float

    def summary(self) -> dict:
        s, g = self.serving, self.gateway
        return {
            "duration_s": round(self.duration, 3),
            "throughput_tok_s": round(s.throughput, 1),
            "ttft_p50_ms": round(s.ttft_p50 * 1e3, 1),
            "ttft_p99_ms": round(s.ttft_p99 * 1e3, 1),
            "n_finished": s.n_finished,
            "n_starved": s.n_starved_requests,
            "n_admitted": g.n_admitted,
            "n_rejected": g.n_rejected,
            "rejected_per_adapter": dict(g.rejected_per_adapter),
            "n_streamed_tokens": g.n_streamed_tokens,
            "n_client_disconnects": g.n_client_disconnects,
            "n_timeouts": g.n_timeouts,
            "n_retries": g.n_retries,
            "n_failed_requests": g.n_failed_requests,
            "n_crashes": g.n_crashes,
            "n_recoveries": g.n_recoveries,
        }


# --------------------------------------------------------------------------- #
# the gateway
# --------------------------------------------------------------------------- #

class AsyncGateway:
    """Asyncio open-loop front-end over one resumable ``ServingEngine``.

    Two driving modes (one gateway instance serves one lifetime; build a
    fresh gateway + engine per run):

    * **driven** — ``await gateway.run(arrivals)``: iterate an arrival
      process (any iterable of ``Request`` in arrival order, e.g.
      ``open_loop_arrivals`` or ``replay_trace``), advancing the engine
      to each arrival with ``run_until(arrival, strict=True)`` before
      offering it, then drain.  Deterministic: with admission off this
      reproduces ``ServingEngine.run`` bit-for-bit.
    * **live** — ``await gateway.start()`` arms a pump task that ticks
      the engine's virtual clock against wall time (``time_scale``
      virtual seconds per wall second); ``await gateway.submit(...)``
      stamps each caller's request with the current virtual time (this
      is what the HTTP binding calls); ``await gateway.shutdown()``
      stops admitting, drains in-flight work, and flushes metrics.
    """

    def __init__(self, engine: ServingEngine,
                 admission: Optional[AdmissionControl] = None,
                 tick: float = 0.02, time_scale: float = 1.0,
                 fault_plan: Optional[FaultPlan] = None,
                 reliability: Optional[ReliabilityPolicy] = None):
        self.engine = engine
        self.admission = admission
        self.tick = tick                  # live-mode pump period (wall s)
        self.time_scale = time_scale      # live-mode virtual s per wall s
        self.metrics = GatewayMetrics()
        self.state = "idle"               # idle|serving|draining|stopped
        self.trace: List[Request] = []    # every offered request, in order
        self._streams: Dict[int, CompletionStream] = {}
        self._done_events: Dict[int, asyncio.Event] = {}
        self._pump_task: Optional[asyncio.Task] = None
        self._t0: Optional[float] = None
        self._uid = 0
        engine.on_token = self._on_token
        # ---- fault injection / reliability (single replica = index 0) --
        self.fault_plan = fault_plan
        self.reliability = reliability
        self._rel_enabled = reliability is not None and reliability.enabled
        self._fault_active = fault_plan is not None or self._rel_enabled
        if fault_plan is not None:
            self._crashes = [c for c in fault_plan.crashes
                             if c.replica == 0]
            self._adapter_evs = [e for e in fault_plan.adapter_faults
                                 if e.replica == 0]
            self._straggler_evs = [e for e in fault_plan.straggler_windows
                                   if e.replica == 0]
            self._exec_evs = [e for e in fault_plan.executor_faults
                              if e.replica == 0]
            self._disconnect_evs = list(fault_plan.disconnects)
        else:
            self._crashes = []
            self._adapter_evs = []
            self._straggler_evs = []
            self._exec_evs = []
            self._disconnect_evs = []
        times: set = set()
        for c in self._crashes:
            times.add(c.at)
            if c.recover_at is not None:
                times.add(c.recover_at)
        for e in self._adapter_evs + self._straggler_evs:
            times.add(e.at)
            if math.isfinite(e.until):
                times.add(e.until)
        for e in self._exec_evs:
            times.add(e.at)
            times.add(e.at + e.duration)
        for e in self._disconnect_evs:
            times.add(e.at)
        self._fault_times = sorted(times)
        self._crash_seen: set = set()
        self._pending_recover: list = []
        self._crash_orphans: List[Request] = []
        self._retry_q: List[Request] = []
        self._inflight: Dict[int, Request] = {}
        self._ckpt = {"clock": 0.0, "adapters": []}

    # ------------------------------------------------------------------ #
    # token fan-out (called synchronously off the engine step loop)
    # ------------------------------------------------------------------ #
    def _on_token(self, req: Request, t: float) -> None:
        self.metrics.n_streamed_tokens += 1
        stream = self._streams.get(req.uid)
        if stream is not None:
            stream._push(completion_chunk(req, t))
            if req.done:
                stream._push(_END)
                del self._streams[req.uid]
        if req.done:
            ev = self._done_events.pop(req.uid, None)
            if ev is not None:
                ev.set()

    # ------------------------------------------------------------------ #
    # admission (shared by both modes)
    # ------------------------------------------------------------------ #
    def offer(self, req: Request, stream: bool = False
              ) -> Union[Request, CompletionStream, Rejected]:
        """Synchronous admission decision + enqueue for one arrival.

        Returns the request itself (admitted), a ``CompletionStream``
        (admitted, ``stream=True``), or a ``Rejected`` (admission gate
        tripped, or the gateway is draining — status 503)."""
        self.metrics.n_submitted += 1
        self.trace.append(req)
        if self.state in ("draining", "stopped"):
            self.metrics.reject(req.adapter, draining=True)
            return Rejected(req, "gateway is draining", status=503)
        if self.engine.halted:
            # crashed (fault injection) and not yet recovered
            self.metrics.reject(req.adapter)
            return Rejected(req, "no alive replicas", status=503)
        if self.admission is not None:
            reason = self.admission.decide(self.engine, req)
            if reason is not None:
                self.metrics.reject(req.adapter)
                return Rejected(req, reason)
        self.engine.submit([req])
        self.metrics.n_admitted += 1
        if self._fault_active:
            self._inflight[req.uid] = req
        if stream:
            s = CompletionStream(req)
            self._streams[req.uid] = s
            self.metrics.n_streams += 1
            return s
        return req

    # ------------------------------------------------------------------ #
    # driven mode
    # ------------------------------------------------------------------ #
    async def run(self, arrivals: Iterable[Request],
                  duration: Optional[float] = None, drain: bool = True,
                  want_stream: Optional[Callable[[Request], bool]] = None
                  ) -> GatewayReport:
        """Serve an open-loop arrival process end to end (driven mode).

        ``arrivals`` yields requests in nondecreasing arrival order; the
        engine is advanced to each arrival (``run_until(arrival,
        strict=True)``) before the admission decision, so the controller
        always sees the queue depth *at* the arrival instant.  Arrivals
        at or past ``duration`` are dropped at the door.  With ``drain``
        every admitted request is finished before the report; otherwise
        the engine stops once its clock reaches ``duration`` (matching
        closed-loop ``run(horizon=duration)`` semantics)."""
        if self.state != "idle":
            raise RuntimeError(f"gateway already {self.state}")
        self.engine.reset_stream()
        self.state = "serving"
        for req in arrivals:
            if duration is not None and req.arrival >= duration:
                break
            self._advance(req.arrival)
            self.offer(req, stream=bool(want_stream and want_stream(req)))
            await asyncio.sleep(0)       # let stream consumers breathe
        return await self.shutdown(duration=duration, drain=drain)

    # ------------------------------------------------------------------ #
    # live mode
    # ------------------------------------------------------------------ #
    async def start(self) -> None:
        """Arm live mode: a background pump advances the engine's
        virtual clock against wall time until ``shutdown``."""
        if self.state != "idle":
            raise RuntimeError(f"gateway already {self.state}")
        self.engine.reset_stream()
        self.state = "serving"
        self._t0 = asyncio.get_running_loop().time()
        self._pump_task = asyncio.create_task(self._pump())

    async def _pump(self) -> None:
        loop = asyncio.get_running_loop()
        while True:
            await asyncio.sleep(self.tick)
            target = (loop.time() - self._t0) * self.time_scale
            self._advance(target)

    def _virtual_now(self) -> float:
        if self._t0 is None:
            return self.engine.clock
        elapsed = asyncio.get_running_loop().time() - self._t0
        return max(self.engine.clock, elapsed * self.time_scale)

    async def submit(self, adapter: int, prompt_len: int, output_len: int,
                     stream: bool = False,
                     arrival: Optional[float] = None,
                     prefix_id: Optional[int] = None,
                     prefix_len: int = 0
                     ) -> Union[Completion, CompletionStream, Rejected]:
        """Live-mode entry point (what the HTTP handlers call): stamp
        the request with the current virtual time, admit or reject, and
        either return the chunk stream immediately or await the
        completed request.  ``prefix_id``/``prefix_len`` tag the leading
        tokens of the prompt as a shared prefix for the engine's
        cross-adapter prefix cache (no-op when the cache is off)."""
        req = Request(uid=self._next_uid(), adapter=adapter,
                      arrival=self._virtual_now() if arrival is None
                      else arrival,
                      prompt_len=max(int(prompt_len), 1),
                      output_len=max(int(output_len), 1),
                      prefix_id=prefix_id,
                      prefix_len=max(int(prefix_len), 0))
        res = self.offer(req, stream=stream)
        if isinstance(res, (Rejected, CompletionStream)):
            return res
        ev = asyncio.Event()
        self._done_events[req.uid] = ev
        await ev.wait()
        return Completion(req)

    def _next_uid(self) -> int:
        self._uid += 1
        return self._uid - 1

    # ------------------------------------------------------------------ #
    # fault injection + request reliability (virtual-time, deterministic)
    # ------------------------------------------------------------------ #
    def _advance(self, target: float) -> None:
        """Advance the engine to virtual time ``target``, segmenting the
        interval at every fault-event boundary, retry release, and
        request deadline so each segment runs under one fault regime.
        With no FaultPlan/ReliabilityPolicy this is exactly
        ``run_until(target, strict=True)`` (the determinism guard)."""
        eng = self.engine
        if not self._fault_active:
            eng.run_until(target, strict=True)
            return
        cursor = min(eng.clock, target)
        while True:
            self._process_events(cursor)
            self._release_retries(cursor)
            nb = self._next_boundary(cursor, target)
            if eng.halted:
                eng.clock = max(eng.clock, nb)   # time passes while down
            elif self._stalled_at(cursor):
                eng.stall_until(nb)              # executor hang: no work
            else:
                self._apply_windows(cursor)
                eng.run_until(nb, strict=True)
                self._ckpt = eng.snapshot()      # last healthy state
            if self._rel_enabled:
                self._check_timeouts(nb)
            if nb >= target:
                return
            cursor = nb

    def _next_boundary(self, t: float, target: float) -> float:
        b = target
        for x in self._fault_times:
            if x > t:
                if x < b:
                    b = x
                break
        for r in self._retry_q:
            if r.retry_at is not None and t < r.retry_at < b:
                b = r.retry_at
        if self._rel_enabled:
            for r in self._inflight.values():
                if (r.finished_at is not None or r.failed_at is not None
                        or r.disconnected_at is not None):
                    continue
                started = (r.retry_at if r.retry_at is not None
                           else r.arrival)
                d = started + self.reliability.timeout_s
                if t < d < b:
                    b = d
        return b

    def _apply_windows(self, t: float) -> None:
        factor = 1.0
        for ev in self._straggler_evs:
            if ev.at <= t < ev.until:
                factor = ev.factor
        self.engine.slow_factor = factor
        self.engine.adapters.failing = {
            ev.adapter for ev in self._adapter_evs if ev.at <= t < ev.until}

    def _stalled_at(self, t: float) -> bool:
        return any(ev.at <= t < ev.at + ev.duration
                   for ev in self._exec_evs)

    def _process_events(self, t: float) -> None:
        eng = self.engine
        for c in self._crashes:
            if c.at <= t and c not in self._crash_seen:
                self._crash_seen.add(c)
                self.metrics.n_crashes += 1
                orphans = eng.drain()            # halts the engine
                if c.recover_at is not None:
                    self._crash_orphans.extend(orphans)
                    self._pending_recover.append(c)
                else:
                    for r in orphans:
                        self._fail(r, t)
        for c in list(self._pending_recover):
            if c.recover_at <= t:
                self._pending_recover.remove(c)
                lcf = (self.reliability.load_cost_fn
                       if self.reliability else None)
                eng.restore(self._ckpt, t, load_cost_fn=lcf)
                self.metrics.n_recoveries += 1
                orphans, self._crash_orphans = self._crash_orphans, []
                for r in sorted(orphans, key=lambda r: r.uid):
                    if (r.disconnected_at is not None
                            or r.failed_at is not None):
                        continue
                    r.generated = 0
                    r.admitted_at = None
                    r.first_token_at = None
                    r.token_times = []
                    r.n_retries += 1
                    self.metrics.n_retries += 1
                    eng.submit([r])
        for ev in list(self._disconnect_evs):
            if ev.at <= t and 0 <= ev.request_index < len(self.trace):
                self._disconnect_evs.remove(ev)
                self.disconnect(self.trace[ev.request_index], at=t)

    def _check_timeouts(self, now: float) -> None:
        if self.engine.halted:
            return                               # orphans already drained
        rel = self.reliability
        retry_uids = {r.uid for r in self._retry_q}
        orphan_uids = {r.uid for r in self._crash_orphans}
        for r in list(self._inflight.values()):
            if (r.finished_at is not None or r.failed_at is not None
                    or r.disconnected_at is not None
                    or r.uid in retry_uids or r.uid in orphan_uids):
                continue
            started = r.retry_at if r.retry_at is not None else r.arrival
            if now < started + rel.timeout_s:
                continue
            will_retry = r.n_retries < rel.max_retries
            got = self.engine.cancel(r.uid, forget=will_retry)
            if got is None:
                continue
            r.n_timeouts += 1
            self.metrics.n_timeouts += 1
            if will_retry:
                r.n_retries += 1
                self.metrics.n_retries += 1
                r.generated = 0
                r.admitted_at = None
                r.first_token_at = None
                r.token_times = []
                r.retry_at = now + rel.backoff(r.n_retries)
                self._retry_q.append(r)
            else:
                self._fail(r, now)

    def _release_retries(self, now: float) -> None:
        if not self._retry_q or self.engine.halted:
            return
        due = [r for r in self._retry_q if r.retry_at <= now]
        if not due:
            return
        self._retry_q = [r for r in self._retry_q if r.retry_at > now]
        for r in sorted(due, key=lambda r: r.uid):
            self.engine.submit([r])

    def _fail(self, req: Request, t: float) -> None:
        req.failed_at = t
        self.metrics.n_failed_requests += 1
        s = self._streams.pop(req.uid, None)
        if s is not None:
            s._push(_END)
        ev = self._done_events.pop(req.uid, None)
        if ev is not None:
            ev.set()

    def disconnect(self, req: Request, at: Optional[float] = None) -> bool:
        """Client went away: cancel the request engine-side (its KV slot
        frees, its adapter unpins), close its stream, and account it
        under ``n_client_disconnects``.  Idempotent; returns False if
        the request already reached a terminal state."""
        if (req.finished_at is not None or req.failed_at is not None
                or req.disconnected_at is not None):
            return False
        if not self.engine.halted:
            self.engine.cancel(req.uid, forget=False)
        self._retry_q = [r for r in self._retry_q if r.uid != req.uid]
        self._crash_orphans = [r for r in self._crash_orphans
                               if r.uid != req.uid]
        req.disconnected_at = (at if at is not None else self.engine.clock)
        self.metrics.n_client_disconnects += 1
        s = self._streams.pop(req.uid, None)
        if s is not None:
            s._push(_END)
        ev = self._done_events.pop(req.uid, None)
        if ev is not None:
            ev.set()
        return True

    def _drain_faulted(self) -> None:
        """Drain when a FaultPlan/ReliabilityPolicy is armed: keep
        advancing virtual time in segments so pending recoveries fire,
        backoff timers elapse, and deadlines expire.  If a pass makes no
        progress (nothing finished, timed out, retried, or recovered and
        the clock is pinned), the stragglers are explicitly failed —
        every admitted request ends in exactly one terminal state."""
        rel = self.reliability
        step = max(rel.timeout_s if self._rel_enabled else 0.0, 1.0)
        vt = self.engine.clock
        prev = None
        for _ in range(100_000):
            live = [r for r in self._inflight.values()
                    if r.finished_at is None and r.failed_at is None
                    and r.disconnected_at is None]
            if not live:
                return
            vt = max(vt, self.engine.clock) + step
            self._advance(vt)
            m = self.metrics
            cur = (self.engine.clock,
                   sum(1 for r in self._inflight.values()
                       if r.finished_at is not None),
                   m.n_timeouts, m.n_retries, m.n_failed_requests,
                   m.n_recoveries, m.n_client_disconnects)
            if cur == prev:
                for r in live:
                    if not self.engine.halted:
                        self.engine.cancel(r.uid, forget=False)
                    self._fail(r, vt)
                return
            prev = cur

    # ------------------------------------------------------------------ #
    # shutdown / drain
    # ------------------------------------------------------------------ #
    async def shutdown(self, duration: Optional[float] = None,
                       drain: bool = True) -> GatewayReport:
        """Graceful drain: stop admitting (new offers get a 503
        ``Rejected``), finish in-flight work, flush metrics."""
        self.state = "draining"
        if self._pump_task is not None:
            self._pump_task.cancel()
            try:
                await self._pump_task
            except asyncio.CancelledError:
                pass
            self._pump_task = None
        if drain:
            if self._fault_active:
                self._drain_faulted()
            else:
                self.engine.run_until(None)
        elif duration is not None:
            if self._fault_active:
                self._advance(duration)
            else:
                self.engine.run_until(duration)
        serving = self.engine.finalize()
        # close any stream cut off by a no-drain horizon
        for s in self._streams.values():
            s._push(_END)
        self._streams.clear()
        for ev in self._done_events.values():
            ev.set()
        self._done_events.clear()
        self.state = "stopped"
        return GatewayReport(serving=serving, gateway=self.metrics,
                             duration=self.engine.clock)

    # ------------------------------------------------------------------ #
    def snapshot(self) -> dict:
        """Live counters (the ``/v1/metrics`` endpoint).

        The ``serving`` section mirrors the twin-equivalence contract:
        the twin-gateway-metrics lint rule requires every
        ``TWIN_EXACT_FIELDS`` name to appear as a literal key here, so
        an operator polling ``/v1/metrics`` sees exactly the fields the
        twins are validated on.
        """
        pc = getattr(self.engine, "prefix", None)
        s = self.engine.finalize()
        return {
            "state": self.state,
            "clock_s": round(self.engine.clock, 3),
            "queue_depth": self.engine.queue_depth,
            "n_submitted": self.metrics.n_submitted,
            "n_admitted": self.metrics.n_admitted,
            "n_rejected": self.metrics.n_rejected,
            "rejected_per_adapter": dict(
                self.metrics.rejected_per_adapter),
            "n_streamed_tokens": self.metrics.n_streamed_tokens,
            "n_client_disconnects": self.metrics.n_client_disconnects,
            "n_timeouts": self.metrics.n_timeouts,
            "n_retries": self.metrics.n_retries,
            "n_failed_requests": self.metrics.n_failed_requests,
            "n_crashes": self.metrics.n_crashes,
            "n_recoveries": self.metrics.n_recoveries,
            "n_load_faults": getattr(self.engine, "n_load_faults", 0),
            "n_prefix_hits": pc.n_hits if pc else 0,
            "n_prefix_misses": pc.n_misses if pc else 0,
            "n_prefix_evictions": pc.n_evictions if pc else 0,
            "prefix_tokens_saved": pc.tokens_saved if pc else 0,
            # engine-side metrics over the elapsed virtual clock — one
            # literal key per TWIN_EXACT_FIELDS entry (lint-enforced)
            "serving": {
                "throughput": s.throughput,
                "ideal_throughput": s.ideal_throughput,
                "duration": s.duration,
                "n_finished": s.n_finished,
                "n_preemptions": s.n_preemptions,
                "n_loads": s.n_loads,
                "max_kv_used": s.max_kv_used,
                "ttft": s.ttft,
                "ttft_p50": s.ttft_p50,
                "ttft_p99": s.ttft_p99,
                "n_starved_requests": s.n_starved_requests,
                "starved_per_adapter": dict(s.starved_per_adapter),
                "n_timeouts": s.n_timeouts,
                "n_retries": s.n_retries,
                "n_failed_requests": s.n_failed_requests,
                "n_load_faults": s.n_load_faults,
                "n_prefix_hits": s.n_prefix_hits,
                "n_prefix_misses": s.n_prefix_misses,
                "n_prefix_evictions": s.n_prefix_evictions,
                "prefix_tokens_saved": s.prefix_tokens_saved,
            },
        }


# --------------------------------------------------------------------------- #
# stdlib HTTP binding (optional; no new runtime deps)
# --------------------------------------------------------------------------- #

def sse_format(data) -> bytes:
    """One Server-Sent-Events frame (``data: <json>\\n\\n``)."""
    payload = data if isinstance(data, str) else json.dumps(data)
    return b"data: " + payload.encode() + b"\n\n"


class GatewayHTTPServer:
    """Minimal OpenAI-style HTTP/1.1 binding over ``asyncio.start_server``.

    Routes:

    * ``POST /v1/completions`` — body keys: ``adapter`` (int) or
      ``model`` (``"adapter-<uid>"``), ``prompt`` (string; whitespace
      tokens) or ``prompt_tokens`` (int), ``max_tokens``, ``stream``.
      Responds 200 JSON, 200 ``text/event-stream`` of chunks terminated
      by ``data: [DONE]``, 429 when admission control rejects, or 503
      while draining.
    * ``GET /v1/metrics`` (or ``/metrics``) — gateway counters snapshot.
    * ``GET /v1/health`` — lifecycle state.

    Deliberately *not* a production HTTP server — it exists so the
    gateway can be driven by real sockets without adding a web-framework
    dependency."""

    def __init__(self, gateway: AsyncGateway, host: str = "127.0.0.1",
                 port: int = 0):
        self.gateway = gateway
        self.host = host
        self.port = port
        self._server: Optional[asyncio.AbstractServer] = None

    async def start(self) -> "GatewayHTTPServer":
        self._server = await asyncio.start_server(
            self._handle, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        return self

    async def stop(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None

    # ------------------------------------------------------------------ #
    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        try:
            parsed = await self._read_request(reader)
            if parsed is None:
                return
            method, path, payload = parsed
            if method == "POST" and path == "/v1/completions":
                await self._completions(writer, payload)
            elif method == "GET" and path in ("/v1/metrics", "/metrics"):
                await self._respond(writer, 200, self.gateway.snapshot())
            elif method == "GET" and path == "/v1/health":
                await self._respond(writer, 200,
                                    {"status": self.gateway.state})
            else:
                await self._respond(writer, 404, {"error": {
                    "message": f"no route for {method} {path}",
                    "code": 404}})
        except (ConnectionResetError, BrokenPipeError):
            pass
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionResetError, BrokenPipeError):
                pass

    async def _read_request(self, reader: asyncio.StreamReader):
        line = await reader.readline()
        if not line:
            return None
        parts = line.decode("latin-1").split()
        if len(parts) < 2:
            return None
        method, path = parts[0].upper(), parts[1]
        headers: Dict[str, str] = {}
        while True:
            h = await reader.readline()
            if h in (b"\r\n", b"\n", b""):
                break
            key, _, val = h.decode("latin-1").partition(":")
            headers[key.strip().lower()] = val.strip()
        length = int(headers.get("content-length", 0) or 0)
        body = await reader.readexactly(length) if length else b""
        try:
            payload = json.loads(body) if body else {}
        except json.JSONDecodeError:
            payload = None
        return method, path, payload

    async def _completions(self, writer, payload) -> None:
        if not isinstance(payload, dict):
            await self._respond(writer, 400, {"error": {
                "message": "body must be a JSON object", "code": 400}})
            return
        adapter = payload.get("adapter")
        if adapter is None:
            tail = str(payload.get("model", "adapter-0")).rsplit("-", 1)[-1]
            adapter = int(tail) if tail.isdigit() else 0
        prompt_len = int(payload.get("prompt_tokens", 0) or 0)
        if prompt_len <= 0:
            prompt_len = max(len(str(payload.get("prompt", "")).split()), 1)
        max_tokens = max(int(payload.get("max_tokens", 16)), 1)
        stream = bool(payload.get("stream", False))
        try:
            res = await self.gateway.submit(
                adapter=int(adapter), prompt_len=prompt_len,
                output_len=max_tokens, stream=stream)
        except NoAliveReplicasError as exc:
            await self._respond(writer, 503, {"error": {
                "message": str(exc), "type": "unavailable", "code": 503}})
            return
        if isinstance(res, Rejected):
            await self._respond(writer, res.status, res.to_json())
        elif isinstance(res, CompletionStream):
            writer.write(self._head(200, "text/event-stream"))
            await writer.drain()
            try:
                async for chunk in res:
                    writer.write(sse_format(chunk))
                    await writer.drain()
                writer.write(sse_format("[DONE]"))
                await writer.drain()
            except (ConnectionResetError, BrokenPipeError):
                # client went away mid-stream: cancel engine-side so its
                # KV slot frees and the loss is counted, not leaked
                self.gateway.disconnect(res.request)
                raise
        else:
            await self._respond(writer, 200, res.to_json())

    # ------------------------------------------------------------------ #
    _STATUS = {200: "OK", 400: "Bad Request", 404: "Not Found",
               429: "Too Many Requests", 503: "Service Unavailable"}

    def _head(self, status: int, ctype: str,
              length: Optional[int] = None) -> bytes:
        lines = [f"HTTP/1.1 {status} {self._STATUS.get(status, 'OK')}",
                 f"Content-Type: {ctype}", "Connection: close"]
        if length is not None:
            lines.append(f"Content-Length: {length}")
        return ("\r\n".join(lines) + "\r\n\r\n").encode()

    async def _respond(self, writer, status: int, obj: dict) -> None:
        body = json.dumps(obj).encode()
        writer.write(self._head(status, "application/json", len(body)))
        writer.write(body)
        await writer.drain()

"""Deterministic fault injection + request-reliability primitives.

The paper's Digital Twin claim is only useful if it extends to the
*unhealthy* system: production adapter-serving fleets are defined by how
they behave under replica crashes, adapter-load failures, stragglers and
client disconnects.  This module provides the shared vocabulary:

* typed fault events + a seeded :class:`FaultPlan` schedule that the
  cluster loop, the gateway and the Digital Twin all consume — the same
  plan replays bitwise-identically in ``ServingCluster.run_online`` and
  ``ClusterDigitalTwin.simulate_online`` so faulted runs become
  labelable training data;
* :class:`ReliabilityPolicy` — per-request timeouts, bounded
  retry-with-exponential-backoff, circuit-breaker thresholds and the
  Fig. 4 reload-cost hook used when a crashed replica restores its
  adapter cache;
* :class:`CircuitBreaker` — closed / open / half-open per-replica
  breaker sitting next to the router's straggler flag;
* :class:`FaultStats` — the fault/reliability counters surfaced by
  ``OnlineReport`` and the gateway's ``/v1/metrics``;
* :class:`NoAliveReplicasError` — the terminal-fleet contract raised by
  ``ClusterRouter.eligible``/``mark_dead`` and translated to HTTP 503.

Fault timing is epoch-granular by design: an event with time ``at``
takes effect at the first epoch boundary ``t >= at``, and a window event
``[at, until)`` applies to epochs whose start falls inside it.  This is
what makes the cluster and the twin agree bitwise — both advance the
same virtual-clock epoch loop.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence


class NoAliveReplicasError(RuntimeError):
    """Raised when a routing decision needs an alive replica and the
    fleet has none.  The gateway and cluster translate this to a 503 —
    it is a *fleet-state* condition, not a caller bug."""


# --------------------------------------------------------------------------- #
# fault events
# --------------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class ReplicaCrash:
    """Replica dies at ``at``; with ``recover_at`` set it rejoins via the
    heartbeat path with its adapter cache restored (Fig. 4 reload costs
    charged for everything that was resident)."""
    replica: int
    at: float
    recover_at: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class AdapterLoadFault:
    """Loads of ``adapter`` on ``replica`` fail during ``[at, until)``:
    preloads/restores refuse (counted ``n_load_faults``), admission falls
    back to bounded retry on another replica via the breaker path."""
    replica: int
    adapter: int
    at: float
    until: float


@dataclasses.dataclass(frozen=True)
class StragglerWindow:
    """Replica runs ``factor`` times slower during ``[at, until)`` —
    the detector's busy-time heuristic should flag it and routing should
    steer new work away."""
    replica: int
    at: float
    until: float
    factor: float = 4.0


@dataclasses.dataclass(frozen=True)
class ExecutorFault:
    """Transient executor error: the replica stalls (no service, no
    heartbeat) for ``duration`` seconds starting at ``at``."""
    replica: int
    at: float
    duration: float = 5.0


@dataclasses.dataclass(frozen=True)
class ClientDisconnect:
    """The ``request_index``-th request of the arrival stream (in
    submission order) disconnects at ``at``: the server cancels the
    engine-side work and accounts it instead of leaking the stream."""
    at: float
    request_index: int


FaultEvent = object   # union of the five dataclasses above (py3.10-safe)


@dataclasses.dataclass
class FaultPlan:
    """A seeded, replayable schedule of fault events.

    The plan is pure data: injecting the same plan into the cluster, the
    gateway or the twin yields the same virtual-clock fault timeline.
    ``seed`` records provenance (the generator seed) for labelling.
    """
    events: List[object] = dataclasses.field(default_factory=list)
    seed: int = 0

    def _of(self, kind) -> list:
        return sorted((e for e in self.events if isinstance(e, kind)),
                      key=lambda e: e.at)

    @property
    def crashes(self) -> List[ReplicaCrash]:
        return self._of(ReplicaCrash)

    @property
    def adapter_faults(self) -> List[AdapterLoadFault]:
        return self._of(AdapterLoadFault)

    @property
    def straggler_windows(self) -> List[StragglerWindow]:
        return self._of(StragglerWindow)

    @property
    def executor_faults(self) -> List[ExecutorFault]:
        return self._of(ExecutorFault)

    @property
    def disconnects(self) -> List[ClientDisconnect]:
        return self._of(ClientDisconnect)

    def summary(self) -> Dict[str, int]:
        return {"crashes": len(self.crashes),
                "adapter_faults": len(self.adapter_faults),
                "straggler_windows": len(self.straggler_windows),
                "executor_faults": len(self.executor_faults),
                "disconnects": len(self.disconnects)}


# --------------------------------------------------------------------------- #
# reliability policy + per-replica circuit breaker
# --------------------------------------------------------------------------- #

@dataclasses.dataclass
class ReliabilityPolicy:
    """Request-lifecycle reliability knobs.

    ``timeout_s == 0`` disables timeouts (and with them retries) — the
    default keeps every pre-existing run bitwise-identical.
    ``load_cost_fn`` maps an adapter uid to its Fig. 4 reload cost in
    seconds, charged when a recovering replica restores its cache.
    """
    timeout_s: float = 0.0
    max_retries: int = 2
    backoff_base: float = 1.0
    breaker_threshold: int = 3
    breaker_cooldown_s: float = 10.0
    load_cost_fn: Optional[Callable[[int], float]] = None

    @property
    def enabled(self) -> bool:
        return self.timeout_s > 0.0

    def backoff(self, n_retries: int) -> float:
        """Exponential backoff before the ``n_retries``-th re-submission
        (1-indexed): base, 2*base, 4*base, ..."""
        return self.backoff_base * (2.0 ** max(n_retries - 1, 0))


class CircuitBreaker:
    """Per-replica circuit breaker (closed -> open -> half-open).

    Failures accumulate across windows; ``threshold`` consecutive
    failures open the breaker, which blocks routing for ``cooldown_s``
    virtual seconds.  After the cooldown the breaker goes *half-open*: a
    single probe request is allowed through, and its outcome closes the
    breaker (success) or re-opens it (failure).  Success only resets the
    counter from the half-open probe or an explicit ``reset()`` — a
    replica that heartbeats fine but fails every adapter load must still
    trip the breaker.
    """

    CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"

    def __init__(self, threshold: int = 3, cooldown_s: float = 10.0):
        self.threshold = max(int(threshold), 1)
        self.cooldown_s = float(cooldown_s)
        self.state = self.CLOSED
        self.failures = 0
        self.opened_at = 0.0
        self.n_opens = 0

    def record_failure(self, now: float) -> None:
        if self.state == self.HALF_OPEN:
            # the probe failed: straight back to open
            self.state, self.opened_at = self.OPEN, now
            self.n_opens += 1
            return
        self.failures += 1
        if self.state == self.CLOSED and self.failures >= self.threshold:
            self.state, self.opened_at = self.OPEN, now
            self.n_opens += 1

    def record_success(self) -> None:
        # only the half-open probe's success closes the breaker; routine
        # successes while closed do NOT erase accumulated failures
        if self.state == self.HALF_OPEN:
            self.reset()

    def tick(self, now: float) -> None:
        """Advance open -> half-open once the cooldown elapses."""
        if self.state == self.OPEN and \
                now - self.opened_at >= self.cooldown_s:
            self.state = self.HALF_OPEN

    def reset(self) -> None:
        self.state = self.CLOSED
        self.failures = 0

    @property
    def blocked(self) -> bool:
        """True while routing should avoid this replica entirely."""
        return self.state == self.OPEN


# --------------------------------------------------------------------------- #
# fault/reliability counters
# --------------------------------------------------------------------------- #

@dataclasses.dataclass
class FaultStats:
    """Counters for everything the fault layer did during a run."""
    n_timeouts: int = 0            # requests that exceeded the deadline
    n_retries: int = 0             # re-submissions performed
    n_failed_requests: int = 0     # requests explicitly failed (retries spent)
    n_disconnects: int = 0         # client disconnects processed
    n_adapter_faults: int = 0      # AdapterLoadFault windows activated
    n_load_faults: int = 0         # refused adapter loads (preload/restore)
    n_executor_faults: int = 0     # executor stalls injected
    n_crashes: int = 0             # replica crashes injected
    n_recoveries: int = 0          # replicas restored + rejoined
    n_breaker_opens: int = 0       # circuit-breaker open transitions

    def as_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)

    def add(self, other: "FaultStats") -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name,
                    getattr(self, f.name) + getattr(other, f.name))


_CHAOS_KINDS = ("crash", "loadfail", "straggler", "stall", "disconnect")


def parse_chaos_spec(spec: str, n_replicas: int, horizon: float,
                     seed: int = 0,
                     adapters: Optional[Sequence[int]] = None,
                     n_requests: int = 0) -> FaultPlan:
    """Parse a ``--chaos`` spec into a seeded :class:`FaultPlan`.

    Grammar: comma-separated ``kind[:count]`` terms over the kinds
    ``crash``, ``loadfail``, ``straggler``, ``stall``, ``disconnect``
    (count defaults to 1), e.g. ``crash:1,loadfail:2,straggler``.
    Identical (spec, seed, topology) arguments produce an identical
    plan — the CLI face of :func:`generate_fault_plan`."""
    counts = {k: 0 for k in _CHAOS_KINDS}
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        kind, _, cnt = part.partition(":")
        if kind not in counts:
            raise ValueError(
                f"--chaos: unknown fault kind {kind!r} "
                f"(choose from {', '.join(_CHAOS_KINDS)})")
        counts[kind] += int(cnt) if cnt else 1
    return generate_fault_plan(
        n_replicas, horizon, seed=seed, adapters=adapters,
        n_crashes=counts["crash"], n_adapter_faults=counts["loadfail"],
        n_stragglers=counts["straggler"],
        n_executor_faults=counts["stall"],
        n_disconnects=counts["disconnect"], n_requests=n_requests)


def generate_fault_plan(n_replicas: int,
                        horizon: float,
                        seed: int = 0,
                        adapters: Optional[Sequence[int]] = None,
                        n_crashes: int = 1,
                        n_adapter_faults: int = 1,
                        n_stragglers: int = 1,
                        n_executor_faults: int = 0,
                        n_disconnects: int = 1,
                        n_requests: int = 0,
                        recover: bool = True) -> FaultPlan:
    """Seeded fault-storm generator (the ``--chaos`` backend).

    Event times are drawn uniformly over the middle of the horizon so
    the system has warm state to break; identical arguments produce an
    identical plan, which is the determinism contract the twin tests
    pin.  ``n_requests`` bounds the disconnect target indices (0 skips
    disconnects when the stream size is unknown).
    """
    import numpy as np
    rng = np.random.default_rng(seed)
    pool = list(adapters) if adapters else [0]
    events: List[object] = []
    for _ in range(n_crashes):
        rep = int(rng.integers(0, n_replicas))
        at = float(rng.uniform(0.2, 0.5) * horizon)
        rec = float(at + rng.uniform(0.15, 0.3) * horizon) if recover \
            else None
        events.append(ReplicaCrash(replica=rep, at=at, recover_at=rec))
    for _ in range(n_adapter_faults):
        rep = int(rng.integers(0, n_replicas))
        uid = int(pool[int(rng.integers(0, len(pool)))])
        at = float(rng.uniform(0.1, 0.4) * horizon)
        events.append(AdapterLoadFault(
            replica=rep, adapter=uid, at=at,
            until=float(at + rng.uniform(0.2, 0.4) * horizon)))
    for _ in range(n_stragglers):
        rep = int(rng.integers(0, n_replicas))
        at = float(rng.uniform(0.2, 0.5) * horizon)
        events.append(StragglerWindow(
            replica=rep, at=at,
            until=float(at + rng.uniform(0.15, 0.3) * horizon),
            factor=float(rng.uniform(3.0, 6.0))))
    for _ in range(n_executor_faults):
        rep = int(rng.integers(0, n_replicas))
        events.append(ExecutorFault(
            replica=rep, at=float(rng.uniform(0.2, 0.7) * horizon),
            duration=float(rng.uniform(2.0, 6.0))))
    if n_requests > 0:
        for _ in range(n_disconnects):
            events.append(ClientDisconnect(
                at=float(rng.uniform(0.2, 0.8) * horizon),
                request_index=int(rng.integers(0, n_requests))))
    return FaultPlan(events=events, seed=seed)

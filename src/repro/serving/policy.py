"""Pluggable scheduling policies: the admission/preemption decision seam.

The paper's whole premise is that starvation is a *policy* outcome — the
(N, G) placement model is trained against vLLM's fixed FCFS +
loaded-adapter-priority scheduler.  This module turns that scheduler
into one point in a policy space, shared verbatim by all three
consumers:

  * ``serving.scheduler.Scheduler`` — the real engine and the
    object-mode Digital Twin (they already share the scheduler);
  * ``core.fast_twin.FastEngine`` — the struct-of-arrays twin fast
    path, which keeps its SoA layout and delegates only the admission
    *ordering* (and optional victim choice) to the policy.

A policy never touches resources.  The mechanical admission loop —
adapter-slot eligibility, KV admission check with head-of-line blocking,
``max_running``, the skip of requests preempted this very step — is
identical across policies and consumers; the policy decides the *order*
in which waiting requests are offered to that loop and may veto the
default preemption victim.  Because both consumers feed the policy the
same (arrival, adapter, context-length, residency) values, one policy
instance produces bit-identical decisions on either side — the
per-policy fast-vs-legacy equivalence tests in ``tests/test_fast_twin``
enforce it.

Registered policies (``SCHED_POLICIES``; add your own with
``@register_sched_policy``):

  * ``fcfs``            — today's behaviour, byte-identical metrics as
                          the default: arrival order with vLLM's
                          loaded-adapter priority (the eligibility skip
                          is in the mechanical loop, so every policy
                          inherits it).
  * ``slo-priority``    — deadline ordering: each adapter belongs to a
                          priority class and its requests are served in
                          order of ``arrival + slo_base * class``, with
                          an aging term so a low-priority request's
                          extra wait is bounded by
                          ``slo_base * class / (1 + aging)`` — low
                          classes cannot starve.
  * ``adapter-fair``    — deficit round-robin across adapters: the head
                          request of every waiting adapter is offered
                          before any adapter's second request, adapters
                          with the smallest cumulative admitted tokens
                          (the deficit counter) first — one hot adapter
                          cannot monopolize admission slots.
  * ``adapter-cluster`` — S-LoRA-style clustering: requests whose
                          adapter is already resident are offered first,
                          grouped per adapter, so same-adapter work
                          batches and cold loads (the Fig. 4 cost) are
                          deferred.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Type, Union


class SchedView:
    """Accessor protocol a consumer hands to the policy.

    ``item`` is whatever the consumer queues: a ``Request`` object in the
    object-mode scheduler, a struct-of-arrays row id in ``FastEngine``.
    Implementations must return the *same* values for the same logical
    request on either side (floats bit-identical), which is what makes
    policy decisions consumer-independent.
    """

    def arrival(self, item) -> float:
        raise NotImplementedError

    def adapter(self, item) -> int:
        raise NotImplementedError

    def context_len(self, item) -> int:
        raise NotImplementedError

    def resident(self, adapter: int) -> bool:
        raise NotImplementedError


SCHED_POLICIES: Dict[str, Type["SchedulingPolicy"]] = {}


def register_sched_policy(cls: Type["SchedulingPolicy"]
                          ) -> Type["SchedulingPolicy"]:
    SCHED_POLICIES[cls.name] = cls
    return cls


def make_sched_policy(policy: Union[str, "SchedulingPolicy", None],
                      **kwargs) -> "SchedulingPolicy":
    """Resolve a policy name to a fresh instance.

    A ``SchedulingPolicy`` *instance* is passed through as-is — the
    caller owns its lifetime, and sharing one stateful instance between
    engines shares its fairness state (each engine still ``reset()``s it
    at stream start)."""
    if policy is None:
        policy = "fcfs"
    if isinstance(policy, SchedulingPolicy):
        return policy
    if policy not in SCHED_POLICIES:
        raise ValueError(f"unknown scheduling policy {policy!r}; "
                         f"have {sorted(SCHED_POLICIES)}")
    return SCHED_POLICIES[policy](**kwargs)


def sched_policy_index(name: str) -> int:
    """Stable numeric encoding of a policy name (placement-model
    feature): its position in *registration order*, so registering a
    new policy appends an index and never shifts the encoding of
    already-labelled datasets or trained models."""
    try:
        return list(SCHED_POLICIES).index(name)
    except ValueError:
        raise ValueError(f"unknown scheduling policy {name!r}; "
                         f"have {sorted(SCHED_POLICIES)}")


class SchedulingPolicy:
    """Base policy: admission order + optional hooks.

    Subclasses override ``order`` (and optionally ``on_admit`` /
    ``victim``).  ``order`` must be side-effect free on the queue it is
    given and deterministic in (items, view state, own state) — both
    scheduler implementations call it with identical inputs and must
    reach identical decisions.
    """

    name = ""

    def reset(self) -> None:
        """Drop accumulated state (new request stream)."""

    def order(self, items: Sequence, view: SchedView, now: float) -> Sequence:
        """Admission attempt order — a permutation of ``items``.

        The mechanical loop walks this order applying the shared
        eligibility rules; returning ``items`` unchanged is FCFS.
        """
        return items

    def on_admit(self, item, view: SchedView, now: float) -> None:
        """Called after ``item`` is admitted (charge fairness state)."""

    def victim(self, running: Sequence, view: SchedView) -> Optional[object]:
        """Preemption victim among ``running`` (None = nothing to evict).

        Default is the engine's preempt-by-recompute rule: the most
        recently arrived running request.  Consumers keep their native
        (vectorized) implementation of this default and only call in
        when a subclass overrides it.
        """
        if not running:
            return None
        return max(running, key=view.arrival)


# used by consumers to skip virtual dispatch on the hot path when the
# policy doesn't customise a hook
def overrides_on_admit(policy: SchedulingPolicy) -> bool:
    return type(policy).on_admit is not SchedulingPolicy.on_admit


def overrides_victim(policy: SchedulingPolicy) -> bool:
    return type(policy).victim is not SchedulingPolicy.victim


@register_sched_policy
class FCFSPolicy(SchedulingPolicy):
    """Arrival order (today's vLLM-style behaviour, the default)."""

    name = "fcfs"


@register_sched_policy
class SLOPriorityPolicy(SchedulingPolicy):
    """Deadline/TTFT-aware ordering with aging.

    Each adapter belongs to a priority class (``priorities`` mapping, or
    ``adapter_uid % n_classes`` when unspecified; class 0 is most
    urgent).  A request's deadline is ``arrival + slo_base * class`` and
    admission is attempted in order of
    ``deadline - aging * (now - arrival)`` — i.e. class-c work may be
    overtaken by newer urgent work for at most ``slo_base * c / (1 +
    aging)`` seconds of extra waiting, after which it wins every
    comparison: aging bounds the priority boost, so low-priority
    adapters cannot starve.
    """

    name = "slo-priority"

    def __init__(self, slo_base: float = 5.0, aging: float = 0.5,
                 priorities: Optional[Dict[int, int]] = None,
                 n_classes: int = 4):
        self.slo_base = slo_base
        self.aging = aging
        self.priorities = dict(priorities or {})
        self.n_classes = max(int(n_classes), 1)

    def priority_of(self, adapter: int) -> int:
        return self.priorities.get(adapter, adapter % self.n_classes)

    def order(self, items: Sequence, view: SchedView, now: float) -> List:
        def key(item):
            arr = view.arrival(item)
            deadline = arr + self.slo_base * self.priority_of(
                view.adapter(item))
            return (deadline - self.aging * (now - arr), arr)
        return sorted(items, key=key)


@register_sched_policy
class AdapterFairPolicy(SchedulingPolicy):
    """Deficit round-robin across adapters.

    Admission order is lexicographic on (position within the adapter's
    own waiting queue, cumulative admitted prefill tokens — the deficit
    counter, arrival): the head request of every waiting adapter is
    offered before any adapter's second request, least-served adapters
    first.  ``on_admit`` charges the admitted context to the adapter, so
    an adapter that monopolized slots sinks behind the others the next
    time a slot frees.
    """

    name = "adapter-fair"

    def __init__(self):
        self._served: Dict[int, float] = {}

    def reset(self) -> None:
        self._served.clear()

    def order(self, items: Sequence, view: SchedView, now: float) -> List:
        depth: Dict[int, int] = {}
        keyed = []
        for item in items:
            a = view.adapter(item)
            k = depth.get(a, 0)
            depth[a] = k + 1
            keyed.append(((k, self._served.get(a, 0.0),
                           view.arrival(item)), item))
        keyed.sort(key=lambda kv: kv[0])
        return [item for _, item in keyed]

    def on_admit(self, item, view: SchedView, now: float) -> None:
        a = view.adapter(item)
        self._served[a] = self._served.get(a, 0.0) \
            + view.context_len(item) + 1


@register_sched_policy
class AdapterClusterPolicy(SchedulingPolicy):
    """S-LoRA-style adapter clustering.

    Requests whose adapter is already resident are offered first (their
    admission needs no slot and batches with running same-adapter work);
    within each group, adapters are visited oldest-waiting-first and a
    whole adapter's queue is offered contiguously — same-adapter work
    clusters into the batch and cold loads are taken one adapter at a
    time instead of thrashing the LRU.
    """

    name = "adapter-cluster"

    def order(self, items: Sequence, view: SchedView, now: float) -> List:
        oldest: Dict[int, float] = {}
        for item in items:
            a = view.adapter(item)
            if a not in oldest:
                oldest[a] = view.arrival(item)

        def key(item):
            a = view.adapter(item)
            return (0 if view.resident(a) else 1, oldest[a], a,
                    view.arrival(item))
        return sorted(items, key=key)

"""Predictive (model-driven) rebalancing: plan ahead of popularity drift.

The reactive ``RebalancePolicy`` (``repro.serving.rebalance``) waits for
observed suffering — EWMA imbalance plus a growing backlog — before it
moves anything, so every phase change of a drifting workload costs a few
windows of degraded service while the EWMA catches up.  The paper's core
claim is that an *interpretable placement model* can predict the optimal
configuration instead of reacting to starvation; this module closes that
loop at runtime:

  * ``PredictiveRebalancer`` extrapolates the drift tracker's EWMA rates
    one planning horizon forward (linear trend per adapter), feeds the
    *forecast* rates through the trained ``ClusterPlacementModel`` to
    decide how many adapters the fleet should actively plan for (the
    model's N*), LPT-packs that hot set over the live replicas, and
    proposes the migrations that realise the plan — before the backlog
    ever builds.  The cost/benefit veto is inherited unchanged: each
    move still pays the Fig. 4 load cost against its forecast benefit.
  * ``plan_initial_placement`` turns one model call into the fleet's
    *initial* adapter->replica bin-packing (``PlacementRouter.plan``),
    which ``ServingCluster.run_online`` warms before serving starts —
    replacing first-touch affinity scatter with the model's plan.

Replication (``Replicate | Unreplicate`` in the plan vocabulary) is
inherited from the base policy: a single adapter too hot for any one
replica gets a second home, which migration alone can never achieve.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from .rebalance import Migration, RebalancePolicy
from .router import PlacementRouter


class PredictiveRebalancer(RebalancePolicy):
    """Model-driven planner over the reactive policy's observation state.

    ``model`` is anything with the ``ClusterPlacementModel.recommend``
    signature (``recommend(rates, ranks, length_stats, n_replicas)``);
    ``pool`` and ``length_stats`` describe the workload the model was
    trained to reason about.  ``forecast_horizon_s`` defaults to two
    observation windows — far enough ahead to front-run drift, close
    enough that the linear trend still holds.
    """

    def __init__(self, router, model, pool: Sequence,
                 length_stats: Dict[str, float],
                 load_cost_fn=None,
                 forecast_horizon_s: Optional[float] = None,
                 threshold: float = 1.15,
                 max_moves_per_round: int = 4,
                 imbalance_patience: int = 1,
                 **kwargs):
        super().__init__(router, load_cost_fn=load_cost_fn,
                         threshold=threshold,
                         max_moves_per_round=max_moves_per_round, **kwargs)
        self.model = model
        self.pool = list(pool)
        self.length_stats = dict(length_stats)
        self.forecast_horizon_s = forecast_horizon_s
        # planning replaces the reactive suffering gate — acting before
        # queues build is the whole point — at the price of an
        # occasional noise-triggered move on a stationary fleet (each
        # bounded by the cost/benefit veto).  Raise ``imbalance_patience``
        # (consecutive imbalanced rounds required) to trade
        # responsiveness back for stillness on fleets known stationary.
        self.imbalance_patience = imbalance_patience
        self._imbalanced_rounds = 0
        self._fleet: Dict[int, float] = {}       # uid -> EWMA rate now
        self._forecast: Dict[int, float] = {}    # uid -> forecast rate

    # ------------------------------------------------------------------ #
    def observe(self, now: float, window_s: float,
                served_tokens: Optional[List[float]] = None,
                backlog: Optional[List[int]] = None) -> None:
        super().observe(now, window_s, served_tokens=served_tokens,
                        backlog=backlog)
        prev = self._fleet
        self._fleet = {a.uid: self.tracker.adapter_rate(a.uid)
                       for a in self.pool}
        h = self.forecast_horizon_s or 2.0 * max(window_s, 1e-9)
        w = max(window_s, 1e-9)
        self._forecast = {}
        for uid, cur in self._fleet.items():
            trend = (cur - prev.get(uid, cur)) / w
            self._forecast[uid] = max(cur + trend * h, 0.0)

    # ------------------------------------------------------------------ #
    def _propose_migrations(self, now: float,
                            skip: frozenset = frozenset()
                            ) -> List[Migration]:
        """Overrides the reactive migration hook — the base ``propose``
        (replication pass + Replicate-skip coupling) is inherited."""
        r = self.router
        live = r.live_replicas()
        if len(live) < 2 or not self._forecast:
            return []
        eligible = [i for i in live if not r.straggler[i]] or live

        # forecast per-replica loads under the *current* homes
        loads = {i: 0.0 for i in live}
        home_of: Dict[int, int] = {}
        for uid, f in self._forecast.items():
            homes = r.homes(uid)
            if not homes:
                continue                 # never routed yet: no home to fix
            if len(homes) > 1:
                for h in homes:          # multi-home splits the load
                    loads[h] += self._norm(h, f / len(homes))
                continue
            home_of[uid] = homes[0]
            loads[homes[0]] += self._norm(homes[0], f)
        mean = sum(loads.values()) / len(loads)
        if mean <= 0 or max(loads.values()) <= self.threshold * mean:
            self.report.n_rounds_balanced += 1
            self._imbalanced_rounds = 0
            return []                    # forecast says: stay put
        self._imbalanced_rounds += 1
        if self._imbalanced_rounds < self.imbalance_patience:
            return []                    # one noisy window is not drift

        # model inference on the forecast workload: how many adapters the
        # fleet should actively plan placements for (the model's N*).
        # The fleet's scheduling policy is a model feature (it shifts
        # N*); heterogeneous-policy fleets are summarised by replica 0.
        rates = [self._forecast.get(a.uid, 0.0) for a in self.pool]
        ranks = [a.rank for a in self.pool]
        rec = self.model.recommend(
            rates, ranks, self.length_stats, n_replicas=len(eligible),
            sched_policy=r.specs[0].sched_policy)
        n_hot = min(max(int(rec["served_adapters"]), len(eligible)),
                    len(self.pool))
        hot = set(sorted((uid for uid in home_of
                          if self._forecast[uid] > self.min_adapter_rate),
                         key=lambda u: (-self._forecast[u], u))[:n_hot])

        # the reactive policy's greedy donor->recipient walk, but on the
        # *forecast* loads and without the suffering gate: rising
        # adapters weigh more than fading ones before the queues show
        # it, and the no-inversion guard keeps the plan from flapping
        gain_window = self.gain_window_s or max(self._last_window_s, 1e-9)
        moves: List[Migration] = []
        for _ in range(self.max_moves):
            mean = sum(loads.values()) / len(loads)
            donor = max(live, key=lambda i: (loads[i], -i))
            recips = [i for i in eligible if i != donor]
            if not recips or mean <= 0 \
                    or loads[donor] <= self.threshold * mean:
                break
            recip = min(recips, key=lambda i: (loads[i], i))
            gap = loads[donor] - loads[recip]
            mig = None
            for uid in sorted((u for u in hot
                               if home_of.get(u) == donor
                               and u not in skip),
                              key=lambda u: (-self._forecast[u], u)):
                f = self._forecast[uid]
                if self._norm(donor, f) + self._norm(recip, f) > gap:
                    continue             # move would invert the imbalance
                self.report.n_proposed += 1
                cost_s = float(self.load_cost_fn(uid))
                if self._cost_tokens(cost_s, recip, gain_window) \
                        >= f * gain_window:
                    self.report.n_declined_cost += 1
                    continue
                mig = Migration(adapter=uid, src=donor, dst=recip,
                                cost_s=cost_s)
                break
            if mig is None:
                break
            moves.append(mig)
            f = self._forecast[mig.adapter]
            loads[donor] -= self._norm(donor, f)
            loads[recip] += self._norm(recip, f)
            home_of[mig.adapter] = recip
        return moves


# --------------------------------------------------------------------------- #
# plan-level initial placement (the model's bin-packing, warmed at t=0)
# --------------------------------------------------------------------------- #

def plan_initial_placement(model, pool: Sequence,
                           length_stats: Dict[str, float],
                           n_replicas: int,
                           sched_policy: str = "fcfs") -> Dict[int, int]:
    """One model call -> the fleet's initial adapter->replica packing.

    ``model`` is a ``ClusterPlacementModel`` (its per-node inference view
    is used, with ``sched_policy`` baked in so per-node capacity is
    inferred for the fleet's actual scheduler) or any
    ``PlacementPipeline``-shaped object with ``recommend(rates, ranks,
    length_stats)``.  The result feeds
    ``ServingCluster.run_online(initial_placement=...)`` /
    ``ClusterDigitalTwin.simulate_online(initial_placement=...)``.
    """
    pipeline = model.as_node_pipeline(sched_policy=sched_policy) \
        if hasattr(model, "as_node_pipeline") else model
    router = PlacementRouter(pipeline, n_replicas)
    state = router.plan(list(pool), dict(length_stats))
    return dict(state.assignment)

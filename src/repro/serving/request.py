"""Request and adapter descriptors shared by the engine and the Digital Twin."""
from __future__ import annotations

import dataclasses
from typing import List, Optional


@dataclasses.dataclass
class Adapter:
    uid: int
    rank: int
    rate: float = 0.0                  # req/s (workload descriptor)
    location: str = "cpu"              # cpu | disk

    def bytes(self, d_model: int, n_layers: int, n_targets: int = 2,
              dtype_bytes: int = 2) -> int:
        # A (d, r) + B (r, o~d) per target per layer; ``dtype_bytes``
        # defaults to bf16 (2) — int8 adapter banks pass 1
        return dtype_bytes * 2 * self.rank * d_model * n_targets * n_layers


@dataclasses.dataclass
class Request:
    uid: int
    adapter: int
    arrival: float
    prompt_len: int
    output_len: int

    # shared-prefix identity: the first min(prefix_len, prompt_len)
    # prompt tokens are the shared system prompt named ``prefix_id``
    # (typically the tenant/adapter uid).  None = no shared prefix —
    # bitwise-identical to the pre-prefix-cache engine everywhere.
    prefix_id: Optional[int] = None
    prefix_len: int = 0

    # progress
    generated: int = 0
    admitted_at: Optional[float] = None
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None
    token_times: List[float] = dataclasses.field(default_factory=list)
    n_preemptions: int = 0

    # reliability lifecycle (all None/0 on the healthy path)
    n_retries: int = 0                       # re-submissions performed
    n_timeouts: int = 0                      # deadline expiries observed
    failed_at: Optional[float] = None        # retries exhausted here
    retry_at: Optional[float] = None         # backoff release time
    disconnected_at: Optional[float] = None  # client went away here

    @property
    def done(self) -> bool:
        return self.generated >= self.output_len

    @property
    def context_len(self) -> int:
        return self.prompt_len + self.generated

    # latency metrics ---------------------------------------------------- #
    @property
    def ttft(self) -> Optional[float]:
        if self.first_token_at is None:
            return None
        return self.first_token_at - self.arrival

    @property
    def itl(self) -> Optional[float]:
        if len(self.token_times) < 2:
            return None
        spans = [b - a for a, b in zip(self.token_times, self.token_times[1:])]
        return sum(spans) / len(spans)

    @property
    def latency(self) -> Optional[float]:
        if self.finished_at is None:
            return None
        return self.finished_at - self.arrival

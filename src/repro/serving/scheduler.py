"""Continuous-batching scheduler: FCFS + vLLM adapter-slot priority,
greedy KV allocation with preemption-by-recompute.

This class is shared verbatim by the real engine and the Digital Twin —
the paper's DT replicates scheduling *logic* exactly (Fig. 8: "As vLLM, we
use a FCFS policy and a greedy allocation of KV cache"); only step *times*
and memory *capacity* differ (measured vs estimated).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional, Set

from .adapter_cache import AdapterSlotCache
from .kv_cache import PagedKVCache
from .request import Request


@dataclasses.dataclass
class StepPlan:
    admitted: List[Request]          # requests prefilling this step
    preempted: List[Request]
    cold_loads: List[int]            # adapter uids loaded from host this step
    running: List[Request]           # full running batch (incl. admitted)

    @property
    def unique_adapters(self) -> Set[int]:
        return {r.adapter for r in self.running}

    @property
    def prefill_tokens(self) -> int:
        return sum(r.context_len for r in self.admitted)


class Scheduler:
    def __init__(self, kv: PagedKVCache, adapters: AdapterSlotCache,
                 max_running: int = 256):
        self.kv = kv
        self.adapters = adapters
        self.max_running = max_running
        self.waiting: Deque[Request] = deque()
        self.running: List[Request] = []
        self._pos: dict = {}               # request uid -> index in running

    # ------------------------------------------------------------------ #
    def add(self, reqs: List[Request]) -> None:
        self.waiting.extend(reqs)

    def _append_running(self, req: Request) -> None:
        self._pos[req.uid] = len(self.running)
        self.running.append(req)

    def _remove_running(self, req: Request) -> None:
        """O(1) swap-remove via the uid->index map.  ``list.remove`` on a
        dataclass list is an O(n) field-by-field equality scan — this is
        the engine step's (and the Digital Twin's) hottest removal."""
        i = self._pos.pop(req.uid)
        last = self.running.pop()
        if i < len(self.running):
            self.running[i] = last
            self._pos[last.uid] = i

    def clear(self) -> None:
        """Drop every queued/running request (fault-tolerance drain)."""
        self.running.clear()
        self._pos.clear()
        self.waiting.clear()

    def finish(self, req: Request) -> None:
        self._remove_running(req)
        self.kv.free(req.uid)
        self.adapters.unpin(req.adapter)

    def _preempt_one(self) -> Optional[Request]:
        """Evict the most recently arrived running request (recompute)."""
        if not self.running:
            return None
        victim = max(self.running, key=lambda r: r.arrival)
        self._remove_running(victim)
        self.kv.free(victim.uid)
        self.adapters.unpin(victim.adapter)
        victim.n_preemptions += 1
        self.waiting.appendleft(victim)
        return victim

    # ------------------------------------------------------------------ #
    def schedule(self, now: float) -> StepPlan:
        admitted: List[Request] = []
        preempted: List[Request] = []
        cold_loads: List[int] = []

        # 1. greedy decode allocation for already-running requests;
        #    preempt (newest first) on memory exhaustion.
        for req in list(self.running):
            while not self.kv.allocate(req.uid, 1):
                # S-LoRA: idle adapter weights are evicted from the unified
                # pool before any request is preempted
                if self.adapters.dynamic and \
                        self.adapters.evict_idle_lru() is not None:
                    continue
                victim = self._preempt_one()
                if victim is None:
                    break
                preempted.append(victim)
                if victim is req:
                    break  # req preempted itself; it no longer decodes

        # 2. admissions: FCFS, but when its adapter cannot get a slot,
        #    skip and let later requests with loaded adapters through
        #    (vLLM's loaded-adapter priority).  Requests preempted in THIS
        #    step stay queued until the next step (no same-step thrash).
        just_preempted = {r.uid for r in preempted}
        skipped: List[Request] = []
        while self.waiting and len(self.running) < self.max_running:
            req = self.waiting[0]
            if req.uid in just_preempted:
                self.waiting.popleft()
                skipped.append(req)
                continue
            need_slots = not self.adapters.is_loaded(req.adapter)
            if need_slots and not self.adapters.can_load(req.adapter):
                self.waiting.popleft()
                skipped.append(req)
                continue
            if not self.kv.can_allocate(req.context_len + 1):
                if self.adapters.dynamic and \
                        self.adapters.evict_idle_lru() is not None:
                    continue
                break
            self.waiting.popleft()
            if self.adapters.load(req.adapter, now):
                cold_loads.append(req.adapter)
            self.adapters.pin(req.adapter)
            self.kv.allocate(req.uid, req.context_len + 1)
            req.admitted_at = now
            self._append_running(req)
            admitted.append(req)
        # skipped requests rejoin the queue in FCFS order
        for req in reversed(skipped):
            self.waiting.appendleft(req)

        for req in self.running:
            self.adapters.touch(req.adapter, now)
        return StepPlan(admitted=admitted, preempted=preempted,
                        cold_loads=cold_loads, running=list(self.running))

    # ------------------------------------------------------------------ #
    @property
    def n_waiting(self) -> int:
        return len(self.waiting)

    @property
    def n_running(self) -> int:
        return len(self.running)

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

"""Continuous-batching scheduler: greedy KV allocation with
preemption-by-recompute, admission order delegated to a pluggable
``SchedulingPolicy`` (default ``fcfs`` = FCFS + vLLM adapter-slot
priority, the paper's fixed scheduler).

This class is shared verbatim by the real engine and the Digital Twin —
the paper's DT replicates scheduling *logic* exactly (Fig. 8: "As vLLM, we
use a FCFS policy and a greedy allocation of KV cache"); only step *times*
and memory *capacity* differ (measured vs estimated).  The policy seam
(``repro.serving.policy``) keeps that replication intact: the same policy
instance drives identical decisions here and in the struct-of-arrays
``FastEngine``.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional, Set, Union

from .adapter_cache import AdapterSlotCache
from .kv_cache import PagedKVCache
from .policy import (SchedulingPolicy, SchedView, make_sched_policy,
                     overrides_victim)
from .prefix_cache import SharedPrefixCache
from .request import Request


@dataclasses.dataclass
class StepPlan:
    admitted: List[Request]          # requests prefilling this step
    preempted: List[Request]
    cold_loads: List[int]            # adapter uids loaded from host this step
    running: List[Request]           # full running batch (incl. admitted)
    # prompt tokens served from the shared-prefix cache this step: the
    # Eq. (1) prefill term (and every executor's) skips them
    prefill_covered: int = 0

    @property
    def unique_adapters(self) -> Set[int]:
        return {r.adapter for r in self.running}

    @property
    def prefill_tokens(self) -> int:
        return sum(r.context_len for r in self.admitted) \
            - self.prefill_covered


class _RequestView(SchedView):
    """Policy accessors over ``Request`` objects."""

    __slots__ = ("_adapters",)

    def __init__(self, adapters: AdapterSlotCache):
        self._adapters = adapters

    def arrival(self, req: Request) -> float:
        return req.arrival

    def adapter(self, req: Request) -> int:
        return req.adapter

    def context_len(self, req: Request) -> int:
        return req.context_len

    def resident(self, adapter: int) -> bool:
        return self._adapters.is_loaded(adapter)


class Scheduler:
    def __init__(self, kv: PagedKVCache, adapters: AdapterSlotCache,
                 max_running: int = 256,
                 policy: Union[str, SchedulingPolicy] = "fcfs",
                 prefix: Optional[SharedPrefixCache] = None):
        self.kv = kv
        self.adapters = adapters
        self.prefix = prefix
        self.max_running = max_running
        self.policy = make_sched_policy(policy)
        self._view = _RequestView(adapters)
        self._custom_victim = overrides_victim(self.policy)
        self.waiting: Deque[Request] = deque()
        self.running: List[Request] = []
        self._pos: dict = {}               # request uid -> index in running

    # ------------------------------------------------------------------ #
    def add(self, reqs: List[Request]) -> None:
        self.waiting.extend(reqs)

    def _append_running(self, req: Request) -> None:
        self._pos[req.uid] = len(self.running)
        self.running.append(req)

    def _remove_running(self, req: Request) -> None:
        """O(1) swap-remove via the uid->index map.  ``list.remove`` on a
        dataclass list is an O(n) field-by-field equality scan — this is
        the engine step's (and the Digital Twin's) hottest removal."""
        i = self._pos.pop(req.uid)
        last = self.running.pop()
        if i < len(self.running):
            self.running[i] = last
            self._pos[last.uid] = i

    def clear(self) -> None:
        """Drop every queued/running request (fault-tolerance drain)."""
        self.running.clear()
        self._pos.clear()
        self.waiting.clear()
        self.policy.reset()

    def finish(self, req: Request) -> None:
        self._remove_running(req)
        self.kv.free(req.uid)
        self.adapters.unpin(req.adapter)
        if self.prefix is not None:
            self.prefix.release(req.uid)

    def _preempt_one(self) -> Optional[Request]:
        """Evict one running request (recompute).  Default rule — the
        most recently arrived — unless the policy overrides ``victim``."""
        if not self.running:
            return None
        if self._custom_victim:
            victim = self.policy.victim(self.running, self._view)
            if victim is None:
                return None
        else:
            victim = max(self.running, key=lambda r: r.arrival)
        self._remove_running(victim)
        self.kv.free(victim.uid)
        self.adapters.unpin(victim.adapter)
        if self.prefix is not None:
            self.prefix.release(victim.uid)
        victim.n_preemptions += 1
        self.waiting.appendleft(victim)
        return victim

    # ------------------------------------------------------------------ #
    def schedule(self, now: float) -> StepPlan:
        admitted: List[Request] = []
        preempted: List[Request] = []
        cold_loads: List[int] = []

        # 1. greedy decode allocation for already-running requests;
        #    preempt (policy victim, default newest-first) on memory
        #    exhaustion.
        for req in list(self.running):
            while not self.kv.allocate(req.uid, 1):
                # S-LoRA: idle adapter weights are evicted from the unified
                # pool before any request is preempted
                if self.adapters.dynamic and \
                        self.adapters.evict_idle_lru() is not None:
                    continue
                # idle (zero-ref) shared prefixes go next — still cheaper
                # than recomputing a live request
                if self.prefix is not None and self.prefix.evict_idle_lru():
                    continue
                victim = self._preempt_one()
                if victim is None:
                    break
                preempted.append(victim)
                if victim is req:
                    break  # req preempted itself; it no longer decodes

        # 2. admissions, in the policy's order.  The mechanical rules are
        #    policy-independent: a request whose adapter cannot get a slot
        #    is skipped (vLLM's loaded-adapter priority — later requests
        #    with loaded adapters pass it), KV exhaustion stops the scan
        #    (head-of-line blocking), and requests preempted in THIS step
        #    stay queued until the next step (no same-step thrash).
        #    Skipped requests keep their place: the waiting queue itself
        #    is never reordered, only the per-step attempt order is.
        just_preempted = {r.uid for r in preempted}
        admitted_uids: Set[int] = set()
        covered_total = 0
        # no admission is possible when the batch is full — skip the
        # policy's ordering work entirely (mirrors the fast path's guard)
        candidates = self.waiting if self.waiting and \
            len(self.running) < self.max_running else ()
        if candidates and self.policy.name != "fcfs":
            candidates = self.policy.order(candidates, self._view, now)
        for req in candidates:
            if len(self.running) >= self.max_running:
                break
            if req.uid in just_preempted:
                continue
            # dynamic (S-LoRA) mode may evict idle adapter weights from the
            # unified pool to make room; every eviction re-runs the full
            # eligibility check (the evicted adapter can be this request's)
            pfx = self.prefix is not None and req.prefix_id is not None \
                and min(req.prefix_len, req.prompt_len) > 0
            covered = want_insert = 0
            if pfx:
                covered, want_insert = self.prefix.plan(
                    req.prefix_id, req.prefix_len, req.prompt_len)
            verdict = "admit"
            while True:
                need_slots = not self.adapters.is_loaded(req.adapter)
                if need_slots and not self.adapters.can_load(req.adapter):
                    verdict = "skip"
                    break
                if covered or want_insert:
                    fits = self.prefix.fit_blocks(
                        covered, want_insert,
                        req.context_len) <= self.kv.free_blocks
                else:
                    fits = self.kv.can_allocate(req.context_len + 1,
                                                uid=req.uid)
                if not fits:
                    if self.adapters.dynamic and \
                            self.adapters.evict_idle_lru() is not None:
                        continue
                    if self.prefix is not None and self.prefix.evict_idle_lru(
                            exclude=req.prefix_id):
                        continue
                    if want_insert:
                        # pool too tight to cache the prefix even after
                        # evicting idle entries: serve uncached (a counted
                        # miss, no insert)
                        want_insert = 0
                        continue
                    verdict = "stop"
                break
            if verdict == "skip":
                continue
            if verdict == "stop":
                break
            if self.adapters.load(req.adapter, now):
                cold_loads.append(req.adapter)
            self.adapters.pin(req.adapter)
            if pfx:
                self.prefix.commit(req.uid, req.prefix_id, covered,
                                   want_insert)
            self.kv.allocate(req.uid,
                             req.context_len + 1 - covered - want_insert)
            covered_total += covered
            req.admitted_at = now
            self._append_running(req)
            admitted.append(req)
            admitted_uids.add(req.uid)
            self.policy.on_admit(req, self._view, now)
        if admitted_uids:
            # remaining requests keep FCFS (arrival) queue order
            self.waiting = deque(r for r in self.waiting
                                 if r.uid not in admitted_uids)

        for req in self.running:
            self.adapters.touch(req.adapter, now)
        return StepPlan(admitted=admitted, preempted=preempted,
                        cold_loads=cold_loads, running=list(self.running),
                        prefill_covered=covered_total)

    # ------------------------------------------------------------------ #
    @property
    def n_waiting(self) -> int:
        return len(self.waiting)

    @property
    def n_running(self) -> int:
        return len(self.running)

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

from .pipeline import DataConfig, TokenPipeline  # noqa

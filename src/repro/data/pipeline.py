"""Synthetic token data pipeline.

Deterministic, seekable (resume from any step without replaying), and
shard-aware: each (data-parallel) host materializes only its slice of the
global batch.  Documents are Zipf-distributed token streams packed into
fixed-length sequences — enough structure for the training loss to fall.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3
    n_image_tokens: int = 0      # VLM: prepend patch embeddings
    d_model: int = 0


class TokenPipeline:
    def __init__(self, cfg: DataConfig,
                 shard: Tuple[int, int] = (0, 1)):
        self.cfg = cfg
        self.shard_idx, self.n_shards = shard
        assert cfg.global_batch % self.n_shards == 0
        self.local_batch = cfg.global_batch // self.n_shards

    def _batch_rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            (self.cfg.seed, step, self.shard_idx))

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Seekable batch: same (seed, step, shard) -> same data."""
        cfg = self.cfg
        rng = self._batch_rng(step)
        # zipf-ish unigram stream with local n-gram structure: tokens are
        # a lagged mixture so next-token prediction is learnable.
        shape = (self.local_batch, cfg.seq_len + 1)
        base = rng.zipf(cfg.zipf_a, size=shape) % cfg.vocab_size
        lag = np.roll(base, 1, axis=1)
        copy_mask = rng.random(shape) < 0.5
        tokens = np.where(copy_mask, (lag * 7 + 11) % cfg.vocab_size, base)
        out = {"tokens": tokens.astype(np.int32)}
        if cfg.n_image_tokens:
            out["img_embeds"] = rng.normal(
                0, 1, (self.local_batch, cfg.n_image_tokens, cfg.d_model)
            ).astype(np.float32)
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1

from .compression import compressed_grad_sync, quantized_psum  # noqa
from .optimizer import AdamWConfig, adamw_init, adamw_update  # noqa
from .train_lib import TrainConfig, init_train_state, make_train_step  # noqa

"""Train-step factory: loss + grad + AdamW, with microbatch accumulation,
remat, and (optional) compressed cross-pod gradient sync.

The returned step is a pure function suitable for ``jax.jit`` with
explicit in/out shardings — the same function the multi-pod dry-run lowers.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from ..models.transformer import Model
from .optimizer import AdamWConfig, adamw_init, adamw_update


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = AdamWConfig()
    microbatches: int = 1        # gradient accumulation
    aux_weight: float = 0.01


def make_train_step(model: Model, tcfg: TrainConfig = TrainConfig()
                    ) -> Callable:
    def loss_fn(params, batch):
        return model.train_loss(params, batch)

    def train_step(params, opt_state, batch):
        if tcfg.microbatches > 1:
            def micro(g_acc_loss, mb):
                g_acc, loss_acc = g_acc_loss
                loss, g = jax.value_and_grad(loss_fn)(params, mb)
                return (jax.tree.map(jnp.add, g_acc, g),
                        loss_acc + loss), None

            mbs = jax.tree.map(
                lambda x: x.reshape(tcfg.microbatches,
                                    x.shape[0] // tcfg.microbatches,
                                    *x.shape[1:]), batch)
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(micro, (zero, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / tcfg.microbatches, grads)
            loss = loss / tcfg.microbatches
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state, info = adamw_update(params, grads, opt_state,
                                               tcfg.optimizer)
        info["loss"] = loss
        return params, opt_state, info

    return train_step


def init_train_state(model: Model, key, tcfg: TrainConfig = TrainConfig()):
    params = model.init(key)
    opt_state = adamw_init(params, tcfg.optimizer)
    return params, opt_state
